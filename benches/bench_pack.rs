//! Bit-packing throughput — turning quantized values into the wire/memory
//! representation and back. Compares the scalar reference path against the
//! dispatched SIMD/block kernels, the fused pipelines, and the threaded
//! variants. Acceptance gates: block pack+unpack ≥ 2x scalar (PR 1), and
//! the dispatched quantize / fused rows ≥ 2x their scalar rows on an AVX2
//! host (PR 4).
//!
//! Dispatched rows carry the resolved ISA level in the label (`[avx2]`,
//! `[sse2]`, `[scalar]`); reference rows say `[ref-scalar]` — a name that
//! stays distinct even when the dispatch resolves to scalar, so
//! `bench_trend.py` never sees duplicate row keys. Bytes per iteration =
//! f32 input + packed output (pack direction) or the reverse (unpack).
//!
//! Set `OMC_BENCH_JSON=1` to also write `BENCH_pack.json` for cross-PR
//! tracking.

use omc_fl::benchkit::{consume, Suite};
use omc_fl::omc::format::FloatFormat;
use omc_fl::omc::pack::{
    pack, pack_scalar, pack_threaded, quantize_transform_pack, unpack,
    unpack_scalar, unpack_transform_into, unpack_transform_into_threaded,
};
use omc_fl::omc::quantize::{quantize_slice, quantize_slice_scalar, quantize_vec};
use omc_fl::util::rng::Xoshiro256pp;
use omc_fl::util::simd;
use omc_fl::util::threadpool::default_workers;

fn main() {
    let isa = simd::kernels().level.label();
    if cfg!(target_arch = "x86_64") && simd::kernels().level != simd::Level::Avx2 {
        // CI greps for this (PR 3 convention): the dispatched rows below
        // would silently measure a lower ISA level, so fail the smoke
        // loudly instead of reporting misleading numbers.
        println!("SKIPPED: bench_pack SIMD rows — AVX2 unavailable (resolved: {isa})");
    }

    let mut suite = Suite::new("omc::pack / unpack / quantize throughput");
    let mut rng = Xoshiro256pp::new(2);
    let n = 262_144usize;
    let workers = default_workers();

    for fmt_s in ["S1E5M10", "S1E4M14", "S1E3M7", "S1E2M3"] {
        let fmt: FloatFormat = fmt_s.parse().unwrap();
        let mut v = vec![0.0f32; n];
        rng.fill_normal(&mut v, 0.05);
        let q = quantize_vec(&v, fmt);
        let bytes = pack(&q, fmt).unwrap();
        let io_pack = 4 * n + bytes.len(); // f32 in + packed out
        let io_q = 8 * n; // f32 in + f32 out

        let mut out_q = vec![0.0f32; n];
        suite.bench_case(
            &format!("quantize [ref-scalar] {fmt_s} n={n}"),
            Some(n),
            Some(io_q),
            || {
                quantize_slice_scalar(&v, fmt, &mut out_q);
                consume(&out_q);
            },
        );
        suite.bench_case(
            &format!("quantize [{isa}]   {fmt_s} n={n}"),
            Some(n),
            Some(io_q),
            || {
                quantize_slice(&v, fmt, &mut out_q);
                consume(&out_q);
            },
        );

        suite.bench_case(
            &format!("pack [ref-scalar]   {fmt_s} n={n}"),
            Some(n),
            Some(io_pack),
            || {
                consume(pack_scalar(&q, fmt).unwrap());
            },
        );
        suite.bench_case(
            &format!("pack [{isa}]       {fmt_s} n={n}"),
            Some(n),
            Some(io_pack),
            || {
                consume(pack(&q, fmt).unwrap());
            },
        );
        let mut payload = Vec::new();
        suite.bench_case(
            &format!("fused q+f+p [{isa}] {fmt_s} n={n}"),
            Some(n),
            Some(io_pack),
            || {
                payload.clear();
                consume(quantize_transform_pack(&v, fmt, true, &mut payload));
            },
        );
        if workers > 1 {
            suite.bench_case(
                &format!("pack thr({workers}) [{isa}] {fmt_s} n={n}"),
                Some(n),
                Some(io_pack),
                || {
                    consume(pack_threaded(&q, fmt, workers).unwrap());
                },
            );
        }

        suite.bench_case(
            &format!("unpack [ref-scalar] {fmt_s} n={n}"),
            Some(n),
            Some(io_pack),
            || {
                consume(unpack_scalar(&bytes, n, fmt));
            },
        );
        suite.bench_case(
            &format!("unpack [{isa}]     {fmt_s} n={n}"),
            Some(n),
            Some(io_pack),
            || {
                consume(unpack(&bytes, n, fmt));
            },
        );
        let mut out = Vec::new();
        suite.bench_case(
            &format!("unpack+xform [{isa}] {fmt_s} n={n}"),
            Some(n),
            Some(io_pack),
            || {
                unpack_transform_into(&bytes, n, fmt, 1.25, -0.5, &mut out);
                consume(&out);
            },
        );
        if workers > 1 {
            suite.bench_case(
                &format!("unpack thr({workers}) [{isa}] {fmt_s} n={n}"),
                Some(n),
                Some(io_pack),
                || {
                    unpack_transform_into_threaded(
                        &bytes, n, fmt, 1.25, -0.5, workers, &mut out,
                    );
                    consume(&out);
                },
            );
        }
    }

    suite.finish("BENCH_pack.json");
}
