//! Bit-packing throughput — turning quantized values into the wire/memory
//! representation and back. Compares the scalar reference path against the
//! block/word kernels, the fused pipelines, and the threaded variants (the
//! acceptance gate for the block-codec work: pack+unpack ≥ 2x scalar on
//! S1E5M10 and S1E3M7).
//!
//! Set `OMC_BENCH_JSON=1` to also write `BENCH_pack.json` for cross-PR
//! tracking.

use omc_fl::benchkit::{consume, Suite};
use omc_fl::omc::format::FloatFormat;
use omc_fl::omc::pack::{
    pack, pack_scalar, pack_threaded, quantize_transform_pack, unpack,
    unpack_scalar, unpack_transform_into, unpack_transform_into_threaded,
};
use omc_fl::omc::quantize::quantize_vec;
use omc_fl::util::rng::Xoshiro256pp;
use omc_fl::util::threadpool::default_workers;

fn main() {
    let mut suite = Suite::new("omc::pack / unpack throughput");
    let mut rng = Xoshiro256pp::new(2);
    let n = 262_144usize;
    let workers = default_workers();

    for fmt_s in ["S1E5M10", "S1E4M14", "S1E3M7", "S1E2M3"] {
        let fmt: FloatFormat = fmt_s.parse().unwrap();
        let mut v = vec![0.0f32; n];
        rng.fill_normal(&mut v, 0.05);
        let q = quantize_vec(&v, fmt);
        let bytes = pack(&q, fmt).unwrap();

        suite.bench(&format!("pack scalar   {fmt_s} n={n}"), Some(n), || {
            consume(pack_scalar(&q, fmt).unwrap());
        });
        suite.bench(&format!("pack block    {fmt_s} n={n}"), Some(n), || {
            consume(pack(&q, fmt).unwrap());
        });
        let mut payload = Vec::new();
        suite.bench(&format!("fused q+f+p   {fmt_s} n={n}"), Some(n), || {
            payload.clear();
            consume(quantize_transform_pack(&v, fmt, true, &mut payload));
        });
        if workers > 1 {
            suite.bench(
                &format!("pack thr({workers})   {fmt_s} n={n}"),
                Some(n),
                || {
                    consume(pack_threaded(&q, fmt, workers).unwrap());
                },
            );
        }

        suite.bench(&format!("unpack scalar {fmt_s} n={n}"), Some(n), || {
            consume(unpack_scalar(&bytes, n, fmt));
        });
        suite.bench(&format!("unpack block  {fmt_s} n={n}"), Some(n), || {
            consume(unpack(&bytes, n, fmt));
        });
        let mut out = Vec::new();
        suite.bench(&format!("unpack+xform  {fmt_s} n={n}"), Some(n), || {
            unpack_transform_into(&bytes, n, fmt, 1.25, -0.5, &mut out);
            consume(&out);
        });
        if workers > 1 {
            suite.bench(
                &format!("unpack thr({workers}) {fmt_s} n={n}"),
                Some(n),
                || {
                    unpack_transform_into_threaded(
                        &bytes, n, fmt, 1.25, -0.5, workers, &mut out,
                    );
                    consume(&out);
                },
            );
        }
    }

    suite.finish("BENCH_pack.json");
}
