//! Bit-packing throughput — turning quantized values into the wire/memory
//! representation and back.

use omc_fl::benchkit::{consume, Suite};
use omc_fl::omc::format::FloatFormat;
use omc_fl::omc::pack::{pack, unpack};
use omc_fl::omc::quantize::quantize_vec;
use omc_fl::util::rng::Xoshiro256pp;

fn main() {
    let mut suite = Suite::new("omc::pack / unpack throughput");
    let mut rng = Xoshiro256pp::new(2);
    let n = 262_144usize;

    for fmt_s in ["S1E5M10", "S1E4M14", "S1E3M7", "S1E2M3"] {
        let fmt: FloatFormat = fmt_s.parse().unwrap();
        let mut v = vec![0.0f32; n];
        rng.fill_normal(&mut v, 0.05);
        let q = quantize_vec(&v, fmt);
        let bytes = pack(&q, fmt).unwrap();
        suite.bench(&format!("pack   {fmt_s} n={n}"), Some(n), || {
            consume(pack(&q, fmt).unwrap());
        });
        suite.bench(&format!("unpack {fmt_s} n={n}"), Some(n), || {
            consume(unpack(&bytes, n, fmt));
        });
    }

    suite.report();
}
