//! Quantizer throughput across the paper's formats and variable sizes —
//! the L3-side half of OMC's per-round compression cost. Rows are labeled
//! with the resolved SIMD dispatch level; the scalar-vs-dispatched pair
//! lives in `bench_pack` (one suite owns the comparison rows so the JSON
//! trajectory has a single source). Bytes per iteration = f32 in + out.

use omc_fl::benchkit::{consume, Suite};
use omc_fl::omc::format::FloatFormat;
use omc_fl::omc::quantize::{quantize_slice, quantize_vec};
use omc_fl::util::rng::Xoshiro256pp;
use omc_fl::util::simd;

fn main() {
    let mut suite = Suite::new("omc::quantize throughput");
    let mut rng = Xoshiro256pp::new(1);
    let isa = simd::kernels().level.label();

    for fmt_s in ["S1E5M10", "S1E4M14", "S1E3M7", "S1E2M3"] {
        let fmt: FloatFormat = fmt_s.parse().unwrap();
        for n in [4_096usize, 262_144] {
            let mut v = vec![0.0f32; n];
            rng.fill_normal(&mut v, 0.05);
            let mut out = vec![0.0f32; n];
            suite.bench_case(
                &format!("quantize [{isa}] {fmt_s} n={n}"),
                Some(n),
                Some(8 * n),
                || {
                    quantize_slice(&v, fmt, &mut out);
                    consume(&out);
                },
            );
        }
    }

    // fp32 passthrough should be a memcpy
    let n = 262_144;
    let mut v = vec![0.0f32; n];
    rng.fill_normal(&mut v, 0.05);
    suite.bench_case(
        "quantize S1E8M23 (identity) n=262144",
        Some(n),
        Some(8 * n),
        || {
            consume(quantize_vec(&v, FloatFormat::FP32));
        },
    );

    suite.finish("BENCH_quantize.json");
}
