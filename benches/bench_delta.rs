//! Cross-round delta wire stage: the XOR + per-block bitpack kernels and
//! the v3 frame write/decode path around them — the per-client uplink
//! cost the delta stage adds on top of the plain codec. The bench-trend
//! gate tracks these rows (`--strict-suites delta`): the kernels must
//! stay in GB/s territory or the stage would dominate the round loop it
//! is meant to shrink.

use omc_fl::benchkit::{consume, Suite};
use omc_fl::omc::codec::{DeltaScratch, WireWriter};
use omc_fl::omc::delta::{xor_decode_into, xor_encode_into, DeltaBase};
use omc_fl::omc::format::FloatFormat;
use omc_fl::omc::store::{CompressedModel, StoredVar};
use omc_fl::testkit::{decode_all_based, Gen};
use omc_fl::util::simd;

fn main() {
    let mut suite = Suite::new("omc::delta cross-round wire stage");
    let mut g = Gen::new(11);
    let isa = simd::kernels().level.label();

    // ---- kernel regimes over a 4 MiB packed payload --------------------
    let n = 4 << 20;
    let base: Vec<u8> = (0..n).map(|_| (g.u64() & 0xFF) as u8).collect();

    // converged regime: identical payload, every block zero-width
    let same = base.clone();
    // sparse regime: ~0.1% of bytes moved (the paper's cross-round drift)
    let mut sparse = base.clone();
    for _ in 0..n / 1000 {
        let i = g.usize_below(n);
        sparse[i] ^= (g.u64() & 0xFF) as u8;
    }
    // dense regime: independent payload, the fallback-triggering worst case
    let dense: Vec<u8> = (0..n).map(|_| (g.u64() & 0xFF) as u8).collect();

    let mut xored = Vec::new();
    let mut stream = Vec::new();
    for (label, cur) in [
        ("zero-delta", &same),
        ("sparse 0.1%", &sparse),
        ("dense random", &dense),
    ] {
        suite.bench(
            &format!("xor+bitpack encode [{isa}] {label} (4 MiB)"),
            Some(n),
            || {
                consume(xor_encode_into(cur, &base, &mut xored, &mut stream));
            },
        );
    }

    // decode side: unpack + XOR back against the base, sparse regime
    let slen = xor_encode_into(&sparse, &base, &mut xored, &mut stream);
    let mut words = Vec::new();
    let mut payload = Vec::new();
    suite.bench(
        &format!("bitunpack+xor decode [{isa}] sparse ({slen} B stream)"),
        Some(n),
        || {
            consume(
                xor_decode_into(&stream, &base, &mut words, &mut payload)
                    .unwrap(),
            );
        },
    );

    // ---- whole-frame path: v3 write + based decode ---------------------
    let fmt: FloatFormat = "S1E3M7".parse().unwrap();
    let weights = g.vec_normal(1 << 20, 0.05);
    let base_model =
        CompressedModel::new(vec![StoredVar::compress(&weights, fmt, true)]);
    // drift a copy the way converging training does: a few payload bytes
    let cur_model = {
        let mut m = base_model.clone();
        if let StoredVar::Packed { bytes, .. } = &mut m.vars[0] {
            for _ in 0..64 {
                let i = g.usize_below(bytes.len());
                bytes[i] ^= (g.u64() & 0xFF) as u8;
            }
        }
        m
    };
    let dbase = DeltaBase::from_model(1, &base_model);
    let total = weights.len();
    let mut scratch = DeltaScratch::default();
    suite.bench(
        &format!("WireWriter v3 var_delta ({total} params)"),
        Some(total),
        || {
            let mut w = WireWriter::with_delta(0, 7, 1);
            for (i, v) in cur_model.vars.iter().enumerate() {
                w.var_delta(v, dbase.var(i), &mut scratch);
            }
            consume(w.finish());
        },
    );
    let mut w = WireWriter::with_delta(0, 7, 1);
    for (i, v) in cur_model.vars.iter().enumerate() {
        w.var_delta(v, dbase.var(i), &mut scratch);
    }
    let wire = w.finish();
    suite.bench(
        &format!(
            "decode_all_based v3 ({} KiB frame)",
            wire.len() / 1024
        ),
        Some(total),
        || {
            consume(decode_all_based(&wire, Some(&dbase)).unwrap());
        },
    );

    suite.finish("BENCH_delta.json");
}
