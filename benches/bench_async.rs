//! Async round-engine throughput: commits/sec sequential vs pooled, a
//! sync-rounds comparison row, and the snapshot-ring memory/compression
//! row. Runs entirely on the native backend (`native:tiny`), so it needs
//! no artifacts and no `pjrt` feature — this bench can never silently
//! self-skip.
//!
//! The pooled row measures the wave-training parallelism only: the async
//! engine folds every commit centrally in plan order, so pooled and
//! sequential runs produce *byte-identical* committed models — asserted
//! here on every iteration, making the bench double as a determinism
//! smoke (the same property the CI `async-determinism` leg gates).

use std::path::Path;

use omc_fl::benchkit::Suite;
use omc_fl::coordinator::config::{ExperimentConfig, OmcConfig};
use omc_fl::coordinator::Experiment;
use omc_fl::fl::async_round::{self, AsyncConfig, StalenessPolicy};
use omc_fl::omc::selection::SelectionPolicy;
use omc_fl::omc::store::SnapshotRing;
use omc_fl::runtime::engine::Engine;

const COMMITS: usize = 6;

fn cfg(name: &str, workers: usize, async_on: bool) -> ExperimentConfig {
    let mut c = ExperimentConfig::default_with(name, Path::new("native:tiny"));
    c.rounds = COMMITS;
    c.num_clients = 16;
    c.clients_per_round = 8;
    c.local_steps = 1;
    c.lr = 0.2;
    c.eval_every = COMMITS + 1; // only the mandatory final eval
    c.eval_batches = 1;
    c.workers = workers;
    c.omc = OmcConfig {
        format: "S1E4M14".parse().unwrap(),
        use_pvt: true,
        weights_only: true,
        fraction: 1.0,
        integrity: false,
    };
    c.cohort.straggler_mean_s = 2.0;
    if async_on {
        c.async_cfg = AsyncConfig {
            enabled: true,
            concurrency: 8,
            buffer_k: 4,
            policy: StalenessPolicy::Polynomial { alpha: 0.5 },
            max_staleness: usize::MAX,
            snapshot_ring: 4,
        };
    }
    c
}

fn run_params(engine: &Engine, cfg: ExperimentConfig) -> Vec<Vec<u32>> {
    let mut exp = Experiment::prepare(engine, cfg).expect("prepare");
    exp.run().expect("run");
    exp.server
        .params
        .iter()
        .map(|v| v.iter().map(|x| x.to_bits()).collect())
        .collect()
}

fn main() {
    let engine = match Engine::cpu() {
        Ok(e) => e,
        Err(e) => {
            // unreachable in default builds (the native engine always
            // constructs); kept so a failure is loud, not a fake pass
            println!("SKIPPED: bench_async — engine unavailable: {e}");
            return;
        }
    };

    let mut suite = Suite::new(&format!(
        "async round engine ({COMMITS} commits, K=4, conc=8, native:tiny)"
    ));

    let seq_bits = run_params(&engine, cfg("async_seq_probe", 1, true));
    suite.bench(
        &format!("async {COMMITS} commits sequential [workers=1]"),
        Some(COMMITS),
        || {
            let bits = run_params(&engine, cfg("async_seq", 1, true));
            assert_eq!(bits, seq_bits, "sequential run became nondeterministic");
        },
    );
    for workers in [2usize, 4] {
        suite.bench(
            &format!("async {COMMITS} commits pooled [workers={workers}]"),
            Some(COMMITS),
            || {
                let bits =
                    run_params(&engine, cfg("async_pool", workers, true));
                assert_eq!(
                    bits, seq_bits,
                    "pooled committed bytes diverged from sequential"
                );
            },
        );
    }
    // the sync engine on the same transport shape, for the rounds/sec
    // comparison column (not byte-comparable: different aggregation order)
    suite.bench(
        &format!("sync {COMMITS} rounds [workers=1] (reference)"),
        Some(COMMITS),
        || {
            let _ = run_params(&engine, cfg("sync_ref", 1, false));
        },
    );

    // snapshot-ring row: compress-and-push a committed model version at
    // the experiment format. `elems` = params, `bytes` = the compressed
    // snapshot size, so the row reads as snapshot GB/s; the printed line
    // below is the ring-memory accounting the baselines README references.
    let exp = Experiment::prepare(&engine, cfg("ring_probe", 1, true)).expect("prepare");
    let params = exp.server.params.clone();
    let specs = exp.model.manifest.variables.clone();
    let n_params: usize = params.iter().map(|v| v.len()).sum();
    let policy = SelectionPolicy {
        weights_only: true,
        fraction: 1.0,
    };
    let fmt = "S1E4M14".parse().unwrap();
    let snap = async_round::snapshot_model(&params, &specs, &policy, fmt, true, 1);
    let snap_bytes = snap.memory_bytes();
    let mut ring = SnapshotRing::new(4);
    let mut version = 0usize;
    suite.bench_case(
        "snapshot ring push (compress one version)",
        Some(n_params),
        Some(snap_bytes),
        || {
            ring.push(
                version,
                async_round::snapshot_model(&params, &specs, &policy, fmt, true, 1),
            );
            version += 1;
        },
    );

    suite.finish("BENCH_async.json");
    for r in suite.results() {
        if r.name.contains("commits") || r.name.contains("rounds") {
            println!(
                "  {}: {:.2} commits/s",
                r.name,
                COMMITS as f64 / (r.median_ns / 1e9)
            );
        }
    }
    let ring_full = 4 * snap_bytes;
    let ring_fp32 = 4 * n_params * 4;
    println!(
        "  snapshot ring memory (R=4, S1E4M14): {} vs {} fp32 ({:.0}% of fp32)",
        ring_full,
        ring_fp32,
        100.0 * ring_full as f64 / ring_fp32 as f64
    );
}
