//! Fleet-scale population substrate: lazy `(seed, cid)` profile
//! derivation, streaming cohort sampling out of 10^6–10^7 registered
//! clients, and the edge→root merged-frame hop. The bench-trend gate
//! tracks these rows (`--strict-suites population` once a baseline is
//! blessed): sampling must stay O(cohort) regardless of the registered
//! fleet — the whole point of the lazy design — and the edge hop must
//! move sums at codec-class throughput or the two-tier topology would
//! cost more than the uplinks it merges.

use omc_fl::benchkit::{consume, Suite};
use omc_fl::fl::population::{
    self, PopulationConfig, DEVICE_CLASSES, NUM_CLASSES,
};
use omc_fl::fl::server::StreamingAggregator;
use omc_fl::omc::codec::NonceLedger;
use omc_fl::testkit::Gen;

fn fleet(registered: usize) -> PopulationConfig {
    PopulationConfig {
        enabled: true,
        registered,
        edges: 4,
        churn_rate: 0.3,
        churn_period: 2,
        wave_amplitude: 0.5,
        wave_period: 6,
    }
}

fn main() {
    let mut suite = Suite::new("fl::population fleet-scale substrate");
    let mut g = Gen::new(13);
    let seed = 0xF1EE7u64;

    // ---- streaming cohort sampling: clients/sec drawn -------------------
    // The registered axis is the point: 10x the fleet must not change the
    // work per sampled client (rejection rates depend on churn/wave knobs,
    // not on `registered`).
    let k = 64;
    for registered in [1_000_000usize, 10_000_000] {
        let cfg = fleet(registered);
        let mut round = 0u64;
        suite.bench(
            &format!("sample_cohort k={k} of {registered} registered"),
            Some(k),
            || {
                let (cohort, _) =
                    population::sample_cohort(&cfg, seed, round, k).unwrap();
                round += 1;
                consume(cohort.len());
            },
        );
    }

    // ---- lazy per-client state: profiles/sec derived ---------------------
    // Strided cids spanning the whole 10^7 space — nothing is materialized,
    // so position in the fleet cannot matter.
    let cfg7 = fleet(10_000_000);
    let n_profiles = 10_000usize;
    let stride = cfg7.registered / n_profiles;
    suite.bench(
        &format!("derive_profile x{n_profiles} across 10^7 fleet"),
        Some(n_profiles),
        || {
            let mut acc = 0usize;
            for i in 0..n_profiles {
                acc += population::derive_profile(&cfg7, seed, i * stride).class;
            }
            consume(acc);
        },
    );
    suite.bench(
        &format!("availability x{n_profiles} (churn + wave gates)"),
        Some(n_profiles),
        || {
            let mut active = 0usize;
            for i in 0..n_profiles {
                if matches!(
                    population::availability(&cfg7, seed, 3, i * stride),
                    population::Availability::Active
                ) {
                    active += 1;
                }
            }
            consume(active);
        },
    );

    // ---- edge→root hop: merged-frame encode/decode throughput ------------
    let var_lens = [1usize << 18, 1 << 18];
    let total: usize = var_lens.iter().sum();
    let model: Vec<Vec<f32>> =
        var_lens.iter().map(|&n| g.vec_normal(n, 0.05)).collect();
    let mut edge = StreamingAggregator::new(&var_lens);
    for c in 0..8 {
        edge.accumulate_model(&model, 1.0 / 8.0)
            .unwrap_or_else(|e| panic!("fold client {c}: {e}"));
    }
    let nonce = population::edge_nonce(seed, 0, 0);
    suite.bench(
        &format!("encode_edge_frame verbatim ({total} params, CRC)"),
        Some(total),
        || {
            consume(
                population::encode_edge_frame(&edge, true, nonce, false, &[])
                    .shipped
                    .len(),
            );
        },
    );
    let frame = population::encode_edge_frame(&edge, true, nonce, false, &[]);
    suite.bench(
        &format!(
            "decode_edge_frame verbatim ({} KiB shipped)",
            frame.shipped.len() / 1024
        ),
        Some(total),
        || {
            let mut root = StreamingAggregator::new(&var_lens);
            let mut ledger = NonceLedger::new(8);
            consume(
                population::decode_edge_frame(
                    &frame.shipped,
                    &[],
                    &mut root,
                    &mut ledger,
                    Some(nonce),
                )
                .unwrap()
                .len(),
            );
        },
    );
    // converged regime: identical sums round-over-round, the delta hop
    // collapses the shipped frame (EDGE_TAG_DELTA + zero-width blocks)
    suite.bench(
        &format!("encode_edge_frame delta vs identical prev ({total} params)"),
        Some(total),
        || {
            consume(
                population::encode_edge_frame(
                    &edge,
                    true,
                    nonce,
                    true,
                    &frame.verbatim,
                )
                .shipped
                .len(),
            );
        },
    );

    // ---- O(active) memory: the structural claim, asserted ----------------
    // Accounted state after sampling + one full edge fold is identical for
    // a 10^6 and a 10^7 fleet: cohort vectors are O(k) and aggregators are
    // O(params); nothing scales with `registered`.
    let mut footprints = [0usize; 2];
    for (slot, registered) in [1_000_000usize, 10_000_000].iter().enumerate() {
        let cfg = fleet(*registered);
        let (cohort, stats) =
            population::sample_cohort(&cfg, seed, 0, k).unwrap();
        assert_eq!(cohort.len(), k);
        assert!(stats.attempts >= k as u64);
        let root = StreamingAggregator::new(&var_lens);
        footprints[slot] =
            root.memory_bytes() + cohort.len() * std::mem::size_of::<usize>();
    }
    assert_eq!(
        footprints[0], footprints[1],
        "peak accounted bytes must not scale with the registered fleet"
    );
    assert!(
        footprints[0] < 16 << 20,
        "O(active) footprint blew past 16 MiB: {} B",
        footprints[0]
    );
    println!(
        "# O(active) check: {} B accounted at 10^6 and 10^7 registered \
         ({} device classes: {:?})",
        footprints[0],
        NUM_CLASSES,
        DEVICE_CLASSES.map(|c| c.name),
    );

    suite.finish("BENCH_population.json");
}
