//! Whole-model wire codec: the per-client downlink build (quantize + PVT +
//! pack + frame) and uplink decode — the L3 hot path around each PJRT call.

use omc_fl::benchkit::{consume, Suite};
use omc_fl::fl::client::make_downlink;
use omc_fl::omc::codec::{
    decode, decode_decompressed, encode, verify_frame, Encoder, WireWriter,
};
use omc_fl::omc::format::FloatFormat;
use omc_fl::omc::store::{CompressedModel, StoredVar};
use omc_fl::util::rng::Xoshiro256pp;
use omc_fl::util::simd;

fn main() {
    let mut suite = Suite::new("omc::codec whole-model wire path");
    let mut rng = Xoshiro256pp::new(4);
    // a small_streaming-like model: 72 vars, ~200k params, 90% weights
    let mut global = Vec::new();
    let mut mask = Vec::new();
    for i in 0..72usize {
        let n = if i % 12 == 0 { 64 } else { 2_900 };
        let mut v = vec![0.0f32; n];
        rng.fill_normal(&mut v, 0.05);
        global.push(v);
        mask.push(if i % 12 == 0 { 0.0 } else { 1.0 });
    }
    let total: usize = global.iter().map(|v| v.len()).sum();
    let fmt: FloatFormat = "S1E3M7".parse().unwrap();

    suite.bench(
        &format!("make_downlink S1E3M7 ({total} params)"),
        Some(total),
        || {
            consume(make_downlink(&global, &mask, fmt, true));
        },
    );
    suite.bench(
        &format!("make_downlink FP32 ({total} params)"),
        Some(total),
        || {
            consume(make_downlink(&global, &mask, FloatFormat::FP32, true));
        },
    );

    let wire = make_downlink(&global, &mask, fmt, true);
    suite.bench("decode + decompress_all", Some(total), || {
        consume(decode(&wire).unwrap().decompress_all());
    });
    suite.bench("decode_decompressed (fused)", Some(total), || {
        consume(decode_decompressed(&wire).unwrap());
    });

    // wire-integrity overhead, isolated: the raw CRC32C kernel over a
    // whole-model frame (dispatched vs reference), then verify_frame on
    // the v1 layout (structural walk only — the integrity-off fast path)
    // and on the checksummed v2 layout (header + per-var CRC)
    let isa = simd::kernels().level.label();
    suite.bench(
        &format!("crc32c [{isa}] ({} KiB frame)", wire.len() / 1024),
        Some(wire.len()),
        || {
            consume(simd::crc32c(0, &wire));
        },
    );
    suite.bench(
        &format!("crc32c [ref-scalar] ({} KiB frame)", wire.len() / 1024),
        Some(wire.len()),
        || {
            consume(simd::crc32c_reference(0, &wire));
        },
    );
    let mut w2 = WireWriter::with_integrity(0, 0xC4A05);
    for (v, &m) in global.iter().zip(&mask) {
        if m > 0.5 {
            w2.compress_values(v, fmt, true);
        } else {
            w2.raw(v);
        }
    }
    let wire2 = w2.finish();
    suite.bench("verify_frame v1 (structural walk)", Some(total), || {
        consume(verify_frame(&wire).unwrap().nvars);
    });
    suite.bench("verify_frame v2 (CRC all vars)", Some(total), || {
        consume(verify_frame(&wire2).unwrap().nvars);
    });

    let model = CompressedModel::new(
        global
            .iter()
            .zip(&mask)
            .map(|(v, &m)| {
                if m > 0.5 {
                    StoredVar::compress(v, fmt, true)
                } else {
                    StoredVar::raw(v.clone())
                }
            })
            .collect(),
    );
    suite.bench("encode (pre-compressed model)", Some(total), || {
        consume(encode(&model));
    });
    let mut enc = Encoder::new();
    suite.bench("encode (recycled Encoder buf)", Some(total), || {
        consume(enc.encode(&model).len());
    });

    suite.finish("BENCH_codec.json");
}
