//! Uplink sparsification stage: deterministic top-k / rand-k selection,
//! the gap-coded bitpacked index stream, and the tag-3 wire record
//! write/decode path around them — the per-client uplink cost the sparse
//! stage adds on top of the plain codec. The bench-trend gate tracks
//! these rows (`--strict-suites sparse`): selection and index coding
//! must stay far below the quantize/pack cost of the values they
//! replace, or the stage would dominate the round loop it is meant to
//! shrink.

use omc_fl::benchkit::{consume, Suite};
use omc_fl::omc::codec::{for_each_var, WireWriter};
use omc_fl::omc::format::FloatFormat;
use omc_fl::omc::sparse::{
    decode_indices_into, encode_indices_into, gather_into, select_count,
    select_randk, select_topk,
};
use omc_fl::testkit::Gen;

fn main() {
    let mut suite = Suite::new("omc::sparse uplink selection stage");
    let mut g = Gen::new(13);

    // ---- selection kernels over a 1M-element update --------------------
    let n = 1 << 20;
    let e: Vec<f32> = g.vec_normal(n, 0.05);

    let mut idx = Vec::new();
    for &(label, fraction) in &[("25%", 0.25f32), ("1%", 0.01f32)] {
        let k = select_count(n, fraction);
        suite.bench(
            &format!("select_topk {label} ({n} elems)"),
            Some(n),
            || {
                select_topk(&e, k, &mut idx);
                consume(idx.len());
            },
        );
    }
    let k1 = select_count(n, 0.01);
    let mut scratch = Vec::new();
    suite.bench(&format!("select_randk 1% ({n} elems)"), Some(n), || {
        select_randk(n, k1, 0xC0FFEE, &mut idx, &mut scratch);
        consume(idx.len());
    });

    // ---- index stream codec at the 1% top-k selection ------------------
    select_topk(&e, k1, &mut idx);
    let mut stream = Vec::new();
    suite.bench(&format!("encode_indices ({k1} of {n})"), Some(k1), || {
        stream.clear();
        consume(encode_indices_into(&idx, &mut stream));
    });
    stream.clear();
    encode_indices_into(&idx, &mut stream);
    let mut back = Vec::new();
    suite.bench(
        &format!("decode_indices ({} B stream)", stream.len()),
        Some(k1),
        || {
            decode_indices_into(&stream, k1, n, &mut back).unwrap();
            consume(back.len());
        },
    );

    // ---- whole-record path: tag-3 write + decode to the dense update ---
    let fmt: FloatFormat = "S1E4M14".parse().unwrap();
    let mut gathered = Vec::new();
    gather_into(&e, &idx, &mut gathered);
    suite.bench(
        &format!("WireWriter v2 sparse_values ({k1} of {n})"),
        Some(n),
        || {
            let mut w = WireWriter::with_integrity(0, 7);
            w.sparse_values(&gathered, &idx, n, fmt, true);
            consume(w.finish());
        },
    );
    let mut w = WireWriter::with_integrity(0, 7);
    w.sparse_values(&gathered, &idx, n, fmt, true);
    let wire = w.finish();
    let mut dense = Vec::new();
    suite.bench(
        &format!(
            "decode sparse to dense update ({} KiB frame)",
            wire.len() / 1024
        ),
        Some(n),
        || {
            let count = for_each_var(&wire, |_, view| {
                view.decompress_into(&mut dense);
                Ok(())
            })
            .unwrap();
            consume(count);
        },
    );

    suite.finish("BENCH_sparse.json");
}
