//! Sweep-engine throughput: cells/sec for the smoke grid, sequential vs
//! pooled over the thread pool. Runs entirely on the native backend
//! (`native:tiny`), so it needs no artifacts and no `pjrt` feature — this
//! bench can never silently self-skip.
//!
//! The pooled row measures the *scheduling* win only: cells are
//! self-contained (intra-cell workers pinned to 1), so pooled and
//! sequential runs produce byte-identical summaries — asserted here on
//! every iteration's output so the bench doubles as a determinism smoke.

use omc_fl::benchkit::Suite;
use omc_fl::coordinator::sweep::{self, SweepOptions};
use omc_fl::runtime::engine::Engine;

fn main() {
    let engine = match Engine::cpu() {
        Ok(e) => e,
        Err(e) => {
            // unreachable in default builds (the native engine always
            // constructs); kept so a failure is loud, not a fake pass
            println!("SKIPPED: bench_sweep — engine unavailable: {e}");
            return;
        }
    };
    let out_root = std::env::temp_dir().join(format!(
        "omc_bench_sweep_{}",
        std::process::id()
    ));
    let spec_for = |dir: &str| {
        let mut spec = sweep::smoke(42).expect("smoke spec");
        spec.output_dir = out_root.join(dir);
        spec
    };
    let n_cells = spec_for("probe").cells.len();

    let mut suite = Suite::new(&format!(
        "sweep engine (smoke grid, {n_cells} cells, native:tiny)"
    ));
    suite.min_time_s = suite.min_time_s.min(2.0);

    let seq_spec = spec_for("seq");
    let seq_opts = SweepOptions {
        workers: 1,
        sequential: true,
        resume: false,
    };
    let mut seq_bytes = String::new();
    suite.bench(
        &format!("sweep {n_cells} cells sequential"),
        Some(n_cells),
        || {
            let report =
                sweep::run_sweep(&engine, &seq_spec, &seq_opts).expect("sweep");
            seq_bytes = report.summary_bytes;
        },
    );

    for workers in [2usize, 4] {
        let spec = spec_for(&format!("pool{workers}"));
        let opts = SweepOptions {
            workers,
            sequential: false,
            resume: false,
        };
        suite.bench(
            &format!("sweep {n_cells} cells pooled (workers={workers})"),
            Some(n_cells),
            || {
                let report =
                    sweep::run_sweep(&engine, &spec, &opts).expect("sweep");
                assert_eq!(
                    report.summary_bytes, seq_bytes,
                    "pooled summary bytes diverged from sequential"
                );
            },
        );
    }

    // resume throughput: every cell already has a matching summary
    let resume_spec = spec_for("seq");
    let resume_opts = SweepOptions {
        workers: 1,
        sequential: true,
        resume: true,
    };
    suite.bench(
        &format!("sweep {n_cells} cells resumed (all cached)"),
        Some(n_cells),
        || {
            let report = sweep::run_sweep(&engine, &resume_spec, &resume_opts)
                .expect("sweep");
            assert_eq!(report.cells_resumed, n_cells);
            assert_eq!(report.summary_bytes, seq_bytes);
        },
    );

    suite.finish("BENCH_sweep.json");
    for r in suite.results() {
        println!(
            "  {}: {:.1} cells/s",
            r.name,
            n_cells as f64 / (r.median_ns / 1e9)
        );
    }
    std::fs::remove_dir_all(&out_root).ok();
}
