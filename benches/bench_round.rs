//! End-to-end federated round latency, FP32 vs OMC — the micro version of
//! the Tables' "Speed (Rounds/Min)" column. Needs `make artifacts`.

use std::sync::Arc;

use omc_fl::benchkit::Suite;
use omc_fl::coordinator::config::{ExperimentConfig, OmcConfig};
use omc_fl::coordinator::experiment::Experiment;
use omc_fl::runtime::engine::Engine;

fn main() {
    let dir = std::path::Path::new("artifacts/tiny");
    if !dir.exists() {
        eprintln!("SKIP bench_round: artifacts/tiny missing — run `make artifacts`");
        return;
    }
    let engine = Engine::cpu().expect("pjrt cpu client");
    let model = Arc::new(engine.load_model(dir).expect("load model"));

    let mut suite = Suite::new("end-to-end federated round (tiny model, 4 clients)");
    // rounds are ~100 ms; cap the sample budget
    suite.min_time_s = suite.min_time_s.min(2.0);

    for (label, omc) in [
        ("round FP32 (S1E8M23)", OmcConfig::fp32_baseline()),
        ("round OMC S1E4M14", OmcConfig::paper("S1E4M14".parse().unwrap())),
        ("round OMC S1E3M7", OmcConfig::paper("S1E3M7".parse().unwrap())),
    ] {
        let mut cfg = ExperimentConfig::default_with(label, dir);
        cfg.rounds = 1;
        cfg.num_clients = 8;
        cfg.clients_per_round = 4;
        cfg.eval_every = 10_000; // never eval inside the bench
        cfg.omc = omc;
        let mut exp =
            Experiment::prepare_with_model(cfg, Arc::clone(&model)).unwrap();
        exp.warmup().unwrap();
        // run one round per iteration (server state advances; that's fine —
        // the cost is stationary)
        suite.bench(label, None, || {
            let _ = exp.run_one_round_for_bench().unwrap();
        });
    }

    suite.finish("BENCH_round.json");
    println!(
        "The FP32-vs-OMC ratio here is the Tables' Speed column \
         (paper: OMC ~91-93% of FP32)."
    );
}
