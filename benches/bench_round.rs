//! End-to-end federated round latency — the micro version of the Tables'
//! "Speed (Rounds/Min)" column, plus cohort-scaling rows for the streaming
//! round engine (sequential vs sharded dispatch, failure scenarios).
//! Needs the AOT artifacts: `python python/compile/aot.py --out-dir artifacts`.

use std::sync::Arc;

use omc_fl::benchkit::Suite;
use omc_fl::coordinator::config::{ExperimentConfig, OmcConfig};
use omc_fl::coordinator::experiment::Experiment;
use omc_fl::fl::cohort::CohortConfig;
use omc_fl::runtime::engine::Engine;
use omc_fl::util::simd;

fn main() {
    let isa = simd::kernels().level.label();
    if cfg!(target_arch = "x86_64") && simd::kernels().level != simd::Level::Avx2 {
        // CI greps for this (PR 3 convention): the dispatched rows would
        // measure a lower ISA level than the trajectory expects.
        println!("SKIPPED: bench_round SIMD rows — AVX2 unavailable (resolved: {isa})");
    }
    // Prefer the compiled artifacts; fall back to the pure-Rust native
    // backend so the round-latency trajectory exists in every environment
    // (CI has no artifacts; default builds can't execute artifacts even
    // when present). If a bench genuinely cannot run it must print a
    // `SKIPPED:` line — CI greps for it so a wholly-skipped bench can't
    // masquerade as a passing smoke.
    let engine = match Engine::cpu() {
        Ok(e) => e,
        Err(e) => {
            println!("SKIPPED: bench_round — engine unavailable: {e}");
            return;
        }
    };
    let artifact_dir = std::path::Path::new("artifacts/tiny");
    let native_dir = std::path::Path::new("native:tiny");
    let (dir, model) = match engine.load_model(artifact_dir) {
        Ok(m) => (artifact_dir, Arc::new(m)),
        Err(first) => match engine.load_model(native_dir) {
            Ok(m) => {
                eprintln!(
                    "NOTE bench_round: cannot run artifacts/tiny ({first:#}) \
                     — falling back to the native backend (native:tiny)."
                );
                (native_dir, Arc::new(m))
            }
            Err(e) => {
                println!("SKIPPED: bench_round — no runnable model: {e}");
                return;
            }
        },
    };

    let mut suite = Suite::new("end-to-end federated round (tiny model, 4 clients)");
    // rounds are ~100 ms; cap the sample budget
    suite.min_time_s = suite.min_time_s.min(2.0);

    // scalar-vs-dispatched pairs: the same round config, once with the
    // dispatch forced to the scalar kernels and once resolved — the delta
    // is the whole-round win of the SIMD codec layer
    for (label, omc) in [
        ("round FP32 (S1E8M23)", OmcConfig::fp32_baseline()),
        ("round OMC S1E4M14", OmcConfig::paper("S1E4M14".parse().unwrap())),
        ("round OMC S1E3M7", OmcConfig::paper("S1E3M7".parse().unwrap())),
    ] {
        let mut cfg = ExperimentConfig::default_with(label, dir);
        cfg.rounds = 1;
        cfg.num_clients = 8;
        cfg.clients_per_round = 4;
        cfg.eval_every = 10_000; // never eval inside the bench
        cfg.omc = omc;
        let mut exp =
            Experiment::prepare_with_model(cfg, Arc::clone(&model)).unwrap();
        exp.warmup().unwrap();
        // run one round per iteration (server state advances; that's fine —
        // the cost is stationary)
        // "[forced-scalar]" vs "[<isa>]": structurally distinct names even
        // when the resolved level IS scalar, so bench_trend.py never sees
        // duplicate row keys
        assert!(simd::force_level(Some(simd::Level::Scalar)));
        suite.bench(&format!("{label} [forced-scalar]"), None, || {
            let _ = exp.run_one_round_for_bench().unwrap();
        });
        assert!(simd::force_level(None));
        suite.bench(&format!("{label} [{isa}]"), None, || {
            let _ = exp.run_one_round_for_bench().unwrap();
        });
    }

    // Wire-integrity row: the same OMC round framed in the checksummed v2
    // layout (per-var CRC32C + nonces both directions). The delta against
    // the "round OMC S1E4M14" row above is the whole-round integrity cost;
    // the row above *is* the integrity-off fast path, so its trajectory
    // doubles as the no-regression gate.
    {
        let mut cfg =
            ExperimentConfig::default_with("round OMC S1E4M14 +integrity", dir);
        cfg.rounds = 1;
        cfg.num_clients = 8;
        cfg.clients_per_round = 4;
        cfg.eval_every = 10_000;
        cfg.omc = OmcConfig::paper("S1E4M14".parse().unwrap());
        cfg.omc.integrity = true;
        let mut exp =
            Experiment::prepare_with_model(cfg, Arc::clone(&model)).unwrap();
        exp.warmup().unwrap();
        suite.bench(&format!("round OMC S1E4M14 +integrity [{isa}]"), None, || {
            let _ = exp.run_one_round_for_bench().unwrap();
        });
    }

    // Cohort-scaling rows: the same OMC round at a doubled cohort, run
    // with workers=1 vs workers=4, plus a failure-model round. With the
    // PJRT backend client *training* stays pinned (`Engine::is_send_safe`
    // is false), so the delta between these rows comes from the parallel
    // downlink build and the thread-pooled uplink decode+aggregation; a
    // Send-safe engine would additionally shard the training loop itself
    // over the same rows.
    let stress = CohortConfig {
        dropout_prob: 0.1,
        straggler_mean_s: 2.0,
        deadline_s: 4.0,
        weight_by_examples: true,
    };
    for (label, workers, cohort) in [
        ("round OMC cohort=8 sequential (workers=1)", 1, CohortConfig::ideal()),
        ("round OMC cohort=8 sharded (workers=4)", 4, CohortConfig::ideal()),
        ("round OMC cohort=8 dropout+stragglers", 4, stress),
    ] {
        let mut cfg = ExperimentConfig::default_with(label, dir);
        cfg.rounds = 1;
        cfg.num_clients = 16;
        cfg.clients_per_round = 8;
        cfg.eval_every = 10_000;
        cfg.omc = OmcConfig::paper("S1E4M14".parse().unwrap());
        cfg.cohort = cohort;
        cfg.workers = workers;
        let mut exp =
            Experiment::prepare_with_model(cfg, Arc::clone(&model)).unwrap();
        exp.warmup().unwrap();
        suite.bench(label, None, || {
            let _ = exp.run_one_round_for_bench().unwrap();
        });
    }

    suite.finish("BENCH_round.json");
    println!(
        "The FP32-vs-OMC ratio here is the Tables' Speed column \
         (paper: OMC ~91-93% of FP32)."
    );
}
