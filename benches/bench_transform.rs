//! Per-variable transformation cost: the f64 least-squares fit and the f32
//! affine decompression.

use omc_fl::benchkit::{consume, Suite};
use omc_fl::omc::format::FloatFormat;
use omc_fl::omc::quantize::quantize_vec;
use omc_fl::omc::transform::{apply, fit};
use omc_fl::util::rng::Xoshiro256pp;

fn main() {
    let mut suite = Suite::new("omc::transform (PVT) fit + apply");
    let mut rng = Xoshiro256pp::new(3);
    let fmt: FloatFormat = "S1E3M7".parse().unwrap();

    for n in [4_096usize, 65_536, 1_048_576] {
        let mut v = vec![0.0f32; n];
        rng.fill_normal(&mut v, 0.05);
        let vt = quantize_vec(&v, fmt);
        suite.bench(&format!("pvt fit   n={n}"), Some(n), || {
            consume(fit(&v, &vt));
        });
        let p = fit(&v, &vt);
        let mut out = vec![0.0f32; n];
        suite.bench(&format!("pvt apply n={n}"), Some(n), || {
            apply(p, &vt, &mut out);
            consume(&out);
        });
    }

    suite.finish("BENCH_transform.json");
}
