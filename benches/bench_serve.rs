//! Serving-engine wall-clock benchmark: commits/sec through real worker
//! threads at several worker counts, plus the measured uplink latency
//! quantiles and the arena-on vs arena-off A/B rows. Runs entirely on the
//! native backend (`native:tiny`), so it needs no artifacts and no `pjrt`
//! feature — this bench can never silently self-skip.
//!
//! Every serve run is byte-compared against the planned-timeline
//! reference (`Experiment::run_async_params_only`), so the bench doubles
//! as a determinism smoke for the same contract the CI `smoke-serve` leg
//! gates with `cmp` on dumped parameters.
//!
//! The latency/throughput rows come from `Suite::metric`: engine-reported
//! wall-clock numbers (p50/p99, bytes/sec) are facts of one run, not
//! closures benchkit can sample, but they belong in the same
//! `BENCH_serve.json` schema the cross-PR trend tracker reads.

use std::path::Path;

use omc_fl::benchkit::Suite;
use omc_fl::coordinator::config::{ExperimentConfig, OmcConfig};
use omc_fl::coordinator::Experiment;
use omc_fl::fl::async_round::{AsyncConfig, StalenessPolicy};
use omc_fl::fl::serve::{ServeConfig, ServeReport};
use omc_fl::runtime::engine::Engine;

const COMMITS: usize = 6;

fn cfg(name: &str, workers: usize, arena: bool) -> ExperimentConfig {
    let mut c = ExperimentConfig::default_with(name, Path::new("native:tiny"));
    c.rounds = COMMITS;
    c.num_clients = 16;
    c.clients_per_round = 8;
    c.local_steps = 1;
    c.lr = 0.2;
    c.eval_every = COMMITS + 1; // only the mandatory final eval
    c.eval_batches = 1;
    c.omc = OmcConfig {
        format: "S1E4M14".parse().unwrap(),
        use_pvt: true,
        weights_only: true,
        fraction: 1.0,
        integrity: false,
    };
    c.cohort.straggler_mean_s = 2.0;
    c.async_cfg = AsyncConfig {
        enabled: true,
        concurrency: 8,
        buffer_k: 4,
        policy: StalenessPolicy::Polynomial { alpha: 0.5 },
        max_staleness: usize::MAX,
        snapshot_ring: 4,
    };
    c.serve = ServeConfig {
        enabled: true,
        workers,
        arena,
        probe: false, // keep the measured run free of the shutdown probe
        ..ServeConfig::default()
    };
    // the per-commit stream is part of the measured path, but its rows
    // don't belong in the repo working tree
    c.output_dir = std::env::temp_dir().join("omc_bench_serve");
    c
}

fn bits(exp: &Experiment) -> Vec<Vec<u32>> {
    exp.server
        .params
        .iter()
        .map(|v| v.iter().map(|x| x.to_bits()).collect())
        .collect()
}

fn run_serve(engine: &Engine, cfg: ExperimentConfig) -> (Vec<Vec<u32>>, ServeReport) {
    let mut exp = Experiment::prepare(engine, cfg).expect("prepare");
    let (_, report) = exp.run_serve().expect("serve run");
    (bits(&exp), report)
}

fn reference_bits(engine: &Engine, cfg: ExperimentConfig) -> Vec<Vec<u32>> {
    let mut exp = Experiment::prepare(engine, cfg).expect("prepare");
    exp.run_async_params_only().expect("reference run");
    bits(&exp)
}

fn main() {
    let engine = match Engine::cpu() {
        Ok(e) => e,
        Err(e) => {
            // unreachable in default builds (the native engine always
            // constructs); kept so a failure is loud, not a fake pass
            println!("SKIPPED: bench_serve — engine unavailable: {e}");
            return;
        }
    };

    let mut suite = Suite::new(&format!(
        "serving engine ({COMMITS} commits, K=4, conc=8, native:tiny)"
    ));

    // the bit-identity yardstick every serve row is held to
    let ref_bits = reference_bits(&engine, cfg("serve_ref", 1, true));

    for workers in [1usize, 2, 4] {
        suite.bench(
            &format!("serve {COMMITS} commits [workers={workers} arena=on]"),
            Some(COMMITS),
            || {
                let (bits, report) =
                    run_serve(&engine, cfg("serve_bench", workers, true));
                assert_eq!(
                    bits, ref_bits,
                    "served commits diverged from the planned timeline \
                     at workers={workers}"
                );
                assert_eq!(report.commits, COMMITS);
            },
        );
    }

    // A/B: one measured run per arena setting at full fan-out; the report
    // rows below are what the trend tracker and PERFORMANCE.md cite
    let (on_bits, on) = run_serve(&engine, cfg("serve_arena_on", 4, true));
    let (off_bits, off) = run_serve(&engine, cfg("serve_arena_off", 4, false));
    assert_eq!(on_bits, ref_bits, "arena-on run diverged");
    assert_eq!(off_bits, ref_bits, "arena pooling leaked into commits");
    assert!(on.frame_arena.recycled > 0, "arena-on run never recycled");
    assert_eq!(off.frame_arena.recycled, 0, "disabled arena recycled");

    for (label, r) in [("arena=on", &on), ("arena=off", &off)] {
        // ns per commit with transport bytes => the row reads as both
        // commits/sec and wire GB/s
        suite.metric(
            &format!("serve report: wall per commit [workers=4 {label}]"),
            r.wall_s * 1e9 / r.commits.max(1) as f64,
            Some(r.commits),
            Some((r.down_bytes + r.up_bytes) / r.commits.max(1)),
        );
        suite.metric(
            &format!("serve report: uplink p50 [workers=4 {label}]"),
            r.uplink_p50_s * 1e9,
            Some(r.uplinks),
            None,
        );
        suite.metric(
            &format!("serve report: uplink p99 [workers=4 {label}]"),
            r.uplink_p99_s * 1e9,
            Some(r.uplinks),
            None,
        );
    }

    suite.finish("BENCH_serve.json");
    for r in suite.results() {
        if r.name.contains("commits [") {
            println!(
                "  {}: {:.2} commits/s",
                r.name,
                COMMITS as f64 / (r.median_ns / 1e9)
            );
        }
    }
    for (label, r) in [("arena=on", &on), ("arena=off", &off)] {
        println!(
            "  serve [workers=4 {label}]: {:.2} commits/s, {:.0} bytes/s, \
             p50 {:.2}ms p99 {:.2}ms, queue peak {}/{}, \
             frame arena {} acquires = {} fresh + {} recycled",
            r.commits_per_sec(),
            r.bytes_per_sec(),
            r.uplink_p50_s * 1e3,
            r.uplink_p99_s * 1e3,
            r.queue_peak_depth,
            r.queue_depth,
            r.frame_arena.acquires,
            r.frame_arena.fresh,
            r.frame_arena.recycled,
        );
    }
}
