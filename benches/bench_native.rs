//! Native-backend GEMM throughput — the compute side of every
//! `cargo test`/CI sweep round since PR 3. Rows compare the naive
//! dot-product loop (the scalar baseline the acceptance gate measures
//! against) with the blocked axpy-form kernel and its threaded variant,
//! plus whole `sgd_step`/`run_eval` rows for the round-level trajectory.
//!
//! The GEMM pair is the PR 4 acceptance gate: blocked ≥ 2x naive on an
//! AVX2 host. Bytes per iteration = x + w + bias + out traffic (one pass).
//! Elems = multiply-accumulates, so Melem/s reads as MMAC/s.
//!
//! This bench runs everywhere (pure Rust, no artifacts) and never skips.

use omc_fl::benchkit::{consume, Suite};
use omc_fl::runtime::gemm::{
    gemm_bias_act, gemm_bias_act_threaded, gemm_naive, Act,
};
use omc_fl::runtime::native::{manifest_for, NativeModel};
use omc_fl::util::rng::Xoshiro256pp;
use omc_fl::util::threadpool::default_workers;

fn main() {
    let mut suite = Suite::new("runtime::native GEMM + step throughput");
    let mut rng = Xoshiro256pp::new(7);
    let workers = default_workers();

    // a bench-scale GEMM: big enough that blocking and vectorization show,
    // small enough for the OMC_BENCH_FAST smoke tier
    for (rows, in_dim, out_dim) in [(256usize, 256usize, 256usize), (512, 128, 64)] {
        let mut x = vec![0.0f32; rows * in_dim];
        rng.fill_normal(&mut x, 1.0);
        let mut w = vec![0.0f32; in_dim * out_dim];
        rng.fill_normal(&mut w, 0.1);
        let mut bias = vec![0.0f32; out_dim];
        rng.fill_normal(&mut bias, 0.1);
        let mut out = vec![0.0f32; rows * out_dim];
        let macs = rows * in_dim * out_dim;
        let io = 4 * (x.len() + w.len() + bias.len() + out.len());
        let shape = format!("{rows}x{in_dim}x{out_dim}");

        suite.bench_case(&format!("gemm naive   {shape}"), Some(macs), Some(io), || {
            gemm_naive(&x, &w, &bias, rows, in_dim, out_dim, Act::Relu, &mut out);
            consume(&out);
        });
        suite.bench_case(&format!("gemm blocked {shape}"), Some(macs), Some(io), || {
            gemm_bias_act(&x, &w, &bias, rows, in_dim, out_dim, Act::Relu, &mut out);
            consume(&out);
        });
        if workers > 1 {
            suite.bench_case(
                &format!("gemm thr({workers}) {shape}"),
                Some(macs),
                Some(io),
                || {
                    gemm_bias_act_threaded(
                        &x, &w, &bias, rows, in_dim, out_dim, Act::Relu, workers,
                        &mut out,
                    );
                    consume(&out);
                },
            );
        }
    }

    // whole native training/eval steps (the unit the sweep engine pays
    // per client per round)
    for name in ["tiny", "small"] {
        let manifest = manifest_for(name).unwrap();
        let nm = NativeModel::from_manifest(&manifest).unwrap();
        let params = nm.run_init(1).unwrap();
        let c = &manifest.config;
        let frames = c.batch * c.seq_len;
        let mut x = vec![0.0f32; frames * c.feature_dim];
        rng.fill_normal(&mut x, 1.0);
        let y: Vec<i32> = (0..frames)
            .map(|i| (i % c.vocab) as i32)
            .collect();
        suite.bench(&format!("sgd_step native:{name}"), Some(frames), || {
            consume(nm.run_train_fp32(&params, &x, &y, 0.1).unwrap());
        });
        suite.bench(&format!("run_eval native:{name}"), Some(frames), || {
            consume(nm.run_eval(&params, &x, &y).unwrap());
        });
    }

    suite.finish("BENCH_native.json");
}
