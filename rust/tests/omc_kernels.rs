//! Bit-exactness property tests for the block codec kernel layer.
//!
//! Correctness contract (see `omc::pack` module docs): the block/word
//! kernels, the fused pipelines, and the threaded variants must produce
//! **byte-identical wire payloads** and **bit-identical decoded f32s**
//! versus the scalar reference path (`pack_scalar` / `unpack_scalar`) —
//! for every format, including subnormals, saturated values, signed
//! zeros, and tail lengths not divisible by the 256-value block size.

use omc_fl::omc::format::FloatFormat;
use omc_fl::omc::pack::{
    pack, pack_scalar, pack_threaded, quantize_transform_pack, unpack,
    unpack_scalar, unpack_transform, unpack_transform_into,
    unpack_transform_into_threaded, BLOCK,
};
use omc_fl::omc::quantize::{quantize_one, quantize_vec};
use omc_fl::omc::transform::{fit, Pvt};
use omc_fl::testkit::{check, Gen};

/// The paper's table formats (monomorphized fast paths) plus two formats
/// that exercise the generic-width kernel.
const FORMATS: [&str; 6] = [
    "S1E5M10", "S1E4M14", "S1E3M7", "S1E2M3", "S1E3M9", "S1E5M7",
];

/// Lengths straddling every dispatch boundary: empty, scalar-only tails,
/// exact block multiples, and block multiples ± small tails.
const LENGTHS: [usize; 10] = [
    0,
    1,
    7,
    BLOCK - 1,
    BLOCK,
    BLOCK + 1,
    2 * BLOCK,
    4 * BLOCK - 3,
    4 * BLOCK,
    4 * BLOCK + 129,
];

/// A value set deliberately heavy in edge cases for `fmt`: signed zeros,
/// the whole subnormal neighborhood, saturation at ±max, and normals
/// across scales.
fn edge_heavy_values(g: &mut Gen, n: usize, fmt: FloatFormat) -> Vec<f32> {
    let quantum = fmt.min_positive() as f32;
    let max = fmt.max_value() as f32;
    let mut v = Vec::with_capacity(n);
    for i in 0..n {
        let x = match i % 8 {
            0 => 0.0,
            1 => -0.0,
            2 => quantum * g.usize_below(1 << fmt.mant_bits.min(16)) as f32,
            3 => -quantum * g.usize_below(3) as f32,
            4 => 1e30,  // saturates to +max
            5 => -1e30, // saturates to -max
            6 => max,
            _ => g.f32_normalish([1e-6, 0.05, 1.0, 1e3][g.usize_below(4)]),
        };
        v.push(x);
    }
    quantize_vec(&v, fmt)
}

#[test]
fn block_pack_is_byte_identical_to_scalar_for_all_formats_and_tails() {
    let mut g = Gen::new(101);
    for fmt_s in FORMATS {
        let fmt: FloatFormat = fmt_s.parse().unwrap();
        for n in LENGTHS {
            let v = edge_heavy_values(&mut g, n, fmt);
            let reference = pack_scalar(&v, fmt).unwrap();
            let fast = pack(&v, fmt).unwrap();
            assert_eq!(reference, fast, "{fmt_s} n={n}: payload bytes differ");
            assert_eq!(reference.len(), fmt.packed_bytes(n), "{fmt_s} n={n}");
        }
    }
}

#[test]
fn block_unpack_is_bit_identical_to_scalar_for_all_formats_and_tails() {
    let mut g = Gen::new(102);
    for fmt_s in FORMATS {
        let fmt: FloatFormat = fmt_s.parse().unwrap();
        for n in LENGTHS {
            let v = edge_heavy_values(&mut g, n, fmt);
            let bytes = pack_scalar(&v, fmt).unwrap();
            let a = unpack_scalar(&bytes, n, fmt);
            let b = unpack(&bytes, n, fmt);
            for i in 0..n {
                assert_eq!(
                    a[i].to_bits(),
                    b[i].to_bits(),
                    "{fmt_s} n={n} idx {i}"
                );
                assert_eq!(
                    b[i].to_bits(),
                    v[i].to_bits(),
                    "{fmt_s} n={n} idx {i}: roundtrip"
                );
            }
        }
    }
}

#[test]
fn fused_compress_matches_separate_passes_property() {
    // quantize_transform_pack == quantize_vec + fit + pack_scalar, bit for
    // bit, across random formats, scales, pvt on/off, subnormal-heavy and
    // saturating inputs
    check("fused_qtp_full", 120, |g| {
        let fmt: FloatFormat =
            FORMATS[g.usize_below(FORMATS.len())].parse().unwrap();
        let n = g.usize_below(3 * BLOCK + 2);
        let use_pvt = g.usize_below(2) == 0;
        // raw (unquantized) inputs — the fused pipeline quantizes itself
        let mut v = g.vec_normal(n, [1e-7f32, 0.05, 1.0, 1e5][g.usize_below(4)]);
        if n > 2 {
            v[0] = f32::INFINITY; // saturates
            v[1] = -0.0;
            v[2] = fmt.min_positive() as f32 / 2.0; // subnormal rounding
        }
        let vt = quantize_vec(&v, fmt);
        let ref_pvt = if use_pvt { fit(&v, &vt) } else { Pvt::IDENTITY };
        let ref_bytes = pack_scalar(&vt, fmt).map_err(|e| e.to_string())?;

        let mut bytes = Vec::new();
        let pvt = quantize_transform_pack(&v, fmt, use_pvt, &mut bytes);
        if bytes != ref_bytes {
            return Err(format!("{fmt} n={n} pvt={use_pvt}: payload differs"));
        }
        if pvt.s.to_bits() != ref_pvt.s.to_bits()
            || pvt.b.to_bits() != ref_pvt.b.to_bits()
        {
            return Err(format!("{fmt} n={n}: pvt {pvt:?} != {ref_pvt:?}"));
        }
        Ok(())
    });
}

#[test]
fn fused_decompress_matches_separate_passes_property() {
    check("fused_unpack_transform", 100, |g| {
        let fmt: FloatFormat =
            FORMATS[g.usize_below(FORMATS.len())].parse().unwrap();
        let n = g.usize_below(3 * BLOCK + 2);
        let v = quantize_vec(
            &g.vec_normal(n, [1e-6f32, 0.05, 1e3][g.usize_below(3)]),
            fmt,
        );
        let bytes = pack_scalar(&v, fmt).map_err(|e| e.to_string())?;
        let (s, b) = if g.usize_below(3) == 0 {
            (1.0, 0.0) // identity fast path (must preserve -0.0 bits)
        } else {
            (g.f32_normalish(1.0), g.f32_normalish(0.1))
        };
        // reference: scalar unpack, then the affine in a separate pass
        let tilde = unpack_scalar(&bytes, n, fmt);
        let reference: Vec<f32> = if s == 1.0 && b == 0.0 {
            tilde
        } else {
            tilde.iter().map(|&t| s * t + b).collect()
        };
        let fused = unpack_transform(&bytes, n, fmt, s, b);
        let mut fused_into = Vec::new();
        unpack_transform_into(&bytes, n, fmt, s, b, &mut fused_into);
        for i in 0..n {
            if fused[i].to_bits() != reference[i].to_bits()
                || fused_into[i].to_bits() != reference[i].to_bits()
            {
                return Err(format!("{fmt} n={n} idx {i} s={s} b={b}"));
            }
        }
        Ok(())
    });
}

#[test]
fn threaded_kernels_match_serial_property() {
    check("threaded_vs_serial", 8, |g| {
        let fmt: FloatFormat =
            ["S1E5M10", "S1E3M7"][g.usize_below(2)].parse().unwrap();
        // big enough to engage the parallel path, odd tail included
        let n = 640 * BLOCK + g.usize_below(2 * BLOCK);
        let v = quantize_vec(&g.vec_normal(n, 0.05), fmt);
        let serial = pack(&v, fmt).map_err(|e| e.to_string())?;
        let workers = 2 + g.usize_below(4);
        let par = pack_threaded(&v, fmt, workers).map_err(|e| e.to_string())?;
        if serial != par {
            return Err(format!("{fmt} n={n} workers={workers}: pack differs"));
        }
        let mut a = Vec::new();
        let mut b = Vec::new();
        unpack_transform_into(&serial, n, fmt, 1.1, 0.01, &mut a);
        unpack_transform_into_threaded(&par, n, fmt, 1.1, 0.01, workers, &mut b);
        for i in 0..n {
            if a[i].to_bits() != b[i].to_bits() {
                return Err(format!("{fmt} idx {i}: unpack differs"));
            }
        }
        Ok(())
    });
}

#[test]
fn saturated_and_subnormal_codes_survive_the_wire() {
    // the classic trouble spots, checked end to end through pack→unpack
    for fmt_s in FORMATS {
        let fmt: FloatFormat = fmt_s.parse().unwrap();
        let quantum = fmt.min_positive() as f32;
        let max = fmt.max_value() as f32;
        let mut vals = vec![0.0f32, -0.0, max, -max];
        for k in 0..(1usize << fmt.mant_bits.min(10)) {
            vals.push(k as f32 * quantum);
            vals.push(-(k as f32) * quantum);
        }
        // every one must already be a quantizer fixed point
        for &x in &vals {
            assert_eq!(quantize_one(x, fmt).to_bits(), x.to_bits(), "{fmt_s}");
        }
        // pad to cross a block boundary so both kernels run
        while vals.len() < BLOCK + 17 {
            vals.push(quantum);
        }
        let bytes = pack(&vals, fmt).unwrap();
        assert_eq!(bytes, pack_scalar(&vals, fmt).unwrap(), "{fmt_s}");
        let back = unpack(&bytes, vals.len(), fmt);
        for (i, (a, b)) in back.iter().zip(&vals).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{fmt_s} idx {i}");
        }
    }
}
