//! Bit-exactness property tests for the block codec kernel layer.
//!
//! Correctness contract (see `omc::pack` module docs): the block/word
//! kernels, the fused pipelines, and the threaded variants must produce
//! **byte-identical wire payloads** and **bit-identical decoded f32s**
//! versus the scalar reference path (`pack_scalar` / `unpack_scalar`) —
//! for every format, including subnormals, saturated values, signed
//! zeros, and tail lengths not divisible by the 256-value block size.

use omc_fl::omc::format::FloatFormat;
use omc_fl::omc::pack::{
    pack, pack_scalar, pack_threaded, quantize_transform_pack, unpack,
    unpack_scalar, unpack_transform, unpack_transform_into,
    unpack_transform_into_threaded, BLOCK,
};
use omc_fl::omc::quantize::{quantize_one, quantize_slice_scalar, quantize_vec};
use omc_fl::omc::transform::{fit, FitAcc, Pvt};
use omc_fl::testkit::{check, Gen};
use omc_fl::util::simd;

/// The paper's table formats (SIMD byte-lane / monomorphized fast paths)
/// plus `S1E4M3` (the 8-bit byte-lane path) and two formats that
/// exercise the generic-width kernel.
const FORMATS: [&str; 7] = [
    "S1E5M10", "S1E4M14", "S1E3M7", "S1E2M3", "S1E4M3", "S1E3M9", "S1E5M7",
];

/// Lengths straddling every dispatch boundary: empty, scalar-only tails,
/// exact block multiples, and block multiples ± small tails.
const LENGTHS: [usize; 10] = [
    0,
    1,
    7,
    BLOCK - 1,
    BLOCK,
    BLOCK + 1,
    2 * BLOCK,
    4 * BLOCK - 3,
    4 * BLOCK,
    4 * BLOCK + 129,
];

/// A value set deliberately heavy in edge cases for `fmt`: signed zeros,
/// the whole subnormal neighborhood, saturation at ±max, and normals
/// across scales.
fn edge_heavy_values(g: &mut Gen, n: usize, fmt: FloatFormat) -> Vec<f32> {
    let quantum = fmt.min_positive() as f32;
    let max = fmt.max_value() as f32;
    let mut v = Vec::with_capacity(n);
    for i in 0..n {
        let x = match i % 8 {
            0 => 0.0,
            1 => -0.0,
            2 => quantum * g.usize_below(1 << fmt.mant_bits.min(16)) as f32,
            3 => -quantum * g.usize_below(3) as f32,
            4 => 1e30,  // saturates to +max
            5 => -1e30, // saturates to -max
            6 => max,
            _ => {
                let scale = [1e-6, 0.05, 1.0, 1e3][g.usize_below(4)];
                g.f32_normalish(scale)
            }
        };
        v.push(x);
    }
    quantize_vec(&v, fmt)
}

#[test]
fn block_pack_is_byte_identical_to_scalar_for_all_formats_and_tails() {
    let mut g = Gen::new(101);
    for fmt_s in FORMATS {
        let fmt: FloatFormat = fmt_s.parse().unwrap();
        for n in LENGTHS {
            let v = edge_heavy_values(&mut g, n, fmt);
            let reference = pack_scalar(&v, fmt).unwrap();
            let fast = pack(&v, fmt).unwrap();
            assert_eq!(reference, fast, "{fmt_s} n={n}: payload bytes differ");
            assert_eq!(reference.len(), fmt.packed_bytes(n), "{fmt_s} n={n}");
        }
    }
}

#[test]
fn block_unpack_is_bit_identical_to_scalar_for_all_formats_and_tails() {
    let mut g = Gen::new(102);
    for fmt_s in FORMATS {
        let fmt: FloatFormat = fmt_s.parse().unwrap();
        for n in LENGTHS {
            let v = edge_heavy_values(&mut g, n, fmt);
            let bytes = pack_scalar(&v, fmt).unwrap();
            let a = unpack_scalar(&bytes, n, fmt);
            let b = unpack(&bytes, n, fmt);
            for i in 0..n {
                assert_eq!(
                    a[i].to_bits(),
                    b[i].to_bits(),
                    "{fmt_s} n={n} idx {i}"
                );
                assert_eq!(
                    b[i].to_bits(),
                    v[i].to_bits(),
                    "{fmt_s} n={n} idx {i}: roundtrip"
                );
            }
        }
    }
}

#[test]
fn fused_compress_matches_separate_passes_property() {
    // quantize_transform_pack == quantize_vec + fit + pack_scalar, bit for
    // bit, across random formats, scales, pvt on/off, subnormal-heavy and
    // saturating inputs
    check("fused_qtp_full", 120, |g| {
        let fmt: FloatFormat =
            FORMATS[g.usize_below(FORMATS.len())].parse().unwrap();
        let n = g.usize_below(3 * BLOCK + 2);
        let use_pvt = g.usize_below(2) == 0;
        // raw (unquantized) inputs — the fused pipeline quantizes itself
        let scale = [1e-7f32, 0.05, 1.0, 1e5][g.usize_below(4)];
        let mut v = g.vec_normal(n, scale);
        if n > 2 {
            v[0] = f32::INFINITY; // saturates
            v[1] = -0.0;
            v[2] = fmt.min_positive() as f32 / 2.0; // subnormal rounding
        }
        let vt = quantize_vec(&v, fmt);
        let ref_pvt = if use_pvt { fit(&v, &vt) } else { Pvt::IDENTITY };
        let ref_bytes = pack_scalar(&vt, fmt).map_err(|e| e.to_string())?;

        let mut bytes = Vec::new();
        let pvt = quantize_transform_pack(&v, fmt, use_pvt, &mut bytes);
        if bytes != ref_bytes {
            return Err(format!("{fmt} n={n} pvt={use_pvt}: payload differs"));
        }
        if pvt.s.to_bits() != ref_pvt.s.to_bits()
            || pvt.b.to_bits() != ref_pvt.b.to_bits()
        {
            return Err(format!("{fmt} n={n}: pvt {pvt:?} != {ref_pvt:?}"));
        }
        Ok(())
    });
}

#[test]
fn fused_decompress_matches_separate_passes_property() {
    check("fused_unpack_transform", 100, |g| {
        let fmt: FloatFormat =
            FORMATS[g.usize_below(FORMATS.len())].parse().unwrap();
        let n = g.usize_below(3 * BLOCK + 2);
        let scale = [1e-6f32, 0.05, 1e3][g.usize_below(3)];
        let v = quantize_vec(&g.vec_normal(n, scale), fmt);
        let bytes = pack_scalar(&v, fmt).map_err(|e| e.to_string())?;
        let (s, b) = if g.usize_below(3) == 0 {
            (1.0, 0.0) // identity fast path (must preserve -0.0 bits)
        } else {
            (g.f32_normalish(1.0), g.f32_normalish(0.1))
        };
        // reference: scalar unpack, then the affine in a separate pass
        let tilde = unpack_scalar(&bytes, n, fmt);
        let reference: Vec<f32> = if s == 1.0 && b == 0.0 {
            tilde
        } else {
            tilde.iter().map(|&t| s * t + b).collect()
        };
        let fused = unpack_transform(&bytes, n, fmt, s, b);
        let mut fused_into = Vec::new();
        unpack_transform_into(&bytes, n, fmt, s, b, &mut fused_into);
        for i in 0..n {
            if fused[i].to_bits() != reference[i].to_bits()
                || fused_into[i].to_bits() != reference[i].to_bits()
            {
                return Err(format!("{fmt} n={n} idx {i} s={s} b={b}"));
            }
        }
        Ok(())
    });
}

#[test]
fn threaded_kernels_match_serial_property() {
    check("threaded_vs_serial", 8, |g| {
        let fmt: FloatFormat =
            ["S1E5M10", "S1E3M7"][g.usize_below(2)].parse().unwrap();
        // big enough to engage the parallel path, odd tail included
        let n = 640 * BLOCK + g.usize_below(2 * BLOCK);
        let v = quantize_vec(&g.vec_normal(n, 0.05), fmt);
        let serial = pack(&v, fmt).map_err(|e| e.to_string())?;
        let workers = 2 + g.usize_below(4);
        let par = pack_threaded(&v, fmt, workers).map_err(|e| e.to_string())?;
        if serial != par {
            return Err(format!("{fmt} n={n} workers={workers}: pack differs"));
        }
        let mut a = Vec::new();
        let mut b = Vec::new();
        unpack_transform_into(&serial, n, fmt, 1.1, 0.01, &mut a);
        unpack_transform_into_threaded(&par, n, fmt, 1.1, 0.01, workers, &mut b);
        for i in 0..n {
            if a[i].to_bits() != b[i].to_bits() {
                return Err(format!("{fmt} idx {i}: unpack differs"));
            }
        }
        Ok(())
    });
}

/// Lengths spanning every SIMD dispatch boundary: tails mod the 256-value
/// block and mod the 8-wide (and 4-wide) vector lane count.
const SIMD_LENGTHS: [usize; 12] = [
    0,
    1,
    3,
    4,
    7,
    8,
    9,
    15,
    17,
    BLOCK - 1,
    BLOCK,
    2 * BLOCK + 13,
];

#[test]
fn simd_quantize_levels_match_scalar_for_all_formats_and_tails() {
    let mut g = Gen::new(201);
    for level in simd::available_levels() {
        let k = simd::kernels_for(level).unwrap();
        for fmt_s in FORMATS {
            let fmt: FloatFormat = fmt_s.parse().unwrap();
            for n in SIMD_LENGTHS {
                let xs = g.vec_edge_heavy(n);
                let mut want = vec![0.0f32; n];
                quantize_slice_scalar(&xs, fmt, &mut want);
                let mut got = vec![0.0f32; n];
                (k.quantize)(&xs, fmt.exp_bits, fmt.mant_bits, &mut got);
                let mut inp = xs.clone();
                (k.quantize_in_place)(&mut inp, fmt.exp_bits, fmt.mant_bits);
                for i in 0..n {
                    assert_eq!(
                        want[i].to_bits(),
                        got[i].to_bits(),
                        "{level:?} {fmt_s} n={n} idx {i} x={:e}",
                        xs[i]
                    );
                    assert_eq!(want[i].to_bits(), inp[i].to_bits());
                }
            }
        }
    }
}

#[test]
fn simd_affine_levels_match_scalar_for_all_tails() {
    let mut g = Gen::new(202);
    for level in simd::available_levels() {
        let k = simd::kernels_for(level).unwrap();
        for n in SIMD_LENGTHS {
            let xs = g.vec_edge_heavy(n);
            let (s, b) = (g.f32_normalish(1.0), g.f32_normalish(0.1));
            let want: Vec<f32> = xs.iter().map(|&x| s * x + b).collect();
            let mut got = vec![0.0f32; n];
            (k.axpb)(s, b, &xs, &mut got);
            let mut inp = xs.clone();
            (k.axpb_in_place)(s, b, &mut inp);
            for i in 0..n {
                assert_eq!(want[i].to_bits(), got[i].to_bits(), "{level:?} n={n}");
                assert_eq!(want[i].to_bits(), inp[i].to_bits());
            }
        }
    }
}

#[test]
fn simd_pack_unpack_levels_match_scalar_for_byte_lane_formats() {
    // the pow2-width (8/16-bit) whole-block kernels vs the scalar
    // bitstream reference: payload bytes and decoded bits must agree,
    // with and without the fused affine
    let mut g = Gen::new(203);
    for level in simd::available_levels() {
        let k = simd::kernels_for(level).unwrap();
        let (Some(pack_k), Some(unpack_k)) = (k.pack_pow2, k.unpack_pow2) else {
            continue; // level has no byte-lane kernels (scalar / sse2)
        };
        for fmt_s in ["S1E5M10", "S1E4M3", "S1E2M5"] {
            let fmt: FloatFormat = fmt_s.parse().unwrap();
            for blocks in [1usize, 2, 5] {
                let n = blocks * BLOCK;
                let v = edge_heavy_values(&mut g, n, fmt);
                let want = pack_scalar(&v, fmt).unwrap();
                let mut got = vec![0u8; fmt.packed_bytes(n)];
                pack_k(&v, fmt.exp_bits, fmt.mant_bits, &mut got);
                assert_eq!(want, got, "{level:?} {fmt_s} blocks={blocks}: pack");

                let quantum = fmt.min_positive() as f32;
                for map in [None, Some((1.25f32, -0.5f32))] {
                    let mut dec = vec![0.0f32; n];
                    unpack_k(&want, fmt.exp_bits, fmt.mant_bits, quantum, map, &mut dec);
                    let reference = unpack_scalar(&want, n, fmt);
                    for i in 0..n {
                        let r = match map {
                            None => reference[i],
                            Some((s, b)) => s * reference[i] + b,
                        };
                        assert_eq!(
                            r.to_bits(),
                            dec[i].to_bits(),
                            "{level:?} {fmt_s} map={map:?} idx {i}"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn fit_acc_is_identical_under_forced_scalar_and_dispatched_paths() {
    // the FitAcc determinism contract: the fixed virtual-lane schedule
    // makes the PVT scalars a pure function of the stream, not the ISA
    let mut g = Gen::new(204);
    let v = g.vec_normal(4 * BLOCK + 77, 0.05);
    let fmt: FloatFormat = "S1E3M7".parse().unwrap();
    let vt = quantize_vec(&v, fmt);

    let scalar_k = simd::kernels_for(simd::Level::Scalar).unwrap();
    let mut scalar_acc = FitAcc::new();
    for (cv, ct) in v.chunks(100).zip(vt.chunks(100)) {
        // odd chunking (100 % 4 != 0) exercises the lane phase logic
        scalar_acc.update_with(scalar_k, cv, ct);
    }
    let scalar_pvt = scalar_acc.finish();

    for level in simd::available_levels() {
        let k = simd::kernels_for(level).unwrap();
        let mut acc = FitAcc::new();
        for (cv, ct) in v.chunks(100).zip(vt.chunks(100)) {
            acc.update_with(k, cv, ct);
        }
        let pvt = acc.finish();
        assert_eq!(scalar_pvt.s.to_bits(), pvt.s.to_bits(), "{level:?}");
        assert_eq!(scalar_pvt.b.to_bits(), pvt.b.to_bits(), "{level:?}");
    }

    // and the dispatched public path agrees with the forced-scalar one
    let dispatched = fit(&v, &vt);
    assert_eq!(scalar_pvt.s.to_bits(), dispatched.s.to_bits());
    assert_eq!(scalar_pvt.b.to_bits(), dispatched.b.to_bits());
}

#[test]
fn saturated_and_subnormal_codes_survive_the_wire() {
    // the classic trouble spots, checked end to end through pack→unpack
    for fmt_s in FORMATS {
        let fmt: FloatFormat = fmt_s.parse().unwrap();
        let quantum = fmt.min_positive() as f32;
        let max = fmt.max_value() as f32;
        let mut vals = vec![0.0f32, -0.0, max, -max];
        for k in 0..(1usize << fmt.mant_bits.min(10)) {
            vals.push(k as f32 * quantum);
            vals.push(-(k as f32) * quantum);
        }
        // every one must already be a quantizer fixed point
        for &x in &vals {
            assert_eq!(quantize_one(x, fmt).to_bits(), x.to_bits(), "{fmt_s}");
        }
        // pad to cross a block boundary so both kernels run
        while vals.len() < BLOCK + 17 {
            vals.push(quantum);
        }
        let bytes = pack(&vals, fmt).unwrap();
        assert_eq!(bytes, pack_scalar(&vals, fmt).unwrap(), "{fmt_s}");
        let back = unpack(&bytes, vals.len(), fmt);
        for (i, (a, b)) in back.iter().zip(&vals).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{fmt_s} idx {i}");
        }
    }
}
