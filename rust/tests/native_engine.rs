//! End-to-end federated runs on the pure-Rust native backend.
//!
//! These are the artifact-free twins of `fl_integration.rs`: they run in
//! every build (no PJRT, no `artifacts/`), so CI finally executes whole
//! federated rounds — including the sharded dispatch, which until the
//! native backend existed was only reachable from mock-job unit tests.

use std::path::Path;
use std::sync::Arc;

use omc_fl::coordinator::config::{ExperimentConfig, OmcConfig};
use omc_fl::coordinator::Experiment;
use omc_fl::runtime::engine::Engine;

fn base_cfg(name: &str, rounds: usize) -> ExperimentConfig {
    let mut cfg =
        ExperimentConfig::default_with(name, Path::new("native:tiny"));
    cfg.rounds = rounds;
    cfg.num_clients = 8;
    cfg.clients_per_round = 4;
    cfg.local_steps = 1;
    cfg.lr = 0.5;
    cfg.seed = 11;
    cfg.eval_every = rounds; // evaluate once at the end
    cfg.eval_batches = 2;
    cfg.workers = 1;
    cfg.output_dir = std::env::temp_dir().join("omc_native_test_results");
    cfg
}

fn run_cfg(cfg: ExperimentConfig) -> (Experiment, Vec<f64>) {
    let engine = Engine::cpu().unwrap();
    let mut exp = Experiment::prepare(&engine, cfg).unwrap();
    let (rec, _) = exp.run().unwrap();
    let losses = rec.records.iter().map(|r| r.train_loss).collect();
    (exp, losses)
}

#[test]
fn fp32_run_learns_and_replays_exactly() {
    let (exp_a, losses) = run_cfg(base_cfg("fp32", 8));
    assert_eq!(losses.len(), 8);
    assert!(
        losses[7] < losses[0],
        "loss should fall: {} -> {}",
        losses[0],
        losses[7]
    );
    // exact replay with the same seed
    let (exp_b, _) = run_cfg(base_cfg("fp32", 8));
    for (a, b) in exp_a.server.params.iter().zip(&exp_b.server.params) {
        assert_eq!(
            a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            b.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }
    // a different seed moves the trajectory
    let mut other = base_cfg("fp32_other", 8);
    other.seed = 12;
    let (exp_c, _) = run_cfg(other);
    assert!(exp_a
        .server
        .params
        .iter()
        .zip(&exp_c.server.params)
        .any(|(a, c)| a != c));
}

#[test]
fn omc_cell_compresses_and_still_learns() {
    let fp32 = {
        let (exp, _) = run_cfg(base_cfg("fp32_ref", 6));
        let bytes = exp.client_param_bytes();
        drop(exp);
        bytes
    };
    let mut cfg = base_cfg("omc", 6);
    cfg.omc = OmcConfig::paper("S1E4M14".parse().unwrap());
    let engine = Engine::cpu().unwrap();
    let mut exp = Experiment::prepare(&engine, cfg).unwrap();
    let (rec, summary) = exp.run().unwrap();
    assert!(summary.final_wer.is_finite());
    assert!(
        rec.records.last().unwrap().train_loss
            < rec.records.first().unwrap().train_loss,
        "OMC at 15 bits should still learn"
    );
    // compressed store + wire both beat FP32
    assert!(summary.memory_ratio < 1.0, "{}", summary.memory_ratio);
    assert!(summary.param_memory_bytes < fp32);
    let r0 = &rec.records[0];
    let fp32_round_bytes = 2 * 4 * 4 * 1600; // 4 clients × 1600 params × 4B, both ways
    assert!(
        r0.down_bytes + r0.up_bytes < fp32_round_bytes,
        "comm {} should be below the FP32 wire volume {fp32_round_bytes}",
        r0.down_bytes + r0.up_bytes
    );
}

#[test]
fn sharded_execution_matches_pinned_within_reassociation() {
    // native models advertise Send-safety, so workers > 1 takes the
    // sharded dispatch with real training jobs
    let engine = Engine::cpu().unwrap();
    assert!(engine
        .load_model(Path::new("native:tiny"))
        .unwrap()
        .is_send_safe());

    let run_with_workers = |workers: usize| {
        let mut cfg = base_cfg("shard", 4);
        cfg.clients_per_round = 8; // whole population, several shards
        cfg.workers = workers;
        let engine = Engine::cpu().unwrap();
        let mut exp = Experiment::prepare(&engine, cfg).unwrap();
        let (rec, _) = exp.run().unwrap();
        let bytes: Vec<(usize, usize)> = rec
            .records
            .iter()
            .map(|r| (r.down_bytes, r.up_bytes))
            .collect();
        (exp.server.params.clone(), bytes)
    };
    let (pinned, bytes_pinned) = run_with_workers(1);
    let (sharded, bytes_sharded) = run_with_workers(4);
    // byte accounting is exact across dispatches
    assert_eq!(bytes_pinned, bytes_sharded);
    // aggregation only reassociates f64 sums
    for (a, b) in pinned.iter().zip(&sharded) {
        for (x, y) in a.iter().zip(b) {
            assert!(
                (x - y).abs() <= 1e-5,
                "sharded {y} vs pinned {x} diverged"
            );
        }
    }
}

#[test]
fn checkpoint_roundtrip_via_native_models() {
    let dir = std::env::temp_dir().join(format!(
        "omc_native_ckpt_{}",
        std::process::id()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt = dir.join("pre.bin");
    let mut cfg = base_cfg("pre", 3);
    cfg.save_to = Some(ckpt.clone());
    let (exp, _) = run_cfg(cfg);
    let final_params = exp.server.params.clone();
    drop(exp);

    let mut cfg = base_cfg("adapt", 2);
    cfg.init_from = Some(ckpt);
    cfg.domain = 1;
    let engine = Engine::cpu().unwrap();
    let exp = Experiment::prepare(&engine, cfg).unwrap();
    // the adaptation run starts exactly from the checkpoint
    for (a, b) in exp.server.params.iter().zip(&final_params) {
        assert_eq!(
            a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            b.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn shared_model_binding_serves_multiple_variants() {
    let engine = Engine::cpu().unwrap();
    let model = Arc::new(engine.load_model(Path::new("native:tiny")).unwrap());
    for (name, omc) in [
        ("a_fp32", OmcConfig::fp32_baseline()),
        ("b_omc", OmcConfig::paper("S1E3M7".parse().unwrap())),
    ] {
        let mut cfg = base_cfg(name, 2);
        cfg.omc = omc;
        let mut exp =
            Experiment::prepare_with_model(cfg, Arc::clone(&model)).unwrap();
        let (rec, _) = exp.run().unwrap();
        assert_eq!(rec.records.len(), 2);
    }
}
