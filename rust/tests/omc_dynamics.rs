//! Unit-level reproduction of the paper's error-accumulation arguments —
//! pure Rust, no artifacts needed. These are the mechanisms behind Fig. 3
//! (PVT stabilizes repeated re-quantization) and Table 4 (each mitigation
//! reduces error), isolated from the training loop.

use omc_fl::omc::format::FloatFormat;
use omc_fl::omc::quantize::quantize_vec;
use omc_fl::omc::store::StoredVar;
use omc_fl::omc::transform::{fit, mse};
use omc_fl::util::rng::Xoshiro256pp;

/// Simulate OMC's per-iteration cycle on a drifting variable: apply a small
/// "gradient" update to the decompressed values, re-compress, repeat.
/// Returns the final MSE against the exact (never-quantized) trajectory.
fn drift_mse(fmt: FloatFormat, use_pvt: bool, iters: usize, seed: u64) -> f64 {
    let n = 4096;
    let mut rng = Xoshiro256pp::new(seed);
    let mut exact = vec![0.0f32; n];
    rng.fill_normal(&mut exact, 0.05);
    let mut stored = StoredVar::compress(&exact, fmt, use_pvt);
    let mut upd_rng = Xoshiro256pp::new(seed ^ 0xFEED);
    let mut upd = vec![0.0f32; n];
    for _ in 0..iters {
        upd_rng.fill_normal(&mut upd, 2e-4);
        // exact trajectory
        for (e, &u) in exact.iter_mut().zip(&upd) {
            *e += u;
        }
        // OMC trajectory: decompress -> update -> re-compress
        let mut v = stored.decompress();
        for (x, &u) in v.iter_mut().zip(&upd) {
            *x += u;
        }
        stored = StoredVar::compress(&v, fmt, use_pvt);
    }
    mse(&exact, &stored.decompress())
}

#[test]
fn pvt_reduces_accumulated_error() {
    // Fig. 3 mechanism: after many compress/update cycles at a coarse
    // format (few exponent bits => systematic bias PVT can correct), the
    // PVT trajectory tracks the exact one strictly better.
    for fmt_s in ["S1E3M7", "S1E2M3"] {
        let fmt: FloatFormat = fmt_s.parse().unwrap();
        let with = drift_mse(fmt, true, 200, 11);
        let without = drift_mse(fmt, false, 200, 11);
        assert!(
            with < without,
            "{fmt_s}: PVT {with:e} should beat no-PVT {without:e}"
        );
    }
    // wide-exponent formats have no bias to correct: PVT must at least not
    // hurt (parity within noise) — matching the paper's use of PVT as a
    // strictly-no-downside mitigation
    let fmt: FloatFormat = "S1E5M7".parse().unwrap();
    let with = drift_mse(fmt, true, 200, 11);
    let without = drift_mse(fmt, false, 200, 11);
    assert!(with < without * 1.05, "{with:e} vs {without:e}");
}

#[test]
fn error_accumulates_with_iterations() {
    // the premise of Sec. 2: per-iteration quantization error compounds
    let fmt: FloatFormat = "S1E2M3".parse().unwrap();
    let short = drift_mse(fmt, true, 10, 3);
    let long = drift_mse(fmt, true, 300, 3);
    assert!(
        long > short,
        "accumulated error should grow: {short:e} vs {long:e}"
    );
}

#[test]
fn finer_formats_accumulate_less() {
    // the bitwidth ladder of Tables 1-2: error monotone in precision
    let coarse = drift_mse("S1E2M3".parse().unwrap(), true, 100, 7);
    let mid = drift_mse("S1E3M7".parse().unwrap(), true, 100, 7);
    let fine = drift_mse("S1E4M14".parse().unwrap(), true, 100, 7);
    assert!(coarse > mid && mid > fine, "{coarse:e} {mid:e} {fine:e}");
}

#[test]
fn one_shot_pvt_improvement_matches_analysis() {
    // Table-4 row 2 mechanism: the PVT fit strictly reduces one-shot
    // reconstruction error whenever quantization introduced bias
    let mut rng = Xoshiro256pp::new(5);
    let mut v = vec![0.0f32; 65_536];
    rng.fill_normal(&mut v, 0.02);
    // asymmetric shift => quantization bias PVT can correct
    for x in v.iter_mut() {
        *x += 0.013;
    }
    let fmt: FloatFormat = "S1E3M7".parse().unwrap();
    let vt = quantize_vec(&v, fmt);
    let p = fit(&v, &vt);
    let dec: Vec<f32> = vt.iter().map(|&t| p.s * t + p.b).collect();
    let gain = mse(&v, &vt) / mse(&v, &dec).max(1e-30);
    assert!(gain > 1.0, "PVT gain {gain}");
}

#[test]
fn partial_quantization_mixes_precise_updates() {
    // Sec. 2.5 mechanism at the aggregation level: averaging K client
    // copies where each quantizes the variable with prob 0.9 yields lower
    // error than all clients quantizing (the 10% unquantized copies pull
    // the mean toward the exact value).
    let n = 8192;
    let clients = 10;
    let fmt: FloatFormat = "S1E2M3".parse().unwrap();
    let mut rng = Xoshiro256pp::new(9);
    let mut v = vec![0.0f32; n];
    rng.fill_normal(&mut v, 0.05);
    let q = quantize_vec(&v, fmt);

    let avg = |quantized_clients: usize| -> Vec<f32> {
        let mut acc = vec![0.0f64; n];
        for c in 0..clients {
            let src = if c < quantized_clients { &q } else { &v };
            for (a, &x) in acc.iter_mut().zip(src) {
                *a += x as f64 / clients as f64;
            }
        }
        acc.into_iter().map(|x| x as f32).collect()
    };

    let apq = avg(clients); // all clients quantize
    let ppq = avg(9); // 90%: one client keeps full precision
    assert!(mse(&v, &ppq) < mse(&v, &apq));
}
