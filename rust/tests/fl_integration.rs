//! End-to-end federated runs on the tiny model: learning, determinism,
//! communication accounting, checkpointing, and the FP32-vs-OMC parity
//! shape at small scale.

mod common;

use std::path::Path;

use omc_fl::coordinator::config::{ExperimentConfig, OmcConfig};
use omc_fl::coordinator::{params_io, Experiment};
use omc_fl::data::partition::Partition;
use omc_fl::fl::chaos::ChaosConfig;
use omc_fl::fl::cohort::CohortConfig;
use omc_fl::runtime::engine::Engine;

fn base_cfg(name: &str, rounds: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default_with(
        name,
        &common::artifacts_dir().join("tiny"),
    );
    cfg.rounds = rounds;
    cfg.num_clients = 8;
    cfg.clients_per_round = 4;
    cfg.local_steps = 1;
    cfg.lr = 0.1;
    cfg.eval_every = rounds; // evaluate once at the end
    cfg.eval_batches = 4;
    cfg.output_dir = std::env::temp_dir().join("omc_fl_test_results");
    cfg
}

#[test]
fn fp32_run_learns_and_is_deterministic() {
    if common::artifacts_missing("tiny") {
        return;
    }
    let engine = Engine::cpu().unwrap();

    let run = |seed: u64| {
        let mut cfg = base_cfg("fp32", 6);
        cfg.seed = seed;
        let mut exp = Experiment::prepare(&engine, cfg).unwrap();
        let (rec, summary) = exp.run().unwrap();
        (rec, summary, exp.server.params.clone())
    };

    let (rec, summary, params_a) = run(5);
    assert_eq!(rec.records.len(), 6);
    // loss decreases over the run
    let first = rec.records.first().unwrap().train_loss;
    let last = rec.records.last().unwrap().train_loss;
    assert!(last < first, "loss {first} -> {last}");
    assert!(summary.final_wer.is_finite());
    // FP32 communicates 4 bytes/param each way (+ small headers)
    let n_params = 26_272; // tiny model
    let per_round_min = (2 * 4 * n_params * 4) as usize; // 4 clients
    let r0 = &rec.records[0];
    assert!(r0.down_bytes + r0.up_bytes >= per_round_min);
    assert!((summary.memory_ratio - 1.0).abs() < 1e-9);

    // exact replay with the same seed
    let (_, _, params_b) = run(5);
    for (a, b) in params_a.iter().zip(&params_b) {
        assert_eq!(
            a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            b.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }
}

#[test]
fn omc_run_learns_with_reduced_communication() {
    if common::artifacts_missing("tiny") {
        return;
    }
    let engine = Engine::cpu().unwrap();
    let mut cfg = base_cfg("omc_s1e4m14", 6);
    cfg.omc = OmcConfig::paper("S1E4M14".parse().unwrap());
    let mut exp = Experiment::prepare(&engine, cfg).unwrap();
    let expected_ratio = exp.client_param_bytes() as f64
        / (exp.model.manifest.total_params * 4) as f64;
    let (rec, summary) = exp.run().unwrap();
    let first = rec.records.first().unwrap().train_loss;
    let last = rec.records.last().unwrap().train_loss;
    assert!(last < first, "loss {first} -> {last}");

    // communication ratio ~= memory ratio (tiny model has ~93% weight
    // fraction, so the exact value differs from the paper's 64%; the
    // *accounting identity* is what we assert here)
    let fp32_round_bytes = (2 * 4 * exp.model.manifest.total_params
        * exp.cfg.clients_per_round) as f64;
    let measured = (rec.records[0].down_bytes + rec.records[0].up_bytes) as f64;
    let measured_ratio = measured / fp32_round_bytes;
    assert!(
        (measured_ratio - expected_ratio).abs() < 0.02,
        "measured {measured_ratio:.4} vs accounted {expected_ratio:.4}"
    );
    assert!(summary.memory_ratio < 0.75);
}

#[test]
fn noniid_partition_runs() {
    if common::artifacts_missing("tiny") {
        return;
    }
    let engine = Engine::cpu().unwrap();
    let mut cfg = base_cfg("noniid", 4);
    cfg.partition = Partition::BySpeaker;
    cfg.omc = OmcConfig::paper("S1E4M14".parse().unwrap());
    let mut exp = Experiment::prepare(&engine, cfg).unwrap();
    let (rec, _) = exp.run().unwrap();
    assert_eq!(rec.records.len(), 4);
    assert!(rec.records.iter().all(|r| r.train_loss.is_finite()));
}

#[test]
fn checkpoint_roundtrip_resumes_adaptation() {
    if common::artifacts_missing("tiny") {
        return;
    }
    let engine = Engine::cpu().unwrap();
    let ckpt = std::env::temp_dir().join(format!(
        "omc_fl_ckpt_{}.bin",
        std::process::id()
    ));

    // pretrain on domain 0, save
    let mut cfg = base_cfg("pretrain", 4);
    cfg.save_to = Some(ckpt.clone());
    let mut exp = Experiment::prepare(&engine, cfg).unwrap();
    exp.run().unwrap();
    let saved = exp.server.params.clone();

    // checkpoint content matches the in-memory final model
    let loaded = params_io::load(&ckpt).unwrap();
    for (a, b) in saved.iter().zip(&loaded) {
        assert_eq!(
            a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            b.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }

    // adaptation: init_from the checkpoint, train on domain 1
    let mut cfg = base_cfg("adapt", 3);
    cfg.init_from = Some(ckpt.clone());
    cfg.domain = 1;
    cfg.omc = OmcConfig::paper("S1E3M7".parse().unwrap());
    let mut exp2 = Experiment::prepare(&engine, cfg).unwrap();
    // server starts exactly at the checkpoint
    for (a, b) in exp2.server.params.iter().zip(&saved) {
        assert_eq!(
            a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            b.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }
    let (rec, _) = exp2.run().unwrap();
    assert!(rec.records.iter().all(|r| r.train_loss.is_finite()));
    std::fs::remove_file(&ckpt).ok();
}

/// Like [`base_cfg`] but on the pure-Rust native backend, so the chaos and
/// integrity tests below run in every environment (no AOT artifacts).
fn native_cfg(name: &str, rounds: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default_with(name, Path::new("native:tiny"));
    cfg.rounds = rounds;
    cfg.num_clients = 8;
    cfg.clients_per_round = 4;
    cfg.local_steps = 1;
    cfg.lr = 0.2;
    cfg.eval_every = rounds;
    cfg.eval_batches = 2;
    cfg.output_dir = std::env::temp_dir().join("omc_fl_test_results");
    cfg
}

fn param_bits(exp: &Experiment) -> Vec<Vec<u32>> {
    exp.server
        .params
        .iter()
        .map(|v| v.iter().map(|x| x.to_bits()).collect())
        .collect()
}

#[test]
fn integrity_framing_changes_bytes_not_values() {
    // the checksummed v2 wire layout (nonces + per-var CRC32C) must be a
    // pure framing change: the decoded values — and therefore the
    // committed model — are bit-identical to the v1 fast path, and a
    // clean run never has a frame rejected (no false positives)
    let engine = Engine::cpu().unwrap();
    let mk = |integrity: bool, name: &str| {
        let mut cfg = native_cfg(name, 3);
        cfg.omc = OmcConfig::paper("S1E4M14".parse().unwrap());
        cfg.omc.integrity = integrity;
        let mut exp = Experiment::prepare(&engine, cfg).unwrap();
        let (rec, _) = exp.run().unwrap();
        (exp, rec)
    };
    let (v1_exp, v1_rec) = mk(false, "wire_v1");
    let (v2_exp, v2_rec) = mk(true, "wire_v2");
    assert_eq!(
        param_bits(&v1_exp),
        param_bits(&v2_exp),
        "integrity framing leaked into the model values"
    );
    for (a, b) in v1_rec.records.iter().zip(&v2_rec.records) {
        // v2 spends strictly more wire (12-byte header extension + 4
        // bytes/var CRC, both directions) for the same payload
        assert!(b.down_bytes > a.down_bytes);
        assert!(b.up_bytes > a.up_bytes);
        assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
    }
    // clean frames all verify: zero rejections on both paths
    assert_eq!(v1_rec.total_frames_rejected(), 0);
    assert_eq!(v2_rec.total_frames_rejected(), 0);
    assert_eq!(v2_rec.total_up_bytes_rejected(), 0);
}

#[test]
fn chaos_sync_rounds_conserve_cohort_and_byte_accounting() {
    // end-to-end twin of the fl::round unit-level conservation test:
    // with dropout, stragglers, a deadline, and the chaos engine all on,
    // every sampled client lands in exactly one accounting bucket and the
    // wire-health counters see the injected faults — and the whole thing
    // replays bit-identically from the seed
    let engine = Engine::cpu().unwrap();
    let mk = || {
        let mut cfg = native_cfg("chaos_sync", 6);
        cfg.omc = OmcConfig::paper("S1E4M14".parse().unwrap());
        cfg.omc.integrity = true;
        cfg.cohort = CohortConfig {
            dropout_prob: 0.1,
            straggler_mean_s: 1.0,
            deadline_s: 2.5,
            weight_by_examples: true,
        };
        cfg.chaos = ChaosConfig {
            enabled: true,
            bitflip_prob: 0.25,
            truncate_prob: 0.15,
            duplicate_prob: 0.2,
            // 0.25, not 0.1: at this seed the 24 sampled client-rounds
            // draw no u_crash below 0.11, and give-ups need three corrupt
            // attempts in a row — a lower rate leaves `crashed` at zero
            // and the nonzero assertion below vacuous
            crash_prob: 0.25,
            ..ChaosConfig::default()
        };
        let mut exp = Experiment::prepare(&engine, cfg).unwrap();
        let (rec, _) = exp.run().unwrap();
        (exp, rec)
    };
    let (exp, rec) = mk();
    assert_eq!(rec.records.len(), 6);
    for r in &rec.records {
        // conservation: every sampled client has exactly one fate (sync
        // rounds carry no in-flight remainder)
        assert_eq!(
            r.sampled,
            r.completed + r.dropped + r.late + r.crashed,
            "round {} leaked a client",
            r.round
        );
        // discarded and rejected bytes are disjoint subsets of the spent
        // uplink bytes
        assert!(r.up_bytes >= r.up_bytes_discarded + r.up_bytes_rejected);
    }
    // the fault rates above must be visible in the health counters
    assert!(rec.total_frames_rejected() > 0, "no corrupt frames rejected");
    assert!(rec.total_up_bytes_rejected() > 0);
    assert!(rec.total_crashed() > 0, "no chaos kills");
    // faults are a pure function of the seed: exact replay, metrics and all
    let (exp2, rec2) = mk();
    assert_eq!(param_bits(&exp), param_bits(&exp2));
    assert_eq!(rec.to_csv(), rec2.to_csv());
}

#[test]
fn ppq_fraction_drives_bytes_monotonically() {
    if common::artifacts_missing("tiny") {
        return;
    }
    let engine = Engine::cpu().unwrap();
    let mut bytes = Vec::new();
    for frac in [0.25, 0.5, 0.9, 1.0] {
        let mut cfg = base_cfg(&format!("frac{frac}"), 1);
        cfg.omc = OmcConfig {
            format: "S1E3M7".parse().unwrap(),
            use_pvt: true,
            weights_only: true,
            fraction: frac,
            integrity: false,
        };
        let mut exp = Experiment::prepare(&engine, cfg).unwrap();
        let (rec, _) = exp.run().unwrap();
        bytes.push(rec.records[0].down_bytes);
    }
    assert!(
        bytes.windows(2).all(|w| w[0] > w[1]),
        "more quantization => fewer bytes: {bytes:?}"
    );
}
