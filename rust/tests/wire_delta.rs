//! Property/fuzz suite for the lossless cross-round delta + bitpack wire
//! stage (frame v3), driven end to end through the `testkit` frame
//! generator and corruption driver:
//!
//! * **Bit-exact round-trip** across every paper format (S1E4M14,
//!   S1E3M7, S1E2M3) plus raw-f32 variables, random shapes, and block
//!   tails around the 64-word / 256-value boundaries — and delta frames
//!   decode to exactly the bytes a verbatim v2 frame of the same model
//!   decodes to.
//! * **Frame-length identity** — a v3 frame is never more than the
//!   8-byte `base_version` field larger than its v2 twin, and
//!   `delta_saved()` accounts for the difference exactly.
//! * **Ack lag** — any base within the emulated snapshot-ring window
//!   round-trips; a base at the *wrong* version is a typed
//!   [`BaseVersionMismatch`], and a missing base a typed
//!   [`MissingDeltaBase`] — never a silent mis-decode.
//! * **Corruption totality** — every 1-byte truncation and every
//!   single-bit flip of a v3 frame decodes to a typed [`DecodeError`];
//!   replayed delta frames still trip the [`NonceLedger`].

use omc_fl::omc::codec::{frame_nonce, DecodeError, NonceLedger};
use omc_fl::omc::delta::DeltaBase;
use omc_fl::omc::format::FloatFormat;
use omc_fl::omc::store::{CompressedModel, StoredVar};
use omc_fl::testkit::{
    check, corrupt_byte, decode_all_based, encode_frame_v2, encode_frame_v3,
    flip_bit, perturbed_model, sample_wire_model, truncate_at, Gen,
};

/// Bit patterns of a decoded plaintext, for exact comparison.
fn bits(vals: &[Vec<f32>]) -> Vec<Vec<u32>> {
    vals.iter()
        .map(|v| v.iter().map(|x| x.to_bits()).collect())
        .collect()
}

fn expect_bits(m: &CompressedModel) -> Vec<Vec<u32>> {
    bits(&m.decompress_all())
}

/// Decode or stringify the typed refusal.
fn decode(
    wire: &[u8],
    base: Option<&DeltaBase<'_>>,
) -> Result<Vec<Vec<u32>>, String> {
    decode_all_based(wire, base)
        .map(|v| bits(&v))
        .map_err(|e| format!("{e:?}"))
}

/// The invariant tying the two wire generations together: tag-1 records
/// are byte-identical between v2 and v3 writers, the v3 header is 8
/// bytes wider (`base_version`), and `delta_saved` is defined as the
/// exact reduction tag-2 records achieved vs writing verbatim.
fn assert_frame_length_identity(
    v3: &[u8],
    saved: usize,
    v2: &[u8],
) -> Result<(), String> {
    if v3.len() + saved != v2.len() + 8 {
        return Err(format!(
            "length identity broken: v3 {} + saved {} != v2 {} + 8",
            v3.len(),
            saved,
            v2.len()
        ));
    }
    Ok(())
}

/// Value counts straddling the bitpack block geometry: 64-word (512-byte)
/// blocks and the 256-value / 64-value marks the per-block class headers
/// key off. Packed formats land odd byte counts (e.g. 11-bit codes), so
/// these also exercise ragged word tails.
const TAIL_LENS: [usize; 12] = [0, 1, 2, 63, 64, 65, 255, 256, 257, 511, 512, 513];

#[test]
fn delta_roundtrip_is_bit_exact_across_all_paper_formats() {
    for fmt_s in ["S1E4M14", "S1E3M7", "S1E2M3"] {
        let fmt: FloatFormat = fmt_s.parse().unwrap();
        check(&format!("delta_roundtrip_{fmt_s}"), 40, |g| {
            let lens = [
                g.usize_below(700),
                TAIL_LENS[g.usize_below(TAIL_LENS.len())],
                g.usize_below(3),
            ];
            let base_m = CompressedModel::new(
                lens.iter()
                    .enumerate()
                    .map(|(i, &n)| {
                        StoredVar::compress(&g.vec_normal(n, 0.1), fmt, i % 2 == 0)
                    })
                    .collect(),
            );
            let cur = perturbed_model(g, &base_m, 1 + g.usize_below(9));
            let base = DeltaBase::from_model(5, &base_m);
            let (wire, saved) = encode_frame_v3(&cur, g.u64(), &base);
            let v2 = encode_frame_v2(&cur, 1);
            assert_frame_length_identity(&wire, saved, &v2)?;
            let got = decode(&wire, Some(&base))?;
            if got != expect_bits(&cur) {
                return Err(format!("{fmt_s}: delta round-trip not bit-exact"));
            }
            if got != decode(&v2, None)? {
                return Err(format!("{fmt_s}: delta and verbatim decodes differ"));
            }
            Ok(())
        });
    }
}

#[test]
fn delta_roundtrip_covers_raw_fp32_and_mixed_frames() {
    // raw variables never delta-code (the base holds them as `None`) but
    // must ride v3 frames unchanged, including empty ones
    check("delta_roundtrip_raw_fp32", 40, |g| {
        let raw_m = CompressedModel::new(vec![
            StoredVar::raw(g.vec_normal(TAIL_LENS[g.usize_below(TAIL_LENS.len())], 1.0)),
            StoredVar::raw(vec![]),
            StoredVar::raw(g.vec_edge_heavy(96)),
        ]);
        let base = DeltaBase::from_model(2, &raw_m);
        let (wire, saved) = encode_frame_v3(&raw_m, g.u64(), &base);
        if saved != 0 {
            return Err(format!("raw-only frame claims {saved} delta bytes"));
        }
        assert_frame_length_identity(&wire, saved, &encode_frame_v2(&raw_m, 1))?;
        if decode(&wire, Some(&base))? != expect_bits(&raw_m) {
            return Err("raw round-trip not bit-exact".into());
        }
        Ok(())
    });
    // the canonical mixed-shape model: pvt-packed + raw + packed + empty
    check("delta_roundtrip_mixed", 60, |g| {
        let base_m = sample_wire_model(g);
        let cur = perturbed_model(g, &base_m, g.usize_below(12));
        let base = DeltaBase::from_model(9, &base_m);
        let (wire, saved) = encode_frame_v3(&cur, g.u64(), &base);
        assert_frame_length_identity(&wire, saved, &encode_frame_v2(&cur, 1))?;
        if decode(&wire, Some(&base))? != expect_bits(&cur) {
            return Err("mixed-frame round-trip not bit-exact".into());
        }
        Ok(())
    });
}

#[test]
fn delta_block_tails_roundtrip_at_every_boundary() {
    // deterministic single-variable sweep over the block geometry, packed
    // and raw, perturbed and identical
    let mut g = Gen::new(0xB10C);
    let fmt: FloatFormat = "S1E3M7".parse().unwrap();
    for &n in &TAIL_LENS {
        for flips in [0usize, 3] {
            let base_m = CompressedModel::new(vec![StoredVar::compress(
                &g.vec_normal(n, 0.1),
                fmt,
                true,
            )]);
            let cur = perturbed_model(&mut g, &base_m, flips);
            let base = DeltaBase::from_model(1, &base_m);
            let (wire, saved) = encode_frame_v3(&cur, g.u64(), &base);
            assert_frame_length_identity(&wire, saved, &encode_frame_v2(&cur, 1))
                .unwrap();
            assert_eq!(
                decode(&wire, Some(&base)).unwrap(),
                expect_bits(&cur),
                "tail n={n} flips={flips} not bit-exact"
            );
        }
    }
}

#[test]
fn all_zero_and_high_entropy_streams_roundtrip() {
    // identical model (the converged regime): every block hits the
    // zero-width path, the savings dominate the packed payload, and the
    // frame still decodes bit-exactly
    check("delta_all_zero", 30, |g| {
        let m = sample_wire_model(g);
        let base = DeltaBase::from_model(3, &m);
        let (wire, saved) = encode_frame_v3(&m, g.u64(), &base);
        let v2 = encode_frame_v2(&m, 1);
        assert_frame_length_identity(&wire, saved, &v2)?;
        if saved == 0 {
            return Err("identical model produced no savings".into());
        }
        if wire.len() * 2 >= v2.len() {
            return Err(format!(
                "zero-delta frame did not collapse: {} vs {}",
                wire.len(),
                v2.len()
            ));
        }
        if decode(&wire, Some(&base))? != expect_bits(&m) {
            return Err("zero-delta round-trip not bit-exact".into());
        }
        Ok(())
    });
    // all-zero *values*: uniform payload codes, still lossless
    check("delta_zero_values", 20, |g| {
        let fmt: FloatFormat = "S1E4M14".parse().unwrap();
        let zeros = vec![0.0f32; 200 + g.usize_below(400)];
        let m =
            CompressedModel::new(vec![StoredVar::compress(&zeros, fmt, false)]);
        let base = DeltaBase::from_model(1, &m);
        let (wire, _) = encode_frame_v3(&m, g.u64(), &base);
        if decode(&wire, Some(&base))? != expect_bits(&m) {
            return Err("zero-values round-trip not bit-exact".into());
        }
        Ok(())
    });
    // adversarial high-entropy payloads: XOR finds no slack, the writer
    // must fall back to verbatim records (saved == 0, frame == v2 + 8)
    // and stay bit-exact
    check("delta_high_entropy", 30, |g| {
        let base_m = sample_wire_model(g);
        let cur = perturbed_model(g, &base_m, 3000);
        let base = DeltaBase::from_model(4, &base_m);
        let (wire, saved) = encode_frame_v3(&cur, g.u64(), &base);
        let v2 = encode_frame_v2(&cur, 1);
        assert_frame_length_identity(&wire, saved, &v2)?;
        if wire.len() > v2.len() + 8 {
            return Err(format!(
                "delta framing regressed the wire: {} vs {}",
                wire.len(),
                v2.len()
            ));
        }
        if decode(&wire, Some(&base))? != expect_bits(&cur) {
            return Err("high-entropy round-trip not bit-exact".into());
        }
        Ok(())
    });
}

#[test]
fn delta_roundtrip_survives_any_ack_lag_within_the_ring() {
    check("delta_ack_lag", 60, |g| {
        // a chain of committed versions, like the server's SnapshotRing
        let depth = 1 + g.usize_below(4);
        let mut chain = vec![sample_wire_model(g)];
        for _ in 0..depth {
            let prev = chain.last().unwrap().clone();
            chain.push(perturbed_model(g, &prev, 1 + g.usize_below(6)));
        }
        let t = chain.len() - 1;
        let lag = g.usize_below(depth + 1).min(t);
        let bv = (t - lag) as u64;
        let base = DeltaBase::from_model(bv, &chain[t - lag]);
        let cur = &chain[t];
        let (wire, saved) = encode_frame_v3(cur, g.u64(), &base);
        if decode(&wire, Some(&base))? != expect_bits(cur) {
            return Err(format!("lag {lag}: round-trip not bit-exact"));
        }
        // a base at any other version is a typed refusal, up front
        let wrong_v = g.usize_below(t + 1) as u64;
        if wrong_v != bv {
            let wrong =
                DeltaBase::from_model(wrong_v, &chain[wrong_v as usize]);
            match decode_all_based(&wire, Some(&wrong)) {
                Err(DecodeError::BaseVersionMismatch { frame, have })
                    if frame == bv && have == wrong_v => {}
                other => {
                    return Err(format!(
                        "wrong base must be BaseVersionMismatch, got {other:?}"
                    ))
                }
            }
        }
        // and a *missing* base refuses any frame that carries tag-2
        // records instead of guessing
        if saved > 0 {
            match decode_all_based(&wire, None) {
                Err(DecodeError::MissingDeltaBase { .. }) => {}
                other => {
                    return Err(format!(
                        "missing base must be MissingDeltaBase, got {other:?}"
                    ))
                }
            }
        }
        Ok(())
    });
}

#[test]
fn base_payload_length_mismatch_is_a_typed_refusal() {
    check("delta_len_mismatch", 30, |g| {
        let base_m = sample_wire_model(g);
        let cur = perturbed_model(g, &base_m, 2);
        let base = DeltaBase::from_model(6, &base_m);
        let (wire, saved) = encode_frame_v3(&cur, g.u64(), &base);
        if saved == 0 {
            return Ok(()); // no tag-2 record to mis-match against
        }
        // same version number, different payload shapes: a fresh model's
        // packed vars have different lengths with probability ~1
        let other = sample_wire_model(g);
        let shifted = DeltaBase::from_model(6, &other);
        match decode_all_based(&wire, Some(&shifted)) {
            Err(DecodeError::DeltaLengthMismatch { .. })
            | Err(DecodeError::DeltaCorrupt { .. })
            | Err(DecodeError::BadBlockWidth { .. })
            | Err(DecodeError::MissingDeltaBase { .. }) => Ok(()),
            Ok(got) => {
                // identical shapes by coincidence: XOR against different
                // bytes must not reproduce the plaintext
                if bits(&got) == expect_bits(&cur) {
                    return Err("wrong base silently decoded correctly".into());
                }
                Ok(())
            }
            Err(e) => Err(format!("unexpected refusal {e:?}")),
        }
    });
}

// ---- corruption totality (fuzz layer over the corruption driver) ----------

/// A small-but-complete v3 frame: two packed vars (one delta-coded, one
/// fallback-prone), a raw var, and an empty var behind a real base.
fn small_delta_frame(
    g: &mut Gen,
) -> (CompressedModel, CompressedModel, Vec<u8>) {
    let fmt: FloatFormat = "S1E3M7".parse().unwrap();
    let base_m = CompressedModel::new(vec![
        StoredVar::compress(&g.vec_normal(220, 0.05), fmt, true),
        StoredVar::raw(g.vec_normal(16, 1.0)),
        StoredVar::compress(&g.vec_normal(77, 0.2), fmt, false),
        StoredVar::raw(vec![]),
    ]);
    let cur = perturbed_model(g, &base_m, 2);
    let base = DeltaBase::from_model(11, &base_m);
    let (wire, _) = encode_frame_v3(&cur, 0xFEED_F00D, &base);
    (base_m, cur, wire)
}

#[test]
fn every_truncation_of_a_v3_frame_is_a_typed_error() {
    let mut g = Gen::new(0x7A11);
    let (base_m, cur, wire) = small_delta_frame(&mut g);
    let base = DeltaBase::from_model(11, &base_m);
    assert_eq!(
        decode(&wire, Some(&base)).unwrap(),
        expect_bits(&cur),
        "the uncorrupted frame must decode"
    );
    for len in 0..wire.len() {
        let cut = truncate_at(&wire, len);
        match decode_all_based(cut, Some(&base)) {
            Err(_) => {}
            Ok(_) => panic!("truncation to {len}/{} decoded", wire.len()),
        }
    }
}

#[test]
fn every_single_bit_flip_of_a_v3_frame_is_a_typed_error() {
    // CRC32C coverage is total: the header CRC spans every header byte
    // (magic, version, nvars, nonce, base_version) and each record's CRC
    // spans the record, so no single-bit flip may decode — corrupted
    // deltas must never silently XOR into a wrong model
    let mut g = Gen::new(0xF11B);
    let (base_m, _cur, wire) = small_delta_frame(&mut g);
    let base = DeltaBase::from_model(11, &base_m);
    for bit in 0..wire.len() * 8 {
        let mut bad = wire.clone();
        flip_bit(&mut bad, bit);
        match decode_all_based(&bad, Some(&base)) {
            Err(_) => {}
            Ok(_) => panic!("bit flip {bit} decoded silently"),
        }
    }
}

#[test]
fn random_byte_corruption_is_always_refused() {
    check("delta_byte_corruption", 120, |g| {
        let (base_m, _cur, wire) = small_delta_frame(g);
        let base = DeltaBase::from_model(11, &base_m);
        let mut bad = wire.clone();
        let at = g.usize_below(bad.len());
        let xor = 1 + (g.u64() & 0xFE) as u8; // nonzero
        corrupt_byte(&mut bad, at, xor);
        match decode_all_based(&bad, Some(&base)) {
            Err(_) => Ok(()),
            Ok(_) => Err(format!("byte {at} ^ {xor:#x} decoded silently")),
        }
    });
}

#[test]
fn replayed_delta_frames_trip_the_nonce_ledger() {
    let mut g = Gen::new(0xD0_0DAD);
    let (_base_m, _cur, wire) = small_delta_frame(&mut g);
    let nonce = frame_nonce(&wire).unwrap();
    assert_eq!(nonce, Some(0xFEED_F00D), "v3 frames carry their nonce");
    let mut ledger = NonceLedger::new(8);
    ledger.observe(nonce).unwrap();
    match ledger.observe(nonce) {
        Err(DecodeError::DuplicateNonce(n)) => assert_eq!(n, 0xFEED_F00D),
        other => panic!("replay must be DuplicateNonce, got {other:?}"),
    }
}
