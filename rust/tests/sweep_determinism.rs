//! Sweep-engine guarantees: byte-identical summaries across runs and
//! across sequential vs pooled scheduling, and resume equivalence (an
//! interrupted sweep completed with `--resume` emits the exact bytes of an
//! uninterrupted run). Everything runs on the native backend, so these
//! gates hold in every build — they are the in-repo twin of the CI
//! `smoke-goldens` job.

use std::path::PathBuf;

use omc_fl::coordinator::sweep::{self, SweepOptions, SweepSpec};
use omc_fl::runtime::engine::Engine;
use omc_fl::util::json;

fn tmp_dir(case: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "omc_sweep_test_{}_{case}",
        std::process::id()
    ));
    std::fs::remove_dir_all(&d).ok();
    d
}

fn smoke_spec(out: &PathBuf) -> SweepSpec {
    let mut spec = sweep::smoke(7).unwrap();
    spec.output_dir = out.clone();
    spec
}

fn opts(workers: usize, sequential: bool, resume: bool) -> SweepOptions {
    SweepOptions {
        workers,
        sequential,
        resume,
    }
}

#[test]
fn summary_bytes_identical_across_runs_and_scheduling() {
    let engine = Engine::cpu().unwrap();
    let dirs: Vec<PathBuf> =
        ["a", "b", "c"].iter().map(|s| tmp_dir(s)).collect();

    // two sequential runs + one pooled run of the same spec
    let seq_a = sweep::run_sweep(&engine, &smoke_spec(&dirs[0]), &opts(1, true, false)).unwrap();
    let seq_b = sweep::run_sweep(&engine, &smoke_spec(&dirs[1]), &opts(1, true, false)).unwrap();
    let pooled = sweep::run_sweep(&engine, &smoke_spec(&dirs[2]), &opts(4, false, false)).unwrap();

    assert!(!seq_a.summary_bytes.is_empty());
    assert_eq!(
        seq_a.summary_bytes, seq_b.summary_bytes,
        "same spec, two runs: summary bytes must match"
    );
    assert_eq!(
        seq_a.summary_bytes, pooled.summary_bytes,
        "sequential vs pooled scheduling: summary bytes must match"
    );
    // the bytes on disk are the bytes reported
    let on_disk = std::fs::read_to_string(&seq_a.summary_path).unwrap();
    assert_eq!(on_disk, seq_a.summary_bytes);

    // sanity: the document is well-formed and cell-complete
    let doc = json::parse(&seq_a.summary_bytes).unwrap();
    assert_eq!(
        doc.get("num_cells").and_then(|v| v.as_usize()),
        Some(seq_a.cells.len())
    );
    assert_eq!(doc.get("sweep").and_then(|v| v.as_str()), Some("sweep_smoke"));
    let cells = doc.get("cells").unwrap().as_arr().unwrap();
    assert_eq!(cells.len(), 5);
    // every cell carries a finite loss and its fingerprint
    for c in cells {
        assert!(c.get("config_hash").and_then(|v| v.as_str()).is_some());
        assert!(c
            .get("final_train_loss")
            .and_then(|v| v.as_f64())
            .is_some());
    }
    for d in dirs {
        std::fs::remove_dir_all(d).ok();
    }
}

#[test]
fn resume_completes_interrupted_sweep_byte_identically() {
    let engine = Engine::cpu().unwrap();
    let full_dir = tmp_dir("full");
    let resume_dir = tmp_dir("resume");

    // reference: uninterrupted run
    let full = sweep::run_sweep(&engine, &smoke_spec(&full_dir), &opts(1, true, false)).unwrap();

    // "killed after 2 cells": run a truncated copy of the same spec —
    // cells keep their positions and derived seeds (no re-finalize)
    let mut partial = smoke_spec(&resume_dir);
    partial.cells.truncate(2);
    sweep::run_sweep(&engine, &partial, &opts(1, true, false)).unwrap();

    // --resume completes the remaining cells
    let resumed = sweep::run_sweep(
        &engine,
        &smoke_spec(&resume_dir),
        &opts(1, true, true),
    )
    .unwrap();
    assert_eq!(resumed.cells_resumed, 2);
    assert!(resumed.cells[0].resumed && resumed.cells[1].resumed);
    assert!(resumed.cells[2..].iter().all(|c| !c.resumed));
    assert_eq!(
        resumed.summary_bytes, full.summary_bytes,
        "resumed sweep must emit the uninterrupted run's exact bytes"
    );

    // a second resume touches nothing and still matches
    let again = sweep::run_sweep(
        &engine,
        &smoke_spec(&resume_dir),
        &opts(1, true, true),
    )
    .unwrap();
    assert_eq!(again.cells_resumed, 5);
    assert_eq!(again.summary_bytes, full.summary_bytes);

    std::fs::remove_dir_all(full_dir).ok();
    std::fs::remove_dir_all(resume_dir).ok();
}

#[test]
fn resume_reruns_cells_with_stale_fingerprints() {
    let engine = Engine::cpu().unwrap();
    let dir = tmp_dir("stale");
    let spec = smoke_spec(&dir);
    let full = sweep::run_sweep(&engine, &spec, &opts(1, true, false)).unwrap();

    // tamper with cell 1's recorded fingerprint → its summary is stale
    let stem = sweep::cell_file_stem(1, &spec.cells[1].name);
    let path = dir.join("cells").join(format!("{stem}.json"));
    let text = std::fs::read_to_string(&path).unwrap();
    let real = spec.cell_fingerprint_hex(&spec.cells[1]);
    let tampered = text.replace(&real, "0000000000000000");
    assert_ne!(tampered, text, "fingerprint must appear in the summary");
    std::fs::write(&path, tampered).unwrap();

    let resumed = sweep::run_sweep(&engine, &spec, &opts(1, true, true)).unwrap();
    assert_eq!(resumed.cells_resumed, 4, "the stale cell must re-run");
    assert!(!resumed.cells[1].resumed);
    assert_eq!(
        resumed.summary_bytes, full.summary_bytes,
        "re-running the stale cell restores the reference bytes"
    );
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn bless_writes_the_golden_copy() {
    let engine = Engine::cpu().unwrap();
    let dir = tmp_dir("bless");
    let goldens = tmp_dir("bless_goldens");
    let report =
        sweep::run_sweep(&engine, &smoke_spec(&dir), &opts(1, true, false)).unwrap();
    let path = sweep::bless_golden(&report, &goldens).unwrap();
    assert_eq!(path.file_name().unwrap(), "sweep_smoke.json");
    assert_eq!(
        std::fs::read_to_string(&path).unwrap(),
        report.summary_bytes
    );
    std::fs::remove_dir_all(dir).ok();
    std::fs::remove_dir_all(goldens).ok();
}
