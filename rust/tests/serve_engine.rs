//! Serving-engine guarantees, end-to-end on the native backend:
//!
//! * **Bit-identity vs the planned timeline** — the wall-clock engine
//!   (real worker threads, epoch-published snapshots, arena-pooled
//!   frames, bounded uplink queue) commits byte-identical parameters to
//!   the inline `AsyncRoundEngine` reference at any worker count, because
//!   the server re-imposes plan order on whatever the queue delivers.
//!   This is the in-repo twin of the CI `smoke-serve` `cmp` gate.
//! * **Feature transparency** — chaos injection and the delta wire stage
//!   ride through the served path unchanged: same commits, same metrics
//!   as the planned timeline with the same knobs.
//! * **Backpressure safety** — a one-slot uplink queue forces the
//!   reject-and-account → blocking re-admit path; planned folds are never
//!   lost and the committed bytes still match.
//! * **Report honesty** — the admission probe rejects exactly its eight
//!   offered frames, the arena A/B shows recycling only when enabled, and
//!   latency quantiles are populated whenever uplinks flowed.

use std::path::Path;

use omc_fl::coordinator::config::{ExperimentConfig, OmcConfig};
use omc_fl::coordinator::Experiment;
use omc_fl::fl::async_round::{AsyncConfig, StalenessPolicy};
use omc_fl::fl::chaos::ChaosConfig;
use omc_fl::fl::cohort::CohortConfig;
use omc_fl::fl::serve::{ServeConfig, ServeReport};
use omc_fl::metrics::recorder::Recorder;
use omc_fl::runtime::engine::Engine;

/// The async stress shape from `tests/async_round.rs`: stragglers,
/// dropout, weighted FedAvg, a small buffer, polynomial discount,
/// staleness discards, and partial selection.
fn base_cfg(name: &str) -> ExperimentConfig {
    let mut c = ExperimentConfig::default_with(name, Path::new("native:tiny"));
    c.rounds = 5;
    c.num_clients = 16;
    c.clients_per_round = 8;
    c.local_steps = 1;
    c.lr = 0.2;
    c.eval_every = 10;
    c.eval_batches = 1;
    c.omc = OmcConfig {
        format: "S1E4M14".parse().unwrap(),
        use_pvt: true,
        weights_only: true,
        fraction: 0.9,
        integrity: false,
    };
    c.cohort = CohortConfig {
        dropout_prob: 0.1,
        straggler_mean_s: 2.0,
        deadline_s: f64::INFINITY,
        weight_by_examples: true,
    };
    c.async_cfg = AsyncConfig {
        enabled: true,
        concurrency: 6,
        buffer_k: 3,
        policy: StalenessPolicy::Polynomial { alpha: 0.5 },
        max_staleness: 4,
        snapshot_ring: 3,
    };
    // streamed per-commit rows belong in a scratch dir, not the repo
    c.output_dir = std::env::temp_dir().join("omc_serve_engine_test");
    c
}

fn serve_cfg(name: &str, base: &ExperimentConfig, serve: ServeConfig) -> ExperimentConfig {
    let mut c = base.clone();
    c.name = name.to_string();
    c.serve = serve;
    c
}

fn param_bits(exp: &Experiment) -> Vec<Vec<u32>> {
    exp.server
        .params
        .iter()
        .map(|v| v.iter().map(|x| x.to_bits()).collect())
        .collect()
}

fn reference_bits(base: &ExperimentConfig) -> Vec<Vec<u32>> {
    let engine = Engine::cpu().unwrap();
    let mut c = base.clone();
    c.name = format!("{}_ref", c.name);
    let mut exp = Experiment::prepare(&engine, c).unwrap();
    exp.run_async_params_only().unwrap();
    param_bits(&exp)
}

fn run_serve(cfg: ExperimentConfig) -> (Vec<Vec<u32>>, Recorder, ServeReport) {
    let engine = Engine::cpu().unwrap();
    let mut exp = Experiment::prepare(&engine, cfg).unwrap();
    let (rec, report) = exp.run_serve().unwrap();
    (param_bits(&exp), rec, report)
}

#[test]
fn served_commits_are_bit_identical_to_planned_timeline() {
    let base = base_cfg("serve_eq");
    let ref_bits = reference_bits(&base);
    let mut csv: Option<String> = None;
    for workers in [1usize, 4] {
        let serve = ServeConfig {
            enabled: true,
            workers,
            ..ServeConfig::default()
        };
        let (bits, rec, report) =
            run_serve(serve_cfg(&format!("serve_w{workers}"), &base, serve));
        assert_eq!(
            bits, ref_bits,
            "served commits diverged at workers={workers}"
        );
        // the virtual-time metrics are schedule-independent too
        match &csv {
            None => csv = Some(rec.commits_csv()),
            Some(c) => assert_eq!(c, &rec.commits_csv()),
        }
        assert_eq!(report.commits, base.rounds);
        assert_eq!(report.workers, workers);
        assert!(report.uplinks > 0, "no uplinks delivered");
        assert!(report.wall_s > 0.0);
        assert!(report.down_bytes > 0 && report.up_bytes > 0);
    }
}

#[test]
fn serve_is_transparent_to_chaos_and_delta_stages() {
    let mut base = base_cfg("serve_chaos_delta");
    base.rounds = 6;
    base.omc.integrity = true; // chaos + delta both ride the v3 layout
    base.delta.enabled = true;
    base.chaos = ChaosConfig {
        enabled: true,
        bitflip_prob: 0.2,
        truncate_prob: 0.1,
        duplicate_prob: 0.15,
        crash_prob: 0.1,
        commit_failure_prob: 0.5,
        ..ChaosConfig::default()
    };
    let ref_bits = reference_bits(&base);
    let serve = ServeConfig {
        enabled: true,
        workers: 4,
        ..ServeConfig::default()
    };
    let (bits, rec, _) = run_serve(serve_cfg("serve_cd_w4", &base, serve));
    assert_eq!(bits, ref_bits, "chaos+delta served run diverged");
    // the fault injection really fired through the served path
    assert!(rec.total_frames_rejected() > 0, "chaos never bit a frame");
    assert!(rec.total_crashed() > 0, "no chaos kills");
}

#[test]
fn one_slot_queue_backpressure_loses_no_folds() {
    let base = base_cfg("serve_bp");
    let ref_bits = reference_bits(&base);
    let serve = ServeConfig {
        enabled: true,
        workers: 4,
        queue_depth: 1,
        probe: false,
        ..ServeConfig::default()
    };
    let (bits, _, report) = run_serve(serve_cfg("serve_bp_q1", &base, serve));
    assert_eq!(bits, ref_bits, "backpressure leaked into the commits");
    assert_eq!(report.queue_depth, 1);
    assert!(report.queue_peak_depth <= 1, "queue overfilled its bound");
    // rejected uplinks were re-admitted, never dropped: every fold the
    // plan scheduled happened (proved by the bit-identity above), and any
    // rejection that did occur carries its bytes
    if report.queue_rejected_frames > 0 {
        assert!(report.queue_rejected_bytes > 0);
    }
}

#[test]
fn report_accounts_probe_arena_and_latency() {
    let base = base_cfg("serve_report");
    let on = ServeConfig {
        enabled: true,
        workers: 2,
        ..ServeConfig::default()
    };
    let (_, _, rep_on) = run_serve(serve_cfg("serve_rep_on", &base, on));
    // the shutdown probe offers 8 frames to a deliberately-full queue and
    // every one must be rejected-and-accounted (the CI liveness grep)
    assert_eq!(rep_on.probe_rejected_frames, 8);
    assert!(rep_on.rejected_total() >= 8);
    // pooling really pooled: downlink frames recycle wave-over-wave
    assert!(rep_on.frame_arena.acquires > 0);
    assert!(rep_on.frame_arena.recycled > 0, "arena never recycled");
    assert_eq!(
        rep_on.frame_arena.fresh + rep_on.frame_arena.recycled,
        rep_on.frame_arena.acquires
    );
    assert!(rep_on.scratch_arena.acquires > 0);
    // measured latency quantiles are populated and ordered
    assert!(rep_on.uplink_p50_s > 0.0);
    assert!(rep_on.uplink_p99_s >= rep_on.uplink_p50_s);
    assert!(rep_on.commits_per_sec() > 0.0);
    assert!(rep_on.bytes_per_sec() > 0.0);

    let off = ServeConfig {
        arena: false,
        probe: false,
        ..on
    };
    let (_, _, rep_off) = run_serve(serve_cfg("serve_rep_off", &base, off));
    assert_eq!(rep_off.probe_rejected_frames, 0, "probe ran while disabled");
    assert_eq!(rep_off.frame_arena.recycled, 0, "disabled arena recycled");
    assert_eq!(rep_off.scratch_arena.recycled, 0);
}

#[test]
fn paced_open_loop_run_matches_unpaced_commits() {
    // pacing throttles *dispatch wall-clock*, never the plan: a fast rate
    // keeps the test quick while still walking the pacing code path
    let base = base_cfg("serve_paced");
    let ref_bits = reference_bits(&base);
    let serve = ServeConfig {
        enabled: true,
        workers: 2,
        rate: 2000.0,
        probe: false,
        ..ServeConfig::default()
    };
    let (bits, _, report) = run_serve(serve_cfg("serve_paced_r", &base, serve));
    assert_eq!(bits, ref_bits, "pacing leaked into the commits");
    assert_eq!(report.commits, base.rounds);
}
