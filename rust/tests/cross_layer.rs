//! Cross-layer bit-exactness: the Rust OMC codec vs the Pallas kernel.
//!
//! Executes `artifacts/quant.hlo.txt` (the standalone L1 quantizer, lowered
//! from the Pallas kernel) on the PJRT CPU client and asserts the outputs
//! equal `omc::quantize` **bit for bit** across formats and input
//! distributions. This is the invariant that lets quantized values cross
//! the wire bit-packed (DESIGN.md §6).

mod common;

use omc_fl::omc::format::FloatFormat;
use omc_fl::omc::pack;
use omc_fl::omc::quantize::quantize_vec;
use omc_fl::runtime::engine::{lit_f32, lit_i32_scalar, to_f32_vec, Engine};
use omc_fl::util::rng::Xoshiro256pp;

const N: usize = 8192; // must match aot.QUANT_TEST_N

fn gen_inputs(seed: u64, scale: f32) -> Vec<f32> {
    let mut rng = Xoshiro256pp::new(seed);
    let mut v = vec![0.0f32; N];
    rng.fill_normal(&mut v, scale);
    // sprinkle special values
    v[0] = 0.0;
    v[1] = -0.0;
    v[2] = f32::MIN_POSITIVE;
    v[3] = -f32::MIN_POSITIVE / 2.0;
    v[4] = 3.4e38;
    v[5] = -3.4e38;
    v
}

#[test]
fn rust_codec_matches_pallas_kernel_bitexact() {
    if common::artifacts_missing("quant.hlo.txt") {
        return;
    }
    let engine = Engine::cpu().unwrap();
    let exe = engine
        .load_hlo_text(&common::artifacts_dir().join("quant.hlo.txt"))
        .unwrap();
    for fmt_s in [
        "S1E8M23", "S1E5M10", "S1E4M14", "S1E3M7", "S1E2M3", "S1E3M9",
        "S1E4M8", "S1E5M7",
    ] {
        let fmt: FloatFormat = fmt_s.parse().unwrap();
        for (seed, scale) in [(1u64, 0.05f32), (2, 1.0), (3, 1e-4), (4, 300.0)] {
            let v = gen_inputs(seed, scale);
            let outs = exe
                .run(&[
                    lit_f32(&v, &[N as i64]).unwrap(),
                    lit_i32_scalar(fmt.exp_bits as i32),
                    lit_i32_scalar(fmt.mant_bits as i32),
                ])
                .unwrap();
            let kernel = to_f32_vec(&outs[0]).unwrap();
            let rust = quantize_vec(&v, fmt);
            let mut mismatches = 0;
            for i in 0..N {
                if kernel[i].to_bits() != rust[i].to_bits() {
                    if mismatches < 5 {
                        eprintln!(
                            "{fmt_s} seed {seed} idx {i}: x={:e} kernel={:e}({:#010x}) rust={:e}({:#010x})",
                            v[i],
                            kernel[i],
                            kernel[i].to_bits(),
                            rust[i],
                            rust[i].to_bits()
                        );
                    }
                    mismatches += 1;
                }
            }
            assert_eq!(mismatches, 0, "{fmt_s} seed {seed}: {mismatches}/{N}");
        }
    }
}

#[test]
fn kernel_outputs_pack_without_loss() {
    // end-to-end: kernel-quantized values must survive the Rust bit-packer
    if common::artifacts_missing("quant.hlo.txt") {
        return;
    }
    let engine = Engine::cpu().unwrap();
    let exe = engine
        .load_hlo_text(&common::artifacts_dir().join("quant.hlo.txt"))
        .unwrap();
    let fmt: FloatFormat = "S1E3M7".parse().unwrap();
    let v = gen_inputs(7, 0.05);
    let outs = exe
        .run(&[
            lit_f32(&v, &[N as i64]).unwrap(),
            lit_i32_scalar(3),
            lit_i32_scalar(7),
        ])
        .unwrap();
    let kernel = to_f32_vec(&outs[0]).unwrap();
    let bytes = pack::pack(&kernel, fmt).expect("kernel output must be packable");
    assert_eq!(bytes.len(), fmt.packed_bytes(N));
    let back = pack::unpack(&bytes, N, fmt);
    for i in 0..N {
        assert_eq!(back[i].to_bits(), kernel[i].to_bits(), "idx {i}");
    }
}
