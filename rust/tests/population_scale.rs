//! Fleet-scale population guarantees, end to end: the 10^6-registered
//! smoke-scale profile emits byte-identical summaries across runs and
//! scheduling, its population metrics object carries live churn/wave/edge
//! counters (the in-repo twin of the CI scale-determinism leg's greps),
//! and a direct population-mode experiment keeps per-round records whose
//! accounting is O(active cohort) — nothing scales with the registered
//! fleet. Everything runs on the native backend.

use std::path::PathBuf;

use omc_fl::coordinator::config::ExperimentConfig;
use omc_fl::coordinator::sweep::{self, SweepOptions, SweepSpec};
use omc_fl::coordinator::Experiment;
use omc_fl::fl::population::PopulationConfig;
use omc_fl::runtime::engine::Engine;
use omc_fl::util::json;

fn tmp_dir(case: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "omc_pop_test_{}_{case}",
        std::process::id()
    ));
    std::fs::remove_dir_all(&d).ok();
    d
}

fn scale_spec(out: &PathBuf) -> SweepSpec {
    let mut spec = sweep::smoke_scale(7).unwrap();
    spec.output_dir = out.clone();
    spec
}

fn opts(workers: usize, sequential: bool) -> SweepOptions {
    SweepOptions {
        workers,
        sequential,
        resume: false,
    }
}

#[test]
fn scale_summary_is_byte_identical_and_counters_are_live() {
    let engine = Engine::cpu().unwrap();
    let dirs: Vec<PathBuf> =
        ["a", "b", "c"].iter().map(|s| tmp_dir(s)).collect();

    let seq_a =
        sweep::run_sweep(&engine, &scale_spec(&dirs[0]), &opts(1, true))
            .unwrap();
    let seq_b =
        sweep::run_sweep(&engine, &scale_spec(&dirs[1]), &opts(1, true))
            .unwrap();
    let pooled =
        sweep::run_sweep(&engine, &scale_spec(&dirs[2]), &opts(4, false))
            .unwrap();

    assert!(!seq_a.summary_bytes.is_empty());
    assert_eq!(
        seq_a.summary_bytes, seq_b.summary_bytes,
        "same spec, two runs: summary bytes must match"
    );
    assert_eq!(
        seq_a.summary_bytes, pooled.summary_bytes,
        "sequential vs pooled scheduling: summary bytes must match"
    );

    let doc = json::parse(&seq_a.summary_bytes).unwrap();
    assert_eq!(doc.get("schema_version").and_then(|v| v.as_usize()), Some(5));
    let cells = doc.get("cells").unwrap().as_arr().unwrap();
    assert_eq!(cells.len(), 5);

    // every cell runs the lazy fleet and records live scale metrics — the
    // in-repo twin of the CI scale leg's nonzero-counter greps
    let mut churn = 0.0f64;
    let mut wave = 0.0f64;
    let mut frames = 0.0f64;
    for c in cells {
        assert_eq!(c.get("population_mode").and_then(|v| v.as_bool()), Some(true));
        let p = c.get("population").expect("population metrics object");
        assert_eq!(
            p.get("registered").and_then(|v| v.as_f64()),
            Some(1_000_000.0)
        );
        let attempts = p.get("sample_attempts").and_then(|v| v.as_f64()).unwrap();
        assert!(attempts > 0.0);
        churn += p.get("churn_rejections").and_then(|v| v.as_f64()).unwrap();
        wave += p.get("wave_rejections").and_then(|v| v.as_f64()).unwrap();
        frames += p.get("edge_frames").and_then(|v| v.as_f64()).unwrap();
        assert!(p.get("edge_up_bytes").and_then(|v| v.as_f64()).unwrap() > 0.0);
        // the per-class arrays cover the full device ladder
        assert_eq!(
            p.get("class_sampled").and_then(|v| v.as_arr()).unwrap().len(),
            4
        );
    }
    assert!(churn > 0.0, "churn knobs must reject candidates");
    assert!(wave > 0.0, "wave knobs must reject candidates");
    assert!(frames > 0.0, "edge hop must ship frames");

    // the delta cell's edge hop saves bytes by round 2+ (static fleet
    // weights → repeating participation headers and near-static sums)
    let delta_cell = cells
        .iter()
        .find(|c| {
            c.get("label").and_then(|v| v.as_str())
                == Some("edges4_integrity_delta")
        })
        .expect("delta cell present");
    assert_eq!(
        delta_cell.get("delta_enabled").and_then(|v| v.as_bool()),
        Some(true)
    );

    for d in dirs {
        std::fs::remove_dir_all(d).ok();
    }
}

#[test]
fn scale_resume_completes_byte_identically() {
    let engine = Engine::cpu().unwrap();
    let full_dir = tmp_dir("full");
    let resume_dir = tmp_dir("resume");

    let full =
        sweep::run_sweep(&engine, &scale_spec(&full_dir), &opts(1, true))
            .unwrap();

    let mut partial = scale_spec(&resume_dir);
    partial.cells.truncate(2);
    sweep::run_sweep(&engine, &partial, &opts(1, true)).unwrap();

    let resumed = sweep::run_sweep(
        &engine,
        &scale_spec(&resume_dir),
        &SweepOptions {
            workers: 1,
            sequential: true,
            resume: true,
        },
    )
    .unwrap();
    assert_eq!(resumed.cells_resumed, 2);
    assert_eq!(
        resumed.summary_bytes, full.summary_bytes,
        "population cells must splice back byte-identically"
    );

    std::fs::remove_dir_all(full_dir).ok();
    std::fs::remove_dir_all(resume_dir).ok();
}

#[test]
fn direct_population_run_records_o_active_rounds() {
    let engine = Engine::cpu().unwrap();
    let out = tmp_dir("direct");
    let mut cfg = ExperimentConfig::default_with(
        "pop_e2e",
        std::path::Path::new("native:tiny"),
    );
    cfg.rounds = 3;
    cfg.num_clients = 8; // ignored: the lazy fleet below replaces it
    cfg.clients_per_round = 4;
    cfg.local_steps = 1;
    cfg.lr = 0.2;
    cfg.eval_every = 2;
    cfg.eval_batches = 2;
    cfg.workers = 1;
    cfg.output_dir = out.clone();
    cfg.population = PopulationConfig {
        enabled: true,
        registered: 1_000_000,
        edges: 2,
        churn_rate: 0.4,
        churn_period: 1,
        wave_amplitude: 0.5,
        wave_period: 4,
    };
    cfg.validate().unwrap();

    let mut exp = Experiment::prepare(&engine, cfg).unwrap();
    let (rec, summary) = exp.run().unwrap();
    assert!(summary.final_loss.is_finite());
    assert!(rec.is_population());
    assert_eq!(rec.populations.len(), 3, "one record per round");
    for p in &rec.populations {
        assert_eq!(p.registered, 1_000_000);
        assert_eq!(p.edges, 2);
        // the cohort streams out of the fleet: k draws need >= k attempts
        assert!(p.sample.attempts >= 4);
        let sampled: u64 = p.sample.class_sampled.iter().sum();
        assert_eq!(sampled, 4, "class tallies cover the whole cohort");
        // at most one merged frame per edge ever reaches the root
        assert!(p.edge.frames >= 1 && p.edge.frames <= 2);
        assert!(p.edge.up_bytes > 0);
    }
    assert!(rec.total_sample_attempts() >= 12);
    assert!(rec.mean_active_estimate() > 0.0);
    assert!(
        rec.mean_active_estimate() < 1_000_000.0,
        "churn + wave must shrink the active fleet below registered"
    );

    // per-round population log lands beside the usual csv/json outputs
    rec.write(&out).unwrap();
    let pop_csv =
        std::fs::read_to_string(out.join("pop_e2e_population.csv")).unwrap();
    assert!(pop_csv.starts_with("round,registered,"));
    assert_eq!(pop_csv.lines().count(), 4, "header + one row per round");

    std::fs::remove_dir_all(out).ok();
}
