//! Shared helpers for integration tests (which need the AOT artifacts).

use std::path::PathBuf;

/// Repo root (tests run with CWD = crate root).
pub fn repo_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

pub fn artifacts_dir() -> PathBuf {
    repo_root().join("artifacts")
}

/// Skip (returning true) when artifacts have not been built. CI and the
/// Makefile always build them; this keeps a bare `cargo test` usable.
pub fn artifacts_missing(sub: &str) -> bool {
    let p = artifacts_dir().join(sub);
    if p.exists() {
        false
    } else {
        eprintln!(
            "SKIP: {} not found — run `python python/compile/aot.py --out-dir artifacts` first",
            p.display()
        );
        true
    }
}
