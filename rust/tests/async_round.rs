//! Async round engine guarantees, end-to-end on the native backend:
//!
//! * **Sync equivalence** — with a constant staleness discount, buffer
//!   `K = concurrency = cohort size`, and an ideal-latency cohort, the
//!   async engine's first commit performs exactly the f64 operations of
//!   one synchronous round — same cohort, masks, RNG streams, downlink
//!   bytes, fold order (zero-latency arrivals process FIFO = the sync
//!   cohort order) and normalized weights — so the committed model bytes
//!   are bit-identical to the `StreamingAggregator` sync path.
//! * **Schedule independence** — sequential vs `scope_map`-pooled async
//!   execution produces byte-identical committed model bytes AND metrics
//!   for any worker count (stronger than the sync sharded path, which
//!   reassociates f64 sums). This is the in-repo twin of the CI
//!   `async-determinism` leg.
//! * **Smoke-async sweep determinism** — `sweep::smoke_async` summaries
//!   are byte-identical across runs and cell-pool scheduling.
//! * **Delta wire stage** — turning the lossless cross-round delta stage
//!   on (v3 frames, XOR against the served snapshot + per-block
//!   bitpacking) changes the bytes on the wire and nothing else: the
//!   committed model, losses, and WER are bit-identical to the verbatim
//!   control, in sync mode, through the async snapshot-ring base path,
//!   and under chaos-driven rejects/retries.

use std::path::{Path, PathBuf};

use omc_fl::coordinator::config::{ExperimentConfig, OmcConfig};
use omc_fl::coordinator::{sweep, Experiment, SweepOptions};
use omc_fl::data::partition::Partition;
use omc_fl::fl::async_round::{AsyncConfig, StalenessPolicy};
use omc_fl::fl::chaos::ChaosConfig;
use omc_fl::fl::cohort::CohortConfig;
use omc_fl::metrics::sweep::cell_summary;
use omc_fl::runtime::engine::Engine;

fn base_cfg(name: &str) -> ExperimentConfig {
    let mut c = ExperimentConfig::default_with(name, Path::new("native:tiny"));
    c.rounds = 1;
    c.num_clients = 8;
    c.clients_per_round = 4;
    c.local_steps = 1;
    c.lr = 0.2;
    c.eval_every = 10;
    c.eval_batches = 2;
    c.workers = 1;
    // full selection: every eligible variable ships packed, so the async
    // snapshot-ring downlink is byte-identical to the sync downlink
    c.omc = OmcConfig {
        format: "S1E4M14".parse().unwrap(),
        use_pvt: true,
        weights_only: true,
        fraction: 1.0,
        integrity: false,
    };
    // by-speaker shards give clients different example counts, so the
    // weighted normalization is non-trivial
    c.partition = Partition::BySpeaker;
    c.cohort = CohortConfig {
        weight_by_examples: true,
        ..CohortConfig::ideal()
    };
    c
}

fn run(cfg: ExperimentConfig) -> (Experiment, omc_fl::metrics::recorder::Recorder) {
    let engine = Engine::cpu().unwrap();
    let mut exp = Experiment::prepare(&engine, cfg).unwrap();
    let (rec, _) = exp.run().unwrap();
    (exp, rec)
}

fn param_bits(exp: &Experiment) -> Vec<Vec<u32>> {
    exp.server
        .params
        .iter()
        .map(|v| v.iter().map(|x| x.to_bits()).collect())
        .collect()
}

#[test]
fn async_first_commit_is_bit_exact_vs_sync_streaming_round() {
    // sync: one round through the StreamingAggregator path
    let (sync_exp, sync_rec) = run(base_cfg("sync_ref"));

    // async: one commit, K = concurrency = cohort, constant discount 1.0
    let mut acfg = base_cfg("async_eq");
    acfg.async_cfg = AsyncConfig {
        enabled: true,
        concurrency: 0, // -> clients_per_round
        buffer_k: 0,    // -> concurrency
        policy: StalenessPolicy::Constant(1.0),
        max_staleness: usize::MAX,
        snapshot_ring: 2,
    };
    let (async_exp, async_rec) = run(acfg);

    assert_eq!(
        param_bits(&sync_exp),
        param_bits(&async_exp),
        "first async commit must be bit-exact vs the sync round"
    );
    // everything the folded cohort produced agrees bit-for-bit; the async
    // engine additionally dispatched replacement clients that were still
    // in flight when the run ended (their downlinks are honest spend, so
    // down_bytes/sampled legitimately exceed the sync round's)
    let (s, a) = (&sync_rec.records[0], &async_rec.records[0]);
    assert_eq!(s.train_loss.to_bits(), a.train_loss.to_bits());
    assert_eq!(s.eval_wer.to_bits(), a.eval_wer.to_bits());
    assert_eq!(s.eval_loss.to_bits(), a.eval_loss.to_bits());
    assert_eq!(s.up_bytes, a.up_bytes, "only the folded cohort trained");
    assert_eq!(s.completed, a.completed);
    assert!(a.down_bytes >= s.down_bytes, "refills spend extra downlink");
    assert!(a.sampled >= s.sampled);
    // async bookkeeping for the equivalent commit: no staleness at all
    assert!(async_rec.is_async());
    assert_eq!(async_rec.staleness_histogram(), vec![4]);
    assert_eq!(async_rec.total_discarded_updates(), 0);
    assert!(async_rec.last_ring_bytes() > 0);
}

#[test]
fn async_constant_discount_value_does_not_change_commits() {
    // the constant cancels in the per-commit normalization: 0.5 scales
    // weights by an exact power of two that divides out bit-exactly
    let mk = |c: f64, name: &str| {
        let mut cfg = base_cfg(name);
        cfg.rounds = 3;
        cfg.async_cfg = AsyncConfig {
            enabled: true,
            policy: StalenessPolicy::Constant(c),
            snapshot_ring: 2,
            ..AsyncConfig::default()
        };
        run(cfg).0
    };
    assert_eq!(param_bits(&mk(1.0, "c1")), param_bits(&mk(0.5, "c05")));
}

/// A config that exercises everything at once: stragglers, dropout,
/// weighted FedAvg, a small buffer, polynomial discount, staleness
/// discards, and partial selection (the snapshot ring serves some
/// variables as decompressed copies).
fn stress_cfg(workers: usize) -> ExperimentConfig {
    let mut c = base_cfg("async_stress");
    c.rounds = 5;
    c.num_clients = 16;
    c.clients_per_round = 8;
    c.workers = workers;
    c.omc.fraction = 0.9;
    c.cohort = CohortConfig {
        dropout_prob: 0.1,
        straggler_mean_s: 2.0,
        deadline_s: f64::INFINITY,
        weight_by_examples: true,
    };
    c.async_cfg = AsyncConfig {
        enabled: true,
        concurrency: 6,
        buffer_k: 3,
        policy: StalenessPolicy::Polynomial { alpha: 0.5 },
        max_staleness: 4,
        snapshot_ring: 3,
    };
    c
}

#[test]
fn async_sequential_vs_pooled_is_byte_identical() {
    let (ref_exp, ref_rec) = run(stress_cfg(1));
    let ref_bits = param_bits(&ref_exp);
    // the deterministic cell summary covers every recorded metric and
    // carries no timing — byte-compare it across worker counts
    let ref_summary =
        cell_summary(0, &ref_exp.cfg, "wtest", &ref_rec, &dummy_run()).to_string();
    assert!(ref_summary.contains("\"async_mode\":true"));
    for workers in [2usize, 4, 32] {
        let (exp, rec) = run(stress_cfg(workers));
        assert_eq!(
            ref_bits,
            param_bits(&exp),
            "committed model bytes diverged at workers={workers}"
        );
        let summary =
            cell_summary(0, &exp.cfg, "wtest", &rec, &dummy_run()).to_string();
        assert_eq!(
            ref_summary, summary,
            "async metrics diverged at workers={workers}"
        );
        // the commit-level records agree field by field too
        assert_eq!(rec.commits_csv(), ref_rec.commits_csv());
    }
}

fn dummy_run() -> omc_fl::coordinator::experiment::RunSummary {
    omc_fl::coordinator::experiment::RunSummary {
        label: "w".into(),
        final_wer: 0.0,
        final_loss: 0.0,
        param_memory_bytes: 0,
        memory_ratio: 0.0,
        comm_bytes_per_round: 0.0,
        rounds_per_min: 0.0,
        rounds: 0,
    }
}

#[test]
fn async_run_is_deterministic_across_runs() {
    let (a, rec_a) = run(stress_cfg(4));
    let (b, rec_b) = run(stress_cfg(4));
    assert_eq!(param_bits(&a), param_bits(&b));
    assert_eq!(rec_a.commits_csv(), rec_b.commits_csv());
}

#[test]
fn async_stress_actually_exercises_staleness_and_discards() {
    // guard against the stress config silently degenerating into the
    // sync-equivalent regime where the other tests prove nothing
    let (_, rec) = run(stress_cfg(1));
    assert_eq!(rec.commits.len(), 5);
    assert!(rec.mean_staleness() > 0.0, "no staleness observed");
    assert!(rec.final_virtual_time() > 0.0);
    // virtual time is monotone across commits
    for w in rec.commits.windows(2) {
        assert!(w[1].virtual_time >= w[0].virtual_time);
    }
    // ring memory is reported and beats R × fp32 for this mostly-packed model
    assert!(rec.last_ring_bytes() > 0);
    // every commit folded exactly K updates with a valid histogram
    for c in &rec.commits {
        assert_eq!(c.folded, 3);
        assert_eq!(c.staleness_hist.iter().sum::<usize>(), 3);
        assert!(c.mean_occupancy > 0.0);
        assert!(c.param_drift.is_finite());
    }
}

#[test]
fn snapshot_ring_depth_changes_memory_not_committed_bytes() {
    // the stress run at the minimum ring depth: every commit evicts the
    // previous snapshot, and downlink assembly + the drift pass must keep
    // serving from the surviving window — the committed model bytes
    // cannot depend on how much history the server retains. The tightened
    // staleness window makes the regime discard-heavy (at the stress
    // window of 4 this seed discards nothing), so eviction coexists with
    // stale arrivals from already-evicted versions.
    let mk = |ring: usize| {
        let mut c = stress_cfg(1);
        c.async_cfg.snapshot_ring = ring;
        c.async_cfg.max_staleness = 1;
        run(c)
    };
    let (deep_exp, deep_rec) = mk(3);
    let (min_exp, min_rec) = mk(1);
    assert_eq!(
        param_bits(&deep_exp),
        param_bits(&min_exp),
        "ring depth leaked into the committed model"
    );
    // the regime really is discard-heavy (the eviction pressure is real)
    assert!(min_rec.total_discarded_updates() > 0);
    // eviction released the accounted bytes: retaining 1 snapshot costs
    // well under half of retaining 3
    assert!(min_rec.last_ring_bytes() > 0);
    assert!(
        (min_rec.last_ring_bytes() as f64)
            < 0.5 * deep_rec.last_ring_bytes() as f64,
        "ring bytes {} vs {} — eviction did not release memory",
        min_rec.last_ring_bytes(),
        deep_rec.last_ring_bytes()
    );
    // per-commit ring accounting is bounded by the depth at every commit
    let cap1 = min_rec.commits.iter().map(|c| c.ring_bytes).max().unwrap();
    let cap3 = deep_rec.commits.iter().map(|c| c.ring_bytes).max().unwrap();
    assert!(cap1 < cap3);
}

fn chaos_stress_cfg(workers: usize) -> ExperimentConfig {
    let mut c = stress_cfg(workers);
    c.rounds = 8;
    c.omc.integrity = true;
    c.chaos = ChaosConfig {
        enabled: true,
        bitflip_prob: 0.2,
        truncate_prob: 0.1,
        duplicate_prob: 0.15,
        crash_prob: 0.1,
        commit_failure_prob: 0.5,
        ..ChaosConfig::default()
    };
    c
}

#[test]
fn async_chaos_run_conserves_accounting_and_is_deterministic() {
    let (ref_exp, ref_rec) = run(chaos_stress_cfg(1));
    assert_eq!(ref_rec.records.len(), 8);

    // run-level conservation: every dispatched client lands in exactly one
    // bucket; the only dispatches missing from the records are the ones
    // still in flight when the final commit landed, bounded by concurrency
    let sum = |f: fn(&omc_fl::metrics::recorder::RoundRecord) -> usize| {
        ref_rec.records.iter().map(f).sum::<usize>()
    };
    let sampled = sum(|r| r.sampled);
    let accounted =
        sum(|r| r.completed) + sum(|r| r.dropped) + sum(|r| r.late) + sum(|r| r.crashed);
    assert!(
        sampled >= accounted,
        "accounted fates {accounted} exceed {sampled} dispatches"
    );
    assert!(
        sampled - accounted <= 6,
        "unaccounted dispatches {} exceed the concurrency bound",
        sampled - accounted
    );
    // byte accounting: discarded and rejected uplink bytes are disjoint
    // subsets of the spent uplink bytes, per record
    for r in &ref_rec.records {
        assert!(r.up_bytes >= r.up_bytes_discarded + r.up_bytes_rejected);
    }
    // chaos at these rates must be visible in the wire-health counters,
    // and every rejected frame carries rejected bytes
    assert!(ref_rec.total_frames_rejected() > 0, "no frames rejected");
    assert!(ref_rec.total_up_bytes_rejected() > 0);
    assert!(ref_rec.total_crashed() > 0, "no chaos kills");
    assert!(ref_rec.total_commit_failures() > 0, "no commit failures");

    // fault injection is schedule-independent: same seed => same faults =>
    // byte-identical committed model and metrics at any worker count
    let ref_bits = param_bits(&ref_exp);
    for workers in [4usize, 32] {
        let (exp, rec) = run(chaos_stress_cfg(workers));
        assert_eq!(
            ref_bits,
            param_bits(&exp),
            "chaos run diverged at workers={workers}"
        );
        assert_eq!(rec.to_csv(), ref_rec.to_csv());
        assert_eq!(rec.commits_csv(), ref_rec.commits_csv());
    }
}

#[test]
fn smoke_async_sweep_bytes_identical_across_runs_and_scheduling() {
    let engine = Engine::cpu().unwrap();
    let tmp = |case: &str| -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "omc_async_sweep_{}_{case}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&d).ok();
        d
    };
    let spec_for = |dir: &PathBuf| {
        let mut s = sweep::smoke_async(7).unwrap();
        s.output_dir = dir.clone();
        s
    };
    let opts = |workers: usize, sequential: bool| SweepOptions {
        workers,
        sequential,
        resume: false,
    };
    let dirs = [tmp("a"), tmp("b"), tmp("c")];
    let seq_a = sweep::run_sweep(&engine, &spec_for(&dirs[0]), &opts(1, true)).unwrap();
    let seq_b = sweep::run_sweep(&engine, &spec_for(&dirs[1]), &opts(1, true)).unwrap();
    let pooled = sweep::run_sweep(&engine, &spec_for(&dirs[2]), &opts(4, false)).unwrap();
    assert!(!seq_a.summary_bytes.is_empty());
    assert_eq!(seq_a.summary_bytes, seq_b.summary_bytes);
    assert_eq!(seq_a.summary_bytes, pooled.summary_bytes);
    // the async cells actually recorded async metrics
    assert!(seq_a.summary_bytes.contains("\"async_mode\":true"));
    assert!(seq_a.summary_bytes.contains("\"staleness_hist\""));
    for d in dirs {
        std::fs::remove_dir_all(d).ok();
    }
}

// ---- delta wire stage -----------------------------------------------------

fn delta_cfg(name: &str, delta: bool, lr: f32) -> ExperimentConfig {
    let mut c = base_cfg(name);
    c.rounds = 3;
    c.lr = lr;
    c.omc.integrity = true; // the delta stage rides the checksummed v3 layout
    c.delta.enabled = delta;
    c
}

#[test]
fn delta_stage_is_lossless_at_training_lr() {
    // real training: quantized codes move every round, so the writer falls
    // back to verbatim records wherever XOR+bitpack finds no slack — the
    // committed model and every recorded loss must still be bit-identical
    // to the verbatim control
    let (v_exp, v_rec) = run(delta_cfg("dl_verbatim", false, 0.2));
    let (d_exp, d_rec) = run(delta_cfg("dl_delta", true, 0.2));
    assert_eq!(
        param_bits(&v_exp),
        param_bits(&d_exp),
        "delta framing leaked into training"
    );
    for (v, d) in v_rec.records.iter().zip(&d_rec.records) {
        assert_eq!(v.train_loss.to_bits(), d.train_loss.to_bits());
        assert_eq!(v.eval_wer.to_bits(), d.eval_wer.to_bits());
        assert_eq!(v.eval_loss.to_bits(), d.eval_loss.to_bits());
        assert_eq!(v.completed, d.completed);
    }
    // the control never frames deltas, so its counter stays pinned at zero
    assert_eq!(v_rec.total_up_bytes_delta_saved(), 0);
}

#[test]
fn delta_converged_regime_saves_uplink_bytes() {
    // a step size far below the S1E4M14 quantization dead zone: packed
    // uplinks are bitwise static round-over-round, every delta block hits
    // the zero-width path, and the uplink spend collapses — the regime the
    // paper's cross-round residual compression targets, and the one the CI
    // delta-determinism grep gate keys off
    let (v_exp, v_rec) = run(delta_cfg("cv_verbatim", false, 1e-12));
    let (d_exp, d_rec) = run(delta_cfg("cv_delta", true, 1e-12));
    assert_eq!(param_bits(&v_exp), param_bits(&d_exp));
    let saved = d_rec.total_up_bytes_delta_saved();
    assert!(saved > 0, "converged-regime delta found no slack");
    let vu: usize = v_rec.records.iter().map(|r| r.up_bytes).sum();
    let du: usize = d_rec.records.iter().map(|r| r.up_bytes).sum();
    assert!(du < vu / 2, "uplink did not collapse: {du} vs {vu} bytes");
    // `saved` is the reduction vs framing the same uploads verbatim; the
    // only extra spend a v3 frame carries is its 8-byte base-version
    // header field, once per upload (4 clients x 3 rounds)
    assert!(du + saved >= vu, "saved counter under-reports: {du}+{saved} < {vu}");
    assert!(
        du + saved <= vu + 12 * 16,
        "saved counter over-reports: {du}+{saved} vs {vu}"
    );
    // per-round records carry the counter (the CSV column the sweep
    // summaries and the CI gate aggregate)
    assert!(d_rec.records.iter().all(|r| r.up_bytes_delta_saved > 0));
}

#[test]
fn delta_async_ring_base_is_lossless_and_schedule_independent() {
    let mk = |name: &str, delta: bool, workers: usize| {
        let mut c = delta_cfg(name, delta, 0.2);
        c.async_cfg = AsyncConfig {
            enabled: true,
            buffer_k: 2,
            snapshot_ring: 2,
            ..AsyncConfig::default()
        };
        c.workers = workers;
        run(c)
    };
    // losslessness through the snapshot-ring base path: stale dispatches
    // delta against older ring versions (or fall back to verbatim once
    // their base is evicted) and the commits still match bit-for-bit
    let (v_exp, _) = mk("adl_verbatim", false, 1);
    let (d_exp, d_rec) = mk("adl_delta", true, 1);
    assert_eq!(
        param_bits(&v_exp),
        param_bits(&d_exp),
        "ring-based delta framing leaked into the committed model"
    );
    // schedule independence with delta framing on: the ack ledger and the
    // per-round savings accounting are worker-count invariant
    let (p_exp, p_rec) = mk("adl_delta_pooled", true, 4);
    assert_eq!(param_bits(&d_exp), param_bits(&p_exp));
    assert_eq!(d_rec.to_csv(), p_rec.to_csv());
    assert_eq!(d_rec.commits_csv(), p_rec.commits_csv());
}

fn delta_chaos_cfg(workers: usize) -> ExperimentConfig {
    let mut c = chaos_stress_cfg(workers);
    c.delta.enabled = true;
    c
}

#[test]
fn delta_chaos_run_stays_deterministic_and_conserves_accounting() {
    // chaos corrupts/truncates/replays v3 delta frames; every reject must
    // leave the ack base where it was (a frame decoded against a wrong
    // base would break the bit-identity across worker counts below)
    let (ref_exp, ref_rec) = run(delta_chaos_cfg(1));
    assert!(ref_rec.total_frames_rejected() > 0, "chaos never bit a v3 frame");
    assert!(ref_rec.total_up_bytes_rejected() > 0);
    for r in &ref_rec.records {
        assert!(r.up_bytes >= r.up_bytes_discarded + r.up_bytes_rejected);
    }
    let ref_bits = param_bits(&ref_exp);
    for workers in [4usize, 32] {
        let (exp, rec) = run(delta_chaos_cfg(workers));
        assert_eq!(
            ref_bits,
            param_bits(&exp),
            "delta+chaos run diverged at workers={workers}"
        );
        assert_eq!(rec.to_csv(), ref_rec.to_csv());
        assert_eq!(rec.commits_csv(), ref_rec.commits_csv());
    }
}
