//! Runtime integration: the tiny model's full artifact set through PJRT.
//!
//! Covers the L3↔L2 contract: init determinism, training-step semantics
//! (loss decreases, OMC outputs are exactly representable, masks respected),
//! eval outputs, and shape validation errors.

mod common;

use omc_fl::data::synth::{Domain, TaskConfig};
use omc_fl::omc::format::FloatFormat;
use omc_fl::omc::quantize::is_representable;
use omc_fl::runtime::engine::{Engine, LoadedModel};
use omc_fl::util::rng::Xoshiro256pp;

fn load_tiny(engine: &Engine) -> LoadedModel {
    engine
        .load_model(&common::artifacts_dir().join("tiny"))
        .unwrap()
}

fn task_for(model: &LoadedModel, seed: u64) -> (Domain, Xoshiro256pp) {
    let mc = &model.manifest.config;
    let task = TaskConfig::from_model(mc.vocab, mc.feature_dim, mc.seq_len, seed);
    (Domain::new(&task, 0), Xoshiro256pp::new(seed))
}

#[test]
fn full_runtime_contract() {
    if common::artifacts_missing("tiny") {
        return;
    }
    let engine = Engine::cpu().unwrap();
    let model = load_tiny(&engine);
    let n = model.num_vars();
    let mc = model.manifest.config.clone();

    // ---- init: deterministic in the seed, correct shapes ----------------
    let p1 = model.run_init(7).unwrap();
    let p2 = model.run_init(7).unwrap();
    let p3 = model.run_init(8).unwrap();
    assert_eq!(p1.len(), n);
    for (i, spec) in model.manifest.variables.iter().enumerate() {
        assert_eq!(p1[i].len(), spec.size, "{}", spec.name);
        assert_eq!(
            p1[i].iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            p2[i].iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }
    assert!(
        p1.iter()
            .zip(&p3)
            .any(|(a, b)| a.iter().zip(b).any(|(x, y)| x != y)),
        "different seeds must differ"
    );

    // ---- fp32 training reduces loss -------------------------------------
    let (domain, mut rng) = task_for(&model, 11);
    let speakers: Vec<usize> = (0..8).collect();
    let mut params = p1.clone();
    let mut first = None;
    let mut last = 0.0;
    for _ in 0..30 {
        let b = domain.batch(&speakers, mc.batch, &mut rng);
        let out = model.run_train_fp32(&params, &b.x, &b.y, 0.1).unwrap();
        params = out.params;
        last = out.loss;
        first.get_or_insert(out.loss);
    }
    let first = first.unwrap();
    assert!(
        last < first * 0.8,
        "fp32 loss did not decrease: {first} -> {last}"
    );

    // ---- OMC step: representability + mask semantics --------------------
    let fmt: FloatFormat = "S1E3M7".parse().unwrap();
    let mask: Vec<f32> = model
        .manifest
        .variables
        .iter()
        .map(|v| {
            if v.kind == omc_fl::model::manifest::VarKind::Weight {
                1.0
            } else {
                0.0
            }
        })
        .collect();
    let s = vec![1.0f32; n];
    let bb = vec![0.0f32; n];
    let b = domain.batch(&speakers, mc.batch, &mut rng);
    let out = model
        .run_train_omc(
            true, &params, &s, &bb, &mask, &b.x, &b.y, 0.05, fmt.exp_bits,
            fmt.mant_bits,
        )
        .unwrap();
    assert!(out.loss.is_finite());
    for i in 0..n {
        if mask[i] > 0.5 {
            for (j, &x) in out.tildes[i].iter().enumerate() {
                assert!(
                    is_representable(x, fmt),
                    "var {i} ({}) elem {j} = {x:e} not representable",
                    model.manifest.variables[i].name
                );
            }
        } else {
            assert_eq!(out.s[i], 1.0, "unselected var {i} must keep s=1");
            assert_eq!(out.b[i], 0.0, "unselected var {i} must keep b=0");
        }
    }

    // ---- OMC with zero mask == fp32 step (tight tolerance) --------------
    let zero_mask = vec![0.0f32; n];
    let omc_out = model
        .run_train_omc(
            true, &params, &s, &bb, &zero_mask, &b.x, &b.y, 0.1, 3, 7,
        )
        .unwrap();
    let fp_out = model.run_train_fp32(&params, &b.x, &b.y, 0.1).unwrap();
    assert!((omc_out.loss - fp_out.loss).abs() < 1e-5);
    for i in 0..n {
        for (a, c) in omc_out.tildes[i].iter().zip(&fp_out.params[i]) {
            assert!(
                (a - c).abs() <= 1e-5 * c.abs().max(1e-3),
                "var {i}: {a} vs {c}"
            );
        }
    }

    // ---- eval outputs ----------------------------------------------------
    let ev = model.run_eval(&params, &b.x, &b.y).unwrap();
    assert!(ev.loss.is_finite());
    assert_eq!(ev.pred.len(), mc.batch * mc.seq_len);
    assert!(ev
        .pred
        .iter()
        .all(|&t| t >= 0 && (t as usize) < mc.vocab));

    // ---- shape validation errors -----------------------------------------
    let mut bad = params.clone();
    bad[0].pop();
    assert!(model.run_train_fp32(&bad, &b.x, &b.y, 0.1).is_err());
    assert!(model
        .run_train_fp32(&params, &b.x[..b.x.len() - 1], &b.y, 0.1)
        .is_err());
    assert!(model
        .run_train_omc(true, &params, &s[..n - 1], &bb, &mask, &b.x, &b.y, 0.1, 3, 7)
        .is_err());
}

#[test]
fn nopvt_artifact_keeps_identity_transform() {
    if common::artifacts_missing("tiny") {
        return;
    }
    let engine = Engine::cpu().unwrap();
    let model = load_tiny(&engine);
    let n = model.num_vars();
    let mc = model.manifest.config.clone();
    let params = model.run_init(1).unwrap();
    let (domain, mut rng) = task_for(&model, 2);
    let b = domain.batch(&[0, 1], mc.batch, &mut rng);
    let mask = vec![1.0f32; n];
    let s = vec![1.0f32; n];
    let bb = vec![0.0f32; n];
    let out = model
        .run_train_omc(false, &params, &s, &bb, &mask, &b.x, &b.y, 0.05, 3, 7)
        .unwrap();
    assert!(out.s.iter().all(|&x| x == 1.0));
    assert!(out.b.iter().all(|&x| x == 0.0));
}
