//! Property/fuzz suite for the uplink sparsification wire stage (tag-3
//! records on the checksummed v2/v3 layouts), driven end to end through
//! the `testkit` corruption driver:
//!
//! * **Bit-exact round-trip** across every paper format (S1E4M14,
//!   S1E3M7, S1E2M3), both selection rules, random shapes, and index
//!   counts straddling the 64-gap block geometry — the decoded dense
//!   update equals the same values quantized through the dense packed
//!   pipeline, scattered over zeros.
//! * **Savings accounting** — `sparse_saved()` is defined as the exact
//!   byte reduction vs the verbatim tag-1 record the sparse record
//!   replaced: frame lengths obey `sparse + saved == dense`.
//! * **Index-stream totality** — gap-coded streams round-trip exactly;
//!   every truncation, trailing byte, impossible width class, and
//!   out-of-range reconstruction is a typed [`SparseIndexError`], never
//!   a panic or a silent wrong decode.
//! * **Error-feedback conservation** — selection partitions the dense
//!   update bitwise: scattering the selected values over the residual
//!   reconstructs the update exactly, for top-k and rand-k alike.
//! * **Corruption totality** — every 1-byte truncation and every
//!   single-bit flip of a frame carrying a sparse record decodes to a
//!   typed [`DecodeError`]; replayed frames still trip the
//!   [`NonceLedger`]. (Tag 3 on the unchecksummed v1 layout is refused
//!   as `UnknownTag` — pinned by the codec unit tests.)

use omc_fl::omc::codec::{
    self, frame_nonce, DecodeError, NonceLedger, WireWriter,
};
use omc_fl::omc::format::FloatFormat;
use omc_fl::omc::sparse::{
    decode_indices_into, encode_indices_into, gather_into, select_count,
    select_randk, select_topk, SparseIndexError,
};
use omc_fl::omc::store::StoredVar;
use omc_fl::testkit::{check, corrupt_byte, flip_bit, truncate_at, Gen};

/// Value counts straddling the index-stream block geometry: 64-gap
/// blocks, plus small and ragged shapes around them.
const TAIL_LENS: [usize; 12] =
    [0, 1, 2, 63, 64, 65, 255, 256, 257, 511, 512, 513];

/// Bit patterns of a decoded plaintext, for exact comparison.
fn bits(vals: &[Vec<f32>]) -> Vec<Vec<u32>> {
    vals.iter()
        .map(|v| v.iter().map(|x| x.to_bits()).collect())
        .collect()
}

/// Decode every variable of a frame to its dense values (sparse views
/// decode to the dense update: zeros plus the decompressed gathered
/// values at their coordinates), or stringify the typed refusal.
fn decode_dense(wire: &[u8]) -> Result<Vec<Vec<f32>>, DecodeError> {
    let mut out = Vec::new();
    codec::for_each_var(wire, |_, view| {
        let mut v = Vec::new();
        view.decompress_into(&mut v);
        out.push(v);
        Ok(())
    })?;
    Ok(out)
}

/// Select `k` coordinates of `e` with a coin-flipped rule, returning the
/// ascending index set.
fn select_either(g: &mut Gen, e: &[f32], k: usize, idx: &mut Vec<u32>) {
    if g.usize_below(2) == 0 {
        select_topk(e, k, idx);
    } else {
        let mut scratch = Vec::new();
        select_randk(e.len(), k, g.u64(), idx, &mut scratch);
    }
}

#[test]
fn sparse_roundtrip_is_bit_exact_across_all_paper_formats() {
    for fmt_s in ["S1E4M14", "S1E3M7", "S1E2M3"] {
        let fmt: FloatFormat = fmt_s.parse().unwrap();
        check(&format!("sparse_roundtrip_{fmt_s}"), 40, |g| {
            let n = if g.usize_below(2) == 0 {
                TAIL_LENS[g.usize_below(TAIL_LENS.len())]
            } else {
                g.usize_below(700)
            };
            let e = g.vec_normal(n, 0.1);
            let fraction = [0.01f32, 0.1, 0.25, 1.0][g.usize_below(4)];
            let k = select_count(n, fraction);
            let mut idx = Vec::new();
            select_either(g, &e, k, &mut idx);
            let mut gathered = Vec::new();
            gather_into(&e, &idx, &mut gathered);
            let use_pvt = g.usize_below(2) == 0;

            let mut w = WireWriter::with_integrity(0, g.u64());
            w.sparse_values(&gathered, &idx, n, fmt, use_pvt);
            let wire = w.finish();

            // oracle: the gathered values quantized through the dense
            // packed pipeline, scattered over zeros
            let quantized =
                StoredVar::compress(&gathered, fmt, use_pvt).decompress();
            let mut expect = vec![0.0f32; n];
            for (j, &i) in idx.iter().enumerate() {
                expect[i as usize] = quantized[j];
            }
            let got = decode_dense(&wire).map_err(|e| format!("{e:?}"))?;
            if bits(&got) != bits(&[expect]) {
                return Err(format!(
                    "{fmt_s}: sparse round-trip not bit-exact (n={n} k={k})"
                ));
            }
            Ok(())
        });
    }
}

#[test]
fn mixed_frames_carry_sparse_records_next_to_packed_and_raw() {
    check("sparse_mixed_frame", 40, |g| {
        let fmt: FloatFormat = "S1E3M7".parse().unwrap();
        let dense_vals = g.vec_normal(220, 0.05);
        let dense = StoredVar::compress(&dense_vals, fmt, true);
        let raw = g.vec_normal(16, 1.0);
        let n = 300;
        let e = g.vec_normal(n, 0.1);
        let k = select_count(n, 0.1);
        let mut idx = Vec::new();
        select_either(g, &e, k, &mut idx);
        let mut gathered = Vec::new();
        gather_into(&e, &idx, &mut gathered);

        let mut w = WireWriter::with_integrity(0, g.u64());
        w.var(&dense);
        w.raw(&raw);
        w.sparse_values(&gathered, &idx, n, fmt, true);
        w.raw(&[]);
        let wire = w.finish();

        let got = decode_dense(&wire).map_err(|e| format!("{e:?}"))?;
        if got.len() != 4 {
            return Err(format!("expected 4 vars, got {}", got.len()));
        }
        if bits(&got[..2]) != bits(&[dense.decompress(), raw.clone()]) {
            return Err("dense/raw vars disturbed by sparse record".into());
        }
        let quantized = StoredVar::compress(&gathered, fmt, true).decompress();
        let mut expect = vec![0.0f32; n];
        for (j, &i) in idx.iter().enumerate() {
            expect[i as usize] = quantized[j];
        }
        if bits(&got[2..3]) != bits(&[expect]) {
            return Err("sparse var in mixed frame not bit-exact".into());
        }
        if !got[3].is_empty() {
            return Err("empty raw var no longer empty".into());
        }
        Ok(())
    });
}

#[test]
fn sparse_saved_accounts_exactly_for_the_verbatim_reduction() {
    check("sparse_saved_accounting", 40, |g| {
        let fmt: FloatFormat = "S1E4M14".parse().unwrap();
        let n = 256 + g.usize_below(700);
        let e = g.vec_normal(n, 0.1);
        let k = select_count(n, 0.05);
        let mut idx = Vec::new();
        select_either(g, &e, k, &mut idx);
        let mut gathered = Vec::new();
        gather_into(&e, &idx, &mut gathered);

        let mut w = WireWriter::with_integrity(0, 7);
        w.sparse_values(&gathered, &idx, n, fmt, true);
        let saved = w.sparse_saved();
        let sparse_wire = w.finish();

        // the verbatim twin: a tag-1 record of the same (n, fmt) — its
        // length depends only on the shape, not the values
        let mut w = WireWriter::with_integrity(0, 7);
        w.var(&StoredVar::compress(&e, fmt, true));
        let dense_wire = w.finish();

        if sparse_wire.len() >= dense_wire.len() {
            return Err(format!(
                "5% selection did not shrink the frame: {} vs {}",
                sparse_wire.len(),
                dense_wire.len()
            ));
        }
        if sparse_wire.len() + saved != dense_wire.len() {
            return Err(format!(
                "savings identity broken: sparse {} + saved {saved} != dense {}",
                sparse_wire.len(),
                dense_wire.len()
            ));
        }
        Ok(())
    });
}

#[test]
fn index_stream_roundtrips_at_every_block_boundary() {
    // deterministic sweep over the gap-block geometry: consecutive runs
    // (zero-width blocks), uniform draws, and single wide gaps
    let mut g = Gen::new(0x1D_EC5);
    for &k in &TAIL_LENS {
        let n = (4 * k).max(k + 1);
        // consecutive run 0..k — every block is width class 0
        let run: Vec<u32> = (0..k as u32).collect();
        // uniform distinct draw
        let mut uni = Vec::new();
        let mut scratch = Vec::new();
        select_randk(n, k, g.u64(), &mut uni, &mut scratch);
        for idx in [&run, &uni] {
            let mut stream = Vec::new();
            let islen = encode_indices_into(idx, &mut stream);
            assert_eq!(islen, stream.len());
            let mut back = Vec::new();
            decode_indices_into(&stream, idx.len(), n, &mut back).unwrap();
            assert_eq!(&back, idx, "k={k} round-trip");
        }
    }
    // one maximal gap: the full 32-bit width class
    let idx = vec![0u32, u32::MAX - 1];
    let mut stream = Vec::new();
    encode_indices_into(&idx, &mut stream);
    let mut back = Vec::new();
    decode_indices_into(&stream, 2, u32::MAX as usize, &mut back).unwrap();
    assert_eq!(back, idx);
}

#[test]
fn every_malformed_index_stream_is_a_typed_error() {
    check("sparse_index_malformed", 40, |g| {
        let n = 64 + g.usize_below(1000);
        let k = 1 + g.usize_below(n.min(200));
        let mut idx = Vec::new();
        let mut scratch = Vec::new();
        select_randk(n, k, g.u64(), &mut idx, &mut scratch);
        let mut stream = Vec::new();
        encode_indices_into(&idx, &mut stream);
        let mut out = Vec::new();
        // every strict prefix is short of its declared gaps
        for len in 0..stream.len() {
            match decode_indices_into(&stream[..len], k, n, &mut out) {
                Err(_) => {}
                Ok(()) => {
                    return Err(format!("prefix {len}/{} decoded", stream.len()))
                }
            }
        }
        // a trailing byte is refused even though the gaps decode
        let mut long = stream.clone();
        long.push(0);
        match decode_indices_into(&long, k, n, &mut out) {
            Err(SparseIndexError::TrailingBytes) => {}
            other => return Err(format!("trailing byte gave {other:?}")),
        }
        // an impossible width class is refused up front
        let mut bad = stream.clone();
        bad[0] = 33;
        match decode_indices_into(&bad, k, n, &mut out) {
            Err(SparseIndexError::BadWidth(33)) => {}
            // widening the first block can also starve later ones
            Err(SparseIndexError::Truncated) => {}
            other => return Err(format!("width 33 gave {other:?}")),
        }
        // shrinking n below the top index reconstructs out of range
        let top = *idx.last().unwrap() as usize;
        match decode_indices_into(&stream, k, top, &mut out) {
            Err(SparseIndexError::IndexOverflow) => Ok(()),
            other => Err(format!("n={top} gave {other:?}")),
        }
    });
}

#[test]
fn error_feedback_partitions_the_dense_update_bitwise() {
    check("sparse_ef_partition", 60, |g| {
        let n = 1 + g.usize_below(900);
        let e = if g.usize_below(3) == 0 {
            g.vec_edge_heavy(n)
        } else {
            g.vec_normal(n, 0.1)
        };
        let fraction = [0.01f32, 0.25, 0.9][g.usize_below(3)];
        let k = select_count(n, fraction);
        let mut idx = Vec::new();
        select_either(g, &e, k, &mut idx);
        if idx.len() != k {
            return Err(format!("selected {} of k={k}", idx.len()));
        }
        // indices strictly ascend and stay in range — the precondition
        // the gap coding and the scatter both rely on
        for w in idx.windows(2) {
            if w[0] >= w[1] {
                return Err(format!("indices not ascending: {w:?}"));
            }
        }
        if idx.last().is_some_and(|&i| i as usize >= n) {
            return Err("selected index out of range".into());
        }
        // the client's split: ship the selected values, bank the rest
        let mut gathered = Vec::new();
        gather_into(&e, &idx, &mut gathered);
        let mut residual = e.clone();
        for &i in &idx {
            residual[i as usize] = 0.0;
        }
        // conservation: scattering the shipment over the residual must
        // reconstruct the dense update bit for bit — nothing is lost
        // between the wire and the error-feedback bank
        let mut recon = residual.clone();
        for (j, &i) in idx.iter().enumerate() {
            recon[i as usize] = gathered[j];
        }
        if bits(&[recon]) != bits(&[e.clone()]) {
            return Err("selected + residual != dense update".into());
        }
        Ok(())
    });
}

#[test]
fn topk_is_a_deterministic_magnitude_total_order() {
    check("sparse_topk_order", 60, |g| {
        let n = 2 + g.usize_below(700);
        let e = g.vec_normal(n, 0.1);
        let k = 1 + g.usize_below(n - 1);
        let mut idx = Vec::new();
        select_topk(&e, k, &mut idx);
        let selected: std::collections::HashSet<u32> =
            idx.iter().copied().collect();
        let floor = idx
            .iter()
            .map(|&i| e[i as usize].abs())
            .fold(f32::INFINITY, f32::min);
        for (i, &x) in e.iter().enumerate() {
            if !selected.contains(&(i as u32)) && x.abs() > floor {
                return Err(format!(
                    "unselected |e[{i}]|={} beats selected floor {floor}",
                    x.abs()
                ));
            }
        }
        // bit-exact rerun: selection is a pure function of (e, k)
        let mut again = Vec::new();
        select_topk(&e, k, &mut again);
        if again != idx {
            return Err("top-k selection not deterministic".into());
        }
        Ok(())
    });
}

// ---- corruption totality (fuzz layer over the corruption driver) ----------

/// A small-but-complete v2 frame holding a sparse record among packed,
/// raw, and empty neighbours.
fn small_sparse_frame(g: &mut Gen) -> (Vec<Vec<f32>>, Vec<u8>) {
    let fmt: FloatFormat = "S1E3M7".parse().unwrap();
    let dense = StoredVar::compress(&g.vec_normal(120, 0.05), fmt, true);
    let raw = g.vec_normal(16, 1.0);
    let n = 300;
    let e = g.vec_normal(n, 0.1);
    let k = select_count(n, 0.08);
    let mut idx = Vec::new();
    select_topk(&e, k, &mut idx);
    let mut gathered = Vec::new();
    gather_into(&e, &idx, &mut gathered);

    let mut w = WireWriter::with_integrity(0, 0xFEED_F00D);
    w.var(&dense);
    w.raw(&raw);
    w.sparse_values(&gathered, &idx, n, fmt, true);
    let wire = w.finish();

    let quantized = StoredVar::compress(&gathered, fmt, true).decompress();
    let mut update = vec![0.0f32; n];
    for (j, &i) in idx.iter().enumerate() {
        update[i as usize] = quantized[j];
    }
    (vec![dense.decompress(), raw, update], wire)
}

#[test]
fn every_truncation_of_a_sparse_frame_is_a_typed_error() {
    let mut g = Gen::new(0x5A_7A11);
    let (expect, wire) = small_sparse_frame(&mut g);
    assert_eq!(
        bits(&decode_dense(&wire).unwrap()),
        bits(&expect),
        "the uncorrupted frame must decode"
    );
    for len in 0..wire.len() {
        let cut = truncate_at(&wire, len);
        match decode_dense(cut) {
            Err(_) => {}
            Ok(_) => panic!("truncation to {len}/{} decoded", wire.len()),
        }
    }
}

#[test]
fn every_single_bit_flip_of_a_sparse_frame_is_a_typed_error() {
    // CRC32C coverage is total: the record CRC spans the tag, counts,
    // index stream, and value payload alike, so no single-bit flip may
    // decode — a corrupted index stream must never silently scatter
    // values to the wrong coordinates
    let mut g = Gen::new(0x5A_F11B);
    let (_expect, wire) = small_sparse_frame(&mut g);
    for bit in 0..wire.len() * 8 {
        let mut bad = wire.clone();
        flip_bit(&mut bad, bit);
        match decode_dense(&bad) {
            Err(_) => {}
            Ok(_) => panic!("bit flip {bit} decoded silently"),
        }
    }
}

#[test]
fn random_byte_corruption_is_always_refused() {
    check("sparse_byte_corruption", 120, |g| {
        let (_expect, wire) = small_sparse_frame(g);
        let mut bad = wire.clone();
        let at = g.usize_below(bad.len());
        let xor = 1 + (g.u64() & 0xFE) as u8; // nonzero
        corrupt_byte(&mut bad, at, xor);
        match decode_dense(&bad) {
            Err(_) => Ok(()),
            Ok(_) => Err(format!("byte {at} ^ {xor:#x} decoded silently")),
        }
    });
}

#[test]
fn replayed_sparse_frames_trip_the_nonce_ledger() {
    let mut g = Gen::new(0x5A_DAD);
    let (_expect, wire) = small_sparse_frame(&mut g);
    let nonce = frame_nonce(&wire).unwrap();
    assert_eq!(nonce, Some(0xFEED_F00D), "v2 frames carry their nonce");
    let mut ledger = NonceLedger::new(8);
    ledger.observe(nonce).unwrap();
    match ledger.observe(nonce) {
        Err(DecodeError::DuplicateNonce(n)) => assert_eq!(n, 0xFEED_F00D),
        other => panic!("replay must be DuplicateNonce, got {other:?}"),
    }
}
