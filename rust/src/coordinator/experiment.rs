//! The experiment driver: a full federated run with periodic WER
//! evaluation, byte accounting, and table-style reporting.

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::coordinator::config::ExperimentConfig;
use crate::coordinator::params_io;
use crate::data::partition::ClientAssignment;
use crate::data::synth::{collapse_words, Domain, TaskConfig};
use crate::fl::async_round::{AsyncContext, AsyncRoundEngine};
use crate::fl::chaos::Quarantine;
use crate::fl::client::ClientTrainConfig;
use crate::fl::round::{RoundContext, RoundEngine};
use crate::fl::sampler::Sampler;
use crate::fl::serve::{ServeEngine, ServeReport};
use crate::fl::server::Server;
use crate::metrics::recorder::{CsvStream, Recorder, RoundRecord};
use crate::metrics::stats::Timer;
use crate::metrics::wer::WerAccumulator;
use crate::omc::selection::SelectionPolicy;
use crate::runtime::engine::{Engine, LoadedModel};
use crate::util::rng::{hash_seed, Xoshiro256pp};

/// A prepared experiment: runtime + data + config, ready to run.
pub struct Experiment {
    pub cfg: ExperimentConfig,
    pub model: Arc<LoadedModel>,
    pub domain: Domain,
    pub assignment: ClientAssignment,
    pub sampler: Sampler,
    pub server: Server,
    /// round executor owning the codec buffers reused across rounds
    /// (zero-alloc steady state); [`Experiment::run_with`] lets a caller
    /// substitute its own handle so buffers survive across experiments
    rounds: RoundEngine,
}

/// Final summary, one per experiment run (a row of a paper table).
#[derive(Clone, Debug)]
pub struct RunSummary {
    pub label: String,
    pub final_wer: f64,
    pub final_loss: f64,
    /// parameter memory of a client's compressed store, bytes
    pub param_memory_bytes: usize,
    /// memory relative to FP32
    pub memory_ratio: f64,
    /// mean per-round communication (down + up), bytes
    pub comm_bytes_per_round: f64,
    pub rounds_per_min: f64,
    pub rounds: usize,
}

impl Experiment {
    /// Build everything from a config (loads + compiles artifacts).
    pub fn prepare(engine: &Engine, cfg: ExperimentConfig) -> Result<Self> {
        cfg.validate()?;
        let model = Arc::new(engine.load_model(&cfg.model_dir)?);
        Self::prepare_with_model(cfg, model)
    }

    /// Build an experiment over an already-bound model. Use this to share
    /// one compilation cache across several experiment variants in the same
    /// process (every table example runs 2–5 variants of the same model).
    pub fn prepare_with_model(
        cfg: ExperimentConfig,
        model: Arc<LoadedModel>,
    ) -> Result<Self> {
        cfg.validate()?;
        let mc = &model.manifest.config;
        let mut task = TaskConfig::from_model(
            mc.vocab,
            mc.feature_dim,
            mc.seq_len,
            hash_seed(&[cfg.seed, 0xDA7A]),
        );
        task.noise = cfg.noise;
        let domain = Domain::new(&task, cfg.domain);
        // population mode swaps both client-space structures for their
        // O(active)-memory twins: a lazy assignment over the registered
        // fleet (shards derived on demand, bit-identical to the dense
        // builder) and an availability-aware rejection sampler
        let (assignment, sampler) = if cfg.population.enabled {
            (
                ClientAssignment::lazy(
                    cfg.partition,
                    cfg.population.registered,
                    task.num_speakers,
                    cfg.seed,
                ),
                Sampler::for_population(
                    cfg.population,
                    cfg.clients_per_round,
                    cfg.seed,
                )?,
            )
        } else {
            (
                ClientAssignment::build(
                    cfg.partition,
                    cfg.num_clients,
                    task.num_speakers,
                    cfg.seed,
                ),
                Sampler::try_new(
                    cfg.sampler,
                    cfg.num_clients,
                    cfg.clients_per_round,
                    cfg.seed,
                )?,
            )
        };
        let params = match &cfg.init_from {
            Some(path) => {
                let p = params_io::load(path)
                    .with_context(|| format!("loading init checkpoint {path:?}"))?;
                anyhow::ensure!(
                    p.len() == model.num_vars(),
                    "checkpoint has {} vars, model needs {}",
                    p.len(),
                    model.num_vars()
                );
                p
            }
            None => model.run_init(cfg.seed as i32)?,
        };
        let server = Server::new(params);
        Ok(Self {
            cfg,
            model,
            domain,
            assignment,
            sampler,
            server,
            rounds: RoundEngine::new(),
        })
    }

    fn train_config(&self) -> ClientTrainConfig {
        let omc = &self.cfg.omc;
        ClientTrainConfig {
            lr: self.cfg.lr,
            local_steps: self.cfg.local_steps,
            format: omc.format,
            use_pvt: omc.use_pvt,
            fp32_baseline: omc.is_baseline(),
            // the engines stamp the per-client nonce when integrity is on,
            // and the delta base version when the delta stage frames a
            // particular uplink
            uplink_nonce: None,
            delta_base: None,
        }
    }

    fn policy(&self) -> SelectionPolicy {
        if self.cfg.omc.is_baseline() {
            SelectionPolicy::fp32()
        } else {
            SelectionPolicy {
                weights_only: self.cfg.omc.weights_only,
                fraction: self.cfg.omc.fraction,
            }
        }
    }

    /// Evaluate the current server model: corpus WER + mean eval loss over
    /// `eval_batches` held-out batches (a dedicated RNG stream disjoint
    /// from training).
    pub fn evaluate(&self) -> Result<(f64, f64)> {
        let mc = &self.model.manifest.config;
        let mut rng = Xoshiro256pp::new(hash_seed(&[
            self.cfg.seed, 0xE7A1, self.server.round as u64,
        ]));
        let all_speakers: Vec<usize> =
            (0..self.domainless_speakers()).collect();
        let mut acc = WerAccumulator::new();
        let mut loss_sum = 0.0;
        for _ in 0..self.cfg.eval_batches {
            let batch = self.domain.batch(&all_speakers, mc.batch, &mut rng);
            let out = self
                .model
                .run_eval(&self.server.params, &batch.x, &batch.y)?;
            loss_sum += out.loss as f64;
            let refs = batch.reference_words();
            for b in 0..batch.batch {
                let hyp = collapse_words(
                    &out.pred[b * batch.seq_len..(b + 1) * batch.seq_len],
                    batch.word_len,
                );
                acc.add(&hyp, &refs[b]);
            }
        }
        Ok((acc.wer(), loss_sum / self.cfg.eval_batches.max(1) as f64))
    }

    fn domainless_speakers(&self) -> usize {
        // evaluation uses the whole speaker population (test-set analog)
        64
    }

    /// Parameter-store bytes for one client under the current policy —
    /// the Tables' "Parameter Memory / Communication" column. Uses the
    /// *expected* PPQ mask (fraction of eligible variables).
    pub fn client_param_bytes(&self) -> usize {
        let policy = self.policy();
        let fmt = self.cfg.omc.format;
        let specs = &self.model.manifest.variables;
        let mut total = 0usize;
        for spec in specs {
            let quantized_frac = if policy.eligible(spec) && !fmt.is_fp32() {
                policy.fraction
            } else {
                0.0
            };
            let q_bytes = fmt.packed_bytes(spec.size) + 8;
            let raw_bytes = spec.size * 4;
            total += (quantized_frac * q_bytes as f64
                + (1.0 - quantized_frac) * raw_bytes as f64)
                .round() as usize;
        }
        total
    }

    /// Force-compile the executables this experiment will use, so compile
    /// time never pollutes per-round timings (the Tables' Speed column).
    pub fn warmup(&self) -> Result<()> {
        let t = Timer::start();
        self.model.warmup(self.cfg.omc.is_baseline(), self.cfg.omc.use_pvt)?;
        crate::log_info!("warmup (XLA compile) took {:.1}s", t.elapsed_s());
        Ok(())
    }

    /// Run exactly one federated round with no evaluation or recording —
    /// the unit the round-latency bench times.
    pub fn run_one_round_for_bench(&mut self) -> Result<(f64, usize)> {
        let policy = self.policy();
        let train = self.train_config();
        let ctx = RoundContext {
            model: &self.model,
            domain: &self.domain,
            assignment: &self.assignment,
            sampler: &self.sampler,
            policy,
            train,
            cohort: self.cfg.cohort,
            chaos: self.cfg.chaos,
            integrity: self.cfg.omc.integrity,
            delta: self.cfg.delta.enabled,
            sparse: self.cfg.sparse.params(),
            population: self.cfg.population,
            quarantined: &[],
            seed: self.cfg.seed,
            workers: self.cfg.workers,
        };
        let outcome = self.rounds.run(&ctx, &mut self.server)?;
        Ok((outcome.mean_loss, outcome.down_bytes + outcome.up_bytes))
    }

    /// Run the full experiment; returns the recorder with per-round logs.
    pub fn run(&mut self) -> Result<(Recorder, RunSummary)> {
        let mut rounds = std::mem::take(&mut self.rounds);
        let out = self.run_with(&mut rounds);
        self.rounds = rounds;
        out
    }

    /// Like [`run`](Self::run), but executing through a caller-owned
    /// [`RoundEngine`] — the sweep engine passes one handle per worker so
    /// warmed codec buffers carry across cells. With `[async] enabled`,
    /// the experiment's rounds run as buffered asynchronous *commits*
    /// through `fl::async_round` instead of synchronous rounds (the
    /// engine's pooled downlink buffers and client scratches are shared
    /// either way).
    pub fn run_with(&mut self, rounds: &mut RoundEngine) -> Result<(Recorder, RunSummary)> {
        self.warmup()?;
        let mut rec = Recorder::new(&self.cfg.name);
        let policy = self.policy();
        let train = self.train_config();
        crate::log_info!(
            "experiment '{}': {} rounds, {}/{} clients/round, format {}, pvt={}, weights_only={}, fraction={}",
            self.cfg.name,
            self.cfg.rounds,
            self.cfg.clients_per_round,
            self.cfg.num_clients,
            self.cfg.omc.format,
            self.cfg.omc.use_pvt,
            self.cfg.omc.weights_only,
            self.cfg.omc.fraction
        );
        if !self.cfg.cohort.is_ideal() {
            crate::log_info!(
                "cohort failure model: dropout={}, straggler_mean={}s, deadline={}s, weight_by_examples={}",
                self.cfg.cohort.dropout_prob,
                self.cfg.cohort.straggler_mean_s,
                self.cfg.cohort.deadline_s,
                self.cfg.cohort.weight_by_examples
            );
        }
        if !self.cfg.chaos.is_off() {
            crate::log_info!(
                "chaos engine: bitflip={}, truncate={}, duplicate={}, crash={}, commit_failure={}, retries={}, quarantine {}x{} rounds",
                self.cfg.chaos.bitflip_prob,
                self.cfg.chaos.truncate_prob,
                self.cfg.chaos.duplicate_prob,
                self.cfg.chaos.crash_prob,
                self.cfg.chaos.commit_failure_prob,
                self.cfg.chaos.max_retries,
                self.cfg.chaos.quarantine_threshold,
                self.cfg.chaos.quarantine_rounds
            );
        }
        if self.cfg.delta.enabled {
            crate::log_info!(
                "delta wire stage: uplinks XOR against the round's downlink \
                 and bitpack per 64-word block (lossless, v3 frames)"
            );
        }
        if self.cfg.sparse.enabled {
            crate::log_info!(
                "sparse uplink stage: {} selection, fraction={}, \
                 error feedback on (residuals fold into the next round)",
                self.cfg.sparse.mode,
                self.cfg.sparse.fraction
            );
        }
        if self.cfg.population.enabled {
            crate::log_info!(
                "population mode: registered={}, edges={}, churn={}@{}r, wave={}@{}r",
                self.cfg.population.registered,
                self.cfg.population.edges,
                self.cfg.population.churn_rate,
                self.cfg.population.churn_period,
                self.cfg.population.wave_amplitude,
                self.cfg.population.wave_period
            );
        }
        if self.cfg.async_cfg.enabled {
            self.run_async_rounds(rounds, &mut rec, policy, train)?;
        } else {
            self.run_sync_rounds(rounds, &mut rec, policy, train)?;
        }
        if let Some(path) = &self.cfg.save_to {
            params_io::save(path, &self.server.params)?;
            crate::log_info!("saved checkpoint to {}", path.display());
        }
        let param_bytes = self.client_param_bytes();
        let fp32_bytes = self.model.manifest.total_params * 4;
        let summary = RunSummary {
            label: self.cfg.name.clone(),
            final_wer: rec.final_wer(3),
            final_loss: rec.last().map(|r| r.train_loss).unwrap_or(f64::NAN),
            param_memory_bytes: param_bytes,
            memory_ratio: param_bytes as f64 / fp32_bytes as f64,
            comm_bytes_per_round: rec.total_comm_bytes() as f64
                / rec.records.len().max(1) as f64,
            rounds_per_min: rec.rounds_per_min(),
            rounds: rec.records.len(),
        };
        Ok((rec, summary))
    }

    /// The synchronous round loop (the paper's setting).
    fn run_sync_rounds(
        &mut self,
        rounds: &mut RoundEngine,
        rec: &mut Recorder,
        policy: SelectionPolicy,
        train: ClientTrainConfig,
    ) -> Result<()> {
        let mut quarantine = Quarantine::new();
        for r in 0..self.cfg.rounds {
            let t = Timer::start();
            // the ladder's verdicts from earlier rounds gate this round's
            // sampled cohort; async runs keep their timeline instead
            // (planned up front) — see docs/ROBUSTNESS.md
            let quarantined = quarantine.quarantined_at(r as u64);
            let ctx = RoundContext {
                model: &self.model,
                domain: &self.domain,
                assignment: &self.assignment,
                sampler: &self.sampler,
                policy,
                train,
                cohort: self.cfg.cohort,
                chaos: self.cfg.chaos,
                integrity: self.cfg.omc.integrity,
                delta: self.cfg.delta.enabled,
                sparse: self.cfg.sparse.params(),
                population: self.cfg.population,
                quarantined: &quarantined,
                seed: self.cfg.seed,
                workers: self.cfg.workers,
            };
            let outcome = rounds.run(&ctx, &mut self.server)?;
            for rep in &outcome.chaos_reports {
                if quarantine.record(
                    &self.cfg.chaos,
                    rep.cid,
                    rep.corrupt_frames,
                    rep.delivered_clean,
                    r as u64,
                ) {
                    crate::log_info!(
                        "round {:>4}: client {} quarantined for {} rounds",
                        r,
                        rep.cid,
                        self.cfg.chaos.quarantine_rounds
                    );
                }
            }
            let round_seconds = t.elapsed_s();
            let (wer, eval_loss) = self.maybe_evaluate(r)?;
            if wer >= 0.0 {
                crate::log_info!(
                    "round {:>4}: loss {:.4} | WER {:.2}% | {:.0} ms",
                    r,
                    outcome.mean_loss,
                    wer,
                    round_seconds * 1e3
                );
            } else {
                crate::log_debug!(
                    "round {:>4}: loss {:.4} | {:.0} ms",
                    r,
                    outcome.mean_loss,
                    round_seconds * 1e3
                );
            }
            rec.push(RoundRecord {
                round: r,
                train_loss: outcome.mean_loss,
                eval_loss,
                eval_wer: wer,
                down_bytes: outcome.down_bytes,
                up_bytes: outcome.up_bytes,
                up_bytes_discarded: outcome.up_bytes_discarded,
                sampled: outcome.sampled,
                completed: outcome.completed,
                dropped: outcome.dropped,
                late: outcome.late,
                crashed: outcome.crashed,
                frames_rejected: outcome.frames_rejected,
                up_bytes_rejected: outcome.up_bytes_rejected,
                up_bytes_delta_saved: outcome.up_bytes_delta_saved,
                up_bytes_sparse_saved: outcome.up_bytes_sparse_saved,
                sparse_selected: outcome.sparse_selected,
                sparse_total: outcome.sparse_total,
                sparse_residual_sq: outcome.sparse_residual_sq,
                round_seconds,
            });
            if let Some(p) = outcome.population {
                rec.push_population(p);
            }
        }
        Ok(())
    }

    /// The buffered asynchronous commit loop: `cfg.rounds` commits through
    /// `fl::async_round`, one [`RoundRecord`] + `CommitRecord` per commit.
    /// Column mapping for the shared round log: `sampled` counts the wave's
    /// dispatches, `completed` the folded updates (buffer K), and `late`
    /// the stale-discarded updates of the commit *window*. Note the
    /// attribution asymmetry: `up_bytes_discarded` is attributed to the
    /// row whose wave *trained* the update (keeping it a subset of that
    /// row's `up_bytes`, the field's documented invariant), while `late`
    /// and `CommitRecord::discarded_bytes` are attributed to the window
    /// the discard happened in — per-row the two can disagree; run totals
    /// always match.
    fn run_async_rounds(
        &mut self,
        rounds: &mut RoundEngine,
        rec: &mut Recorder,
        policy: SelectionPolicy,
        train: ClientTrainConfig,
    ) -> Result<()> {
        let acfg = self.cfg.async_cfg.resolved(self.cfg.clients_per_round);
        crate::log_info!(
            "async engine: concurrency={}, buffer K={}, policy={}, max_staleness={}, ring={}",
            acfg.concurrency,
            acfg.buffer_k,
            acfg.policy,
            if acfg.max_staleness == usize::MAX {
                "unlimited".to_string()
            } else {
                acfg.max_staleness.to_string()
            },
            acfg.snapshot_ring
        );
        let ctx = AsyncContext {
            model: &self.model,
            domain: &self.domain,
            assignment: &self.assignment,
            sampler: &self.sampler,
            policy,
            train,
            cohort: self.cfg.cohort,
            chaos: self.cfg.chaos,
            integrity: self.cfg.omc.integrity,
            delta: self.cfg.delta.enabled,
            sparse: self.cfg.sparse.params(),
            acfg,
            population: self.cfg.population,
            seed: self.cfg.seed,
            workers: self.cfg.workers,
        };
        let mut engine = AsyncRoundEngine::plan(&ctx, self.cfg.rounds)?;
        // async timelines are planned up front, so the ladder cannot gate
        // dispatch — it still tracks strikes for monitoring parity with
        // the sync engine (docs/ROBUSTNESS.md)
        let mut quarantine = Quarantine::new();
        for r in 0..self.cfg.rounds {
            let t = Timer::start();
            let outcome =
                engine.run_commit(&ctx, &mut self.server, rounds.scratch_mut())?;
            for rep in &outcome.chaos_reports {
                if quarantine.record(
                    &self.cfg.chaos,
                    rep.cid,
                    rep.corrupt_frames,
                    rep.delivered_clean,
                    r as u64,
                ) {
                    crate::log_info!(
                        "commit {:>4}: client {} crossed the quarantine \
                         threshold ({} strikes)",
                        r,
                        rep.cid,
                        self.cfg.chaos.quarantine_threshold
                    );
                }
            }
            let round_seconds = t.elapsed_s();
            let (wer, eval_loss) = self.maybe_evaluate(r)?;
            if wer >= 0.0 {
                crate::log_info!(
                    "commit {:>4}: loss {:.4} | WER {:.2}% | vt {:.1}s | {:.0} ms",
                    r,
                    outcome.mean_loss,
                    wer,
                    outcome.commit.virtual_time,
                    round_seconds * 1e3
                );
            } else {
                crate::log_debug!(
                    "commit {:>4}: loss {:.4} | vt {:.1}s | {:.0} ms",
                    r,
                    outcome.mean_loss,
                    outcome.commit.virtual_time,
                    round_seconds * 1e3
                );
            }
            rec.push(RoundRecord {
                round: r,
                train_loss: outcome.mean_loss,
                eval_loss,
                eval_wer: wer,
                down_bytes: outcome.down_bytes,
                up_bytes: outcome.up_bytes,
                up_bytes_discarded: outcome.up_bytes_discarded,
                sampled: outcome.dispatched,
                completed: outcome.folded,
                dropped: outcome.dropped,
                late: outcome.commit.discarded_updates,
                crashed: outcome.crashed,
                frames_rejected: outcome.frames_rejected,
                up_bytes_rejected: outcome.up_bytes_rejected,
                up_bytes_delta_saved: outcome.up_bytes_delta_saved,
                up_bytes_sparse_saved: outcome.up_bytes_sparse_saved,
                sparse_selected: outcome.sparse_selected,
                sparse_total: outcome.sparse_total,
                sparse_residual_sq: outcome.sparse_residual_sq,
                round_seconds,
            });
            rec.push_commit(outcome.commit);
        }
        Ok(())
    }

    /// Drive the async plan through the wall-clock serving engine
    /// (`fl::serve`, `omc-fl serve`): real worker threads, lock-free
    /// snapshot publication, arena-pooled frames, bounded uplink queue.
    /// Per-commit rows stream to `<output_dir>/<name>_serve_commits.csv`
    /// through a held writer flushed on each commit boundary, so a killed
    /// run keeps every completed commit on disk. No WER evaluation — the
    /// serving engine measures throughput; training results are
    /// bit-identical to [`run_with`](Self::run_with) in async mode.
    pub fn run_serve(&mut self) -> Result<(Recorder, ServeReport)> {
        self.warmup()?;
        let mut rec = Recorder::new(&self.cfg.name);
        // inline field borrows (not a helper taking &self) so the context
        // stays disjoint from the `&mut self.server` the engine needs
        let ctx = AsyncContext {
            model: &self.model,
            domain: &self.domain,
            assignment: &self.assignment,
            sampler: &self.sampler,
            policy: self.policy(),
            train: self.train_config(),
            cohort: self.cfg.cohort,
            chaos: self.cfg.chaos,
            integrity: self.cfg.omc.integrity,
            delta: self.cfg.delta.enabled,
            sparse: self.cfg.sparse.params(),
            acfg: self.cfg.async_cfg.resolved(self.cfg.clients_per_round),
            population: self.cfg.population,
            seed: self.cfg.seed,
            workers: self.cfg.workers,
        };
        let mut engine =
            ServeEngine::new(&ctx, self.cfg.rounds, &self.cfg.serve)?;
        let scfg = *engine.config();
        crate::log_info!(
            "serving engine: workers={}, queue_depth={}, arena={}, rate={}, {} commits",
            scfg.workers,
            scfg.queue_depth,
            scfg.arena,
            if scfg.rate > 0.0 {
                format!("{}/s", scfg.rate)
            } else {
                "unpaced".to_string()
            },
            self.cfg.rounds
        );
        let stream_path = self
            .cfg
            .output_dir
            .join(format!("{}_serve_commits.csv", self.cfg.name));
        let mut stream = CsvStream::create(
            &stream_path,
            "commit,folded,discarded,virtual_time,loss",
        )?;
        let report = engine.run(&ctx, &mut self.server, |v, outcome| {
            stream.append(&format!(
                "{},{},{},{:.6},{:.6}",
                v,
                outcome.folded,
                outcome.commit.discarded_updates,
                outcome.commit.virtual_time,
                outcome.mean_loss
            ))?;
            stream.flush()?;
            rec.push(RoundRecord {
                round: v,
                train_loss: outcome.mean_loss,
                eval_loss: 0.0,
                eval_wer: -1.0,
                down_bytes: outcome.down_bytes,
                up_bytes: outcome.up_bytes,
                up_bytes_discarded: outcome.up_bytes_discarded,
                sampled: outcome.dispatched,
                completed: outcome.folded,
                dropped: outcome.dropped,
                late: outcome.commit.discarded_updates,
                crashed: outcome.crashed,
                frames_rejected: outcome.frames_rejected,
                up_bytes_rejected: outcome.up_bytes_rejected,
                up_bytes_delta_saved: outcome.up_bytes_delta_saved,
                up_bytes_sparse_saved: outcome.up_bytes_sparse_saved,
                sparse_selected: outcome.sparse_selected,
                sparse_total: outcome.sparse_total,
                sparse_residual_sq: outcome.sparse_residual_sq,
                round_seconds: 0.0,
            });
            rec.push_commit(outcome.commit.clone());
            Ok(())
        })?;
        crate::log_info!(
            "serve: {} commits in {:.2}s ({:.1} commits/sec, {:.0} bytes/sec), \
             p50 {:.1}ms p99 {:.1}ms, queue peak {} rejected {}",
            report.commits,
            report.wall_s,
            report.commits_per_sec(),
            report.bytes_per_sec(),
            report.uplink_p50_s * 1e3,
            report.uplink_p99_s * 1e3,
            report.queue_peak_depth,
            report.rejected_total()
        );
        if let Some(path) = &self.cfg.save_to {
            params_io::save(path, &self.server.params)?;
            crate::log_info!("saved checkpoint to {}", path.display());
        }
        Ok((rec, report))
    }

    /// The planned-timeline reference for the serving engine's bit-identity
    /// contract: run the async commits inline with no evaluation and no
    /// recording, leaving only the committed parameters in `self.server`.
    pub fn run_async_params_only(&mut self) -> Result<()> {
        self.warmup()?;
        let ctx = AsyncContext {
            model: &self.model,
            domain: &self.domain,
            assignment: &self.assignment,
            sampler: &self.sampler,
            policy: self.policy(),
            train: self.train_config(),
            cohort: self.cfg.cohort,
            chaos: self.cfg.chaos,
            integrity: self.cfg.omc.integrity,
            delta: self.cfg.delta.enabled,
            sparse: self.cfg.sparse.params(),
            acfg: self.cfg.async_cfg.resolved(self.cfg.clients_per_round),
            population: self.cfg.population,
            seed: self.cfg.seed,
            workers: self.cfg.workers,
        };
        let mut engine = AsyncRoundEngine::plan(&ctx, self.cfg.rounds)?;
        let mut rounds = std::mem::take(&mut self.rounds);
        let mut out = Ok(());
        for _ in 0..self.cfg.rounds {
            if let Err(e) =
                engine.run_commit(&ctx, &mut self.server, rounds.scratch_mut())
            {
                out = Err(e);
                break;
            }
        }
        self.rounds = rounds;
        out?;
        if let Some(path) = &self.cfg.save_to {
            params_io::save(path, &self.server.params)?;
            crate::log_info!("saved checkpoint to {}", path.display());
        }
        Ok(())
    }

    /// Evaluate on the cadence the sync and async loops share: every
    /// `eval_every` rounds and always on the final round. Returns
    /// `(-1.0, 0.0)` on skipped rounds.
    fn maybe_evaluate(&self, r: usize) -> Result<(f64, f64)> {
        if (r + 1) % self.cfg.eval_every == 0 || r + 1 == self.cfg.rounds {
            self.evaluate()
        } else {
            Ok((-1.0, 0.0))
        }
    }
}

/// Print table rows in the paper's layout (used by the examples).
pub fn print_table(title: &str, rows: &[RunSummary]) {
    println!("\n## {title}\n");
    println!(
        "| {:<28} | {:>8} | {:>22} | {:>18} |",
        "", "WER", "Param Memory / Comm", "Speed (Rounds/Min)"
    );
    println!(
        "|{}|{}|{}|{}|",
        "-".repeat(30),
        "-".repeat(10),
        "-".repeat(24),
        "-".repeat(20)
    );
    let base_speed = rows
        .first()
        .map(|r| r.rounds_per_min)
        .unwrap_or(1.0)
        .max(1e-12);
    for r in rows {
        println!(
            "| {:<28} | {:>7.2}% | {:>9} ({:>4.0}%)       | {:>8.1} ({:>4.0}%)   |",
            r.label,
            r.final_wer,
            human_bytes(r.param_memory_bytes),
            100.0 * r.memory_ratio,
            r.rounds_per_min,
            100.0 * r.rounds_per_min / base_speed,
        );
    }
    println!();
}

pub fn human_bytes(b: usize) -> String {
    if b >= 1 << 20 {
        format!("{:.1}MB", b as f64 / (1 << 20) as f64)
    } else if b >= 1 << 10 {
        format!("{:.1}KB", b as f64 / (1 << 10) as f64)
    } else {
        format!("{b}B")
    }
}
