//! Typed experiment configuration, read from TOML files (`configs/`) with
//! programmatic builders for the example drivers.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::data::partition::Partition;
use crate::fl::async_round::{AsyncConfig, StalenessPolicy};
use crate::fl::chaos::ChaosConfig;
use crate::fl::cohort::CohortConfig;
use crate::fl::population::PopulationConfig;
use crate::fl::sampler::SamplerKind;
use crate::fl::serve::ServeConfig;
use crate::omc::format::FloatFormat;
use crate::omc::sparse::{SparseMode, SparseParams};
use crate::util::toml::{self, Table};

/// OMC-specific knobs (paper Sec. 2).
#[derive(Clone, Copy, Debug)]
pub struct OmcConfig {
    /// storage/transport format; `S1E8M23` means the FP32 baseline
    pub format: FloatFormat,
    /// per-variable transformation (Sec. 2.3)
    pub use_pvt: bool,
    /// weight-matrices-only rule (Sec. 2.4)
    pub weights_only: bool,
    /// PPQ fraction (Sec. 2.5); 1.0 = all eligible params every client
    pub fraction: f64,
    /// frame all transport in the checksummed v2 wire layout (CRC32C per
    /// variable + header CRC + round/client nonce); required by `[chaos]`
    pub integrity: bool,
}

impl OmcConfig {
    pub fn fp32_baseline() -> Self {
        Self {
            format: FloatFormat::FP32,
            use_pvt: false,
            weights_only: true,
            fraction: 0.0,
            integrity: false,
        }
    }

    pub fn paper(format: FloatFormat) -> Self {
        Self {
            format,
            use_pvt: true,
            weights_only: true,
            fraction: 0.9,
            integrity: false,
        }
    }

    pub fn is_baseline(&self) -> bool {
        self.format.is_fp32() || self.fraction == 0.0
    }
}

/// Cross-round delta stage (`[delta]` table): XOR uplink payloads against
/// the last model the client downloaded, then bitpack per 64-word block.
/// Lossless — decoded bytes are identical to the verbatim v2 path — so it
/// changes wire size only, never training results. Requires
/// `omc.integrity` (delta frames ride the checksummed v3 layout).
#[derive(Clone, Copy, Debug, Default)]
pub struct DeltaConfig {
    /// master switch for the delta wire stage
    pub enabled: bool,
}

/// Uplink sparsification stage (`[sparse]` table): magnitude top-k or
/// random-k selection over each client's masked update, with per-client
/// error-feedback residuals folded into the next round's update before
/// selection. Lossy but conservative — selected + residual reproduce the
/// dense update exactly. Requires `omc.integrity` (sparse records ride
/// the checksummed v2/v3 wire layouts).
#[derive(Clone, Copy, Debug)]
pub struct SparseConfig {
    /// master switch for the sparse uplink stage
    pub enabled: bool,
    /// selection rule: magnitude `topk` or keyed-uniform `randk`
    pub mode: SparseMode,
    /// fraction of coordinates kept per variable, in (0, 1]
    pub fraction: f64,
}

impl Default for SparseConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            mode: SparseMode::TopK,
            fraction: 0.25,
        }
    }
}

impl SparseConfig {
    /// Engine knobs when the stage is on; `None` keeps the dense uplink.
    pub fn params(&self) -> Option<SparseParams> {
        self.enabled.then(|| SparseParams {
            mode: self.mode,
            fraction: self.fraction as f32,
        })
    }
}

/// A full experiment description.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub name: String,
    /// `artifacts/<size>` directory with manifest + HLO files
    pub model_dir: PathBuf,
    pub rounds: usize,
    pub num_clients: usize,
    pub clients_per_round: usize,
    pub local_steps: usize,
    pub lr: f32,
    pub seed: u64,
    pub partition: Partition,
    pub sampler: SamplerKind,
    /// synthetic-data domain id (domain adaptation uses two ids)
    pub domain: u64,
    pub noise: f32,
    pub eval_every: usize,
    pub eval_batches: usize,
    pub omc: OmcConfig,
    /// cohort failure model: dropout, stragglers, weighted FedAvg
    pub cohort: CohortConfig,
    /// buffered asynchronous aggregation (`[async]` table); when enabled,
    /// `rounds` counts commits and `clients_per_round` seeds the default
    /// concurrency/buffer size
    pub async_cfg: AsyncConfig,
    /// fault-injection model (`[chaos]` table); requires `omc.integrity`
    pub chaos: ChaosConfig,
    /// lossless cross-round delta + bitpack wire stage (`[delta]` table);
    /// requires `omc.integrity`
    pub delta: DeltaConfig,
    /// lossy uplink sparsification + error feedback (`[sparse]` table);
    /// requires `omc.integrity`, incompatible with `[serve]`
    pub sparse: SparseConfig,
    /// population-scale simulation (`[population]` table): a registered
    /// fleet of 10^6–10^7 clients with lazy per-client state, churn and
    /// diurnal availability, a device-class ladder, and a two-tier
    /// edge→root aggregation topology (`fl::population`, docs/SCALE.md).
    /// When enabled, `registered` replaces `fl.clients` as the fleet size
    pub population: PopulationConfig,
    /// wall-clock serving engine (`[serve]` table): drive the async phase
    /// through real worker threads with lock-free snapshot publication,
    /// arena-pooled frames, and a bounded uplink queue (`fl::serve`,
    /// docs/SERVING.md). Requires `async.enabled`
    pub serve: ServeConfig,
    pub output_dir: PathBuf,
    /// optional checkpoint to start from (domain adaptation)
    pub init_from: Option<PathBuf>,
    /// optional checkpoint to write at the end
    pub save_to: Option<PathBuf>,
    pub workers: usize,
}

impl ExperimentConfig {
    /// Sensible defaults for the small model; drivers override fields.
    pub fn default_with(name: &str, model_dir: &Path) -> Self {
        Self {
            name: name.to_string(),
            model_dir: model_dir.to_path_buf(),
            rounds: 60,
            num_clients: 32,
            clients_per_round: 8,
            local_steps: 1,
            lr: 0.1,
            seed: 42,
            partition: Partition::Iid,
            sampler: SamplerKind::Uniform,
            domain: 0,
            noise: 0.3,
            eval_every: 5,
            eval_batches: 8,
            omc: OmcConfig::fp32_baseline(),
            cohort: CohortConfig::default(),
            async_cfg: AsyncConfig::default(),
            chaos: ChaosConfig::default(),
            delta: DeltaConfig::default(),
            sparse: SparseConfig::default(),
            population: PopulationConfig::off(),
            serve: ServeConfig::default(),
            output_dir: PathBuf::from("results"),
            init_from: None,
            save_to: None,
            workers: crate::util::threadpool::default_workers(),
        }
    }

    /// Load from a TOML file (see `configs/*.toml`).
    pub fn from_toml_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let t = toml::parse(&text)
            .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        Self::from_table(&t)
    }

    pub fn from_table(t: &Table) -> Result<Self> {
        let get_str = |k: &str| -> Option<&str> { t.get(k).and_then(|v| v.as_str()) };
        let get_i = |k: &str| -> Option<i64> { t.get(k).and_then(|v| v.as_i64()) };
        let get_f = |k: &str| -> Option<f64> { t.get(k).and_then(|v| v.as_f64()) };
        let get_b = |k: &str| -> Option<bool> { t.get(k).and_then(|v| v.as_bool()) };

        let name = get_str("name")
            .ok_or_else(|| anyhow::anyhow!("config needs a name"))?
            .to_string();
        let model_dir = PathBuf::from(
            get_str("model_dir").unwrap_or("artifacts/small"),
        );
        let mut cfg = Self::default_with(&name, &model_dir);
        if let Some(v) = get_i("rounds") {
            cfg.rounds = v as usize;
        }
        if let Some(v) = get_i("fl.clients") {
            cfg.num_clients = v as usize;
        }
        if let Some(v) = get_i("fl.clients_per_round") {
            cfg.clients_per_round = v as usize;
        }
        if let Some(v) = get_i("fl.local_steps") {
            cfg.local_steps = v as usize;
        }
        if let Some(v) = get_f("fl.lr") {
            cfg.lr = v as f32;
        }
        if let Some(v) = get_i("seed") {
            cfg.seed = v as u64;
        }
        if let Some(v) = get_str("fl.partition") {
            cfg.partition = Partition::parse(v)?;
        }
        if let Some(v) = get_str("fl.sampler") {
            cfg.sampler = SamplerKind::parse(v)?;
        }
        if let Some(v) = get_i("data.domain") {
            cfg.domain = v as u64;
        }
        if let Some(v) = get_f("data.noise") {
            cfg.noise = v as f32;
        }
        if let Some(v) = get_i("eval.every") {
            cfg.eval_every = v as usize;
        }
        if let Some(v) = get_i("eval.batches") {
            cfg.eval_batches = v as usize;
        }
        if let Some(v) = get_str("omc.format") {
            cfg.omc.format = v.parse()?;
        }
        if let Some(v) = get_b("omc.pvt") {
            cfg.omc.use_pvt = v;
        }
        if let Some(v) = get_b("omc.weights_only") {
            cfg.omc.weights_only = v;
        }
        if let Some(v) = get_f("omc.fraction") {
            cfg.omc.fraction = v;
        }
        if let Some(v) = get_b("omc.integrity") {
            cfg.omc.integrity = v;
        }
        if let Some(v) = get_f("cohort.dropout") {
            cfg.cohort.dropout_prob = v;
        }
        if let Some(v) = get_f("cohort.straggler_mean_s") {
            cfg.cohort.straggler_mean_s = v;
        }
        if let Some(v) = get_f("cohort.deadline_s") {
            cfg.cohort.deadline_s = v;
        }
        if let Some(v) = get_b("cohort.weight_by_examples") {
            cfg.cohort.weight_by_examples = v;
        }
        if let Some(v) = get_b("async.enabled") {
            cfg.async_cfg.enabled = v;
        }
        if let Some(v) = get_i("async.concurrency") {
            anyhow::ensure!(v >= 0, "async.concurrency must be >= 0");
            cfg.async_cfg.concurrency = v as usize;
        }
        if let Some(v) = get_i("async.buffer_k") {
            anyhow::ensure!(v >= 0, "async.buffer_k must be >= 0");
            cfg.async_cfg.buffer_k = v as usize;
        }
        let (discount, alpha) = (get_f("async.discount"), get_f("async.alpha"));
        match get_str("async.policy") {
            Some(p) => {
                cfg.async_cfg.policy = StalenessPolicy::parse(p, discount, alpha)?;
            }
            // a dangling discount/alpha would otherwise be silently ignored
            // (default Constant(1.0)) — reject the misconfiguration instead
            None => anyhow::ensure!(
                discount.is_none() && alpha.is_none(),
                "async.discount/async.alpha need async.policy (constant | polynomial)"
            ),
        }
        if let Some(v) = get_i("async.max_staleness") {
            anyhow::ensure!(v >= 0, "async.max_staleness must be >= 0");
            cfg.async_cfg.max_staleness = v as usize;
        }
        if let Some(v) = get_i("async.snapshot_ring") {
            anyhow::ensure!(v >= 1, "async.snapshot_ring must be >= 1");
            cfg.async_cfg.snapshot_ring = v as usize;
        }
        let chaos_enabled = get_b("chaos.enabled");
        if let Some(v) = chaos_enabled {
            cfg.chaos.enabled = v;
        }
        let mut chaos_knobs = false;
        for (key, field) in [
            ("chaos.bitflip", &mut cfg.chaos.bitflip_prob as &mut f64),
            ("chaos.truncate", &mut cfg.chaos.truncate_prob),
            ("chaos.duplicate", &mut cfg.chaos.duplicate_prob),
            ("chaos.crash", &mut cfg.chaos.crash_prob),
            ("chaos.commit_failure", &mut cfg.chaos.commit_failure_prob),
            ("chaos.backoff_base_s", &mut cfg.chaos.backoff_base_s),
        ] {
            if let Some(v) = get_f(key) {
                *field = v;
                chaos_knobs = true;
            }
        }
        if let Some(v) = get_i("chaos.max_retries") {
            anyhow::ensure!(v >= 0, "chaos.max_retries must be >= 0");
            cfg.chaos.max_retries = v as u32;
            chaos_knobs = true;
        }
        if let Some(v) = get_i("chaos.quarantine_threshold") {
            anyhow::ensure!(v >= 1, "chaos.quarantine_threshold must be >= 1");
            cfg.chaos.quarantine_threshold = v as u32;
            chaos_knobs = true;
        }
        if let Some(v) = get_i("chaos.quarantine_rounds") {
            anyhow::ensure!(v >= 1, "chaos.quarantine_rounds must be >= 1");
            cfg.chaos.quarantine_rounds = v as u64;
            chaos_knobs = true;
        }
        // fault knobs without the master switch would silently no-op —
        // reject the misconfiguration instead (same rule as async.policy)
        anyhow::ensure!(
            !chaos_knobs || chaos_enabled.is_some(),
            "[chaos] knobs need an explicit chaos.enabled = true|false"
        );
        if let Some(v) = get_b("delta.enabled") {
            cfg.delta.enabled = v;
        }
        let sparse_enabled = get_b("sparse.enabled");
        if let Some(v) = sparse_enabled {
            cfg.sparse.enabled = v;
        }
        let mut sparse_knobs = false;
        if let Some(v) = get_str("sparse.mode") {
            cfg.sparse.mode = v
                .parse()
                .map_err(|e: String| anyhow::anyhow!("sparse.mode: {e}"))?;
            sparse_knobs = true;
        }
        if let Some(v) = get_f("sparse.fraction") {
            cfg.sparse.fraction = v;
            sparse_knobs = true;
        }
        // selection knobs without the master switch would silently no-op —
        // reject the misconfiguration (same rule as [chaos]/[population])
        anyhow::ensure!(
            !sparse_knobs || sparse_enabled.is_some(),
            "[sparse] knobs need an explicit sparse.enabled = true|false"
        );
        let pop_enabled = get_b("population.enabled");
        if let Some(v) = pop_enabled {
            cfg.population.enabled = v;
        }
        let mut pop_knobs = false;
        if let Some(v) = get_i("population.registered") {
            anyhow::ensure!(v >= 1, "population.registered must be >= 1");
            cfg.population.registered = v as usize;
            pop_knobs = true;
        }
        if let Some(v) = get_i("population.edges") {
            anyhow::ensure!(v >= 1, "population.edges must be >= 1");
            cfg.population.edges = v as usize;
            pop_knobs = true;
        }
        if let Some(v) = get_f("population.churn_rate") {
            cfg.population.churn_rate = v;
            pop_knobs = true;
        }
        if let Some(v) = get_i("population.churn_period") {
            anyhow::ensure!(v >= 1, "population.churn_period must be >= 1");
            cfg.population.churn_period = v as u64;
            pop_knobs = true;
        }
        if let Some(v) = get_f("population.wave_amplitude") {
            cfg.population.wave_amplitude = v;
            pop_knobs = true;
        }
        if let Some(v) = get_i("population.wave_period") {
            anyhow::ensure!(v >= 1, "population.wave_period must be >= 1");
            cfg.population.wave_period = v as u64;
            pop_knobs = true;
        }
        // scenario knobs without the master switch would silently no-op —
        // reject the misconfiguration (same rule as [chaos]/async.policy)
        anyhow::ensure!(
            !pop_knobs || pop_enabled.is_some(),
            "[population] knobs need an explicit population.enabled = true|false"
        );
        let serve_enabled = get_b("serve.enabled");
        if let Some(v) = serve_enabled {
            cfg.serve.enabled = v;
        }
        let mut serve_knobs = false;
        if let Some(v) = get_i("serve.workers") {
            anyhow::ensure!(v >= 0, "serve.workers must be >= 0 (0 = auto)");
            cfg.serve.workers = v as usize;
            serve_knobs = true;
        }
        if let Some(v) = get_i("serve.queue_depth") {
            anyhow::ensure!(
                v >= 0,
                "serve.queue_depth must be >= 0 (0 = 2x concurrency)"
            );
            cfg.serve.queue_depth = v as usize;
            serve_knobs = true;
        }
        if let Some(v) = get_b("serve.arena") {
            cfg.serve.arena = v;
            serve_knobs = true;
        }
        if let Some(v) = get_f("serve.rate") {
            cfg.serve.rate = v;
            serve_knobs = true;
        }
        if let Some(v) = get_b("serve.probe") {
            cfg.serve.probe = v;
            serve_knobs = true;
        }
        // serving knobs without the master switch would silently no-op —
        // reject the misconfiguration (same rule as [chaos]/[population])
        anyhow::ensure!(
            !serve_knobs || serve_enabled.is_some(),
            "[serve] knobs need an explicit serve.enabled = true|false"
        );
        if let Some(v) = get_str("output_dir") {
            cfg.output_dir = PathBuf::from(v);
        }
        if let Some(v) = get_str("init_from") {
            cfg.init_from = Some(PathBuf::from(v));
        }
        if let Some(v) = get_str("save_to") {
            cfg.save_to = Some(PathBuf::from(v));
        }
        if let Some(v) = get_i("workers") {
            cfg.workers = (v as usize).max(1);
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.rounds > 0, "rounds must be > 0");
        anyhow::ensure!(self.num_clients > 0, "clients must be > 0");
        anyhow::ensure!(
            self.clients_per_round > 0 && self.clients_per_round <= self.num_clients,
            "clients_per_round must be in 1..=clients"
        );
        anyhow::ensure!(self.local_steps > 0, "local_steps must be > 0");
        anyhow::ensure!(self.lr > 0.0, "lr must be positive");
        anyhow::ensure!(self.eval_every > 0, "eval_every must be > 0");
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.omc.fraction),
            "omc.fraction must be in [0, 1]"
        );
        // a quantized format with nothing selected silently trains the
        // FP32 path while reporting the quantized label — reject it on
        // every construction path (TOML, presets, sweep grid expansion)
        anyhow::ensure!(
            self.omc.format.is_fp32() || self.omc.fraction > 0.0,
            "omc.format is {} but omc.fraction is 0 — set fraction or use S1E8M23",
            self.omc.format
        );
        self.cohort.validate()?;
        self.async_cfg.validate()?;
        self.chaos.validate()?;
        self.population.validate()?;
        // in population mode the registered fleet replaces fl.clients as
        // the client space, so the cohort must fit inside it
        anyhow::ensure!(
            !self.population.enabled
                || self.clients_per_round <= self.population.registered,
            "clients_per_round ({}) exceeds population.registered ({})",
            self.clients_per_round,
            self.population.registered
        );
        // a corrupt frame on the unchecksummed v1 wire can be
        // indistinguishable from a valid one — chaos without integrity
        // would inject faults the server cannot reliably detect
        anyhow::ensure!(
            self.chaos.is_off() || self.omc.integrity,
            "chaos.enabled requires omc.integrity = true (corrupt frames \
             must be detectable to be rejected)"
        );
        // a delta frame decoded against the wrong base silently corrupts
        // the aggregate — the v3 layout's checksums + base-version
        // handshake are what make that impossible, so the stage only
        // exists on the integrity wire
        anyhow::ensure!(
            !self.delta.enabled || self.omc.integrity,
            "delta.enabled requires omc.integrity = true (delta frames \
             ride the checksummed v3 wire layout)"
        );
        // a sparse record decoded on the unchecksummed v1 wire has no CRC
        // to refuse a corrupt index stream — the stage only exists on the
        // integrity layouts (same rule as [delta]/[chaos])
        anyhow::ensure!(
            !self.sparse.enabled || self.omc.integrity,
            "sparse.enabled requires omc.integrity = true (sparse records \
             ride the checksummed v2/v3 wire layouts)"
        );
        anyhow::ensure!(
            !self.sparse.enabled
                || (self.sparse.fraction > 0.0 && self.sparse.fraction <= 1.0),
            "sparse.fraction must be in (0, 1], got {}",
            self.sparse.fraction
        );
        self.serve.validate()?;
        // the serving engine executes the *async* planned timeline through
        // real threads — without the async phase there is nothing to serve
        anyhow::ensure!(
            !self.serve.enabled || self.async_cfg.enabled,
            "serve.enabled requires async.enabled = true (the serving \
             engine drives the buffered async plan)"
        );
        // error feedback needs durable per-client residual state between
        // commits; the serving engine's workers keep none, so the pair
        // would silently drop residuals — reject it instead
        anyhow::ensure!(
            !(self.sparse.enabled && self.serve.enabled),
            "sparse.enabled is not supported with serve.enabled (the \
             serving engine keeps no per-client error-feedback state)"
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
        name = "table1_omc"
        model_dir = "artifacts/small"
        rounds = 120
        seed = 7

        [fl]
        clients = 64
        clients_per_round = 16
        local_steps = 1
        lr = 0.1
        partition = "iid"

        [omc]
        format = "S1E4M14"
        pvt = true
        weights_only = true
        fraction = 0.9

        [cohort]
        dropout = 0.1
        straggler_mean_s = 2.0
        deadline_s = 5.0
        weight_by_examples = true

        [eval]
        every = 10
        batches = 4
    "#;

    #[test]
    fn parses_full_config() {
        let t = toml::parse(SAMPLE).unwrap();
        let c = ExperimentConfig::from_table(&t).unwrap();
        assert_eq!(c.name, "table1_omc");
        assert_eq!(c.rounds, 120);
        assert_eq!(c.num_clients, 64);
        assert_eq!(c.clients_per_round, 16);
        assert_eq!(c.omc.format.to_string(), "S1E4M14");
        assert!(c.omc.use_pvt);
        assert_eq!(c.omc.fraction, 0.9);
        assert_eq!(c.eval_every, 10);
        assert!(!c.omc.is_baseline());
        assert_eq!(c.cohort.dropout_prob, 0.1);
        assert_eq!(c.cohort.straggler_mean_s, 2.0);
        assert_eq!(c.cohort.deadline_s, 5.0);
        assert!(c.cohort.weight_by_examples);
        assert!(!c.cohort.is_ideal());
    }

    #[test]
    fn cohort_defaults_to_ideal_and_rejects_bad_knobs() {
        let minimal = r#"name = "x""#;
        let t = toml::parse(minimal).unwrap();
        let c = ExperimentConfig::from_table(&t).unwrap();
        assert!(c.cohort.is_ideal());
        for (from, to) in [
            ("dropout = 0.1", "dropout = 1.5"),
            ("deadline_s = 5.0", "deadline_s = 0.0"),
            ("straggler_mean_s = 2.0", "straggler_mean_s = -1.0"),
        ] {
            let bad = SAMPLE.replace(from, to);
            let t = toml::parse(&bad).unwrap();
            assert!(ExperimentConfig::from_table(&t).is_err(), "{to}");
        }
    }

    #[test]
    fn rejects_inconsistent_omc() {
        let bad = SAMPLE.replace("fraction = 0.9", "fraction = 0.0");
        let t = toml::parse(&bad).unwrap();
        assert!(ExperimentConfig::from_table(&t).is_err());
    }

    #[test]
    fn rejects_bad_bounds() {
        for (from, to) in [
            ("rounds = 120", "rounds = 0"),
            ("clients_per_round = 16", "clients_per_round = 100"),
            ("lr = 0.1", "lr = -0.5"),
        ] {
            let bad = SAMPLE.replace(from, to);
            let t = toml::parse(&bad).unwrap();
            assert!(ExperimentConfig::from_table(&t).is_err(), "{to}");
        }
    }

    const ASYNC_SAMPLE: &str = r#"
        name = "async_cell"

        [fl]
        clients = 16
        clients_per_round = 8

        [async]
        enabled = true
        concurrency = 6
        buffer_k = 3
        policy = "polynomial"
        alpha = 0.5
        max_staleness = 4
        snapshot_ring = 3
    "#;

    #[test]
    fn parses_async_table_and_defaults() {
        let t = toml::parse(ASYNC_SAMPLE).unwrap();
        let c = ExperimentConfig::from_table(&t).unwrap();
        assert!(c.async_cfg.enabled);
        assert_eq!(c.async_cfg.concurrency, 6);
        assert_eq!(c.async_cfg.buffer_k, 3);
        assert_eq!(
            c.async_cfg.policy,
            StalenessPolicy::Polynomial { alpha: 0.5 }
        );
        assert_eq!(c.async_cfg.max_staleness, 4);
        assert_eq!(c.async_cfg.snapshot_ring, 3);
        // absent table → disabled sync defaults; 0-knobs resolve to cpr
        let plain = ExperimentConfig::from_table(&toml::parse("name = \"x\"").unwrap()).unwrap();
        assert!(!plain.async_cfg.enabled);
        let r = plain.async_cfg.resolved(plain.clients_per_round);
        assert_eq!(r.concurrency, plain.clients_per_round);
        assert_eq!(r.buffer_k, plain.clients_per_round);
    }

    #[test]
    fn rejects_bad_async_knobs() {
        for (from, to) in [
            ("snapshot_ring = 3", "snapshot_ring = 0"),
            ("policy = \"polynomial\"", "policy = \"chaos\""),
            ("alpha = 0.5", "alpha = -1.0"),
            ("max_staleness = 4", "max_staleness = -1"),
        ] {
            let bad = ASYNC_SAMPLE.replace(from, to);
            let t = toml::parse(&bad).unwrap();
            assert!(ExperimentConfig::from_table(&t).is_err(), "{to}");
        }
        // discount/alpha without a policy key would silently no-op — reject
        let dangling = ASYNC_SAMPLE.replace("policy = \"polynomial\"", "");
        assert!(
            ExperimentConfig::from_table(&toml::parse(&dangling).unwrap()).is_err(),
            "alpha without async.policy must be rejected, not ignored"
        );
        // constant policy with an explicit discount parses; 0 is rejected
        let constant = ASYNC_SAMPLE
            .replace("policy = \"polynomial\"", "policy = \"constant\"")
            .replace("alpha = 0.5", "discount = 0.5");
        let c = ExperimentConfig::from_table(&toml::parse(&constant).unwrap()).unwrap();
        assert_eq!(c.async_cfg.policy, StalenessPolicy::Constant(0.5));
        let zero = constant.replace("discount = 0.5", "discount = 0.0");
        assert!(ExperimentConfig::from_table(&toml::parse(&zero).unwrap()).is_err());
    }

    #[test]
    fn baseline_detection() {
        assert!(OmcConfig::fp32_baseline().is_baseline());
        assert!(!OmcConfig::paper("S1E3M7".parse().unwrap()).is_baseline());
    }

    const CHAOS_SAMPLE: &str = r#"
        name = "chaos_cell"

        [omc]
        integrity = true

        [chaos]
        enabled = true
        bitflip = 0.1
        truncate = 0.05
        duplicate = 0.1
        crash = 0.02
        commit_failure = 0.2
        max_retries = 2
        backoff_base_s = 0.5
        quarantine_threshold = 3
        quarantine_rounds = 2
    "#;

    #[test]
    fn parses_chaos_table_and_integrity() {
        let t = toml::parse(CHAOS_SAMPLE).unwrap();
        let c = ExperimentConfig::from_table(&t).unwrap();
        assert!(c.omc.integrity);
        assert!(c.chaos.enabled);
        assert_eq!(c.chaos.bitflip_prob, 0.1);
        assert_eq!(c.chaos.truncate_prob, 0.05);
        assert_eq!(c.chaos.duplicate_prob, 0.1);
        assert_eq!(c.chaos.crash_prob, 0.02);
        assert_eq!(c.chaos.commit_failure_prob, 0.2);
        assert_eq!(c.chaos.max_retries, 2);
        assert_eq!(c.chaos.backoff_base_s, 0.5);
        assert_eq!(c.chaos.quarantine_threshold, 3);
        assert_eq!(c.chaos.quarantine_rounds, 2);
        // defaults: everything off, integrity off
        let plain =
            ExperimentConfig::from_table(&toml::parse("name = \"x\"").unwrap())
                .unwrap();
        assert!(!plain.omc.integrity);
        assert!(plain.chaos.is_off());
    }

    #[test]
    fn chaos_requires_integrity() {
        let bad = CHAOS_SAMPLE.replace("integrity = true", "integrity = false");
        let t = toml::parse(&bad).unwrap();
        let err = ExperimentConfig::from_table(&t).unwrap_err();
        assert!(err.to_string().contains("omc.integrity"), "{err}");
        // integrity alone (no chaos) is fine
        let quiet = "name = \"x\"\n[omc]\nintegrity = true\n";
        let c = ExperimentConfig::from_table(&toml::parse(quiet).unwrap()).unwrap();
        assert!(c.omc.integrity && c.chaos.is_off());
    }

    #[test]
    fn parses_delta_table_and_requires_integrity() {
        let good = "name = \"x\"\n[omc]\nintegrity = true\n[delta]\nenabled = true\n";
        let c = ExperimentConfig::from_table(&toml::parse(good).unwrap()).unwrap();
        assert!(c.delta.enabled);
        // default: off
        let plain =
            ExperimentConfig::from_table(&toml::parse("name = \"x\"").unwrap())
                .unwrap();
        assert!(!plain.delta.enabled);
        // delta without the checksummed wire must be rejected, not
        // silently downgraded to verbatim
        let bad = "name = \"x\"\n[delta]\nenabled = true\n";
        let err =
            ExperimentConfig::from_table(&toml::parse(bad).unwrap()).unwrap_err();
        assert!(err.to_string().contains("omc.integrity"), "{err}");
        // explicit enabled = false parses without integrity
        let off = "name = \"x\"\n[delta]\nenabled = false\n";
        assert!(ExperimentConfig::from_table(&toml::parse(off).unwrap()).is_ok());
    }

    const SPARSE_SAMPLE: &str = r#"
        name = "sparse_cell"

        [omc]
        integrity = true

        [sparse]
        enabled = true
        mode = "randk"
        fraction = 0.1
    "#;

    #[test]
    fn parses_sparse_table_and_defaults() {
        let t = toml::parse(SPARSE_SAMPLE).unwrap();
        let c = ExperimentConfig::from_table(&t).unwrap();
        assert!(c.sparse.enabled);
        assert_eq!(c.sparse.mode, SparseMode::RandK);
        assert_eq!(c.sparse.fraction, 0.1);
        let p = c.sparse.params().unwrap();
        assert_eq!(p.mode, SparseMode::RandK);
        assert_eq!(p.fraction, 0.1f32);
        // absent table → disabled defaults, params() = None
        let plain =
            ExperimentConfig::from_table(&toml::parse("name = \"x\"").unwrap())
                .unwrap();
        assert!(!plain.sparse.enabled);
        assert_eq!(plain.sparse.mode, SparseMode::TopK);
        assert!(plain.sparse.params().is_none());
    }

    #[test]
    fn sparse_requires_integrity_and_rejects_bad_knobs() {
        // sparse without the checksummed wire must be rejected, not
        // silently downgraded to dense
        let bad = SPARSE_SAMPLE.replace("integrity = true", "integrity = false");
        let err =
            ExperimentConfig::from_table(&toml::parse(&bad).unwrap()).unwrap_err();
        assert!(err.to_string().contains("omc.integrity"), "{err}");
        for (from, to) in [
            ("fraction = 0.1", "fraction = 0.0"),
            ("fraction = 0.1", "fraction = 1.5"),
            ("mode = \"randk\"", "mode = \"magic\""),
        ] {
            let broken = SPARSE_SAMPLE.replace(from, to);
            let t = toml::parse(&broken).unwrap();
            assert!(ExperimentConfig::from_table(&t).is_err(), "{to}");
        }
        // selection knobs without the master switch must be rejected, not
        // silently ignored
        let dangling = SPARSE_SAMPLE.replace("enabled = true", "");
        let err =
            ExperimentConfig::from_table(&toml::parse(&dangling).unwrap())
                .unwrap_err();
        assert!(err.to_string().contains("sparse.enabled"), "{err}");
        // explicit enabled = false parses without integrity
        let off = "name = \"x\"\n[sparse]\nenabled = false\n";
        assert!(ExperimentConfig::from_table(&toml::parse(off).unwrap()).is_ok());
    }

    #[test]
    fn sparse_rejects_serve() {
        let combined = format!(
            "{SPARSE_SAMPLE}\n[async]\nenabled = true\n[serve]\nenabled = true\n"
        );
        let err = ExperimentConfig::from_table(&toml::parse(&combined).unwrap())
            .unwrap_err();
        assert!(err.to_string().contains("serve.enabled"), "{err}");
    }

    const POPULATION_SAMPLE: &str = r#"
        name = "scale_cell"

        [fl]
        clients = 32
        clients_per_round = 8

        [population]
        enabled = true
        registered = 1000000
        edges = 4
        churn_rate = 0.3
        churn_period = 2
        wave_amplitude = 0.5
        wave_period = 6
    "#;

    #[test]
    fn parses_population_table_and_defaults() {
        let t = toml::parse(POPULATION_SAMPLE).unwrap();
        let c = ExperimentConfig::from_table(&t).unwrap();
        assert!(c.population.enabled);
        assert_eq!(c.population.registered, 1_000_000);
        assert_eq!(c.population.edges, 4);
        assert_eq!(c.population.churn_rate, 0.3);
        assert_eq!(c.population.churn_period, 2);
        assert_eq!(c.population.wave_amplitude, 0.5);
        assert_eq!(c.population.wave_period, 6);
        // absent table → disabled defaults
        let plain =
            ExperimentConfig::from_table(&toml::parse("name = \"x\"").unwrap())
                .unwrap();
        assert!(!plain.population.enabled);
        assert_eq!(plain.population, PopulationConfig::off());
    }

    #[test]
    fn rejects_bad_population_knobs_and_dangling_table() {
        for (from, to) in [
            ("registered = 1000000", "registered = 0"),
            ("edges = 4", "edges = 0"),
            ("churn_rate = 0.3", "churn_rate = 1.0"),
            ("churn_period = 2", "churn_period = 0"),
            ("wave_amplitude = 0.5", "wave_amplitude = 1.5"),
            ("wave_period = 6", "wave_period = 0"),
            // the cohort must fit in the registered fleet
            ("registered = 1000000", "registered = 4"),
        ] {
            let bad = POPULATION_SAMPLE.replace(from, to);
            let t = toml::parse(&bad).unwrap();
            assert!(ExperimentConfig::from_table(&t).is_err(), "{to}");
        }
        // scenario knobs without the master switch must be rejected, not
        // silently ignored
        let dangling = POPULATION_SAMPLE.replace("enabled = true", "");
        let err =
            ExperimentConfig::from_table(&toml::parse(&dangling).unwrap())
                .unwrap_err();
        assert!(err.to_string().contains("population.enabled"), "{err}");
    }

    const SERVE_SAMPLE: &str = r#"
        name = "serve_cell"

        [fl]
        clients = 16
        clients_per_round = 8

        [async]
        enabled = true
        concurrency = 6
        buffer_k = 3

        [serve]
        enabled = true
        workers = 4
        queue_depth = 10
        arena = false
        rate = 200.0
        probe = false
    "#;

    #[test]
    fn parses_serve_table_and_defaults() {
        let t = toml::parse(SERVE_SAMPLE).unwrap();
        let c = ExperimentConfig::from_table(&t).unwrap();
        assert!(c.serve.enabled);
        assert_eq!(c.serve.workers, 4);
        assert_eq!(c.serve.queue_depth, 10);
        assert!(!c.serve.arena);
        assert_eq!(c.serve.rate, 200.0);
        assert!(!c.serve.probe);
        // absent table → disabled defaults with arena + probe on
        let plain =
            ExperimentConfig::from_table(&toml::parse("name = \"x\"").unwrap())
                .unwrap();
        assert!(!plain.serve.enabled);
        assert!(plain.serve.arena && plain.serve.probe);
        assert_eq!((plain.serve.workers, plain.serve.queue_depth), (0, 0));
    }

    #[test]
    fn serve_requires_async_and_rejects_bad_knobs() {
        // serving without the async phase has nothing to execute
        let no_async = SERVE_SAMPLE.replace(
            "[async]\n        enabled = true",
            "[async]\n        enabled = false",
        );
        let err = ExperimentConfig::from_table(&toml::parse(&no_async).unwrap())
            .unwrap_err();
        assert!(err.to_string().contains("async.enabled"), "{err}");
        for (from, to) in [
            ("workers = 4", "workers = -1"),
            ("queue_depth = 10", "queue_depth = -2"),
            ("rate = 200.0", "rate = -5.0"),
        ] {
            let bad = SERVE_SAMPLE.replace(from, to);
            let t = toml::parse(&bad).unwrap();
            assert!(ExperimentConfig::from_table(&t).is_err(), "{to}");
        }
        // serving knobs without the master switch must be rejected, not
        // silently ignored
        let dangling = SERVE_SAMPLE.replace(
            "[serve]\n        enabled = true",
            "[serve]",
        );
        let err =
            ExperimentConfig::from_table(&toml::parse(&dangling).unwrap())
                .unwrap_err();
        assert!(err.to_string().contains("serve.enabled"), "{err}");
    }

    #[test]
    fn rejects_bad_chaos_knobs_and_dangling_table() {
        for (from, to) in [
            ("bitflip = 0.1", "bitflip = 1.5"),
            ("crash = 0.02", "crash = -0.1"),
            ("max_retries = 2", "max_retries = 99"),
            ("backoff_base_s = 0.5", "backoff_base_s = -1.0"),
            ("quarantine_threshold = 3", "quarantine_threshold = 0"),
            ("quarantine_rounds = 2", "quarantine_rounds = 0"),
        ] {
            let bad = CHAOS_SAMPLE.replace(from, to);
            let t = toml::parse(&bad).unwrap();
            assert!(ExperimentConfig::from_table(&t).is_err(), "{to}");
        }
        // bitflip + truncate must leave room for a clean attempt
        let saturated = CHAOS_SAMPLE
            .replace("bitflip = 0.1", "bitflip = 0.6")
            .replace("truncate = 0.05", "truncate = 0.5");
        assert!(
            ExperimentConfig::from_table(&toml::parse(&saturated).unwrap())
                .is_err()
        );
        // fault knobs without the master switch must be rejected, not
        // silently ignored
        let dangling = CHAOS_SAMPLE.replace("enabled = true", "");
        let err =
            ExperimentConfig::from_table(&toml::parse(&dangling).unwrap())
                .unwrap_err();
        assert!(err.to_string().contains("chaos.enabled"), "{err}");
    }
}
