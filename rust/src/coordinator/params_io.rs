//! Checkpoint I/O: save/load the full-precision global model.
//!
//! Used by the domain-adaptation experiments (Table 2 / Table 4 / Fig. 4):
//! pretrain on domain A, checkpoint, then finetune with OMC on domain B.
//!
//! Format: `OMCP` magic, u32 version, u32 nvars, then per variable
//! u32 length + raw little-endian f32 payload.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{ensure, Context, Result};

const MAGIC: &[u8; 4] = b"OMCP";
const VERSION: u32 = 1;

pub fn save(path: &Path, params: &[Vec<f32>]) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    f.write_all(MAGIC)?;
    f.write_all(&VERSION.to_le_bytes())?;
    f.write_all(&(params.len() as u32).to_le_bytes())?;
    for v in params {
        f.write_all(&(v.len() as u32).to_le_bytes())?;
        let mut buf = Vec::with_capacity(v.len() * 4);
        for x in v {
            buf.extend_from_slice(&x.to_le_bytes());
        }
        f.write_all(&buf)?;
    }
    Ok(())
}

pub fn load(path: &Path) -> Result<Vec<Vec<f32>>> {
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    let mut bytes = Vec::new();
    f.read_to_end(&mut bytes)?;
    ensure!(bytes.len() >= 12, "checkpoint too short");
    ensure!(&bytes[..4] == MAGIC, "bad checkpoint magic");
    let ver = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
    ensure!(ver == VERSION, "unsupported checkpoint version {ver}");
    let nvars = u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
    let mut i = 12usize;
    let mut out = Vec::with_capacity(nvars);
    for vi in 0..nvars {
        ensure!(i + 4 <= bytes.len(), "truncated at var {vi}");
        let n = u32::from_le_bytes(bytes[i..i + 4].try_into().unwrap()) as usize;
        i += 4;
        ensure!(i + 4 * n <= bytes.len(), "truncated payload at var {vi}");
        let mut v = Vec::with_capacity(n);
        for c in bytes[i..i + 4 * n].chunks_exact(4) {
            v.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        i += 4 * n;
        out.push(v);
    }
    ensure!(i == bytes.len(), "trailing bytes in checkpoint");
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::Gen;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("omc_ckpt_{}_{name}", std::process::id()))
    }

    #[test]
    fn roundtrip() {
        let mut g = Gen::new(1);
        let params = vec![g.vec_normal(100, 0.3), vec![], g.vec_normal(7, 2.0)];
        let p = tmp("rt.bin");
        save(&p, &params).unwrap();
        let back = load(&p).unwrap();
        assert_eq!(params.len(), back.len());
        for (a, b) in params.iter().zip(&back) {
            assert_eq!(
                a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                b.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
            );
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_corruption() {
        let p = tmp("bad.bin");
        save(&p, &[vec![1.0, 2.0]]).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        bytes[0] = b'X';
        std::fs::write(&p, &bytes).unwrap();
        assert!(load(&p).is_err());
        std::fs::write(&p, &bytes[..5]).unwrap();
        assert!(load(&p).is_err());
        std::fs::remove_file(&p).ok();
    }
}
