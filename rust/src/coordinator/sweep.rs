//! Grid-sweep engine: run whole experiment grids concurrently with
//! byte-deterministic summaries.
//!
//! The paper's headline results are *grids* — format × transform ×
//! quantized-fraction × cohort — and reproducing a table used to mean one
//! process per cell plus hand-collected JSON. A [`SweepSpec`] describes
//! the whole grid up front: an ordered list of [`ExperimentConfig`] cells
//! (stable order is part of the output contract) plus an optional
//! pretraining phase that produces the shared checkpoint the adaptation
//! tables start from.
//!
//! # Determinism contract
//!
//! * Cell seeds derive from `(sweep seed, cell index)`
//!   ([`SweepSpec::finalize`]) — never from scheduling.
//! * Each cell is self-contained: its result depends only on its config
//!   (including its *intra-cell* `workers` count, which profiles pin to 1
//!   for byte-stable aggregation) — never on which sweep worker ran it.
//! * Summaries contain no wall-clock fields ([`crate::metrics::sweep`]);
//!   timing lands in the separate, non-golden `sweep_timing.json`.
//!
//! Together: `sweep_summary.json` is byte-identical across runs and across
//! sequential vs pooled scheduling — the property the CI `smoke-goldens`
//! job gates on with a plain `cmp`.
//!
//! # Scheduling
//!
//! Cells are independent, so they pool over [`threadpool`] in contiguous
//! chunks, one chunk per worker, each worker reusing a warmed
//! [`RoundEngine`] across its cells ([`threadpool::scope_map_chunked`]).
//! Engines that are not `Send`-safe (PJRT: `is_send_safe() == false`) pin
//! every cell to the calling thread — same dispatch rule as `fl::round`.
//!
//! # Resume
//!
//! `--resume` skips a cell when its on-disk summary exists **and** its
//! `config_hash` matches the cell's [`SweepSpec::cell_fingerprint_hex`]
//! (a hash over every semantically relevant config field, including the
//! sweep's pretrain phase when one exists — a changed pretrain
//! invalidates dependent cells AND the checkpoint, whose own fingerprint
//! is kept beside it). Stale or corrupt summaries re-run.
//! Spliced-in summaries keep byte equality because the JSON writer is
//! idempotent over its own output (tested in `metrics::sweep`).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::coordinator::config::{ExperimentConfig, OmcConfig, SparseConfig};
use crate::coordinator::experiment::{self, Experiment, RunSummary};
use crate::data::partition::Partition;
use crate::fl::async_round::{AsyncConfig, StalenessPolicy};
use crate::fl::chaos::ChaosConfig;
use crate::fl::cohort::CohortConfig;
use crate::fl::population::PopulationConfig;
use crate::fl::round::RoundEngine;
use crate::metrics::stats::Timer;
use crate::metrics::sweep as summaries;
use crate::metrics::sweep::CellView;
use crate::omc::format::FloatFormat;
use crate::omc::sparse::SparseMode;
use crate::runtime::engine::{Engine, LoadedModel};
use crate::util::json::{self, Json};
use crate::util::rng::hash_seed;
use crate::util::threadpool;
use crate::util::toml::{self, Table};

/// A fully-expanded sweep: ordered cells + optional pretraining phase.
#[derive(Clone, Debug)]
pub struct SweepSpec {
    /// sweep name — also the golden file stem (`goldens/<name>.json`)
    pub name: String,
    /// sweep-level seed; cell seeds derive from `(seed, cell_index)`
    pub seed: u64,
    /// output root: `sweep_summary.json`, `sweep_timing.json`, `cells/`
    pub output_dir: PathBuf,
    /// optional checkpoint-producing phase run before any cell (domain
    /// adaptation); its `save_to` is the cells' `init_from`
    pub pretrain: Option<ExperimentConfig>,
    /// grid cells in presentation order (the order is part of the output)
    pub cells: Vec<ExperimentConfig>,
}

impl SweepSpec {
    /// Empty spec; push cells then call [`finalize`](Self::finalize).
    pub fn new(name: &str, seed: u64, output_dir: &Path) -> Self {
        Self {
            name: name.to_string(),
            seed,
            output_dir: output_dir.to_path_buf(),
            pretrain: None,
            cells: Vec::new(),
        }
    }

    /// Derive per-cell seeds from `(sweep seed, cell index)` and validate.
    /// Call after the cell list is complete — the derivation is positional.
    pub fn finalize(mut self) -> Result<Self> {
        for (i, cell) in self.cells.iter_mut().enumerate() {
            cell.seed = hash_seed(&[self.seed, i as u64]);
        }
        self.validate()?;
        Ok(self)
    }

    /// Structural checks: at least one cell, valid configs, unique file
    /// stems (labels may repeat across sweeps, not within one).
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(!self.cells.is_empty(), "sweep has no cells");
        let mut stems = std::collections::BTreeSet::new();
        for (i, cell) in self.cells.iter().enumerate() {
            cell.validate()
                .with_context(|| format!("cell {i} ({})", cell.name))?;
            anyhow::ensure!(
                stems.insert(cell_file_stem(i, &cell.name)),
                "duplicate cell file stem for label {:?}",
                cell.name
            );
        }
        if let Some(pre) = &self.pretrain {
            pre.validate().context("pretrain config")?;
            anyhow::ensure!(
                pre.save_to.is_some(),
                "pretrain phase must set save_to (cells start from it)"
            );
        }
        Ok(())
    }
}

/// Runtime options for one sweep invocation (scheduling + resume — nothing
/// here may change summary bytes).
#[derive(Clone, Debug)]
pub struct SweepOptions {
    /// pool width across cells (intra-cell parallelism is the cell
    /// config's own `workers` field)
    pub workers: usize,
    /// force cell-at-a-time scheduling on the calling thread
    pub sequential: bool,
    /// skip cells whose on-disk summary matches their config fingerprint
    pub resume: bool,
}

impl Default for SweepOptions {
    fn default() -> Self {
        Self {
            workers: threadpool::default_workers(),
            sequential: false,
            resume: false,
        }
    }
}

/// One cell's result inside a [`SweepReport`].
pub struct CellOutcome {
    /// position in the grid (also the seed-derivation index)
    pub index: usize,
    /// the cell's pretty label (config `name`)
    pub label: String,
    /// the deterministic summary document, as written to disk
    pub cell_json: Json,
    /// live run summary — `None` when the cell was resumed from disk
    pub run: Option<RunSummary>,
    /// whether `--resume` spliced this cell in without re-running
    pub resumed: bool,
}

/// What [`run_sweep`] hands back.
pub struct SweepReport {
    /// sweep name (golden stem)
    pub name: String,
    /// where the consolidated summary was written
    pub summary_path: PathBuf,
    /// the exact bytes written — the golden artifact
    pub summary_bytes: String,
    /// per-cell outcomes in grid order
    pub cells: Vec<CellOutcome>,
    /// how many cells `--resume` skipped
    pub cells_resumed: usize,
    /// wall-clock for the whole sweep (reporting only — never in goldens)
    pub wall_s: f64,
    /// the models the sweep bound, keyed by model-dir string — reuse
    /// these for follow-up evaluation instead of re-binding (under PJRT a
    /// fresh binding would recompile its graphs from scratch)
    pub models: BTreeMap<String, Arc<LoadedModel>>,
}

impl SweepReport {
    /// The bound model for a model dir, if the sweep used that dir.
    pub fn model_for(&self, dir: &Path) -> Option<Arc<LoadedModel>> {
        self.models.get(&dir.display().to_string()).map(Arc::clone)
    }
}

// ---- fingerprinting ------------------------------------------------------

/// Canonical encoding of every semantically relevant config field. Floats
/// are encoded by bit pattern; the string feeds [`fingerprint_hex`].
fn canonical_config(cfg: &ExperimentConfig) -> String {
    format!(
        "schema={};name={};model={};rounds={};clients={};cpr={};steps={};\
         lr={:08x};seed={};partition={};sampler={};domain={};noise={:08x};\
         eval_every={};eval_batches={};fmt={};pvt={};wo={};frac={:016x};\
         dropout={:016x};straggler={:016x};deadline={:016x};weighted={};\
         init={};save={};workers={};\
         async={};aconc={};ak={};apol={};astale={};aring={};\
         integrity={};chaos={};cbf={:016x};ctr={:016x};cdup={:016x};\
         ccr={:016x};ccf={:016x};cret={};cbo={:016x};cqt={};cqr={};\
         delta={};sp={};spm={};spf={:016x};\
         pop={};preg={};pedg={};pchr={:016x};pchp={};\
         pwa={:016x};pwp={}",
        summaries::SWEEP_SCHEMA_VERSION,
        cfg.name,
        cfg.model_dir.display(),
        cfg.rounds,
        cfg.num_clients,
        cfg.clients_per_round,
        cfg.local_steps,
        cfg.lr.to_bits(),
        cfg.seed,
        cfg.partition,
        cfg.sampler,
        cfg.domain,
        cfg.noise.to_bits(),
        cfg.eval_every,
        cfg.eval_batches,
        cfg.omc.format,
        cfg.omc.use_pvt,
        cfg.omc.weights_only,
        cfg.omc.fraction.to_bits(),
        cfg.cohort.dropout_prob.to_bits(),
        cfg.cohort.straggler_mean_s.to_bits(),
        cfg.cohort.deadline_s.to_bits(),
        cfg.cohort.weight_by_examples,
        cfg.init_from
            .as_ref()
            .map(|p| p.display().to_string())
            .unwrap_or_default(),
        cfg.save_to
            .as_ref()
            .map(|p| p.display().to_string())
            .unwrap_or_default(),
        cfg.workers,
        cfg.async_cfg.enabled,
        cfg.async_cfg.concurrency,
        cfg.async_cfg.buffer_k,
        cfg.async_cfg.policy.canonical(),
        cfg.async_cfg.max_staleness,
        cfg.async_cfg.snapshot_ring,
        cfg.omc.integrity,
        cfg.chaos.enabled,
        cfg.chaos.bitflip_prob.to_bits(),
        cfg.chaos.truncate_prob.to_bits(),
        cfg.chaos.duplicate_prob.to_bits(),
        cfg.chaos.crash_prob.to_bits(),
        cfg.chaos.commit_failure_prob.to_bits(),
        cfg.chaos.max_retries,
        cfg.chaos.backoff_base_s.to_bits(),
        cfg.chaos.quarantine_threshold,
        cfg.chaos.quarantine_rounds,
        cfg.delta.enabled,
        cfg.sparse.enabled,
        cfg.sparse.mode,
        cfg.sparse.fraction.to_bits(),
        cfg.population.enabled,
        cfg.population.registered,
        cfg.population.edges,
        cfg.population.churn_rate.to_bits(),
        cfg.population.churn_period,
        cfg.population.wave_amplitude.to_bits(),
        cfg.population.wave_period,
    )
}

fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// The cell's config hash (16 hex digits) — written into its summary as
/// `config_hash` and verified by `--resume`.
pub fn fingerprint_hex(cfg: &ExperimentConfig) -> String {
    format!("{:016x}", fnv1a64(canonical_config(cfg).as_bytes()))
}

impl SweepSpec {
    /// A cell's fingerprint *in this sweep*: the cell config plus the
    /// pretrain phase (if any) that produced the checkpoint the cell
    /// starts from. Changing the pretrain — its rounds, its seed —
    /// invalidates every dependent cell summary, not just the checkpoint.
    /// Equal to [`fingerprint_hex`] for sweeps without a pretrain phase.
    pub fn cell_fingerprint_hex(&self, cfg: &ExperimentConfig) -> String {
        let mut canon = canonical_config(cfg);
        if let Some(pre) = &self.pretrain {
            canon.push_str(";pretrain=");
            canon.push_str(&canonical_config(pre));
        }
        format!("{:016x}", fnv1a64(canon.as_bytes()))
    }

    /// Fingerprint of the pretrain phase itself — written beside the
    /// checkpoint so `--resume` can tell a reusable checkpoint from a
    /// stale one.
    fn pretrain_fingerprint_hex(pre: &ExperimentConfig) -> String {
        fingerprint_hex(pre)
    }
}

/// Filesystem-safe stem for cell output files:
/// `c<index>_<sanitized label>`.
pub fn cell_file_stem(index: usize, label: &str) -> String {
    let safe: String = label
        .chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() || matches!(c, '.' | '-' | '_') {
                c
            } else {
                '_'
            }
        })
        .collect();
    format!("c{index:02}_{safe}")
}

// ---- grid expansion from TOML --------------------------------------------

/// Load a sweep description from a TOML file: the usual experiment keys
/// form the base cell, and the `[sweep]` table holds the grid axes
/// (`formats` is required; `pvt`, `fractions`, `partitions`, `domains`
/// default to the base config's values).
pub fn from_toml_file(path: &Path) -> Result<SweepSpec> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let t = toml::parse(&text)
        .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
    from_table(&t).with_context(|| format!("expanding {}", path.display()))
}

/// Named cohort-failure scenario for the `sweep.cohorts` axis — the same
/// ladder `presets::cohort_ladder` escalates through.
fn cohort_by_name(name: &str) -> Result<CohortConfig> {
    Ok(match name {
        "ideal" => CohortConfig::ideal(),
        "dropout" => CohortConfig {
            dropout_prob: 0.1,
            ..CohortConfig::ideal()
        },
        "stragglers" => CohortConfig {
            straggler_mean_s: 2.0,
            deadline_s: 4.0,
            ..CohortConfig::ideal()
        },
        "stress" => CohortConfig {
            dropout_prob: 0.1,
            straggler_mean_s: 2.0,
            deadline_s: 4.0,
            weight_by_examples: true,
        },
        other => anyhow::bail!(
            "unknown cohort scenario {other:?} (ideal | dropout | stragglers | stress)"
        ),
    })
}

/// Named fault-injection scenario for the `sweep.chaos` axis. Any scenario
/// other than `off` forces `omc.integrity = true` on its cells — corrupt
/// frames must be detectable to be rejected.
fn chaos_by_name(name: &str) -> Result<ChaosConfig> {
    Ok(match name {
        "off" => ChaosConfig::default(),
        "light" => ChaosConfig {
            enabled: true,
            bitflip_prob: 0.05,
            truncate_prob: 0.05,
            duplicate_prob: 0.05,
            crash_prob: 0.05,
            commit_failure_prob: 0.05,
            ..ChaosConfig::default()
        },
        "heavy" => ChaosConfig {
            enabled: true,
            bitflip_prob: 0.25,
            truncate_prob: 0.15,
            duplicate_prob: 0.2,
            crash_prob: 0.1,
            // high enough that the smoke-chaos async cell's 4 planned
            // commits register at least one failure at the CI seed (its
            // lowest commit draw sits just under 0.29) — the
            // chaos-determinism gate greps for a nonzero counter
            commit_failure_prob: 0.35,
            ..ChaosConfig::default()
        },
        other => anyhow::bail!(
            "unknown chaos scenario {other:?} (off | light | heavy)"
        ),
    })
}

/// Named uplink-sparsification scenario for the `sweep.sparse` axis. Any
/// scenario other than `off` forces `omc.integrity = true` on its cells —
/// sparse records ride the checksummed v2/v3 layouts. Both selection
/// modes keep a quarter of the coordinates so paired cells A/B the
/// selection rule, not the budget.
fn sparse_by_name(name: &str) -> Result<SparseConfig> {
    Ok(match name {
        "off" => SparseConfig::default(),
        "topk" => SparseConfig {
            enabled: true,
            mode: SparseMode::TopK,
            fraction: 0.25,
        },
        "randk" => SparseConfig {
            enabled: true,
            mode: SparseMode::RandK,
            fraction: 0.25,
        },
        other => anyhow::bail!(
            "unknown sparse scenario {other:?} (off | topk | randk)"
        ),
    })
}

/// Named fleet-scale scenario for the `sweep.population` axis. Any
/// scenario other than `off` runs its cells in lazy population mode:
/// `registered` replaces `fl.clients` as the fleet size, cohorts stream
/// out of the registered space, and edge aggregators fold shards before
/// one merged uplink per edge reaches the root.
fn population_by_name(name: &str) -> Result<PopulationConfig> {
    Ok(match name {
        "off" => PopulationConfig::off(),
        "city" => PopulationConfig {
            enabled: true,
            registered: 100_000,
            edges: 2,
            churn_rate: 0.2,
            churn_period: 4,
            wave_amplitude: 0.3,
            wave_period: 8,
        },
        "nation" => PopulationConfig {
            enabled: true,
            registered: 1_000_000,
            edges: 4,
            churn_rate: 0.3,
            churn_period: 2,
            wave_amplitude: 0.5,
            wave_period: 6,
        },
        "planet" => PopulationConfig {
            enabled: true,
            registered: 10_000_000,
            edges: 8,
            churn_rate: 0.4,
            churn_period: 2,
            wave_amplitude: 0.6,
            wave_period: 4,
        },
        other => anyhow::bail!(
            "unknown population scenario {other:?} (off | city | nation | planet)"
        ),
    })
}

/// Expand a parsed table into a sweep. Cell order is the nested axis order
/// `partition → domain → cohort → format → pvt → fraction`; an FP32 entry
/// in `formats` contributes exactly one baseline cell per
/// `(partition, domain, cohort)` (transform/fraction axes do not apply to
/// the baseline).
pub fn from_table(t: &Table) -> Result<SweepSpec> {
    let base = ExperimentConfig::from_table(t)?;
    let axis_strs = |key: &str| -> Result<Option<Vec<String>>> {
        match t.get(key) {
            None => Ok(None),
            Some(v) => {
                let arr = v
                    .as_arr()
                    .ok_or_else(|| anyhow::anyhow!("{key} must be an array"))?;
                arr.iter()
                    .map(|x| {
                        x.as_str().map(str::to_string).ok_or_else(|| {
                            anyhow::anyhow!("{key} entries must be strings")
                        })
                    })
                    .collect::<Result<Vec<_>>>()
                    .map(Some)
            }
        }
    };

    let formats: Vec<FloatFormat> = axis_strs("sweep.formats")?
        .ok_or_else(|| anyhow::anyhow!("a sweep needs sweep.formats"))?
        .iter()
        .map(|s| s.parse())
        .collect::<Result<_>>()?;
    anyhow::ensure!(!formats.is_empty(), "sweep.formats is empty");

    let pvts: Vec<bool> = match t.get("sweep.pvt") {
        None => vec![base.omc.use_pvt],
        Some(v) => v
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("sweep.pvt must be an array"))?
            .iter()
            .map(|x| {
                x.as_bool()
                    .ok_or_else(|| anyhow::anyhow!("sweep.pvt entries must be bools"))
            })
            .collect::<Result<_>>()?,
    };
    let fractions: Vec<f64> = match t.get("sweep.fractions") {
        None => vec![base.omc.fraction],
        Some(v) => {
            let fr: Vec<f64> = v
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("sweep.fractions must be an array"))?
                .iter()
                .map(|x| {
                    x.as_f64().ok_or_else(|| {
                        anyhow::anyhow!("sweep.fractions entries must be numbers")
                    })
                })
                .collect::<Result<_>>()?;
            for &f in &fr {
                anyhow::ensure!(
                    (0.0..=1.0).contains(&f) && f > 0.0,
                    "sweep fractions must be in (0, 1], got {f}"
                );
            }
            fr
        }
    };
    let partitions: Vec<Partition> = match axis_strs("sweep.partitions")? {
        None => vec![base.partition],
        Some(v) => v
            .iter()
            .map(|s| Partition::parse(s))
            .collect::<Result<_>>()?,
    };
    let domains: Vec<u64> = match t.get("sweep.domains") {
        None => vec![base.domain],
        Some(v) => v
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("sweep.domains must be an array"))?
            .iter()
            .map(|x| {
                x.as_i64().map(|d| d as u64).ok_or_else(|| {
                    anyhow::anyhow!("sweep.domains entries must be integers")
                })
            })
            .collect::<Result<_>>()?,
    };

    let cohorts: Vec<(String, CohortConfig)> = match axis_strs("sweep.cohorts")? {
        None => vec![(String::new(), base.cohort)],
        Some(names) => names
            .iter()
            .map(|n| cohort_by_name(n).map(|c| (n.clone(), c)))
            .collect::<Result<_>>()?,
    };

    // execution-mode axis: each entry runs the grid synchronously or
    // through the buffered async engine (the base `[async]` table supplies
    // the async knobs; `sweep.modes = ["sync", "async"]` A/Bs them)
    let modes: Vec<String> = match axis_strs("sweep.modes")? {
        None => vec![if base.async_cfg.enabled { "async" } else { "sync" }
            .to_string()],
        Some(names) => {
            for n in &names {
                anyhow::ensure!(
                    n == "sync" || n == "async",
                    "unknown sweep mode {n:?} (sync | async)"
                );
            }
            names
        }
    };

    // fault-injection axis: named chaos scenarios (`chaos_by_name`); any
    // non-`off` entry forces wire integrity on its cells
    let chaoses: Vec<(String, ChaosConfig)> = match axis_strs("sweep.chaos")? {
        None => vec![(String::new(), base.chaos)],
        Some(names) => names
            .iter()
            .map(|n| chaos_by_name(n).map(|c| (n.clone(), c)))
            .collect::<Result<_>>()?,
    };

    // delta wire-stage axis: `sweep.delta = [false, true]` A/Bs verbatim
    // against delta framing (lossless, so the training metrics of paired
    // cells must match — only the byte counters move); a `true` entry
    // forces wire integrity on its cells, same rule as the chaos axis
    let deltas: Vec<bool> = match t.get("sweep.delta") {
        None => vec![base.delta.enabled],
        Some(v) => v
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("sweep.delta must be an array"))?
            .iter()
            .map(|x| {
                x.as_bool().ok_or_else(|| {
                    anyhow::anyhow!("sweep.delta entries must be bools")
                })
            })
            .collect::<Result<_>>()?,
    };

    // fleet-scale axis: named population scenarios (`population_by_name`);
    // any non-`off` entry runs the grid at that scenario's registered
    // fleet size with lazy per-client state and two-tier edge aggregation
    let populations: Vec<(String, PopulationConfig)> =
        match axis_strs("sweep.population")? {
            None => vec![(String::new(), base.population)],
            Some(names) => names
                .iter()
                .map(|n| population_by_name(n).map(|p| (n.clone(), p)))
                .collect::<Result<_>>()?,
        };

    // uplink sparsification axis: named scenarios (`sparse_by_name`); a
    // non-`off` entry runs its cells with magnitude or random selection
    // plus per-client error feedback, and forces wire integrity — sparse
    // records only exist on the checksummed v2/v3 layouts
    let sparses: Vec<(String, SparseConfig)> = match axis_strs("sweep.sparse")? {
        None => vec![(String::new(), base.sparse)],
        Some(names) => names
            .iter()
            .map(|n| sparse_by_name(n).map(|s| (n.clone(), s)))
            .collect::<Result<_>>()?,
    };

    let mut spec = SweepSpec::new(&base.name, base.seed, &base.output_dir);
    let multi_axis = partitions.len() > 1
        || domains.len() > 1
        || cohorts.len() > 1
        || modes.len() > 1
        || chaoses.len() > 1
        || deltas.len() > 1
        || populations.len() > 1
        || sparses.len() > 1;
    for &partition in &partitions {
        for &domain in &domains {
            for (cohort_name, cohort) in &cohorts {
                for mode in &modes {
                    for (chaos_name, chaos) in &chaoses {
                        for &delta in &deltas {
                        for (pop_name, pop) in &populations {
                        for (sparse_name, sparse) in &sparses {
                            let suffix = if multi_axis {
                                let c = if cohort_name.is_empty() {
                                    String::new()
                                } else {
                                    format!("_{cohort_name}")
                                };
                                let m = if modes.len() > 1 {
                                    format!("_{mode}")
                                } else {
                                    String::new()
                                };
                                let x = if chaos_name.is_empty() {
                                    String::new()
                                } else {
                                    format!("_{chaos_name}")
                                };
                                let d = if deltas.len() > 1 {
                                    if delta { "_delta" } else { "_verbatim" }
                                } else {
                                    ""
                                };
                                let p = if pop_name.is_empty() {
                                    String::new()
                                } else {
                                    format!("_{pop_name}")
                                };
                                let sp = if sparse_name.is_empty() {
                                    String::new()
                                } else {
                                    format!("_{sparse_name}")
                                };
                                format!(
                                    "_{partition}_d{domain}{c}{m}{x}{d}{p}{sp}"
                                )
                            } else {
                                String::new()
                            };
                            let mut cell_with = |label: String, omc: OmcConfig| {
                                let mut c = base.clone();
                                c.name = label;
                                c.omc = omc;
                                c.omc.integrity = base.omc.integrity
                                    || !chaos.is_off()
                                    || delta
                                    || sparse.enabled;
                                c.partition = partition;
                                c.domain = domain;
                                c.cohort = *cohort;
                                c.async_cfg.enabled = mode == "async";
                                c.chaos = *chaos;
                                c.delta.enabled = delta;
                                c.population = *pop;
                                c.sparse = *sparse;
                                spec.cells.push(c);
                            };
                            if formats.iter().any(|f| f.is_fp32()) {
                                cell_with(
                                    format!("fp32_baseline{suffix}"),
                                    OmcConfig::fp32_baseline(),
                                );
                            }
                            for &fmt in formats.iter().filter(|f| !f.is_fp32()) {
                                for &use_pvt in &pvts {
                                    for &fraction in &fractions {
                                        let label = format!(
                                            "{fmt}_{}_f{fraction}{suffix}",
                                            if use_pvt { "pvt" } else { "nopvt" }
                                        );
                                        cell_with(
                                            label,
                                            OmcConfig {
                                                format: fmt,
                                                use_pvt,
                                                weights_only: base.omc.weights_only,
                                                fraction,
                                                integrity: base.omc.integrity,
                                            },
                                        );
                                    }
                                }
                            }
                        }
                        }
                        }
                    }
                }
            }
        }
    }
    spec.finalize()
}

// ---- built-in profiles ---------------------------------------------------

/// The CI smoke tier: five cells on `native:tiny` covering the format,
/// transform, and selection axes. Small enough for seconds-scale CI, and
/// byte-deterministic: every cell pins `workers = 1` so the streaming
/// aggregation order is fixed.
pub fn smoke(seed: u64) -> Result<SweepSpec> {
    let mut base =
        ExperimentConfig::default_with("smoke", Path::new("native:tiny"));
    base.rounds = 4;
    base.num_clients = 8;
    base.clients_per_round = 4;
    base.local_steps = 1;
    base.lr = 0.2;
    base.eval_every = 2;
    base.eval_batches = 2;
    base.workers = 1; // byte-stable aggregation order
    base.output_dir = PathBuf::from("results/sweep_smoke");

    let mut spec = SweepSpec::new("sweep_smoke", seed, &base.output_dir);
    let cells: Vec<(String, OmcConfig)> = vec![
        ("fp32_baseline".into(), OmcConfig::fp32_baseline()),
        (
            "S1E4M14_pvt_f0.9".into(),
            OmcConfig::paper("S1E4M14".parse()?),
        ),
        (
            "S1E4M14_nopvt_f0.9".into(),
            OmcConfig {
                use_pvt: false,
                ..OmcConfig::paper("S1E4M14".parse()?)
            },
        ),
        (
            "S1E3M7_pvt_f0.9".into(),
            OmcConfig::paper("S1E3M7".parse()?),
        ),
        (
            "S1E2M3_apq".into(),
            OmcConfig {
                format: "S1E2M3".parse()?,
                use_pvt: true,
                weights_only: false,
                fraction: 1.0,
                integrity: false,
            },
        ),
    ];
    for (label, omc) in cells {
        let mut c = base.clone();
        c.name = label;
        c.omc = omc;
        spec.cells.push(c);
    }
    spec.finalize()
}

/// The async CI smoke tier (`--profile smoke-async`): four `native:tiny`
/// cells covering sync-vs-async, buffer sizes, the polynomial staleness
/// discount, and the `max_staleness` discard path. The sync cell pins
/// `workers = 1` (its shard-merge order depends on the worker count); the
/// async cells deliberately run with `workers = 4` — the async engine's
/// committed bytes and metrics are worker-count-independent by
/// construction (training parallelism only; one central fold), which is
/// exactly what the CI `async-determinism` leg gates with `cmp`.
pub fn smoke_async(seed: u64) -> Result<SweepSpec> {
    let mut base =
        ExperimentConfig::default_with("smoke_async", Path::new("native:tiny"));
    base.rounds = 4; // commits, for the async cells
    base.num_clients = 8;
    base.clients_per_round = 4;
    base.local_steps = 1;
    base.lr = 0.2;
    base.eval_every = 2;
    base.eval_batches = 2;
    base.output_dir = PathBuf::from("results/sweep_smoke_async");
    base.omc = OmcConfig {
        format: "S1E4M14".parse()?,
        use_pvt: true,
        weights_only: true,
        fraction: 1.0,
        integrity: false,
    };
    // stragglers make staleness real; async ignores the deadline
    let straggled = CohortConfig {
        straggler_mean_s: 2.0,
        ..CohortConfig::ideal()
    };

    let mut spec =
        SweepSpec::new("sweep_smoke_async", seed, &base.output_dir);
    let poly = StalenessPolicy::Polynomial { alpha: 0.5 };
    let cells: Vec<(&str, AsyncConfig, CohortConfig, usize)> = vec![
        ("sync_fedavg", AsyncConfig::default(), CohortConfig::ideal(), 1),
        (
            "async_k4_const",
            AsyncConfig {
                enabled: true,
                snapshot_ring: 2,
                ..AsyncConfig::default()
            },
            CohortConfig::ideal(),
            4,
        ),
        (
            "async_k2_poly",
            AsyncConfig {
                enabled: true,
                buffer_k: 2,
                policy: poly,
                snapshot_ring: 2,
                ..AsyncConfig::default()
            },
            straggled,
            4,
        ),
        (
            "async_k2_poly_stale1",
            AsyncConfig {
                enabled: true,
                buffer_k: 2,
                policy: poly,
                max_staleness: 1,
                snapshot_ring: 2,
                ..AsyncConfig::default()
            },
            straggled,
            4,
        ),
    ];
    for (label, acfg, cohort, workers) in cells {
        let mut c = base.clone();
        c.name = label.to_string();
        c.async_cfg = acfg;
        c.cohort = cohort;
        c.workers = workers;
        spec.cells.push(c);
    }
    spec.finalize()
}

/// The chaos CI smoke tier (`--profile smoke-chaos`): four `native:tiny`
/// cells exercising the wire-integrity + fault-injection stack end to end.
/// One clean cell proves the checksummed v2 frames round-trip with zero
/// rejections; two sync cells inject heavy faults (one tuned to trip the
/// quarantine ladder); one async cell adds commit failures on top and runs
/// with `workers = 4` — rejected-frame accounting happens in the
/// deterministic task-order results pass, so its summary is worker-count
/// independent. The CI `chaos-determinism` leg runs this profile at two
/// worker counts plus `OMC_FORCE_SCALAR=1` and `cmp`s the summaries.
pub fn smoke_chaos(seed: u64) -> Result<SweepSpec> {
    let mut base =
        ExperimentConfig::default_with("smoke_chaos", Path::new("native:tiny"));
    base.rounds = 4;
    base.num_clients = 8;
    base.clients_per_round = 4;
    base.local_steps = 1;
    base.lr = 0.2;
    base.eval_every = 2;
    base.eval_batches = 2;
    base.workers = 1; // byte-stable sync aggregation order
    base.output_dir = PathBuf::from("results/sweep_smoke_chaos");
    base.omc = OmcConfig {
        format: "S1E4M14".parse()?,
        use_pvt: true,
        weights_only: true,
        fraction: 1.0,
        integrity: true,
    };

    let heavy = chaos_by_name("heavy")?;
    // every corrupt frame counts against the client immediately — with
    // heavy fault rates this trips the ladder within the smoke horizon
    let trigger_happy = ChaosConfig {
        quarantine_threshold: 1,
        ..heavy
    };

    let mut spec = SweepSpec::new("sweep_smoke_chaos", seed, &base.output_dir);
    let cells: Vec<(&str, ChaosConfig, bool, usize)> = vec![
        ("sync_integrity_clean", ChaosConfig::default(), false, 1),
        ("sync_chaos_heavy", heavy, false, 1),
        ("sync_chaos_quarantine", trigger_happy, false, 1),
        ("async_chaos_heavy", heavy, true, 4),
    ];
    for (label, chaos, is_async, workers) in cells {
        let mut c = base.clone();
        c.name = label.to_string();
        c.chaos = chaos;
        if is_async {
            c.async_cfg = AsyncConfig {
                enabled: true,
                buffer_k: 2,
                snapshot_ring: 2,
                ..AsyncConfig::default()
            };
        }
        c.workers = workers;
        spec.cells.push(c);
    }
    spec.finalize()
}

/// The delta CI smoke tier (`--profile smoke-delta`): four `native:tiny`
/// cells proving the lossless cross-round delta stage end to end. A
/// verbatim/delta sync pair shares every training knob — the delta stage
/// is lossless, so their losses and WER curves must be identical — and a
/// converged-regime delta cell (step size below the quantization dead
/// zone, so packed uplinks are bitwise static) guarantees `up_bytes`
/// drop and a nonzero `up_bytes_delta_saved` for the CI grep gate. An
/// async delta cell exercises the snapshot-ring base path with
/// `workers = 4` (fold order is worker-count independent), and a chaos
/// delta cell drives corrupt/retried v3 frames through the ack ledger.
/// The CI `delta-determinism` leg runs this profile at two worker counts
/// plus `OMC_FORCE_SCALAR=1` and `cmp`s the summaries.
pub fn smoke_delta(seed: u64) -> Result<SweepSpec> {
    let mut base =
        ExperimentConfig::default_with("smoke_delta", Path::new("native:tiny"));
    base.rounds = 4;
    base.num_clients = 8;
    base.clients_per_round = 4;
    base.local_steps = 1;
    base.lr = 0.2;
    base.eval_every = 2;
    base.eval_batches = 2;
    base.workers = 1; // byte-stable sync aggregation order
    base.output_dir = PathBuf::from("results/sweep_smoke_delta");
    base.omc = OmcConfig {
        format: "S1E4M14".parse()?,
        use_pvt: true,
        weights_only: true,
        fraction: 1.0,
        integrity: true,
    };

    let mut spec = SweepSpec::new("sweep_smoke_delta", seed, &base.output_dir);
    // (label, delta, chaos, async, workers, lr) — the converged cell runs
    // at a step size far below the S1E4M14 quantization dead zone, so its
    // packed uplinks are bitwise static round-over-round and the delta
    // stage's zero-block path makes `up_bytes_delta_saved` structurally
    // nonzero (the regime the paper's cross-round residuals target); the
    // CI grep gate keys off that cell. The real-lr cells prove the stage
    // lossless where codes actually move.
    let cells: Vec<(&str, bool, ChaosConfig, bool, usize, f32)> = vec![
        ("sync_verbatim", false, ChaosConfig::default(), false, 1, 0.2),
        ("sync_delta", true, ChaosConfig::default(), false, 1, 0.2),
        ("sync_delta_converged", true, ChaosConfig::default(), false, 1, 1e-12),
        ("async_delta", true, ChaosConfig::default(), true, 4, 0.2),
        ("sync_delta_chaos", true, chaos_by_name("light")?, false, 1, 0.2),
    ];
    for (label, delta, chaos, is_async, workers, lr) in cells {
        let mut c = base.clone();
        c.name = label.to_string();
        c.delta.enabled = delta;
        c.chaos = chaos;
        c.lr = lr;
        if is_async {
            c.async_cfg = AsyncConfig {
                enabled: true,
                buffer_k: 2,
                snapshot_ring: 2,
                ..AsyncConfig::default()
            };
        }
        c.workers = workers;
        spec.cells.push(c);
    }
    spec.finalize()
}

/// The sparse CI smoke tier (`--profile smoke-sparse`): six `native:tiny`
/// cells proving uplink sparsification with error feedback end to end. A
/// dense/top-k sync pair shares every training knob, so the top-k cell's
/// `up_bytes` must come in strictly below its dense twin (the CI gate
/// `cmp`s that inequality, and greps for nonzero `up_bytes_sparse_saved`
/// and a nonzero residual norm — error feedback is actually banking the
/// unsent mass). A rand-k cell A/Bs the selection rule at the same
/// budget, an async top-k cell exercises the ring-snapshot sparse-base
/// fold with `workers = 4` (task-order residual commits keep it
/// worker-count independent), a partial-selection cell composes top-k
/// with a coarser format and `omc.fraction < 1` (masked-out vars must
/// never be sparsified), and a converged cell (step size below the
/// quantization dead zone) pins the regime where the residual stream
/// goes quiet. The CI `sparse-determinism` leg runs this profile at two
/// worker counts plus `OMC_FORCE_SCALAR=1` and `cmp`s the summaries.
pub fn smoke_sparse(seed: u64) -> Result<SweepSpec> {
    let mut base =
        ExperimentConfig::default_with("smoke_sparse", Path::new("native:tiny"));
    base.rounds = 4;
    base.num_clients = 8;
    base.clients_per_round = 4;
    base.local_steps = 1;
    base.lr = 0.2;
    base.eval_every = 2;
    base.eval_batches = 2;
    base.workers = 1; // byte-stable sync aggregation order
    base.output_dir = PathBuf::from("results/sweep_smoke_sparse");
    base.omc = OmcConfig {
        format: "S1E4M14".parse()?,
        use_pvt: true,
        weights_only: true,
        fraction: 1.0,
        integrity: true,
    };

    let topk = SparseConfig {
        enabled: true,
        mode: SparseMode::TopK,
        fraction: 0.25,
    };
    let randk = SparseConfig {
        enabled: true,
        mode: SparseMode::RandK,
        fraction: 0.25,
    };

    let mut spec = SweepSpec::new("sweep_smoke_sparse", seed, &base.output_dir);
    // (label, sparse, async, workers, lr, format, omc fraction) — the
    // dense cell is the byte-count control for the top-k twin; the
    // partial cell layers top-k under partial per-parameter selection at
    // a coarser format to prove the two selection stages compose; the
    // converged cell runs below the quantization dead zone so selected
    // magnitudes collapse and the sparse stage's savings are structural.
    #[allow(clippy::type_complexity)]
    let cells: Vec<(&str, SparseConfig, bool, usize, f32, &str, f32)> = vec![
        ("sync_dense", SparseConfig::default(), false, 1, 0.2, "S1E4M14", 1.0),
        ("sync_topk", topk, false, 1, 0.2, "S1E4M14", 1.0),
        ("sync_randk", randk, false, 1, 0.2, "S1E4M14", 1.0),
        ("async_topk", topk, true, 4, 0.2, "S1E4M14", 1.0),
        ("sync_topk_partial", topk, false, 1, 0.2, "S1E3M7", 0.5),
        ("sync_topk_converged", topk, false, 1, 1e-12, "S1E4M14", 1.0),
    ];
    for (label, sparse, is_async, workers, lr, fmt, fraction) in cells {
        let mut c = base.clone();
        c.name = label.to_string();
        c.sparse = sparse;
        c.lr = lr;
        c.omc.format = fmt.parse()?;
        c.omc.fraction = fraction;
        if is_async {
            c.async_cfg = AsyncConfig {
                enabled: true,
                buffer_k: 2,
                snapshot_ring: 2,
                ..AsyncConfig::default()
            };
        }
        c.workers = workers;
        spec.cells.push(c);
    }
    spec.finalize()
}

/// The scale CI smoke tier (`--profile smoke-scale`): five `native:tiny`
/// cells running the lazy-population stack end to end over a registered
/// fleet of 10^6 clients. Nothing materializes the fleet — per-client
/// state derives from `(seed, cid)` on demand — so the profile's peak
/// memory is O(active cohort), which the CI scale leg asserts with an RSS
/// ceiling. Cells cover the single-edge bit-exact path, the multi-edge
/// merged uplink, device-class cohort skew, the integrity+delta edge hop,
/// and fault injection on top; churn and wave knobs are aggressive enough
/// that the rejection counters are structurally nonzero within the
/// four-round horizon (the CI grep gate keys off them). Every cell pins
/// `workers = 1`; the edge fold is calling-thread sequential by
/// construction, so summaries are byte-identical across `--workers`
/// counts — the three-way `cmp` the CI scale-determinism leg gates on.
pub fn smoke_scale(seed: u64) -> Result<SweepSpec> {
    let mut base =
        ExperimentConfig::default_with("smoke_scale", Path::new("native:tiny"));
    base.rounds = 4;
    base.num_clients = 8; // ignored: population mode sizes the fleet below
    base.clients_per_round = 8;
    base.local_steps = 1;
    base.lr = 0.2;
    base.eval_every = 2;
    base.eval_batches = 2;
    base.workers = 1;
    base.output_dir = PathBuf::from("results/sweep_smoke_scale");
    base.omc = OmcConfig {
        format: "S1E4M14".parse()?,
        use_pvt: true,
        weights_only: true,
        fraction: 1.0,
        integrity: false,
    };
    base.population = PopulationConfig {
        enabled: true,
        registered: 1_000_000,
        edges: 4,
        churn_rate: 0.4,
        churn_period: 1,
        wave_amplitude: 0.6,
        wave_period: 4,
    };

    let mut spec = SweepSpec::new("sweep_smoke_scale", seed, &base.output_dir);
    let stress = CohortConfig {
        dropout_prob: 0.1,
        straggler_mean_s: 2.0,
        deadline_s: 4.0,
        weight_by_examples: true,
    };
    // (label, edges, cohort, integrity, delta, chaos)
    let cells: Vec<(&str, usize, CohortConfig, bool, bool, ChaosConfig)> = vec![
        (
            "edges1_ideal",
            1,
            CohortConfig::ideal(),
            false,
            false,
            ChaosConfig::default(),
        ),
        (
            "edges4",
            4,
            CohortConfig::ideal(),
            false,
            false,
            ChaosConfig::default(),
        ),
        (
            "edges4_classes_cohort",
            4,
            stress,
            false,
            false,
            ChaosConfig::default(),
        ),
        (
            "edges4_integrity_delta",
            4,
            CohortConfig::ideal(),
            true,
            true,
            ChaosConfig::default(),
        ),
        (
            "edges4_chaos",
            4,
            CohortConfig::ideal(),
            true,
            false,
            chaos_by_name("light")?,
        ),
    ];
    for (label, edges, cohort, integrity, delta, chaos) in cells {
        let mut c = base.clone();
        c.name = label.to_string();
        c.population.edges = edges;
        c.cohort = cohort;
        c.omc.integrity = integrity || !chaos.is_off() || delta;
        c.delta.enabled = delta;
        c.chaos = chaos;
        spec.cells.push(c);
    }
    spec.finalize()
}

// ---- execution -----------------------------------------------------------

type CellRun = (Json, RunSummary, f64);

/// Execute one cell end-to-end: prepare, run (through the caller's
/// [`RoundEngine`]), write `cells/<stem>.csv` + `cells/<stem>.json`, and
/// return the summary document.
fn run_cell(
    index: usize,
    cfg: ExperimentConfig,
    fp: String,
    model: Arc<LoadedModel>,
    cells_dir: &Path,
    rounds: &mut RoundEngine,
) -> Result<CellRun> {
    let t = Timer::start();
    let stem = cell_file_stem(index, &cfg.name);
    let mut exp = Experiment::prepare_with_model(cfg, model)?;
    let (rec, summary) = exp.run_with(rounds)?;
    let cell = summaries::cell_summary(index, &exp.cfg, &fp, &rec, &summary);
    std::fs::write(cells_dir.join(format!("{stem}.csv")), rec.to_csv())
        .with_context(|| format!("writing {stem}.csv"))?;
    if rec.is_async() {
        std::fs::write(
            cells_dir.join(format!("{stem}_commits.csv")),
            rec.commits_csv(),
        )
        .with_context(|| format!("writing {stem}_commits.csv"))?;
    }
    if rec.is_population() {
        std::fs::write(
            cells_dir.join(format!("{stem}_population.csv")),
            rec.populations_csv(),
        )
        .with_context(|| format!("writing {stem}_population.csv"))?;
    }
    std::fs::write(cells_dir.join(format!("{stem}.json")), cell.to_string())
        .with_context(|| format!("writing {stem}.json"))?;
    Ok((cell, summary, t.elapsed_s()))
}

/// Run a sweep: pretrain (if any), schedule the cells, write per-cell
/// outputs plus the consolidated `sweep_summary.json` and the non-golden
/// `sweep_timing.json`.
pub fn run_sweep(
    engine: &Engine,
    spec: &SweepSpec,
    opts: &SweepOptions,
) -> Result<SweepReport> {
    let t = Timer::start();
    spec.validate()?;
    let cells_dir = spec.output_dir.join("cells");
    std::fs::create_dir_all(&cells_dir)
        .with_context(|| format!("creating {}", cells_dir.display()))?;

    // bind each distinct model dir once (shared compile cache)
    let mut models: BTreeMap<String, Arc<LoadedModel>> = BTreeMap::new();
    let all_dirs = spec
        .cells
        .iter()
        .map(|c| &c.model_dir)
        .chain(spec.pretrain.iter().map(|p| &p.model_dir));
    for dir in all_dirs {
        let key = dir.display().to_string();
        if !models.contains_key(&key) {
            models.insert(key, Arc::new(engine.load_model(dir)?));
        }
    }

    // pretraining phase (shared checkpoint for adaptation grids). Resume
    // only trusts a checkpoint whose recorded fingerprint matches this
    // spec's pretrain config — a checkpoint left by a different seed or
    // round count re-trains instead of silently contaminating the cells.
    if let Some(pre) = &spec.pretrain {
        let ckpt = pre.save_to.as_ref().expect("validated");
        let fp_path = ckpt.with_extension("fingerprint");
        let pre_fp = SweepSpec::pretrain_fingerprint_hex(pre);
        let ckpt_fresh = ckpt.exists()
            && std::fs::read_to_string(&fp_path)
                .map(|s| s.trim() == pre_fp)
                .unwrap_or(false);
        if opts.resume && ckpt_fresh {
            crate::log_info!(
                "sweep '{}': resume — pretrain checkpoint {} matches, skipping",
                spec.name,
                ckpt.display()
            );
        } else {
            crate::log_info!("sweep '{}': pretraining '{}'", spec.name, pre.name);
            if let Some(parent) = ckpt.parent() {
                std::fs::create_dir_all(parent)?;
            }
            let model = Arc::clone(&models[&pre.model_dir.display().to_string()]);
            let mut exp = Experiment::prepare_with_model(pre.clone(), model)
                .context("preparing pretrain phase")?;
            exp.run().context("pretrain phase")?;
            std::fs::write(&fp_path, &pre_fp)
                .with_context(|| format!("writing {}", fp_path.display()))?;
        }
    }

    // resume pass: accept on-disk summaries whose fingerprint matches
    let n = spec.cells.len();
    let mut resumed: Vec<Option<Json>> = Vec::with_capacity(n);
    for (i, cfg) in spec.cells.iter().enumerate() {
        let mut hit = None;
        if opts.resume {
            let path = cells_dir.join(format!("{}.json", cell_file_stem(i, &cfg.name)));
            if let Ok(text) = std::fs::read_to_string(&path) {
                match json::parse(&text) {
                    Ok(doc)
                        if doc.get("config_hash").and_then(|v| v.as_str())
                            == Some(spec.cell_fingerprint_hex(cfg).as_str()) =>
                    {
                        hit = Some(doc);
                    }
                    _ => crate::log_info!(
                        "resume: cell '{}' summary stale or unreadable — re-running",
                        cfg.name
                    ),
                }
            }
        }
        resumed.push(hit);
    }

    // schedule the remaining cells
    type CellJob = (usize, ExperimentConfig, String, Arc<LoadedModel>);
    let jobs: Vec<CellJob> = spec
        .cells
        .iter()
        .enumerate()
        .filter(|(i, _)| resumed[*i].is_none())
        .map(|(i, cfg)| {
            let model =
                Arc::clone(&models[&cfg.model_dir.display().to_string()]);
            (i, cfg.clone(), spec.cell_fingerprint_hex(cfg), model)
        })
        .collect();
    let pool = !opts.sequential
        && opts.workers > 1
        && jobs.len() > 1
        && jobs.iter().all(|(_, _, _, m)| m.is_send_safe());
    let sequential_run = |jobs: Vec<CellJob>| {
        let mut rounds = RoundEngine::new();
        jobs.into_iter()
            .map(|(i, cfg, fp, model)| {
                (i, run_cell(i, cfg, fp, model, &cells_dir, &mut rounds))
            })
            .collect::<Vec<(usize, Result<CellRun>)>>()
    };
    #[cfg(not(feature = "pjrt"))]
    let results: Vec<(usize, Result<CellRun>)> = if pool {
        crate::log_info!(
            "sweep '{}': {} cells pooled over {} workers",
            spec.name,
            jobs.len(),
            opts.workers
        );
        threadpool::scope_map_chunked(
            jobs,
            opts.workers,
            RoundEngine::new,
            |_, (i, cfg, fp, model), rounds| {
                (i, run_cell(i, cfg, fp, model, &cells_dir, rounds))
            },
        )?
    } else {
        sequential_run(jobs)
    };
    #[cfg(feature = "pjrt")]
    let results: Vec<(usize, Result<CellRun>)> = {
        // PJRT models are !Send — every cell is pinned to this thread
        let _ = pool;
        sequential_run(jobs)
    };

    // assemble outcomes in grid order
    let mut fresh: BTreeMap<usize, CellRun> = BTreeMap::new();
    for (i, r) in results {
        let run = r.with_context(|| {
            format!("cell {i} ({})", spec.cells[i].name)
        })?;
        fresh.insert(i, run);
    }
    let mut outcomes = Vec::with_capacity(n);
    let mut cell_seconds: Vec<(usize, f64)> = Vec::new();
    let mut cells_resumed = 0usize;
    for (i, doc) in resumed.into_iter().enumerate() {
        let label = spec.cells[i].name.clone();
        match doc {
            Some(cell_json) => {
                cells_resumed += 1;
                outcomes.push(CellOutcome {
                    index: i,
                    label,
                    cell_json,
                    run: None,
                    resumed: true,
                });
            }
            None => {
                let (cell_json, summary, secs) =
                    fresh.remove(&i).expect("every unplanned cell ran");
                cell_seconds.push((i, secs));
                outcomes.push(CellOutcome {
                    index: i,
                    label,
                    cell_json,
                    run: Some(summary),
                    resumed: false,
                });
            }
        }
    }

    // consolidated summary (the golden artifact) + timing (non-golden)
    let doc = summaries::sweep_summary(
        &spec.name,
        spec.seed,
        outcomes.iter().map(|o| o.cell_json.clone()).collect(),
    );
    let summary_bytes = doc.to_string();
    let summary_path = spec.output_dir.join("sweep_summary.json");
    std::fs::write(&summary_path, &summary_bytes)
        .with_context(|| format!("writing {}", summary_path.display()))?;

    let wall_s = t.elapsed_s();
    let timing = json::obj(vec![
        ("sweep", json::s(&spec.name)),
        ("wall_s", json::num(wall_s)),
        ("workers", json::num(opts.workers as f64)),
        ("sequential", Json::Bool(opts.sequential || !pool)),
        ("cells_run", json::num((n - cells_resumed) as f64)),
        ("cells_resumed", json::num(cells_resumed as f64)),
        (
            "cells_per_s",
            json::num(if wall_s > 0.0 {
                (n - cells_resumed) as f64 / wall_s
            } else {
                f64::NAN
            }),
        ),
        (
            "cell_seconds",
            Json::Arr(
                cell_seconds
                    .iter()
                    .map(|&(i, s)| {
                        Json::Arr(vec![json::num(i as f64), json::num(s)])
                    })
                    .collect(),
            ),
        ),
    ]);
    std::fs::write(
        spec.output_dir.join("sweep_timing.json"),
        timing.to_string(),
    )?;

    crate::log_info!(
        "sweep '{}': {} cells ({} resumed) in {:.2}s → {}",
        spec.name,
        n,
        cells_resumed,
        wall_s,
        summary_path.display()
    );
    Ok(SweepReport {
        name: spec.name.clone(),
        summary_path,
        summary_bytes,
        cells: outcomes,
        cells_resumed,
        wall_s,
        models,
    })
}

/// Print a sweep as a paper-style table. Rows come from the deterministic
/// cell summaries, so fresh and resumed cells render identically (resumed
/// cells have no timing — their Speed column reads 0).
pub fn print_report(title: &str, report: &SweepReport) {
    let rows: Vec<RunSummary> = report
        .cells
        .iter()
        .map(|o| match &o.run {
            Some(r) => r.clone(),
            None => {
                let v = CellView(&o.cell_json);
                RunSummary {
                    label: v.label().to_string(),
                    final_wer: v.final_wer(),
                    final_loss: v.final_train_loss(),
                    param_memory_bytes: v.param_memory_bytes(),
                    memory_ratio: v.memory_ratio(),
                    comm_bytes_per_round: v.total_comm_bytes()
                        / v.rounds().max(1) as f64,
                    rounds_per_min: 0.0,
                    rounds: v.rounds(),
                }
            }
        })
        .collect();
    experiment::print_table(title, &rows);
}

/// Copy a report's consolidated summary into the goldens directory
/// (`goldens/<sweep name>.json`) — the `--bless` workflow.
pub fn bless_golden(report: &SweepReport, goldens_dir: &Path) -> Result<PathBuf> {
    std::fs::create_dir_all(goldens_dir)
        .with_context(|| format!("creating {}", goldens_dir.display()))?;
    let path = goldens_dir.join(format!("{}.json", report.name));
    std::fs::write(&path, &report.summary_bytes)
        .with_context(|| format!("writing {}", path.display()))?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SWEEP_TOML: &str = r#"
        name = "grid"
        model_dir = "native:tiny"
        rounds = 3
        seed = 9
        output_dir = "results/grid"
        workers = 1

        [fl]
        clients = 8
        clients_per_round = 4

        [sweep]
        formats = ["S1E8M23", "S1E4M14", "S1E3M7"]
        pvt = [true, false]
        fractions = [0.9]
    "#;

    #[test]
    fn grid_expands_in_stable_order() {
        let t = toml::parse(SWEEP_TOML).unwrap();
        let spec = from_table(&t).unwrap();
        assert_eq!(spec.name, "grid");
        assert_eq!(spec.seed, 9);
        // 1 baseline + 2 formats × 2 pvt × 1 fraction = 5 cells
        assert_eq!(spec.cells.len(), 5);
        assert_eq!(spec.cells[0].name, "fp32_baseline");
        assert_eq!(spec.cells[1].name, "S1E4M14_pvt_f0.9");
        assert_eq!(spec.cells[2].name, "S1E4M14_nopvt_f0.9");
        assert_eq!(spec.cells[3].name, "S1E3M7_pvt_f0.9");
        assert_eq!(spec.cells[4].name, "S1E3M7_nopvt_f0.9");
        assert!(spec.cells[0].omc.is_baseline());
        assert!(!spec.cells[1].omc.is_baseline());
        // identical expansion both times (the order is a contract)
        let again = from_table(&toml::parse(SWEEP_TOML).unwrap()).unwrap();
        let names: Vec<_> = spec.cells.iter().map(|c| &c.name).collect();
        let names2: Vec<_> = again.cells.iter().map(|c| &c.name).collect();
        assert_eq!(names, names2);
    }

    #[test]
    fn cell_seeds_derive_from_sweep_seed_and_index() {
        let t = toml::parse(SWEEP_TOML).unwrap();
        let spec = from_table(&t).unwrap();
        for (i, cell) in spec.cells.iter().enumerate() {
            assert_eq!(cell.seed, hash_seed(&[9, i as u64]), "cell {i}");
        }
        // a different sweep seed moves every cell seed
        let other = SWEEP_TOML.replace("seed = 9", "seed = 10");
        let spec2 = from_table(&toml::parse(&other).unwrap()).unwrap();
        for (a, b) in spec.cells.iter().zip(&spec2.cells) {
            assert_ne!(a.seed, b.seed);
        }
    }

    #[test]
    fn multi_axis_grids_carry_partition_and_domain_labels() {
        // [sweep] is the last section, so appending keeps the keys in it
        let toml_text = format!(
            "{SWEEP_TOML}\npartitions = [\"iid\", \"by_speaker\"]\ndomains = [0, 1]\n"
        );
        let spec = from_table(&toml::parse(&toml_text).unwrap()).unwrap();
        // 4 (partition, domain) pairs × 5 cells
        assert_eq!(spec.cells.len(), 20);
        assert!(spec.cells[0].name.ends_with("_iid_d0"));
        assert!(spec.cells[19].name.ends_with("_by_speaker_d1"));
        spec.validate().unwrap();
    }

    #[test]
    fn cohort_axis_expands_named_scenarios() {
        let toml_text =
            format!("{SWEEP_TOML}\ncohorts = [\"ideal\", \"stress\"]\n");
        let spec = from_table(&toml::parse(&toml_text).unwrap()).unwrap();
        // 2 cohorts × 5 cells
        assert_eq!(spec.cells.len(), 10);
        assert!(spec.cells[0].name.ends_with("_ideal"));
        assert!(spec.cells[0].cohort.is_ideal());
        assert!(spec.cells[5].name.ends_with("_stress"));
        assert!(!spec.cells[5].cohort.is_ideal());
        assert!(spec.cells[5].cohort.weight_by_examples);
        // unknown scenario names are rejected
        let bad = format!("{SWEEP_TOML}\ncohorts = [\"chaos\"]\n");
        assert!(from_table(&toml::parse(&bad).unwrap()).is_err());
    }

    #[test]
    fn modes_axis_expands_sync_and_async_cells() {
        let toml_text = format!(
            "{SWEEP_TOML}\nmodes = [\"sync\", \"async\"]\n"
        );
        let spec = from_table(&toml::parse(&toml_text).unwrap()).unwrap();
        // 2 modes × 5 cells
        assert_eq!(spec.cells.len(), 10);
        assert!(spec.cells[0].name.ends_with("_sync"));
        assert!(!spec.cells[0].async_cfg.enabled);
        assert!(spec.cells[5].name.ends_with("_async"));
        assert!(spec.cells[5].async_cfg.enabled);
        spec.validate().unwrap();
        // unknown mode names are rejected
        let bad = format!("{SWEEP_TOML}\nmodes = [\"warp\"]\n");
        assert!(from_table(&toml::parse(&bad).unwrap()).is_err());
        // single-mode grids keep the unsuffixed labels
        let plain = from_table(&toml::parse(SWEEP_TOML).unwrap()).unwrap();
        assert_eq!(plain.cells[0].name, "fp32_baseline");
        assert!(plain.cells.iter().all(|c| !c.async_cfg.enabled));
    }

    #[test]
    fn smoke_async_profile_covers_the_async_matrix() {
        let spec = smoke_async(42).unwrap();
        assert_eq!(spec.name, "sweep_smoke_async");
        assert_eq!(spec.cells.len(), 4);
        for c in &spec.cells {
            assert!(c.rounds <= 8, "smoke must stay CI-fast");
            assert_eq!(c.model_dir.to_str(), Some("native:tiny"));
            c.validate().unwrap();
        }
        // one sync reference cell, pinned to one worker
        let sync: Vec<_> = spec
            .cells
            .iter()
            .filter(|c| !c.async_cfg.enabled)
            .collect();
        assert_eq!(sync.len(), 1);
        assert_eq!(sync[0].workers, 1);
        // async cells exercise the pooled intra-cell path...
        assert!(spec
            .cells
            .iter()
            .filter(|c| c.async_cfg.enabled)
            .all(|c| c.workers > 1));
        // ...and cover constant + polynomial discounts and the discard path
        assert!(spec.cells.iter().any(|c| c.async_cfg.enabled
            && matches!(c.async_cfg.policy, StalenessPolicy::Constant(_))));
        assert!(spec.cells.iter().any(|c| {
            matches!(c.async_cfg.policy, StalenessPolicy::Polynomial { .. })
        }));
        assert!(spec
            .cells
            .iter()
            .any(|c| c.async_cfg.max_staleness != usize::MAX));
        // determinism of the expansion itself
        let again = smoke_async(42).unwrap();
        let names: Vec<_> = spec.cells.iter().map(|c| &c.name).collect();
        assert_eq!(names, again.cells.iter().map(|c| &c.name).collect::<Vec<_>>());
    }

    #[test]
    fn chaos_axis_expands_named_scenarios_and_forces_integrity() {
        let toml_text =
            format!("{SWEEP_TOML}\nchaos = [\"off\", \"heavy\"]\n");
        let spec = from_table(&toml::parse(&toml_text).unwrap()).unwrap();
        // 2 chaos scenarios × 5 cells
        assert_eq!(spec.cells.len(), 10);
        assert!(spec.cells[0].name.ends_with("_off"));
        assert!(spec.cells[0].chaos.is_off());
        assert!(!spec.cells[0].omc.integrity, "off keeps base integrity");
        assert!(spec.cells[5].name.ends_with("_heavy"));
        assert!(!spec.cells[5].chaos.is_off());
        // chaos cells must be able to detect the corruption they inject
        assert!(spec.cells[5].omc.integrity);
        spec.validate().unwrap();
        // unknown scenario names are rejected
        let bad = format!("{SWEEP_TOML}\nchaos = [\"cosmic\"]\n");
        assert!(from_table(&toml::parse(&bad).unwrap()).is_err());
    }

    #[test]
    fn smoke_chaos_profile_covers_the_fault_matrix() {
        let spec = smoke_chaos(7).unwrap();
        assert_eq!(spec.name, "sweep_smoke_chaos");
        assert_eq!(spec.cells.len(), 4);
        for c in &spec.cells {
            assert!(c.rounds <= 8, "smoke must stay CI-fast");
            assert_eq!(c.model_dir.to_str(), Some("native:tiny"));
            assert!(c.omc.integrity, "{}: chaos tier always frames v2", c.name);
            c.validate().unwrap();
        }
        // one clean control cell, the rest inject faults
        assert_eq!(spec.cells.iter().filter(|c| c.chaos.is_off()).count(), 1);
        // one cell trips the quarantine ladder on the first corrupt frame
        assert!(spec
            .cells
            .iter()
            .any(|c| !c.chaos.is_off() && c.chaos.quarantine_threshold == 1));
        // the async cell layers commit failures on top and runs pooled
        let async_cells: Vec<_> = spec
            .cells
            .iter()
            .filter(|c| c.async_cfg.enabled)
            .collect();
        assert_eq!(async_cells.len(), 1);
        assert!(async_cells[0].chaos.commit_failure_prob > 0.0);
        assert!(async_cells[0].workers > 1);
        // sync cells stay pinned for byte-stable fold order
        assert!(spec
            .cells
            .iter()
            .filter(|c| !c.async_cfg.enabled)
            .all(|c| c.workers == 1));
        // determinism of the expansion itself
        let again = smoke_chaos(7).unwrap();
        let names: Vec<_> = spec.cells.iter().map(|c| &c.name).collect();
        assert_eq!(
            names,
            again.cells.iter().map(|c| &c.name).collect::<Vec<_>>()
        );
    }

    #[test]
    fn delta_axis_expands_paired_cells_and_forces_integrity() {
        let toml_text = format!("{SWEEP_TOML}\ndelta = [false, true]\n");
        let spec = from_table(&toml::parse(&toml_text).unwrap()).unwrap();
        // 2 delta settings × 5 cells
        assert_eq!(spec.cells.len(), 10);
        let (verbatim, delta): (Vec<_>, Vec<_>) =
            spec.cells.iter().partition(|c| !c.delta.enabled);
        assert_eq!(verbatim.len(), 5);
        assert_eq!(delta.len(), 5);
        assert!(verbatim.iter().all(|c| c.name.ends_with("_verbatim")));
        assert!(delta.iter().all(|c| c.name.ends_with("_delta")));
        // base integrity is off, so verbatim cells stay unframed while
        // delta cells get integrity forced on (v3 frames need checksums)
        assert!(verbatim.iter().all(|c| !c.omc.integrity));
        assert!(delta.iter().all(|c| c.omc.integrity));
        spec.validate().unwrap();
        // non-bool entries are rejected
        let bad = format!("{SWEEP_TOML}\ndelta = [\"on\"]\n");
        assert!(from_table(&toml::parse(&bad).unwrap()).is_err());
        // single-setting grids keep the unsuffixed labels
        let plain = from_table(&toml::parse(SWEEP_TOML).unwrap()).unwrap();
        assert!(plain.cells.iter().all(|c| !c.delta.enabled));
        assert_eq!(plain.cells[0].name, "fp32_baseline");
    }

    #[test]
    fn smoke_delta_profile_covers_the_delta_matrix() {
        let spec = smoke_delta(7).unwrap();
        assert_eq!(spec.name, "sweep_smoke_delta");
        assert_eq!(spec.cells.len(), 5);
        for c in &spec.cells {
            assert!(c.rounds <= 8, "smoke must stay CI-fast");
            assert_eq!(c.model_dir.to_str(), Some("native:tiny"));
            assert!(c.omc.integrity, "{}: delta tier always frames v2/v3", c.name);
            c.validate().unwrap();
        }
        // the verbatim/delta sync pair shares every training knob except
        // the delta switch — the lossless A/B the CI gate relies on
        let verbatim = spec
            .cells
            .iter()
            .find(|c| !c.delta.enabled)
            .expect("one verbatim control cell");
        let paired = spec
            .cells
            .iter()
            .find(|c| {
                c.delta.enabled && !c.async_cfg.enabled && c.chaos.is_off()
            })
            .expect("one plain sync delta cell");
        assert_eq!(verbatim.rounds, paired.rounds);
        assert_eq!(verbatim.omc.format, paired.omc.format);
        assert_eq!(verbatim.workers, paired.workers);
        // the async cell exercises the snapshot-ring base path, pooled
        let async_cells: Vec<_> = spec
            .cells
            .iter()
            .filter(|c| c.async_cfg.enabled)
            .collect();
        assert_eq!(async_cells.len(), 1);
        assert!(async_cells[0].delta.enabled);
        assert!(async_cells[0].workers > 1);
        // one cell layers chaos over delta (ack ledger under retries)
        assert!(spec
            .cells
            .iter()
            .any(|c| c.delta.enabled && !c.chaos.is_off()));
        // the converged-regime cell backs the CI's nonzero-savings grep:
        // its step size sits far below the S1E4M14 dead zone
        let converged = spec
            .cells
            .iter()
            .find(|c| c.name.contains("converged"))
            .expect("one converged-regime delta cell");
        assert!(converged.delta.enabled);
        assert!(converged.lr > 0.0 && converged.lr < 1e-9);
        // determinism of the expansion itself
        let again = smoke_delta(7).unwrap();
        let names: Vec<_> = spec.cells.iter().map(|c| &c.name).collect();
        assert_eq!(
            names,
            again.cells.iter().map(|c| &c.name).collect::<Vec<_>>()
        );
    }

    #[test]
    fn fingerprint_covers_delta_knob() {
        let spec = smoke_delta(1).unwrap();
        let verbatim = &spec.cells[0];
        let delta = &spec.cells[1];
        assert_ne!(fingerprint_hex(verbatim), fingerprint_hex(delta));
        // flipping the switch alone moves the hash — a resumed verbatim
        // summary must not satisfy a delta cell (labels and derived seeds
        // also differ between the two, so compare against the same cell)
        let mut c = verbatim.clone();
        c.delta.enabled = true;
        assert_ne!(fingerprint_hex(&c), fingerprint_hex(verbatim));
    }

    #[test]
    fn sparse_axis_expands_named_scenarios_and_forces_integrity() {
        let toml_text = format!("{SWEEP_TOML}\nsparse = [\"off\", \"topk\"]\n");
        let spec = from_table(&toml::parse(&toml_text).unwrap()).unwrap();
        // 2 sparse scenarios × 5 cells
        assert_eq!(spec.cells.len(), 10);
        let (dense, topk): (Vec<_>, Vec<_>) =
            spec.cells.iter().partition(|c| !c.sparse.enabled);
        assert_eq!(dense.len(), 5);
        assert_eq!(topk.len(), 5);
        assert!(dense.iter().all(|c| c.name.ends_with("_off")));
        assert!(topk.iter().all(|c| c.name.ends_with("_topk")));
        for c in &topk {
            assert_eq!(c.sparse.mode, SparseMode::TopK);
            assert!((c.sparse.fraction - 0.25).abs() < 1e-12);
        }
        // base integrity is off, so dense cells stay unframed while
        // sparse cells get integrity forced on (sparse records only
        // exist on the checksummed v2/v3 layouts)
        assert!(dense.iter().all(|c| !c.omc.integrity));
        assert!(topk.iter().all(|c| c.omc.integrity));
        spec.validate().unwrap();
        // the randk scenario binds the other selection rule
        let rk = format!("{SWEEP_TOML}\nsparse = [\"randk\"]\n");
        let spec = from_table(&toml::parse(&rk).unwrap()).unwrap();
        assert!(spec
            .cells
            .iter()
            .all(|c| c.sparse.enabled && c.sparse.mode == SparseMode::RandK));
        // unknown scenarios are rejected
        let bad = format!("{SWEEP_TOML}\nsparse = [\"magic\"]\n");
        assert!(from_table(&toml::parse(&bad).unwrap()).is_err());
        // single-scenario grids keep the unsuffixed labels and stay off
        let plain = from_table(&toml::parse(SWEEP_TOML).unwrap()).unwrap();
        assert!(plain.cells.iter().all(|c| !c.sparse.enabled));
        assert_eq!(plain.cells[0].name, "fp32_baseline");
    }

    #[test]
    fn smoke_sparse_profile_covers_the_sparse_matrix() {
        let spec = smoke_sparse(7).unwrap();
        assert_eq!(spec.name, "sweep_smoke_sparse");
        assert_eq!(spec.cells.len(), 6);
        for c in &spec.cells {
            assert!(c.rounds <= 8, "smoke must stay CI-fast");
            assert_eq!(c.model_dir.to_str(), Some("native:tiny"));
            assert!(
                c.omc.integrity,
                "{}: sparse tier always frames v2/v3",
                c.name
            );
            c.validate().unwrap();
        }
        // the dense/top-k sync pair shares every training knob except the
        // sparse switch — the byte-count A/B the CI gate relies on
        let dense = spec
            .cells
            .iter()
            .find(|c| !c.sparse.enabled)
            .expect("one dense control cell");
        let paired = spec
            .cells
            .iter()
            .find(|c| {
                c.sparse.enabled
                    && c.sparse.mode == SparseMode::TopK
                    && !c.async_cfg.enabled
                    && c.omc.fraction >= 1.0
                    && c.lr > 1e-9
            })
            .expect("one plain sync top-k cell");
        assert_eq!(dense.rounds, paired.rounds);
        assert_eq!(dense.omc.format, paired.omc.format);
        assert_eq!(dense.workers, paired.workers);
        assert_eq!(dense.lr, paired.lr);
        // one cell A/Bs the selection rule at the same budget
        let randk = spec
            .cells
            .iter()
            .find(|c| c.sparse.mode == SparseMode::RandK && c.sparse.enabled)
            .expect("one rand-k cell");
        assert_eq!(randk.sparse.fraction, paired.sparse.fraction);
        // the async cell exercises the ring-snapshot sparse-base fold,
        // pooled — task-order residual commits keep it deterministic
        let async_cells: Vec<_> = spec
            .cells
            .iter()
            .filter(|c| c.async_cfg.enabled)
            .collect();
        assert_eq!(async_cells.len(), 1);
        assert!(async_cells[0].sparse.enabled);
        assert!(async_cells[0].workers > 1);
        // one cell composes top-k with partial per-parameter selection
        assert!(spec
            .cells
            .iter()
            .any(|c| c.sparse.enabled && c.omc.fraction < 1.0));
        // the converged-regime cell: step size below the dead zone
        let converged = spec
            .cells
            .iter()
            .find(|c| c.name.contains("converged"))
            .expect("one converged-regime sparse cell");
        assert!(converged.sparse.enabled);
        assert!(converged.lr > 0.0 && converged.lr < 1e-9);
        // determinism of the expansion itself
        let again = smoke_sparse(7).unwrap();
        let names: Vec<_> = spec.cells.iter().map(|c| &c.name).collect();
        assert_eq!(
            names,
            again.cells.iter().map(|c| &c.name).collect::<Vec<_>>()
        );
    }

    #[test]
    fn fingerprint_covers_sparse_knobs() {
        let spec = smoke_sparse(1).unwrap();
        let dense = &spec.cells[0];
        let topk = &spec.cells[1];
        assert_ne!(fingerprint_hex(dense), fingerprint_hex(topk));
        // every sparse knob moves the hash — a resumed dense summary must
        // not satisfy a sparse cell, and mode/fraction changes re-run
        let base = fingerprint_hex(topk);
        let mut c = topk.clone();
        c.sparse.enabled = false;
        assert_ne!(base, fingerprint_hex(&c));
        let mut c = topk.clone();
        c.sparse.mode = SparseMode::RandK;
        assert_ne!(base, fingerprint_hex(&c));
        let mut c = topk.clone();
        c.sparse.fraction = 0.5;
        assert_ne!(base, fingerprint_hex(&c));
    }

    #[test]
    fn fingerprint_covers_integrity_and_chaos_knobs() {
        let spec = smoke_chaos(1).unwrap();
        let clean = &spec.cells[0];
        let stormy = &spec.cells[1];
        assert_ne!(fingerprint_hex(clean), fingerprint_hex(stormy));
        // integrity alone moves the hash — a resumed CRC-off summary must
        // not satisfy a CRC-on cell
        let base = fingerprint_hex(clean);
        let mut c = clean.clone();
        c.omc.integrity = false;
        assert_ne!(base, fingerprint_hex(&c));
        // every chaos knob moves the hash
        let base = fingerprint_hex(stormy);
        let mut c = stormy.clone();
        c.chaos.bitflip_prob += 0.01;
        assert_ne!(base, fingerprint_hex(&c));
        let mut c = stormy.clone();
        c.chaos.max_retries += 1;
        assert_ne!(base, fingerprint_hex(&c));
        let mut c = stormy.clone();
        c.chaos.quarantine_threshold += 1;
        assert_ne!(base, fingerprint_hex(&c));
        let mut c = stormy.clone();
        c.chaos.backoff_base_s *= 2.0;
        assert_ne!(base, fingerprint_hex(&c));
    }

    #[test]
    fn fingerprint_covers_async_knobs() {
        let spec = smoke_async(1).unwrap();
        let sync_cell = &spec.cells[0];
        let async_cell = &spec.cells[1];
        assert_ne!(fingerprint_hex(sync_cell), fingerprint_hex(async_cell));
        // every async knob moves the hash — resume must re-run on change
        let base = fingerprint_hex(async_cell);
        let mut c = async_cell.clone();
        c.async_cfg.buffer_k = 3;
        assert_ne!(base, fingerprint_hex(&c));
        let mut c = async_cell.clone();
        c.async_cfg.policy = StalenessPolicy::Polynomial { alpha: 0.25 };
        assert_ne!(base, fingerprint_hex(&c));
        let mut c = async_cell.clone();
        c.async_cfg.max_staleness = 7;
        assert_ne!(base, fingerprint_hex(&c));
        let mut c = async_cell.clone();
        c.async_cfg.snapshot_ring = 9;
        assert_ne!(base, fingerprint_hex(&c));
    }

    #[test]
    fn fingerprint_is_stable_and_sensitive() {
        let t = toml::parse(SWEEP_TOML).unwrap();
        let spec = from_table(&t).unwrap();
        let a = fingerprint_hex(&spec.cells[1]);
        assert_eq!(a.len(), 16);
        assert_eq!(a, fingerprint_hex(&spec.cells[1]));
        // any semantic change moves the hash
        let mut changed = spec.cells[1].clone();
        changed.rounds += 1;
        assert_ne!(a, fingerprint_hex(&changed));
        let mut changed = spec.cells[1].clone();
        changed.omc.fraction = 0.8;
        assert_ne!(a, fingerprint_hex(&changed));
        let mut changed = spec.cells[1].clone();
        changed.seed ^= 1;
        assert_ne!(a, fingerprint_hex(&changed));
        // sibling cells differ
        assert_ne!(a, fingerprint_hex(&spec.cells[2]));
    }

    #[test]
    fn pretrain_phase_is_part_of_cell_fingerprints() {
        let mut spec = smoke(1).unwrap();
        let plain = spec.cell_fingerprint_hex(&spec.cells[0]);
        // no pretrain phase → identical to the standalone fingerprint
        assert_eq!(plain, fingerprint_hex(&spec.cells[0]));
        let mut pre = spec.cells[0].clone();
        pre.save_to = Some(PathBuf::from("pre.bin"));
        spec.pretrain = Some(pre);
        let with_pre = spec.cell_fingerprint_hex(&spec.cells[0]);
        assert_ne!(plain, with_pre);
        // changing the pretrain invalidates every dependent cell summary
        spec.pretrain.as_mut().unwrap().rounds += 1;
        assert_ne!(with_pre, spec.cell_fingerprint_hex(&spec.cells[0]));
    }

    #[test]
    fn file_stems_are_sanitized_and_unique() {
        assert_eq!(
            cell_file_stem(3, "FP32 (S1E8M23)"),
            "c03_FP32__S1E8M23_"
        );
        let spec = smoke(42).unwrap();
        let stems: std::collections::BTreeSet<_> = spec
            .cells
            .iter()
            .enumerate()
            .map(|(i, c)| cell_file_stem(i, &c.name))
            .collect();
        assert_eq!(stems.len(), spec.cells.len());
    }

    #[test]
    fn smoke_profile_is_small_and_pinned() {
        let spec = smoke(42).unwrap();
        assert_eq!(spec.name, "sweep_smoke");
        assert_eq!(spec.cells.len(), 5);
        for c in &spec.cells {
            assert_eq!(c.workers, 1, "{}: intra-cell workers must be pinned", c.name);
            assert!(c.rounds <= 8, "smoke must stay CI-fast");
            assert_eq!(c.model_dir.to_str(), Some("native:tiny"));
        }
        // covers baseline, pvt on/off, and an APQ cell
        assert!(spec.cells.iter().any(|c| c.omc.is_baseline()));
        assert!(spec.cells.iter().any(|c| !c.omc.use_pvt && !c.omc.is_baseline()));
        assert!(spec.cells.iter().any(|c| c.omc.fraction == 1.0));
    }

    #[test]
    fn validate_rejects_duplicate_labels_and_empty_sweeps() {
        let empty = SweepSpec::new("x", 1, Path::new("results/x"));
        assert!(empty.validate().is_err());
        let mut spec = smoke(1).unwrap();
        let dup = spec.cells[0].clone();
        spec.cells[1] = dup;
        // same label at a different index is fine (stem embeds the index)…
        spec.validate().unwrap();
        // …but a pretrain phase without save_to is not
        let mut pre = spec.cells[0].clone();
        pre.save_to = None;
        spec.pretrain = Some(pre);
        assert!(spec.validate().is_err());
    }

    #[test]
    fn toml_requires_formats() {
        let t = toml::parse("name = \"x\"\n").unwrap();
        assert!(from_table(&t).is_err());
    }

    #[test]
    fn example_sweep_config_parses() {
        // the committed example file must stay expandable
        let path = Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("configs/sweep_smoke.toml");
        let spec = from_toml_file(&path).unwrap();
        assert_eq!(spec.cells.len(), 5);
        assert!(spec.cells.iter().all(|c| c.workers == 1));
        assert!(spec.cells.iter().all(|c| c.model_dir.to_str()
            == Some("native:tiny")));
    }

    #[test]
    fn example_chaos_sweep_config_parses() {
        let path = Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("configs/sweep_chaos.toml");
        let spec = from_toml_file(&path).unwrap();
        // 2 modes × 1 format = 2 cells, no baseline (formats has no FP32)
        assert_eq!(spec.cells.len(), 2);
        for c in &spec.cells {
            assert!(c.omc.integrity, "{}", c.name);
            assert!(!c.chaos.is_off(), "{}", c.name);
            assert!(c.chaos.bitflip_prob > 0.0);
            assert_eq!(c.chaos.max_retries, 2);
            assert_eq!(c.chaos.quarantine_threshold, 3);
            c.validate().unwrap();
        }
        assert!(spec.cells.iter().any(|c| c.async_cfg.enabled));
        assert!(spec.cells.iter().any(|c| !c.async_cfg.enabled));
    }

    #[test]
    fn example_delta_sweep_config_parses() {
        let path = Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("configs/sweep_delta.toml");
        let spec = from_toml_file(&path).unwrap();
        // 2 modes × 1 format × 2 delta settings = 4 cells
        assert_eq!(spec.cells.len(), 4);
        let (verbatim, delta): (Vec<_>, Vec<_>) =
            spec.cells.iter().partition(|c| !c.delta.enabled);
        assert_eq!(verbatim.len(), 2);
        assert_eq!(delta.len(), 2);
        for c in &spec.cells {
            // the example keeps integrity on globally so the
            // verbatim/delta A/B shares one wire format
            assert!(c.omc.integrity, "{}", c.name);
            c.validate().unwrap();
        }
        for c in &delta {
            assert!(c.name.ends_with("_delta"), "{}", c.name);
        }
        for c in &verbatim {
            assert!(c.name.ends_with("_verbatim"), "{}", c.name);
        }
        assert!(delta.iter().any(|c| c.async_cfg.enabled));
        assert!(delta.iter().any(|c| !c.async_cfg.enabled));
    }

    #[test]
    fn example_sparse_sweep_config_parses() {
        let path = Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("configs/sweep_sparse.toml");
        let spec = from_toml_file(&path).unwrap();
        // 2 modes × 1 format × 2 sparse scenarios = 4 cells
        assert_eq!(spec.cells.len(), 4);
        let (dense, topk): (Vec<_>, Vec<_>) =
            spec.cells.iter().partition(|c| !c.sparse.enabled);
        assert_eq!(dense.len(), 2);
        assert_eq!(topk.len(), 2);
        for c in &spec.cells {
            // the example keeps integrity on globally so the dense/top-k
            // A/B shares one wire format
            assert!(c.omc.integrity, "{}", c.name);
            c.validate().unwrap();
        }
        for c in &topk {
            assert!(c.name.ends_with("_topk"), "{}", c.name);
            assert_eq!(c.sparse.mode, SparseMode::TopK);
        }
        for c in &dense {
            assert!(c.name.ends_with("_off"), "{}", c.name);
        }
        assert!(topk.iter().any(|c| c.async_cfg.enabled));
        assert!(topk.iter().any(|c| !c.async_cfg.enabled));
    }

    #[test]
    fn example_async_sweep_config_parses() {
        let path = Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("configs/sweep_async.toml");
        let spec = from_toml_file(&path).unwrap();
        // 2 modes × (1 baseline + 1 format) = 4 cells
        assert_eq!(spec.cells.len(), 4);
        let (sync, async_): (Vec<_>, Vec<_>) = spec
            .cells
            .iter()
            .partition(|c| !c.async_cfg.enabled);
        assert_eq!(sync.len(), 2);
        assert_eq!(async_.len(), 2);
        for c in &async_ {
            assert!(c.name.ends_with("_async"), "{}", c.name);
            assert_eq!(c.async_cfg.buffer_k, 2);
            assert_eq!(c.async_cfg.max_staleness, 3);
            assert_eq!(
                c.async_cfg.policy,
                StalenessPolicy::Polynomial { alpha: 0.5 }
            );
        }
        for c in &sync {
            assert!(c.name.ends_with("_sync"), "{}", c.name);
        }
    }

    #[test]
    fn population_axis_expands_named_scenarios() {
        let toml_text =
            format!("{SWEEP_TOML}\npopulation = [\"off\", \"nation\"]\n");
        let spec = from_table(&toml::parse(&toml_text).unwrap()).unwrap();
        // 2 population scenarios × 5 cells
        assert_eq!(spec.cells.len(), 10);
        assert!(spec.cells[0].name.ends_with("_off"));
        assert!(!spec.cells[0].population.enabled);
        assert!(spec.cells[1].name.ends_with("_nation"));
        assert!(spec.cells[1].population.enabled);
        assert_eq!(spec.cells[1].population.registered, 1_000_000);
        assert_eq!(spec.cells[1].population.edges, 4);
        spec.validate().unwrap();
        // unknown scenario names are rejected
        let bad = format!("{SWEEP_TOML}\npopulation = [\"galaxy\"]\n");
        assert!(from_table(&toml::parse(&bad).unwrap()).is_err());
        // single-scenario grids keep the unsuffixed labels and stay off
        let plain = from_table(&toml::parse(SWEEP_TOML).unwrap()).unwrap();
        assert_eq!(plain.cells[0].name, "fp32_baseline");
        assert!(plain.cells.iter().all(|c| !c.population.enabled));
    }

    #[test]
    fn smoke_scale_profile_covers_the_population_matrix() {
        let spec = smoke_scale(42).unwrap();
        assert_eq!(spec.name, "sweep_smoke_scale");
        assert_eq!(spec.cells.len(), 5);
        for c in &spec.cells {
            assert!(c.rounds <= 8, "smoke must stay CI-fast");
            assert_eq!(c.model_dir.to_str(), Some("native:tiny"));
            assert_eq!(c.workers, 1, "{}: edge fold order must be pinned", c.name);
            assert!(c.population.enabled, "{}", c.name);
            assert_eq!(c.population.registered, 1_000_000, "{}", c.name);
            // aggressive scenario knobs keep the CI rejection greps alive
            assert!(c.population.churn_rate > 0.0);
            assert!(c.population.wave_amplitude > 0.0);
            c.validate().unwrap();
        }
        // one single-edge cell (bit-exact vs flat), the rest multi-edge
        assert_eq!(
            spec.cells.iter().filter(|c| c.population.edges == 1).count(),
            1
        );
        assert!(spec.cells.iter().any(|c| c.population.edges > 1));
        // one cell exercises device-class skew through a lossy cohort
        assert!(spec.cells.iter().any(|c| !c.cohort.is_ideal()));
        // one cell runs the integrity+delta edge hop
        assert!(spec
            .cells
            .iter()
            .any(|c| c.delta.enabled && c.omc.integrity));
        // one cell layers fault injection on the edge topology
        let stormy: Vec<_> =
            spec.cells.iter().filter(|c| !c.chaos.is_off()).collect();
        assert_eq!(stormy.len(), 1);
        assert!(stormy[0].omc.integrity);
        // determinism of the expansion itself
        let again = smoke_scale(42).unwrap();
        let names: Vec<_> = spec.cells.iter().map(|c| &c.name).collect();
        assert_eq!(
            names,
            again.cells.iter().map(|c| &c.name).collect::<Vec<_>>()
        );
    }

    #[test]
    fn fingerprint_covers_population_knobs() {
        let spec = smoke_scale(1).unwrap();
        let cell = &spec.cells[1]; // edges4
        let base = fingerprint_hex(cell);
        let mut c = cell.clone();
        c.population.enabled = false;
        assert_ne!(base, fingerprint_hex(&c));
        let mut c = cell.clone();
        c.population.registered *= 10;
        assert_ne!(base, fingerprint_hex(&c));
        let mut c = cell.clone();
        c.population.edges += 1;
        assert_ne!(base, fingerprint_hex(&c));
        let mut c = cell.clone();
        c.population.churn_rate += 0.01;
        assert_ne!(base, fingerprint_hex(&c));
        let mut c = cell.clone();
        c.population.churn_period += 1;
        assert_ne!(base, fingerprint_hex(&c));
        let mut c = cell.clone();
        c.population.wave_amplitude += 0.01;
        assert_ne!(base, fingerprint_hex(&c));
        let mut c = cell.clone();
        c.population.wave_period += 1;
        assert_ne!(base, fingerprint_hex(&c));
    }

    #[test]
    fn example_scale_sweep_config_parses() {
        let path = Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("configs/sweep_scale.toml");
        let spec = from_toml_file(&path).unwrap();
        // 2 population scenarios × 1 format = 2 cells (no FP32 baseline)
        assert_eq!(spec.cells.len(), 2);
        let on: Vec<_> = spec
            .cells
            .iter()
            .filter(|c| c.population.enabled)
            .collect();
        assert_eq!(on.len(), 1);
        assert!(on[0].name.ends_with("_nation"), "{}", on[0].name);
        assert_eq!(on[0].population.registered, 1_000_000);
        for c in &spec.cells {
            c.validate().unwrap();
        }
    }
}
