//! The experiment coordinator — ties config, runtime, data and FL together
//! and drives whole federated runs (the L3 entry point).

pub mod config;
pub mod experiment;
pub mod params_io;
pub mod presets;

pub use config::ExperimentConfig;
pub use experiment::Experiment;
