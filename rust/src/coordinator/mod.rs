//! The experiment coordinator — ties config, runtime, data and FL together
//! and drives whole federated runs and grid sweeps (the L3 entry point).

pub mod config;
pub mod experiment;
pub mod params_io;
pub mod presets;
pub mod sweep;

pub use config::ExperimentConfig;
pub use experiment::Experiment;
pub use sweep::{SweepOptions, SweepSpec};
