//! Shared scaffolding for the table/figure reproduction drivers in
//! `examples/` (DESIGN.md §5): standard experiment shapes, format ladders,
//! and output conventions.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::config::{ExperimentConfig, OmcConfig, SparseConfig};
use crate::coordinator::experiment::{Experiment, RunSummary};
use crate::coordinator::sweep::SweepSpec;
use crate::data::partition::Partition;
use crate::fl::async_round::{AsyncConfig, StalenessPolicy};
use crate::fl::cohort::CohortConfig;
use crate::fl::population::PopulationConfig;
use crate::fl::serve::ServeConfig;
use crate::metrics::recorder::Recorder;
use crate::omc::sparse::SparseMode;
use crate::runtime::engine::{Engine, LoadedModel};

/// The paper's experimental scale, shrunk to this testbed. All examples use
/// these numbers unless a flag overrides them (paper: 128 clients, 1 local
/// step, batch 16; here: 32 clients, 8/round — the batch size is baked into
/// the artifact).
pub struct Scale {
    pub rounds: usize,
    pub num_clients: usize,
    pub clients_per_round: usize,
    pub lr: f32,
    pub seed: u64,
}

impl Scale {
    pub fn from_flags(rounds: usize, seed: u64) -> Self {
        Self {
            rounds,
            num_clients: 32,
            clients_per_round: 8,
            lr: 0.1,
            seed,
        }
    }
}

/// Build the standard experiment config used by the table drivers.
pub fn experiment(
    label: &str,
    model_dir: &str,
    scale: &Scale,
    partition: Partition,
    domain: u64,
    omc: OmcConfig,
    out_dir: &str,
) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default_with(label, &PathBuf::from(model_dir));
    cfg.rounds = scale.rounds;
    cfg.num_clients = scale.num_clients;
    cfg.clients_per_round = scale.clients_per_round;
    cfg.lr = scale.lr;
    cfg.seed = scale.seed;
    cfg.partition = partition;
    cfg.domain = domain;
    cfg.eval_every = (scale.rounds / 10).clamp(1, 20);
    cfg.eval_batches = 8;
    cfg.output_dir = PathBuf::from(out_dir);
    cfg.omc = omc;
    cfg
}

/// Run one experiment variant against a shared compiled model, write its
/// per-round log, and return the summary row.
pub fn run_variant(
    model: &Arc<LoadedModel>,
    cfg: ExperimentConfig,
) -> Result<(Recorder, RunSummary)> {
    let out_dir = cfg.output_dir.clone();
    let mut exp = Experiment::prepare_with_model(cfg, Arc::clone(model))?;
    let (rec, summary) = exp.run()?;
    rec.write(&out_dir)?;
    Ok((rec, summary))
}

/// Bind a model directory once for a whole example (shared compile cache).
pub fn bind_model(engine: &Engine, model_dir: &str) -> Result<Arc<LoadedModel>> {
    Ok(Arc::new(engine.load_model(std::path::Path::new(model_dir))?))
}

/// The ablation ladder of Table 4, in presentation order.
pub fn table4_ladder(format: &str) -> Result<Vec<(String, OmcConfig)>> {
    let fmt = format.parse()?;
    Ok(vec![
        ("FP32 baseline".into(), OmcConfig::fp32_baseline()),
        (
            format!("quant only ({format})"),
            OmcConfig {
                format: fmt,
                use_pvt: false,
                weights_only: false,
                fraction: 1.0,
                integrity: false,
            },
        ),
        (
            "+ per-variable transform".into(),
            OmcConfig {
                format: fmt,
                use_pvt: true,
                weights_only: false,
                fraction: 1.0,
                integrity: false,
            },
        ),
        (
            "+ weights only".into(),
            OmcConfig {
                format: fmt,
                use_pvt: true,
                weights_only: true,
                fraction: 1.0,
                integrity: false,
            },
        ),
        (
            "+ 90% weights (full OMC)".into(),
            OmcConfig {
                format: fmt,
                use_pvt: true,
                weights_only: true,
                fraction: 0.9,
                integrity: false,
            },
        ),
    ])
}

/// The cohort-failure scenario ladder driven by `examples/cohort_stress.rs`
/// and the stress rows of `bench_round`: from the tables' ideal cohort to a
/// production-shaped one (dropout + stragglers + example-weighted FedAvg).
pub fn cohort_ladder() -> Vec<(String, CohortConfig)> {
    vec![
        ("ideal cohort".into(), CohortConfig::ideal()),
        (
            "10% dropout".into(),
            CohortConfig {
                dropout_prob: 0.1,
                ..CohortConfig::ideal()
            },
        ),
        (
            "stragglers (mean 2s, deadline 4s)".into(),
            CohortConfig {
                straggler_mean_s: 2.0,
                deadline_s: 4.0,
                ..CohortConfig::ideal()
            },
        ),
        (
            "dropout + stragglers, weighted".into(),
            CohortConfig {
                dropout_prob: 0.1,
                straggler_mean_s: 2.0,
                deadline_s: 4.0,
                weight_by_examples: true,
            },
        ),
    ]
}

/// The buffered-async scenario ladder driven by `examples/async_stress.rs`
/// and `benches/bench_async.rs`: from synchronous rounds (the tables'
/// setting) through fully-buffered async (first commit ≡ one sync round)
/// down to small aggressive buffers with polynomial staleness discounts
/// and a staleness cutoff. `concurrency`/`buffer_k` of `0` resolve to the
/// experiment's `clients_per_round` at run time, so the ladder fits any
/// cohort scale.
pub fn async_ladder() -> Vec<(String, AsyncConfig)> {
    let on = AsyncConfig {
        enabled: true,
        snapshot_ring: 4,
        ..AsyncConfig::default()
    };
    let poly = StalenessPolicy::Polynomial { alpha: 0.5 };
    vec![
        ("sync rounds (reference)".into(), AsyncConfig::default()),
        ("async K=cohort, constant".into(), on),
        (
            "async K=4, poly(0.5)".into(),
            AsyncConfig {
                buffer_k: 4,
                policy: poly,
                ..on
            },
        ),
        (
            "async K=2, poly(0.5)".into(),
            AsyncConfig {
                buffer_k: 2,
                policy: poly,
                ..on
            },
        ),
        (
            "async K=2, poly(0.5), max_staleness=2".into(),
            AsyncConfig {
                buffer_k: 2,
                policy: poly,
                max_staleness: 2,
                ..on
            },
        ),
    ]
}

/// The fleet-scale scenario ladder driven by `examples/scale_stress.rs`
/// and `benches/bench_population.rs`: from the tables' enumerable fleet
/// (population mode off) through a flat-root 10^5 fleet up to 10^7
/// registered clients behind eight edge aggregators with churn and a deep
/// diurnal availability wave. Peak memory stays O(active cohort) at every
/// rung — per-client state derives lazily from `(seed, cid)` and is never
/// materialized (docs/SCALE.md).
pub fn scale_ladder() -> Vec<(String, PopulationConfig)> {
    vec![
        ("enumerable fleet (reference)".into(), PopulationConfig::off()),
        (
            "100k registered, flat root".into(),
            PopulationConfig {
                enabled: true,
                registered: 100_000,
                edges: 1,
                churn_rate: 0.0,
                wave_amplitude: 0.0,
                ..PopulationConfig::off()
            },
        ),
        (
            "1M registered, 4 edges".into(),
            PopulationConfig {
                enabled: true,
                registered: 1_000_000,
                edges: 4,
                churn_rate: 0.2,
                churn_period: 2,
                wave_amplitude: 0.3,
                wave_period: 6,
            },
        ),
        (
            "10M registered, 8 edges, churn + wave".into(),
            PopulationConfig {
                enabled: true,
                registered: 10_000_000,
                edges: 8,
                churn_rate: 0.4,
                churn_period: 2,
                wave_amplitude: 0.6,
                wave_period: 4,
            },
        ),
    ]
}

/// The uplink-sparsification scenario ladder driven by
/// `benches/bench_sparse.rs` and the sparse CI tier: from the dense
/// reference (sparsification off) through progressively tighter top-k
/// budgets down to a rand-k control arm at the tightest budget. Every
/// rung keeps error feedback on — the unsent mass banks into a
/// per-client residual keyed `(seed, cid)` and folds into the next
/// round's update before selection (docs/COMPRESSION.md), so even the
/// 1% rungs converge instead of starving coordinates.
pub fn sparse_ladder() -> Vec<(String, SparseConfig)> {
    let topk = |fraction| SparseConfig {
        enabled: true,
        mode: SparseMode::TopK,
        fraction,
    };
    vec![
        ("dense uplink (reference)".into(), SparseConfig::default()),
        ("top-k 25%".into(), topk(0.25)),
        ("top-k 10%".into(), topk(0.10)),
        ("top-k 1%".into(), topk(0.01)),
        (
            "rand-k 1% (control)".into(),
            SparseConfig {
                enabled: true,
                mode: SparseMode::RandK,
                fraction: 0.01,
            },
        ),
    ]
}

/// The sustained-service scenario ladder driven by
/// `examples/serve_stress.rs` and `benches/bench_serve.rs`: from a single
/// worker (the concurrency floor — scheduling effects only) through the
/// machine's full worker count, the arena-off A/B control arm, and an
/// open-loop paced arrival stream. Every rung commits bit-identical
/// parameters (`docs/SERVING.md`); only the wall-clock numbers move.
pub fn serve_ladder() -> Vec<(String, ServeConfig)> {
    let on = ServeConfig {
        enabled: true,
        ..ServeConfig::default()
    };
    vec![
        (
            "1 worker, arena".into(),
            ServeConfig { workers: 1, ..on },
        ),
        ("full workers, arena".into(), on),
        (
            "full workers, no arena (A/B)".into(),
            ServeConfig { arena: false, ..on },
        ),
        (
            "full workers, paced 200/s".into(),
            ServeConfig { rate: 200.0, ..on },
        ),
    ]
}

// ---- paper sweep grids ---------------------------------------------------
//
// Each table/figure of the paper as a ready-to-run `SweepSpec`; the
// `examples/` drivers are thin wrappers over these. Cell seeds are derived
// per-cell by `SweepSpec::finalize` from `(scale.seed, cell index)`.

/// Shared pretraining phase for the adaptation grids (source domain, FP32,
/// checkpoint under the grid's output dir).
fn pretrain_phase(
    model_dir: &str,
    rounds: usize,
    seed: u64,
    out: &str,
) -> (ExperimentConfig, PathBuf) {
    let ckpt = PathBuf::from(out).join("pretrained.bin");
    let mut pre = experiment(
        "pretrain_domain0",
        model_dir,
        &Scale::from_flags(rounds, seed),
        Partition::Iid,
        0,
        OmcConfig::fp32_baseline(),
        out,
    );
    pre.save_to = Some(ckpt.clone());
    (pre, ckpt)
}

/// Table 1 — FP32 vs OMC S1E4M14, IID, from scratch.
pub fn table1_grid(model_dir: &str, scale: &Scale) -> Result<SweepSpec> {
    let out = "results/table1";
    let mut spec = SweepSpec::new("table1", scale.seed, Path::new(out));
    for (label, omc) in [
        ("FP32 (S1E8M23)", OmcConfig::fp32_baseline()),
        ("OMC (S1E4M14)", OmcConfig::paper("S1E4M14".parse()?)),
    ] {
        spec.cells
            .push(experiment(label, model_dir, scale, Partition::Iid, 0, omc, out));
    }
    spec.finalize()
}

/// Table 2 — domain adaptation (FP32 / S1E3M7 / S1E2M3) from a shared
/// source-domain checkpoint.
pub fn table2_grid(
    model_dir: &str,
    scale: &Scale,
    pretrain_rounds: usize,
) -> Result<SweepSpec> {
    let out = "results/table2";
    let mut spec = SweepSpec::new("table2", scale.seed, Path::new(out));
    let (pre, ckpt) = pretrain_phase(model_dir, pretrain_rounds, scale.seed, out);
    spec.pretrain = Some(pre);
    for (label, omc) in [
        ("FP32 (S1E8M23)", OmcConfig::fp32_baseline()),
        ("OMC (S1E3M7)", OmcConfig::paper("S1E3M7".parse()?)),
        ("OMC (S1E2M3)", OmcConfig::paper("S1E2M3".parse()?)),
    ] {
        let mut cfg =
            experiment(label, model_dir, scale, Partition::Iid, 1, omc, out);
        cfg.init_from = Some(ckpt.clone());
        cfg.lr = 0.05; // adaptation uses a lower lr, as finetuning does
        spec.cells.push(cfg);
    }
    spec.finalize()
}

/// Table 3 — FP32 vs OMC S1E4M14 on the non-IID (by-speaker) partition.
pub fn table3_grid(model_dir: &str, scale: &Scale) -> Result<SweepSpec> {
    let out = "results/table3";
    let mut spec = SweepSpec::new("table3", scale.seed, Path::new(out));
    for (label, omc) in [
        ("FP32 (S1E8M23)", OmcConfig::fp32_baseline()),
        ("OMC (S1E4M14)", OmcConfig::paper("S1E4M14".parse()?)),
    ] {
        spec.cells.push(experiment(
            label,
            model_dir,
            scale,
            Partition::BySpeaker,
            0,
            omc,
            out,
        ));
    }
    spec.finalize()
}

/// Table 4 — the ablation ladder at `format` on the adaptation workload.
pub fn table4_grid(
    model_dir: &str,
    scale: &Scale,
    pretrain_rounds: usize,
    format: &str,
) -> Result<SweepSpec> {
    let out = "results/table4";
    let mut spec = SweepSpec::new("table4", scale.seed, Path::new(out));
    let (pre, ckpt) = pretrain_phase(model_dir, pretrain_rounds, scale.seed, out);
    spec.pretrain = Some(pre);
    for (label, omc) in table4_ladder(format)? {
        let mut cfg =
            experiment(&label, model_dir, scale, Partition::Iid, 1, omc, out);
        cfg.init_from = Some(ckpt.clone());
        cfg.lr = 0.05;
        spec.cells.push(cfg);
    }
    spec.finalize()
}

/// Fig. 3 — with vs without the per-variable transform, from scratch, at a
/// coarse format (dense eval cadence for the curves).
pub fn fig3_grid(model_dir: &str, scale: &Scale, format: &str) -> Result<SweepSpec> {
    let out = "results/fig3";
    let fmt = format.parse()?;
    let mut spec = SweepSpec::new("fig3", scale.seed, Path::new(out));
    for (label, use_pvt) in [("with_pvt", true), ("without_pvt", false)] {
        let omc = OmcConfig {
            format: fmt,
            use_pvt,
            weights_only: false, // quantize everything: the unstable regime
            fraction: 1.0,
            integrity: false,
        };
        let mut cfg =
            experiment(label, model_dir, scale, Partition::Iid, 0, omc, out);
        cfg.eval_every = (scale.rounds / 25).max(1); // dense curve
        spec.cells.push(cfg);
    }
    spec.finalize()
}

/// Fig. 4 — PPQ at 11 bits (90% of weights) vs APQ at 13 bits, on the
/// adaptation workload.
pub fn fig4_grid(
    model_dir: &str,
    scale: &Scale,
    pretrain_rounds: usize,
) -> Result<SweepSpec> {
    let out = "results/fig4";
    let mut spec = SweepSpec::new("fig4", scale.seed, Path::new(out));
    let (pre, ckpt) = pretrain_phase(model_dir, pretrain_rounds, scale.seed, out);
    spec.pretrain = Some(pre);
    let apq = |fmt: &str| -> Result<OmcConfig> {
        Ok(OmcConfig {
            format: fmt.parse()?,
            use_pvt: true,
            weights_only: true,
            fraction: 1.0,
            integrity: false,
        })
    };
    let variants: Vec<(String, OmcConfig)> = vec![
        (
            "PPQ S1E3M7 @ 90%".into(),
            OmcConfig {
                format: "S1E3M7".parse()?,
                use_pvt: true,
                weights_only: true,
                fraction: 0.9,
                integrity: false,
            },
        ),
        ("APQ S1E3M9 @ 100%".into(), apq("S1E3M9")?),
        ("APQ S1E4M8 @ 100%".into(), apq("S1E4M8")?),
        ("APQ S1E5M7 @ 100%".into(), apq("S1E5M7")?),
    ];
    for (label, omc) in variants {
        let mut cfg =
            experiment(&label, model_dir, scale, Partition::Iid, 1, omc, out);
        cfg.init_from = Some(ckpt.clone());
        cfg.lr = 0.05;
        cfg.eval_every = (scale.rounds / 15).max(1);
        spec.cells.push(cfg);
    }
    spec.finalize()
}

/// Every paper grid with its default model dir — the full reproduction as
/// one list (`omc-fl sweep --preset all` runs them back to back).
pub fn paper_grids(scale: &Scale) -> Result<Vec<SweepSpec>> {
    Ok(vec![
        table1_grid("artifacts/small", scale)?,
        table2_grid("artifacts/small_streaming", scale, 60)?,
        table3_grid("artifacts/small", scale)?,
        table4_grid("artifacts/small_streaming", scale, 60, "S1E3M7")?,
        fig3_grid("artifacts/small", scale, "S1E3M4")?,
        fig4_grid("artifacts/small_streaming", scale, 60)?,
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ladder_matches_table4_rows() {
        let rows = table4_ladder("S1E3M7").unwrap();
        assert_eq!(rows.len(), 5);
        assert!(rows[0].1.is_baseline());
        // row 2: quantization only — no pvt, all params
        assert!(!rows[1].1.use_pvt && !rows[1].1.weights_only);
        assert_eq!(rows[1].1.fraction, 1.0);
        // each later row turns exactly one knob
        assert!(rows[2].1.use_pvt && !rows[2].1.weights_only);
        assert!(rows[3].1.use_pvt && rows[3].1.weights_only);
        assert_eq!(rows[4].1.fraction, 0.9);
    }

    #[test]
    fn cohort_ladder_escalates_from_ideal() {
        let rows = cohort_ladder();
        assert_eq!(rows.len(), 4);
        assert!(rows[0].1.is_ideal());
        for (_, c) in &rows {
            c.validate().unwrap();
        }
        assert!(rows[1].1.dropout_prob > 0.0);
        assert!(rows[2].1.straggler_mean_s > 0.0);
        assert!(rows[2].1.deadline_s.is_finite());
        let last = rows[3].1;
        assert!(last.dropout_prob > 0.0 && last.weight_by_examples);
    }

    #[test]
    fn async_ladder_escalates_from_sync() {
        let rows = async_ladder();
        assert_eq!(rows.len(), 5);
        assert!(!rows[0].1.enabled, "rung 0 is the sync reference");
        for (_, a) in &rows[1..] {
            assert!(a.enabled);
            a.validate().unwrap();
        }
        // rung 1 is the sync-equivalent full buffer: K and concurrency
        // resolve to the cohort, constant discount
        assert_eq!(rows[1].1.buffer_k, 0);
        assert!(matches!(rows[1].1.policy, StalenessPolicy::Constant(_)));
        // buffers shrink down the ladder; the last rung adds the cutoff
        assert_eq!(rows[2].1.buffer_k, 4);
        assert_eq!(rows[3].1.buffer_k, 2);
        assert_eq!(rows[4].1.max_staleness, 2);
        assert!(matches!(
            rows[4].1.policy,
            StalenessPolicy::Polynomial { .. }
        ));
    }

    #[test]
    fn scale_ladder_escalates_from_enumerable() {
        let rows = scale_ladder();
        assert_eq!(rows.len(), 4);
        assert!(!rows[0].1.enabled, "rung 0 is the enumerable reference");
        for (_, p) in &rows[1..] {
            assert!(p.enabled);
            p.validate().unwrap();
        }
        // fleets and edge counts grow down the ladder
        assert_eq!(rows[1].1.registered, 100_000);
        assert_eq!(rows[1].1.edges, 1);
        assert_eq!(rows[2].1.registered, 1_000_000);
        assert_eq!(rows[2].1.edges, 4);
        assert_eq!(rows[3].1.registered, 10_000_000);
        assert_eq!(rows[3].1.edges, 8);
        // the top rung runs both churn and the diurnal wave
        assert!(rows[3].1.churn_rate > 0.0);
        assert!(rows[3].1.wave_amplitude > 0.0);
        // ...while the flat-root rung isolates the lazy-fleet change
        assert_eq!(rows[1].1.churn_rate, 0.0);
        assert_eq!(rows[1].1.wave_amplitude, 0.0);
    }

    #[test]
    fn sparse_ladder_tightens_from_dense() {
        let rows = sparse_ladder();
        assert_eq!(rows.len(), 5);
        assert!(!rows[0].1.enabled, "rung 0 is the dense reference");
        for (_, s) in &rows[1..] {
            assert!(s.enabled);
            assert!(s.fraction > 0.0 && s.fraction <= 1.0);
        }
        // budgets tighten down the top-k rungs
        assert!(rows[1].1.fraction > rows[2].1.fraction);
        assert!(rows[2].1.fraction > rows[3].1.fraction);
        assert!(rows[1..4].iter().all(|(_, s)| s.mode == SparseMode::TopK));
        // the control arm swaps only the selection rule, same budget
        assert_eq!(rows[4].1.mode, SparseMode::RandK);
        assert_eq!(rows[4].1.fraction, rows[3].1.fraction);
    }

    #[test]
    fn serve_ladder_spans_workers_arena_and_pacing() {
        let rows = serve_ladder();
        assert_eq!(rows.len(), 4);
        for (_, s) in &rows {
            assert!(s.enabled);
            s.validate().unwrap();
        }
        // rung 0 pins the concurrency floor; rung 1 resolves to the machine
        assert_eq!(rows[0].1.workers, 1);
        assert_eq!(rows[1].1.workers, 0);
        // the A/B control arm differs from rung 1 only in the arena knob
        assert!(rows[1].1.arena && !rows[2].1.arena);
        assert_eq!(rows[1].1.workers, rows[2].1.workers);
        // the paced rung is the only one with an arrival rate
        assert!(rows[3].1.rate > 0.0);
        assert!(rows[..3].iter().all(|(_, s)| s.rate == 0.0));
    }

    #[test]
    fn paper_grids_cover_every_table_and_figure() {
        let scale = Scale::from_flags(40, 7);
        let grids = paper_grids(&scale).unwrap();
        let names: Vec<&str> = grids.iter().map(|g| g.name.as_str()).collect();
        assert_eq!(
            names,
            ["table1", "table2", "table3", "table4", "fig3", "fig4"]
        );
        for g in &grids {
            g.validate().unwrap();
            // per-cell seeds were derived (no cell keeps the sweep seed
            // unless the hash happens to collide, which it does not here)
            assert!(g.cells.iter().all(|c| c.seed != 7), "{}", g.name);
        }
        // adaptation grids pretrain into the checkpoint the cells read
        for name in ["table2", "table4", "fig4"] {
            let g = grids.iter().find(|g| g.name == name).unwrap();
            let ckpt = g.pretrain.as_ref().unwrap().save_to.clone().unwrap();
            assert!(g.cells.iter().all(|c| c.init_from.as_ref() == Some(&ckpt)));
        }
        // table4 is the 5-row ablation ladder
        let t4 = grids.iter().find(|g| g.name == "table4").unwrap();
        assert_eq!(t4.cells.len(), 5);
        assert!(t4.cells[0].omc.is_baseline());
    }

    #[test]
    fn experiment_builder_fields() {
        let s = Scale::from_flags(100, 7);
        let cfg = experiment(
            "x",
            "artifacts/small",
            &s,
            Partition::Iid,
            3,
            OmcConfig::fp32_baseline(),
            "results/x",
        );
        assert_eq!(cfg.rounds, 100);
        assert_eq!(cfg.domain, 3);
        assert_eq!(cfg.eval_every, 10);
        cfg.validate().unwrap();
    }
}
