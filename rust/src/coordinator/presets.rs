//! Shared scaffolding for the table/figure reproduction drivers in
//! `examples/` (DESIGN.md §5): standard experiment shapes, format ladders,
//! and output conventions.

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::config::{ExperimentConfig, OmcConfig};
use crate::coordinator::experiment::{Experiment, RunSummary};
use crate::data::partition::Partition;
use crate::fl::cohort::CohortConfig;
use crate::metrics::recorder::Recorder;
use crate::runtime::engine::{Engine, LoadedModel};

/// The paper's experimental scale, shrunk to this testbed. All examples use
/// these numbers unless a flag overrides them (paper: 128 clients, 1 local
/// step, batch 16; here: 32 clients, 8/round — the batch size is baked into
/// the artifact).
pub struct Scale {
    pub rounds: usize,
    pub num_clients: usize,
    pub clients_per_round: usize,
    pub lr: f32,
    pub seed: u64,
}

impl Scale {
    pub fn from_flags(rounds: usize, seed: u64) -> Self {
        Self {
            rounds,
            num_clients: 32,
            clients_per_round: 8,
            lr: 0.1,
            seed,
        }
    }
}

/// Build the standard experiment config used by the table drivers.
pub fn experiment(
    label: &str,
    model_dir: &str,
    scale: &Scale,
    partition: Partition,
    domain: u64,
    omc: OmcConfig,
    out_dir: &str,
) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default_with(label, &PathBuf::from(model_dir));
    cfg.rounds = scale.rounds;
    cfg.num_clients = scale.num_clients;
    cfg.clients_per_round = scale.clients_per_round;
    cfg.lr = scale.lr;
    cfg.seed = scale.seed;
    cfg.partition = partition;
    cfg.domain = domain;
    cfg.eval_every = (scale.rounds / 10).clamp(1, 20);
    cfg.eval_batches = 8;
    cfg.output_dir = PathBuf::from(out_dir);
    cfg.omc = omc;
    cfg
}

/// Run one experiment variant against a shared compiled model, write its
/// per-round log, and return the summary row.
pub fn run_variant(
    model: &Arc<LoadedModel>,
    cfg: ExperimentConfig,
) -> Result<(Recorder, RunSummary)> {
    let out_dir = cfg.output_dir.clone();
    let mut exp = Experiment::prepare_with_model(cfg, Arc::clone(model))?;
    let (rec, summary) = exp.run()?;
    rec.write(&out_dir)?;
    Ok((rec, summary))
}

/// Bind a model directory once for a whole example (shared compile cache).
pub fn bind_model(engine: &Engine, model_dir: &str) -> Result<Arc<LoadedModel>> {
    Ok(Arc::new(engine.load_model(std::path::Path::new(model_dir))?))
}

/// The ablation ladder of Table 4, in presentation order.
pub fn table4_ladder(format: &str) -> Result<Vec<(String, OmcConfig)>> {
    let fmt = format.parse()?;
    Ok(vec![
        ("FP32 baseline".into(), OmcConfig::fp32_baseline()),
        (
            format!("quant only ({format})"),
            OmcConfig {
                format: fmt,
                use_pvt: false,
                weights_only: false,
                fraction: 1.0,
            },
        ),
        (
            "+ per-variable transform".into(),
            OmcConfig {
                format: fmt,
                use_pvt: true,
                weights_only: false,
                fraction: 1.0,
            },
        ),
        (
            "+ weights only".into(),
            OmcConfig {
                format: fmt,
                use_pvt: true,
                weights_only: true,
                fraction: 1.0,
            },
        ),
        (
            "+ 90% weights (full OMC)".into(),
            OmcConfig {
                format: fmt,
                use_pvt: true,
                weights_only: true,
                fraction: 0.9,
            },
        ),
    ])
}

/// The cohort-failure scenario ladder driven by `examples/cohort_stress.rs`
/// and the stress rows of `bench_round`: from the tables' ideal cohort to a
/// production-shaped one (dropout + stragglers + example-weighted FedAvg).
pub fn cohort_ladder() -> Vec<(String, CohortConfig)> {
    vec![
        ("ideal cohort".into(), CohortConfig::ideal()),
        (
            "10% dropout".into(),
            CohortConfig {
                dropout_prob: 0.1,
                ..CohortConfig::ideal()
            },
        ),
        (
            "stragglers (mean 2s, deadline 4s)".into(),
            CohortConfig {
                straggler_mean_s: 2.0,
                deadline_s: 4.0,
                ..CohortConfig::ideal()
            },
        ),
        (
            "dropout + stragglers, weighted".into(),
            CohortConfig {
                dropout_prob: 0.1,
                straggler_mean_s: 2.0,
                deadline_s: 4.0,
                weight_by_examples: true,
            },
        ),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ladder_matches_table4_rows() {
        let rows = table4_ladder("S1E3M7").unwrap();
        assert_eq!(rows.len(), 5);
        assert!(rows[0].1.is_baseline());
        // row 2: quantization only — no pvt, all params
        assert!(!rows[1].1.use_pvt && !rows[1].1.weights_only);
        assert_eq!(rows[1].1.fraction, 1.0);
        // each later row turns exactly one knob
        assert!(rows[2].1.use_pvt && !rows[2].1.weights_only);
        assert!(rows[3].1.use_pvt && rows[3].1.weights_only);
        assert_eq!(rows[4].1.fraction, 0.9);
    }

    #[test]
    fn cohort_ladder_escalates_from_ideal() {
        let rows = cohort_ladder();
        assert_eq!(rows.len(), 4);
        assert!(rows[0].1.is_ideal());
        for (_, c) in &rows {
            c.validate().unwrap();
        }
        assert!(rows[1].1.dropout_prob > 0.0);
        assert!(rows[2].1.straggler_mean_s > 0.0);
        assert!(rows[2].1.deadline_s.is_finite());
        let last = rows[3].1;
        assert!(last.dropout_prob > 0.0 && last.weight_by_examples);
    }

    #[test]
    fn experiment_builder_fields() {
        let s = Scale::from_flags(100, 7);
        let cfg = experiment(
            "x",
            "artifacts/small",
            &s,
            Partition::Iid,
            3,
            OmcConfig::fp32_baseline(),
            "results/x",
        );
        assert_eq!(cfg.rounds, 100);
        assert_eq!(cfg.domain, 3);
        assert_eq!(cfg.eval_every, 10);
        cfg.validate().unwrap();
    }
}
