//! Micro-benchmark harness (no `criterion` offline).
//!
//! Warmup + timed iterations, reporting median / MAD / throughput as
//! markdown rows so `cargo bench` output can be pasted into EXPERIMENTS.md.
//! Benches under `benches/` use `harness = false` and drive this directly.

use std::hint::black_box;
use std::time::Instant;

use crate::metrics::stats::{median_abs_dev, percentile};
use crate::util::json::{self, Json};

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub median_ns: f64,
    pub mad_ns: f64,
    pub iters: usize,
    /// optional elements-processed-per-iteration for throughput
    pub elems: Option<usize>,
    /// optional bytes-moved-per-iteration for bandwidth (input + output
    /// traffic of the measured operation — each bench documents what it
    /// counts)
    pub bytes: Option<usize>,
    /// iterations spent in calibration + warmup before sampling started
    pub warmup_iters: usize,
}

impl BenchResult {
    pub fn throughput_m_elems_s(&self) -> Option<f64> {
        self.elems
            .map(|e| e as f64 / (self.median_ns / 1e9) / 1e6)
    }

    /// Decimal GB/s (1 byte/ns = 1 GB/s) when the case recorded bytes.
    pub fn throughput_gb_s(&self) -> Option<f64> {
        self.bytes.map(|b| b as f64 / self.median_ns)
    }

    pub fn row(&self) -> String {
        let thr = match self.throughput_m_elems_s() {
            Some(t) => format!("{t:10.1}"),
            None => format!("{:>10}", "-"),
        };
        let bw = match self.throughput_gb_s() {
            Some(t) => format!("{t:8.2}"),
            None => format!("{:>8}", "-"),
        };
        format!(
            "| {:<38} | {:>12} | {:>9} | {} | {} |",
            self.name,
            fmt_ns(self.median_ns),
            fmt_ns(self.mad_ns),
            thr,
            bw
        )
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3} µs", ns / 1e3)
    } else {
        format!("{ns:.0} ns")
    }
}

/// A benchmark suite printing a markdown table.
pub struct Suite {
    pub title: String,
    results: Vec<BenchResult>,
    /// minimum total measuring time per case
    pub min_time_s: f64,
    /// maximum iterations per case (caps very fast cases)
    pub max_iters: usize,
}

impl Suite {
    pub fn new(title: &str) -> Self {
        // OMC_BENCH_FAST=1 shrinks budgets so `cargo test`-style smoke runs
        // of the benches stay quick.
        let fast = std::env::var("OMC_BENCH_FAST").is_ok();
        Self {
            title: title.to_string(),
            results: Vec::new(),
            min_time_s: if fast { 0.05 } else { 0.5 },
            max_iters: if fast { 200 } else { 100_000 },
        }
    }

    /// Time `f`, which should fully consume its work (`black_box` inside).
    pub fn bench<F: FnMut()>(&mut self, name: &str, elems: Option<usize>, f: F) {
        self.bench_case(name, elems, None, f)
    }

    /// [`Suite::bench`] additionally recording the bytes each iteration
    /// moves, so the JSON rows carry a GB/s figure comparable across
    /// hosts and PRs.
    pub fn bench_case<F: FnMut()>(
        &mut self,
        name: &str,
        elems: Option<usize>,
        bytes: Option<usize>,
        mut f: F,
    ) {
        // warmup + calibration: one timed call sizes a ~10ms batch, then
        // one untimed batch warms caches/branch predictors before sampling
        let t0 = Instant::now();
        f();
        let once = t0.elapsed().as_secs_f64().max(1e-9);
        let batch = ((0.01 / once) as usize).clamp(1, self.max_iters);
        let warmup_batch = batch.min(self.max_iters / 10 + 1);
        for _ in 0..warmup_batch {
            f();
        }
        let warmup_iters = 1 + warmup_batch;

        let mut samples = Vec::new();
        let start = Instant::now();
        let mut total_iters = 0usize;
        while start.elapsed().as_secs_f64() < self.min_time_s
            && samples.len() < 200
        {
            let t = Instant::now();
            for _ in 0..batch {
                f();
            }
            let per = t.elapsed().as_secs_f64() * 1e9 / batch as f64;
            samples.push(per);
            total_iters += batch;
        }
        let res = BenchResult {
            name: name.to_string(),
            median_ns: percentile(&samples, 50.0),
            mad_ns: median_abs_dev(&samples),
            iters: total_iters,
            elems,
            bytes,
            warmup_iters,
        };
        eprintln!("  measured {name}: {}", fmt_ns(res.median_ns));
        self.results.push(res);
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Record a directly-measured quantity as a result row: `median_ns`
    /// carries the value, MAD is 0, and `iters` is 1. Wall-clock engine
    /// numbers (latency quantiles, commits/sec expressed as ns/commit)
    /// can't be re-run under [`bench_case`](Self::bench_case)'s sampling
    /// loop, but still belong in the same JSON schema the cross-PR trend
    /// tracker reads.
    pub fn metric(
        &mut self,
        name: &str,
        value_ns: f64,
        elems: Option<usize>,
        bytes: Option<usize>,
    ) {
        let res = BenchResult {
            name: name.to_string(),
            median_ns: value_ns,
            mad_ns: 0.0,
            iters: 1,
            elems,
            bytes,
            warmup_iters: 0,
        };
        eprintln!("  measured {name}: {}", fmt_ns(res.median_ns));
        self.results.push(res);
    }

    /// Print the markdown table to stdout.
    pub fn report(&self) {
        println!("\n### {}\n", self.title);
        println!(
            "| {:<38} | {:>12} | {:>9} | {:>10} | {:>8} |",
            "case", "median", "mad", "Melem/s", "GB/s"
        );
        println!(
            "|{}|{}|{}|{}|{}|",
            "-".repeat(40),
            "-".repeat(14),
            "-".repeat(11),
            "-".repeat(12),
            "-".repeat(10)
        );
        for r in &self.results {
            println!("{}", r.row());
        }
        println!();
    }

    /// Serialize the results as a JSON object (machine-readable companion
    /// to the markdown table, used to track the perf trajectory across
    /// PRs).
    pub fn to_json(&self) -> Json {
        let results: Vec<Json> = self
            .results
            .iter()
            .map(|r| {
                json::obj(vec![
                    ("name", json::s(&r.name)),
                    ("median_ns", json::num(r.median_ns)),
                    ("mad_ns", json::num(r.mad_ns)),
                    ("iters", json::num(r.iters as f64)),
                    ("warmup_iters", json::num(r.warmup_iters as f64)),
                    (
                        "elems",
                        r.elems.map(|e| json::num(e as f64)).unwrap_or(Json::Null),
                    ),
                    (
                        "bytes",
                        r.bytes.map(|b| json::num(b as f64)).unwrap_or(Json::Null),
                    ),
                    (
                        "melem_per_s",
                        r.throughput_m_elems_s()
                            .map(json::num)
                            .unwrap_or(Json::Null),
                    ),
                    (
                        "gb_per_s",
                        r.throughput_gb_s().map(json::num).unwrap_or(Json::Null),
                    ),
                ])
            })
            .collect();
        json::obj(vec![
            ("title", json::s(&self.title)),
            ("results", Json::Arr(results)),
        ])
    }

    /// Write the machine-readable results to `path`.
    pub fn write_json_to(&self, path: &std::path::Path) {
        match std::fs::write(path, self.to_json().to_string()) {
            Ok(()) => eprintln!("wrote {}", path.display()),
            Err(e) => eprintln!("failed to write {}: {e}", path.display()),
        }
    }

    /// Report, then — when `OMC_BENCH_JSON` is set — also write the
    /// machine-readable results. `OMC_BENCH_JSON=1` (or empty) writes
    /// `file_name` into the current directory (the repo root under
    /// `cargo bench`); any other value is treated as the target directory.
    pub fn finish(&self, file_name: &str) {
        self.report();
        let Ok(dest) = std::env::var("OMC_BENCH_JSON") else {
            return;
        };
        let path = if dest.is_empty() || dest == "1" {
            std::path::PathBuf::from(file_name)
        } else {
            std::path::Path::new(&dest).join(file_name)
        };
        self.write_json_to(&path);
    }
}

/// Re-export for bench binaries.
pub use std::hint::black_box as bb;

/// Consume a value so the optimizer cannot remove the computation.
#[inline]
pub fn consume<T>(x: T) -> T {
    black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_numbers() {
        std::env::set_var("OMC_BENCH_FAST", "1");
        let mut s = Suite::new("test");
        let mut acc = 0u64;
        s.bench("noop-ish", Some(1000), || {
            for i in 0..1000u64 {
                acc = acc.wrapping_add(consume(i));
            }
        });
        let r = &s.results()[0];
        assert!(r.median_ns > 0.0);
        assert!(r.iters >= 1);
        assert!(r.throughput_m_elems_s().unwrap() > 0.0);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(12.0).contains("ns"));
        assert!(fmt_ns(1.2e4).contains("µs"));
        assert!(fmt_ns(3.4e6).contains("ms"));
        assert!(fmt_ns(2.1e9).contains(" s"));
    }

    #[test]
    fn json_output_roundtrips() {
        std::env::set_var("OMC_BENCH_FAST", "1");
        let mut s = Suite::new("json test");
        s.bench_case("case_a", Some(100), Some(800), || {
            consume(41 + 1);
        });
        let j = s.to_json();
        let parsed = crate::util::json::parse(&j.to_string()).unwrap();
        assert_eq!(
            parsed.get("title").unwrap().as_str(),
            Some("json test")
        );
        let results = parsed.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(
            results[0].get("name").unwrap().as_str(),
            Some("case_a")
        );
        assert!(results[0].get("melem_per_s").unwrap().as_f64().unwrap() > 0.0);
        // the cross-PR trajectory fields: element/byte counts, derived
        // bandwidth, and the warmup spent before sampling
        assert_eq!(results[0].get("elems").unwrap().as_f64(), Some(100.0));
        assert_eq!(results[0].get("bytes").unwrap().as_f64(), Some(800.0));
        assert!(results[0].get("gb_per_s").unwrap().as_f64().unwrap() > 0.0);
        assert!(results[0].get("warmup_iters").unwrap().as_f64().unwrap() >= 1.0);
    }

    #[test]
    fn write_json_produces_parseable_file() {
        // the injected-path writer finish() delegates to; no env mutation
        // here (set_var races with concurrent env reads on the default
        // multi-threaded test harness)
        std::env::set_var("OMC_BENCH_FAST", "1");
        let dir = std::env::temp_dir().join(format!(
            "omc_bench_json_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let mut s = Suite::new("file test");
        s.bench("c", None, || {
            consume(1);
        });
        let path = dir.join("BENCH_test.json");
        s.write_json_to(&path);
        let txt = std::fs::read_to_string(&path).unwrap();
        assert!(crate::util::json::parse(&txt).is_ok());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn metric_rows_share_the_result_schema() {
        let mut s = Suite::new("metric test");
        // p99 of 2.5ms with 64 uplinks moving 4096 bytes/iter
        s.metric("serve uplink p99", 2.5e6, Some(64), Some(4096));
        let r = &s.results()[0];
        assert_eq!((r.median_ns, r.mad_ns, r.iters), (2.5e6, 0.0, 1));
        assert!(r.throughput_gb_s().unwrap() > 0.0);
        let j = s.to_json().to_string();
        let parsed = crate::util::json::parse(&j).unwrap();
        let rows = parsed.get("results").unwrap().as_arr().unwrap();
        assert_eq!(rows[0].get("median_ns").unwrap().as_f64(), Some(2.5e6));
        assert_eq!(rows[0].get("iters").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn rows_are_markdown() {
        let r = BenchResult {
            name: "x".into(),
            median_ns: 100.0,
            mad_ns: 1.0,
            iters: 10,
            elems: None,
            bytes: None,
            warmup_iters: 1,
        };
        assert!(r.row().starts_with('|'));
        assert!(r.row().contains(" - "));
    }

    #[test]
    fn gb_per_s_derivation() {
        let r = BenchResult {
            name: "bw".into(),
            median_ns: 1000.0,
            mad_ns: 1.0,
            iters: 10,
            elems: Some(500),
            bytes: Some(2000),
            warmup_iters: 3,
        };
        // 2000 bytes / 1000 ns = 2 GB/s (decimal)
        assert!((r.throughput_gb_s().unwrap() - 2.0).abs() < 1e-12);
    }
}
