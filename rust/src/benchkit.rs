//! Micro-benchmark harness (no `criterion` offline).
//!
//! Warmup + timed iterations, reporting median / MAD / throughput as
//! markdown rows so `cargo bench` output can be pasted into EXPERIMENTS.md.
//! Benches under `benches/` use `harness = false` and drive this directly.

use std::hint::black_box;
use std::time::Instant;

use crate::metrics::stats::{median_abs_dev, percentile};

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub median_ns: f64,
    pub mad_ns: f64,
    pub iters: usize,
    /// optional elements-processed-per-iteration for throughput
    pub elems: Option<usize>,
}

impl BenchResult {
    pub fn throughput_m_elems_s(&self) -> Option<f64> {
        self.elems
            .map(|e| e as f64 / (self.median_ns / 1e9) / 1e6)
    }

    pub fn row(&self) -> String {
        let thr = match self.throughput_m_elems_s() {
            Some(t) => format!("{t:10.1}"),
            None => format!("{:>10}", "-"),
        };
        format!(
            "| {:<38} | {:>12} | {:>9} | {} |",
            self.name,
            fmt_ns(self.median_ns),
            fmt_ns(self.mad_ns),
            thr
        )
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3} µs", ns / 1e3)
    } else {
        format!("{ns:.0} ns")
    }
}

/// A benchmark suite printing a markdown table.
pub struct Suite {
    pub title: String,
    results: Vec<BenchResult>,
    /// minimum total measuring time per case
    pub min_time_s: f64,
    /// maximum iterations per case (caps very fast cases)
    pub max_iters: usize,
}

impl Suite {
    pub fn new(title: &str) -> Self {
        // OMC_BENCH_FAST=1 shrinks budgets so `cargo test`-style smoke runs
        // of the benches stay quick.
        let fast = std::env::var("OMC_BENCH_FAST").is_ok();
        Self {
            title: title.to_string(),
            results: Vec::new(),
            min_time_s: if fast { 0.05 } else { 0.5 },
            max_iters: if fast { 200 } else { 100_000 },
        }
    }

    /// Time `f`, which should fully consume its work (`black_box` inside).
    pub fn bench<F: FnMut()>(&mut self, name: &str, elems: Option<usize>, mut f: F) {
        // warmup + calibration: find an iteration count that runs ~10ms
        let t0 = Instant::now();
        f();
        let once = t0.elapsed().as_secs_f64().max(1e-9);
        let batch = ((0.01 / once) as usize).clamp(1, self.max_iters);

        let mut samples = Vec::new();
        let start = Instant::now();
        let mut total_iters = 0usize;
        while start.elapsed().as_secs_f64() < self.min_time_s
            && samples.len() < 200
        {
            let t = Instant::now();
            for _ in 0..batch {
                f();
            }
            let per = t.elapsed().as_secs_f64() * 1e9 / batch as f64;
            samples.push(per);
            total_iters += batch;
        }
        let res = BenchResult {
            name: name.to_string(),
            median_ns: percentile(&samples, 50.0),
            mad_ns: median_abs_dev(&samples),
            iters: total_iters,
            elems,
        };
        eprintln!("  measured {name}: {}", fmt_ns(res.median_ns));
        self.results.push(res);
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Print the markdown table to stdout.
    pub fn report(&self) {
        println!("\n### {}\n", self.title);
        println!(
            "| {:<38} | {:>12} | {:>9} | {:>10} |",
            "case", "median", "mad", "Melem/s"
        );
        println!("|{}|{}|{}|{}|", "-".repeat(40), "-".repeat(14), "-".repeat(11), "-".repeat(12));
        for r in &self.results {
            println!("{}", r.row());
        }
        println!();
    }
}

/// Re-export for bench binaries.
pub use std::hint::black_box as bb;

/// Consume a value so the optimizer cannot remove the computation.
#[inline]
pub fn consume<T>(x: T) -> T {
    black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_numbers() {
        std::env::set_var("OMC_BENCH_FAST", "1");
        let mut s = Suite::new("test");
        let mut acc = 0u64;
        s.bench("noop-ish", Some(1000), || {
            for i in 0..1000u64 {
                acc = acc.wrapping_add(consume(i));
            }
        });
        let r = &s.results()[0];
        assert!(r.median_ns > 0.0);
        assert!(r.iters >= 1);
        assert!(r.throughput_m_elems_s().unwrap() > 0.0);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(12.0).contains("ns"));
        assert!(fmt_ns(1.2e4).contains("µs"));
        assert!(fmt_ns(3.4e6).contains("ms"));
        assert!(fmt_ns(2.1e9).contains(" s"));
    }

    #[test]
    fn rows_are_markdown() {
        let r = BenchResult {
            name: "x".into(),
            median_ns: 100.0,
            mad_ns: 1.0,
            iters: 10,
            elems: None,
        };
        assert!(r.row().starts_with('|'));
        assert!(r.row().contains(" - "));
    }
}
