//! Bit-exact Rust mirror of the L1 Pallas quantization kernel.
//!
//! The algorithm is identical, operation for operation, to
//! `python/compile/kernels/ref.py::quantize_u32_math` (which the Pallas
//! kernel shares): round-to-nearest-even on the f32 encoding for the normal
//! range, the exact additive trick `(|x| + C) - C` for the subnormal range,
//! saturation to max finite. Cross-language agreement is asserted in
//! `rust/tests/cross_layer.rs` by executing the `quant.hlo.txt` artifact and
//! comparing bit patterns.
//!
//! Bit-exactness matters because the quantized values cross the wire
//! bit-packed (`omc::pack`): the Rust decoder must reproduce the exact f32
//! values the training graph emitted.
//!
//! The scalar algorithm lives in [`crate::util::simd::quantize_one_em`]
//! (the substrate layer, so the SIMD kernels and this module share one
//! source of truth); the bulk entry points here go through the
//! runtime-resolved dispatch table ([`crate::util::simd::kernels`]) and
//! are **bit-exact** against the scalar reference on every ISA path —
//! property-tested in `rust/tests/omc_kernels.rs`.

use super::format::FloatFormat;
use crate::util::simd;

/// Quantize a single f32 to `fmt`. Inf/NaN saturate to max finite
/// (documented in DESIGN.md; training values are finite).
#[inline]
pub fn quantize_one(x: f32, fmt: FloatFormat) -> f32 {
    simd::quantize_one_em(x, fmt.exp_bits, fmt.mant_bits)
}

/// Quantize a slice out-of-place (runtime-dispatched SIMD kernel).
pub fn quantize_slice(xs: &[f32], fmt: FloatFormat, out: &mut [f32]) {
    assert_eq!(xs.len(), out.len());
    if fmt.is_fp32() {
        out.copy_from_slice(xs);
        return;
    }
    (simd::kernels().quantize)(xs, fmt.exp_bits, fmt.mant_bits, out);
}

/// Quantize a slice out-of-place on the scalar reference path, whatever
/// the dispatch resolved (benches use this for scalar-vs-SIMD rows; the
/// kernel tests use it as the ground truth).
pub fn quantize_slice_scalar(xs: &[f32], fmt: FloatFormat, out: &mut [f32]) {
    assert_eq!(xs.len(), out.len());
    if fmt.is_fp32() {
        out.copy_from_slice(xs);
        return;
    }
    for (o, &x) in out.iter_mut().zip(xs) {
        *o = quantize_one(x, fmt);
    }
}

/// Quantize in place (runtime-dispatched SIMD kernel).
pub fn quantize_in_place(xs: &mut [f32], fmt: FloatFormat) {
    if fmt.is_fp32() {
        return;
    }
    (simd::kernels().quantize_in_place)(xs, fmt.exp_bits, fmt.mant_bits);
}

/// Allocating convenience wrapper.
pub fn quantize_vec(xs: &[f32], fmt: FloatFormat) -> Vec<f32> {
    let mut out = vec![0.0; xs.len()];
    quantize_slice(xs, fmt, &mut out);
    out
}

/// Quantize into a reused buffer (cleared first, capacity retained across
/// calls — the codec scratch-buffer discipline).
pub fn quantize_into(xs: &[f32], fmt: FloatFormat, out: &mut Vec<f32>) {
    out.clear();
    out.resize(xs.len(), 0.0);
    quantize_slice(xs, fmt, out);
}

/// True iff `x` is exactly representable in `fmt` (i.e. a fixed point of
/// the quantizer). Used by debug assertions and the packer.
pub fn is_representable(x: f32, fmt: FloatFormat) -> bool {
    quantize_one(x, fmt).to_bits() == x.to_bits()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::Gen;

    fn fmt(s: &str) -> FloatFormat {
        s.parse().unwrap()
    }

    const PAPER_FORMATS: [&str; 8] = [
        "S1E8M23", "S1E5M10", "S1E4M14", "S1E3M7", "S1E2M3", "S1E3M9",
        "S1E4M8", "S1E5M7",
    ];

    #[test]
    fn fp32_is_identity() {
        let mut g = Gen::new(1);
        for _ in 0..10_000 {
            let x = g.f32_wide();
            assert_eq!(quantize_one(x, FloatFormat::FP32).to_bits(), x.to_bits());
        }
    }

    #[test]
    fn idempotent_property() {
        for f in PAPER_FORMATS {
            let fmt = fmt(f);
            let mut g = Gen::new(7);
            for _ in 0..20_000 {
                let x = g.f32_wide();
                let q = quantize_one(x, fmt);
                assert_eq!(
                    quantize_one(q, fmt).to_bits(),
                    q.to_bits(),
                    "{f} x={x:e}"
                );
            }
        }
    }

    #[test]
    fn monotone_property() {
        for f in PAPER_FORMATS {
            let fmt = fmt(f);
            let mut g = Gen::new(3);
            for _ in 0..5_000 {
                let a = g.f32_normalish(1.0);
                let b = g.f32_normalish(1.0);
                let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
                assert!(
                    quantize_one(lo, fmt) <= quantize_one(hi, fmt),
                    "{f} {lo:e} {hi:e}"
                );
            }
        }
    }

    #[test]
    fn sign_symmetry() {
        for f in PAPER_FORMATS {
            let fmt = fmt(f);
            let mut g = Gen::new(5);
            for _ in 0..5_000 {
                let x = g.f32_wide();
                assert_eq!(
                    quantize_one(-x, fmt).to_bits(),
                    (-quantize_one(x, fmt)).to_bits()
                );
            }
        }
    }

    #[test]
    fn matches_ieee_half_for_s1e5m10() {
        // f32 -> f16 -> f32 round trip computed independently via the
        // well-known bit algorithm is what the S1E5M10 quantizer must equal.
        let mut g = Gen::new(11);
        for _ in 0..50_000 {
            let x = g.f32_normalish(10.0);
            let q = quantize_one(x, FloatFormat::FP16);
            let viaf16 = f16_roundtrip(x);
            assert_eq!(q.to_bits(), viaf16.to_bits(), "x={x:e}");
        }
    }

    /// Independent software f32->binary16->f32 (RNE, saturating, no inf).
    fn f16_roundtrip(x: f32) -> f32 {
        let fmt = FloatFormat::FP16;
        // brute-force nearest-even search over the f16 grid is too slow;
        // instead use the double-rounding-free property: binary16 values
        // are exactly the S1E5M10 grid, so compare against a table-free
        // approach: scale into the grid via exact f64 arithmetic.
        let xa = x as f64;
        let max = fmt.max_value();
        if xa.abs() >= max {
            return (max.copysign(xa)) as f32;
        }
        let exp = if xa == 0.0 {
            0
        } else {
            xa.abs().log2().floor() as i32
        };
        let q = if exp < fmt.min_normal_exp() {
            2f64.powi(fmt.min_normal_exp() - fmt.mant_bits as i32)
        } else {
            2f64.powi(exp - fmt.mant_bits as i32)
        };
        let k = xa / q;
        let kr = round_half_even(k);
        // rounding can push |value| to the next binade: recompute quantum
        let v = kr * q;
        let exp2 = if v == 0.0 {
            exp
        } else {
            v.abs().log2().floor() as i32
        };
        if exp2 > exp && exp2 >= fmt.min_normal_exp() {
            let q2 = 2f64.powi(exp2 - fmt.mant_bits as i32);
            (round_half_even(xa / q2) * q2).min(max).max(-max) as f32
        } else {
            v.min(max).max(-max) as f32
        }
    }

    fn round_half_even(x: f64) -> f64 {
        let f = x.floor();
        let d = x - f;
        if d > 0.5 {
            f + 1.0
        } else if d < 0.5 {
            f
        } else if (f as i64) % 2 == 0 {
            f
        } else {
            f + 1.0
        }
    }

    #[test]
    fn saturation() {
        for f in ["S1E3M7", "S1E2M3", "S1E5M10"] {
            let fmt = fmt(f);
            let max = fmt.max_value() as f32;
            assert_eq!(quantize_one(f32::INFINITY, fmt), max);
            assert_eq!(quantize_one(f32::NEG_INFINITY, fmt), -max);
            assert_eq!(quantize_one(1e30, fmt), max);
            assert_eq!(quantize_one(max, fmt), max);
        }
    }

    #[test]
    fn subnormal_grid_uniform() {
        for f in ["S1E3M7", "S1E2M3", "S1E4M8"] {
            let fmt = fmt(f);
            let quantum = fmt.min_positive();
            let mut g = Gen::new(13);
            let min_normal = 2f64.powi(fmt.min_normal_exp());
            for _ in 0..10_000 {
                let x = (g.f64_unit() * 2.0 - 1.0) * min_normal;
                let q = quantize_one(x as f32, fmt) as f64;
                let k = q / quantum;
                assert_eq!(k, k.round(), "{f} x={x:e} q={q:e}");
                assert!((q - x).abs() <= quantum / 2.0 + 1e-18);
            }
        }
    }

    #[test]
    fn ties_round_to_even() {
        // S1E4M2: between 1.0 and 1.25, tie 1.125 -> 1.0 (even); tie
        // 1.375 -> 1.5 (even). Mirrors the python test.
        let fmt = FloatFormat::new(4, 2).unwrap();
        assert_eq!(quantize_one(1.125, fmt), 1.0);
        assert_eq!(quantize_one(1.375, fmt), 1.5);
        assert_eq!(quantize_one(-1.125, fmt), -1.0);
        assert_eq!(quantize_one(-1.375, fmt), -1.5);
    }

    #[test]
    fn zeros_preserved_with_sign() {
        let fmt = fmt("S1E3M7");
        assert_eq!(quantize_one(0.0, fmt).to_bits(), 0.0f32.to_bits());
        assert_eq!(quantize_one(-0.0, fmt).to_bits(), (-0.0f32).to_bits());
    }

    #[test]
    fn error_bounded_by_half_ulp_normals() {
        for f in PAPER_FORMATS {
            let fmt = fmt(f);
            let mut g = Gen::new(17);
            for _ in 0..10_000 {
                let x = g.f32_normalish(1.0);
                let q = quantize_one(x, fmt) as f64;
                let xa = x as f64;
                if xa.abs() >= 2f64.powi(fmt.min_normal_exp())
                    && xa.abs() < fmt.max_value() / 2.0
                {
                    let exp = xa.abs().log2().floor() as i32;
                    let ulp = 2f64.powi(exp - fmt.mant_bits as i32);
                    assert!(
                        (q - xa).abs() <= ulp / 2.0 * 1.0000001,
                        "{f} x={x:e} q={q:e}"
                    );
                }
            }
        }
    }

    #[test]
    fn slice_and_in_place_agree() {
        let fmt = fmt("S1E3M7");
        let mut g = Gen::new(19);
        let xs: Vec<f32> = (0..1000).map(|_| g.f32_normalish(0.1)).collect();
        let a = quantize_vec(&xs, fmt);
        let mut b = xs.clone();
        quantize_in_place(&mut b, fmt);
        assert_eq!(
            a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            b.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn quantize_into_reuses_capacity() {
        let fmt = fmt("S1E3M7");
        let mut g = Gen::new(23);
        let xs: Vec<f32> = (0..500).map(|_| g.f32_normalish(0.1)).collect();
        let mut out = Vec::new();
        quantize_into(&xs, fmt, &mut out);
        let ptr = out.as_ptr();
        quantize_into(&xs, fmt, &mut out);
        assert_eq!(out.as_ptr(), ptr, "quantize_into must not reallocate");
        assert_eq!(
            out.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            quantize_vec(&xs, fmt).iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn dispatched_slice_matches_scalar_reference() {
        let mut g = Gen::new(29);
        for f in PAPER_FORMATS {
            let fmt = fmt(f);
            for n in [0usize, 1, 7, 8, 9, 31, 256, 1000] {
                let xs: Vec<f32> =
                    (0..n).map(|_| g.f32_wide()).collect();
                let mut scalar = vec![0.0f32; n];
                quantize_slice_scalar(&xs, fmt, &mut scalar);
                let mut fast = vec![0.0f32; n];
                quantize_slice(&xs, fmt, &mut fast);
                for i in 0..n {
                    assert_eq!(
                        scalar[i].to_bits(),
                        fast[i].to_bits(),
                        "{f} n={n} idx {i}"
                    );
                }
            }
        }
    }

    #[test]
    fn representability_check() {
        let fmt = fmt("S1E3M7");
        assert!(is_representable(0.25, fmt));
        assert!(is_representable(0.0, fmt));
        assert!(!is_representable(0.1, fmt)); // 0.1 not on any binary grid
    }
}
