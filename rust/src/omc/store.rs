//! The compressed parameter store (paper Sec. 2.1, Fig. 1).
//!
//! `CompressedModel` is what a client keeps between operations: every
//! variable is either bit-packed at the OMC format plus its PVT scalars, or
//! raw f32 (norm parameters, and the PPQ-unselected weights). Decompressed
//! f32 copies are produced on demand and dropped by the caller as soon as
//! they are consumed — mirroring the paper's transient-variable discipline.
//! `memory_bytes()` is the quantity Sec. 3.4 measures.

use std::borrow::Cow;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use super::format::FloatFormat;
use super::pack;
use super::transform::Pvt;

/// One variable in the store.
///
/// ```
/// use omc_fl::omc::store::StoredVar;
/// use omc_fl::FloatFormat;
///
/// let values = vec![0.5f32, -1.25, 3.0, 0.0625];
/// let fmt: FloatFormat = "S1E4M14".parse().unwrap();
/// let sv = StoredVar::compress(&values, fmt, true);
/// assert!(sv.is_packed());
/// assert_eq!(sv.len(), 4);
/// // 19-bit codes + 8 bytes of PVT scalars, vs 16 bytes raw
/// assert_eq!(sv.memory_bytes(), fmt.packed_bytes(4) + 8);
/// // decompress applies the fitted per-variable transform
/// let back = sv.decompress();
/// assert_eq!(back.len(), 4);
/// ```
#[derive(Clone, Debug)]
pub enum StoredVar {
    /// Raw f32 (unquantized) — 4 bytes/element.
    Raw(Vec<f32>),
    /// Bit-packed SxEyMz codes + per-variable transform.
    Packed {
        /// the bit-packed codes
        bytes: Vec<u8>,
        /// element count
        n: usize,
        /// the `SxEyMz` format the codes are packed at
        fmt: FloatFormat,
        /// per-variable transform scalars
        pvt: Pvt,
    },
}

impl StoredVar {
    /// Compress `values` (exact quantizer fixed points NOT required — this
    /// quantizes) with a PVT fit, or store raw when `fmt` is FP32.
    ///
    /// Runs the fused single-pass pipeline
    /// [`pack::quantize_transform_pack`]: quantize → PVT fit → bit-pack per
    /// 256-value block, never materializing the intermediate quantized
    /// `Vec<f32>`. Payload bytes and PVT scalars are bit-identical to the
    /// separate-pass reference.
    pub fn compress(values: &[f32], fmt: FloatFormat, use_pvt: bool) -> Self {
        if fmt.is_fp32() {
            return StoredVar::Raw(values.to_vec());
        }
        let mut bytes = Vec::new();
        let pvt = pack::quantize_transform_pack(values, fmt, use_pvt, &mut bytes);
        StoredVar::Packed {
            bytes,
            n: values.len(),
            fmt,
            pvt,
        }
    }

    /// Store values that are *already* quantizer fixed points (e.g. the Ṽ'
    /// returned by the training graph) along with their fitted transform.
    pub fn from_quantized(
        vt: &[f32],
        fmt: FloatFormat,
        pvt: Pvt,
    ) -> Result<Self, pack::PackError> {
        Ok(StoredVar::Packed {
            bytes: pack::pack(vt, fmt)?,
            n: vt.len(),
            fmt,
            pvt,
        })
    }

    /// Store values unquantized (norm parameters, PPQ-unselected weights).
    pub fn raw(values: Vec<f32>) -> Self {
        StoredVar::Raw(values)
    }

    /// Element count of the variable.
    pub fn len(&self) -> usize {
        match self {
            StoredVar::Raw(v) => v.len(),
            StoredVar::Packed { n, .. } => *n,
        }
    }

    /// Whether the variable has zero elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Whether the variable is bit-packed (vs raw f32).
    pub fn is_packed(&self) -> bool {
        matches!(self, StoredVar::Packed { .. })
    }

    /// Decode to the quantized values Ṽ (no transform applied) — the exact
    /// f32 array the training graph receives as input.
    pub fn decode_tilde(&self) -> Vec<f32> {
        match self {
            StoredVar::Raw(v) => v.clone(),
            StoredVar::Packed { bytes, n, fmt, .. } => pack::unpack(bytes, *n, *fmt),
        }
    }

    /// [`decode_tilde`](Self::decode_tilde) into a reused buffer (cleared
    /// first, capacity retained — no allocation in the steady state).
    pub fn decode_tilde_into(&self, out: &mut Vec<f32>) {
        match self {
            StoredVar::Raw(v) => {
                out.clear();
                out.extend_from_slice(v);
            }
            StoredVar::Packed { bytes, n, fmt, .. } => {
                pack::unpack_into(bytes, *n, *fmt, out)
            }
        }
    }

    /// Decompress to the transformed view `V̄ = s·Ṽ + b` — the values the
    /// model actually computes with (single fused unpack+affine pass).
    pub fn decompress(&self) -> Vec<f32> {
        match self {
            StoredVar::Raw(v) => v.clone(),
            StoredVar::Packed { bytes, n, fmt, pvt } => {
                pack::unpack_transform(bytes, *n, *fmt, pvt.s, pvt.b)
            }
        }
    }

    /// [`decompress`](Self::decompress) into a reused buffer.
    pub fn decompress_into(&self, out: &mut Vec<f32>) {
        match self {
            StoredVar::Raw(v) => {
                out.clear();
                out.extend_from_slice(v);
            }
            StoredVar::Packed { bytes, n, fmt, pvt } => {
                pack::unpack_transform_into(bytes, *n, *fmt, pvt.s, pvt.b, out)
            }
        }
    }

    /// Borrowing decompressed view: `Raw` variables are returned as a
    /// borrow (no copy — the fix for the per-call clone the old
    /// `decompress` forced on unquantized variables); packed variables
    /// decode into an owned vector.
    pub fn as_f32s(&self) -> Cow<'_, [f32]> {
        match self {
            StoredVar::Raw(v) => Cow::Borrowed(v.as_slice()),
            StoredVar::Packed { .. } => Cow::Owned(self.decompress()),
        }
    }

    /// Consuming decompress: `Raw` variables are *moved* out (zero-copy),
    /// packed variables decode. Use when the store is dropped right after —
    /// e.g. the server's uplink-decode path.
    pub fn into_f32s(self) -> Vec<f32> {
        match self {
            StoredVar::Raw(v) => v,
            packed => packed.decompress(),
        }
    }

    /// The per-variable transform scalars (identity for raw variables).
    pub fn pvt(&self) -> Pvt {
        match self {
            StoredVar::Raw(_) => Pvt::IDENTITY,
            StoredVar::Packed { pvt, .. } => *pvt,
        }
    }

    /// Bytes this variable occupies in the store: payload + the PVT scalars
    /// for packed variables (the paper's accounting, DESIGN.md §5).
    pub fn memory_bytes(&self) -> usize {
        match self {
            StoredVar::Raw(v) => v.len() * 4,
            StoredVar::Packed { bytes, .. } => bytes.len() + 8, // + s, b
        }
    }
}

/// A full model in compressed form (one entry per manifest variable).
#[derive(Clone, Debug, Default)]
pub struct CompressedModel {
    /// the stored variables, in manifest order
    pub vars: Vec<StoredVar>,
}

impl CompressedModel {
    /// Wrap a list of stored variables (manifest order).
    pub fn new(vars: Vec<StoredVar>) -> Self {
        Self { vars }
    }

    /// Number of variables.
    pub fn num_vars(&self) -> usize {
        self.vars.len()
    }

    /// Total scalar parameter count across variables.
    pub fn num_params(&self) -> usize {
        self.vars.iter().map(|v| v.len()).sum()
    }

    /// Total parameter-store bytes (the Sec. 3.4 quantity).
    pub fn memory_bytes(&self) -> usize {
        self.vars.iter().map(|v| v.memory_bytes()).sum()
    }

    /// Memory relative to keeping every parameter in f32.
    pub fn memory_ratio(&self) -> f64 {
        let full = self.num_params() * 4;
        if full == 0 {
            return 1.0;
        }
        self.memory_bytes() as f64 / full as f64
    }

    /// Decompress every variable (the transient full-precision copy).
    pub fn decompress_all(&self) -> Vec<Vec<f32>> {
        self.vars.iter().map(|v| v.decompress()).collect()
    }

    /// Consuming [`decompress_all`](Self::decompress_all): raw variables
    /// move out without copying.
    pub fn into_decompressed(self) -> Vec<Vec<f32>> {
        self.vars.into_iter().map(|v| v.into_f32s()).collect()
    }
}

/// Bounded ring of recent committed model versions, stored compressed.
///
/// The async round engine (`fl::async_round`) commits a new global model
/// version every K buffered updates and pushes each committed version here
/// as a [`CompressedModel`] — the server applies the paper's own storage
/// discipline to its version history, so retaining R versions costs
/// R × compressed bytes instead of R × 4 bytes/param. Downlinks for
/// clients that train against version `v` are assembled from `get(v)`;
/// older entries stay addressable for analysis (per-commit parameter
/// drift, replay tooling) until the ring evicts them.
///
/// Versions must be pushed in strictly increasing order; pushing past
/// `capacity` evicts the oldest entry.
/// Entries are held behind `Arc` so concurrent readers (the wall-clock
/// serving engine's downlink path, `fl::serve`) can keep decoding a version
/// the writer has already evicted: `get_shared` hands out a clone of the
/// `Arc`, and eviction merely drops the ring's reference.
#[derive(Clone, Debug)]
pub struct SnapshotRing {
    cap: usize,
    entries: std::collections::VecDeque<(usize, Arc<CompressedModel>)>,
}

impl SnapshotRing {
    /// Empty ring retaining at most `capacity` versions (`capacity >= 1`).
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 1, "snapshot ring needs capacity >= 1");
        Self {
            cap: capacity,
            entries: std::collections::VecDeque::with_capacity(capacity),
        }
    }

    /// Retention capacity the ring was built with.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Number of versions currently retained.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Whether the ring holds no snapshots yet.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Push the snapshot for `version`, evicting the oldest entry when the
    /// ring is full. Versions must arrive in strictly increasing order.
    pub fn push(&mut self, version: usize, model: CompressedModel) {
        if let Some((newest, _)) = self.entries.back() {
            assert!(
                version > *newest,
                "snapshot versions must be strictly increasing ({version} after {newest})"
            );
        }
        if self.entries.len() == self.cap {
            self.entries.pop_front();
        }
        self.entries.push_back((version, Arc::new(model)));
    }

    /// The snapshot for `version`, if still retained.
    pub fn get(&self, version: usize) -> Option<&CompressedModel> {
        self.entries
            .iter()
            .find(|(v, _)| *v == version)
            .map(|(_, m)| m.as_ref())
    }

    /// A shared handle to the snapshot for `version`, if still retained.
    /// The handle stays valid after the ring evicts the version — the
    /// serving engine's downlink readers rely on this to keep decoding a
    /// snapshot the writer has moved past.
    pub fn get_shared(&self, version: usize) -> Option<Arc<CompressedModel>> {
        self.entries
            .iter()
            .find(|(v, _)| *v == version)
            .map(|(_, m)| Arc::clone(m))
    }

    /// The most recently pushed `(version, snapshot)`.
    pub fn newest(&self) -> Option<(usize, &CompressedModel)> {
        self.entries.back().map(|(v, m)| (*v, m.as_ref()))
    }

    /// Total store bytes across retained snapshots (the quantity the async
    /// bench reports against the R × 4 bytes/param fp32 alternative).
    pub fn memory_bytes(&self) -> usize {
        self.entries.iter().map(|(_, m)| m.memory_bytes()).sum()
    }
}

/// One published model version: the compressed snapshot (shared with the
/// [`SnapshotRing`]) plus its decoded working values, ready for downlink
/// assembly without touching the server thread.
#[derive(Debug)]
pub struct PublishedSnapshot {
    /// the committed version this snapshot serves
    pub version: usize,
    /// the compressed store entry (packed variables ship verbatim)
    pub model: Arc<CompressedModel>,
    /// decoded per-variable values for the raw/deselected downlink paths
    pub vals: Vec<Vec<f32>>,
}

/// Lock-free snapshot publication: the single-writer / many-reader epoch
/// pointer the wall-clock serving engine (`fl::serve`) downlinks from.
///
/// The writer stages the new `Arc<PublishedSnapshot>` in a mutex-guarded
/// slot, then *publishes* with one atomic `Release` store of the epoch
/// (`version + 1`; `0` = nothing published yet) and wakes waiters. Readers
/// ([`SnapshotReader`]) cache the `Arc` they last saw together with its
/// epoch, so the steady-state downlink read is **a single `Acquire` load
/// and no lock**: the slot mutex is touched only on an epoch *change* (once
/// per commit per reader, off the per-uplink path). This is the arc-swap
/// discipline without unsafe code — a bare `AtomicPtr` over `Arc` cannot be
/// read soundly without hazard pointers (the load→refcount-increment window
/// races the writer's drop), so the rare cold path pays an uncontended
/// mutex instead.
///
/// A reader holding an old `Arc` keeps a fully consistent snapshot while
/// the writer publishes and the ring evicts past it — eviction only drops
/// references (see `snapshot_publisher_reader_survives_eviction`).
#[derive(Debug, Default)]
pub struct SnapshotPublisher {
    /// `version + 1` of the current publication; `0` = none yet
    epoch: AtomicU64,
    /// the staged publication (locked only by the writer and by readers
    /// refreshing after an epoch change)
    slot: Mutex<Option<Arc<PublishedSnapshot>>>,
    /// wakes [`SnapshotReader::wait_for`] blockers on publish/shutdown
    cond: Condvar,
}

impl SnapshotPublisher {
    /// A publisher with nothing published yet.
    pub fn new() -> Self {
        Self::default()
    }

    /// Publish `snap` as the current version: stage the `Arc` under the
    /// slot lock, then flip the epoch with a single `Release` store and
    /// wake every waiter. Readers that loaded the old epoch keep their old
    /// `Arc`; readers that observe the new epoch see the fully staged slot
    /// (the `Release` store orders the slot write before it).
    pub fn publish(&self, snap: PublishedSnapshot) {
        let epoch = snap.version as u64 + 1;
        {
            let mut slot = self.slot.lock().unwrap();
            *slot = Some(Arc::new(snap));
        }
        self.epoch.store(epoch, Ordering::Release);
        self.cond.notify_all();
    }

    /// The currently published version, if any (single `Acquire` load).
    pub fn version(&self) -> Option<usize> {
        match self.epoch.load(Ordering::Acquire) {
            0 => None,
            e => Some((e - 1) as usize),
        }
    }

    /// Wake every [`SnapshotReader::wait_for`] blocker so it can re-check
    /// its cancellation condition (shutdown path).
    pub fn wake_all(&self) {
        self.cond.notify_all();
    }
}

/// Per-thread read handle over a [`SnapshotPublisher`]: caches the last
/// `Arc` seen so the hot path never locks (see the publisher docs).
#[derive(Debug, Default)]
pub struct SnapshotReader {
    cached: Option<Arc<PublishedSnapshot>>,
    seen: u64,
}

impl SnapshotReader {
    /// A reader that has observed nothing yet.
    pub fn new() -> Self {
        Self::default()
    }

    /// The current publication (or `None` before the first publish).
    /// Steady state — epoch unchanged since the last call — is one
    /// `Acquire` load and a cached-`Arc` clone; an epoch change refreshes
    /// the cache under the slot lock.
    pub fn current(&mut self, p: &SnapshotPublisher) -> Option<Arc<PublishedSnapshot>> {
        let e = p.epoch.load(Ordering::Acquire);
        if e == 0 {
            return None;
        }
        if e != self.seen {
            self.cached = p.slot.lock().unwrap().clone();
            self.seen = e;
        }
        self.cached.clone()
    }

    /// Block until a publication with `version >= want` is visible, or
    /// `cancelled()` turns true (checked at least every ~50 ms and on every
    /// publish/[`wake_all`](SnapshotPublisher::wake_all)). Returns `None`
    /// only on cancellation.
    pub fn wait_for(
        &mut self,
        p: &SnapshotPublisher,
        want: usize,
        mut cancelled: impl FnMut() -> bool,
    ) -> Option<Arc<PublishedSnapshot>> {
        loop {
            if let Some(snap) = self.current(p) {
                if snap.version >= want {
                    return Some(snap);
                }
            }
            if cancelled() {
                return None;
            }
            let guard = p.slot.lock().unwrap();
            // re-check under the lock: a publish between our epoch load and
            // this lock acquisition already fired its notify
            if p.epoch.load(Ordering::Acquire) >= want as u64 + 1 {
                continue;
            }
            let (_guard, _timeout) = p
                .cond
                .wait_timeout(guard, Duration::from_millis(50))
                .unwrap();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::omc::{quantize, transform};
    use crate::testkit::Gen;

    fn fmt(s: &str) -> FloatFormat {
        s.parse().unwrap()
    }

    #[test]
    fn compress_decompress_reduces_error_with_pvt() {
        let mut g = Gen::new(1);
        let v = g.vec_normal(4096, 0.02);
        let with = StoredVar::compress(&v, fmt("S1E3M7"), true);
        let without = StoredVar::compress(&v, fmt("S1E3M7"), false);
        let e_with = transform::mse(&v, &with.decompress());
        let e_without = transform::mse(&v, &without.decompress());
        assert!(e_with <= e_without + 1e-12);
        assert!(without.pvt().is_identity());
    }

    #[test]
    fn fp32_stores_raw() {
        let v = vec![0.1f32, 0.2, 0.3];
        let sv = StoredVar::compress(&v, FloatFormat::FP32, true);
        assert!(!sv.is_packed());
        assert_eq!(sv.decompress(), v);
        assert_eq!(sv.memory_bytes(), 12);
    }

    #[test]
    fn tilde_values_are_fixed_points() {
        let mut g = Gen::new(2);
        let v = g.vec_normal(1000, 0.1);
        let sv = StoredVar::compress(&v, fmt("S1E4M8"), true);
        for x in sv.decode_tilde() {
            assert!(quantize::is_representable(x, fmt("S1E4M8")));
        }
    }

    #[test]
    fn from_quantized_roundtrip() {
        let mut g = Gen::new(3);
        let v = quantize::quantize_vec(&g.vec_normal(500, 0.05), fmt("S1E3M7"));
        let pvt = Pvt { s: 1.25, b: -0.5 };
        let sv = StoredVar::from_quantized(&v, fmt("S1E3M7"), pvt).unwrap();
        let tilde = sv.decode_tilde();
        for (a, b) in tilde.iter().zip(&v) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(sv.pvt(), pvt);
    }

    #[test]
    fn memory_bytes_matches_formula() {
        let mut g = Gen::new(4);
        let n = 10_000;
        let v = g.vec_normal(n, 0.1);
        let f = fmt("S1E3M7");
        let sv = StoredVar::compress(&v, f, true);
        assert_eq!(sv.memory_bytes(), f.packed_bytes(n) + 8);
    }

    #[test]
    fn model_memory_ratio_table2_shape() {
        // all-weights model at S1E3M7, 90% quantized: ratio ~ 0.9*11/32+0.1
        let mut g = Gen::new(5);
        let f = fmt("S1E3M7");
        let mut vars = Vec::new();
        for i in 0..10 {
            let v = g.vec_normal(50_000, 0.05);
            vars.push(if i < 9 {
                StoredVar::compress(&v, f, true)
            } else {
                StoredVar::raw(v)
            });
        }
        let m = CompressedModel::new(vars);
        let expect = 0.9 * 11.0 / 32.0 + 0.1;
        assert!(
            (m.memory_ratio() - expect).abs() < 0.01,
            "{} vs {expect}",
            m.memory_ratio()
        );
    }

    #[test]
    fn empty_model() {
        let m = CompressedModel::default();
        assert_eq!(m.memory_bytes(), 0);
        assert_eq!(m.memory_ratio(), 1.0);
    }

    #[test]
    fn borrowing_and_consuming_accessors_agree() {
        let mut g = Gen::new(6);
        let v = g.vec_normal(700, 0.05);
        let raw = StoredVar::raw(v.clone());
        let packed = StoredVar::compress(&v, fmt("S1E3M7"), true);
        // as_f32s borrows for Raw (no copy), owns for Packed
        assert!(matches!(raw.as_f32s(), std::borrow::Cow::Borrowed(_)));
        assert!(matches!(packed.as_f32s(), std::borrow::Cow::Owned(_)));
        for sv in [&raw, &packed] {
            let reference = sv.decompress();
            assert_eq!(sv.as_f32s().as_ref(), reference.as_slice());
            let mut buf = Vec::new();
            sv.decompress_into(&mut buf);
            assert_eq!(buf, reference);
            let mut tilde = Vec::new();
            sv.decode_tilde_into(&mut tilde);
            assert_eq!(tilde, sv.decode_tilde());
        }
        // into_f32s moves the Raw storage (pointer-stable)
        let ptr = match &raw {
            StoredVar::Raw(v) => v.as_ptr(),
            _ => unreachable!(),
        };
        let moved = raw.into_f32s();
        assert_eq!(moved.as_ptr(), ptr, "Raw into_f32s must move, not copy");
        assert_eq!(packed.into_f32s(), packed2_reference(&v));
    }

    fn packed2_reference(v: &[f32]) -> Vec<f32> {
        StoredVar::compress(v, fmt("S1E3M7"), true).decompress()
    }

    #[test]
    fn snapshot_ring_evicts_oldest_and_accounts_memory() {
        let mut g = Gen::new(8);
        let f = fmt("S1E4M14");
        let mk = |g: &mut Gen| {
            CompressedModel::new(vec![
                StoredVar::compress(&g.vec_normal(2048, 0.05), f, true),
                StoredVar::raw(g.vec_normal(64, 1.0)),
            ])
        };
        let mut ring = SnapshotRing::new(3);
        assert!(ring.is_empty());
        assert_eq!(ring.memory_bytes(), 0);
        for v in 0..5 {
            ring.push(v, mk(&mut g));
        }
        assert_eq!(ring.len(), 3);
        assert_eq!(ring.capacity(), 3);
        // versions 0 and 1 were evicted; 2..=4 remain addressable
        assert!(ring.get(0).is_none());
        assert!(ring.get(1).is_none());
        for v in 2..5 {
            assert!(ring.get(v).is_some(), "version {v} missing");
        }
        let (newest, _) = ring.newest().unwrap();
        assert_eq!(newest, 4);
        // compressed retention beats R × fp32 for a mostly-packed model
        let fp32_bytes = 3 * (2048 + 64) * 4;
        assert!(ring.memory_bytes() < fp32_bytes);
        let per_snap = f.packed_bytes(2048) + 8 + 64 * 4;
        assert_eq!(ring.memory_bytes(), 3 * per_snap);
    }

    #[test]
    fn snapshot_ring_deep_wraparound_keeps_memory_and_lookup_bounded() {
        // the discard-heavy async regime: far more commits than the ring
        // retains, wrapping the backing deque many times over. The window
        // [v-cap+1, v] must stay addressable after every push (downlinks
        // for the newest version are assembled from `get`), everything
        // older must be gone, and evicted snapshots must actually release
        // their accounted bytes instead of accumulating.
        let mut g = Gen::new(9);
        let f = fmt("S1E4M14");
        let n = 1024;
        let per_snap = f.packed_bytes(n) + 8;
        let mut ring = SnapshotRing::new(2);
        for v in 0..50 {
            let m = CompressedModel::new(vec![StoredVar::compress(
                &g.vec_normal(n, 0.05),
                f,
                true,
            )]);
            ring.push(v, m);
            // the serving window after this push
            assert!(ring.get(v).is_some(), "newest version {v} must serve");
            if v >= 1 {
                assert!(ring.get(v - 1).is_some(), "version {} evicted early", v - 1);
            }
            if v >= 2 {
                assert!(ring.get(v - 2).is_none(), "version {} leaked", v - 2);
            }
            assert_eq!(ring.len(), (v + 1).min(2));
            assert_eq!(ring.memory_bytes(), ring.len() * per_snap);
            let (newest, snap) = ring.newest().unwrap();
            assert_eq!(newest, v);
            assert_eq!(snap.vars.len(), 1);
        }
        // a retained entry still round-trips its payload after wraparound
        let served = ring.get(49).unwrap();
        assert_eq!(served.decompress_all()[0].len(), n);
        // version keys need not be consecutive — only strictly increasing
        ring.push(60, CompressedModel::default());
        assert!(ring.get(49).is_some());
        assert!(ring.get(50).is_none());
        assert_eq!(ring.newest().unwrap().0, 60);
    }

    #[test]
    #[should_panic(expected = "strictly increasing")]
    fn snapshot_ring_rejects_stale_versions() {
        let mut ring = SnapshotRing::new(2);
        ring.push(3, CompressedModel::default());
        ring.push(3, CompressedModel::default());
    }

    #[test]
    fn snapshot_publisher_reader_survives_eviction() {
        // The serving engine's downlink contract: a reader holding an old
        // epoch pointer keeps decoding a fully consistent snapshot while
        // the writer publishes new versions and the ring evicts far past
        // it. Each version's payload is keyed to its number, so a torn or
        // mixed read would show up as a marker/payload mismatch.
        use std::sync::atomic::AtomicBool;
        let f = fmt("S1E4M14");
        let n = 512;
        let make = |v: usize| {
            let mut g = Gen::new(100 + v as u64);
            CompressedModel::new(vec![
                StoredVar::raw(vec![v as f32; 8]),
                StoredVar::compress(&g.vec_normal(n, 0.05), f, true),
            ])
        };
        let publisher = Arc::new(SnapshotPublisher::new());
        let stop = Arc::new(AtomicBool::new(false));
        let versions = 40;
        std::thread::scope(|scope| {
            for _ in 0..2 {
                let publisher = Arc::clone(&publisher);
                let stop = Arc::clone(&stop);
                scope.spawn(move || {
                    let mut reader = SnapshotReader::new();
                    // pin the first publication and hold it across every
                    // later publish + eviction
                    let pinned = reader
                        .wait_for(&publisher, 0, || false)
                        .expect("never cancelled");
                    let pinned_ref = pinned.model.decompress_all();
                    let mut epochs_seen = 0u64;
                    let mut last = None;
                    while !stop.load(Ordering::Relaxed) {
                        let snap = reader.current(&publisher).unwrap();
                        // marker and payload always belong to one version
                        assert_eq!(snap.vals[0][0], snap.version as f32);
                        assert_eq!(snap.model.decompress_all(), snap.vals);
                        if last != Some(snap.version) {
                            epochs_seen += 1;
                            last = Some(snap.version);
                        }
                        // the pinned (long-evicted) snapshot still decodes
                        // byte-identically
                        assert_eq!(pinned.model.decompress_all(), pinned_ref);
                    }
                    assert!(epochs_seen >= 1);
                });
            }
            let mut ring = SnapshotRing::new(2);
            for v in 0..versions {
                ring.push(v, make(v));
                let model = ring.get_shared(v).unwrap();
                let vals = model.decompress_all();
                publisher.publish(PublishedSnapshot { version: v, model, vals });
                assert_eq!(publisher.version(), Some(v));
                std::thread::yield_now();
            }
            stop.store(true, Ordering::Relaxed);
            publisher.wake_all();
        });
        // version 0 was evicted from the ring long ago...
        let mut ring_check = SnapshotRing::new(2);
        for v in 0..versions {
            ring_check.push(v, make(v));
        }
        assert!(ring_check.get_shared(0).is_none());
        // ...but a fresh reader still sees the final publication
        let mut reader = SnapshotReader::new();
        let last = reader.current(&publisher).unwrap();
        assert_eq!(last.version, versions - 1);
        assert_eq!(last.vals[0][0], (versions - 1) as f32);
    }

    #[test]
    fn into_decompressed_matches_decompress_all() {
        let mut g = Gen::new(7);
        let mk = |g: &mut Gen| {
            CompressedModel::new(vec![
                StoredVar::compress(&g.vec_normal(500, 0.05), fmt("S1E3M7"), true),
                StoredVar::raw(g.vec_normal(64, 1.0)),
            ])
        };
        let a = mk(&mut g).decompress_all();
        let mut g2 = Gen::new(7);
        let b = mk(&mut g2).into_decompressed();
        assert_eq!(a, b);
    }
}
