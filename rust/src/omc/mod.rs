//! Online Model Compression core (the paper's Sec. 2).
//!
//! * [`format`] — `SxEyMz` floating-point formats (Sec. 2.2).
//! * [`quantize`] — bit-exact mirror of the L1 Pallas kernel.
//! * [`transform`] — per-variable transformation (Sec. 2.3).
//! * [`pack`] — bit-packing of quantized values into (1+e+m)-bit codes;
//!   this is the *actual* in-memory / on-wire representation whose size the
//!   paper's memory and communication columns measure.
//! * [`store`] — the compressed parameter store kept by server and clients.
//! * [`selection`] — weight-matrices-only + partial parameter quantization
//!   (Secs. 2.4, 2.5).
//! * [`codec`] — the transport wire format and byte accounting.

pub mod codec;
pub mod fixed;
pub mod format;
pub mod pack;
pub mod quantize;
pub mod selection;
pub mod store;
pub mod transform;
