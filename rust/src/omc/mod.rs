//! Online Model Compression core (the paper's Sec. 2).
//!
//! * [`format`] — `SxEyMz` floating-point formats (Sec. 2.2).
//! * [`quantize`] — bit-exact mirror of the L1 Pallas kernel.
//! * [`transform`] — per-variable transformation (Sec. 2.3), including the
//!   streaming [`transform::FitAcc`] the fused pipelines share with `fit`.
//! * [`pack`] — bit-packing of quantized values into (1+e+m)-bit codes;
//!   this is the *actual* in-memory / on-wire representation whose size the
//!   paper's memory and communication columns measure.
//! * [`store`] — the compressed parameter store kept by server and clients.
//! * [`selection`] — weight-matrices-only + partial parameter quantization
//!   (Secs. 2.4, 2.5).
//! * [`codec`] — the transport wire format and byte accounting.
//! * [`delta`] — the lossless cross-round wire stage: XOR against a
//!   shared committed version + per-block variable-width bitpacking
//!   (frame v3; `docs/WIRE.md`).
//! * [`sparse`] — uplink sparsification (magnitude top-k / random-k)
//!   with per-client error-feedback residuals; tag-3 wire records carry
//!   a gap-coded bitpacked index stream plus the values in the
//!   variable's quantized format (`docs/COMPRESSION.md`).
//!
//! # Codec kernel layer (§Perf)
//!
//! OMC's compress/decompress is *online* — every simulated client round
//! pays quantize → transform → pack on the uplink and unpack → transform on
//! the downlink — so the codec is organized as a high-throughput kernel
//! layer rather than a per-value loop:
//!
//! * **SIMD lane kernels** ([`crate::util::simd`]): the elementwise hot
//!   loops — quantization, the PVT affine, the f64 fit sums, and the
//!   8/16-bit byte-lane block codecs — go through a dispatch table
//!   resolved once per process (AVX2 / SSE2 / scalar;
//!   `OMC_FORCE_SCALAR=1` pins scalar). Every vector path is bit-exact
//!   against the scalar reference; reductions use a fixed virtual lane
//!   schedule so even the PVT scalars are ISA-independent
//!   (`docs/PERFORMANCE.md` states the full contract).
//! * **Block kernels** ([`pack`]): values are processed in 256-value blocks
//!   through a 64-bit word accumulator. 256 is a multiple of 8, so a block
//!   spans exactly `32·w` bytes for a `w`-bit format — blocks are
//!   byte-aligned, independently codable, and the basis for the threaded
//!   variants. 8/16-bit-wide formats take the SIMD lane kernels; the
//!   paper's other table formats (`S1E4M14`, `S1E3M7`, `S1E2M3`) dispatch
//!   to const-generic monomorphized word kernels; everything else takes
//!   the same kernel with runtime parameters, and the original scalar
//!   path remains in-tree as the bit-exact reference.
//! * **Fused pipelines**: [`pack::quantize_transform_pack`] (uplink:
//!   quantize + PVT fit + pack in one pass) and
//!   [`pack::unpack_transform_into`] (downlink: unpack + affine in one
//!   pass) never materialize an intermediate quantized `Vec<f32>`.
//! * **Zero-alloc round loop**: every stage has a `*_into` variant writing
//!   into caller-owned buffers; `fl::client` reuses them across rounds so
//!   the steady state performs no per-variable heap allocation
//!   (`fl::client` module docs state the full contract).
//!
//! Correctness contract: block, fused, and threaded paths produce
//! byte-identical wire payloads and bit-identical decoded f32s vs. the
//! scalar reference — property-tested in `rust/tests/omc_kernels.rs`.

// This module is the crate's public compression API: every public item
// must carry documentation.
#![warn(missing_docs)]

pub mod codec;
pub mod delta;
pub mod fixed;
pub mod format;
pub mod pack;
pub mod quantize;
pub mod selection;
pub mod sparse;
pub mod store;
pub mod transform;
