//! Bit-packing of SxEyMz values — the *actual* compressed representation.
//!
//! The training graph works on decoded f32 values (every one exactly
//! representable in the target format); this module is what turns them into
//! the `(1+e+m)`-bit codes that sit in client memory and cross the network,
//! i.e. the bytes the paper's "Parameter Memory / Communication" column
//! counts.
//!
//! Encoding of one value (MSB-first within the code):
//! `[sign:1][exponent:e][mantissa:m]` with the target bias; exponent field 0
//! holds zero and subnormals, exactly as IEEE. Values must be representable
//! (`quantize` fixed points) — enforced with debug assertions and a checked
//! error in release via [`PackError`].

use super::format::FloatFormat;

#[derive(Debug, PartialEq)]
pub enum PackError {
    /// Value is not representable in the target format — the caller skipped
    /// quantization or the artifact and codec disagree.
    NotRepresentable { index: usize, value: f32 },
}

impl std::fmt::Display for PackError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PackError::NotRepresentable { index, value } => write!(
                f,
                "value {value:e} at index {index} is not representable in the target format"
            ),
        }
    }
}

impl std::error::Error for PackError {}

/// Encode one representable f32 into its `(1+e+m)`-bit code.
#[inline]
pub fn encode_one(x: f32, fmt: FloatFormat) -> u32 {
    let e = fmt.exp_bits;
    let m = fmt.mant_bits;
    let bias_f = fmt.bias();
    let u = x.to_bits();
    let sign = u >> 31;
    let mag = u & 0x7FFF_FFFF;
    if mag == 0 {
        return sign << (e + m);
    }
    let bexp32 = (mag >> 23) as i32;
    let frac32 = mag & 0x7F_FFFF;
    // f32-subnormal inputs behave as exponent -126 with no implicit bit;
    // they only occur for e=8 targets whose subnormals coincide with f32's.
    let (unb, significand) = if bexp32 == 0 {
        (-126, frac32) // 0.frac * 2^-126
    } else {
        (bexp32 - 127, 0x80_0000 | frac32) // 1.frac * 2^unb
    };
    let min_normal = fmt.min_normal_exp();
    if unb >= min_normal && bexp32 != 0 {
        // normal in the target: field = unb + bias, mantissa = top m bits
        let field = (unb + bias_f) as u32;
        let mant = frac32 >> (23 - m);
        debug_assert_eq!(mant << (23 - m), frac32, "non-representable normal");
        (sign << (e + m)) | (field << m) | mant
    } else {
        // subnormal in the target: value = k * 2^(min_normal - m)
        // k = significand * 2^(unb - 23 - (min_normal - m))
        let d = unb - 23 - (min_normal - m as i32);
        let k = if d >= 0 {
            (significand as u64) << d
        } else {
            let sh = (-d) as u32;
            debug_assert!(
                sh >= 64 || (significand as u64) & ((1u64 << sh.min(63)) - 1) == 0,
                "non-representable subnormal"
            );
            if sh >= 64 {
                0
            } else {
                (significand as u64) >> sh
            }
        };
        debug_assert!(k < (1u64 << m) || m == 0 && k == 0, "subnormal overflow");
        (sign << (e + m)) | (k as u32)
    }
}

/// Decode one `(1+e+m)`-bit code back to the exact f32 value.
///
/// Pure bit construction (§Perf: the original f64 `powi` path ran at
/// ~40 Melem/s; this runs branch-light on the integer units). `quantum` must
/// be `fmt.min_positive() as f32` — hoisted out by the bulk paths.
#[inline]
pub fn decode_one_with_quantum(code: u32, fmt: FloatFormat, quantum: f32) -> f32 {
    let e = fmt.exp_bits;
    let m = fmt.mant_bits;
    let sign = ((code >> (e + m)) & 1) << 31;
    let field = (code >> m) & ((1 << e) - 1);
    let mant = code & ((1 << m) - 1);
    if field == 0 {
        // zero or subnormal: mant * 2^(min_normal - m). Both operands exact,
        // the product has <= m significant bits at an in-range exponent, so
        // the f32 multiply is exact.
        let v = mant as f32 * quantum;
        f32::from_bits(sign | v.to_bits())
    } else {
        // normal: rebuild the f32 encoding directly
        let bexp32 = (field as i32 - fmt.bias() + 127) as u32;
        f32::from_bits(sign | (bexp32 << 23) | (mant << (23 - m)))
    }
}

/// Decode one code (convenience wrapper computing the quantum).
#[inline]
pub fn decode_one(code: u32, fmt: FloatFormat) -> f32 {
    decode_one_with_quantum(code, fmt, fmt.min_positive() as f32)
}

/// Pack a slice of representable values into bytes (little-endian bit
/// order: code 0 occupies the lowest bits of byte 0).
///
/// §Perf: rolling u64 bit accumulator flushing whole bytes — the original
/// scatter-OR into 5 output bytes per value ran at ~80–160 Melem/s.
pub fn pack(values: &[f32], fmt: FloatFormat) -> Result<Vec<u8>, PackError> {
    let width = fmt.bits() as usize;
    let mut out = Vec::with_capacity(fmt.packed_bytes(values.len()));
    let mut acc: u64 = 0;
    let mut nbits: usize = 0;
    for (i, &x) in values.iter().enumerate() {
        if cfg!(debug_assertions) && !super::quantize::is_representable(x, fmt) {
            return Err(PackError::NotRepresentable { index: i, value: x });
        }
        acc |= (encode_one(x, fmt) as u64) << nbits;
        nbits += width;
        while nbits >= 8 {
            out.push((acc & 0xFF) as u8);
            acc >>= 8;
            nbits -= 8;
        }
    }
    if nbits > 0 {
        out.push((acc & 0xFF) as u8);
    }
    debug_assert_eq!(out.len(), fmt.packed_bytes(values.len()));
    Ok(out)
}

/// Unpack `n` values from `bytes`.
///
/// §Perf: rolling accumulator + bit-construction decode (the original
/// 8-byte-window + f64 `powi` path ran at ~40 Melem/s).
pub fn unpack(bytes: &[u8], n: usize, fmt: FloatFormat) -> Vec<f32> {
    let mut out = Vec::with_capacity(n);
    unpack_into(bytes, n, fmt, |v| out.push(v));
    out
}

/// Unpack `n` values, applying the per-variable transform in the same pass
/// (`V̄ = s·Ṽ + b` in f32, the wire-contract decompression) — saves a full
/// re-traversal on the server's uplink-decode hot path.
pub fn unpack_transform(
    bytes: &[u8],
    n: usize,
    fmt: FloatFormat,
    s: f32,
    b: f32,
) -> Vec<f32> {
    let mut out = Vec::with_capacity(n);
    if s == 1.0 && b == 0.0 {
        unpack_into(bytes, n, fmt, |v| out.push(v));
    } else {
        unpack_into(bytes, n, fmt, |v| out.push(s * v + b));
    }
    out
}

#[inline]
fn unpack_into<F: FnMut(f32)>(bytes: &[u8], n: usize, fmt: FloatFormat, mut sink: F) {
    let width = fmt.bits() as usize;
    let mask = if width == 32 {
        u32::MAX as u64
    } else {
        (1u64 << width) - 1
    };
    let quantum = fmt.min_positive() as f32;
    let mut acc: u64 = 0;
    let mut nbits: usize = 0;
    let mut pos: usize = 0;
    for _ in 0..n {
        while nbits < width {
            acc |= (bytes[pos] as u64) << nbits;
            pos += 1;
            nbits += 8;
        }
        let code = (acc & mask) as u32;
        acc >>= width;
        nbits -= width;
        sink(decode_one_with_quantum(code, fmt, quantum));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::omc::quantize::{quantize_one, quantize_vec};
    use crate::testkit::{check, Gen};

    const FORMATS: [&str; 7] = [
        "S1E5M10", "S1E4M14", "S1E3M7", "S1E2M3", "S1E3M9", "S1E4M8",
        "S1E5M7",
    ];

    #[test]
    fn encode_decode_roundtrip_property() {
        check("pack_roundtrip", 60, |g| {
            let fmt: FloatFormat =
                FORMATS[g.usize_below(FORMATS.len())].parse().unwrap();
            let n = 1 + g.usize_below(3000);
            let scale = [1e-4f32, 0.05, 1.0, 100.0][g.usize_below(4)];
            let v = quantize_vec(&g.vec_normal(n, scale), fmt);
            let bytes = pack(&v, fmt).map_err(|e| e.to_string())?;
            if bytes.len() != fmt.packed_bytes(n) {
                return Err("wrong byte length".into());
            }
            let back = unpack(&bytes, n, fmt);
            for i in 0..n {
                if back[i].to_bits() != v[i].to_bits() {
                    return Err(format!(
                        "{fmt} index {i}: {:e} != {:e}",
                        back[i], v[i]
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn code_width_and_grid_exhaustive_small_format() {
        // S1E2M3 has 2^6 = 64 codes; the 16 with the all-ones exponent
        // field are reserved (IEEE inf/NaN slots — the encoder never emits
        // them because the quantizer saturates). Every *finite* code must
        // decode to a quantizer fixed point and re-encode to itself.
        let fmt: FloatFormat = "S1E2M3".parse().unwrap();
        let reserved_field = (1u32 << fmt.exp_bits) - 1;
        let mut seen = std::collections::BTreeSet::new();
        for code in 0u32..64 {
            let field = (code >> fmt.mant_bits) & ((1 << fmt.exp_bits) - 1);
            if field == reserved_field {
                continue;
            }
            let v = decode_one(code, fmt);
            assert_eq!(
                quantize_one(v, fmt).to_bits(),
                v.to_bits(),
                "code {code} -> {v:e} not a fixed point"
            );
            let code2 = encode_one(v, fmt);
            assert_eq!(code2, code, "code {code} -> {v:e} -> {code2}");
            seen.insert(v.to_bits());
        }
        // 2 signs x 3 fields x 8 mantissas = 48 distinct finite values
        // (+0.0 and -0.0 count separately at the bit level)
        assert_eq!(seen.len(), 48);
    }

    #[test]
    fn zero_codes() {
        for f in FORMATS {
            let fmt: FloatFormat = f.parse().unwrap();
            assert_eq!(encode_one(0.0, fmt), 0);
            assert_eq!(decode_one(0, fmt).to_bits(), 0.0f32.to_bits());
            let neg = encode_one(-0.0, fmt);
            assert_eq!(decode_one(neg, fmt).to_bits(), (-0.0f32).to_bits());
        }
    }

    #[test]
    fn subnormal_encoding() {
        let fmt: FloatFormat = "S1E3M7".parse().unwrap();
        let quantum = fmt.min_positive() as f32;
        for k in 0..128u32 {
            let v = k as f32 * quantum;
            let code = encode_one(v, fmt);
            assert_eq!(code, k, "k={k}");
            assert_eq!(decode_one(code, fmt), v);
        }
        // first normal
        let min_normal = 2f32.powi(fmt.min_normal_exp());
        let code = encode_one(min_normal, fmt);
        assert_eq!(code >> fmt.mant_bits, 1);
    }

    #[test]
    fn max_value_roundtrip() {
        for f in FORMATS {
            let fmt: FloatFormat = f.parse().unwrap();
            let max = fmt.max_value() as f32;
            let code = encode_one(max, fmt);
            assert_eq!(decode_one(code, fmt), max, "{f}");
            let ncode = encode_one(-max, fmt);
            assert_eq!(decode_one(ncode, fmt), -max, "{f}");
        }
    }

    #[test]
    fn pack_rejects_unrepresentable_in_debug() {
        if cfg!(debug_assertions) {
            let fmt: FloatFormat = "S1E3M7".parse().unwrap();
            let r = pack(&[0.1f32], fmt);
            assert!(matches!(r, Err(PackError::NotRepresentable { .. })));
        }
    }

    #[test]
    fn packed_size_is_the_paper_ratio() {
        // Table 2: S1E3M7 payload is 11/32 of FP32 for the quantized part
        let fmt: FloatFormat = "S1E3M7".parse().unwrap();
        let n = 320_000;
        assert_eq!(fmt.packed_bytes(n), n * 11 / 8 / 4 * 4); // 11 bits/value
        let ratio = fmt.packed_bytes(n) as f64 / (4 * n) as f64;
        assert!((ratio - 11.0 / 32.0).abs() < 1e-6);
    }

    #[test]
    fn unpack_handles_tail_bytes() {
        // n not divisible by 8/gcd(width,8): tail code straddles the final
        // partial byte
        let fmt: FloatFormat = "S1E3M7".parse().unwrap(); // 11 bits
        let vals = quantize_vec(&[0.3, -0.7, 0.0015], fmt);
        let bytes = pack(&vals, fmt).unwrap();
        assert_eq!(bytes.len(), (3 * 11 + 7) / 8);
        let back = unpack(&bytes, 3, fmt);
        for (a, b) in back.iter().zip(&vals) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn fp32_width_pack() {
        // degenerate: packing at S1E8M23 is just the raw bits
        let fmt = FloatFormat::FP32;
        let mut g = Gen::new(6);
        let v = g.vec_normal(100, 1.0);
        let bytes = pack(&v, fmt).unwrap();
        assert_eq!(bytes.len(), 400);
        let back = unpack(&bytes, 100, fmt);
        for (a, b) in back.iter().zip(&v) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}
