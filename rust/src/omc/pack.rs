//! Bit-packing of SxEyMz values — the *actual* compressed representation.
//!
//! The training graph works on decoded f32 values (every one exactly
//! representable in the target format); this module is what turns them into
//! the `(1+e+m)`-bit codes that sit in client memory and cross the network,
//! i.e. the bytes the paper's "Parameter Memory / Communication" column
//! counts.
//!
//! Encoding of one value (MSB-first within the code):
//! `[sign:1][exponent:e][mantissa:m]` with the target bias; exponent field 0
//! holds zero and subnormals, exactly as IEEE. Values must be representable
//! (`quantize` fixed points) — enforced with debug assertions and a checked
//! error in release via [`PackError`].
//!
//! # Block kernel layer (§Perf)
//!
//! The wire format is a single little-endian bitstream (code 0 occupies the
//! lowest bits of byte 0), but it is *processed* in fixed-size blocks of
//! [`BLOCK`] = 256 values. Because 256 is a multiple of 8, every block spans
//! exactly `256·w` bits = `32·w` bytes = `4·w` u64 words for a `w`-bit
//! format, so
//!
//! * blocks start and end on byte (indeed word) boundaries,
//! * each block can be encoded/decoded independently (the basis of the
//!   threaded variants), and
//! * the block kernels move whole 64-bit words instead of single bytes.
//!
//! Dispatch rules, fastest eligible path first:
//!
//! 1. **SIMD lane kernels** (`util::simd`): formats whose code width is
//!    exactly 8 or 16 bits (and `e` in `2..8`) are byte-lane formats — a block's
//!    bitstream is literally a little-endian `u8`/`u16` array — so whole
//!    blocks encode/decode 8 values per vector through the
//!    runtime-dispatched `pack_pow2`/`unpack_pow2` kernels (AVX2 shuffles
//!    narrow the lanes; the decoder fuses the PVT affine). `S1E5M10`, the
//!    paper's 16-bit format, takes this path.
//! 2. **Const-generic word kernels**: the paper's other table formats —
//!    `S1E4M14`, `S1E3M7`, `S1E2M3` — hit monomorphized block kernels
//!    (`*_mono::<E, M>`) whose shifts, masks and biases constant-fold.
//! 3. `S1E8M23` (plain f32) is a byte copy; every other format runs the
//!    generic block kernel with runtime `e`/`m`.
//!
//! The pre-block scalar path is kept in-tree as [`pack_scalar`] /
//! [`unpack_scalar`] — it is the correctness reference (every other path
//! must be **byte-identical**, asserted by the property tests in
//! `rust/tests/omc_kernels.rs`) and handles the `< 256` value tail of
//! every array.
//!
//! Zero-alloc contract: the `*_into` / `*_extend` variants write into
//! caller-provided buffers and never allocate beyond growing the
//! destination `Vec` to the (exactly known) output size — the steady-state
//! round loop in `fl::client` reuses those buffers across rounds so the
//! codec performs no per-variable heap allocation.

use super::format::FloatFormat;
use super::quantize::quantize_slice;
use super::transform::{FitAcc, Pvt};
use crate::util::simd;
use crate::util::threadpool;

/// Number of values per codec block. 256 keeps a block's f32 image (1 KiB)
/// and packed image (≤ 1 KiB) inside L1 while making every block span a
/// whole number of u64 words for any code width ≤ 32.
pub const BLOCK: usize = 256;

/// Below this many values the threaded variants fall back to single-thread
/// (thread hand-off costs more than the packing).
const PAR_MIN: usize = 8 * PAR_CHUNK_VALUES;
/// Values per parallel work item: 64 blocks ≈ 64 KiB of f32 input.
const PAR_CHUNK_VALUES: usize = 64 * BLOCK;

/// Why a pack of already-quantized values failed.
#[derive(Debug, PartialEq)]
pub enum PackError {
    /// Value is not representable in the target format — the caller skipped
    /// quantization or the artifact and codec disagree.
    NotRepresentable {
        /// index of the offending element
        index: usize,
        /// the non-representable value
        value: f32,
    },
}

impl std::fmt::Display for PackError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PackError::NotRepresentable { index, value } => write!(
                f,
                "value {value:e} at index {index} is not representable in the target format"
            ),
        }
    }
}

impl std::error::Error for PackError {}

/// Encode one representable f32 into its `(1+e+m)`-bit code.
#[inline(always)]
pub fn encode_one(x: f32, fmt: FloatFormat) -> u32 {
    let e = fmt.exp_bits;
    let m = fmt.mant_bits;
    let bias_f = fmt.bias();
    let u = x.to_bits();
    let sign = u >> 31;
    let mag = u & 0x7FFF_FFFF;
    if mag == 0 {
        return sign << (e + m);
    }
    let bexp32 = (mag >> 23) as i32;
    let frac32 = mag & 0x7F_FFFF;
    // f32-subnormal inputs behave as exponent -126 with no implicit bit;
    // they only occur for e=8 targets whose subnormals coincide with f32's.
    let (unb, significand) = if bexp32 == 0 {
        (-126, frac32) // 0.frac * 2^-126
    } else {
        (bexp32 - 127, 0x80_0000 | frac32) // 1.frac * 2^unb
    };
    let min_normal = fmt.min_normal_exp();
    if unb >= min_normal && bexp32 != 0 {
        // normal in the target: field = unb + bias, mantissa = top m bits
        let field = (unb + bias_f) as u32;
        let mant = frac32 >> (23 - m);
        debug_assert_eq!(mant << (23 - m), frac32, "non-representable normal");
        (sign << (e + m)) | (field << m) | mant
    } else {
        // subnormal in the target: value = k * 2^(min_normal - m)
        // k = significand * 2^(unb - 23 - (min_normal - m))
        let d = unb - 23 - (min_normal - m as i32);
        let k = if d >= 0 {
            (significand as u64) << d
        } else {
            let sh = (-d) as u32;
            debug_assert!(
                sh >= 64 || (significand as u64) & ((1u64 << sh.min(63)) - 1) == 0,
                "non-representable subnormal"
            );
            if sh >= 64 {
                0
            } else {
                (significand as u64) >> sh
            }
        };
        debug_assert!(k < (1u64 << m) || m == 0 && k == 0, "subnormal overflow");
        (sign << (e + m)) | (k as u32)
    }
}

/// Decode one `(1+e+m)`-bit code back to the exact f32 value.
///
/// Pure bit construction (§Perf: the original f64 `powi` path ran at
/// ~40 Melem/s; this runs branch-light on the integer units). `quantum` must
/// be `fmt.min_positive() as f32` — hoisted out by the bulk paths.
#[inline(always)]
pub fn decode_one_with_quantum(code: u32, fmt: FloatFormat, quantum: f32) -> f32 {
    let e = fmt.exp_bits;
    let m = fmt.mant_bits;
    let sign = ((code >> (e + m)) & 1) << 31;
    let field = (code >> m) & ((1 << e) - 1);
    let mant = code & ((1 << m) - 1);
    if field == 0 {
        // zero or subnormal: mant * 2^(min_normal - m). Both operands exact,
        // the product has <= m significant bits at an in-range exponent, so
        // the f32 multiply is exact.
        let v = mant as f32 * quantum;
        f32::from_bits(sign | v.to_bits())
    } else {
        // normal: rebuild the f32 encoding directly
        let bexp32 = (field as i32 - fmt.bias() + 127) as u32;
        f32::from_bits(sign | (bexp32 << 23) | (mant << (23 - m)))
    }
}

/// Decode one code (convenience wrapper computing the quantum).
#[inline]
pub fn decode_one(code: u32, fmt: FloatFormat) -> f32 {
    decode_one_with_quantum(code, fmt, fmt.min_positive() as f32)
}

// ---------------------------------------------------------------------------
// scalar reference path
// ---------------------------------------------------------------------------

/// Representability pre-check — same debug-only contract the scalar packer
/// always had: checked error in debug builds, trusted caller in release.
#[inline]
fn check_representable(values: &[f32], fmt: FloatFormat) -> Result<(), PackError> {
    if cfg!(debug_assertions) {
        for (i, &x) in values.iter().enumerate() {
            if !super::quantize::is_representable(x, fmt) {
                return Err(PackError::NotRepresentable { index: i, value: x });
            }
        }
    }
    Ok(())
}

/// Scalar bitstream packer writing into an exactly-sized slice. This is the
/// reference implementation the block kernels must match byte-for-byte; it
/// also encodes the sub-block tail of every array.
fn pack_scalar_slice(values: &[f32], fmt: FloatFormat, out: &mut [u8]) {
    let width = fmt.bits() as usize;
    let mut acc: u64 = 0;
    let mut nbits: usize = 0;
    let mut o = 0usize;
    for &x in values {
        acc |= (encode_one(x, fmt) as u64) << nbits;
        nbits += width;
        while nbits >= 8 {
            out[o] = (acc & 0xFF) as u8;
            o += 1;
            acc >>= 8;
            nbits -= 8;
        }
    }
    if nbits > 0 {
        out[o] = (acc & 0xFF) as u8;
        o += 1;
    }
    debug_assert_eq!(o, out.len());
}

/// Scalar reference packer (byte-granular accumulator, one value at a
/// time). Kept in-tree as the correctness baseline for the block kernels —
/// `pack` must produce byte-identical output.
pub fn pack_scalar(values: &[f32], fmt: FloatFormat) -> Result<Vec<u8>, PackError> {
    check_representable(values, fmt)?;
    let mut out = vec![0u8; fmt.packed_bytes(values.len())];
    pack_scalar_slice(values, fmt, &mut out);
    Ok(out)
}

/// Scalar bitstream decoder feeding values (in order) to `sink`.
#[inline]
fn unpack_scalar_sink<F: FnMut(f32)>(bytes: &[u8], n: usize, fmt: FloatFormat, mut sink: F) {
    let width = fmt.bits() as usize;
    let mask = if width == 32 {
        u32::MAX as u64
    } else {
        (1u64 << width) - 1
    };
    let quantum = fmt.min_positive() as f32;
    let mut acc: u64 = 0;
    let mut nbits: usize = 0;
    let mut pos: usize = 0;
    for _ in 0..n {
        while nbits < width {
            acc |= (bytes[pos] as u64) << nbits;
            pos += 1;
            nbits += 8;
        }
        let code = (acc & mask) as u32;
        acc >>= width;
        nbits -= width;
        sink(decode_one_with_quantum(code, fmt, quantum));
    }
}

/// Scalar reference decoder — the baseline `unpack` must match bit-for-bit.
pub fn unpack_scalar(bytes: &[u8], n: usize, fmt: FloatFormat) -> Vec<f32> {
    let mut out = Vec::with_capacity(n);
    unpack_scalar_sink(bytes, n, fmt, |v| out.push(v));
    out
}

// ---------------------------------------------------------------------------
// block kernels (word-level, 256 values / block)
// ---------------------------------------------------------------------------

/// Pack whole blocks (`values.len() % BLOCK == 0`) into an exactly-sized
/// slice using a rolling u64 accumulator that flushes whole words.
///
/// Loop invariants (`w = fmt.bits() ≤ 32`): `nbits < 64` on entry to every
/// iteration; a flush happens only when `nbits + w ≥ 64`, i.e. `nbits ≥ 32`,
/// so both shifts (`code << nbits`, `code >> (64 - nbits)`) stay in range.
/// A block is `256·w` bits = a whole number of u64 words, so `nbits == 0`
/// at block end and the final word is always flushed.
#[inline(always)]
fn pack_blocks_body(values: &[f32], fmt: FloatFormat, out: &mut [u8]) {
    let width = fmt.bits();
    let bpb = BLOCK * width as usize / 8;
    debug_assert_eq!(values.len() % BLOCK, 0);
    debug_assert_eq!(out.len(), values.len() / BLOCK * bpb);
    for (chunk, obuf) in values.chunks_exact(BLOCK).zip(out.chunks_exact_mut(bpb)) {
        let mut acc: u64 = 0;
        let mut nbits: u32 = 0;
        let mut o = 0usize;
        for &x in chunk {
            let code = encode_one(x, fmt) as u64;
            acc |= code << nbits;
            let total = nbits + width;
            if total >= 64 {
                obuf[o..o + 8].copy_from_slice(&acc.to_le_bytes());
                o += 8;
                acc = code >> (64 - nbits);
                nbits = total - 64;
            } else {
                nbits = total;
            }
        }
        debug_assert_eq!(nbits, 0);
        debug_assert_eq!(o, bpb);
    }
}

/// Const-generic instantiation: `E`/`M` become compile-time constants so the
/// format-dependent shifts and masks in `encode_one` constant-fold.
fn pack_blocks_mono<const E: u32, const M: u32>(values: &[f32], out: &mut [u8]) {
    pack_blocks_body(values, FloatFormat { exp_bits: E, mant_bits: M }, out);
}

/// Whether `fmt` is a byte-lane format eligible for the SIMD block
/// kernels: code width exactly 8 or 16 bits and `e` in `2..8`. `e = 8`
/// formats other than plain f32 are exotic and `1/quantum` would leave
/// the normal f32 range; `e = 1` (bias 0) makes every finite value
/// subnormal-coded including the non-grid-aligned saturation value
/// `2 − 2^−m`, where the SIMD encoder's exact-multiple assumption and
/// the scalar shift truncation disagree — both stay on the word
/// kernels, which match `encode_one` bit for bit on every input.
#[inline]
fn pow2_lane_format(fmt: FloatFormat) -> bool {
    (2..8).contains(&fmt.exp_bits) && matches!(fmt.bits(), 8 | 16)
}

/// Whole-block packer with the fast-path dispatch (see module docs).
fn pack_blocks(values: &[f32], fmt: FloatFormat, out: &mut [u8]) {
    if pow2_lane_format(fmt) {
        if let Some(kernel) = simd::kernels().pack_pow2 {
            kernel(values, fmt.exp_bits, fmt.mant_bits, out);
            return;
        }
    }
    match (fmt.exp_bits, fmt.mant_bits) {
        (5, 10) => pack_blocks_mono::<5, 10>(values, out),
        (4, 14) => pack_blocks_mono::<4, 14>(values, out),
        (3, 7) => pack_blocks_mono::<3, 7>(values, out),
        (2, 3) => pack_blocks_mono::<2, 3>(values, out),
        _ => pack_blocks_body(values, fmt, out),
    }
}

/// Decode whole blocks from an exactly-sized byte slice, applying `map` to
/// every decoded value (identity or the PVT affine — monomorphized per
/// closure type, so the fused transform costs one fma in-register).
///
/// Mirrors `pack_blocks_body`: reads whole u64 words; `nbits < 64` always,
/// and the refill branch runs only when `nbits < w ≤ 32`, keeping all three
/// shifts in range.
#[inline(always)]
fn unpack_blocks_body<F: Fn(f32) -> f32 + Copy>(
    bytes: &[u8],
    fmt: FloatFormat,
    out: &mut [f32],
    map: F,
) {
    let width = fmt.bits();
    let mask: u64 = if width == 32 {
        u32::MAX as u64
    } else {
        (1u64 << width) - 1
    };
    let quantum = fmt.min_positive() as f32;
    let bpb = BLOCK * width as usize / 8;
    debug_assert_eq!(out.len() % BLOCK, 0);
    debug_assert_eq!(bytes.len(), out.len() / BLOCK * bpb);
    for (obuf, chunk) in out.chunks_exact_mut(BLOCK).zip(bytes.chunks_exact(bpb)) {
        let mut acc: u64 = 0;
        let mut nbits: u32 = 0;
        let mut i = 0usize;
        for o in obuf.iter_mut() {
            let code = if nbits >= width {
                let c = acc & mask;
                acc >>= width;
                nbits -= width;
                c
            } else {
                let word = u64::from_le_bytes(chunk[i..i + 8].try_into().unwrap());
                i += 8;
                let c = (acc | (word << nbits)) & mask;
                acc = word >> (width - nbits);
                nbits += 64 - width;
                c
            };
            *o = map(decode_one_with_quantum(code as u32, fmt, quantum));
        }
        debug_assert_eq!(nbits, 0);
        debug_assert_eq!(i, bpb);
    }
}

fn unpack_blocks_mono<const E: u32, const M: u32, F: Fn(f32) -> f32 + Copy>(
    bytes: &[u8],
    out: &mut [f32],
    map: F,
) {
    unpack_blocks_body(bytes, FloatFormat { exp_bits: E, mant_bits: M }, out, map);
}

fn unpack_blocks<F: Fn(f32) -> f32 + Copy>(
    bytes: &[u8],
    fmt: FloatFormat,
    out: &mut [f32],
    map: F,
) {
    match (fmt.exp_bits, fmt.mant_bits) {
        (5, 10) => unpack_blocks_mono::<5, 10, F>(bytes, out, map),
        (4, 14) => unpack_blocks_mono::<4, 14, F>(bytes, out, map),
        (3, 7) => unpack_blocks_mono::<3, 7, F>(bytes, out, map),
        (2, 3) => unpack_blocks_mono::<2, 3, F>(bytes, out, map),
        _ => unpack_blocks_body(bytes, fmt, out, map),
    }
}

/// Decode whole blocks applying the optional PVT affine (`Some((s, b))`;
/// `None` is the bit-preserving identity). Byte-lane formats go through
/// the SIMD dispatch table; everything else takes the word kernels with
/// the map monomorphized per closure.
fn unpack_blocks_affine(
    bytes: &[u8],
    fmt: FloatFormat,
    out: &mut [f32],
    map: Option<(f32, f32)>,
) {
    if pow2_lane_format(fmt) {
        if let Some(kernel) = simd::kernels().unpack_pow2 {
            let quantum = fmt.min_positive() as f32;
            kernel(bytes, fmt.exp_bits, fmt.mant_bits, quantum, map, out);
            return;
        }
    }
    match map {
        None => unpack_blocks(bytes, fmt, out, |v| v),
        Some((s, b)) => unpack_blocks(bytes, fmt, out, move |v| s * v + b),
    }
}

/// Fill an exactly-sized slice: blocks via the kernel dispatch, tail via
/// the scalar reference, the optional affine applied to every value.
fn unpack_slice_affine(
    bytes: &[u8],
    fmt: FloatFormat,
    out: &mut [f32],
    map: Option<(f32, f32)>,
) {
    if fmt.is_fp32() {
        // degenerate 32-bit format: the payload is the raw f32 LE image
        for (o, c) in out.iter_mut().zip(bytes.chunks_exact(4)) {
            let v = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
            *o = match map {
                None => v,
                Some((s, b)) => s * v + b,
            };
        }
        return;
    }
    let n = out.len();
    let nb = n / BLOCK * BLOCK;
    let split = fmt.packed_bytes(nb); // block region is byte-aligned
    unpack_blocks_affine(&bytes[..split], fmt, &mut out[..nb], map);
    let tail = &mut out[nb..];
    let mut i = 0;
    unpack_scalar_sink(&bytes[split..], n - nb, fmt, |v| {
        tail[i] = match map {
            None => v,
            Some((s, b)) => s * v + b,
        };
        i += 1;
    });
}

// ---------------------------------------------------------------------------
// public bulk API
// ---------------------------------------------------------------------------

/// Pack a slice of representable values into bytes (little-endian bit
/// order: code 0 occupies the lowest bits of byte 0). Block fast path; the
/// output is byte-identical to [`pack_scalar`].
///
/// Values must be quantizer fixed points: debug builds reject others via
/// [`PackError`]; in release builds the payload for a non-representable
/// value is unspecified and — since the SIMD byte-lane encoder rounds
/// where the scalar encoder truncates — may differ across ISA paths.
pub fn pack(values: &[f32], fmt: FloatFormat) -> Result<Vec<u8>, PackError> {
    let mut out = Vec::new();
    pack_extend(values, fmt, &mut out)?;
    Ok(out)
}

/// Pack into a reused buffer (cleared first; capacity is retained across
/// calls — the zero-alloc steady state).
pub fn pack_into(values: &[f32], fmt: FloatFormat, out: &mut Vec<u8>) -> Result<(), PackError> {
    out.clear();
    pack_extend(values, fmt, out)
}

/// Pack, *appending* to `out` (used by the wire writer to emit payloads
/// directly into the frame buffer with no intermediate allocation).
pub fn pack_extend(
    values: &[f32],
    fmt: FloatFormat,
    out: &mut Vec<u8>,
) -> Result<(), PackError> {
    check_representable(values, fmt)?;
    let start = out.len();
    out.resize(start + fmt.packed_bytes(values.len()), 0);
    let dst = &mut out[start..];
    if fmt.is_fp32() {
        for (c, &x) in dst.chunks_exact_mut(4).zip(values) {
            c.copy_from_slice(&x.to_le_bytes());
        }
        return Ok(());
    }
    let nb = values.len() / BLOCK * BLOCK;
    let split = fmt.packed_bytes(nb);
    let (head, tail) = dst.split_at_mut(split);
    pack_blocks(&values[..nb], fmt, head);
    pack_scalar_slice(&values[nb..], fmt, tail);
    Ok(())
}

/// Multi-threaded pack for large tensors: whole-block chunks fan out over
/// the scoped thread pool; the (byte-aligned) chunks land in disjoint spans
/// of the output, so the result is byte-identical to the serial path.
pub fn pack_threaded(
    values: &[f32],
    fmt: FloatFormat,
    workers: usize,
) -> Result<Vec<u8>, PackError> {
    check_representable(values, fmt)?;
    let n = values.len();
    if workers <= 1 || n < PAR_MIN || fmt.is_fp32() {
        return pack(values, fmt);
    }
    let mut out = vec![0u8; fmt.packed_bytes(n)];
    let nb = n / BLOCK * BLOCK;
    let split = fmt.packed_bytes(nb);
    let bpb = BLOCK * fmt.bits() as usize / 8;
    {
        let (head, tail) = out.split_at_mut(split);
        let items: Vec<(&[f32], &mut [u8])> = values[..nb]
            .chunks(PAR_CHUNK_VALUES)
            .zip(head.chunks_mut(PAR_CHUNK_VALUES / BLOCK * bpb))
            .collect();
        threadpool::scope_map_send(items, workers, |_, (v, o)| pack_blocks(v, fmt, o))
            .expect("pack worker panicked");
        pack_scalar_slice(&values[nb..], fmt, tail);
    }
    Ok(out)
}

/// Unpack `n` values from `bytes` (block fast path, bit-identical to
/// [`unpack_scalar`]).
pub fn unpack(bytes: &[u8], n: usize, fmt: FloatFormat) -> Vec<f32> {
    let mut out = Vec::new();
    unpack_into(bytes, n, fmt, &mut out);
    out
}

/// Unpack into a reused buffer (cleared first, capacity retained).
pub fn unpack_into(bytes: &[u8], n: usize, fmt: FloatFormat, out: &mut Vec<f32>) {
    out.clear();
    out.resize(n, 0.0);
    unpack_slice_affine(bytes, fmt, out, None);
}

/// Unpack `n` values, applying the per-variable transform in the same pass
/// (`V̄ = s·Ṽ + b` in f32, the wire-contract decompression) — saves a full
/// re-traversal on the server's uplink-decode hot path.
pub fn unpack_transform(bytes: &[u8], n: usize, fmt: FloatFormat, s: f32, b: f32) -> Vec<f32> {
    let mut out = Vec::new();
    unpack_transform_into(bytes, n, fmt, s, b, &mut out);
    out
}

/// Fused unpack + transform into a reused buffer: the downlink decode path
/// never materializes an intermediate `Vec<f32>` of quantized values.
pub fn unpack_transform_into(
    bytes: &[u8],
    n: usize,
    fmt: FloatFormat,
    s: f32,
    b: f32,
    out: &mut Vec<f32>,
) {
    out.clear();
    out.resize(n, 0.0);
    if s == 1.0 && b == 0.0 {
        unpack_slice_affine(bytes, fmt, out, None);
    } else {
        unpack_slice_affine(bytes, fmt, out, Some((s, b)));
    }
}

/// Multi-threaded fused unpack+transform for large tensors (block chunks
/// over the thread pool; bit-identical to the serial path).
pub fn unpack_transform_into_threaded(
    bytes: &[u8],
    n: usize,
    fmt: FloatFormat,
    s: f32,
    b: f32,
    workers: usize,
    out: &mut Vec<f32>,
) {
    if workers <= 1 || n < PAR_MIN || fmt.is_fp32() {
        return unpack_transform_into(bytes, n, fmt, s, b, out);
    }
    out.clear();
    out.resize(n, 0.0);
    let nb = n / BLOCK * BLOCK;
    let split = fmt.packed_bytes(nb);
    let bpb = BLOCK * fmt.bits() as usize / 8;
    let (head, tail) = out.split_at_mut(nb);
    let identity = s == 1.0 && b == 0.0;
    let map = if identity { None } else { Some((s, b)) };
    let items: Vec<(&[u8], &mut [f32])> = bytes[..split]
        .chunks(PAR_CHUNK_VALUES / BLOCK * bpb)
        .zip(head.chunks_mut(PAR_CHUNK_VALUES))
        .collect();
    threadpool::scope_map_send(items, workers, |_, (bseg, oseg)| {
        unpack_blocks_affine(bseg, fmt, oseg, map)
    })
    .expect("unpack worker panicked");
    let mut i = 0;
    unpack_scalar_sink(&bytes[split..], n - nb, fmt, |v| {
        tail[i] = if identity { v } else { s * v + b };
        i += 1;
    });
}

// ---------------------------------------------------------------------------
// fused uplink pipeline: quantize → PVT fit → pack in one pass
// ---------------------------------------------------------------------------

/// Single-pass compress: quantize each 256-value block into a stack buffer,
/// feed the (value, quantized) pairs to the PVT least-squares accumulator,
/// and bit-pack the block — no intermediate `Vec<f32>` of quantized values
/// is ever materialized. Appends the payload to `out` and returns the
/// fitted transform (identity when `use_pvt` is false).
///
/// Bit-exactness: the f64 fit sums accumulate in the same element order as
/// `transform::fit` over `quantize::quantize_vec`, and the packed bytes go
/// through the same block kernels as `pack`, so payload and PVT scalars are
/// identical to the separate-pass reference (property-tested in
/// `rust/tests/omc_kernels.rs`).
///
/// ```
/// use omc_fl::omc::pack;
/// use omc_fl::FloatFormat;
///
/// let fmt: FloatFormat = "S1E3M7".parse().unwrap();
/// let values = vec![0.25f32, -0.5, 0.125, 1.0, -0.0625];
///
/// // uplink: quantize → PVT-fit → bit-pack, one pass
/// let mut payload = Vec::new();
/// let pvt = pack::quantize_transform_pack(&values, fmt, true, &mut payload);
/// assert_eq!(payload.len(), fmt.packed_bytes(values.len()));
///
/// // downlink: unpack + affine transform, one pass
/// let mut decoded = Vec::new();
/// pack::unpack_transform_into(&payload, values.len(), fmt, pvt.s, pvt.b, &mut decoded);
/// assert_eq!(decoded.len(), values.len());
/// ```
pub fn quantize_transform_pack(
    values: &[f32],
    fmt: FloatFormat,
    use_pvt: bool,
    out: &mut Vec<u8>,
) -> Pvt {
    // quantize / fit / pack each do their own kernel dispatch per block,
    // so no per-format monomorphization is needed at this level
    qtp_body(values, fmt, use_pvt, out)
}

fn qtp_body(values: &[f32], fmt: FloatFormat, use_pvt: bool, out: &mut Vec<u8>) -> Pvt {
    let width = fmt.bits() as usize;
    let start = out.len();
    out.resize(start + fmt.packed_bytes(values.len()), 0);
    let dst = &mut out[start..];
    let mut q = [0.0f32; BLOCK];
    let mut acc = FitAcc::new();
    let mut off = 0usize;
    for chunk in values.chunks(BLOCK) {
        let qs = &mut q[..chunk.len()];
        quantize_slice(chunk, fmt, qs);
        if use_pvt {
            acc.update(chunk, qs);
        }
        let nbytes = (chunk.len() * width + 7) / 8;
        let seg = &mut dst[off..off + nbytes];
        if chunk.len() == BLOCK {
            pack_blocks(qs, fmt, seg);
        } else {
            pack_scalar_slice(qs, fmt, seg);
        }
        off += nbytes;
    }
    debug_assert_eq!(off, dst.len());
    if use_pvt {
        acc.finish()
    } else {
        Pvt::IDENTITY
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::omc::quantize::{quantize_one, quantize_vec};
    use crate::omc::transform;
    use crate::testkit::{check, Gen};

    const FORMATS: [&str; 7] = [
        "S1E5M10", "S1E4M14", "S1E3M7", "S1E2M3", "S1E3M9", "S1E4M8",
        "S1E5M7",
    ];

    #[test]
    fn encode_decode_roundtrip_property() {
        check("pack_roundtrip", 60, |g| {
            let fmt: FloatFormat =
                FORMATS[g.usize_below(FORMATS.len())].parse().unwrap();
            let n = 1 + g.usize_below(3000);
            let scale = [1e-4f32, 0.05, 1.0, 100.0][g.usize_below(4)];
            let v = quantize_vec(&g.vec_normal(n, scale), fmt);
            let bytes = pack(&v, fmt).map_err(|e| e.to_string())?;
            if bytes.len() != fmt.packed_bytes(n) {
                return Err("wrong byte length".into());
            }
            let back = unpack(&bytes, n, fmt);
            for i in 0..n {
                if back[i].to_bits() != v[i].to_bits() {
                    return Err(format!(
                        "{fmt} index {i}: {:e} != {:e}",
                        back[i], v[i]
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn block_path_matches_scalar_reference_property() {
        // the core correctness contract of the block kernel layer:
        // byte-identical payloads, bit-identical decodes, across formats,
        // scales, and tail lengths (incl. exactly-BLOCK boundaries)
        check("block_vs_scalar", 80, |g| {
            let fmt: FloatFormat =
                FORMATS[g.usize_below(FORMATS.len())].parse().unwrap();
            let n = match g.usize_below(5) {
                0 => g.usize_below(BLOCK),               // scalar only
                1 => BLOCK * (1 + g.usize_below(4)),     // whole blocks
                _ => 1 + g.usize_below(3 * BLOCK),       // blocks + tail
            };
            let scale = [1e-6f32, 0.05, 1.0, 1e4][g.usize_below(4)];
            let v = quantize_vec(&g.vec_normal(n, scale), fmt);
            let reference = pack_scalar(&v, fmt).map_err(|e| e.to_string())?;
            let fast = pack(&v, fmt).map_err(|e| e.to_string())?;
            if reference != fast {
                return Err(format!("{fmt} n={n}: pack bytes differ"));
            }
            let a = unpack_scalar(&reference, n, fmt);
            let b = unpack(&fast, n, fmt);
            for i in 0..n {
                if a[i].to_bits() != b[i].to_bits() {
                    return Err(format!("{fmt} n={n} idx {i}: decode differs"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn fused_pipeline_matches_separate_passes() {
        check("fused_qtp", 40, |g| {
            let fmt: FloatFormat =
                FORMATS[g.usize_below(FORMATS.len())].parse().unwrap();
            let n = 1 + g.usize_below(2000);
            let scale = [1e-5f32, 0.05, 10.0][g.usize_below(3)];
            let v = g.vec_normal(n, scale);
            let use_pvt = g.usize_below(2) == 0;
            // reference: three separate passes
            let vt = quantize_vec(&v, fmt);
            let ref_pvt = if use_pvt {
                transform::fit(&v, &vt)
            } else {
                Pvt::IDENTITY
            };
            let ref_bytes = pack_scalar(&vt, fmt).map_err(|e| e.to_string())?;
            // fused single pass
            let mut bytes = Vec::new();
            let pvt = quantize_transform_pack(&v, fmt, use_pvt, &mut bytes);
            if bytes != ref_bytes {
                return Err(format!("{fmt} n={n}: fused payload differs"));
            }
            if pvt.s.to_bits() != ref_pvt.s.to_bits()
                || pvt.b.to_bits() != ref_pvt.b.to_bits()
            {
                return Err(format!(
                    "{fmt} n={n}: pvt {pvt:?} != {ref_pvt:?}"
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn into_variants_reuse_capacity() {
        let fmt: FloatFormat = "S1E3M7".parse().unwrap();
        let mut g = Gen::new(21);
        let v = quantize_vec(&g.vec_normal(4096, 0.05), fmt);
        let mut bytes = Vec::new();
        pack_into(&v, fmt, &mut bytes).unwrap();
        let cap = bytes.capacity();
        let ptr = bytes.as_ptr();
        pack_into(&v, fmt, &mut bytes).unwrap();
        assert_eq!(bytes.capacity(), cap);
        assert_eq!(bytes.as_ptr(), ptr, "pack_into must not reallocate");
        let mut out = Vec::new();
        unpack_into(&bytes, v.len(), fmt, &mut out);
        let optr = out.as_ptr();
        unpack_into(&bytes, v.len(), fmt, &mut out);
        assert_eq!(out.as_ptr(), optr, "unpack_into must not reallocate");
        for (a, b) in out.iter().zip(&v) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn threaded_variants_match_serial() {
        let fmt: FloatFormat = "S1E3M7".parse().unwrap();
        let mut g = Gen::new(23);
        // large enough to cross PAR_MIN, with a non-block tail
        let v = quantize_vec(&g.vec_normal(PAR_MIN + 777, 0.05), fmt);
        let serial = pack(&v, fmt).unwrap();
        for workers in [1, 2, 5] {
            let par = pack_threaded(&v, fmt, workers).unwrap();
            assert_eq!(serial, par, "workers={workers}");
            let mut out = Vec::new();
            unpack_transform_into_threaded(
                &par, v.len(), fmt, 1.5, -0.25, workers, &mut out,
            );
            let reference = unpack_transform(&serial, v.len(), fmt, 1.5, -0.25);
            assert_eq!(
                out.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                reference.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
            );
        }
    }

    #[test]
    fn code_width_and_grid_exhaustive_small_format() {
        // S1E2M3 has 2^6 = 64 codes; the 16 with the all-ones exponent
        // field are reserved (IEEE inf/NaN slots — the encoder never emits
        // them because the quantizer saturates). Every *finite* code must
        // decode to a quantizer fixed point and re-encode to itself.
        let fmt: FloatFormat = "S1E2M3".parse().unwrap();
        let reserved_field = (1u32 << fmt.exp_bits) - 1;
        let mut seen = std::collections::BTreeSet::new();
        for code in 0u32..64 {
            let field = (code >> fmt.mant_bits) & ((1 << fmt.exp_bits) - 1);
            if field == reserved_field {
                continue;
            }
            let v = decode_one(code, fmt);
            assert_eq!(
                quantize_one(v, fmt).to_bits(),
                v.to_bits(),
                "code {code} -> {v:e} not a fixed point"
            );
            let code2 = encode_one(v, fmt);
            assert_eq!(code2, code, "code {code} -> {v:e} -> {code2}");
            seen.insert(v.to_bits());
        }
        // 2 signs x 3 fields x 8 mantissas = 48 distinct finite values
        // (+0.0 and -0.0 count separately at the bit level)
        assert_eq!(seen.len(), 48);
    }

    #[test]
    fn zero_codes() {
        for f in FORMATS {
            let fmt: FloatFormat = f.parse().unwrap();
            assert_eq!(encode_one(0.0, fmt), 0);
            assert_eq!(decode_one(0, fmt).to_bits(), 0.0f32.to_bits());
            let neg = encode_one(-0.0, fmt);
            assert_eq!(decode_one(neg, fmt).to_bits(), (-0.0f32).to_bits());
        }
    }

    #[test]
    fn subnormal_encoding() {
        let fmt: FloatFormat = "S1E3M7".parse().unwrap();
        let quantum = fmt.min_positive() as f32;
        for k in 0..128u32 {
            let v = k as f32 * quantum;
            let code = encode_one(v, fmt);
            assert_eq!(code, k, "k={k}");
            assert_eq!(decode_one(code, fmt), v);
        }
        // first normal
        let min_normal = 2f32.powi(fmt.min_normal_exp());
        let code = encode_one(min_normal, fmt);
        assert_eq!(code >> fmt.mant_bits, 1);
    }

    #[test]
    fn max_value_roundtrip() {
        for f in FORMATS {
            let fmt: FloatFormat = f.parse().unwrap();
            let max = fmt.max_value() as f32;
            let code = encode_one(max, fmt);
            assert_eq!(decode_one(code, fmt), max, "{f}");
            let ncode = encode_one(-max, fmt);
            assert_eq!(decode_one(ncode, fmt), -max, "{f}");
        }
    }

    #[test]
    fn pack_rejects_unrepresentable_in_debug() {
        if cfg!(debug_assertions) {
            let fmt: FloatFormat = "S1E3M7".parse().unwrap();
            let r = pack(&[0.1f32], fmt);
            assert!(matches!(r, Err(PackError::NotRepresentable { .. })));
            let r = pack_scalar(&[0.1f32], fmt);
            assert!(matches!(r, Err(PackError::NotRepresentable { .. })));
        }
    }

    #[test]
    fn packed_size_is_the_paper_ratio() {
        // Table 2: S1E3M7 payload is 11/32 of FP32 for the quantized part
        let fmt: FloatFormat = "S1E3M7".parse().unwrap();
        let n = 320_000;
        assert_eq!(fmt.packed_bytes(n), n * 11 / 8 / 4 * 4); // 11 bits/value
        let ratio = fmt.packed_bytes(n) as f64 / (4 * n) as f64;
        assert!((ratio - 11.0 / 32.0).abs() < 1e-6);
    }

    #[test]
    fn unpack_handles_tail_bytes() {
        // n not divisible by 8/gcd(width,8): tail code straddles the final
        // partial byte
        let fmt: FloatFormat = "S1E3M7".parse().unwrap(); // 11 bits
        let vals = quantize_vec(&[0.3, -0.7, 0.0015], fmt);
        let bytes = pack(&vals, fmt).unwrap();
        assert_eq!(bytes.len(), (3 * 11 + 7) / 8);
        let back = unpack(&bytes, 3, fmt);
        for (a, b) in back.iter().zip(&vals) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn fp32_width_pack() {
        // degenerate: packing at S1E8M23 is just the raw bits
        let fmt = FloatFormat::FP32;
        let mut g = Gen::new(6);
        let v = g.vec_normal(100, 1.0);
        let bytes = pack(&v, fmt).unwrap();
        assert_eq!(bytes.len(), 400);
        assert_eq!(bytes, pack_scalar(&v, fmt).unwrap());
        let back = unpack(&bytes, 100, fmt);
        for (a, b) in back.iter().zip(&v) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn unpack_transform_preserves_identity_bits() {
        // s=1, b=0 must take the bit-copy path: -0.0 stays -0.0 (an affine
        // -0.0*1+0 would flip it to +0.0)
        let fmt: FloatFormat = "S1E3M7".parse().unwrap();
        let vals = quantize_vec(&[-0.0f32, 0.5, -0.25], fmt);
        let bytes = pack(&vals, fmt).unwrap();
        let back = unpack_transform(&bytes, 3, fmt, 1.0, 0.0);
        assert_eq!(back[0].to_bits(), (-0.0f32).to_bits());
    }
}
