//! Variable selection: weight-matrices-only (Sec. 2.4) + partial parameter
//! quantization (Sec. 2.5).
//!
//! Each client in each round quantizes a random fraction (90% in the paper)
//! of the *eligible* variables; the subset is re-drawn per (round, client)
//! from a deterministic seed so runs replay exactly and the server can
//! reconstruct any client's mask.

use crate::model::manifest::{VarKind, VarSpec};
use crate::util::rng::{hash_seed, Xoshiro256pp};

/// Static selection policy for an experiment.
#[derive(Clone, Copy, Debug)]
pub struct SelectionPolicy {
    /// Only `kind == Weight` variables are eligible (Sec. 2.4). Disabled in
    /// the Table-4 ablation rows that quantize everything.
    pub weights_only: bool,
    /// Fraction of eligible variables each client quantizes (Sec. 2.5;
    /// 1.0 = APQ, 0.9 = the paper's PPQ setting).
    pub fraction: f64,
}

impl SelectionPolicy {
    /// Baseline: nothing quantized (used with `FloatFormat::FP32`).
    pub fn fp32() -> Self {
        Self { weights_only: true, fraction: 0.0 }
    }

    /// The paper's PPQ setting: 90% of the weight matrices per client.
    pub fn paper_default() -> Self {
        Self { weights_only: true, fraction: 0.9 }
    }

    /// Whether a variable may be quantized at all under this policy.
    pub fn eligible(&self, spec: &VarSpec) -> bool {
        !self.weights_only || spec.kind == VarKind::Weight
    }

    /// Draw the 0/1 quantization mask for (round, client).
    ///
    /// Exactly `round(fraction * n_eligible)` eligible variables get mask 1,
    /// chosen uniformly; ineligible variables always get 0. The same
    /// (seed, round, client) triple always yields the same mask.
    pub fn draw_mask(
        &self,
        specs: &[VarSpec],
        seed: u64,
        round: u64,
        client: u64,
    ) -> Vec<f32> {
        let eligible: Vec<usize> = specs
            .iter()
            .enumerate()
            .filter(|(_, s)| self.eligible(s))
            .map(|(i, _)| i)
            .collect();
        let k = ((self.fraction * eligible.len() as f64).round() as usize)
            .min(eligible.len());
        let mut mask = vec![0.0f32; specs.len()];
        if k == 0 {
            return mask;
        }
        let mut rng =
            Xoshiro256pp::new(hash_seed(&[seed, 0x5e1ec7, round, client]));
        for j in rng.sample_indices(eligible.len(), k) {
            mask[eligible[j]] = 1.0;
        }
        mask
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::{VarKind, VarSpec};

    fn specs() -> Vec<VarSpec> {
        let mut v = Vec::new();
        for i in 0..10 {
            v.push(VarSpec {
                name: format!("w{i}"),
                shape: vec![4, 4],
                kind: VarKind::Weight,
                size: 16,
            });
        }
        v.push(VarSpec {
            name: "ln".into(),
            shape: vec![4],
            kind: VarKind::NormScale,
            size: 4,
        });
        v.push(VarSpec {
            name: "b".into(),
            shape: vec![4],
            kind: VarKind::Bias,
            size: 4,
        });
        v
    }

    #[test]
    fn weights_only_excludes_norm_and_bias() {
        let p = SelectionPolicy { weights_only: true, fraction: 1.0 };
        let mask = p.draw_mask(&specs(), 1, 0, 0);
        assert_eq!(&mask[10..], &[0.0, 0.0]);
        assert!(mask[..10].iter().all(|&m| m == 1.0));
    }

    #[test]
    fn fraction_selects_exact_count() {
        let p = SelectionPolicy { weights_only: true, fraction: 0.9 };
        for client in 0..50 {
            let mask = p.draw_mask(&specs(), 7, 3, client);
            let count: f32 = mask.iter().sum();
            assert_eq!(count, 9.0); // round(0.9 * 10)
        }
    }

    #[test]
    fn deterministic_per_round_client() {
        let p = SelectionPolicy::paper_default();
        let a = p.draw_mask(&specs(), 42, 5, 17);
        let b = p.draw_mask(&specs(), 42, 5, 17);
        assert_eq!(a, b);
    }

    #[test]
    fn varies_across_clients_and_rounds() {
        let p = SelectionPolicy::paper_default();
        let base = p.draw_mask(&specs(), 42, 5, 0);
        let mut differs = 0;
        for client in 1..40 {
            if p.draw_mask(&specs(), 42, 5, client) != base {
                differs += 1;
            }
        }
        assert!(differs > 20, "selection should vary across clients");
        assert_ne!(p.draw_mask(&specs(), 42, 6, 0), base);
    }

    #[test]
    fn every_weight_selected_somewhere() {
        // Sec. 2.5 rationale: across many clients, every parameter gets
        // unquantized (precise) updates from the 10% holdout — equivalently
        // every variable must be *excluded* by at least one client.
        let p = SelectionPolicy::paper_default();
        let s = specs();
        let mut excluded = vec![false; 10];
        for client in 0..200 {
            let mask = p.draw_mask(&s, 9, 0, client);
            for i in 0..10 {
                if mask[i] == 0.0 {
                    excluded[i] = true;
                }
            }
        }
        assert!(excluded.iter().all(|&e| e), "{excluded:?}");
    }

    #[test]
    fn fp32_policy_selects_nothing() {
        let p = SelectionPolicy::fp32();
        let mask = p.draw_mask(&specs(), 1, 0, 0);
        assert!(mask.iter().all(|&m| m == 0.0));
    }

    #[test]
    fn all_params_policy_includes_everything() {
        let p = SelectionPolicy { weights_only: false, fraction: 1.0 };
        let mask = p.draw_mask(&specs(), 1, 0, 0);
        assert!(mask.iter().all(|&m| m == 1.0));
    }
}
