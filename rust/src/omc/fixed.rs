//! Fixed-point quantization — the alternative compression format the paper
//! names in Sec. 2.2 ("other formats, such as the fixed-point format, can
//! also be used").
//!
//! A variable is stored as signed `bits`-bit integers under a per-variable
//! affine map `x ≈ scale·q + zero` fitted to the value range (symmetric
//! mode forces `zero = 0`, the usual choice for weights). This is the
//! standard INT-k scheme; it complements the SxEyMz path and lets the
//! ablation example compare float-vs-fixed at equal bitwidths.

use crate::util::rng::Xoshiro256pp;

/// Fixed-point format descriptor.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FixedFormat {
    /// total bits per value, 2..=16 (sign included)
    pub bits: u32,
    /// force zero-point 0 (symmetric; standard for weights)
    pub symmetric: bool,
}

impl FixedFormat {
    /// Validated constructor (`bits` in 2..=16).
    pub fn new(bits: u32, symmetric: bool) -> anyhow::Result<Self> {
        anyhow::ensure!((2..=16).contains(&bits), "fixed bits in 2..=16");
        Ok(Self { bits, symmetric })
    }

    /// Largest representable code `2^(bits-1) - 1`.
    pub fn qmax(&self) -> i32 {
        (1i32 << (self.bits - 1)) - 1
    }

    /// Smallest representable code `-2^(bits-1)`.
    pub fn qmin(&self) -> i32 {
        -(1i32 << (self.bits - 1))
    }

    /// Bytes needed to store `n` codes bit-packed at this width.
    pub fn packed_bytes(&self, n: usize) -> usize {
        (n * self.bits as usize + 7) / 8
    }
}

/// A fixed-point-compressed variable.
#[derive(Clone, Debug)]
pub struct FixedVar {
    /// bit-packed two's-complement codes
    pub codes: Vec<u8>,
    /// element count
    pub n: usize,
    /// the fixed-point format the codes use
    pub fmt: FixedFormat,
    /// affine scale in `x ≈ scale·q + zero`
    pub scale: f32,
    /// affine zero-point (0 in symmetric mode)
    pub zero: f32,
}

/// Quantize a variable to fixed point (round-to-nearest-even, saturating).
pub fn compress(v: &[f32], fmt: FixedFormat) -> FixedVar {
    let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
    for &x in v {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    if !lo.is_finite() || !hi.is_finite() || v.is_empty() {
        lo = 0.0;
        hi = 0.0;
    }
    let (scale, zero) = if fmt.symmetric {
        let amax = lo.abs().max(hi.abs());
        let scale = if amax == 0.0 {
            1.0
        } else {
            amax / fmt.qmax() as f32
        };
        (scale, 0.0f32)
    } else {
        let range = (hi - lo).max(f32::MIN_POSITIVE);
        let scale = range / (fmt.qmax() - fmt.qmin()) as f32;
        (scale, lo - fmt.qmin() as f32 * (range / (fmt.qmax() - fmt.qmin()) as f32))
    };

    let width = fmt.bits as usize;
    let mask = (1u64 << width) - 1;
    let mut codes = Vec::with_capacity(fmt.packed_bytes(v.len()));
    let (mut acc, mut nbits) = (0u64, 0usize);
    for &x in v {
        let q = ((x - zero) / scale).round_ties_even() as i64;
        let q = q.clamp(fmt.qmin() as i64, fmt.qmax() as i64);
        acc |= ((q as u64) & mask) << nbits;
        nbits += width;
        while nbits >= 8 {
            codes.push((acc & 0xFF) as u8);
            acc >>= 8;
            nbits -= 8;
        }
    }
    if nbits > 0 {
        codes.push((acc & 0xFF) as u8);
    }
    FixedVar {
        codes,
        n: v.len(),
        fmt,
        scale,
        zero,
    }
}

/// Decompress back to f32.
pub fn decompress(fv: &FixedVar) -> Vec<f32> {
    let width = fv.fmt.bits as usize;
    let mask = (1u64 << width) - 1;
    let sign_bit = 1u64 << (width - 1);
    let mut out = Vec::with_capacity(fv.n);
    let (mut acc, mut nbits, mut pos) = (0u64, 0usize, 0usize);
    for _ in 0..fv.n {
        while nbits < width {
            acc |= (fv.codes[pos] as u64) << nbits;
            pos += 1;
            nbits += 8;
        }
        let raw = acc & mask;
        acc >>= width;
        nbits -= width;
        // sign-extend two's complement
        let q = if raw & sign_bit != 0 {
            (raw | !mask) as i64
        } else {
            raw as i64
        };
        out.push(fv.scale * q as f32 + fv.zero);
    }
    out
}

/// Memory bytes for the paper-style accounting (payload + scale + zero).
pub fn memory_bytes(fv: &FixedVar) -> usize {
    fv.codes.len() + 8
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::omc::transform::mse;
    use crate::testkit::Gen;

    #[test]
    fn roundtrip_error_bounded_by_half_step() {
        let mut g = Gen::new(1);
        for bits in [4, 8, 12, 16] {
            let fmt = FixedFormat::new(bits, true).unwrap();
            let v = g.vec_normal(4096, 0.05);
            let fv = compress(&v, fmt);
            let back = decompress(&fv);
            let max_err = v
                .iter()
                .zip(&back)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(
                max_err <= fv.scale * 0.5 + 1e-9,
                "bits={bits} err={max_err} scale={}",
                fv.scale
            );
        }
    }

    #[test]
    fn asymmetric_handles_shifted_ranges() {
        let mut g = Gen::new(2);
        let v: Vec<f32> = g.vec_normal(2048, 0.01).iter().map(|x| x + 1.0).collect();
        let sym = compress(&v, FixedFormat::new(6, true).unwrap());
        let asym = compress(&v, FixedFormat::new(6, false).unwrap());
        let e_sym = mse(&v, &decompress(&sym));
        let e_asym = mse(&v, &decompress(&asym));
        assert!(
            e_asym < e_sym,
            "asym {e_asym:e} should beat sym {e_sym:e} on shifted data"
        );
    }

    #[test]
    fn constant_and_zero_variables() {
        for val in [0.0f32, 3.25] {
            let v = vec![val; 64];
            let fv = compress(&v, FixedFormat::new(8, true).unwrap());
            let back = decompress(&fv);
            for b in back {
                assert!((b - val).abs() <= fv.scale * 0.5 + 1e-9);
            }
        }
        let fv = compress(&[], FixedFormat::new(8, true).unwrap());
        assert!(decompress(&fv).is_empty());
    }

    #[test]
    fn saturates_outliers() {
        let mut v = vec![0.01f32; 100];
        v[0] = f32::INFINITY; // forces lo/hi reset path? no — inf max
        // inf range is degenerate: fall back must not panic
        let fmt = FixedFormat::new(8, true).unwrap();
        let fv = compress(&v, fmt);
        let back = decompress(&fv);
        assert_eq!(back.len(), 100);
        assert!(back.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn packed_size_matches_bits() {
        let fmt = FixedFormat::new(6, true).unwrap();
        let mut g = Gen::new(3);
        let v = g.vec_normal(1000, 0.1);
        let fv = compress(&v, fmt);
        assert_eq!(fv.codes.len(), (1000 * 6 + 7) / 8);
        assert_eq!(memory_bytes(&fv), fv.codes.len() + 8);
    }

    #[test]
    fn float_bounds_relative_error_fixed_does_not() {
        // the trade-off behind the paper's format choice: at equal bits a
        // uniform (fixed-point) grid can win on MSE over a bounded range,
        // but floating point bounds the *relative* error of every weight
        // regardless of magnitude — which is what keeps small-magnitude
        // layers trainable. Measure max relative error over a wide
        // dynamic-range mixture at equal 13-bit budgets.
        let mut g = Gen::new(4);
        let mut v = g.vec_normal(16_384, 0.02);
        for (i, x) in v.iter_mut().enumerate() {
            if i % 7 == 0 {
                *x *= 100.0; // mixture of scales, like real layers
            }
        }
        let rel_err = |dec: &[f32]| -> f64 {
            v.iter()
                .zip(dec)
                .filter(|(a, _)| a.abs() > 1e-3)
                .map(|(a, b)| ((a - b).abs() / a.abs()) as f64)
                .fold(0.0, f64::max)
        };
        let fx = compress(&v, FixedFormat::new(13, true).unwrap());
        let fixed_rel = rel_err(&decompress(&fx));
        let fmt: crate::omc::format::FloatFormat = "S1E5M7".parse().unwrap();
        let vt = crate::omc::quantize::quantize_vec(&v, fmt);
        let float_rel = rel_err(&vt);
        // S1E5M7 guarantees <= 2^-8 relative error for all normals
        assert!(float_rel < 0.005, "float rel {float_rel}");
        assert!(
            fixed_rel > 10.0 * float_rel,
            "fixed rel {fixed_rel} vs float rel {float_rel}"
        );
    }

    #[test]
    fn rejects_bad_bits() {
        assert!(FixedFormat::new(1, true).is_err());
        assert!(FixedFormat::new(17, true).is_err());
    }
}
