//! `SxEyMz` floating-point formats (paper Sec. 2.2).
//!
//! A format has 1 sign bit, `e` exponent bits and `m` mantissa bits,
//! IEEE-like: bias `2^(e-1)-1`, reserved all-ones exponent (so the maximum
//! finite unbiased exponent equals the bias), gradual underflow. `S1E8M23`
//! is exactly f32 and quantization to it is the identity.

use std::fmt;
use std::str::FromStr;

/// An `SxEyMz` storage format: 1 sign bit, `exp_bits` exponent bits,
/// `mant_bits` mantissa bits (parse one with `"S1E4M14".parse()`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct FloatFormat {
    /// exponent bits `e`, 1..=8
    pub exp_bits: u32,
    /// mantissa bits `m`, 0..=23
    pub mant_bits: u32,
}

impl FloatFormat {
    /// `S1E8M23` — exactly f32; quantization to it is the identity and the
    /// store/transport layers ship such variables raw.
    pub const FP32: FloatFormat = FloatFormat { exp_bits: 8, mant_bits: 23 };
    /// IEEE binary16 (used for the Sec. 3.4 memory measurement).
    pub const FP16: FloatFormat = FloatFormat { exp_bits: 5, mant_bits: 10 };

    /// Validated constructor (same rules the `FromStr` parser applies).
    pub fn new(exp_bits: u32, mant_bits: u32) -> anyhow::Result<Self> {
        anyhow::ensure!(
            (1..=8).contains(&exp_bits),
            "exponent bits must be in 1..=8, got {exp_bits}"
        );
        anyhow::ensure!(
            mant_bits <= 23,
            "mantissa bits must be <= 23, got {mant_bits}"
        );
        // The subnormal rounding path requires m <= 22 unless the format is
        // exactly f32 (see kernels/ref.py); every format in the paper obeys
        // this.
        anyhow::ensure!(
            mant_bits <= 22 || exp_bits == 8,
            "m = 23 is only supported with e = 8 (plain f32)"
        );
        Ok(Self { exp_bits, mant_bits })
    }

    /// Total storage bits per value: 1 + e + m.
    pub fn bits(&self) -> u32 {
        1 + self.exp_bits + self.mant_bits
    }

    /// Whether this is plain f32 (the no-compression baseline).
    pub fn is_fp32(&self) -> bool {
        *self == Self::FP32
    }

    /// IEEE-style exponent bias `2^(e-1) - 1`.
    pub fn bias(&self) -> i32 {
        (1i32 << (self.exp_bits - 1)) - 1
    }

    /// Smallest normal unbiased exponent `1 - bias`.
    pub fn min_normal_exp(&self) -> i32 {
        1 - self.bias()
    }

    /// Largest finite value `(2 - 2^-m) * 2^bias`.
    pub fn max_value(&self) -> f64 {
        (2.0 - (0.5f64).powi(self.mant_bits as i32 + 1) * 2.0)
            * 2f64.powi(self.bias())
    }

    /// Smallest positive (subnormal) value `2^(min_normal - m)`.
    pub fn min_positive(&self) -> f64 {
        2f64.powi(self.min_normal_exp() - self.mant_bits as i32)
    }

    /// Bytes needed to store `n` values bit-packed at this format.
    pub fn packed_bytes(&self, n: usize) -> usize {
        (n * self.bits() as usize + 7) / 8
    }
}

impl fmt::Display for FloatFormat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "S1E{}M{}", self.exp_bits, self.mant_bits)
    }
}

impl FromStr for FloatFormat {
    type Err = anyhow::Error;

    /// Parse the paper's `SxEyMz` notation (sign bits must be 1).
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let err = || anyhow::anyhow!("bad float format {s:?}; expected e.g. S1E4M14");
        let rest = s.strip_prefix('S').ok_or_else(err)?;
        let epos = rest.find('E').ok_or_else(err)?;
        let mpos = rest.find('M').ok_or_else(err)?;
        anyhow::ensure!(epos < mpos, "bad float format {s:?}");
        let sign: u32 = rest[..epos].parse().map_err(|_| err())?;
        anyhow::ensure!(sign == 1, "only 1 sign bit is supported, got {sign}");
        let e: u32 = rest[epos + 1..mpos].parse().map_err(|_| err())?;
        let m: u32 = rest[mpos + 1..].parse().map_err(|_| err())?;
        FloatFormat::new(e, m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_paper_formats() {
        for (txt, e, m, bits) in [
            ("S1E8M23", 8, 23, 32),
            ("S1E4M14", 4, 14, 19),
            ("S1E3M7", 3, 7, 11),
            ("S1E2M3", 2, 3, 6),
            ("S1E5M10", 5, 10, 16),
            ("S1E3M9", 3, 9, 13),
            ("S1E4M8", 4, 8, 13),
            ("S1E5M7", 5, 7, 13),
        ] {
            let f: FloatFormat = txt.parse().unwrap();
            assert_eq!((f.exp_bits, f.mant_bits), (e, m), "{txt}");
            assert_eq!(f.bits(), bits, "{txt}");
            assert_eq!(f.to_string(), txt);
        }
    }

    #[test]
    fn rejects_bad_formats() {
        for bad in ["", "S1E9M2", "S2E4M4", "E4M14", "S1E4", "S1M4E4",
                    "S1E0M3", "S1E4M24", "S1E4M23"] {
            assert!(bad.parse::<FloatFormat>().is_err(), "{bad}");
        }
        // m=23 allowed only for e=8
        assert!("S1E8M23".parse::<FloatFormat>().is_ok());
    }

    #[test]
    fn fp32_constants() {
        let f = FloatFormat::FP32;
        assert!(f.is_fp32());
        assert_eq!(f.bias(), 127);
        assert_eq!(f.min_normal_exp(), -126);
        assert_eq!(f.max_value(), f32::MAX as f64);
    }

    #[test]
    fn fp16_range() {
        let f = FloatFormat::FP16;
        assert_eq!(f.bias(), 15);
        assert_eq!(f.max_value(), 65504.0);
        assert_eq!(f.min_positive(), 2f64.powi(-24));
    }

    #[test]
    fn packed_bytes_rounding() {
        let f: FloatFormat = "S1E3M7".parse().unwrap(); // 11 bits
        assert_eq!(f.packed_bytes(0), 0);
        assert_eq!(f.packed_bytes(1), 2);  // 11 bits -> 2 bytes
        assert_eq!(f.packed_bytes(8), 11); // 88 bits -> 11 bytes
    }

    #[test]
    fn memory_ratio_matches_paper_table1() {
        // Table 1: S1E4M14 on 90% of weights ~= 64% of FP32. With weights
        // ~99.8% of the model: 0.9*19/32 + 0.1 ~= 0.634.
        let f: FloatFormat = "S1E4M14".parse().unwrap();
        let ratio = 0.9 * f.bits() as f64 / 32.0 + 0.1;
        assert!((ratio - 0.634).abs() < 0.001);
    }
}
