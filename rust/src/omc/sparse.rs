//! Uplink sparsification with error feedback (ROADMAP item 3).
//!
//! Quantization shrinks every shipped value; sparsification ships fewer
//! of them. This module provides the second compression family the sweep
//! grid composes with the quantized formats: per-variable **magnitude
//! top-k** and **random-k** selection over the client's error-corrected
//! update, with per-client **error-feedback residuals** so the mass a
//! round leaves behind is added back into the next round's update before
//! selection (Konečný et al., arXiv:1610.05492; pruning × quantization
//! per Grativol et al., arXiv:2310.14693).
//!
//! The pieces, in wire order:
//!
//! 1. **Selection** ([`select_topk`] / [`select_randk`]): pick `k =
//!    clamp(ceil(fraction·n), 1, n)` coordinates of the corrected update
//!    `e = (trained − downlink) + residual`. Top-k orders by magnitude
//!    bits with an index tie-break — a total order, so the selection is
//!    bit-exact on every ISA. Random-k draws a keyed partial
//!    Fisher–Yates from the `(seed, round, cid, var)` stream
//!    ([`sparse_key`] / [`var_seed`]), so A/B runs stay stream-aligned.
//! 2. **Index stream** ([`encode_indices_into`] /
//!    [`decode_indices_into`]): the sorted indices are gap-coded
//!    (`d₀ = i₀`, `dⱼ = iⱼ − iⱼ₋₁ − 1`) and bitpacked in blocks of
//!    [`GAPS_PER_BLOCK`] = 64 gaps, each block led by a class-header
//!    byte `w ∈ 0..=32` — the significant width of the block's OR-fold,
//!    exactly the [`delta`](crate::omc::delta) block scheme scaled to
//!    u32 gaps. Decoding is strict: impossible widths, short streams,
//!    leftover bytes, and out-of-range reconstructed indices all surface
//!    as a typed [`SparseIndexError`].
//! 3. **Value stream**: the `k` gathered values ride in the variable's
//!    existing `SxEyMz` format via the fused uplink pipeline — the
//!    tag-3 wire record in [`codec`](crate::omc::codec) carries both
//!    streams under the v2/v3 CRC integrity contract.
//! 4. **Error feedback** ([`ClientResidual`] / [`SparseStore`]): the new
//!    residual is the corrected update with the selected coordinates
//!    zeroed — a bitwise partition, so `scatter(selected) + residual ==
//!    e` holds exactly (f64 accumulation property-tested in
//!    `rust/tests/wire_sparse.rs`). The store is keyed by client id and
//!    committed in plan order by the round engines, keeping summaries
//!    byte-identical for any worker count.
//!
//! `docs/COMPRESSION.md` documents the record layout, the bitpacking,
//! the error-feedback state machine, and the determinism contract.

use std::collections::BTreeMap;

use crate::util::rng::{hash_seed, Xoshiro256pp};

/// Stream label for sparsification randomness: mixed with
/// `(seed, round, cid)` so random-k draws are independent of every other
/// per-client stream (sampling, chaos, training noise).
pub const SPARSE_STREAM: u64 = 0x5A_B5_E7;

/// Gaps per bitpacked index block: 64 u32 gaps, one class-header byte
/// each (the [`delta`](crate::omc::delta) block scheme at u32 width).
pub const GAPS_PER_BLOCK: usize = 64;

/// Which coordinates of the corrected update a client ships.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SparseMode {
    /// The `k` largest-magnitude coordinates (index-ascending tie-break).
    TopK,
    /// `k` uniform coordinates from the keyed `(seed, round, cid, var)`
    /// stream — the unbiased baseline top-k is compared against.
    RandK,
}

impl SparseMode {
    /// Canonical lowercase name (the TOML / sweep-axis spelling).
    pub fn name(&self) -> &'static str {
        match self {
            SparseMode::TopK => "topk",
            SparseMode::RandK => "randk",
        }
    }
}

impl std::fmt::Display for SparseMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for SparseMode {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "topk" => Ok(SparseMode::TopK),
            "randk" => Ok(SparseMode::RandK),
            other => Err(format!(
                "unknown sparse mode '{other}' (expected topk or randk)"
            )),
        }
    }
}

/// Per-client sparsification knobs threaded into
/// [`ClientTrainConfig`](crate::fl::client::ClientTrainConfig).
#[derive(Clone, Copy, Debug)]
pub struct SparseTrainParams {
    /// Selection rule.
    pub mode: SparseMode,
    /// Fraction of coordinates kept per variable, in `(0, 1]`.
    pub fraction: f32,
    /// Per-`(seed, round, cid)` stream key from [`sparse_key`].
    pub key: u64,
}

/// Engine-level sparsification knobs (what the `[sparse]` config table
/// resolves to); the per-client `key` is bound per round/wave by the
/// engines via [`SparseParams::bind`].
#[derive(Clone, Copy, Debug)]
pub struct SparseParams {
    /// Selection rule.
    pub mode: SparseMode,
    /// Fraction of coordinates kept per variable, in `(0, 1]`.
    pub fraction: f32,
}

impl SparseParams {
    /// Bind the engine knobs to one client's keyed stream for `round`.
    pub fn bind(self, seed: u64, round: u64, cid: u64) -> SparseTrainParams {
        SparseTrainParams {
            mode: self.mode,
            fraction: self.fraction,
            key: sparse_key(seed, round, cid),
        }
    }
}

/// Derive the per-client sparse stream key for one round (sync) or wave
/// (async). Keyed exactly like every other client stream so A/B runs
/// over the same `(seed, cid)` population stay aligned.
pub fn sparse_key(seed: u64, round: u64, cid: u64) -> u64 {
    hash_seed(&[seed, SPARSE_STREAM, round, cid])
}

/// Derive the per-variable random-k seed from a client's stream key.
pub fn var_seed(key: u64, var: usize) -> u64 {
    hash_seed(&[key, var as u64])
}

/// Number of coordinates shipped for an `n`-element variable at the
/// configured keep-fraction: `clamp(ceil(fraction·n), 1, n)`, and 0 only
/// for an empty variable.
pub fn select_count(n: usize, fraction: f32) -> usize {
    if n == 0 {
        return 0;
    }
    ((n as f64 * fraction as f64).ceil() as usize).clamp(1, n)
}

/// Magnitude bits of an f32: for finite values the unsigned bit pattern
/// of `|x|` orders exactly like `|x|`, giving an exact integer compare
/// that is identical on every ISA (no NaN-sensitive float compare).
#[inline]
fn mag_bits(x: f32) -> u32 {
    x.to_bits() & 0x7FFF_FFFF
}

/// Indices of the `k` largest-magnitude entries of `e`, written into
/// `out` **sorted ascending** (the order the index stream gap-codes).
/// Ties break toward the lower index, making the selection a total
/// order: bit-exact across ISA, worker count, and run.
pub fn select_topk(e: &[f32], k: usize, out: &mut Vec<u32>) {
    out.clear();
    if k == 0 || e.is_empty() {
        return;
    }
    let k = k.min(e.len());
    out.extend(0..e.len() as u32);
    if k < e.len() {
        out.select_nth_unstable_by(k - 1, |&a, &b| {
            mag_bits(e[b as usize])
                .cmp(&mag_bits(e[a as usize]))
                .then(a.cmp(&b))
        });
        out.truncate(k);
    }
    out.sort_unstable();
}

/// `k` distinct uniform indices from `0..n`, drawn by partial
/// Fisher–Yates from the keyed stream and written into `out` **sorted
/// ascending**. `scratch` holds the permutation buffer so the steady
/// state allocates nothing.
pub fn select_randk(
    n: usize,
    k: usize,
    seed: u64,
    out: &mut Vec<u32>,
    scratch: &mut Vec<u32>,
) {
    out.clear();
    if k == 0 || n == 0 {
        return;
    }
    let k = k.min(n);
    scratch.clear();
    scratch.extend(0..n as u32);
    let mut rng = Xoshiro256pp::new(seed);
    for i in 0..k {
        let j = i + rng.next_below((n - i) as u64) as usize;
        scratch.swap(i, j);
    }
    out.extend_from_slice(&scratch[..k]);
    out.sort_unstable();
}

/// Typed failure while decoding a bitpacked index stream.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SparseIndexError {
    /// A block class header byte exceeds 32 (no such width class).
    BadWidth(u8),
    /// The stream ended before the declared gaps could be read.
    Truncated,
    /// Bytes remain after the last block of the declared index count.
    TrailingBytes,
    /// A reconstructed index reached or passed the variable length —
    /// also covers duplicate/descending indices, which gap-coding makes
    /// unrepresentable without overshooting `n`.
    IndexOverflow,
}

impl std::fmt::Display for SparseIndexError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SparseIndexError::BadWidth(w) => {
                write!(f, "impossible index block class {w}")
            }
            SparseIndexError::Truncated => {
                write!(f, "truncated index stream")
            }
            SparseIndexError::TrailingBytes => {
                write!(f, "trailing bytes after index stream")
            }
            SparseIndexError::IndexOverflow => {
                write!(f, "index stream reconstructs out-of-range index")
            }
        }
    }
}

impl std::error::Error for SparseIndexError {}

/// Gap-code and bitpack sorted, strictly ascending `indices` into `out`
/// (appended, not cleared). Returns the number of bytes appended. The
/// stream is self-delimiting given the index count `k`, which the wire
/// record carries.
pub fn encode_indices_into(indices: &[u32], out: &mut Vec<u8>) -> usize {
    let start = out.len();
    let mut prev: u64 = 0;
    let mut gaps = [0u32; GAPS_PER_BLOCK];
    let mut k = 0usize;
    while k < indices.len() {
        let t = (indices.len() - k).min(GAPS_PER_BLOCK);
        let mut folded = 0u32;
        for (j, gap) in gaps.iter_mut().enumerate().take(t) {
            let idx = indices[k + j] as u64;
            debug_assert!(k + j == 0 || idx > prev, "indices must ascend");
            *gap = if k + j == 0 {
                idx as u32
            } else {
                (idx - prev - 1) as u32
            };
            folded |= *gap;
            prev = idx;
        }
        // class = significant width of the OR-fold (exact integer math)
        let w = 32 - folded.leading_zeros() as usize;
        out.push(w as u8);
        if w > 0 {
            // LSB-first bit accumulator, flushed at block end; u64 holds
            // the worst case (7 residual bits + a 32-bit gap).
            let mut acc: u64 = 0;
            let mut bits = 0usize;
            for &gap in gaps.iter().take(t) {
                acc |= (gap as u64) << bits;
                bits += w;
                while bits >= 8 {
                    out.push((acc & 0xFF) as u8);
                    acc >>= 8;
                    bits -= 8;
                }
            }
            if bits > 0 {
                out.push((acc & 0xFF) as u8);
            }
        }
        k += t;
    }
    out.len() - start
}

/// Decode a bitpacked index stream back to `k` ascending indices below
/// `n` (cleared into `out`). Strict: every malformed stream — and every
/// stream whose gaps reconstruct an index at or past `n` — is a typed
/// error, never a panic or a silent wrong decode.
pub fn decode_indices_into(
    stream: &[u8],
    k: usize,
    n: usize,
    out: &mut Vec<u32>,
) -> Result<(), SparseIndexError> {
    out.clear();
    if k > n {
        return Err(SparseIndexError::IndexOverflow);
    }
    out.reserve(k);
    let mut i = 0usize; // stream cursor
    let mut g = 0usize; // gaps decoded
    let mut prev: u64 = 0;
    while g < k {
        let t = (k - g).min(GAPS_PER_BLOCK);
        let w = *stream.get(i).ok_or(SparseIndexError::Truncated)? as usize;
        i += 1;
        if w > 32 {
            return Err(SparseIndexError::BadWidth(w as u8));
        }
        if w == 0 {
            // all-zero gaps: a consecutive run from the previous index
            for j in 0..t {
                let idx = if g + j == 0 { 0 } else { prev + 1 };
                if idx >= n as u64 {
                    return Err(SparseIndexError::IndexOverflow);
                }
                out.push(idx as u32);
                prev = idx;
            }
        } else {
            let need = (t * w).div_ceil(8);
            let body = stream
                .get(i..i + need)
                .ok_or(SparseIndexError::Truncated)?;
            i += need;
            let mask = (1u64 << w) - 1;
            let mut acc: u64 = 0;
            let mut bits = 0usize;
            let mut bi = 0usize;
            for j in 0..t {
                while bits < w {
                    acc |= (body[bi] as u64) << bits;
                    bi += 1;
                    bits += 8;
                }
                let gap = acc & mask;
                acc >>= w;
                bits -= w;
                let idx = if g + j == 0 { gap } else { prev + 1 + gap };
                if idx >= n as u64 {
                    return Err(SparseIndexError::IndexOverflow);
                }
                out.push(idx as u32);
                prev = idx;
            }
        }
        g += t;
    }
    if i != stream.len() {
        return Err(SparseIndexError::TrailingBytes);
    }
    debug_assert_eq!(out.len(), k);
    Ok(())
}

/// Gather `values[idx]` for each selected index into `out` (cleared
/// first) — the value stream the wire record packs.
pub fn gather_into(values: &[f32], indices: &[u32], out: &mut Vec<f32>) {
    out.clear();
    out.reserve(indices.len());
    for &i in indices {
        out.push(values[i as usize]);
    }
}

/// One client's error-feedback state: per-variable residual vectors.
/// `None` entries are variables sparsification never touched (raw /
/// masked-out vars, or no round shipped them yet) — their residual is
/// identically zero.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ClientResidual {
    vars: Vec<Option<Vec<f32>>>,
}

impl ClientResidual {
    /// Empty residual over `nvars` variables.
    pub fn new(nvars: usize) -> Self {
        Self {
            vars: vec![None; nvars],
        }
    }

    /// Number of variable slots.
    pub fn num_vars(&self) -> usize {
        self.vars.len()
    }

    /// The residual for variable `i`, when a prior round deposited one.
    pub fn var(&self, i: usize) -> Option<&[f32]> {
        self.vars.get(i).and_then(|v| v.as_deref())
    }

    /// Deposit the new residual for variable `i`.
    pub fn set(&mut self, i: usize, residual: Vec<f32>) {
        if i >= self.vars.len() {
            self.vars.resize(i + 1, None);
        }
        self.vars[i] = Some(residual);
    }

    /// Sum of squared residual entries, accumulated in f64 in index
    /// order — deterministic, and the source of the per-round
    /// `sparse_residual_norm` liveness counter.
    pub fn norm_sq(&self) -> f64 {
        let mut acc = 0.0f64;
        for v in self.vars.iter().flatten() {
            for &x in v {
                acc += x as f64 * x as f64;
            }
        }
        acc
    }

    /// Heap bytes held by the residual vectors.
    pub fn memory_bytes(&self) -> usize {
        self.vars
            .iter()
            .flatten()
            .map(|v| v.capacity() * std::mem::size_of::<f32>())
            .sum()
    }
}

/// Server-side registry of per-client error-feedback residuals, keyed by
/// client id. The round engines read a client's entry at dispatch and
/// commit the returned residual **sequentially in plan order** after the
/// cohort runs, so the store's contents — and everything derived from
/// them — are byte-identical for any worker count.
#[derive(Clone, Debug, Default)]
pub struct SparseStore {
    residuals: BTreeMap<u64, ClientResidual>,
}

impl SparseStore {
    /// Empty store (no client has a residual yet).
    pub fn new() -> Self {
        Self::default()
    }

    /// The residual carried by client `cid`, if any round deposited one.
    pub fn get(&self, cid: u64) -> Option<&ClientResidual> {
        self.residuals.get(&cid)
    }

    /// Replace client `cid`'s residual with this round's leftover.
    pub fn commit(&mut self, cid: u64, residual: ClientResidual) {
        self.residuals.insert(cid, residual);
    }

    /// Drop every residual (the start-of-run reset).
    pub fn clear(&mut self) {
        self.residuals.clear();
    }

    /// Number of clients with a stored residual.
    pub fn len(&self) -> usize {
        self.residuals.len()
    }

    /// Whether no client has a stored residual.
    pub fn is_empty(&self) -> bool {
        self.residuals.is_empty()
    }

    /// Total squared residual mass across all clients (f64, in client-id
    /// order — deterministic).
    pub fn norm_sq(&self) -> f64 {
        self.residuals.values().map(|r| r.norm_sq()).sum()
    }

    /// Heap bytes held by all residuals (the O(participating-clients)
    /// memory the population caveat in `docs/COMPRESSION.md` documents).
    pub fn memory_bytes(&self) -> usize {
        self.residuals
            .values()
            .map(|r| r.memory_bytes() + std::mem::size_of::<u64>())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{check, Gen};

    fn roundtrip(indices: &[u32], n: usize) -> Vec<u32> {
        let mut stream = Vec::new();
        let written = encode_indices_into(indices, &mut stream);
        assert_eq!(written, stream.len());
        let mut back = Vec::new();
        decode_indices_into(&stream, indices.len(), n, &mut back).unwrap();
        back
    }

    #[test]
    fn empty_selection_roundtrips_to_empty_stream() {
        let mut stream = Vec::new();
        assert_eq!(encode_indices_into(&[], &mut stream), 0);
        let mut back = vec![7u32; 3];
        decode_indices_into(&[], 0, 10, &mut back).unwrap();
        assert!(back.is_empty());
    }

    #[test]
    fn consecutive_indices_cost_one_header_byte_per_block() {
        // gaps all zero -> class 0, header-only blocks
        for n in [1usize, 63, 64, 65, 300] {
            let idx: Vec<u32> = (0..n as u32).collect();
            let mut stream = Vec::new();
            encode_indices_into(&idx, &mut stream);
            assert_eq!(stream.len(), n.div_ceil(GAPS_PER_BLOCK), "n {n}");
            assert_eq!(roundtrip(&idx, n), idx);
        }
    }

    #[test]
    fn width_classes_match_gap_contents() {
        // one block whose max gap needs exactly w bits, for every w
        for w in 1usize..=32 {
            let gap: u32 = if w == 32 { u32::MAX } else { (1 << w) - 1 };
            let idx = vec![0u32, 1 + gap];
            let n = 3 + gap as usize;
            let mut stream = Vec::new();
            encode_indices_into(&idx, &mut stream);
            assert_eq!(stream[0] as usize, w, "class for width {w}");
            assert_eq!(stream.len(), 1 + (2 * w).div_ceil(8), "width {w}");
            assert_eq!(roundtrip(&idx, n), idx);
        }
    }

    #[test]
    fn roundtrip_property_over_adversarial_selections() {
        check("sparse index roundtrip", 200, |g| {
            let n = 1 + g.usize_below(3000);
            let k = 1 + g.usize_below(n);
            // draw k distinct ascending indices three ways: dense run,
            // uniform, clustered
            let mut idx: Vec<u32> = match g.usize_below(3) {
                0 => (0..k as u32).collect(),
                1 => {
                    let mut rng = Xoshiro256pp::new(g.u64());
                    rng.sample_indices(n, k)
                        .into_iter()
                        .map(|i| i as u32)
                        .collect()
                }
                _ => (0..k).map(|i| (i * n / k) as u32).collect(),
            };
            idx.sort_unstable();
            idx.dedup();
            let back = roundtrip(&idx, n);
            if back != idx {
                return Err(format!("n {n} k {} mismatched", idx.len()));
            }
            Ok(())
        });
    }

    #[test]
    fn decode_rejects_malformed_streams() {
        let idx: Vec<u32> = (0..200u32).map(|i| i * 3).collect();
        let n = 600;
        let mut stream = Vec::new();
        encode_indices_into(&idx, &mut stream);
        let mut out = Vec::new();
        // impossible class header
        let mut bad = stream.clone();
        bad[0] = 33;
        assert_eq!(
            decode_indices_into(&bad, idx.len(), n, &mut out),
            Err(SparseIndexError::BadWidth(33))
        );
        // every truncation is typed, never a panic
        for cut in 0..stream.len() {
            let r = decode_indices_into(&stream[..cut], idx.len(), n, &mut out);
            assert!(r.is_err(), "cut {cut} accepted");
        }
        // trailing bytes are rejected
        let mut bad = stream.clone();
        bad.push(0);
        assert_eq!(
            decode_indices_into(&bad, idx.len(), n, &mut out),
            Err(SparseIndexError::TrailingBytes)
        );
        // a shrunk variable length turns in-range gaps into overflow
        assert_eq!(
            decode_indices_into(&stream, idx.len(), 500, &mut out),
            Err(SparseIndexError::IndexOverflow)
        );
        // more indices than the variable holds is unrepresentable
        assert_eq!(
            decode_indices_into(&stream, idx.len(), idx.len() - 1, &mut out),
            Err(SparseIndexError::IndexOverflow)
        );
    }

    #[test]
    fn topk_picks_largest_magnitudes_with_index_tiebreak() {
        let e = [0.1f32, -3.0, 0.0, 3.0, -0.5, 2.0];
        let mut out = Vec::new();
        select_topk(&e, 3, &mut out);
        // |−3.0| ties |3.0| -> lower index 1 first, both kept with 2.0
        assert_eq!(out, vec![1, 3, 5]);
        select_topk(&e, 1, &mut out);
        assert_eq!(out, vec![1], "tie at k=1 keeps the lower index");
        select_topk(&e, 6, &mut out);
        assert_eq!(out, vec![0, 1, 2, 3, 4, 5]);
        select_topk(&e, 9, &mut out);
        assert_eq!(out, vec![0, 1, 2, 3, 4, 5], "k clamps to n");
    }

    #[test]
    fn topk_is_a_total_order_property() {
        check("topk total order", 100, |g| {
            let n = 1 + g.usize_below(500);
            let e: Vec<f32> = (0..n)
                .map(|_| (g.u64() % 17) as f32 - 8.0) // many exact ties
                .collect();
            let k = 1 + g.usize_below(n);
            let (mut a, mut b) = (Vec::new(), Vec::new());
            select_topk(&e, k, &mut a);
            select_topk(&e, k, &mut b);
            if a != b {
                return Err("re-selection differed".into());
            }
            if a.len() != k {
                return Err(format!("selected {} of k {k}", a.len()));
            }
            if a.windows(2).any(|w| w[0] >= w[1]) {
                return Err("selection not strictly ascending".into());
            }
            // no unselected magnitude strictly exceeds a selected one
            let sel_min = a
                .iter()
                .map(|&i| super::mag_bits(e[i as usize]))
                .min()
                .unwrap();
            for i in 0..n as u32 {
                if !a.contains(&i)
                    && super::mag_bits(e[i as usize]) > sel_min
                {
                    return Err(format!("index {i} unjustly dropped"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn randk_is_keyed_distinct_and_sorted() {
        let (mut a, mut b, mut scratch) = (Vec::new(), Vec::new(), Vec::new());
        select_randk(100, 30, 7, &mut a, &mut scratch);
        select_randk(100, 30, 7, &mut b, &mut scratch);
        assert_eq!(a, b, "same key must reproduce the selection");
        select_randk(100, 30, 8, &mut b, &mut scratch);
        assert_ne!(a, b, "a different key must move the selection");
        assert_eq!(a.len(), 30);
        assert!(a.windows(2).all(|w| w[0] < w[1]), "sorted + distinct");
        assert!(a.iter().all(|&i| i < 100));
        select_randk(5, 9, 1, &mut a, &mut scratch);
        assert_eq!(a, vec![0, 1, 2, 3, 4], "k clamps to n");
    }

    #[test]
    fn select_count_clamps_to_at_least_one() {
        assert_eq!(select_count(0, 0.25), 0);
        assert_eq!(select_count(1, 0.01), 1);
        assert_eq!(select_count(300, 0.25), 75);
        assert_eq!(select_count(10, 1.0), 10);
        assert_eq!(select_count(3, 0.9), 3);
    }

    #[test]
    fn sparse_key_varies_over_every_part() {
        let k = sparse_key(42, 3, 9);
        assert_ne!(k, sparse_key(43, 3, 9));
        assert_ne!(k, sparse_key(42, 4, 9));
        assert_ne!(k, sparse_key(42, 3, 10));
        assert_ne!(var_seed(k, 0), var_seed(k, 1));
    }

    #[test]
    fn mode_parses_and_prints_canonically() {
        assert_eq!("topk".parse::<SparseMode>().unwrap(), SparseMode::TopK);
        assert_eq!("randk".parse::<SparseMode>().unwrap(), SparseMode::RandK);
        assert!("dense".parse::<SparseMode>().is_err());
        assert_eq!(SparseMode::TopK.to_string(), "topk");
        assert_eq!(SparseMode::RandK.to_string(), "randk");
    }

    #[test]
    fn residual_partition_is_bitwise_exact() {
        check("residual partition", 100, |g| {
            let n = 1 + g.usize_below(800);
            let e = g.vec_normal(n, 0.3);
            let k = select_count(n, 0.25);
            let mut idx = Vec::new();
            select_topk(&e, k, &mut idx);
            let mut gathered = Vec::new();
            gather_into(&e, &idx, &mut gathered);
            // residual = e with selected coords zeroed
            let mut residual = e.clone();
            for &i in &idx {
                residual[i as usize] = 0.0;
            }
            // scatter(selected) + residual == e, bitwise
            let mut rebuilt = residual.clone();
            for (j, &i) in idx.iter().enumerate() {
                rebuilt[i as usize] = gathered[j];
            }
            for i in 0..n {
                if rebuilt[i].to_bits() != e[i].to_bits() {
                    return Err(format!("coord {i} not a partition"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn store_commits_by_client_and_tracks_mass() {
        let mut store = SparseStore::new();
        assert!(store.is_empty());
        assert!(store.get(3).is_none());
        let mut r = ClientResidual::new(2);
        r.set(0, vec![3.0, -4.0]);
        assert_eq!(r.norm_sq(), 25.0);
        assert_eq!(r.var(0), Some(&[3.0f32, -4.0][..]));
        assert_eq!(r.var(1), None);
        store.commit(3, r.clone());
        store.commit(5, ClientResidual::new(2));
        assert_eq!(store.len(), 2);
        assert_eq!(store.norm_sq(), 25.0);
        assert!(store.memory_bytes() >= 2 * 4);
        // re-commit replaces, never accumulates
        r.set(0, vec![1.0]);
        store.commit(3, r);
        assert_eq!(store.norm_sq(), 1.0);
        store.clear();
        assert!(store.is_empty());
        assert_eq!(store.norm_sq(), 0.0);
    }
}
