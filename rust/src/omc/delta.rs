//! Lossless cross-round delta stage for the wire codec (ROADMAP item 1).
//!
//! Quantized block payloads are shipped verbatim every round even though
//! both ends hold history: the client just decoded this round's downlink,
//! and the server keeps recent committed versions in the
//! [`SnapshotRing`](crate::omc::store::SnapshotRing). As training
//! converges, the XOR of a round's packed payload against the base version
//! both sides share collapses toward zeros — which a variable-width
//! bitpacker turns into a fraction of the verbatim bytes, losslessly.
//!
//! The stage is two passes over the packed payload bytes:
//!
//! 1. **XOR-delta** (`util::simd::xor_bytes`): `d = cur ⊕ base`, byte for
//!    byte. Both payloads were produced by the same deterministic
//!    compressor, so unchanged values XOR to zero runs.
//! 2. **Per-block bitpacking** ([`encode_into`]): the XORed bytes are
//!    read as little-endian u64 words and grouped into blocks of
//!    [`WORDS_PER_BLOCK`] = 64 words (512 bytes). Each block emits one
//!    **class header byte** `w ∈ 0..=64` — the maximum significant width
//!    (64 minus the leading zeros of the OR-fold of the block's words):
//!
//!    | class | meaning | block body |
//!    |-------|---------------------------|------------------------|
//!    | 0 | all-zeros | none (header only) |
//!    | 1..=63| leading-zero class | `ceil(t·w / 8)` bytes |
//!    | 64 | no compression (memcpy) | `8·t` bytes |
//!
//!    where `t` is the block's word count (64, or the tail remainder).
//!    Words are packed LSB-first at `w` bits each; every block is
//!    byte-aligned (the bit accumulator flushes at block end).
//!
//! The framing that carries these streams (frame v3, tag-2 records, the
//! `base_version` ack handshake, verbatim fallback) lives in
//! [`codec`](crate::omc::codec); `docs/WIRE.md` documents the full wire
//! contract and the ack/fallback state machine. Decoding is strict: an
//! impossible class header, a short stream, or leftover bytes surface as a
//! typed [`DeltaError`] — never a panic, never a silent wrong decode.

use crate::omc::store::{CompressedModel, StoredVar};
use crate::util::simd;

/// Words per bitpacked block: 64 little-endian u64 words = 512 bytes of
/// payload per full block, one class-header byte each.
pub const WORDS_PER_BLOCK: usize = 64;

/// Typed failure while decoding a bitpacked delta stream.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DeltaError {
    /// A block class header byte exceeds 64 (no such width class).
    BadWidth(u8),
    /// The stream ended before the declared blocks could be read.
    Truncated,
    /// Bytes remain after the last block of the declared payload length.
    TrailingBytes,
}

impl std::fmt::Display for DeltaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DeltaError::BadWidth(w) => write!(f, "impossible block class {w}"),
            DeltaError::Truncated => write!(f, "truncated delta stream"),
            DeltaError::TrailingBytes => {
                write!(f, "trailing bytes after delta stream")
            }
        }
    }
}

impl std::error::Error for DeltaError {}

/// Number of u64 words covering `len` payload bytes (tail zero-padded).
#[inline]
fn word_count(len: usize) -> usize {
    len.div_ceil(8)
}

/// Read word `k` of a byte slice as a little-endian u64, zero-padding the
/// final partial word.
#[inline]
fn word_at(bytes: &[u8], k: usize) -> u64 {
    let start = k * 8;
    if start + 8 <= bytes.len() {
        u64::from_le_bytes(bytes[start..start + 8].try_into().unwrap())
    } else {
        let mut b = [0u8; 8];
        let tail = &bytes[start..];
        b[..tail.len()].copy_from_slice(tail);
        u64::from_le_bytes(b)
    }
}

/// Bitpack an XORed payload into `out` (appended, not cleared). Returns
/// the number of bytes appended. The stream is self-delimiting given the
/// original payload length (`xored.len()`), which the wire record carries
/// as `raw_len`.
pub fn encode_into(xored: &[u8], out: &mut Vec<u8>) -> usize {
    let start = out.len();
    let words = word_count(xored.len());
    let mut k = 0usize;
    while k < words {
        let t = (words - k).min(WORDS_PER_BLOCK);
        let block_bytes = &xored[k * 8..xored.len().min((k + t) * 8)];
        // class = significant width of the OR-fold (exact integer math:
        // identical on every simd dispatch path)
        let folded = simd::or_fold_words(block_bytes);
        let w = 64 - folded.leading_zeros() as usize;
        out.push(w as u8);
        if w == 64 {
            // memcpy class: 8·t bytes, zero-padding the final word
            for j in 0..t {
                out.extend_from_slice(&word_at(block_bytes, j).to_le_bytes());
            }
        } else if w > 0 {
            // LSB-first bit accumulator, flushed at block end. A u128
            // holds the worst case (7 residual bits + a 63-bit word)
            // without the shift overflow a u64 accumulator would hit.
            let mut acc: u128 = 0;
            let mut bits = 0usize;
            for j in 0..t {
                let word = word_at(block_bytes, j);
                debug_assert!(w == 64 || word < (1 << w));
                acc |= (word as u128) << bits;
                bits += w;
                while bits >= 8 {
                    out.push((acc & 0xFF) as u8);
                    acc >>= 8;
                    bits -= 8;
                }
            }
            if bits > 0 {
                out.push((acc & 0xFF) as u8);
            }
        }
        k += t;
    }
    out.len() - start
}

/// Decode a bitpacked stream back to the XORed payload (`raw_len` bytes,
/// cleared into `out`). Strict: every malformed stream is a typed error.
pub fn decode_into(
    stream: &[u8],
    raw_len: usize,
    out: &mut Vec<u8>,
) -> Result<(), DeltaError> {
    out.clear();
    out.reserve(raw_len);
    let words = word_count(raw_len);
    let mut i = 0usize; // stream cursor
    let mut k = 0usize; // word cursor
    while k < words {
        let t = (words - k).min(WORDS_PER_BLOCK);
        let w = *stream.get(i).ok_or(DeltaError::Truncated)? as usize;
        i += 1;
        if w > 64 {
            return Err(DeltaError::BadWidth(w as u8));
        }
        if w == 0 {
            push_words(out, &mut k, t, raw_len, || 0);
        } else if w == 64 {
            let need = 8 * t;
            let body =
                stream.get(i..i + need).ok_or(DeltaError::Truncated)?;
            i += need;
            let mut j = 0usize;
            push_words(out, &mut k, t, raw_len, || {
                let v = word_at(body, j);
                j += 1;
                v
            });
        } else {
            let need = (t * w).div_ceil(8);
            let body =
                stream.get(i..i + need).ok_or(DeltaError::Truncated)?;
            i += need;
            let mask = (1u64 << w) - 1;
            let mut acc: u128 = 0;
            let mut bits = 0usize;
            let mut bi = 0usize;
            let mut words_out = [0u64; WORDS_PER_BLOCK];
            for word in words_out.iter_mut().take(t) {
                while bits < w {
                    acc |= (body[bi] as u128) << bits;
                    bi += 1;
                    bits += 8;
                }
                *word = (acc as u64) & mask;
                acc >>= w;
                bits -= w;
            }
            let mut j = 0usize;
            push_words(out, &mut k, t, raw_len, || {
                let v = words_out[j];
                j += 1;
                v
            });
        }
    }
    if i != stream.len() {
        return Err(DeltaError::TrailingBytes);
    }
    debug_assert_eq!(out.len(), raw_len);
    Ok(())
}

/// Append `t` words from `next` to `out` as little-endian bytes,
/// truncating the final word at `raw_len`.
#[inline]
fn push_words(
    out: &mut Vec<u8>,
    k: &mut usize,
    t: usize,
    raw_len: usize,
    mut next: impl FnMut() -> u64,
) {
    for _ in 0..t {
        let bytes = next().to_le_bytes();
        let take = (raw_len - out.len()).min(8);
        out.extend_from_slice(&bytes[..take]);
        *k += 1;
    }
}

/// Per-variable packed-payload view of a base model version — what the
/// decoder XORs tag-2 records against. `None` entries are variables the
/// base holds raw (or not at all): a delta record targeting one is a
/// [`MissingDeltaBase`](crate::omc::codec::DecodeError::MissingDeltaBase)
/// frame error, never a silent mis-decode.
pub struct DeltaBase<'a> {
    /// the version number the frame's `base_version` header must match
    pub version: u64,
    vars: Vec<Option<&'a [u8]>>,
}

impl<'a> DeltaBase<'a> {
    /// Base payloads from a committed [`CompressedModel`] (the
    /// `SnapshotRing` entry the receiver retained for `version`).
    pub fn from_model(version: u64, model: &'a CompressedModel) -> Self {
        Self {
            version,
            vars: model
                .vars
                .iter()
                .map(|v| match v {
                    StoredVar::Packed { bytes, .. } => Some(bytes.as_slice()),
                    StoredVar::Raw(_) => None,
                })
                .collect(),
        }
    }

    /// Base payloads from a per-variable compression cache (the sync
    /// engine's `DownlinkCache` shape: `None` where the format or mask
    /// left the variable raw).
    pub fn from_packed_vars(version: u64, vars: &'a [Option<StoredVar>]) -> Self {
        Self {
            version,
            vars: vars
                .iter()
                .map(|v| match v {
                    Some(StoredVar::Packed { bytes, .. }) => {
                        Some(bytes.as_slice())
                    }
                    _ => None,
                })
                .collect(),
        }
    }

    /// The base payload for variable `i`, when the base holds it packed.
    pub fn var(&self, i: usize) -> Option<&'a [u8]> {
        self.vars.get(i).copied().flatten()
    }

    /// Number of variables the base covers.
    pub fn num_vars(&self) -> usize {
        self.vars.len()
    }
}

/// Per-client last-*accepted* base version — the receiver half of the
/// ack-version handshake (`docs/WIRE.md`).
///
/// The invariant the regression tests pin (and a real deployment would
/// depend on): the ledger advances **only when a frame's update was
/// verified and committed**. Rejected frames — chaos-corrupted, replayed,
/// truncated — and retries of the same logical upload (which share a
/// nonce) must leave it untouched, because a desynced ack would make the
/// peer delta against a base the other side never agreed on.
#[derive(Clone, Debug, Default)]
pub struct AckLedger {
    acked: std::collections::BTreeMap<u64, u64>,
}

impl AckLedger {
    /// Empty ledger (no client has an acknowledged base yet).
    pub fn new() -> Self {
        Self::default()
    }

    /// Record that client `cid`'s upload against `base_version` was
    /// accepted and committed. Monotonic: a stale ack (older than the
    /// recorded one) is ignored. Returns whether the entry advanced.
    pub fn advance(&mut self, cid: u64, base_version: u64) -> bool {
        match self.acked.entry(cid) {
            std::collections::btree_map::Entry::Vacant(e) => {
                e.insert(base_version);
                true
            }
            std::collections::btree_map::Entry::Occupied(mut e) => {
                if base_version > *e.get() {
                    e.insert(base_version);
                    true
                } else {
                    false
                }
            }
        }
    }

    /// The last accepted base version for `cid`, if any upload from it
    /// was ever committed.
    pub fn last(&self, cid: u64) -> Option<u64> {
        self.acked.get(&cid).copied()
    }

    /// Number of clients with an acknowledged base.
    pub fn len(&self) -> usize {
        self.acked.len()
    }

    /// Whether no client has an acknowledged base.
    pub fn is_empty(&self) -> bool {
        self.acked.is_empty()
    }
}

/// XOR `cur` against `base` into `out` (cleared first) and bitpack the
/// result into `stream` (appended). Returns the appended stream length.
/// Both slices must be the same length — the caller falls back to a
/// verbatim record otherwise.
pub fn xor_encode_into(
    cur: &[u8],
    base: &[u8],
    xor_scratch: &mut Vec<u8>,
    stream: &mut Vec<u8>,
) -> usize {
    debug_assert_eq!(cur.len(), base.len());
    xor_scratch.clear();
    xor_scratch.resize(cur.len(), 0);
    simd::xor_bytes(cur, base, xor_scratch);
    encode_into(xor_scratch, stream)
}

/// Decode a bitpacked stream and XOR it against `base` into `out`
/// (cleared first) — the receiver half of [`xor_encode_into`].
pub fn xor_decode_into(
    stream: &[u8],
    base: &[u8],
    delta_scratch: &mut Vec<u8>,
    out: &mut Vec<u8>,
) -> Result<(), DeltaError> {
    decode_into(stream, base.len(), delta_scratch)?;
    out.clear();
    out.resize(base.len(), 0);
    simd::xor_bytes(delta_scratch, base, out);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{check, Gen};

    fn roundtrip(bytes: &[u8]) -> Vec<u8> {
        let mut stream = Vec::new();
        let n = encode_into(bytes, &mut stream);
        assert_eq!(n, stream.len());
        let mut back = Vec::new();
        decode_into(&stream, bytes.len(), &mut back).unwrap();
        back
    }

    #[test]
    fn empty_payload_roundtrips_to_empty_stream() {
        let mut stream = Vec::new();
        assert_eq!(encode_into(&[], &mut stream), 0);
        let mut back = vec![1u8; 3];
        decode_into(&[], 0, &mut back).unwrap();
        assert!(back.is_empty());
    }

    #[test]
    fn all_zero_payload_is_one_byte_per_block() {
        for len in [1usize, 8, 511, 512, 513, 4096] {
            let zeros = vec![0u8; len];
            let mut stream = Vec::new();
            encode_into(&zeros, &mut stream);
            let blocks = word_count(len).div_ceil(WORDS_PER_BLOCK);
            assert_eq!(stream.len(), blocks, "len {len}");
            assert!(stream.iter().all(|&b| b == 0));
            assert_eq!(roundtrip(&zeros), zeros);
        }
    }

    #[test]
    fn high_entropy_payload_falls_into_memcpy_class() {
        let mut g = Gen::new(1);
        let bytes: Vec<u8> =
            (0..4096).map(|_| (g.u64() >> 56) as u8 | 0x80).collect();
        // every word has its top byte's MSB set -> class 64 everywhere
        let mut stream = Vec::new();
        encode_into(&bytes, &mut stream);
        let blocks = word_count(bytes.len()).div_ceil(WORDS_PER_BLOCK);
        assert_eq!(stream.len(), bytes.len() + blocks);
        assert_eq!(roundtrip(&bytes), bytes);
    }

    #[test]
    fn roundtrip_property_over_adversarial_streams() {
        check("delta roundtrip", 200, |g| {
            // lengths hit tails mod 8, mod 512, and whole blocks
            let len = match g.usize_below(4) {
                0 => g.usize_below(17),
                1 => 512 * (1 + g.usize_below(3)) + g.usize_below(9),
                2 => 511 + g.usize_below(3),
                _ => g.usize_below(3000),
            };
            let sparsity = g.usize_below(4);
            let bytes: Vec<u8> = (0..len)
                .map(|_| {
                    if g.usize_below(4) <= sparsity {
                        0u8
                    } else {
                        (g.u64() & 0xFF) as u8
                    }
                })
                .collect();
            let back = roundtrip(&bytes);
            if back != bytes {
                return Err(format!("len {len} mismatched"));
            }
            Ok(())
        });
    }

    #[test]
    fn width_classes_match_block_contents() {
        // one block whose max word needs exactly w bits, for every w
        for w in 1usize..=64 {
            let mut bytes = vec![0u8; 512];
            let word: u64 = if w == 64 { u64::MAX } else { (1 << w) - 1 };
            bytes[0..8].copy_from_slice(&word.to_le_bytes());
            let mut stream = Vec::new();
            encode_into(&bytes, &mut stream);
            assert_eq!(stream[0] as usize, w, "class for width {w}");
            let body = if w == 64 { 512 } else { (64 * w).div_ceil(8) };
            assert_eq!(stream.len(), 1 + body, "width {w}");
            assert_eq!(roundtrip(&bytes), bytes);
        }
    }

    #[test]
    fn decode_rejects_malformed_streams() {
        let bytes: Vec<u8> = (0..600u32).map(|i| (i % 7) as u8).collect();
        let mut stream = Vec::new();
        encode_into(&bytes, &mut stream);
        let mut out = Vec::new();
        // impossible class header
        let mut bad = stream.clone();
        bad[0] = 65;
        assert_eq!(
            decode_into(&bad, bytes.len(), &mut out),
            Err(DeltaError::BadWidth(65))
        );
        // every truncation is typed, never a panic
        for cut in 0..stream.len() {
            let r = decode_into(&stream[..cut], bytes.len(), &mut out);
            assert!(r.is_err(), "cut {cut} accepted");
        }
        // trailing bytes are rejected
        let mut bad = stream.clone();
        bad.push(0);
        assert_eq!(
            decode_into(&bad, bytes.len(), &mut out),
            Err(DeltaError::TrailingBytes)
        );
        // empty stream for a nonzero payload
        assert_eq!(
            decode_into(&[], bytes.len(), &mut out),
            Err(DeltaError::Truncated)
        );
    }

    #[test]
    fn xor_encode_decode_recovers_current_payload() {
        check("xor stage roundtrip", 100, |g| {
            let len = 1 + g.usize_below(2000);
            let base: Vec<u8> =
                (0..len).map(|_| (g.u64() & 0xFF) as u8).collect();
            // a few byte flips on top of the base (the converging regime)
            let mut cur = base.clone();
            for _ in 0..g.usize_below(8) {
                let i = g.usize_below(len);
                cur[i] ^= (g.u64() & 0xFF) as u8;
            }
            let (mut xs, mut stream) = (Vec::new(), Vec::new());
            let slen = xor_encode_into(&cur, &base, &mut xs, &mut stream);
            let (mut ds, mut back) = (Vec::new(), Vec::new());
            xor_decode_into(&stream, &base, &mut ds, &mut back)
                .map_err(|e| e.to_string())?;
            if back != cur {
                return Err(format!("len {len}: decode != current"));
            }
            // near-identical payloads must compress well below verbatim
            if len >= 1024 && slen >= len {
                return Err(format!("no gain on sparse delta (len {len})"));
            }
            Ok(())
        });
    }

    #[test]
    fn delta_base_views_models_and_caches() {
        let mut g = Gen::new(3);
        let fmt: crate::omc::format::FloatFormat = "S1E3M7".parse().unwrap();
        let model = CompressedModel::new(vec![
            StoredVar::compress(&g.vec_normal(300, 0.05), fmt, true),
            StoredVar::raw(g.vec_normal(10, 1.0)),
        ]);
        let base = DeltaBase::from_model(7, &model);
        assert_eq!(base.version, 7);
        assert_eq!(base.num_vars(), 2);
        assert!(base.var(0).is_some());
        assert!(base.var(1).is_none());
        assert!(base.var(2).is_none());
        let cache = vec![
            Some(StoredVar::compress(&g.vec_normal(64, 0.1), fmt, false)),
            None,
        ];
        let base = DeltaBase::from_packed_vars(9, &cache);
        assert_eq!(base.version, 9);
        assert!(base.var(0).is_some());
        assert!(base.var(1).is_none());
    }

    #[test]
    fn ack_ledger_is_monotonic_per_client() {
        let mut led = AckLedger::new();
        assert!(led.is_empty());
        assert_eq!(led.last(3), None);
        assert!(led.advance(3, 5));
        assert!(!led.advance(3, 5), "same version must not re-advance");
        assert!(!led.advance(3, 2), "stale ack must be ignored");
        assert_eq!(led.last(3), Some(5));
        assert!(led.advance(3, 6));
        assert!(led.advance(4, 0));
        assert_eq!(led.len(), 2);
        assert_eq!(led.last(4), Some(0));
    }
}
