//! Transport wire format + byte accounting.
//!
//! The downlink (server→client) and uplink (client→server) payloads are the
//! serialized [`CompressedModel`]: a small header, then per variable either
//! the bit-packed SxEyMz codes with the PVT scalars, or raw f32. These byte
//! counts are exactly what the paper's "Communication" column reports.
//!
//! Layout (all little-endian):
//! ```text
//! magic  "OMCW"            4 bytes
//! version u16              currently 1
//! nvars  u32
//! per variable:
//!   tag   u8               0 = raw f32, 1 = packed
//!   n     u32              element count
//!   raw:    n * f32
//!   packed: e u8, m u8, s f32, b f32, payload_len u32, payload bytes
//! ```

use anyhow::{bail, ensure, Context, Result};

use super::format::FloatFormat;
use super::pack::{self, PackError};
use super::store::{CompressedModel, StoredVar};
use super::transform::Pvt;

const MAGIC: &[u8; 4] = b"OMCW";
const VERSION: u16 = 1;

/// Streaming writer for the wire format — lets callers assemble a payload
/// from borrowed parts without materializing a `CompressedModel` (the
/// round loop reuses one compressed copy of each variable across all
/// clients and only the framing differs per client).
pub struct WireWriter {
    buf: Vec<u8>,
    nvars: u32,
}

impl WireWriter {
    /// Start a frame in a fresh buffer with `cap` bytes reserved.
    pub fn with_capacity(cap: usize) -> Self {
        Self::with_buf_and_capacity(Vec::new(), cap)
    }

    /// Start a frame in a recycled buffer (cleared; its capacity plus
    /// `cap` extra is retained) — the round loop's per-client payload
    /// buffers live across rounds this way.
    pub fn with_buf_and_capacity(mut buf: Vec<u8>, cap: usize) -> Self {
        buf.clear();
        buf.reserve(cap + 16);
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&VERSION.to_le_bytes());
        buf.extend_from_slice(&0u32.to_le_bytes()); // patched in finish()
        Self { buf, nvars: 0 }
    }

    /// Emit an unquantized variable: `n` f32 values shipped as-is.
    pub fn raw(&mut self, v: &[f32]) {
        self.buf.push(0u8);
        self.buf.extend_from_slice(&(v.len() as u32).to_le_bytes());
        // bulk-copy the f32 payload (little-endian hosts: this is memcpy)
        for x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
        self.nvars += 1;
    }

    /// Emit an already bit-packed variable payload with its PVT scalars.
    pub fn packed(&mut self, bytes: &[u8], n: usize, fmt: FloatFormat, pvt: Pvt) {
        self.packed_header(n, fmt, pvt, bytes.len());
        self.buf.extend_from_slice(bytes);
        self.nvars += 1;
    }

    fn packed_header(&mut self, n: usize, fmt: FloatFormat, pvt: Pvt, plen: usize) {
        self.buf.push(1u8);
        self.buf.extend_from_slice(&(n as u32).to_le_bytes());
        self.buf.push(fmt.exp_bits as u8);
        self.buf.push(fmt.mant_bits as u8);
        self.buf.extend_from_slice(&pvt.s.to_le_bytes());
        self.buf.extend_from_slice(&pvt.b.to_le_bytes());
        self.buf.extend_from_slice(&(plen as u32).to_le_bytes());
    }

    /// Emit a packed variable by bit-packing `vt` (already-quantized fixed
    /// points, e.g. the Ṽ' a training step returned) straight into the
    /// frame — the client uplink path, with no intermediate payload `Vec`.
    pub fn packed_values(
        &mut self,
        vt: &[f32],
        fmt: FloatFormat,
        pvt: Pvt,
    ) -> std::result::Result<(), PackError> {
        self.packed_header(vt.len(), fmt, pvt, fmt.packed_bytes(vt.len()));
        pack::pack_extend(vt, fmt, &mut self.buf)?;
        self.nvars += 1;
        Ok(())
    }

    /// Emit a packed variable by running the fused quantize → PVT-fit →
    /// pack pipeline straight into the frame (`values` need not be
    /// quantized). The PVT scalars land in the header retroactively.
    pub fn compress_values(&mut self, values: &[f32], fmt: FloatFormat, use_pvt: bool) {
        let plen = fmt.packed_bytes(values.len());
        self.packed_header(values.len(), fmt, Pvt::IDENTITY, plen);
        // s/b sit 12 bytes back from the header end (s f32, b f32, plen u32)
        let sb_at = self.buf.len() - 12;
        let pvt = pack::quantize_transform_pack(values, fmt, use_pvt, &mut self.buf);
        self.buf[sb_at..sb_at + 4].copy_from_slice(&pvt.s.to_le_bytes());
        self.buf[sb_at + 4..sb_at + 8].copy_from_slice(&pvt.b.to_le_bytes());
        self.nvars += 1;
    }

    /// Emit a stored variable (raw or packed, whichever it is).
    pub fn var(&mut self, v: &StoredVar) {
        match v {
            StoredVar::Raw(data) => self.raw(data),
            StoredVar::Packed { bytes, n, fmt, pvt } => {
                self.packed(bytes, *n, *fmt, *pvt)
            }
        }
    }

    /// Patch the header's variable count and hand back the finished frame.
    pub fn finish(mut self) -> Vec<u8> {
        let nv = self.nvars.to_le_bytes();
        self.buf[6..10].copy_from_slice(&nv);
        self.buf
    }
}

/// Serialize a compressed model into wire bytes.
pub fn encode(model: &CompressedModel) -> Vec<u8> {
    let mut w = WireWriter::with_capacity(model.memory_bytes() + 8 * model.vars.len());
    for var in &model.vars {
        w.var(var);
    }
    w.finish()
}

/// [`encode`] into a recycled buffer (cleared; capacity retained).
pub fn encode_into(model: &CompressedModel, buf: &mut Vec<u8>) {
    let cap = model.memory_bytes() + 8 * model.vars.len();
    let mut w = WireWriter::with_buf_and_capacity(std::mem::take(buf), cap);
    for var in &model.vars {
        w.var(var);
    }
    *buf = w.finish();
}

/// Reusable wire encoder: owns a buffer recycled across `encode` calls so
/// repeated whole-model serialization performs no steady-state allocation.
#[derive(Default)]
pub struct Encoder {
    buf: Vec<u8>,
}

impl Encoder {
    /// Fresh encoder with an empty (cold) buffer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Encode into the internal buffer and borrow the frame.
    pub fn encode(&mut self, model: &CompressedModel) -> &[u8] {
        encode_into(model, &mut self.buf);
        &self.buf
    }
}

/// A borrowed view of one variable in a wire frame — what the streaming
/// decoder hands to its callback. Payloads reference the input buffer;
/// nothing is copied until the caller decides where the values go.
#[derive(Debug)]
pub enum VarView<'a> {
    /// Unquantized variable: `n` f32 values, little-endian bytes.
    Raw {
        /// the `n * 4` little-endian f32 bytes, borrowed from the frame
        data: &'a [u8],
        /// element count
        n: usize,
    },
    /// Bit-packed variable: decode with `pack::unpack*` family.
    Packed {
        /// the bit-packed codes, borrowed from the frame
        payload: &'a [u8],
        /// element count
        n: usize,
        /// the `SxEyMz` format the codes are packed at
        fmt: FloatFormat,
        /// per-variable transform scalars
        pvt: Pvt,
    },
}

impl VarView<'_> {
    /// Element count of the variable.
    pub fn len(&self) -> usize {
        match self {
            VarView::Raw { n, .. } | VarView::Packed { n, .. } => *n,
        }
    }

    /// Whether the variable has zero elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Bytes this variable would occupy in a client's parameter store
    /// (the Sec. 3.4 accounting: payload + PVT scalars when packed).
    pub fn memory_bytes(&self) -> usize {
        match self {
            VarView::Raw { data, .. } => data.len(),
            VarView::Packed { payload, .. } => payload.len() + 8,
        }
    }

    /// Decode this variable's decompressed values (`V̄ = s·Ṽ + b`) into a
    /// reused buffer.
    pub fn decompress_into(&self, out: &mut Vec<f32>) {
        match *self {
            VarView::Raw { data, .. } => raw_f32s_into(data, out),
            VarView::Packed { payload, n, fmt, pvt } => {
                pack::unpack_transform_into(payload, n, fmt, pvt.s, pvt.b, out)
            }
        }
    }

    /// Decode this variable's quantized values Ṽ (no transform) into a
    /// reused buffer.
    pub fn tilde_into(&self, out: &mut Vec<f32>) {
        match *self {
            VarView::Raw { data, .. } => raw_f32s_into(data, out),
            VarView::Packed { payload, n, fmt, .. } => {
                pack::unpack_into(payload, n, fmt, out)
            }
        }
    }
}

/// Copy a little-endian f32 image into a reused buffer.
fn raw_f32s_into(data: &[u8], out: &mut Vec<f32>) {
    out.clear();
    out.reserve(data.len() / 4);
    for c in data.chunks_exact(4) {
        out.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
    }
}

/// Streaming decoder: validate the frame and hand each variable to `f` as
/// a borrowed [`VarView`], in order. Returns the variable count. This is
/// the single wire parser — [`decode`], the client's zero-alloc downlink
/// path, and the server's streaming uplink aggregation are all built on it.
///
/// ```
/// use omc_fl::omc::codec::{self, WireWriter};
///
/// // assemble a two-variable frame...
/// let mut w = WireWriter::with_capacity(0);
/// w.raw(&[1.0f32, 2.0, 3.0]);
/// w.raw(&[-4.0f32]);
/// let frame = w.finish();
///
/// // ...and stream it back out without materializing a model
/// let mut total = 0usize;
/// let nvars = codec::for_each_var(&frame, |_i, view| {
///     total += view.len();
///     Ok(())
/// })
/// .unwrap();
/// assert_eq!((nvars, total), (2, 4));
/// ```
pub fn for_each_var<F>(bytes: &[u8], mut f: F) -> Result<usize>
where
    F: FnMut(usize, VarView<'_>) -> Result<()>,
{
    let mut r = Reader { b: bytes, i: 0 };
    let magic = r.take(4)?;
    ensure!(magic == MAGIC, "bad magic {:?}", &magic);
    let version = r.u16()?;
    ensure!(version == VERSION, "unsupported wire version {version}");
    let nvars = r.u32()? as usize;
    // sanity bound: each variable needs >= 6 bytes of header
    ensure!(
        nvars <= bytes.len() / 5 + 1,
        "implausible variable count {nvars}"
    );
    for vi in 0..nvars {
        let tag = r.u8()?;
        let n = r.u32()? as usize;
        match tag {
            0 => {
                let data = r.take(n * 4).with_context(|| format!("raw var {vi}"))?;
                f(vi, VarView::Raw { data, n })?;
            }
            1 => {
                let e = r.u8()? as u32;
                let m = r.u8()? as u32;
                let fmt = FloatFormat::new(e, m)
                    .with_context(|| format!("packed var {vi}"))?;
                let s = f32::from_le_bytes(r.arr4()?);
                let b = f32::from_le_bytes(r.arr4()?);
                ensure!(
                    s.is_finite() && b.is_finite(),
                    "non-finite PVT scalars in var {vi}"
                );
                let plen = r.u32()? as usize;
                ensure!(
                    plen == fmt.packed_bytes(n),
                    "payload length {plen} inconsistent with n={n} at {fmt}"
                );
                let payload = r.take(plen)?;
                f(
                    vi,
                    VarView::Packed {
                        payload,
                        n,
                        fmt,
                        pvt: Pvt { s, b },
                    },
                )?;
            }
            t => bail!("unknown variable tag {t}"),
        }
    }
    ensure!(r.i == bytes.len(), "trailing bytes after payload");
    Ok(nvars)
}

/// Decode wire bytes back into a compressed model.
pub fn decode(bytes: &[u8]) -> Result<CompressedModel> {
    let mut vars = Vec::new();
    for_each_var(bytes, |_, view| {
        vars.push(match view {
            VarView::Raw { data, .. } => {
                let mut v = Vec::new();
                raw_f32s_into(data, &mut v);
                StoredVar::Raw(v)
            }
            VarView::Packed { payload, n, fmt, pvt } => StoredVar::Packed {
                bytes: payload.to_vec(),
                n,
                fmt,
                pvt,
            },
        });
        Ok(())
    })?;
    Ok(CompressedModel::new(vars))
}

/// Decode wire bytes straight to decompressed `V̄` values (fused
/// unpack+transform per variable, no `CompressedModel` intermediate) — the
/// server's uplink-decode hot path.
pub fn decode_decompressed(bytes: &[u8]) -> Result<Vec<Vec<f32>>> {
    let mut out = Vec::new();
    for_each_var(bytes, |_, view| {
        let mut v = Vec::new();
        view.decompress_into(&mut v);
        out.push(v);
        Ok(())
    })?;
    Ok(out)
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(self.i + n <= self.b.len(), "truncated payload");
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        let s = self.take(2)?;
        Ok(u16::from_le_bytes([s[0], s[1]]))
    }

    fn u32(&mut self) -> Result<u32> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn arr4(&mut self) -> Result<[u8; 4]> {
        let s = self.take(4)?;
        Ok([s[0], s[1], s[2], s[3]])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::Gen;

    fn sample_model(g: &mut Gen) -> CompressedModel {
        let fmt: FloatFormat = "S1E3M7".parse().unwrap();
        let mut vars = Vec::new();
        vars.push(StoredVar::compress(&g.vec_normal(1000, 0.05), fmt, true));
        vars.push(StoredVar::raw(g.vec_normal(64, 1.0)));
        vars.push(StoredVar::compress(&g.vec_normal(333, 0.2), fmt, false));
        vars.push(StoredVar::raw(vec![]));
        CompressedModel::new(vars)
    }

    #[test]
    fn roundtrip_bit_exact() {
        let mut g = Gen::new(1);
        let model = sample_model(&mut g);
        let wire = encode(&model);
        let back = decode(&wire).unwrap();
        assert_eq!(back.num_vars(), model.num_vars());
        for (a, b) in model.vars.iter().zip(&back.vars) {
            assert_eq!(a.is_packed(), b.is_packed());
            assert_eq!(a.pvt(), b.pvt());
            let (ta, tb) = (a.decode_tilde(), b.decode_tilde());
            assert_eq!(ta.len(), tb.len());
            for (x, y) in ta.iter().zip(&tb) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn wire_size_accounts_for_compression() {
        let mut g = Gen::new(2);
        let fmt: FloatFormat = "S1E4M14".parse().unwrap(); // 19 bits
        let n = 100_000;
        let v = g.vec_normal(n, 0.05);
        let packed = CompressedModel::new(vec![StoredVar::compress(&v, fmt, true)]);
        let raw = CompressedModel::new(vec![StoredVar::raw(v)]);
        let ratio = encode(&packed).len() as f64 / encode(&raw).len() as f64;
        assert!((ratio - 19.0 / 32.0).abs() < 0.01, "ratio {ratio}");
    }

    #[test]
    fn rejects_corruption() {
        let mut g = Gen::new(3);
        let wire = encode(&sample_model(&mut g));
        // bad magic
        let mut bad = wire.clone();
        bad[0] = b'X';
        assert!(decode(&bad).is_err());
        // bad version
        let mut bad = wire.clone();
        bad[4] = 9;
        assert!(decode(&bad).is_err());
        // truncation at every prefix must error, never panic
        for cut in [5, 11, 16, wire.len() / 2, wire.len() - 1] {
            assert!(decode(&wire[..cut]).is_err(), "cut {cut}");
        }
        // trailing garbage
        let mut bad = wire.clone();
        bad.push(0);
        assert!(decode(&bad).is_err());
    }

    #[test]
    fn rejects_nonfinite_pvt() {
        let mut g = Gen::new(4);
        let model = sample_model(&mut g);
        let mut wire = encode(&model);
        // var 0 header: 4 magic + 2 ver + 4 nvars + 1 tag + 4 n = 15; then
        // e,m at 15,16; s at 17..21 — overwrite s with NaN
        wire[17..21].copy_from_slice(&f32::NAN.to_le_bytes());
        assert!(decode(&wire).is_err());
    }

    #[test]
    fn empty_model_roundtrip() {
        let m = CompressedModel::default();
        let back = decode(&encode(&m)).unwrap();
        assert_eq!(back.num_vars(), 0);
    }

    #[test]
    fn streaming_writers_match_storedvar_path() {
        // packed_values (pre-quantized) and compress_values (fused) must
        // emit byte-identical frames to the StoredVar::compress + var path
        let mut g = Gen::new(6);
        let fmt: FloatFormat = "S1E3M7".parse().unwrap();
        let v = g.vec_normal(1000, 0.05);
        let sv = StoredVar::compress(&v, fmt, true);

        let mut a = WireWriter::with_capacity(0);
        a.var(&sv);
        let a = a.finish();

        let mut b = WireWriter::with_capacity(0);
        b.compress_values(&v, fmt, true);
        let b = b.finish();
        assert_eq!(a, b, "compress_values frame differs");

        let tilde = sv.decode_tilde();
        let mut c = WireWriter::with_capacity(0);
        c.packed_values(&tilde, fmt, sv.pvt()).unwrap();
        let c = c.finish();
        assert_eq!(a, c, "packed_values frame differs");
    }

    #[test]
    fn encoder_reuses_buffer() {
        let mut g = Gen::new(7);
        let model = sample_model(&mut g);
        let reference = encode(&model);
        let mut enc = Encoder::new();
        assert_eq!(enc.encode(&model), reference.as_slice());
        let ptr = enc.encode(&model).as_ptr();
        assert_eq!(enc.encode(&model).as_ptr(), ptr, "Encoder must recycle");
    }

    #[test]
    fn decode_decompressed_matches_two_step() {
        let mut g = Gen::new(8);
        let wire = encode(&sample_model(&mut g));
        let two_step = decode(&wire).unwrap().decompress_all();
        let fused = decode_decompressed(&wire).unwrap();
        assert_eq!(two_step.len(), fused.len());
        for (a, b) in two_step.iter().zip(&fused) {
            assert_eq!(
                a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                b.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
            );
        }
    }

    #[test]
    fn for_each_var_reports_views_in_order() {
        let mut g = Gen::new(9);
        let model = sample_model(&mut g);
        let wire = encode(&model);
        let mut seen = Vec::new();
        let count = for_each_var(&wire, |i, view| {
            seen.push((i, view.len(), view.memory_bytes()));
            Ok(())
        })
        .unwrap();
        assert_eq!(count, model.num_vars());
        for (i, (vi, n, mem)) in seen.iter().enumerate() {
            assert_eq!(i, *vi);
            assert_eq!(*n, model.vars[i].len());
            assert_eq!(*mem, model.vars[i].memory_bytes());
        }
    }

    #[test]
    fn fuzz_decoder_never_panics() {
        // random byte soup must be rejected gracefully
        let mut g = Gen::new(5);
        for _ in 0..500 {
            let n = g.usize_below(200);
            let bytes: Vec<u8> = (0..n).map(|_| (g.u64() & 0xFF) as u8).collect();
            let _ = decode(&bytes); // must not panic
        }
        // and mutated-valid payloads too
        let wire = encode(&sample_model(&mut g));
        for _ in 0..300 {
            let mut bad = wire.clone();
            let idx = g.usize_below(bad.len());
            bad[idx] ^= 1 << g.usize_below(8);
            let _ = decode(&bad); // must not panic (may succeed or fail)
        }
    }
}
