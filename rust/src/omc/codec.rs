//! Transport wire format + byte accounting.
//!
//! The downlink (server→client) and uplink (client→server) payloads are the
//! serialized [`CompressedModel`]: a small header, then per variable either
//! the bit-packed SxEyMz codes with the PVT scalars, or raw f32. These byte
//! counts are exactly what the paper's "Communication" column reports.
//!
//! Layout (all little-endian). Version 1 is the integrity-off fast path —
//! byte-identical to every frame this repo has ever emitted, which is what
//! keeps the committed sweep goldens and the wire-ratio accounting stable:
//! ```text
//! magic  "OMCW"            4 bytes
//! version u16              1 (plain), 2 (integrity), 3 (integrity + delta)
//! nvars  u32
//! v2/v3:
//!   nonce u64              round/version nonce for duplicate detection
//! v3 only:
//!   base_version u64       the committed version deltas are taken against
//! v2/v3:
//!   hcrc  u32              CRC32C over every header byte before it
//! per variable:
//!   tag   u8               0 = raw f32, 1 = packed, 2 = delta-packed (v3),
//!                          3 = sparse-packed (v2/v3)
//!   n     u32              element count
//!   raw:    n * f32
//!   packed: e u8, m u8, s f32, b f32, payload_len u32, payload bytes
//!   delta:  e u8, m u8, s f32, b f32, raw_len u32, payload_len u32,
//!           payload bytes  (the `omc::delta` bitpacked XOR stream; XOR
//!           against the base version's packed payload restores the
//!           tag-1 payload bit for bit)
//!   sparse: e u8, m u8, s f32, b f32, k u32, index_len u32,
//!           payload_len u32, index bytes, payload bytes
//!           (k selected coordinates of an n-element *update*: the
//!           `omc::sparse` gap-coded bitpacked index stream, then the k
//!           gathered values bit-packed at the variable's format —
//!           `payload_len` must equal `packed_bytes(k)`)
//!   v2/v3: crc u32         CRC32C over this variable's record bytes
//! ```
//!
//! Decoding is version-agnostic: [`for_each_var`] accepts every layout and
//! verifies every checksum before a variable reaches the callback, so the
//! client/server decode paths need no knowledge of which framing the peer
//! used. Delta frames additionally need the base model both ends agreed
//! on: [`for_each_var_based`] takes an optional
//! [`DeltaBase`](crate::omc::delta::DeltaBase) and refuses — typed, never
//! silent — to decode a tag-2 record without the matching base. All
//! malformed-input conditions surface as typed [`DecodeError`]s — never a
//! panic, never a silently mis-decoded frame (see `docs/ROBUSTNESS.md`
//! and `docs/WIRE.md` for the full contract).

use anyhow::Result;

use super::delta::{self, DeltaBase, DeltaError};
use super::format::FloatFormat;
use super::pack::{self, PackError};
use super::sparse::{self, SparseIndexError};
use super::store::{CompressedModel, StoredVar};
use super::transform::Pvt;
use crate::util::simd::crc32c;

const MAGIC: &[u8; 4] = b"OMCW";
const VERSION: u16 = 1;
/// Wire version with nonce + header/per-variable CRC32C.
const VERSION_INTEGRITY: u16 = 2;
/// Wire version with integrity plus the cross-round delta stage: the
/// header carries the base version of the ack handshake and variables may
/// use tag 2 (delta-packed).
const VERSION_DELTA: u16 = 3;
/// Byte length of the v2 header (magic 4, version 2, nvars 4, nonce 8,
/// hcrc 4); the header CRC covers everything before the `hcrc` field.
const V2_HEADER_LEN: usize = 22;
const V2_HCRC_AT: usize = 18;
/// Byte length of the v3 header (v2 fields + base_version u64 before the
/// header CRC).
const V3_HEADER_LEN: usize = 30;
const V3_HCRC_AT: usize = 26;

/// Typed decode failure for wire frames. Every way a frame can be
/// malformed — truncation, corruption, duplication — maps to a variant
/// here, so the round engines can *account* rejected frames instead of
/// aborting the round, while ad-hoc callers keep using `?` (the type
/// converts into `anyhow::Error`).
#[derive(Debug)]
pub enum DecodeError {
    /// The frame ended before a field or payload could be read.
    Truncated {
        /// byte offset at which the read ran past the end
        at: usize,
    },
    /// The first four bytes are not `OMCW`.
    BadMagic,
    /// A version this decoder does not understand.
    UnsupportedVersion(u16),
    /// The declared variable count cannot fit in the frame.
    ImplausibleVarCount(usize),
    /// A declared length overflows addressable size.
    LengthOverflow {
        /// variable index
        var: usize,
    },
    /// A packed variable declares an invalid `SxEyMz` format.
    BadFormat {
        /// variable index
        var: usize,
        /// declared exponent bits
        e: u32,
        /// declared mantissa bits
        m: u32,
    },
    /// A packed variable carries non-finite PVT scalars.
    NonFinitePvt {
        /// variable index
        var: usize,
    },
    /// A packed payload length disagrees with `n` at the declared format.
    LengthMismatch {
        /// variable index
        var: usize,
    },
    /// An unknown per-variable tag byte.
    UnknownTag {
        /// variable index
        var: usize,
        /// the tag byte
        tag: u8,
    },
    /// Bytes remain after the last declared variable.
    TrailingBytes,
    /// The v2 header checksum does not match (covers magic through nonce).
    HeaderCrcMismatch,
    /// A variable record's CRC32C does not match its bytes.
    CrcMismatch {
        /// variable index
        var: usize,
    },
    /// The frame's nonce was already accepted (replayed/duplicated uplink).
    DuplicateNonce(u64),
    /// A delta (tag 2) record arrived but the receiver holds no packed
    /// base payload for this variable (no base provided, or the base
    /// stores the variable raw).
    MissingDeltaBase {
        /// variable index
        var: usize,
    },
    /// The frame's `base_version` header disagrees with the base model
    /// the receiver holds — decoding would XOR against the wrong bytes.
    BaseVersionMismatch {
        /// the base version the frame was encoded against
        frame: u64,
        /// the base version the receiver holds
        have: u64,
    },
    /// A delta block's class header exceeds 64 (no such width class).
    BadBlockWidth {
        /// variable index
        var: usize,
        /// the impossible class byte
        width: u8,
    },
    /// A delta record's `raw_len` disagrees with the format/`n`, or with
    /// the base payload's length.
    DeltaLengthMismatch {
        /// variable index
        var: usize,
    },
    /// A delta stream is structurally malformed (short of its declared
    /// blocks, or bytes left over after them).
    DeltaCorrupt {
        /// variable index
        var: usize,
    },
    /// A sparse (tag 3) record declares more selected coordinates than
    /// the variable holds (`k > n`).
    SparseCountMismatch {
        /// variable index
        var: usize,
    },
    /// A sparse record's value payload length disagrees with `k` at the
    /// declared format.
    SparseLengthMismatch {
        /// variable index
        var: usize,
    },
    /// A sparse index stream is structurally malformed (impossible block
    /// class, short of its declared gaps, or bytes left over after them).
    SparseIndexCorrupt {
        /// variable index
        var: usize,
    },
    /// A sparse index stream reconstructs an index at or past `n` —
    /// scattering it would write out of bounds.
    SparseIndexOutOfRange {
        /// variable index
        var: usize,
    },
    /// The per-variable callback failed (not a wire-format problem).
    Callback(anyhow::Error),
}

impl std::fmt::Display for DecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DecodeError::Truncated { at } => {
                write!(f, "truncated frame (read past end at byte {at})")
            }
            DecodeError::BadMagic => write!(f, "bad magic (not an OMC frame)"),
            DecodeError::UnsupportedVersion(v) => {
                write!(f, "unsupported wire version {v}")
            }
            DecodeError::ImplausibleVarCount(n) => {
                write!(f, "implausible variable count {n}")
            }
            DecodeError::LengthOverflow { var } => {
                write!(f, "length overflow in var {var}")
            }
            DecodeError::BadFormat { var, e, m } => {
                write!(f, "invalid format S1E{e}M{m} in var {var}")
            }
            DecodeError::NonFinitePvt { var } => {
                write!(f, "non-finite PVT scalars in var {var}")
            }
            DecodeError::LengthMismatch { var } => {
                write!(f, "payload length inconsistent with n in var {var}")
            }
            DecodeError::UnknownTag { var, tag } => {
                write!(f, "unknown variable tag {tag} in var {var}")
            }
            DecodeError::TrailingBytes => {
                write!(f, "trailing bytes after payload")
            }
            DecodeError::HeaderCrcMismatch => write!(f, "header CRC mismatch"),
            DecodeError::CrcMismatch { var } => {
                write!(f, "CRC mismatch in var {var}")
            }
            DecodeError::DuplicateNonce(n) => {
                write!(f, "duplicate frame nonce {n:#018x}")
            }
            DecodeError::MissingDeltaBase { var } => {
                write!(f, "no delta base payload for var {var}")
            }
            DecodeError::BaseVersionMismatch { frame, have } => {
                write!(f, "frame delta base version {frame} but receiver holds {have}")
            }
            DecodeError::BadBlockWidth { var, width } => {
                write!(f, "impossible delta block class {width} in var {var}")
            }
            DecodeError::DeltaLengthMismatch { var } => {
                write!(f, "delta raw length inconsistent in var {var}")
            }
            DecodeError::DeltaCorrupt { var } => {
                write!(f, "malformed delta stream in var {var}")
            }
            DecodeError::SparseCountMismatch { var } => {
                write!(f, "sparse count exceeds length in var {var}")
            }
            DecodeError::SparseLengthMismatch { var } => {
                write!(f, "sparse payload length inconsistent in var {var}")
            }
            DecodeError::SparseIndexCorrupt { var } => {
                write!(f, "malformed sparse index stream in var {var}")
            }
            DecodeError::SparseIndexOutOfRange { var } => {
                write!(f, "sparse index out of range in var {var}")
            }
            DecodeError::Callback(e) => write!(f, "decode callback: {e}"),
        }
    }
}

impl std::error::Error for DecodeError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            DecodeError::Callback(e) => Some(e.as_ref()),
            _ => None,
        }
    }
}

impl DecodeError {
    /// True when the error describes a bad *frame* (rejectable transport
    /// corruption) as opposed to a failed callback (a caller-side problem
    /// that must propagate, not be accounted as a rejected frame).
    pub fn is_frame_error(&self) -> bool {
        !matches!(self, DecodeError::Callback(_))
    }
}

/// Streaming writer for the wire format — lets callers assemble a payload
/// from borrowed parts without materializing a `CompressedModel` (the
/// round loop reuses one compressed copy of each variable across all
/// clients and only the framing differs per client).
pub struct WireWriter {
    buf: Vec<u8>,
    nvars: u32,
    /// `Some(nonce)` ⇒ emit the v2 integrity layout (nonce + header CRC +
    /// per-variable CRC32C); `None` ⇒ the byte-identical v1 fast path.
    integrity: Option<u64>,
    /// `Some(base_version)` ⇒ emit the v3 delta layout (implies
    /// integrity): the header carries the base version and variables may
    /// be delta-packed against it.
    base_version: Option<u64>,
    /// Bytes the delta stage saved vs the verbatim tag-1 records it
    /// replaced (accumulated across [`packed_delta`](Self::packed_delta)
    /// calls).
    delta_saved: usize,
    /// Bytes the sparse stage saved vs the verbatim tag-1 records it
    /// replaced (accumulated across
    /// [`sparse_values`](Self::sparse_values) calls).
    sparse_saved: usize,
}

/// Reused buffers for the delta encode path: the quantized payload image,
/// the XOR scratch, and the bitpacked stream. One per encoding thread,
/// recycled across variables and rounds.
#[derive(Default)]
pub struct DeltaScratch {
    packed: Vec<u8>,
    xored: Vec<u8>,
    stream: Vec<u8>,
}

impl WireWriter {
    /// Start a frame in a fresh buffer with `cap` bytes reserved.
    pub fn with_capacity(cap: usize) -> Self {
        Self::with_buf_and_capacity(Vec::new(), cap)
    }

    /// Start a frame in a recycled buffer (cleared; its capacity plus
    /// `cap` extra is retained) — the round loop's per-client payload
    /// buffers live across rounds this way.
    pub fn with_buf_and_capacity(buf: Vec<u8>, cap: usize) -> Self {
        Self::new_inner(buf, cap, None, None)
    }

    /// Start a checksummed v2 frame carrying `nonce` in a fresh buffer.
    pub fn with_integrity(cap: usize, nonce: u64) -> Self {
        Self::new_inner(Vec::new(), cap, Some(nonce), None)
    }

    /// [`with_integrity`](Self::with_integrity) into a recycled buffer.
    pub fn with_buf_and_integrity(buf: Vec<u8>, cap: usize, nonce: u64) -> Self {
        Self::new_inner(buf, cap, Some(nonce), None)
    }

    /// Start a v3 delta frame carrying `nonce` and the ack handshake's
    /// `base_version` in a fresh buffer. Delta frames are always
    /// checksummed — the XOR stage amplifies a flipped payload bit into
    /// wrong values across the whole variable, so v3 without per-record
    /// CRCs is not a layout this writer can emit.
    pub fn with_delta(cap: usize, nonce: u64, base_version: u64) -> Self {
        Self::new_inner(Vec::new(), cap, Some(nonce), Some(base_version))
    }

    /// [`with_delta`](Self::with_delta) into a recycled buffer.
    pub fn with_buf_and_delta(
        buf: Vec<u8>,
        cap: usize,
        nonce: u64,
        base_version: u64,
    ) -> Self {
        Self::new_inner(buf, cap, Some(nonce), Some(base_version))
    }

    fn new_inner(
        mut buf: Vec<u8>,
        cap: usize,
        integrity: Option<u64>,
        base_version: Option<u64>,
    ) -> Self {
        debug_assert!(
            base_version.is_none() || integrity.is_some(),
            "delta frames require the integrity layout"
        );
        buf.clear();
        buf.reserve(cap + 40);
        buf.extend_from_slice(MAGIC);
        match integrity {
            None => {
                buf.extend_from_slice(&VERSION.to_le_bytes());
                buf.extend_from_slice(&0u32.to_le_bytes()); // patched in finish()
            }
            Some(nonce) => {
                let version = if base_version.is_some() {
                    VERSION_DELTA
                } else {
                    VERSION_INTEGRITY
                };
                buf.extend_from_slice(&version.to_le_bytes());
                buf.extend_from_slice(&0u32.to_le_bytes()); // patched in finish()
                buf.extend_from_slice(&nonce.to_le_bytes());
                if let Some(bv) = base_version {
                    buf.extend_from_slice(&bv.to_le_bytes());
                }
                buf.extend_from_slice(&0u32.to_le_bytes()); // hcrc, in finish()
            }
        }
        Self {
            buf,
            nvars: 0,
            integrity,
            base_version,
            delta_saved: 0,
            sparse_saved: 0,
        }
    }

    /// Close out the variable record that started at byte `start`: append
    /// its CRC32C when writing the integrity layout, and count it.
    fn seal_var(&mut self, start: usize) {
        if self.integrity.is_some() {
            let crc = crc32c(0, &self.buf[start..]);
            self.buf.extend_from_slice(&crc.to_le_bytes());
        }
        self.nvars += 1;
    }

    /// Emit an unquantized variable: `n` f32 values shipped as-is.
    pub fn raw(&mut self, v: &[f32]) {
        let start = self.buf.len();
        self.buf.push(0u8);
        self.buf.extend_from_slice(&(v.len() as u32).to_le_bytes());
        // bulk-copy the f32 payload (little-endian hosts: this is memcpy)
        for x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
        self.seal_var(start);
    }

    /// Emit an already bit-packed variable payload with its PVT scalars.
    pub fn packed(&mut self, bytes: &[u8], n: usize, fmt: FloatFormat, pvt: Pvt) {
        let start = self.buf.len();
        self.packed_header(n, fmt, pvt, bytes.len());
        self.buf.extend_from_slice(bytes);
        self.seal_var(start);
    }

    fn packed_header(&mut self, n: usize, fmt: FloatFormat, pvt: Pvt, plen: usize) {
        self.buf.push(1u8);
        self.buf.extend_from_slice(&(n as u32).to_le_bytes());
        self.buf.push(fmt.exp_bits as u8);
        self.buf.push(fmt.mant_bits as u8);
        self.buf.extend_from_slice(&pvt.s.to_le_bytes());
        self.buf.extend_from_slice(&pvt.b.to_le_bytes());
        self.buf.extend_from_slice(&(plen as u32).to_le_bytes());
    }

    /// Emit a packed variable by bit-packing `vt` (already-quantized fixed
    /// points, e.g. the Ṽ' a training step returned) straight into the
    /// frame — the client uplink path, with no intermediate payload `Vec`.
    pub fn packed_values(
        &mut self,
        vt: &[f32],
        fmt: FloatFormat,
        pvt: Pvt,
    ) -> std::result::Result<(), PackError> {
        let start = self.buf.len();
        self.packed_header(vt.len(), fmt, pvt, fmt.packed_bytes(vt.len()));
        pack::pack_extend(vt, fmt, &mut self.buf)?;
        self.seal_var(start);
        Ok(())
    }

    /// Emit a packed variable by running the fused quantize → PVT-fit →
    /// pack pipeline straight into the frame (`values` need not be
    /// quantized). The PVT scalars land in the header retroactively
    /// (before the record is sealed, so the v2 CRC covers the final bytes).
    pub fn compress_values(&mut self, values: &[f32], fmt: FloatFormat, use_pvt: bool) {
        let start = self.buf.len();
        let plen = fmt.packed_bytes(values.len());
        self.packed_header(values.len(), fmt, Pvt::IDENTITY, plen);
        // s/b sit 12 bytes back from the header end (s f32, b f32, plen u32)
        let sb_at = self.buf.len() - 12;
        let pvt = pack::quantize_transform_pack(values, fmt, use_pvt, &mut self.buf);
        self.buf[sb_at..sb_at + 4].copy_from_slice(&pvt.s.to_le_bytes());
        self.buf[sb_at + 4..sb_at + 8].copy_from_slice(&pvt.b.to_le_bytes());
        self.seal_var(start);
    }

    /// Emit a stored variable (raw or packed, whichever it is).
    pub fn var(&mut self, v: &StoredVar) {
        match v {
            StoredVar::Raw(data) => self.raw(data),
            StoredVar::Packed { bytes, n, fmt, pvt } => {
                self.packed(bytes, *n, *fmt, *pvt)
            }
        }
    }

    /// Emit a packed variable delta-coded against `base` — the base
    /// version's packed payload for the same variable — falling back to a
    /// verbatim tag-1 record whenever the delta cannot win: no base, a
    /// base of different length (format or shape changed between
    /// versions), or a bitpacked stream at least as large as the verbatim
    /// payload. The fallback decision is a pure function of the two
    /// payloads, so encoder and decoder never need to negotiate it.
    /// Requires a writer started with [`with_delta`](Self::with_delta).
    pub fn packed_delta(
        &mut self,
        payload: &[u8],
        n: usize,
        fmt: FloatFormat,
        pvt: Pvt,
        base: Option<&[u8]>,
        scratch: &mut DeltaScratch,
    ) {
        debug_assert!(
            self.base_version.is_some(),
            "packed_delta requires a v3 (with_delta) writer"
        );
        if let Some(base) = base {
            if base.len() == payload.len() && !payload.is_empty() {
                scratch.stream.clear();
                let slen = delta::xor_encode_into(
                    payload,
                    base,
                    &mut scratch.xored,
                    &mut scratch.stream,
                );
                // a tag-2 record carries one extra u32 (raw_len) over tag 1
                if slen + 4 < payload.len() {
                    self.delta_saved += payload.len() - (slen + 4);
                    let start = self.buf.len();
                    self.buf.push(2u8);
                    self.buf.extend_from_slice(&(n as u32).to_le_bytes());
                    self.buf.push(fmt.exp_bits as u8);
                    self.buf.push(fmt.mant_bits as u8);
                    self.buf.extend_from_slice(&pvt.s.to_le_bytes());
                    self.buf.extend_from_slice(&pvt.b.to_le_bytes());
                    self.buf
                        .extend_from_slice(&(payload.len() as u32).to_le_bytes());
                    self.buf.extend_from_slice(&(slen as u32).to_le_bytes());
                    self.buf.extend_from_slice(&scratch.stream);
                    self.seal_var(start);
                    return;
                }
            }
        }
        self.packed(payload, n, fmt, pvt);
    }

    /// Emit a packed variable by bit-packing `vt` and delta-coding the
    /// payload against `base` (see [`packed_delta`](Self::packed_delta))
    /// — the client uplink path when the delta stage is on.
    pub fn packed_values_delta(
        &mut self,
        vt: &[f32],
        fmt: FloatFormat,
        pvt: Pvt,
        base: Option<&[u8]>,
        scratch: &mut DeltaScratch,
    ) -> std::result::Result<(), PackError> {
        scratch.packed.clear();
        pack::pack_extend(vt, fmt, &mut scratch.packed)?;
        let packed = std::mem::take(&mut scratch.packed);
        self.packed_delta(&packed, vt.len(), fmt, pvt, base, scratch);
        scratch.packed = packed;
        Ok(())
    }

    /// Emit a stored variable, delta-coding packed payloads against
    /// `base` (raw variables ship verbatim as always).
    pub fn var_delta(
        &mut self,
        v: &StoredVar,
        base: Option<&[u8]>,
        scratch: &mut DeltaScratch,
    ) {
        match v {
            StoredVar::Raw(data) => self.raw(data),
            StoredVar::Packed { bytes, n, fmt, pvt } => {
                self.packed_delta(bytes, *n, *fmt, *pvt, base, scratch)
            }
        }
    }

    /// Emit a sparse (tag 3) variable record: `k` selected coordinates of
    /// an `n`-element update. `indices` must be sorted strictly ascending
    /// with every entry below `n`, and `gathered` holds the corresponding
    /// update values in the same order. The index stream is gap-coded and
    /// bitpacked ([`sparse::encode_indices_into`]); the values run through
    /// the fused quantize → PVT-fit → pack pipeline at `fmt`, exactly like
    /// [`compress_values`](Self::compress_values). Returns the fitted PVT
    /// scalars (the decoder needs nothing else — the record is
    /// self-describing). Requires an integrity writer (v2/v3): a flipped
    /// index-stream bit would scatter values to the wrong coordinates, so
    /// tag 3 without a record CRC is not a layout this writer can emit.
    pub fn sparse_values(
        &mut self,
        gathered: &[f32],
        indices: &[u32],
        n: usize,
        fmt: FloatFormat,
        use_pvt: bool,
    ) -> Pvt {
        debug_assert!(
            self.integrity.is_some(),
            "sparse_values requires an integrity (v2/v3) writer"
        );
        debug_assert_eq!(gathered.len(), indices.len());
        let k = indices.len();
        let start = self.buf.len();
        self.buf.push(3u8);
        self.buf.extend_from_slice(&(n as u32).to_le_bytes());
        self.buf.push(fmt.exp_bits as u8);
        self.buf.push(fmt.mant_bits as u8);
        self.buf.extend_from_slice(&Pvt::IDENTITY.s.to_le_bytes());
        self.buf.extend_from_slice(&Pvt::IDENTITY.b.to_le_bytes());
        let sb_at = self.buf.len() - 8;
        self.buf.extend_from_slice(&(k as u32).to_le_bytes());
        let islen_at = self.buf.len();
        self.buf.extend_from_slice(&0u32.to_le_bytes()); // patched below
        self.buf
            .extend_from_slice(&(fmt.packed_bytes(k) as u32).to_le_bytes());
        let islen = sparse::encode_indices_into(indices, &mut self.buf);
        self.buf[islen_at..islen_at + 4]
            .copy_from_slice(&(islen as u32).to_le_bytes());
        let pvt = pack::quantize_transform_pack(gathered, fmt, use_pvt, &mut self.buf);
        self.buf[sb_at..sb_at + 4].copy_from_slice(&pvt.s.to_le_bytes());
        self.buf[sb_at + 4..sb_at + 8].copy_from_slice(&pvt.b.to_le_bytes());
        // accounting vs the verbatim tag-1 record this replaced: dense
        // costs 19 header bytes + packed_bytes(n) (CRC identical on both)
        let record = self.buf.len() - start;
        self.sparse_saved +=
            (19 + fmt.packed_bytes(n)).saturating_sub(record);
        self.seal_var(start);
        pvt
    }

    /// Bytes the delta stage has saved so far vs verbatim tag-1 records
    /// (0 for non-delta writers and for frames where every variable fell
    /// back). Read before [`finish`](Self::finish).
    pub fn delta_saved(&self) -> usize {
        self.delta_saved
    }

    /// Bytes the sparse stage has saved so far vs the verbatim tag-1
    /// records it replaced (0 when no sparse record was emitted). A
    /// selection too dense to win can make an individual record larger
    /// than verbatim; such records contribute 0, never negative. Read
    /// before [`finish`](Self::finish).
    pub fn sparse_saved(&self) -> usize {
        self.sparse_saved
    }

    /// Patch the header's variable count (and, for integrity frames, the
    /// header CRC) and hand back the finished frame.
    pub fn finish(mut self) -> Vec<u8> {
        let nv = self.nvars.to_le_bytes();
        self.buf[6..10].copy_from_slice(&nv);
        if self.integrity.is_some() {
            let (hcrc_at, header_len) = if self.base_version.is_some() {
                (V3_HCRC_AT, V3_HEADER_LEN)
            } else {
                (V2_HCRC_AT, V2_HEADER_LEN)
            };
            let hcrc = crc32c(0, &self.buf[..hcrc_at]);
            self.buf[hcrc_at..header_len].copy_from_slice(&hcrc.to_le_bytes());
        }
        self.buf
    }
}

/// Serialize a compressed model into wire bytes.
pub fn encode(model: &CompressedModel) -> Vec<u8> {
    let mut w = WireWriter::with_capacity(model.memory_bytes() + 8 * model.vars.len());
    for var in &model.vars {
        w.var(var);
    }
    w.finish()
}

/// [`encode`] into a recycled buffer (cleared; capacity retained).
pub fn encode_into(model: &CompressedModel, buf: &mut Vec<u8>) {
    let cap = model.memory_bytes() + 8 * model.vars.len();
    let mut w = WireWriter::with_buf_and_capacity(std::mem::take(buf), cap);
    for var in &model.vars {
        w.var(var);
    }
    *buf = w.finish();
}

/// Reusable wire encoder: owns a buffer recycled across `encode` calls so
/// repeated whole-model serialization performs no steady-state allocation.
#[derive(Default)]
pub struct Encoder {
    buf: Vec<u8>,
}

impl Encoder {
    /// Fresh encoder with an empty (cold) buffer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Encode into the internal buffer and borrow the frame.
    pub fn encode(&mut self, model: &CompressedModel) -> &[u8] {
        encode_into(model, &mut self.buf);
        &self.buf
    }
}

/// A borrowed view of one variable in a wire frame — what the streaming
/// decoder hands to its callback. Payloads reference the input buffer;
/// nothing is copied until the caller decides where the values go.
#[derive(Debug)]
pub enum VarView<'a> {
    /// Unquantized variable: `n` f32 values, little-endian bytes.
    Raw {
        /// the `n * 4` little-endian f32 bytes, borrowed from the frame
        data: &'a [u8],
        /// element count
        n: usize,
    },
    /// Bit-packed variable: decode with `pack::unpack*` family.
    Packed {
        /// the bit-packed codes, borrowed from the frame
        payload: &'a [u8],
        /// element count
        n: usize,
        /// the `SxEyMz` format the codes are packed at
        fmt: FloatFormat,
        /// per-variable transform scalars
        pvt: Pvt,
    },
    /// Sparse-packed *update* (tag 3): `k` selected coordinates of an
    /// `n`-element update vector. The index stream was decoded and
    /// validated before this view reached the callback; `payload` holds
    /// the `k` gathered values bit-packed at `fmt`. Unselected
    /// coordinates are zero by construction.
    Sparse {
        /// the selected coordinates, ascending, all below `n` (borrowed
        /// from the decoder's scratch, not the frame)
        indices: &'a [u32],
        /// the bit-packed codes of the `k` gathered values
        payload: &'a [u8],
        /// dense element count of the update
        n: usize,
        /// the `SxEyMz` format the gathered values are packed at
        fmt: FloatFormat,
        /// transform scalars fitted over the gathered values
        pvt: Pvt,
    },
}

impl VarView<'_> {
    /// Element count of the variable (the dense count for sparse views).
    pub fn len(&self) -> usize {
        match self {
            VarView::Raw { n, .. }
            | VarView::Packed { n, .. }
            | VarView::Sparse { n, .. } => *n,
        }
    }

    /// Whether the variable has zero elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Bytes this variable would occupy in a client's parameter store
    /// (the Sec. 3.4 accounting: payload + PVT scalars when packed).
    pub fn memory_bytes(&self) -> usize {
        match self {
            VarView::Raw { data, .. } => data.len(),
            VarView::Packed { payload, .. } => payload.len() + 8,
            VarView::Sparse { indices, payload, .. } => {
                indices.len() * 4 + payload.len() + 8
            }
        }
    }

    /// Decode this variable's decompressed values (`V̄ = s·Ṽ + b`) into a
    /// reused buffer. A sparse view decodes to the **dense update
    /// vector**: zeros everywhere, the decompressed gathered values
    /// scattered at their indices.
    pub fn decompress_into(&self, out: &mut Vec<f32>) {
        match *self {
            VarView::Raw { data, .. } => raw_f32s_into(data, out),
            VarView::Packed { payload, n, fmt, pvt } => {
                pack::unpack_transform_into(payload, n, fmt, pvt.s, pvt.b, out)
            }
            VarView::Sparse { indices, payload, n, fmt, pvt } => {
                pack::unpack_transform_into(
                    payload,
                    indices.len(),
                    fmt,
                    pvt.s,
                    pvt.b,
                    out,
                );
                scatter_in_place(out, indices, n);
            }
        }
    }

    /// Decode this variable's quantized values Ṽ (no transform) into a
    /// reused buffer. A sparse view yields the dense update layout with
    /// the raw codes scattered at their indices.
    pub fn tilde_into(&self, out: &mut Vec<f32>) {
        match *self {
            VarView::Raw { data, .. } => raw_f32s_into(data, out),
            VarView::Packed { payload, n, fmt, .. } => {
                pack::unpack_into(payload, n, fmt, out)
            }
            VarView::Sparse { indices, payload, n, fmt, .. } => {
                pack::unpack_into(payload, indices.len(), fmt, out);
                scatter_in_place(out, indices, n);
            }
        }
    }
}

/// Expand `out` — holding `indices.len()` gathered values — to the dense
/// `n`-element layout in place: value `j` moves to `indices[j]`, every
/// other coordinate becomes zero. Indices ascend, so `indices[j] >= j`
/// and a single back-to-front pass never overwrites an unread value.
fn scatter_in_place(out: &mut Vec<f32>, indices: &[u32], n: usize) {
    debug_assert_eq!(out.len(), indices.len());
    out.resize(n, 0.0);
    for j in (0..indices.len()).rev() {
        let v = out[j];
        out[j] = 0.0;
        out[indices[j] as usize] = v;
    }
}

/// Copy a little-endian f32 image into a reused buffer.
fn raw_f32s_into(data: &[u8], out: &mut Vec<f32>) {
    out.clear();
    out.reserve(data.len() / 4);
    for c in data.chunks_exact(4) {
        out.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
    }
}

/// Streaming decoder: validate the frame and hand each variable to `f` as
/// a borrowed [`VarView`], in order. Returns the variable count. This is
/// the single wire parser — [`decode`], the client's zero-alloc downlink
/// path, and the server's streaming uplink aggregation are all built on it.
///
/// ```
/// use omc_fl::omc::codec::{self, WireWriter};
///
/// // assemble a two-variable frame...
/// let mut w = WireWriter::with_capacity(0);
/// w.raw(&[1.0f32, 2.0, 3.0]);
/// w.raw(&[-4.0f32]);
/// let frame = w.finish();
///
/// // ...and stream it back out without materializing a model
/// let mut total = 0usize;
/// let nvars = codec::for_each_var(&frame, |_i, view| {
///     total += view.len();
///     Ok(())
/// })
/// .unwrap();
/// assert_eq!((nvars, total), (2, 4));
/// ```
pub fn for_each_var<F>(
    bytes: &[u8],
    f: F,
) -> std::result::Result<usize, DecodeError>
where
    F: FnMut(usize, VarView<'_>) -> Result<()>,
{
    for_each_var_based(bytes, None, f)
}

/// [`for_each_var`] with an optional delta base: the committed model
/// version a v3 frame's tag-2 records are XOR-coded against. Tag-2
/// payloads are delta-decoded and XORed into a scratch buffer before the
/// callback sees them, so the callback receives ordinary packed views
/// either way. Typed refusals instead of silent mis-decodes:
///
/// * a tag-2 record with no base (or a raw base variable) ⇒
///   [`DecodeError::MissingDeltaBase`];
/// * a base whose version disagrees with the frame header ⇒
///   [`DecodeError::BaseVersionMismatch`];
/// * a base payload of the wrong length ⇒
///   [`DecodeError::DeltaLengthMismatch`].
///
/// Passing a base to a v1/v2 frame is harmless — plain frames never
/// reference it.
pub fn for_each_var_based<F>(
    bytes: &[u8],
    base: Option<&DeltaBase<'_>>,
    mut f: F,
) -> std::result::Result<usize, DecodeError>
where
    F: FnMut(usize, VarView<'_>) -> Result<()>,
{
    let mut r = Reader { b: bytes, i: 0 };
    let (version, nvars) = r.header(bytes)?;
    let checked = version != VERSION;
    let delta_frame = version == VERSION_DELTA;
    if delta_frame {
        if let Some(b) = base {
            let frame_bv = u64::from_le_bytes(
                bytes[18..26].try_into().expect("header bounds checked"),
            );
            if frame_bv != b.version {
                return Err(DecodeError::BaseVersionMismatch {
                    frame: frame_bv,
                    have: b.version,
                });
            }
        }
    }
    // reused across variables: the unpacked XOR stream and the
    // reconstructed payload a tag-2 view borrows from, plus the decoded
    // index list a tag-3 view borrows from
    let mut delta_words = Vec::new();
    let mut delta_payload = Vec::new();
    let mut sparse_indices = Vec::new();
    for vi in 0..nvars {
        let start = r.i;
        let parsed = r.parse_var(vi, delta_frame, checked)?;
        if checked {
            // verify the record's checksum BEFORE the view reaches the
            // callback — corrupted bytes must never be decoded
            let end = r.i;
            let want = r.u32()?;
            if crc32c(0, &bytes[start..end]) != want {
                return Err(DecodeError::CrcMismatch { var: vi });
            }
        }
        match parsed {
            ParsedVar::Plain(view) => {
                f(vi, view).map_err(DecodeError::Callback)?;
            }
            ParsedVar::Delta { stream, raw_len, n, fmt, pvt } => {
                let base_payload = base
                    .and_then(|b| b.var(vi))
                    .ok_or(DecodeError::MissingDeltaBase { var: vi })?;
                if base_payload.len() != raw_len {
                    return Err(DecodeError::DeltaLengthMismatch { var: vi });
                }
                delta::xor_decode_into(
                    stream,
                    base_payload,
                    &mut delta_words,
                    &mut delta_payload,
                )
                .map_err(|e| match e {
                    DeltaError::BadWidth(w) => {
                        DecodeError::BadBlockWidth { var: vi, width: w }
                    }
                    _ => DecodeError::DeltaCorrupt { var: vi },
                })?;
                f(
                    vi,
                    VarView::Packed { payload: &delta_payload, n, fmt, pvt },
                )
                .map_err(DecodeError::Callback)?;
            }
            ParsedVar::Sparse { index_stream, payload, k, n, fmt, pvt } => {
                sparse::decode_indices_into(
                    index_stream,
                    k,
                    n,
                    &mut sparse_indices,
                )
                .map_err(|e| match e {
                    SparseIndexError::IndexOverflow => {
                        DecodeError::SparseIndexOutOfRange { var: vi }
                    }
                    _ => DecodeError::SparseIndexCorrupt { var: vi },
                })?;
                f(
                    vi,
                    VarView::Sparse {
                        indices: &sparse_indices,
                        payload,
                        n,
                        fmt,
                        pvt,
                    },
                )
                .map_err(DecodeError::Callback)?;
            }
        }
    }
    if r.i != bytes.len() {
        return Err(DecodeError::TrailingBytes);
    }
    Ok(nvars)
}

/// Summary of a verified frame, returned by [`verify_frame`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FrameInfo {
    /// wire version (1 plain, 2 integrity, 3 delta)
    pub version: u16,
    /// declared (and verified) variable count
    pub nvars: usize,
    /// the v2/v3 nonce; `None` for v1 frames
    pub nonce: Option<u64>,
    /// the v3 ack base version; `None` for v1/v2 frames
    pub base_version: Option<u64>,
}

/// Parse a frame's header and return its nonce (`None` for v1 frames).
/// For v2/v3 frames the header CRC is verified first, so a flipped nonce —
/// not covered by any per-variable checksum — is still rejected.
pub fn frame_nonce(bytes: &[u8]) -> std::result::Result<Option<u64>, DecodeError> {
    let mut r = Reader { b: bytes, i: 0 };
    let (version, _) = r.header(bytes)?;
    Ok(match version {
        VERSION_INTEGRITY | VERSION_DELTA => Some(u64::from_le_bytes(
            bytes[10..18].try_into().expect("header bounds checked"),
        )),
        _ => None,
    })
}

/// Parse a frame's header and return the delta base version (`None` for
/// v1/v2 frames). CRC-verified like [`frame_nonce`].
pub fn frame_base_version(
    bytes: &[u8],
) -> std::result::Result<Option<u64>, DecodeError> {
    let mut r = Reader { b: bytes, i: 0 };
    let (version, _) = r.header(bytes)?;
    Ok(match version {
        VERSION_DELTA => Some(u64::from_le_bytes(
            bytes[18..26].try_into().expect("header bounds checked"),
        )),
        _ => None,
    })
}

/// Walk a frame end to end, verifying structure and every checksum
/// without decoding any payload — the cheap accept/reject decision the
/// round engines make before folding an uplink into the aggregator (a
/// CRC failure mid-[`StreamingAggregator`] fold would leave the sums
/// half-updated; verifying first keeps rejection side-effect free).
///
/// [`StreamingAggregator`]: crate::fl::server::StreamingAggregator
pub fn verify_frame(bytes: &[u8]) -> std::result::Result<FrameInfo, DecodeError> {
    let nonce = frame_nonce(bytes)?;
    let base_version = frame_base_version(bytes)?;
    let mut r = Reader { b: bytes, i: 0 };
    let (version, nvars) = r.header(bytes)?;
    let checked = version != VERSION;
    let delta_frame = version == VERSION_DELTA;
    for vi in 0..nvars {
        let start = r.i;
        let _ = r.parse_var(vi, delta_frame, checked)?;
        if checked {
            let end = r.i;
            let want = r.u32()?;
            if crc32c(0, &bytes[start..end]) != want {
                return Err(DecodeError::CrcMismatch { var: vi });
            }
        }
    }
    if r.i != bytes.len() {
        return Err(DecodeError::TrailingBytes);
    }
    Ok(FrameInfo { version, nvars, nonce, base_version })
}

/// Bounded ledger of accepted frame nonces — the server-side duplicate
/// detector. A replayed or duplicated v2 uplink carries a nonce the
/// ledger has already seen and is rejected as
/// [`DecodeError::DuplicateNonce`]; v1 frames (no nonce) pass through.
/// Capacity-bounded FIFO eviction keeps memory O(cap) over long runs.
#[derive(Debug)]
pub struct NonceLedger {
    seen: std::collections::HashSet<u64>,
    order: std::collections::VecDeque<u64>,
    cap: usize,
}

impl NonceLedger {
    /// Ledger remembering at most `cap` recent nonces (`cap >= 1`).
    pub fn new(cap: usize) -> Self {
        assert!(cap >= 1, "nonce ledger capacity must be >= 1");
        Self {
            seen: std::collections::HashSet::new(),
            order: std::collections::VecDeque::new(),
            cap,
        }
    }

    /// Record a frame's nonce. `Err(DuplicateNonce)` when it was already
    /// accepted; `Ok` (and remembered) otherwise. `None` — a v1 frame —
    /// is always accepted and never remembered.
    pub fn observe(
        &mut self,
        nonce: Option<u64>,
    ) -> std::result::Result<(), DecodeError> {
        let Some(n) = nonce else { return Ok(()) };
        if !self.seen.insert(n) {
            return Err(DecodeError::DuplicateNonce(n));
        }
        self.order.push_back(n);
        if self.order.len() > self.cap {
            if let Some(old) = self.order.pop_front() {
                self.seen.remove(&old);
            }
        }
        Ok(())
    }

    /// Nonces currently remembered.
    pub fn len(&self) -> usize {
        self.order.len()
    }

    /// True when no nonce has been recorded.
    pub fn is_empty(&self) -> bool {
        self.order.is_empty()
    }
}

/// Decode wire bytes back into a compressed model. Sparse (tag 3)
/// records — which carry updates, not absolute values — materialize as
/// raw dense update vectors; the aggregation paths fold sparse views
/// directly and never take this route.
pub fn decode(bytes: &[u8]) -> Result<CompressedModel> {
    let mut vars = Vec::new();
    for_each_var(bytes, |_, view| {
        vars.push(match view {
            VarView::Raw { data, .. } => {
                let mut v = Vec::new();
                raw_f32s_into(data, &mut v);
                StoredVar::Raw(v)
            }
            VarView::Packed { payload, n, fmt, pvt } => StoredVar::Packed {
                bytes: payload.to_vec(),
                n,
                fmt,
                pvt,
            },
            sparse @ VarView::Sparse { .. } => {
                let mut v = Vec::new();
                sparse.decompress_into(&mut v);
                StoredVar::Raw(v)
            }
        });
        Ok(())
    })?;
    Ok(CompressedModel::new(vars))
}

/// Decode wire bytes straight to decompressed `V̄` values (fused
/// unpack+transform per variable, no `CompressedModel` intermediate) — the
/// server's uplink-decode hot path.
pub fn decode_decompressed(bytes: &[u8]) -> Result<Vec<Vec<f32>>> {
    let mut out = Vec::new();
    for_each_var(bytes, |_, view| {
        let mut v = Vec::new();
        view.decompress_into(&mut v);
        out.push(v);
        Ok(())
    })?;
    Ok(out)
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> std::result::Result<&'a [u8], DecodeError> {
        let end = self
            .i
            .checked_add(n)
            .filter(|&end| end <= self.b.len())
            .ok_or(DecodeError::Truncated { at: self.i })?;
        let s = &self.b[self.i..end];
        self.i = end;
        Ok(s)
    }

    fn u8(&mut self) -> std::result::Result<u8, DecodeError> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> std::result::Result<u16, DecodeError> {
        let s = self.take(2)?;
        Ok(u16::from_le_bytes([s[0], s[1]]))
    }

    fn u32(&mut self) -> std::result::Result<u32, DecodeError> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn u64(&mut self) -> std::result::Result<u64, DecodeError> {
        let s = self.take(8)?;
        Ok(u64::from_le_bytes([
            s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7],
        ]))
    }

    fn arr4(&mut self) -> std::result::Result<[u8; 4], DecodeError> {
        let s = self.take(4)?;
        Ok([s[0], s[1], s[2], s[3]])
    }

    /// Parse and validate the frame header, leaving the cursor at the
    /// first variable record. Returns `(version, nvars)`.
    fn header(
        &mut self,
        bytes: &[u8],
    ) -> std::result::Result<(u16, usize), DecodeError> {
        let magic = self.take(4)?;
        if magic != MAGIC {
            return Err(DecodeError::BadMagic);
        }
        let version = self.u16()?;
        if version != VERSION
            && version != VERSION_INTEGRITY
            && version != VERSION_DELTA
        {
            return Err(DecodeError::UnsupportedVersion(version));
        }
        let nvars = self.u32()? as usize;
        if version != VERSION {
            let _nonce = self.u64()?;
            let hcrc_at = if version == VERSION_DELTA {
                let _base_version = self.u64()?;
                V3_HCRC_AT
            } else {
                V2_HCRC_AT
            };
            let hcrc = self.u32()?;
            if crc32c(0, &bytes[..hcrc_at]) != hcrc {
                return Err(DecodeError::HeaderCrcMismatch);
            }
        }
        // sanity bound: each variable needs >= 5 bytes of header
        if nvars > bytes.len() / 5 + 1 {
            return Err(DecodeError::ImplausibleVarCount(nvars));
        }
        Ok((version, nvars))
    }

    /// Parse one variable record (tag + metadata + payload). Tag 2 is
    /// only legal inside a v3 frame (`allow_delta`); tag 3 is only legal
    /// inside the checksummed v2/v3 layouts (`allow_sparse`) — a sparse
    /// record without CRC coverage could scatter values to the wrong
    /// coordinates undetected.
    fn parse_var(
        &mut self,
        vi: usize,
        allow_delta: bool,
        allow_sparse: bool,
    ) -> std::result::Result<ParsedVar<'a>, DecodeError> {
        let tag = self.u8()?;
        let n = self.u32()? as usize;
        match tag {
            0 => {
                let len = n
                    .checked_mul(4)
                    .ok_or(DecodeError::LengthOverflow { var: vi })?;
                let data = self.take(len)?;
                Ok(ParsedVar::Plain(VarView::Raw { data, n }))
            }
            1 => {
                let (fmt, pvt) = self.packed_meta(vi)?;
                let plen = self.u32()? as usize;
                if plen != fmt.packed_bytes(n) {
                    return Err(DecodeError::LengthMismatch { var: vi });
                }
                let payload = self.take(plen)?;
                Ok(ParsedVar::Plain(VarView::Packed { payload, n, fmt, pvt }))
            }
            2 if allow_delta => {
                let (fmt, pvt) = self.packed_meta(vi)?;
                let raw_len = self.u32()? as usize;
                if raw_len != fmt.packed_bytes(n) {
                    return Err(DecodeError::DeltaLengthMismatch { var: vi });
                }
                let slen = self.u32()? as usize;
                let stream = self.take(slen)?;
                Ok(ParsedVar::Delta { stream, raw_len, n, fmt, pvt })
            }
            3 if allow_sparse => {
                let (fmt, pvt) = self.packed_meta(vi)?;
                let k = self.u32()? as usize;
                if k > n {
                    return Err(DecodeError::SparseCountMismatch { var: vi });
                }
                let islen = self.u32()? as usize;
                let vlen = self.u32()? as usize;
                if vlen != fmt.packed_bytes(k) {
                    return Err(DecodeError::SparseLengthMismatch { var: vi });
                }
                let index_stream = self.take(islen)?;
                let payload = self.take(vlen)?;
                Ok(ParsedVar::Sparse { index_stream, payload, k, n, fmt, pvt })
            }
            t => Err(DecodeError::UnknownTag { var: vi, tag: t }),
        }
    }

    /// The shared packed-record metadata: format byte pair + PVT scalars.
    fn packed_meta(
        &mut self,
        vi: usize,
    ) -> std::result::Result<(FloatFormat, Pvt), DecodeError> {
        let e = self.u8()? as u32;
        let m = self.u8()? as u32;
        let fmt = FloatFormat::new(e, m)
            .map_err(|_| DecodeError::BadFormat { var: vi, e, m })?;
        let s = f32::from_le_bytes(self.arr4()?);
        let b = f32::from_le_bytes(self.arr4()?);
        if !(s.is_finite() && b.is_finite()) {
            return Err(DecodeError::NonFinitePvt { var: vi });
        }
        Ok((fmt, Pvt { s, b }))
    }
}

/// One parsed variable record: a ready-to-use borrowed view, a delta
/// record whose payload still needs the base XOR, or a sparse record
/// whose index stream still needs decoding.
enum ParsedVar<'a> {
    Plain(VarView<'a>),
    Delta {
        /// the bitpacked XOR stream, borrowed from the frame
        stream: &'a [u8],
        /// length of the reconstructed packed payload
        raw_len: usize,
        n: usize,
        fmt: FloatFormat,
        pvt: Pvt,
    },
    Sparse {
        /// the gap-coded bitpacked index stream, borrowed from the frame
        index_stream: &'a [u8],
        /// the bit-packed codes of the gathered values
        payload: &'a [u8],
        /// selected coordinate count
        k: usize,
        /// dense element count
        n: usize,
        fmt: FloatFormat,
        pvt: Pvt,
    },
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{
        decode_all_based, encode_frame_v2, encode_frame_v3, flip_bit,
        perturbed_model, random_bytes, sample_wire_model as sample_model,
        truncate_at, Gen,
    };

    #[test]
    fn roundtrip_bit_exact() {
        let mut g = Gen::new(1);
        let model = sample_model(&mut g);
        let wire = encode(&model);
        let back = decode(&wire).unwrap();
        assert_eq!(back.num_vars(), model.num_vars());
        for (a, b) in model.vars.iter().zip(&back.vars) {
            assert_eq!(a.is_packed(), b.is_packed());
            assert_eq!(a.pvt(), b.pvt());
            let (ta, tb) = (a.decode_tilde(), b.decode_tilde());
            assert_eq!(ta.len(), tb.len());
            for (x, y) in ta.iter().zip(&tb) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn wire_size_accounts_for_compression() {
        let mut g = Gen::new(2);
        let fmt: FloatFormat = "S1E4M14".parse().unwrap(); // 19 bits
        let n = 100_000;
        let v = g.vec_normal(n, 0.05);
        let packed = CompressedModel::new(vec![StoredVar::compress(&v, fmt, true)]);
        let raw = CompressedModel::new(vec![StoredVar::raw(v)]);
        let ratio = encode(&packed).len() as f64 / encode(&raw).len() as f64;
        assert!((ratio - 19.0 / 32.0).abs() < 0.01, "ratio {ratio}");
    }

    #[test]
    fn rejects_corruption() {
        let mut g = Gen::new(3);
        let wire = encode(&sample_model(&mut g));
        // bad magic
        let mut bad = wire.clone();
        bad[0] = b'X';
        assert!(decode(&bad).is_err());
        // bad version
        let mut bad = wire.clone();
        bad[4] = 9;
        assert!(decode(&bad).is_err());
        // truncation at every prefix must error, never panic
        for cut in [5, 11, 16, wire.len() / 2, wire.len() - 1] {
            assert!(decode(&wire[..cut]).is_err(), "cut {cut}");
        }
        // trailing garbage
        let mut bad = wire.clone();
        bad.push(0);
        assert!(decode(&bad).is_err());
    }

    #[test]
    fn rejects_nonfinite_pvt() {
        let mut g = Gen::new(4);
        let model = sample_model(&mut g);
        let mut wire = encode(&model);
        // var 0 header: 4 magic + 2 ver + 4 nvars + 1 tag + 4 n = 15; then
        // e,m at 15,16; s at 17..21 — overwrite s with NaN
        wire[17..21].copy_from_slice(&f32::NAN.to_le_bytes());
        assert!(decode(&wire).is_err());
    }

    #[test]
    fn empty_model_roundtrip() {
        let m = CompressedModel::default();
        let back = decode(&encode(&m)).unwrap();
        assert_eq!(back.num_vars(), 0);
    }

    #[test]
    fn streaming_writers_match_storedvar_path() {
        // packed_values (pre-quantized) and compress_values (fused) must
        // emit byte-identical frames to the StoredVar::compress + var path
        let mut g = Gen::new(6);
        let fmt: FloatFormat = "S1E3M7".parse().unwrap();
        let v = g.vec_normal(1000, 0.05);
        let sv = StoredVar::compress(&v, fmt, true);

        let mut a = WireWriter::with_capacity(0);
        a.var(&sv);
        let a = a.finish();

        let mut b = WireWriter::with_capacity(0);
        b.compress_values(&v, fmt, true);
        let b = b.finish();
        assert_eq!(a, b, "compress_values frame differs");

        let tilde = sv.decode_tilde();
        let mut c = WireWriter::with_capacity(0);
        c.packed_values(&tilde, fmt, sv.pvt()).unwrap();
        let c = c.finish();
        assert_eq!(a, c, "packed_values frame differs");
    }

    #[test]
    fn encoder_reuses_buffer() {
        let mut g = Gen::new(7);
        let model = sample_model(&mut g);
        let reference = encode(&model);
        let mut enc = Encoder::new();
        assert_eq!(enc.encode(&model), reference.as_slice());
        let ptr = enc.encode(&model).as_ptr();
        assert_eq!(enc.encode(&model).as_ptr(), ptr, "Encoder must recycle");
    }

    #[test]
    fn decode_decompressed_matches_two_step() {
        let mut g = Gen::new(8);
        let wire = encode(&sample_model(&mut g));
        let two_step = decode(&wire).unwrap().decompress_all();
        let fused = decode_decompressed(&wire).unwrap();
        assert_eq!(two_step.len(), fused.len());
        for (a, b) in two_step.iter().zip(&fused) {
            assert_eq!(
                a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                b.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
            );
        }
    }

    #[test]
    fn for_each_var_reports_views_in_order() {
        let mut g = Gen::new(9);
        let model = sample_model(&mut g);
        let wire = encode(&model);
        let mut seen = Vec::new();
        let count = for_each_var(&wire, |i, view| {
            seen.push((i, view.len(), view.memory_bytes()));
            Ok(())
        })
        .unwrap();
        assert_eq!(count, model.num_vars());
        for (i, (vi, n, mem)) in seen.iter().enumerate() {
            assert_eq!(i, *vi);
            assert_eq!(*n, model.vars[i].len());
            assert_eq!(*mem, model.vars[i].memory_bytes());
        }
    }

    #[test]
    fn fuzz_decoder_never_panics() {
        // random byte soup must be rejected gracefully
        let mut g = Gen::new(5);
        for _ in 0..500 {
            let n = g.usize_below(200);
            let bytes = random_bytes(&mut g, n);
            let _ = decode(&bytes); // must not panic
        }
        // and mutated-valid payloads too, for every wire version
        let base = sample_model(&mut g);
        let model = perturbed_model(&mut g, &base, 4);
        let dbase = crate::omc::delta::DeltaBase::from_model(5, &base);
        let (v3, _) = encode_frame_v3(&model, 0xF00E, &dbase);
        for wire in [encode(&model), encode_frame_v2(&model, 0xF00D), v3] {
            for _ in 0..300 {
                let mut bad = wire.clone();
                flip_bit(&mut bad, g.usize_below(bad.len() * 8));
                let _ = decode(&bad); // must not panic (may succeed or fail)
                let _ = verify_frame(&bad);
                let _ = decode_all_based(&bad, Some(&dbase));
            }
        }
    }

    #[test]
    fn v2_roundtrip_and_overhead() {
        let mut g = Gen::new(10);
        let model = sample_model(&mut g);
        let v1 = encode(&model);
        let v2 = encode_frame_v2(&model, 0xDEAD_BEEF_CAFE_F00D);
        // overhead is exactly 12 header bytes (nonce + hcrc) + 4 per var
        assert_eq!(v2.len(), v1.len() + 12 + 4 * model.num_vars());
        // decodes to bit-identical values through the version-agnostic path
        let a = decode_decompressed(&v1).unwrap();
        let b = decode_decompressed(&v2).unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(
                x.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                y.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
            );
        }
        let info = verify_frame(&v2).unwrap();
        assert_eq!(
            info,
            FrameInfo {
                version: VERSION_INTEGRITY,
                nvars: model.num_vars(),
                nonce: Some(0xDEAD_BEEF_CAFE_F00D),
                base_version: None,
            }
        );
    }

    #[test]
    fn v3_roundtrip_matches_verbatim_and_saves_bytes() {
        let mut g = Gen::new(20);
        let base = sample_model(&mut g);
        // the converging regime: a handful of changed payload bytes
        let cur = perturbed_model(&mut g, &base, 3);
        let dbase = DeltaBase::from_model(41, &base);
        let (v3, saved) = encode_frame_v3(&cur, 0xBEEF, &dbase);
        let v2 = encode_frame_v2(&cur, 0xBEEF);
        // delta-vs-verbatim equality on the same committed bytes
        let a = decode_all_based(&v3, Some(&dbase)).unwrap();
        let b = decode_all_based(&v2, None).unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(
                x.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                y.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
            );
        }
        // a near-identical model must compress and the accounting must
        // agree with the actual frame sizes (tag 2 carries raw_len: the
        // saving is measured net of that extra u32)
        assert!(saved > 0, "no delta savings on near-identical model");
        assert_eq!(v2.len(), v3.len() + saved - 8, "saved accounting"); // v3 header is 8 bytes larger
        let info = verify_frame(&v3).unwrap();
        assert_eq!(
            info,
            FrameInfo {
                version: VERSION_DELTA,
                nvars: cur.num_vars(),
                nonce: Some(0xBEEF),
                base_version: Some(41),
            }
        );
        assert_eq!(frame_nonce(&v3).unwrap(), Some(0xBEEF));
        assert_eq!(frame_base_version(&v3).unwrap(), Some(41));
        assert_eq!(frame_base_version(&v2).unwrap(), None);
    }

    #[test]
    fn v3_identical_models_collapse_to_headers() {
        let mut g = Gen::new(21);
        let base = sample_model(&mut g);
        let dbase = DeltaBase::from_model(7, &base);
        let (v3, saved) = encode_frame_v3(&base, 1, &dbase);
        let v2 = encode_frame_v2(&base, 1);
        assert!(saved > 0);
        assert!(
            v3.len() < v2.len() / 2,
            "all-zero deltas must collapse: v3 {} vs v2 {}",
            v3.len(),
            v2.len()
        );
        let back = decode_all_based(&v3, Some(&dbase)).unwrap();
        let want = decode_all_based(&v2, None).unwrap();
        assert_eq!(back.len(), want.len());
        for (x, y) in back.iter().zip(&want) {
            assert_eq!(
                x.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                y.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
            );
        }
    }

    #[test]
    fn v3_requires_the_matching_base() {
        let mut g = Gen::new(22);
        let base = sample_model(&mut g);
        let cur = perturbed_model(&mut g, &base, 2);
        let dbase = DeltaBase::from_model(10, &base);
        let (v3, _) = encode_frame_v3(&cur, 3, &dbase);
        // no base at all: typed refusal on the first tag-2 record
        assert!(matches!(
            decode_all_based(&v3, None).unwrap_err(),
            DecodeError::MissingDeltaBase { var: 0 }
        ));
        // the plain for_each_var path is the same refusal
        assert!(matches!(
            for_each_var(&v3, |_, _| Ok(())).unwrap_err(),
            DecodeError::MissingDeltaBase { var: 0 }
        ));
        // a base of the wrong version: rejected before any decode
        let stale = DeltaBase::from_model(9, &base);
        assert!(matches!(
            decode_all_based(&v3, Some(&stale)).unwrap_err(),
            DecodeError::BaseVersionMismatch { frame: 10, have: 9 }
        ));
        // a base with the right version but wrong payload shape
        let fmt: FloatFormat = "S1E3M7".parse().unwrap();
        let other = CompressedModel::new(vec![StoredVar::compress(
            &g.vec_normal(123, 0.05),
            fmt,
            true,
        )]);
        let wrong = DeltaBase::from_model(10, &other);
        assert!(matches!(
            decode_all_based(&v3, Some(&wrong)).unwrap_err(),
            DecodeError::DeltaLengthMismatch { var: 0 }
        ));
        // verification needs no base at all (accept/reject is base-free)
        assert!(verify_frame(&v3).is_ok());
    }

    #[test]
    fn delta_tag_is_rejected_outside_v3_frames() {
        // a hand-built v1 frame declaring tag 2 must be UnknownTag
        let mut bad = Vec::new();
        bad.extend_from_slice(MAGIC);
        bad.extend_from_slice(&VERSION.to_le_bytes());
        bad.extend_from_slice(&1u32.to_le_bytes());
        bad.push(2u8); // delta tag in a v1 frame
        bad.extend_from_slice(&4u32.to_le_bytes());
        assert!(matches!(
            for_each_var(&bad, |_, _| Ok(())).unwrap_err(),
            DecodeError::UnknownTag { var: 0, tag: 2 }
        ));
    }

    #[test]
    fn v1_writer_bytes_unchanged_by_integrity_feature() {
        // the integrity-off path must stay byte-identical to the historic
        // v1 layout: goldens and compression-ratio math depend on it
        let mut g = Gen::new(11);
        let model = sample_model(&mut g);
        let wire = encode(&model);
        assert_eq!(&wire[..4], MAGIC);
        assert_eq!(u16::from_le_bytes([wire[4], wire[5]]), VERSION);
        let info = verify_frame(&wire).unwrap();
        assert_eq!(info.version, VERSION);
        assert_eq!(info.nonce, None);
        assert_eq!(frame_nonce(&wire).unwrap(), None);
    }

    #[test]
    fn every_truncation_yields_typed_error() {
        // satellite: no panic and a typed error for EVERY single-byte
        // truncation of a valid frame, all three wire versions
        let mut g = Gen::new(12);
        let fmt: FloatFormat = "S1E3M7".parse().unwrap();
        let base = CompressedModel::new(vec![
            StoredVar::compress(&g.vec_normal(100, 0.05), fmt, true),
            StoredVar::raw(g.vec_normal(17, 1.0)),
        ]);
        let model = perturbed_model(&mut g, &base, 2);
        let dbase = DeltaBase::from_model(4, &base);
        let (v3, _) = encode_frame_v3(&model, 8, &dbase);
        for wire in [encode(&model), encode_frame_v2(&model, 7), v3] {
            for cut in 0..wire.len() {
                let prefix = truncate_at(&wire, cut);
                let err = for_each_var_based(prefix, Some(&dbase), |_, _| {
                    Ok(())
                })
                .expect_err(&format!("cut {cut} must fail"));
                assert!(err.is_frame_error(), "cut {cut}: {err}");
                assert!(verify_frame(prefix).is_err(), "cut {cut}");
            }
        }
    }

    #[test]
    fn every_bit_flip_of_checksummed_frame_detected() {
        // satellite: the integrity layouts catch every single-bit flip —
        // header bits via magic/version/header-CRC, everything else
        // (including delta class headers and bitpacked streams) via the
        // per-variable CRC32C
        let mut g = Gen::new(13);
        let fmt: FloatFormat = "S1E3M7".parse().unwrap();
        let base = CompressedModel::new(vec![
            StoredVar::compress(&g.vec_normal(100, 0.05), fmt, true),
            StoredVar::raw(g.vec_normal(17, 1.0)),
        ]);
        let model = perturbed_model(&mut g, &base, 2);
        let dbase = DeltaBase::from_model(4, &base);
        let (v3, _) = encode_frame_v3(&model, 9, &dbase);
        for wire in [encode_frame_v2(&model, 0xA5A5_5A5A), v3] {
            for bit in 0..wire.len() * 8 {
                let mut bad = wire.clone();
                flip_bit(&mut bad, bit);
                let err = verify_frame(&bad)
                    .expect_err(&format!("flip bit {bit} must be caught"));
                assert!(err.is_frame_error(), "flip bit {bit}: {err}");
                assert!(
                    for_each_var_based(&bad, Some(&dbase), |_, _| Ok(()))
                        .is_err(),
                    "flip bit {bit} slipped past for_each_var_based"
                );
            }
        }
    }

    #[test]
    fn header_flips_of_v1_frame_never_panic() {
        // v1 has no checksum, so a flip may decode; it must never panic
        // and header flips must produce typed frame errors
        let mut g = Gen::new(14);
        let wire = encode(&sample_model(&mut g));
        for byte in 0..10 {
            for bit in 0..8 {
                let mut bad = wire.clone();
                bad[byte] ^= 1 << bit;
                if let Err(e) = for_each_var(&bad, |_, _| Ok(())) {
                    assert!(e.is_frame_error(), "flip {byte}.{bit}: {e}");
                }
            }
        }
    }

    #[test]
    fn callback_errors_are_not_frame_errors() {
        let mut g = Gen::new(15);
        let wire = encode(&sample_model(&mut g));
        let err = for_each_var(&wire, |_, _| anyhow::bail!("app-level"))
            .expect_err("callback error must surface");
        assert!(!err.is_frame_error());
        assert!(err.to_string().contains("app-level"));
    }

    #[test]
    fn nonce_ledger_rejects_duplicates_and_evicts() {
        let mut led = NonceLedger::new(2);
        assert!(led.observe(None).is_ok()); // v1 frames always pass
        assert!(led.observe(Some(1)).is_ok());
        assert!(matches!(
            led.observe(Some(1)),
            Err(DecodeError::DuplicateNonce(1))
        ));
        assert!(led.observe(Some(2)).is_ok());
        assert_eq!(led.len(), 2);
        assert!(led.observe(Some(3)).is_ok()); // evicts nonce 1
        assert_eq!(led.len(), 2);
        assert!(led.observe(Some(1)).is_ok(), "evicted nonce re-admitted");
        assert!(!led.is_empty());
    }

    #[test]
    fn sparse_record_roundtrips_against_dense_reference() {
        use crate::omc::sparse::{gather_into, select_count, select_topk};
        let mut g = Gen::new(30);
        let fmt: FloatFormat = "S1E3M7".parse().unwrap();
        let n = 300;
        let e = g.vec_normal(n, 0.1);
        let k = select_count(n, 0.25);
        let mut idx = Vec::new();
        select_topk(&e, k, &mut idx);
        let mut gathered = Vec::new();
        gather_into(&e, &idx, &mut gathered);

        let mut w = WireWriter::with_integrity(0, 77);
        let pvt = w.sparse_values(&gathered, &idx, n, fmt, true);
        let saved = w.sparse_saved();
        let wire = w.finish();
        assert!(saved > 0, "a 25% selection must beat verbatim");
        assert!(pvt.s.is_finite() && pvt.b.is_finite());

        // dense reference: quantize the same gathered values through the
        // ordinary packed path, then scatter by hand
        let mut d = WireWriter::with_capacity(0);
        d.compress_values(&gathered, fmt, true);
        let vals = decode_decompressed(&d.finish()).unwrap();
        let mut want = vec![0f32; n];
        for (j, &i) in idx.iter().enumerate() {
            want[i as usize] = vals[0][j];
        }

        let mut got = Vec::new();
        let count = for_each_var(&wire, |_, view| {
            assert_eq!(view.len(), n);
            assert!(matches!(view, VarView::Sparse { .. }));
            view.decompress_into(&mut got);
            Ok(())
        })
        .unwrap();
        assert_eq!((count, got.len()), (1, n));
        for i in 0..n {
            assert_eq!(got[i].to_bits(), want[i].to_bits(), "coord {i}");
        }
        // verification and the size accounting line up with the frame
        let info = verify_frame(&wire).unwrap();
        assert_eq!(info.version, VERSION_INTEGRITY);
        let mut dense = WireWriter::with_integrity(0, 77);
        dense.compress_values(&e, fmt, true);
        let dense = dense.finish();
        assert_eq!(dense.len(), wire.len() + saved, "saved accounting");
    }

    #[test]
    fn sparse_tag_is_rejected_outside_checksummed_frames() {
        // a hand-built v1 frame declaring tag 3 must be UnknownTag: a
        // sparse record without CRC coverage is not a legal layout
        let mut bad = Vec::new();
        bad.extend_from_slice(MAGIC);
        bad.extend_from_slice(&VERSION.to_le_bytes());
        bad.extend_from_slice(&1u32.to_le_bytes());
        bad.push(3u8);
        bad.extend_from_slice(&4u32.to_le_bytes());
        assert!(matches!(
            for_each_var(&bad, |_, _| Ok(())).unwrap_err(),
            DecodeError::UnknownTag { var: 0, tag: 3 }
        ));
    }

    #[test]
    fn duplicate_frame_detected_via_nonce() {
        let mut g = Gen::new(16);
        let model = sample_model(&mut g);
        let wire = encode_frame_v2(&model, 42);
        let mut led = NonceLedger::new(64);
        let info = verify_frame(&wire).unwrap();
        assert!(led.observe(info.nonce).is_ok());
        // the exact same frame replayed is a duplicate
        let again = verify_frame(&wire).unwrap();
        assert!(matches!(
            led.observe(again.nonce),
            Err(DecodeError::DuplicateNonce(42))
        ));
    }
}
