//! Transport wire format + byte accounting.
//!
//! The downlink (server→client) and uplink (client→server) payloads are the
//! serialized [`CompressedModel`]: a small header, then per variable either
//! the bit-packed SxEyMz codes with the PVT scalars, or raw f32. These byte
//! counts are exactly what the paper's "Communication" column reports.
//!
//! Layout (all little-endian):
//! ```text
//! magic  "OMCW"            4 bytes
//! version u16              currently 1
//! nvars  u32
//! per variable:
//!   tag   u8               0 = raw f32, 1 = packed
//!   n     u32              element count
//!   raw:    n * f32
//!   packed: e u8, m u8, s f32, b f32, payload_len u32, payload bytes
//! ```

use anyhow::{bail, ensure, Context, Result};

use super::format::FloatFormat;
use super::store::{CompressedModel, StoredVar};
use super::transform::Pvt;

const MAGIC: &[u8; 4] = b"OMCW";
const VERSION: u16 = 1;

/// Streaming writer for the wire format — lets callers assemble a payload
/// from borrowed parts without materializing a `CompressedModel` (the
/// round loop reuses one compressed copy of each variable across all
/// clients and only the framing differs per client).
pub struct WireWriter {
    buf: Vec<u8>,
    nvars: u32,
}

impl WireWriter {
    pub fn with_capacity(cap: usize) -> Self {
        let mut buf = Vec::with_capacity(cap + 16);
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&VERSION.to_le_bytes());
        buf.extend_from_slice(&0u32.to_le_bytes()); // patched in finish()
        Self { buf, nvars: 0 }
    }

    pub fn raw(&mut self, v: &[f32]) {
        self.buf.push(0u8);
        self.buf.extend_from_slice(&(v.len() as u32).to_le_bytes());
        // bulk-copy the f32 payload (little-endian hosts: this is memcpy)
        for x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
        self.nvars += 1;
    }

    pub fn packed(&mut self, bytes: &[u8], n: usize, fmt: FloatFormat, pvt: Pvt) {
        self.buf.push(1u8);
        self.buf.extend_from_slice(&(n as u32).to_le_bytes());
        self.buf.push(fmt.exp_bits as u8);
        self.buf.push(fmt.mant_bits as u8);
        self.buf.extend_from_slice(&pvt.s.to_le_bytes());
        self.buf.extend_from_slice(&pvt.b.to_le_bytes());
        self.buf.extend_from_slice(&(bytes.len() as u32).to_le_bytes());
        self.buf.extend_from_slice(bytes);
        self.nvars += 1;
    }

    pub fn var(&mut self, v: &StoredVar) {
        match v {
            StoredVar::Raw(data) => self.raw(data),
            StoredVar::Packed { bytes, n, fmt, pvt } => {
                self.packed(bytes, *n, *fmt, *pvt)
            }
        }
    }

    pub fn finish(mut self) -> Vec<u8> {
        let nv = self.nvars.to_le_bytes();
        self.buf[6..10].copy_from_slice(&nv);
        self.buf
    }
}

/// Serialize a compressed model into wire bytes.
pub fn encode(model: &CompressedModel) -> Vec<u8> {
    let mut w = WireWriter::with_capacity(model.memory_bytes() + 8 * model.vars.len());
    for var in &model.vars {
        w.var(var);
    }
    w.finish()
}

/// Decode wire bytes back into a compressed model.
pub fn decode(bytes: &[u8]) -> Result<CompressedModel> {
    let mut r = Reader { b: bytes, i: 0 };
    let magic = r.take(4)?;
    ensure!(magic == MAGIC, "bad magic {:?}", &magic);
    let version = r.u16()?;
    ensure!(version == VERSION, "unsupported wire version {version}");
    let nvars = r.u32()? as usize;
    // sanity bound: each variable needs >= 6 bytes of header
    ensure!(
        nvars <= bytes.len() / 5 + 1,
        "implausible variable count {nvars}"
    );
    let mut vars = Vec::with_capacity(nvars);
    for vi in 0..nvars {
        let tag = r.u8()?;
        let n = r.u32()? as usize;
        match tag {
            0 => {
                let raw = r.take(n * 4).with_context(|| format!("raw var {vi}"))?;
                let mut v = Vec::with_capacity(n);
                for c in raw.chunks_exact(4) {
                    v.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
                }
                vars.push(StoredVar::Raw(v));
            }
            1 => {
                let e = r.u8()? as u32;
                let m = r.u8()? as u32;
                let fmt = FloatFormat::new(e, m)
                    .with_context(|| format!("packed var {vi}"))?;
                let s = f32::from_le_bytes(r.arr4()?);
                let b = f32::from_le_bytes(r.arr4()?);
                ensure!(
                    s.is_finite() && b.is_finite(),
                    "non-finite PVT scalars in var {vi}"
                );
                let plen = r.u32()? as usize;
                ensure!(
                    plen == fmt.packed_bytes(n),
                    "payload length {plen} inconsistent with n={n} at {fmt}"
                );
                let payload = r.take(plen)?.to_vec();
                vars.push(StoredVar::Packed {
                    bytes: payload,
                    n,
                    fmt,
                    pvt: Pvt { s, b },
                });
            }
            t => bail!("unknown variable tag {t}"),
        }
    }
    ensure!(r.i == bytes.len(), "trailing bytes after payload");
    Ok(CompressedModel::new(vars))
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(self.i + n <= self.b.len(), "truncated payload");
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        let s = self.take(2)?;
        Ok(u16::from_le_bytes([s[0], s[1]]))
    }

    fn u32(&mut self) -> Result<u32> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn arr4(&mut self) -> Result<[u8; 4]> {
        let s = self.take(4)?;
        Ok([s[0], s[1], s[2], s[3]])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::Gen;

    fn sample_model(g: &mut Gen) -> CompressedModel {
        let fmt: FloatFormat = "S1E3M7".parse().unwrap();
        let mut vars = Vec::new();
        vars.push(StoredVar::compress(&g.vec_normal(1000, 0.05), fmt, true));
        vars.push(StoredVar::raw(g.vec_normal(64, 1.0)));
        vars.push(StoredVar::compress(&g.vec_normal(333, 0.2), fmt, false));
        vars.push(StoredVar::raw(vec![]));
        CompressedModel::new(vars)
    }

    #[test]
    fn roundtrip_bit_exact() {
        let mut g = Gen::new(1);
        let model = sample_model(&mut g);
        let wire = encode(&model);
        let back = decode(&wire).unwrap();
        assert_eq!(back.num_vars(), model.num_vars());
        for (a, b) in model.vars.iter().zip(&back.vars) {
            assert_eq!(a.is_packed(), b.is_packed());
            assert_eq!(a.pvt(), b.pvt());
            let (ta, tb) = (a.decode_tilde(), b.decode_tilde());
            assert_eq!(ta.len(), tb.len());
            for (x, y) in ta.iter().zip(&tb) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn wire_size_accounts_for_compression() {
        let mut g = Gen::new(2);
        let fmt: FloatFormat = "S1E4M14".parse().unwrap(); // 19 bits
        let n = 100_000;
        let v = g.vec_normal(n, 0.05);
        let packed = CompressedModel::new(vec![StoredVar::compress(&v, fmt, true)]);
        let raw = CompressedModel::new(vec![StoredVar::raw(v)]);
        let ratio = encode(&packed).len() as f64 / encode(&raw).len() as f64;
        assert!((ratio - 19.0 / 32.0).abs() < 0.01, "ratio {ratio}");
    }

    #[test]
    fn rejects_corruption() {
        let mut g = Gen::new(3);
        let wire = encode(&sample_model(&mut g));
        // bad magic
        let mut bad = wire.clone();
        bad[0] = b'X';
        assert!(decode(&bad).is_err());
        // bad version
        let mut bad = wire.clone();
        bad[4] = 9;
        assert!(decode(&bad).is_err());
        // truncation at every prefix must error, never panic
        for cut in [5, 11, 16, wire.len() / 2, wire.len() - 1] {
            assert!(decode(&wire[..cut]).is_err(), "cut {cut}");
        }
        // trailing garbage
        let mut bad = wire.clone();
        bad.push(0);
        assert!(decode(&bad).is_err());
    }

    #[test]
    fn rejects_nonfinite_pvt() {
        let mut g = Gen::new(4);
        let model = sample_model(&mut g);
        let mut wire = encode(&model);
        // var 0 header: 4 magic + 2 ver + 4 nvars + 1 tag + 4 n = 15; then
        // e,m at 15,16; s at 17..21 — overwrite s with NaN
        wire[17..21].copy_from_slice(&f32::NAN.to_le_bytes());
        assert!(decode(&wire).is_err());
    }

    #[test]
    fn empty_model_roundtrip() {
        let m = CompressedModel::default();
        let back = decode(&encode(&m)).unwrap();
        assert_eq!(back.num_vars(), 0);
    }

    #[test]
    fn fuzz_decoder_never_panics() {
        // random byte soup must be rejected gracefully
        let mut g = Gen::new(5);
        for _ in 0..500 {
            let n = g.usize_below(200);
            let bytes: Vec<u8> = (0..n).map(|_| (g.u64() & 0xFF) as u8).collect();
            let _ = decode(&bytes); // must not panic
        }
        // and mutated-valid payloads too
        let wire = encode(&sample_model(&mut g));
        for _ in 0..300 {
            let mut bad = wire.clone();
            let idx = g.usize_below(bad.len());
            bad[idx] ^= 1 << g.usize_below(8);
            let _ = decode(&bad); // must not panic (may succeed or fail)
        }
    }
}
