//! Per-variable transformation (paper Sec. 2.3).
//!
//! After quantizing a variable `V` to `Ṽ`, fit `V̄ = s·Ṽ + b` minimizing
//! `‖V̄ − V‖²`. Closed form (the paper's Eq. with its typo corrected — see
//! DESIGN.md §1):
//!
//! ```text
//! s = (n ΣVṼ − ΣV ΣṼ) / (n ΣṼ² − (ΣṼ)²)
//! b = (ΣV − s ΣṼ) / n
//! ```
//!
//! Accumulation in f64 (Sec. 2.3: "s and b are computed in the 64-bit
//! floating-point precision"); the stored scalars are f32. Degenerate case
//! (`Ṽ` constant ⇒ denominator 0) falls back to `s = 1`.
//!
//! The f64 sums accumulate through [`crate::util::simd::FitSums`]: a
//! **fixed virtual lane width** of 4 f64 accumulators (element `i` lands
//! in lane `i % 4`), folded in a fixed pairwise order at
//! [`FitAcc::finish`]. Every ISA path performs the identical addition
//! sequence, so the fitted scalars — and everything downstream of them,
//! including `sweep_summary.json` — are byte-identical whether the
//! scalar, SSE2, or AVX2 kernels ran (see `docs/PERFORMANCE.md`).

use crate::util::simd;

/// The fitted per-variable transform. `(1.0, 0.0)` is the identity used for
/// unquantized variables.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Pvt {
    /// scale
    pub s: f32,
    /// bias
    pub b: f32,
}

impl Pvt {
    /// The identity transform `(s, b) = (1, 0)` used for raw variables.
    pub const IDENTITY: Pvt = Pvt { s: 1.0, b: 0.0 };

    /// Whether this is exactly the identity transform.
    pub fn is_identity(&self) -> bool {
        self.s == 1.0 && self.b == 0.0
    }
}

/// Streaming accumulator for the least-squares fit — the single source of
/// truth for the PVT math, shared by [`fit`] and the fused
/// quantize→fit→pack pipeline (`pack::quantize_transform_pack`). Feeding
/// the same `(v, vt)` pairs in the same order produces bit-identical f64
/// sums, which is what keeps the fused path's scalars exactly equal to the
/// separate-pass reference. Internally a [`simd::FitSums`]: fixed
/// virtual-lane accumulation, identical on every ISA path.
#[derive(Clone, Copy, Debug, Default)]
pub struct FitAcc {
    sums: simd::FitSums,
}

impl FitAcc {
    /// Empty accumulator (zero pairs seen).
    pub fn new() -> Self {
        Self::default()
    }

    /// Accumulate one `(original, quantized)` pair.
    #[inline]
    pub fn push(&mut self, v: f32, t: f32) {
        self.sums.push(v, t);
    }

    /// Accumulate a batch of pairs through the dispatched SIMD kernel
    /// (bit-identical to element-by-element [`FitAcc::push`]).
    pub fn update(&mut self, v: &[f32], vt: &[f32]) {
        assert_eq!(v.len(), vt.len());
        self.sums.update(v, vt);
    }

    /// [`FitAcc::update`] through an explicit kernel table — how the
    /// cross-ISA determinism tests compare every available level against
    /// the scalar reference from one process.
    pub fn update_with(&mut self, kernels: &simd::Kernels, v: &[f32], vt: &[f32]) {
        assert_eq!(v.len(), vt.len());
        (kernels.fit_update)(&mut self.sums, v, vt);
    }

    /// Solve for `(s, b)`; degenerate cases fall back to `s = 1`.
    pub fn finish(&self) -> Pvt {
        let (n, sum_v, sum_t, sum_tt, sum_vt) = self.sums.totals();
        if n == 0 {
            return Pvt::IDENTITY;
        }
        let nf = n as f64;
        let den = nf * sum_tt - sum_t * sum_t;
        let num = nf * sum_vt - sum_v * sum_t;
        let s_raw = num / den;
        let s = if den == 0.0 || !s_raw.is_finite() {
            1.0
        } else {
            s_raw
        };
        let b = (sum_v - s * sum_t) / nf;
        Pvt {
            s: s as f32,
            b: b as f32,
        }
    }
}

/// Least-squares fit of `s·vt + b ≈ v` (both slices the same length).
pub fn fit(v: &[f32], vt: &[f32]) -> Pvt {
    let mut acc = FitAcc::new();
    acc.update(v, vt);
    acc.finish()
}

/// Apply the transform in f32 — exactly what the lowered graph computes on
/// decompression (`V̄ = s·Ṽ + b` with f32 scalars; runtime-dispatched
/// SIMD lanes, mul-then-add so every path rounds like the scalar code).
pub fn apply(pvt: Pvt, vt: &[f32], out: &mut [f32]) {
    assert_eq!(vt.len(), out.len());
    if pvt.is_identity() {
        out.copy_from_slice(vt);
        return;
    }
    (simd::kernels().axpb)(pvt.s, pvt.b, vt, out);
}

/// In-place variant of [`apply`].
pub fn apply_in_place(pvt: Pvt, xs: &mut [f32]) {
    if pvt.is_identity() {
        return;
    }
    (simd::kernels().axpb_in_place)(pvt.s, pvt.b, xs);
}

/// Mean squared error between two slices, in f64 (used by tests/benches and
/// the ablation analysis example).
pub fn mse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    let mut acc = 0f64;
    for i in 0..a.len() {
        let d = a[i] as f64 - b[i] as f64;
        acc += d * d;
    }
    acc / a.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::omc::format::FloatFormat;
    use crate::omc::quantize::quantize_vec;
    use crate::testkit::{check, Gen};

    #[test]
    fn exact_affine_recovery() {
        let mut g = Gen::new(1);
        let v = g.vec_normal(4096, 1.0);
        let vt: Vec<f32> = v.iter().map(|x| (x - 0.25) / 2.0).collect();
        let p = fit(&v, &vt);
        assert!((p.s - 2.0).abs() < 1e-4, "{p:?}");
        assert!((p.b - 0.25).abs() < 1e-4, "{p:?}");
    }

    #[test]
    fn degenerate_constant_vt() {
        let v = [1.0f32, 2.0, 3.0, 4.0];
        let vt = [2.0f32; 4];
        let p = fit(&v, &vt);
        assert_eq!(p.s, 1.0);
        assert!((p.b - 0.5).abs() < 1e-6); // mean(v) - 2 = 0.5
    }

    #[test]
    fn degenerate_empty_and_single() {
        assert_eq!(fit(&[], &[]), Pvt::IDENTITY);
        let p = fit(&[3.0], &[2.0]);
        assert_eq!(p.s, 1.0);
        assert!((p.b - 1.0).abs() < 1e-6);
    }

    #[test]
    fn pvt_never_hurts_property() {
        // least squares includes (1, 0): decompressed error <= raw error
        check("pvt_never_hurts", 50, |g| {
            let n = 2 + g.usize_below(5000);
            let scale = [1e-3f32, 0.05, 1.0][g.usize_below(3)];
            let v = g.vec_normal(n, scale);
            let fmt = FloatFormat::new(
                2 + g.usize_below(5) as u32,
                g.usize_below(15) as u32,
            )
            .unwrap();
            let vt = quantize_vec(&v, fmt);
            let p = fit(&v, &vt);
            let mut dec = vec![0.0; n];
            apply(p, &vt, &mut dec);
            let with = mse(&v, &dec);
            let without = mse(&v, &vt);
            if with <= without + 1e-12 {
                Ok(())
            } else {
                Err(format!("mse with {with} > without {without} ({fmt})"))
            }
        });
    }

    #[test]
    fn optimality_against_perturbations() {
        let mut g = Gen::new(9);
        let v = g.vec_normal(8192, 0.05);
        let vt = quantize_vec(&v, FloatFormat::new(2, 3).unwrap());
        let p = fit(&v, &vt);
        let mut dec = vec![0.0; v.len()];
        apply(p, &vt, &mut dec);
        let best = mse(&v, &dec);
        for (ds, db) in [(1e-3, 0.0), (-1e-3, 0.0), (0.0, 1e-4), (0.0, -1e-4)] {
            let q = Pvt {
                s: p.s + ds,
                b: p.b + db,
            };
            apply(q, &vt, &mut dec);
            assert!(mse(&v, &dec) >= best - 1e-15);
        }
    }

    #[test]
    fn apply_identity_is_copy() {
        let vt = [1.0f32, 2.0, 3.0];
        let mut out = [0.0f32; 3];
        apply(Pvt::IDENTITY, &vt, &mut out);
        assert_eq!(out, vt);
    }

    #[test]
    fn apply_matches_in_place() {
        let mut g = Gen::new(4);
        let vt = g.vec_normal(100, 1.0);
        let p = Pvt { s: 1.5, b: -0.25 };
        let mut a = vec![0.0; 100];
        apply(p, &vt, &mut a);
        let mut b = vt.clone();
        apply_in_place(p, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn streaming_fit_matches_batch_bitexact() {
        // FitAcc fed block-by-block (as the fused pipeline does) must equal
        // a single fit() call bit-for-bit — f64 sums in identical order
        let mut g = Gen::new(27);
        let v = g.vec_normal(4096 + 133, 0.05);
        let vt = quantize_vec(&v, FloatFormat::new(3, 7).unwrap());
        let whole = fit(&v, &vt);
        let mut acc = FitAcc::new();
        for (cv, ct) in v.chunks(256).zip(vt.chunks(256)) {
            acc.update(cv, ct);
        }
        let streamed = acc.finish();
        assert_eq!(whole.s.to_bits(), streamed.s.to_bits());
        assert_eq!(whole.b.to_bits(), streamed.b.to_bits());
    }

    #[test]
    fn f64_accumulation_survives_large_offset() {
        // badly-cancelling sums: values ~N(100, 1e-3) — f32 accumulation
        // would lose the signal entirely
        let mut g = Gen::new(10);
        let v: Vec<f32> = (0..100_000)
            .map(|_| 100.0 + g.f32_normalish(1e-3))
            .collect();
        let vt = quantize_vec(&v, FloatFormat::FP16);
        let p = fit(&v, &vt);
        assert!(p.s.is_finite() && p.b.is_finite());
        let mut dec = vec![0.0; v.len()];
        apply(p, &vt, &mut dec);
        assert!(mse(&v, &dec) <= mse(&v, &vt) + 1e-12);
    }
}
