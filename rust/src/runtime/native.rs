//! Pure-Rust executable backend: a tiny MLP trained with the exact OMC
//! step semantics, requiring no artifacts and no XLA toolchain.
//!
//! The PJRT engine can only execute where the `xla` bindings and the AOT
//! artifacts exist, which leaves plain `cargo test`, CI, and the sweep
//! smoke tier with nothing that *runs*. This module closes that gap: a
//! deterministic one-hidden-layer classifier over the synthetic ASR task,
//! implementing the same model surface as the lowered graphs —
//! `run_init` / `run_train_fp32` / `run_train_omc` / `run_eval` — with the
//! OMC step reusing the crate's own quantizer ([`crate::omc::quantize`])
//! and PVT fit ([`crate::omc::transform`]), so the compression dynamics
//! the sweep measures are the real ones.
//!
//! Model directories select this backend with the `native:` scheme
//! (`native:tiny`, `native:small`); [`manifest_for`] synthesizes the
//! manifest in memory, so no files are read.
//!
//! # Determinism and thread safety
//!
//! Every entry point is a pure function of its inputs (plus the seed in
//! `run_init`): no time, no global state. The matrix products run on the
//! blocked GEMM kernels in [`super::gemm`], whose per-output-element
//! accumulation order is fixed (bias, then `i` ascending — bit-identical
//! to a naive loop) regardless of cache blocking, worker count, or ISA;
//! the one true reduction in the backward pass (`dh = W₂·dz`) uses the
//! fixed-virtual-lane [`super::gemm::dot_lanes`]. Two runs with the same
//! inputs therefore produce bit-identical outputs on any host, which is
//! what makes the sweep goldens byte-stable. The struct is plain data
//! (`Send + Sync`), so the round engine's sharded dispatch — previously
//! only reachable from mock-job tests — executes real training on it.

use anyhow::Result;

use crate::model::manifest::{Manifest, ModelConfig, VarKind, VarSpec};
use crate::omc::format::FloatFormat;
use crate::omc::quantize::quantize_slice;
use crate::omc::transform;
use crate::util::rng::{hash_seed, Xoshiro256pp};
use crate::util::threadpool;

use super::gemm::{self, Act};

use super::{EvalOut, Fp32StepOut, OmcStepOut};

/// `native:<preset>` model-dir scheme → preset name.
pub fn model_name(dir: &std::path::Path) -> Option<&str> {
    dir.to_str()?.strip_prefix("native:")
}

/// Synthesize the manifest for a native preset (`tiny` or `small`).
pub fn manifest_for(name: &str) -> Result<Manifest> {
    let (f, h, v, batch, seq_len) = match name {
        "tiny" => (16usize, 32usize, 32usize, 4usize, 16usize),
        "small" => (32, 64, 48, 4, 24),
        other => anyhow::bail!(
            "unknown native model {other:?} (use native:tiny or native:small)"
        ),
    };
    let variables = vec![
        VarSpec {
            name: "enc_w".into(),
            shape: vec![f, h],
            kind: VarKind::Weight,
            size: f * h,
        },
        VarSpec {
            name: "enc_b".into(),
            shape: vec![h],
            kind: VarKind::Bias,
            size: h,
        },
        VarSpec {
            name: "dec_w".into(),
            shape: vec![h, v],
            kind: VarKind::Weight,
            size: h * v,
        },
        VarSpec {
            name: "dec_b".into(),
            shape: vec![v],
            kind: VarKind::Bias,
            size: v,
        },
    ];
    let total_params = variables.iter().map(|s| s.size).sum();
    Ok(Manifest {
        config: ModelConfig {
            name: format!("native-{name}"),
            feature_dim: f,
            vocab: v,
            d_model: h,
            num_blocks: 1,
            streaming: false,
            batch,
            seq_len,
        },
        variables,
        total_params,
        artifacts: std::collections::BTreeMap::new(),
    })
}

/// The native model: `relu(x·W1 + b1)·W2 + b2` framewise, softmax
/// cross-entropy loss, SGD. Parameter order matches the manifest:
/// `[enc_w, enc_b, dec_w, dec_b]` (weights row-major `[in][out]`).
#[derive(Clone, Debug)]
pub struct NativeModel {
    f: usize,
    h: usize,
    v: usize,
    batch: usize,
    seq_len: usize,
}

impl NativeModel {
    /// Bind to a synthesized manifest (validates the variable table).
    pub fn from_manifest(m: &Manifest) -> Result<Self> {
        let c = &m.config;
        let nm = Self {
            f: c.feature_dim,
            h: c.d_model,
            v: c.vocab,
            batch: c.batch,
            seq_len: c.seq_len,
        };
        let expect = [nm.f * nm.h, nm.h, nm.h * nm.v, nm.v];
        anyhow::ensure!(
            m.variables.len() == expect.len()
                && m.variables.iter().zip(expect).all(|(s, e)| s.size == e),
            "manifest variable table does not match the native MLP layout"
        );
        Ok(nm)
    }

    fn check_params(&self, params: &[Vec<f32>]) -> Result<()> {
        let expect = [self.f * self.h, self.h, self.h * self.v, self.v];
        anyhow::ensure!(
            params.len() == expect.len(),
            "expected {} variables, got {}",
            expect.len(),
            params.len()
        );
        for (i, (p, e)) in params.iter().zip(expect).enumerate() {
            anyhow::ensure!(
                p.len() == e,
                "variable {i} has {} elements, expected {e}",
                p.len()
            );
        }
        Ok(())
    }

    fn check_batch(&self, x: &[f32], y: &[i32]) -> Result<()> {
        let frames = self.batch * self.seq_len;
        anyhow::ensure!(
            x.len() == frames * self.f,
            "batch x has {} elements, expected {}",
            x.len(),
            frames * self.f
        );
        anyhow::ensure!(
            y.len() == frames,
            "batch y has {} elements, expected {frames}",
            y.len()
        );
        Ok(())
    }

    /// Deterministic initial parameters (keyed by `(seed, var index)`).
    pub fn run_init(&self, seed: i32) -> Result<Vec<Vec<f32>>> {
        let sizes = [self.f * self.h, self.h, self.h * self.v, self.v];
        let scales = [
            1.0 / (self.f as f32).sqrt(),
            0.0,
            1.0 / (self.h as f32).sqrt(),
            0.0,
        ];
        Ok(sizes
            .iter()
            .zip(scales)
            .enumerate()
            .map(|(i, (&n, scale))| {
                let mut v = vec![0.0f32; n];
                if scale > 0.0 {
                    let mut rng = Xoshiro256pp::new(hash_seed(&[
                        seed as i64 as u64,
                        0x1A17,
                        i as u64,
                    ]));
                    rng.fill_normal(&mut v, scale);
                }
                v
            })
            .collect())
    }

    /// Forward + backward + SGD over one batch; returns updated parameters
    /// and the mean framewise cross-entropy loss. Forward runs on the
    /// blocked GEMM kernels (whole batch at once, fused bias+relu);
    /// backward keeps the axpy loop shapes with the fixed-lane dot for
    /// `dh` — bit-deterministic for fixed inputs on any host.
    fn sgd_step(
        &self,
        params: &[Vec<f32>],
        x: &[f32],
        y: &[i32],
        lr: f32,
    ) -> Result<(Vec<Vec<f32>>, f32)> {
        self.check_params(params)?;
        self.check_batch(x, y)?;
        let (f, h, v) = (self.f, self.h, self.v);
        let (w1, b1, w2, b2) = (&params[0], &params[1], &params[2], &params[3]);
        let frames = self.batch * self.seq_len;
        for &yt in y {
            anyhow::ensure!(
                (yt as usize) < v,
                "label {yt} out of range (vocab {v})"
            );
        }
        let workers = threadpool::default_workers();

        // forward for the whole batch: H = relu(X·W1 + b1), Z = H·W2 + b2
        let mut hid = vec![0.0f32; frames * h];
        gemm::gemm_bias_act_auto(x, w1, b1, frames, f, h, Act::Relu, workers, &mut hid);
        let mut z = vec![0.0f32; frames * v];
        gemm::gemm_bias_act_auto(&hid, w2, b2, frames, h, v, Act::Linear, workers, &mut z);

        let mut gw1 = vec![0.0f32; f * h];
        let mut gb1 = vec![0.0f32; h];
        let mut gw2 = vec![0.0f32; h * v];
        let mut gb2 = vec![0.0f32; v];
        let mut dh = vec![0.0f32; h];
        let mut loss_sum = 0.0f64;

        for t in 0..frames {
            let yi = y[t] as usize;
            let zrow = &mut z[t * v..(t + 1) * v];
            // softmax cross-entropy; zrow becomes dz in place
            let zmax = zrow.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
            let zy = zrow[yi];
            let mut sum = 0.0f32;
            for zk in zrow.iter_mut() {
                *zk = (*zk - zmax).exp();
                sum += *zk;
            }
            loss_sum += (sum.ln() + zmax - zy) as f64;
            let inv = 1.0 / sum;
            for (k, zk) in zrow.iter_mut().enumerate() {
                *zk = *zk * inv - if k == yi { 1.0 } else { 0.0 };
            }
            // grads: every loop is axpy over a contiguous row except the
            // dh reduction, which uses the fixed-lane dot
            for (g, &d) in gb2.iter_mut().zip(zrow.iter()) {
                *g += d;
            }
            let hrow = &hid[t * h..(t + 1) * h];
            for j in 0..h {
                let hj = hrow[j];
                if hj > 0.0 {
                    let row = &mut gw2[j * v..(j + 1) * v];
                    for (rk, &d) in row.iter_mut().zip(zrow.iter()) {
                        *rk += hj * d;
                    }
                    // relu grad: pre-activation > 0
                    dh[j] = gemm::dot_lanes(&w2[j * v..(j + 1) * v], zrow);
                } else {
                    dh[j] = 0.0; // relu inactive: no gradient through unit j
                }
            }
            for (g, &d) in gb1.iter_mut().zip(dh.iter()) {
                *g += d;
            }
            let xf = &x[t * f..(t + 1) * f];
            for i in 0..f {
                let xi = xf[i];
                let row = &mut gw1[i * h..(i + 1) * h];
                for (rj, &d) in row.iter_mut().zip(dh.iter()) {
                    *rj += xi * d;
                }
            }
        }

        let scale = lr / frames as f32;
        let apply = |p: &[f32], g: &[f32]| -> Vec<f32> {
            p.iter().zip(g).map(|(&pv, &gv)| pv - scale * gv).collect()
        };
        let new = vec![
            apply(w1, &gw1),
            apply(b1, &gb1),
            apply(w2, &gw2),
            apply(b2, &gb2),
        ];
        Ok((new, (loss_sum / frames as f64) as f32))
    }

    /// One FP32 client step (the baseline path).
    pub fn run_train_fp32(
        &self,
        params: &[Vec<f32>],
        x: &[f32],
        y: &[i32],
        lr: f32,
    ) -> Result<Fp32StepOut> {
        let (params, loss) = self.sgd_step(params, x, y, lr)?;
        Ok(Fp32StepOut { params, loss })
    }

    /// One OMC client step: decompress `V̄ = s·Ṽ + b`, SGD, then masked
    /// re-compress with the crate's quantizer + PVT fit — the same
    /// semantics as the lowered `train_omc` graph
    /// (`python/compile/omc.py::compress_masked`).
    #[allow(clippy::too_many_arguments)]
    pub fn run_train_omc(
        &self,
        use_pvt: bool,
        tildes: &[Vec<f32>],
        s: &[f32],
        b: &[f32],
        mask: &[f32],
        x: &[f32],
        y: &[i32],
        lr: f32,
        exp_bits: u32,
        mant_bits: u32,
    ) -> Result<OmcStepOut> {
        self.check_params(tildes)?;
        let n = tildes.len();
        anyhow::ensure!(
            s.len() == n && b.len() == n && mask.len() == n,
            "s/b/mask must have {n} entries"
        );
        let fmt = FloatFormat::new(exp_bits, mant_bits)?;
        // decompress V̄ = s·Ṽ + b on the dispatched affine kernel
        // (identity transforms bit-copy, preserving signed zeros)
        let decoded: Vec<Vec<f32>> = tildes
            .iter()
            .enumerate()
            .map(|(i, t)| {
                let mut out = vec![0.0f32; t.len()];
                transform::apply(
                    transform::Pvt { s: s[i], b: b[i] },
                    t,
                    &mut out,
                );
                out
            })
            .collect();
        let (updated, loss) = self.sgd_step(&decoded, x, y, lr)?;
        // masked re-compress
        let mut out_t = Vec::with_capacity(n);
        let mut out_s = Vec::with_capacity(n);
        let mut out_b = Vec::with_capacity(n);
        for (i, vnew) in updated.into_iter().enumerate() {
            if mask[i] > 0.5 {
                let mut vt = vec![0.0f32; vnew.len()];
                quantize_slice(&vnew, fmt, &mut vt);
                let pvt = if use_pvt {
                    transform::fit(&vnew, &vt)
                } else {
                    transform::Pvt::IDENTITY
                };
                out_t.push(vt);
                out_s.push(pvt.s);
                out_b.push(pvt.b);
            } else {
                out_t.push(vnew);
                out_s.push(1.0);
                out_b.push(0.0);
            }
        }
        Ok(OmcStepOut {
            tildes: out_t,
            s: out_s,
            b: out_b,
            loss,
        })
    }

    /// One eval step: mean framewise NLL + greedy (first-max) predictions.
    /// Forward runs on the blocked GEMM kernels, whole batch at once; the
    /// per-frame argmax/softmax scan keeps the exact first-max semantics
    /// of the original loop.
    pub fn run_eval(&self, params: &[Vec<f32>], x: &[f32], y: &[i32]) -> Result<EvalOut> {
        self.check_params(params)?;
        self.check_batch(x, y)?;
        let (f, h, v) = (self.f, self.h, self.v);
        let (w1, b1, w2, b2) = (&params[0], &params[1], &params[2], &params[3]);
        let frames = self.batch * self.seq_len;
        for &yt in y {
            anyhow::ensure!(
                (yt as usize) < v,
                "label {yt} out of range (vocab {v})"
            );
        }
        let workers = threadpool::default_workers();
        let mut hid = vec![0.0f32; frames * h];
        gemm::gemm_bias_act_auto(x, w1, b1, frames, f, h, Act::Relu, workers, &mut hid);
        let mut z = vec![0.0f32; frames * v];
        gemm::gemm_bias_act_auto(&hid, w2, b2, frames, h, v, Act::Linear, workers, &mut z);

        let mut pred = Vec::with_capacity(frames);
        let mut loss_sum = 0.0f64;
        for t in 0..frames {
            let yi = y[t] as usize;
            let zrow = &z[t * v..(t + 1) * v];
            let mut best = f32::NEG_INFINITY;
            let mut arg = 0usize;
            for (k, &zk) in zrow.iter().enumerate() {
                if zk > best {
                    best = zk;
                    arg = k;
                }
            }
            let mut sum = 0.0f32;
            for &zk in zrow.iter() {
                sum += (zk - best).exp();
            }
            loss_sum += (sum.ln() + best - zrow[yi]) as f64;
            pred.push(arg as i32);
        }
        Ok(EvalOut {
            loss: (loss_sum / frames as f64) as f32,
            pred,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    fn tiny() -> (NativeModel, Manifest) {
        let m = manifest_for("tiny").unwrap();
        (NativeModel::from_manifest(&m).unwrap(), m)
    }

    fn batch_for(m: &NativeModel, seed: u64) -> (Vec<f32>, Vec<i32>) {
        let mut rng = Xoshiro256pp::new(seed);
        let frames = m.batch * m.seq_len;
        let mut x = vec![0.0f32; frames * m.f];
        rng.fill_normal(&mut x, 1.0);
        let y: Vec<i32> =
            (0..frames).map(|_| rng.next_below(m.v as u64) as i32).collect();
        (x, y)
    }

    #[test]
    fn manifests_are_consistent() {
        for name in ["tiny", "small"] {
            let m = manifest_for(name).unwrap();
            assert_eq!(
                m.variables.iter().map(|v| v.size).sum::<usize>(),
                m.total_params
            );
            NativeModel::from_manifest(&m).unwrap();
        }
        assert!(manifest_for("huge").is_err());
        assert_eq!(
            model_name(std::path::Path::new("native:tiny")),
            Some("tiny")
        );
        assert_eq!(model_name(std::path::Path::new("artifacts/tiny")), None);
    }

    #[test]
    fn init_is_deterministic_and_seed_sensitive() {
        let (nm, _) = tiny();
        let a = nm.run_init(7).unwrap();
        let b = nm.run_init(7).unwrap();
        assert_eq!(a, b);
        let c = nm.run_init(8).unwrap();
        assert_ne!(a, c);
        // biases start at zero
        assert!(a[1].iter().all(|&x| x == 0.0));
        assert!(a[3].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn training_reduces_loss_deterministically() {
        let (nm, _) = tiny();
        let mut params = nm.run_init(1).unwrap();
        let (x, y) = batch_for(&nm, 2);
        let first = nm.run_train_fp32(&params, &x, &y, 0.5).unwrap();
        let mut last = first.loss;
        params = first.params;
        for _ in 0..30 {
            let out = nm.run_train_fp32(&params, &x, &y, 0.5).unwrap();
            params = out.params;
            last = out.loss;
        }
        assert!(
            last < first.loss,
            "loss should fall on a fixed batch: {} -> {last}",
            first.loss
        );
        // bit-determinism: replay the exact same trajectory
        let mut p2 = nm.run_init(1).unwrap();
        for _ in 0..31 {
            p2 = nm.run_train_fp32(&p2, &x, &y, 0.5).unwrap().params;
        }
        for (a, b) in params.iter().zip(&p2) {
            assert_eq!(
                a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                b.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
            );
        }
    }

    #[test]
    fn omc_step_outputs_are_representable_and_respect_mask() {
        let (nm, _) = tiny();
        let params = nm.run_init(3).unwrap();
        let (x, y) = batch_for(&nm, 4);
        let fmt: FloatFormat = "S1E3M7".parse().unwrap();
        let n = params.len();
        let s = vec![1.0f32; n];
        let b = vec![0.0f32; n];
        let mask = vec![1.0f32, 0.0, 1.0, 0.0]; // weights only
        let out = nm
            .run_train_omc(
                true, &params, &s, &b, &mask, &x, &y, 0.1, fmt.exp_bits,
                fmt.mant_bits,
            )
            .unwrap();
        assert!(out.loss.is_finite());
        for (i, t) in out.tildes.iter().enumerate() {
            if mask[i] > 0.5 {
                for &tv in t {
                    assert!(
                        crate::omc::quantize::is_representable(tv, fmt),
                        "masked var {i} value {tv} not {fmt}-representable"
                    );
                }
            } else {
                // raw variables carry the identity transform
                assert_eq!(out.s[i], 1.0);
                assert_eq!(out.b[i], 0.0);
            }
        }
        // with PVT on, at least one masked var fits a non-identity scale
        assert!(
            (0..n).any(|i| mask[i] > 0.5
                && (out.s[i] != 1.0 || out.b[i] != 0.0)),
            "PVT fit should be non-trivial"
        );
        // no-PVT ablation: identity transforms everywhere
        let out2 = nm
            .run_train_omc(
                false, &params, &s, &b, &mask, &x, &y, 0.1, fmt.exp_bits,
                fmt.mant_bits,
            )
            .unwrap();
        assert!(out2.s.iter().all(|&v| v == 1.0));
        assert!(out2.b.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn eval_forward_matches_naive_loops_bitwise() {
        // the GEMM rewrite must preserve the exact per-element accumulation
        // order of the original dot-form loops: replay them here and compare
        // logits, loss, and predictions bit for bit
        let (nm, _) = tiny();
        let params = nm.run_init(9).unwrap();
        let (x, y) = batch_for(&nm, 10);
        let out = nm.run_eval(&params, &x, &y).unwrap();

        let (f, h, v) = (nm.f, nm.h, nm.v);
        let (w1, b1, w2, b2) =
            (&params[0], &params[1], &params[2], &params[3]);
        let frames = nm.batch * nm.seq_len;
        let mut hid = vec![0.0f32; h];
        let mut z = vec![0.0f32; v];
        let mut pred = Vec::new();
        let mut loss_sum = 0.0f64;
        for t in 0..frames {
            let xf = &x[t * f..(t + 1) * f];
            for j in 0..h {
                let mut acc = b1[j];
                for i in 0..f {
                    acc += xf[i] * w1[i * h + j];
                }
                hid[j] = if acc > 0.0 { acc } else { 0.0 };
            }
            let mut best = f32::NEG_INFINITY;
            let mut arg = 0usize;
            for k in 0..v {
                let mut acc = b2[k];
                for j in 0..h {
                    acc += hid[j] * w2[j * v + k];
                }
                z[k] = acc;
                if acc > best {
                    best = acc;
                    arg = k;
                }
            }
            let mut sum = 0.0f32;
            for &zk in z.iter() {
                sum += (zk - best).exp();
            }
            loss_sum += (sum.ln() + best - z[y[t] as usize]) as f64;
            pred.push(arg as i32);
        }
        let naive_loss = (loss_sum / frames as f64) as f32;
        assert_eq!(out.loss.to_bits(), naive_loss.to_bits());
        assert_eq!(out.pred, pred);
    }

    #[test]
    fn eval_loss_tracks_training_and_preds_in_range() {
        let (nm, _) = tiny();
        let mut params = nm.run_init(5).unwrap();
        let (x, y) = batch_for(&nm, 6);
        let before = nm.run_eval(&params, &x, &y).unwrap();
        for _ in 0..40 {
            params = nm.run_train_fp32(&params, &x, &y, 0.5).unwrap().params;
        }
        let after = nm.run_eval(&params, &x, &y).unwrap();
        assert!(after.loss < before.loss);
        assert_eq!(after.pred.len(), nm.batch * nm.seq_len);
        assert!(after.pred.iter().all(|&p| (0..nm.v as i32).contains(&p)));
    }
}
