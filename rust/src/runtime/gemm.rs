//! Cache-blocked, vectorization-friendly GEMM for the native backend
//! (§Perf).
//!
//! The PR 3 native MLP computed every matrix product in dot-product form
//! (`acc += x[i] * w[i][j]` with `j` outer): each output element is a
//! serial f32 reduction, which the compiler cannot vectorize without
//! reassociating the sum, and the inner loop walks `w` column-strided.
//! This module restructures the same math into **axpy form** — for each
//! input position `i`, scale the contiguous row `w[i][..]` into the
//! output row — which
//!
//! * keeps the *per-output-element* accumulation order exactly `bias,
//!   then i ascending`, i.e. **bit-identical** to the naive dot loop
//!   ([`gemm_naive`] stays in-tree as the reference and the bench
//!   baseline), independent of blocking, threading, or ISA;
//! * makes the inner loop an independent elementwise multiply-add over
//!   `out_dim` lanes — trivially auto-vectorizable, and FMA-friendly in
//!   structure (a `-C target-cpu=native` build with contraction enabled
//!   could fuse it; the default build keeps separate mul + add so every
//!   host computes the same bits);
//! * blocks the `i` loop ([`K_BLOCK`] rows of `w` per pass) so the `w`
//!   panel stays cache-resident across a tile of output rows.
//!
//! Threading: [`gemm_bias_act_auto`] fans fixed-size row tiles
//! ([`PAR_ROW_TILE`]) over [`threadpool::scope_map_chunked`] once the
//! multiply-add count crosses [`PAR_MIN_MACS`]. Tiles are fixed-size and
//! every output element is computed independently, so the result is
//! byte-identical for any worker count — the same determinism contract
//! as the codec kernels (see `docs/PERFORMANCE.md`).
//!
//! Reductions that genuinely cross the accumulation order (the backward
//! pass's `dh = W₂·dz`) use [`dot_lanes`]: a fixed 8-lane virtual split
//! with a fixed pairwise fold — reassociated relative to a serial loop,
//! but identically on every host, so it is deterministic too.

use crate::util::threadpool;

/// Fused activation applied after the bias+matmul.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Act {
    /// no activation (logits layer)
    Linear,
    /// `max(x, 0)` with the exact `x > 0.0 ? x : 0.0` semantics of the
    /// lowered graphs (`-0.0` and NaN both map to `+0.0`)
    Relu,
}

/// Rows of `w` processed per blocking pass: a `K_BLOCK × out_dim` panel
/// (`128 × 64` floats = 32 KiB at the native models' sizes) stays L1/L2
/// resident while a tile of output rows streams through it.
pub const K_BLOCK: usize = 128;

/// Fixed rows per parallel work item. Tiles are independent of the
/// worker count, so threading cannot change the output bytes.
pub const PAR_ROW_TILE: usize = 64;

/// Multiply-add count below which threading costs more than it saves.
pub const PAR_MIN_MACS: usize = 1 << 23;

/// Number of virtual lanes in [`dot_lanes`].
pub const DOT_LANES: usize = 8;

fn check_shapes(
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    rows: usize,
    in_dim: usize,
    out_dim: usize,
    out: &[f32],
) {
    assert!(in_dim > 0 && out_dim > 0, "degenerate GEMM dims");
    assert_eq!(x.len(), rows * in_dim, "x shape");
    assert_eq!(w.len(), in_dim * out_dim, "w shape");
    assert_eq!(bias.len(), out_dim, "bias shape");
    assert_eq!(out.len(), rows * out_dim, "out shape");
}

#[inline]
fn apply_act(act: Act, out: &mut [f32]) {
    if act == Act::Relu {
        for o in out.iter_mut() {
            // deliberately NOT `*o <= 0.0`: the negated compare maps NaN
            // to 0.0 too, exactly like the `acc > 0.0 ? acc : 0.0` form
            // in gemm_naive and the lowered graphs
            #[allow(clippy::neg_cmp_op_on_partial_ord)]
            if !(*o > 0.0) {
                *o = 0.0;
            }
        }
    }
}

/// `out = act(x · w + bias)` — `x` row-major `[rows][in_dim]`, `w`
/// row-major `[in_dim][out_dim]`, one bias per output column. Blocked
/// axpy form; bit-identical to [`gemm_naive`].
pub fn gemm_bias_act(
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    rows: usize,
    in_dim: usize,
    out_dim: usize,
    act: Act,
    out: &mut [f32],
) {
    check_shapes(x, w, bias, rows, in_dim, out_dim, out);
    gemm_tile(x, w, bias, in_dim, out_dim, act, out);
}

/// The serial tile kernel (`rows` implied by the slice lengths).
fn gemm_tile(
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    in_dim: usize,
    out_dim: usize,
    act: Act,
    out: &mut [f32],
) {
    for or in out.chunks_exact_mut(out_dim) {
        or.copy_from_slice(bias);
    }
    let mut k0 = 0usize;
    while k0 < in_dim {
        let k1 = (k0 + K_BLOCK).min(in_dim);
        for (xr, or) in x.chunks_exact(in_dim).zip(out.chunks_exact_mut(out_dim)) {
            for i in k0..k1 {
                let a = xr[i];
                let wrow = &w[i * out_dim..(i + 1) * out_dim];
                for (o, &wv) in or.iter_mut().zip(wrow) {
                    *o += a * wv;
                }
            }
        }
        k0 = k1;
    }
    apply_act(act, out);
}

/// [`gemm_bias_act`] that fans fixed row tiles over the scoped thread
/// pool above the [`PAR_MIN_MACS`] cutoff. Output bytes are identical
/// for every `workers` value (tiles are fixed-size and disjoint).
pub fn gemm_bias_act_auto(
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    rows: usize,
    in_dim: usize,
    out_dim: usize,
    act: Act,
    workers: usize,
    out: &mut [f32],
) {
    let macs = rows
        .saturating_mul(in_dim)
        .saturating_mul(out_dim);
    if workers <= 1 || rows <= PAR_ROW_TILE || macs < PAR_MIN_MACS {
        return gemm_bias_act(x, w, bias, rows, in_dim, out_dim, act, out);
    }
    gemm_bias_act_threaded(x, w, bias, rows, in_dim, out_dim, act, workers, out);
}

/// Always-threaded variant (no size cutoff) — [`gemm_bias_act_auto`] is
/// the entry point; this exists so tests and benches can force the
/// parallel path on small problems.
pub fn gemm_bias_act_threaded(
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    rows: usize,
    in_dim: usize,
    out_dim: usize,
    act: Act,
    workers: usize,
    out: &mut [f32],
) {
    check_shapes(x, w, bias, rows, in_dim, out_dim, out);
    let items: Vec<(&[f32], &mut [f32])> = x
        .chunks(PAR_ROW_TILE * in_dim)
        .zip(out.chunks_mut(PAR_ROW_TILE * out_dim))
        .collect();
    threadpool::scope_map_chunked(
        items,
        workers,
        || (),
        |_, (xc, oc), _| gemm_tile(xc, w, bias, in_dim, out_dim, act, oc),
    )
    .expect("gemm worker panicked");
}

/// The naive dot-product-form reference (the PR 3 loop shape): kept as
/// the correctness baseline the blocked kernel must match **bit for
/// bit**, and as the scalar side of the `bench_native` speedup rows.
pub fn gemm_naive(
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    rows: usize,
    in_dim: usize,
    out_dim: usize,
    act: Act,
    out: &mut [f32],
) {
    check_shapes(x, w, bias, rows, in_dim, out_dim, out);
    for r in 0..rows {
        let xr = &x[r * in_dim..(r + 1) * in_dim];
        let or = &mut out[r * out_dim..(r + 1) * out_dim];
        for j in 0..out_dim {
            let mut acc = bias[j];
            for i in 0..in_dim {
                acc += xr[i] * w[i * out_dim + j];
            }
            or[j] = match act {
                Act::Linear => acc,
                Act::Relu => {
                    if acc > 0.0 {
                        acc
                    } else {
                        0.0
                    }
                }
            };
        }
    }
}

/// Deterministic lane-split dot product: [`DOT_LANES`] = 8 independent
/// f32 accumulators (element `i` lands in lane `i % 8`), folded in the
/// fixed order `((l0+l4)+(l1+l5)) + ((l2+l6)+(l3+l7))`. Reassociated
/// relative to a serial sum — but identically on every host and ISA, so
/// results are bit-stable. Auto-vectorizes (the lanes are independent).
pub fn dot_lanes(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; DOT_LANES];
    let ca = a.chunks_exact(DOT_LANES);
    let cb = b.chunks_exact(DOT_LANES);
    let (ra, rb) = (ca.remainder(), cb.remainder());
    for (pa, pb) in ca.zip(cb) {
        for l in 0..DOT_LANES {
            acc[l] += pa[l] * pb[l];
        }
    }
    for (l, (&xa, &xb)) in ra.iter().zip(rb).enumerate() {
        acc[l] += xa * xb;
    }
    ((acc[0] + acc[4]) + (acc[1] + acc[5])) + ((acc[2] + acc[6]) + (acc[3] + acc[7]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::Gen;

    fn rand_problem(
        g: &mut Gen,
        rows: usize,
        in_dim: usize,
        out_dim: usize,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        (
            g.vec_normal(rows * in_dim, 1.0),
            g.vec_normal(in_dim * out_dim, 0.5),
            g.vec_normal(out_dim, 0.1),
        )
    }

    #[test]
    fn blocked_matches_naive_bitwise() {
        let mut g = Gen::new(41);
        for (rows, in_dim, out_dim) in
            [(1, 1, 1), (3, 5, 7), (8, 16, 32), (33, 200, 17), (150, 64, 48)]
        {
            for act in [Act::Linear, Act::Relu] {
                let (x, w, b) = rand_problem(&mut g, rows, in_dim, out_dim);
                let mut want = vec![0.0f32; rows * out_dim];
                gemm_naive(&x, &w, &b, rows, in_dim, out_dim, act, &mut want);
                let mut got = vec![0.0f32; rows * out_dim];
                gemm_bias_act(&x, &w, &b, rows, in_dim, out_dim, act, &mut got);
                for i in 0..want.len() {
                    assert_eq!(
                        want[i].to_bits(),
                        got[i].to_bits(),
                        "{rows}x{in_dim}x{out_dim} {act:?} idx {i}"
                    );
                }
            }
        }
    }

    #[test]
    fn threaded_matches_serial_bitwise_any_worker_count() {
        let mut g = Gen::new(43);
        let (rows, in_dim, out_dim) = (3 * PAR_ROW_TILE + 11, 24, 19);
        let (x, w, b) = rand_problem(&mut g, rows, in_dim, out_dim);
        let mut want = vec![0.0f32; rows * out_dim];
        gemm_bias_act(&x, &w, &b, rows, in_dim, out_dim, Act::Relu, &mut want);
        for workers in [1usize, 2, 3, 8] {
            let mut got = vec![0.0f32; rows * out_dim];
            gemm_bias_act_threaded(
                &x, &w, &b, rows, in_dim, out_dim, Act::Relu, workers, &mut got,
            );
            for i in 0..want.len() {
                assert_eq!(want[i].to_bits(), got[i].to_bits(), "workers={workers}");
            }
        }
    }

    #[test]
    fn relu_zeroes_negatives_and_negative_zero() {
        // one row, identity-ish weights: out = bias exactly
        let bias = [-1.0f32, -0.0, 0.0, 2.0];
        let x = [0.0f32];
        let w = [0.0f32; 4];
        let mut out = vec![0.0f32; 4];
        gemm_bias_act(&x, &w, &bias, 1, 1, 4, Act::Relu, &mut out);
        assert_eq!(out[0].to_bits(), 0.0f32.to_bits());
        assert_eq!(out[1].to_bits(), 0.0f32.to_bits(), "-0.0 -> +0.0");
        assert_eq!(out[2].to_bits(), 0.0f32.to_bits());
        assert_eq!(out[3], 2.0);
    }

    #[test]
    fn dot_lanes_is_accurate_and_deterministic() {
        let mut g = Gen::new(47);
        for n in [0usize, 1, 7, 8, 9, 63, 64, 1000] {
            let a = g.vec_normal(n, 1.0);
            let b = g.vec_normal(n, 1.0);
            let got = dot_lanes(&a, &b);
            let again = dot_lanes(&a, &b);
            assert_eq!(got.to_bits(), again.to_bits());
            let reference: f64 =
                a.iter().zip(&b).map(|(&x, &y)| x as f64 * y as f64).sum();
            assert!(
                (got as f64 - reference).abs() <= reference.abs() * 1e-5 + 1e-5,
                "n={n}: {got} vs {reference}"
            );
        }
    }

    #[test]
    fn auto_dispatch_matches_serial() {
        // below the cutoff auto == serial trivially; force the threaded
        // branch with a shape big enough in rows but tiny in flops is not
        // possible (the cutoff is flops), so check equivalence both ways
        let mut g = Gen::new(49);
        let (rows, in_dim, out_dim) = (2 * PAR_ROW_TILE, 16, 8);
        let (x, w, b) = rand_problem(&mut g, rows, in_dim, out_dim);
        let mut a = vec![0.0f32; rows * out_dim];
        gemm_bias_act(&x, &w, &b, rows, in_dim, out_dim, Act::Linear, &mut a);
        let mut c = vec![0.0f32; rows * out_dim];
        gemm_bias_act_auto(&x, &w, &b, rows, in_dim, out_dim, Act::Linear, 4, &mut c);
        assert_eq!(
            a.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            c.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }
}
