//! The PJRT execution engine and the typed bindings to each artifact.
//!
//! [`Engine`] owns the PJRT CPU client and compiles HLO-text artifacts once;
//! [`LoadedModel`] binds the full artifact set of one model size (init /
//! train_fp32 / train_omc / train_omc_nopvt / eval) against its manifest and
//! exposes shape-checked entry points operating on plain `Vec<f32>`
//! parameter lists — the representation the FL layer works with.
//!
//! Interchange is HLO text, not serialized protos: the crate's XLA
//! (xla_extension 0.5.1) rejects jax≥0.5 64-bit instruction ids, while the
//! text parser reassigns ids (see /opt/xla-example/README.md).

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use crate::model::manifest::Manifest;

use super::native::{self, NativeModel};
pub use super::{EvalOut, Fp32StepOut, OmcStepOut};

/// The PJRT client plus artifact compilation cache.
pub struct Engine {
    client: PjRtClient,
}

impl Engine {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        crate::log_info!(
            "PJRT client: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Self { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Whether models loaded by this engine may be driven from multiple
    /// threads. PJRT's `PjRtLoadedExecutable` holds an `Rc` into the
    /// client, so: no — the round engine keeps client training pinned to
    /// the thread that created the engine (see `fl::round`).
    pub fn is_send_safe(&self) -> bool {
        false
    }

    /// Load one HLO-text artifact and compile it.
    pub fn load_hlo_text(&self, path: &Path) -> Result<Executable> {
        compile_hlo_text(&self.client, path)
    }

    /// Bind the artifact set for one model size directory. Compilation is
    /// *lazy*: each graph compiles on first use, so a run only pays for the
    /// artifacts it actually executes (an FP32 baseline never compiles the
    /// OMC graph and vice versa).
    pub fn load_model(&self, dir: &Path) -> Result<LoadedModel> {
        // `native:` dirs bind the pure-Rust backend (no artifacts, no
        // compilation) — available in every build; see `runtime::native`.
        if let Some(name) = native::model_name(dir) {
            let manifest = native::manifest_for(name)?;
            let nm = NativeModel::from_manifest(&manifest)?;
            crate::log_info!(
                "binding native model '{}' ({} vars, {} params)",
                manifest.config.name,
                manifest.num_vars(),
                manifest.total_params
            );
            let lazy = |n: &str| LazyExecutable::new(dir.join(n));
            return Ok(LoadedModel {
                dir: dir.to_path_buf(),
                init: lazy("init"),
                train_fp32: lazy("train_fp32"),
                train_omc: lazy("train_omc"),
                train_omc_nopvt: lazy("train_omc_nopvt"),
                eval: lazy("eval"),
                manifest,
                engine_client: self.client.clone(),
                native: Some(nm),
            });
        }
        let manifest = Manifest::load(dir)?;
        crate::log_info!(
            "binding model '{}' ({} vars, {} params) from {}",
            manifest.config.name,
            manifest.num_vars(),
            manifest.total_params,
            dir.display()
        );
        let lazy = |name: &str| -> LazyExecutable {
            let file = manifest
                .artifacts
                .get(name)
                .cloned()
                .unwrap_or_else(|| format!("{name}.hlo.txt"));
            LazyExecutable::new(dir.join(file))
        };
        Ok(LoadedModel {
            dir: dir.to_path_buf(),
            init: lazy("init"),
            train_fp32: lazy("train_fp32"),
            train_omc: lazy("train_omc"),
            train_omc_nopvt: lazy("train_omc_nopvt"),
            eval: lazy("eval"),
            manifest,
            engine_client: self.client.clone(),
            native: None,
        })
    }
}

/// Parse + compile one HLO-text file on a PJRT client.
fn compile_hlo_text(client: &PjRtClient, path: &Path) -> Result<Executable> {
    anyhow::ensure!(
        path.exists(),
        "artifact {} not found — run `python python/compile/aot.py --out-dir artifacts` first",
        path.display()
    );
    let t = std::time::Instant::now();
    let proto = HloModuleProto::from_text_file(
        path.to_str()
            .ok_or_else(|| anyhow::anyhow!("non-UTF8 path"))?,
    )
    .with_context(|| format!("parsing HLO text {}", path.display()))?;
    let comp = XlaComputation::from_proto(&proto);
    let exe = client
        .compile(&comp)
        .with_context(|| format!("compiling {}", path.display()))?;
    let name = path
        .file_name()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_default();
    crate::log_debug!("compiled {name} in {:.1}s", t.elapsed().as_secs_f64());
    Ok(Executable { exe, name })
}

/// A lazily-compiled artifact: the HLO text compiles on first use and is
/// cached for the rest of the process.
pub struct LazyExecutable {
    path: PathBuf,
    cell: std::cell::OnceCell<Executable>,
}

impl LazyExecutable {
    fn new(path: PathBuf) -> Self {
        Self {
            path,
            cell: std::cell::OnceCell::new(),
        }
    }

    pub fn get(&self, client: &PjRtClient) -> Result<&Executable> {
        if self.cell.get().is_none() {
            let exe = compile_hlo_text(client, &self.path)?;
            let _ = self.cell.set(exe);
        }
        Ok(self.cell.get().unwrap())
    }
}

/// A compiled artifact.
///
/// NOTE: `PjRtLoadedExecutable` holds an `Rc` into the PJRT client, so it is
/// `!Send` — everything that executes graphs is pinned to the thread that
/// created the [`Engine`]. The FL layer therefore runs client *training*
/// steps sequentially (the CPU plugin's device queue serializes them
/// regardless) and parallelizes only the pure-Rust work (compression,
/// codec, data generation) across the thread pool.
pub struct Executable {
    exe: PjRtLoadedExecutable,
    pub name: String,
}

impl Executable {
    /// Execute with the given operands; returns the unwrapped output tuple.
    pub fn run(&self, args: &[Literal]) -> Result<Vec<Literal>> {
        let out = self
            .exe
            .execute::<Literal>(args)
            .with_context(|| format!("executing {}", self.name))?;
        let lit = out[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.name))?;
        // artifacts are lowered with return_tuple=True
        lit.to_tuple().context("unwrapping output tuple")
    }
}

/// f32 tensor literal in HLO operand layout.
pub fn lit_f32(data: &[f32], dims: &[i64]) -> Result<Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "shape/data mismatch");
    if dims.len() == 1 {
        return Ok(Literal::vec1(data));
    }
    Ok(Literal::vec1(data).reshape(dims)?)
}

/// i32 tensor literal.
pub fn lit_i32(data: &[i32], dims: &[i64]) -> Result<Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "shape/data mismatch");
    if dims.len() == 1 {
        return Ok(Literal::vec1(data));
    }
    Ok(Literal::vec1(data).reshape(dims)?)
}

/// scalar literals
pub fn lit_f32_scalar(x: f32) -> Literal {
    Literal::from(x)
}

pub fn lit_i32_scalar(x: i32) -> Literal {
    Literal::from(x)
}

/// Extract an f32 vector from a literal.
pub fn to_f32_vec(lit: &Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Extract an i32 vector from a literal.
pub fn to_i32_vec(lit: &Literal) -> Result<Vec<i32>> {
    Ok(lit.to_vec::<i32>()?)
}

/// Extract an f32 scalar.
pub fn to_f32_scalar(lit: &Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}

/// The bound artifact set for one model size (each graph compiles lazily on
/// first use; see [`Engine::load_model`]).
pub struct LoadedModel {
    pub dir: PathBuf,
    pub manifest: Manifest,
    pub init: LazyExecutable,
    pub train_fp32: LazyExecutable,
    pub train_omc: LazyExecutable,
    pub train_omc_nopvt: LazyExecutable,
    pub eval: LazyExecutable,
    engine_client: PjRtClient,
    /// `Some` for `native:` model dirs — the pure-Rust backend handles
    /// every entry point and the lazy executables are never compiled
    native: Option<NativeModel>,
}

impl LoadedModel {
    pub fn num_vars(&self) -> usize {
        self.manifest.num_vars()
    }

    /// See [`Engine::is_send_safe`]: PJRT executables are `!Send`, so the
    /// round engine must not shard client execution across threads. This
    /// stays `false` even for native-backed models in `pjrt` builds — the
    /// struct holds the PJRT client, so the type itself is `!Send`.
    pub fn is_send_safe(&self) -> bool {
        false
    }

    /// Force-compile the executables a run will need (eval + the relevant
    /// training graph), so compile time stays out of round timings.
    pub fn warmup(&self, fp32_baseline: bool, use_pvt: bool) -> Result<()> {
        if self.native.is_some() {
            return Ok(()); // nothing to compile
        }
        self.eval.get(&self.engine_client)?;
        if fp32_baseline {
            self.train_fp32.get(&self.engine_client)?;
        } else if use_pvt {
            self.train_omc.get(&self.engine_client)?;
        } else {
            self.train_omc_nopvt.get(&self.engine_client)?;
        }
        Ok(())
    }

    fn var_dims(&self, i: usize) -> Vec<i64> {
        self.manifest.variables[i]
            .shape
            .iter()
            .map(|&d| d as i64)
            .collect()
    }

    fn check_params(&self, params: &[Vec<f32>]) -> Result<()> {
        anyhow::ensure!(
            params.len() == self.num_vars(),
            "expected {} variables, got {}",
            self.num_vars(),
            params.len()
        );
        for (i, p) in params.iter().enumerate() {
            let spec = &self.manifest.variables[i];
            anyhow::ensure!(
                p.len() == spec.size,
                "variable {} ({}) has {} elements, expected {}",
                i,
                spec.name,
                p.len(),
                spec.size
            );
        }
        Ok(())
    }

    fn check_batch(&self, x: &[f32], y: &[i32]) -> Result<()> {
        let c = &self.manifest.config;
        anyhow::ensure!(
            x.len() == c.batch * c.seq_len * c.feature_dim,
            "batch x has {} elements, expected {}",
            x.len(),
            c.batch * c.seq_len * c.feature_dim
        );
        anyhow::ensure!(
            y.len() == c.batch * c.seq_len,
            "batch y has {} elements, expected {}",
            y.len(),
            c.batch * c.seq_len
        );
        Ok(())
    }

    fn param_literals(&self, params: &[Vec<f32>]) -> Result<Vec<Literal>> {
        params
            .iter()
            .enumerate()
            .map(|(i, p)| lit_f32(p, &self.var_dims(i)))
            .collect()
    }

    fn batch_literals(&self, x: &[f32], y: &[i32]) -> Result<(Literal, Literal)> {
        let c = &self.manifest.config;
        Ok((
            lit_f32(
                x,
                &[c.batch as i64, c.seq_len as i64, c.feature_dim as i64],
            )?,
            lit_i32(y, &[c.batch as i64, c.seq_len as i64])?,
        ))
    }

    /// Run the init artifact: seed → initial parameters.
    pub fn run_init(&self, seed: i32) -> Result<Vec<Vec<f32>>> {
        if let Some(n) = &self.native {
            return n.run_init(seed);
        }
        let outs = self.init.get(&self.engine_client)?.run(&[lit_i32_scalar(seed)])?;
        anyhow::ensure!(
            outs.len() == self.num_vars(),
            "init returned {} outputs, expected {}",
            outs.len(),
            self.num_vars()
        );
        outs.iter().map(to_f32_vec).collect()
    }

    /// One FP32 client step (the baseline path).
    pub fn run_train_fp32(
        &self,
        params: &[Vec<f32>],
        x: &[f32],
        y: &[i32],
        lr: f32,
    ) -> Result<Fp32StepOut> {
        if let Some(n) = &self.native {
            return n.run_train_fp32(params, x, y, lr);
        }
        self.check_params(params)?;
        self.check_batch(x, y)?;
        let mut args = self.param_literals(params)?;
        let (lx, ly) = self.batch_literals(x, y)?;
        args.push(lx);
        args.push(ly);
        args.push(lit_f32_scalar(lr));
        let outs = self.train_fp32.get(&self.engine_client)?.run(&args)?;
        let n = self.num_vars();
        anyhow::ensure!(outs.len() == n + 1, "train_fp32 output arity");
        Ok(Fp32StepOut {
            params: outs[..n].iter().map(to_f32_vec).collect::<Result<_>>()?,
            loss: to_f32_scalar(&outs[n])?,
        })
    }

    /// One OMC client step (decompress → train → re-quantize + PVT).
    #[allow(clippy::too_many_arguments)]
    pub fn run_train_omc(
        &self,
        use_pvt: bool,
        tildes: &[Vec<f32>],
        s: &[f32],
        b: &[f32],
        mask: &[f32],
        x: &[f32],
        y: &[i32],
        lr: f32,
        exp_bits: u32,
        mant_bits: u32,
    ) -> Result<OmcStepOut> {
        if let Some(n) = &self.native {
            return n.run_train_omc(
                use_pvt, tildes, s, b, mask, x, y, lr, exp_bits, mant_bits,
            );
        }
        self.check_params(tildes)?;
        self.check_batch(x, y)?;
        let n = self.num_vars();
        anyhow::ensure!(
            s.len() == n && b.len() == n && mask.len() == n,
            "s/b/mask must have {n} entries"
        );
        let mut args = self.param_literals(tildes)?;
        args.push(lit_f32(s, &[n as i64])?);
        args.push(lit_f32(b, &[n as i64])?);
        args.push(lit_f32(mask, &[n as i64])?);
        let (lx, ly) = self.batch_literals(x, y)?;
        args.push(lx);
        args.push(ly);
        args.push(lit_f32_scalar(lr));
        args.push(lit_i32_scalar(exp_bits as i32));
        args.push(lit_i32_scalar(mant_bits as i32));
        let exe = if use_pvt {
            self.train_omc.get(&self.engine_client)?
        } else {
            self.train_omc_nopvt.get(&self.engine_client)?
        };
        let outs = exe.run(&args)?;
        anyhow::ensure!(outs.len() == n + 3, "train_omc output arity");
        Ok(OmcStepOut {
            tildes: outs[..n].iter().map(to_f32_vec).collect::<Result<_>>()?,
            s: to_f32_vec(&outs[n])?,
            b: to_f32_vec(&outs[n + 1])?,
            loss: to_f32_scalar(&outs[n + 2])?,
        })
    }

    /// One eval step: loss + greedy predictions.
    pub fn run_eval(
        &self,
        params: &[Vec<f32>],
        x: &[f32],
        y: &[i32],
    ) -> Result<EvalOut> {
        if let Some(n) = &self.native {
            return n.run_eval(params, x, y);
        }
        self.check_params(params)?;
        self.check_batch(x, y)?;
        let mut args = self.param_literals(params)?;
        let (lx, ly) = self.batch_literals(x, y)?;
        args.push(lx);
        args.push(ly);
        let outs = self.eval.get(&self.engine_client)?.run(&args)?;
        anyhow::ensure!(outs.len() == 2, "eval output arity");
        Ok(EvalOut {
            loss: to_f32_scalar(&outs[0])?,
            pred: to_i32_vec(&outs[1])?,
        })
    }
}
