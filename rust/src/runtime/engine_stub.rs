//! Stub PJRT engine + native dispatch — compiled when the `pjrt` feature
//! is off.
//!
//! Mirrors the public surface of `engine.rs` so the rest of the crate (FL
//! substrate, coordinator, examples, integration tests) builds without the
//! `xla` bindings. `native:` model dirs (see [`super::native`]) load and
//! **execute** — that is the backend plain `cargo test`, the sweep smoke
//! tier, and CI run on. Artifact-backed model dirs still fail with a clear
//! error at runtime; integration tests guard on `artifacts/` existing
//! before touching them, so a stub build runs the whole pure-Rust suite.

use std::path::{Path, PathBuf};

use anyhow::{bail, Result};

use crate::model::manifest::Manifest;

use super::native::{self, NativeModel};
pub use super::{EvalOut, Fp32StepOut, OmcStepOut};

const STUB_MSG: &str =
    "PJRT runtime not available: this binary was built without the `pjrt` \
     feature (requires the xla/xla_extension toolchain). Rebuild with \
     `cargo build --features pjrt`, or use a `native:` model dir \
     (native:tiny / native:small) which runs in every build.";

/// Placeholder for an on-device literal (never constructed in stub builds).
pub struct Literal(());

/// The engine handle: native models execute, PJRT constructors fail.
pub struct Engine {
    _private: (),
}

impl Engine {
    /// Create the engine. Always succeeds in stub builds — whether a model
    /// can *execute* is decided per-`load_model` (native: yes, artifacts:
    /// needs the `pjrt` feature).
    pub fn cpu() -> Result<Self> {
        Ok(Self { _private: () })
    }

    pub fn platform(&self) -> String {
        "native-cpu (pjrt feature off)".to_string()
    }

    /// Whether models loaded by this engine may be driven from multiple
    /// threads. Native models are plain data (`Send + Sync`), so the round
    /// engine shards client execution across the thread pool (see
    /// `fl::round`).
    pub fn is_send_safe(&self) -> bool {
        true
    }

    pub fn load_hlo_text(&self, _path: &Path) -> Result<Executable> {
        bail!(STUB_MSG)
    }

    /// Load a model. `native:<preset>` dirs synthesize their manifest and
    /// bind the pure-Rust backend; artifact dirs need the `pjrt` feature.
    pub fn load_model(&self, dir: &Path) -> Result<LoadedModel> {
        let Some(name) = native::model_name(dir) else {
            bail!("{} (requested model dir: {})", STUB_MSG, dir.display());
        };
        let manifest = native::manifest_for(name)?;
        let native = NativeModel::from_manifest(&manifest)?;
        crate::log_info!(
            "binding native model '{}' ({} vars, {} params)",
            manifest.config.name,
            manifest.num_vars(),
            manifest.total_params
        );
        Ok(LoadedModel {
            dir: dir.to_path_buf(),
            manifest,
            native,
        })
    }
}

/// A compiled artifact (stub — never constructed).
pub struct Executable {
    pub name: String,
    _private: (),
}

impl Executable {
    pub fn run(&self, _args: &[Literal]) -> Result<Vec<Literal>> {
        bail!(STUB_MSG)
    }
}

pub fn lit_f32(_data: &[f32], _dims: &[i64]) -> Result<Literal> {
    bail!(STUB_MSG)
}

pub fn lit_i32(_data: &[i32], _dims: &[i64]) -> Result<Literal> {
    bail!(STUB_MSG)
}

pub fn lit_f32_scalar(_x: f32) -> Literal {
    unreachable!("stub build: literals cannot be constructed")
}

pub fn lit_i32_scalar(_x: i32) -> Literal {
    unreachable!("stub build: literals cannot be constructed")
}

pub fn to_f32_vec(_lit: &Literal) -> Result<Vec<f32>> {
    bail!(STUB_MSG)
}

pub fn to_i32_vec(_lit: &Literal) -> Result<Vec<i32>> {
    bail!(STUB_MSG)
}

pub fn to_f32_scalar(_lit: &Literal) -> Result<f32> {
    bail!(STUB_MSG)
}

/// The bound model: in stub builds, always native-backed.
pub struct LoadedModel {
    pub dir: PathBuf,
    pub manifest: Manifest,
    native: NativeModel,
}

impl LoadedModel {
    pub fn num_vars(&self) -> usize {
        self.manifest.num_vars()
    }

    /// See [`Engine::is_send_safe`]: native models are plain data, so the
    /// round engine may shard client execution across threads.
    pub fn is_send_safe(&self) -> bool {
        true
    }

    /// No-op: the native backend has nothing to compile.
    pub fn warmup(&self, _fp32_baseline: bool, _use_pvt: bool) -> Result<()> {
        Ok(())
    }

    pub fn run_init(&self, seed: i32) -> Result<Vec<Vec<f32>>> {
        self.native.run_init(seed)
    }

    pub fn run_train_fp32(
        &self,
        params: &[Vec<f32>],
        x: &[f32],
        y: &[i32],
        lr: f32,
    ) -> Result<Fp32StepOut> {
        self.native.run_train_fp32(params, x, y, lr)
    }

    #[allow(clippy::too_many_arguments)]
    pub fn run_train_omc(
        &self,
        use_pvt: bool,
        tildes: &[Vec<f32>],
        s: &[f32],
        b: &[f32],
        mask: &[f32],
        x: &[f32],
        y: &[i32],
        lr: f32,
        exp_bits: u32,
        mant_bits: u32,
    ) -> Result<OmcStepOut> {
        self.native.run_train_omc(
            use_pvt, tildes, s, b, mask, x, y, lr, exp_bits, mant_bits,
        )
    }

    pub fn run_eval(&self, params: &[Vec<f32>], x: &[f32], y: &[i32]) -> Result<EvalOut> {
        self.native.run_eval(params, x, y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_dirs_load_and_run() {
        let engine = Engine::cpu().unwrap();
        assert!(engine.is_send_safe());
        let model = engine.load_model(Path::new("native:tiny")).unwrap();
        assert!(model.is_send_safe());
        assert_eq!(model.num_vars(), 4);
        model.warmup(true, true).unwrap();
        let params = model.run_init(1).unwrap();
        assert_eq!(params.len(), 4);
    }

    #[test]
    fn artifact_dirs_error_clearly() {
        let engine = Engine::cpu().unwrap();
        let Err(e) = engine.load_model(Path::new("artifacts/tiny")) else {
            panic!("artifact dirs must need pjrt");
        };
        let err = e.to_string();
        assert!(err.contains("pjrt"), "{err}");
        assert!(err.contains("native:"), "{err}");
    }
}
