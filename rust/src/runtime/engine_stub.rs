//! Stub PJRT engine — compiled when the `pjrt` feature is off.
//!
//! Mirrors the public surface of `engine.rs` so the rest of the crate (FL
//! substrate, coordinator, examples, integration tests) builds without the
//! `xla` bindings. Every constructor fails with a clear error at runtime;
//! nothing downstream of [`Engine::cpu`] can execute. Integration tests
//! guard on `artifacts/` existing before touching the engine, so a stub
//! build still runs the whole pure-Rust test suite.

use std::path::{Path, PathBuf};

use anyhow::{bail, Result};

use crate::model::manifest::Manifest;

const STUB_MSG: &str =
    "PJRT runtime not available: this binary was built without the `pjrt` \
     feature (requires the xla/xla_extension toolchain). Rebuild with \
     `cargo build --features pjrt`.";

/// Placeholder for an on-device literal (never constructed in stub builds).
pub struct Literal(());

/// The PJRT client stub.
pub struct Engine {
    _private: (),
}

impl Engine {
    pub fn cpu() -> Result<Self> {
        bail!(STUB_MSG)
    }

    pub fn platform(&self) -> String {
        unreachable!("stub Engine cannot be constructed")
    }

    /// Whether models loaded by this engine may be driven from multiple
    /// threads. The stub's types are plain data (`Send + Sync`), so a
    /// Send-safe CPU engine with this surface lets the round engine shard
    /// client execution across the thread pool (see `fl::round`).
    pub fn is_send_safe(&self) -> bool {
        true
    }

    pub fn load_hlo_text(&self, _path: &Path) -> Result<Executable> {
        bail!(STUB_MSG)
    }

    pub fn load_model(&self, _dir: &Path) -> Result<LoadedModel> {
        bail!(STUB_MSG)
    }
}

/// A compiled artifact (stub).
pub struct Executable {
    pub name: String,
    _private: (),
}

impl Executable {
    pub fn run(&self, _args: &[Literal]) -> Result<Vec<Literal>> {
        bail!(STUB_MSG)
    }
}

pub fn lit_f32(_data: &[f32], _dims: &[i64]) -> Result<Literal> {
    bail!(STUB_MSG)
}

pub fn lit_i32(_data: &[i32], _dims: &[i64]) -> Result<Literal> {
    bail!(STUB_MSG)
}

pub fn lit_f32_scalar(_x: f32) -> Literal {
    unreachable!("stub build: literals cannot be constructed")
}

pub fn lit_i32_scalar(_x: i32) -> Literal {
    unreachable!("stub build: literals cannot be constructed")
}

pub fn to_f32_vec(_lit: &Literal) -> Result<Vec<f32>> {
    bail!(STUB_MSG)
}

pub fn to_i32_vec(_lit: &Literal) -> Result<Vec<i32>> {
    bail!(STUB_MSG)
}

pub fn to_f32_scalar(_lit: &Literal) -> Result<f32> {
    bail!(STUB_MSG)
}

/// The bound artifact set for one model size (stub — never constructed).
pub struct LoadedModel {
    pub dir: PathBuf,
    pub manifest: Manifest,
    _private: (),
}

/// Outputs of one OMC training step.
pub struct OmcStepOut {
    pub tildes: Vec<Vec<f32>>,
    pub s: Vec<f32>,
    pub b: Vec<f32>,
    pub loss: f32,
}

/// Outputs of one FP32 training step.
pub struct Fp32StepOut {
    pub params: Vec<Vec<f32>>,
    pub loss: f32,
}

/// Outputs of one eval step.
pub struct EvalOut {
    pub loss: f32,
    /// greedy framewise predictions, row-major [batch, seq_len]
    pub pred: Vec<i32>,
}

impl LoadedModel {
    pub fn num_vars(&self) -> usize {
        self.manifest.num_vars()
    }

    /// See [`Engine::is_send_safe`]: stub models are plain data, so the
    /// round engine may shard client execution across threads.
    pub fn is_send_safe(&self) -> bool {
        true
    }

    pub fn warmup(&self, _fp32_baseline: bool, _use_pvt: bool) -> Result<()> {
        bail!(STUB_MSG)
    }

    pub fn run_init(&self, _seed: i32) -> Result<Vec<Vec<f32>>> {
        bail!(STUB_MSG)
    }

    pub fn run_train_fp32(
        &self,
        _params: &[Vec<f32>],
        _x: &[f32],
        _y: &[i32],
        _lr: f32,
    ) -> Result<Fp32StepOut> {
        bail!(STUB_MSG)
    }

    #[allow(clippy::too_many_arguments)]
    pub fn run_train_omc(
        &self,
        _use_pvt: bool,
        _tildes: &[Vec<f32>],
        _s: &[f32],
        _b: &[f32],
        _mask: &[f32],
        _x: &[f32],
        _y: &[i32],
        _lr: f32,
        _exp_bits: u32,
        _mant_bits: u32,
    ) -> Result<OmcStepOut> {
        bail!(STUB_MSG)
    }

    pub fn run_eval(&self, _params: &[Vec<f32>], _x: &[f32], _y: &[i32]) -> Result<EvalOut> {
        bail!(STUB_MSG)
    }
}
