//! PJRT runtime — loading and executing the AOT artifacts.
//!
//! Wraps the `xla` crate (PJRT C API, CPU plugin): HLO **text** →
//! `HloModuleProto` → compile → execute. One compiled executable per
//! artifact; Python never runs here.

pub mod engine;

pub use engine::{Engine, LoadedModel};
