//! Execution runtimes: the PJRT engine for the AOT artifacts, and the
//! pure-Rust native backend.
//!
//! Two backends implement the same model surface (`Engine::cpu` →
//! `load_model` → `run_init` / `run_train_fp32` / `run_train_omc` /
//! `run_eval`):
//!
//! * **PJRT** (`--features pjrt`) — wraps the `xla` crate (PJRT C API, CPU
//!   plugin): HLO **text** → `HloModuleProto` → compile → execute, one
//!   compiled executable per artifact. Python never runs here. Its
//!   executables are `!Send`, so the round engine pins client training to
//!   the engine thread.
//! * **Native** ([`native`]) — a deterministic pure-Rust MLP selected by
//!   `native:` model dirs (`native:tiny`, `native:small`). Available in
//!   every build, needs no artifacts, and is `Send`-safe — the backend the
//!   sweep smoke tier, CI goldens, and the sharded round dispatch run on.
//!
//! Default (non-`pjrt`) builds get `engine_stub.rs`, which executes
//! `native:` models and returns a clear error for artifact-backed ones.

#[cfg(feature = "pjrt")]
pub mod engine;

#[cfg(not(feature = "pjrt"))]
#[path = "engine_stub.rs"]
pub mod engine;

pub mod gemm;
pub mod native;

pub use engine::{Engine, LoadedModel};

/// Outputs of one OMC training step (shared by both backends).
pub struct OmcStepOut {
    /// re-quantized values Ṽ′, one `Vec` per variable
    pub tildes: Vec<Vec<f32>>,
    /// per-variable transform scales
    pub s: Vec<f32>,
    /// per-variable transform biases
    pub b: Vec<f32>,
    /// mean training loss of the step
    pub loss: f32,
}

/// Outputs of one FP32 training step (shared by both backends).
pub struct Fp32StepOut {
    /// updated raw parameters
    pub params: Vec<Vec<f32>>,
    /// mean training loss of the step
    pub loss: f32,
}

/// Outputs of one eval step (shared by both backends).
pub struct EvalOut {
    /// mean framewise negative log-likelihood
    pub loss: f32,
    /// greedy framewise predictions, row-major `[batch, seq_len]`
    pub pred: Vec<i32>,
}
