//! PJRT runtime — loading and executing the AOT artifacts.
//!
//! Wraps the `xla` crate (PJRT C API, CPU plugin): HLO **text** →
//! `HloModuleProto` → compile → execute. One compiled executable per
//! artifact; Python never runs here.
//!
//! The `xla` bindings are only present behind the `pjrt` feature; default
//! builds get `engine_stub.rs`, an API-identical stub whose constructors
//! error at runtime (integration tests skip themselves when `artifacts/`
//! is missing, so the pure-Rust suite runs either way).

#[cfg(feature = "pjrt")]
pub mod engine;

#[cfg(not(feature = "pjrt"))]
#[path = "engine_stub.rs"]
pub mod engine;

pub use engine::{Engine, LoadedModel};
