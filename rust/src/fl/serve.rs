//! Wall-clock sustained-service engine (`docs/SERVING.md`).
//!
//! Every other engine in this repo measures *virtual* time: `fl::round`
//! and [`fl::async_round`](crate::fl::async_round) plan a deterministic
//! timeline and never touch the wall clock. This module is the "raw speed
//! under heavy traffic" proof point — a long-running, multi-threaded
//! serving loop where **real** concurrent client workers train against
//! epoch-published snapshots and a server thread folds their uplinks
//! through the same `StreamingAggregator` the planned engine uses.
//!
//! # Threading model
//!
//! One `std::thread::scope` holds the whole run:
//!
//! * the **server loop** (the calling thread) walks the planned commits:
//!   `begin_wave` → publish the snapshot → collect the wave's results from
//!   the uplink queue → `fold_commit` — the exact sequential verify/fold
//!   code of [`AsyncRoundEngine`], never a re-implementation;
//! * a **dispatcher** thread feeds `(seq, t)` work items in plan order,
//!   optionally paced to an open-loop arrival rate (`rate` dispatches/sec);
//! * `workers` **client workers** each loop: pop a work item, wait for its
//!   version on the [`SnapshotPublisher`] (one `Acquire` load in the steady
//!   state — no lock), assemble the downlink from an arena-pooled buffer,
//!   train, and push the result into the bounded uplink queue.
//!
//! # Determinism vs the planned reference
//!
//! The serving engine executes the *same plan* as `fl::async_round`, and
//! the server drain re-imposes task order on whatever order the worker
//! threads finished in before folding (fold order is drain order, which is
//! plan order). Client uploads are bit-identical per dispatch (RNG, nonce,
//! delta base are pure functions of `(seed, wave, cid)`), so the committed
//! parameter bytes are **bit-identical to the planned-timeline engine at
//! any worker count** — asserted by `rust/tests/serve_engine.rs` and the
//! `smoke-serve` CI leg. Only the wall-clock numbers (latency quantiles,
//! commits/sec) vary run to run.
//!
//! # Backpressure and admission control
//!
//! The uplink queue is bounded (`queue_depth`). A worker first `try_push`es
//! its result; on overflow the frame is *counted as rejected* (frames +
//! bytes — the admission-control accounting) and the worker then blocks
//! until the server drains a slot, modeling a client retrying until
//! admitted. Planned folds are therefore never lost — rejection is an
//! accounting event, not a drop — which is what keeps the wall-clock run
//! bit-identical to the reference. The shutdown **admission probe**
//! (`probe = true`) fills a queue to capacity and verifies the configured
//! overflow is rejected-and-accounted deterministically, so CI's rejection
//! liveness grep never goes vacuous on a run that happened not to contend.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

use anyhow::Result;

use crate::fl::async_round::{AsyncContext, AsyncRoundEngine, CommitOutcome};
use crate::fl::server::Server;
use crate::metrics::recorder::LatencyHistogram;
use crate::util::arena::ArenaStats;

#[cfg(not(feature = "pjrt"))]
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
#[cfg(not(feature = "pjrt"))]
use std::time::Instant;

#[cfg(not(feature = "pjrt"))]
use anyhow::Context;

#[cfg(not(feature = "pjrt"))]
use crate::fl::async_round::{
    assemble_downlink, dispatch_trains, run_planned_client, WaveExecution,
};
#[cfg(not(feature = "pjrt"))]
use crate::fl::client::{ClientResult, ClientScratch};
#[cfg(not(feature = "pjrt"))]
use crate::fl::round::downlink_nonce;
#[cfg(not(feature = "pjrt"))]
use crate::omc::store::{PublishedSnapshot, SnapshotPublisher, SnapshotReader};
#[cfg(not(feature = "pjrt"))]
use crate::util::arena::Arena;

// ---- configuration -------------------------------------------------------

/// Knobs of the wall-clock serving engine (`[serve]` TOML table).
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// drive the async phase through real worker threads against the wall
    /// clock (requires `async.enabled`)
    pub enabled: bool,
    /// client worker threads; `0` means "the machine's default worker
    /// count" (`util::threadpool::default_workers`)
    pub workers: usize,
    /// uplink queue capacity; `0` means "2 × the resolved async
    /// concurrency"
    pub queue_depth: usize,
    /// pool downlink/uplink frame buffers and client scratch across
    /// threads (`util::arena`); `false` is the A/B control arm
    pub arena: bool,
    /// open-loop dispatch rate (dispatches/sec); `0` = unpaced
    pub rate: f64,
    /// run the shutdown admission probe (deterministic nonzero rejection
    /// accounting for the CI liveness grep)
    pub probe: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            workers: 0,
            queue_depth: 0,
            arena: true,
            rate: 0.0,
            probe: true,
        }
    }
}

impl ServeConfig {
    /// Resolve the `0`-means-default knobs against the resolved async
    /// concurrency.
    pub fn resolved(&self, concurrency: usize) -> ServeConfig {
        let mut r = *self;
        if r.workers == 0 {
            r.workers = crate::util::threadpool::default_workers();
        }
        if r.queue_depth == 0 {
            r.queue_depth = (concurrency * 2).max(1);
        }
        r
    }

    /// Bounds-check the knobs (called by `ExperimentConfig::validate`).
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            self.rate.is_finite() && self.rate >= 0.0,
            "serve.rate must be finite and >= 0, got {}",
            self.rate
        );
        Ok(())
    }
}

// ---- bounded MPSC queue --------------------------------------------------

struct QueueState<T> {
    items: VecDeque<T>,
    closed: bool,
    peak: usize,
}

/// Bounded multi-producer / single-consumer queue with explicit admission
/// control: `try_push` rejects on overflow (the accounting hook), blocking
/// `push` waits for a slot, `close` wakes everyone for shutdown.
pub(crate) struct BoundedQueue<T> {
    state: Mutex<QueueState<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    cap: usize,
}

impl<T> BoundedQueue<T> {
    pub(crate) fn new(cap: usize) -> Self {
        assert!(cap >= 1, "queue capacity must be >= 1");
        Self {
            state: Mutex::new(QueueState {
                items: VecDeque::with_capacity(cap),
                closed: false,
                peak: 0,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            cap,
        }
    }

    /// Admit `item` if a slot is free; `Err(item)` when full or closed.
    pub(crate) fn try_push(&self, item: T) -> std::result::Result<(), T> {
        let mut s = self.state.lock().unwrap();
        if s.closed || s.items.len() == self.cap {
            return Err(item);
        }
        s.items.push_back(item);
        s.peak = s.peak.max(s.items.len());
        drop(s);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Block until admitted. Returns `false` (dropping `item`) only when
    /// the queue is closed — the shutdown path.
    pub(crate) fn push(&self, item: T) -> bool {
        let mut s = self.state.lock().unwrap();
        loop {
            if s.closed {
                return false;
            }
            if s.items.len() < self.cap {
                s.items.push_back(item);
                s.peak = s.peak.max(s.items.len());
                drop(s);
                self.not_empty.notify_one();
                return true;
            }
            s = self.not_full.wait(s).unwrap();
        }
    }

    /// Block until an item arrives; `None` once closed *and* drained.
    pub(crate) fn pop(&self) -> Option<T> {
        let mut s = self.state.lock().unwrap();
        loop {
            if let Some(item) = s.items.pop_front() {
                drop(s);
                self.not_full.notify_one();
                return Some(item);
            }
            if s.closed {
                return None;
            }
            s = self.not_empty.wait(s).unwrap();
        }
    }

    /// Close the queue: pending items stay poppable, pushes fail, blocked
    /// threads wake.
    pub(crate) fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Deepest fill observed (the report's queue-depth number).
    pub(crate) fn peak_depth(&self) -> usize {
        self.state.lock().unwrap().peak
    }
}

// ---- the engine ----------------------------------------------------------

/// Wall-clock facts of one serving run (everything here is measured, not
/// simulated — unlike `CommitRecord`, none of it may appear in golden
/// summaries).
#[derive(Clone, Debug)]
pub struct ServeReport {
    /// commits performed
    pub commits: usize,
    /// worker threads driven
    pub workers: usize,
    /// uplink queue capacity
    pub queue_depth: usize,
    /// wall-clock seconds for the whole run
    pub wall_s: f64,
    /// server→client bytes across the run
    pub down_bytes: usize,
    /// client→server bytes across the run
    pub up_bytes: usize,
    /// uplink frames delivered through the queue (trained dispatches)
    pub uplinks: usize,
    /// p50 uplink service latency, seconds (downlink assembly → enqueued)
    pub uplink_p50_s: f64,
    /// p99 uplink service latency, seconds
    pub uplink_p99_s: f64,
    /// deepest uplink-queue fill observed
    pub queue_peak_depth: usize,
    /// uplink frames rejected on first admission (then re-admitted after
    /// blocking — planned folds are never lost)
    pub queue_rejected_frames: u64,
    /// bytes of those rejected frames
    pub queue_rejected_bytes: u64,
    /// frames the shutdown admission probe rejected (deterministic;
    /// zero when `probe = false`)
    pub probe_rejected_frames: u64,
    /// frame/byte-buffer arena counters (downlink + recycled uplink wires)
    pub frame_arena: ArenaStats,
    /// client-scratch arena counters
    pub scratch_arena: ArenaStats,
}

impl ServeReport {
    /// Commits per wall-clock second.
    pub fn commits_per_sec(&self) -> f64 {
        self.commits as f64 / self.wall_s.max(1e-9)
    }

    /// Transport bytes (both directions) per wall-clock second.
    pub fn bytes_per_sec(&self) -> f64 {
        (self.down_bytes + self.up_bytes) as f64 / self.wall_s.max(1e-9)
    }

    /// Total rejected-and-accounted admissions (runtime + probe) — the CI
    /// liveness-grep quantity.
    pub fn rejected_total(&self) -> u64 {
        self.queue_rejected_frames + self.probe_rejected_frames
    }
}

/// One work item: dispatch `seq`, which is task index `t` of its wave.
#[cfg(not(feature = "pjrt"))]
#[derive(Clone, Copy, Debug)]
struct WorkItem {
    seq: usize,
    t: usize,
}

/// What a worker hands the server for one dispatch.
#[cfg(not(feature = "pjrt"))]
struct WorkerResult {
    /// task index within the wave
    t: usize,
    /// downlink frame bytes spent on this dispatch
    down_bytes: usize,
    /// `Ok(Some)` = trained, `Ok(None)` = downlink-only dispatch,
    /// `Err` = worker-side failure (shuts the run down)
    result: Result<Option<ClientResult>>,
}

/// The wall-clock serving engine: owns the planned [`AsyncRoundEngine`]
/// and drives it through real threads. Build with [`new`](Self::new), run
/// once with [`run`](Self::run).
pub struct ServeEngine {
    engine: AsyncRoundEngine,
    cfg: ServeConfig,
}

impl ServeEngine {
    /// Plan `commits` commits and build the engine. `cfg` is resolved
    /// against the context's async concurrency here.
    pub fn new(
        ctx: &AsyncContext<'_>,
        commits: usize,
        cfg: &ServeConfig,
    ) -> Result<Self> {
        let resolved = cfg.resolved(ctx.acfg.concurrency);
        resolved.validate()?;
        let mut engine = AsyncRoundEngine::plan(ctx, commits)?;
        // fold-consumed uplink wires flow back into the frame arena
        engine.set_recycle_uplinks(true);
        Ok(Self {
            engine,
            cfg: resolved,
        })
    }

    /// Commits planned for this run.
    pub fn commits_planned(&self) -> usize {
        self.engine.commits_planned()
    }

    /// The resolved serving knobs.
    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    /// Drive the whole run: spawn the dispatcher + workers, walk every
    /// planned commit on this thread, and call `on_commit` after each fold
    /// (stream metrics from it). Returns the wall-clock report.
    #[cfg(not(feature = "pjrt"))]
    pub fn run(
        &mut self,
        ctx: &AsyncContext<'_>,
        server: &mut Server,
        mut on_commit: impl FnMut(usize, &CommitOutcome) -> Result<()>,
    ) -> Result<ServeReport> {
        anyhow::ensure!(
            ctx.model.is_send_safe(),
            "the serving engine drives real worker threads and needs a \
             Send-safe backend (native:* models)"
        );
        let cfg = self.cfg;
        let commits = self.engine.commits_planned();
        let plan = self.engine.timeline_arc();
        let total_dispatches = plan.dispatches.len();

        let publisher = SnapshotPublisher::new();
        let stop = AtomicBool::new(false);
        let frame_arena: Arena<Vec<u8>> = Arena::with_enabled(cfg.arena);
        let scratch_arena: Arena<ClientScratch> = Arena::with_enabled(cfg.arena);
        // the work queue holds the whole plan so the dispatcher never
        // blocks behind workers; backpressure lives on the uplink queue
        let work_q: BoundedQueue<WorkItem> =
            BoundedQueue::new(total_dispatches.max(1));
        let uplink_q: BoundedQueue<WorkerResult> =
            BoundedQueue::new(cfg.queue_depth);
        let rejected_frames = AtomicU64::new(0);
        let rejected_bytes = AtomicU64::new(0);
        let delta_on = ctx.delta && ctx.integrity;
        let ring_depth = ctx.acfg.snapshot_ring;
        let specs = &ctx.model.manifest.variables;

        let mut totals = (0usize, 0usize, 0usize); // down, up, uplinks
        let mut hist = LatencyHistogram::new();
        let t0 = Instant::now();

        let served: Result<()> = std::thread::scope(|scope| {
            // ---- dispatcher: plan order, optionally paced ---------------
            let dispatcher = {
                let plan = std::sync::Arc::clone(&plan);
                let (stop, work_q) = (&stop, &work_q);
                scope.spawn(move || {
                    let mut per_version: Vec<usize> = Vec::new();
                    let t0 = Instant::now();
                    for d in plan.dispatches.iter() {
                        if stop.load(Ordering::Relaxed) {
                            return;
                        }
                        if cfg.rate > 0.0 {
                            // open-loop: dispatch i is due at i/rate sec;
                            // sleep in short slices so shutdown stays live
                            let due = d.seq as f64 / cfg.rate;
                            while t0.elapsed().as_secs_f64() < due {
                                if stop.load(Ordering::Relaxed) {
                                    return;
                                }
                                let left = due - t0.elapsed().as_secs_f64();
                                std::thread::sleep(
                                    std::time::Duration::from_secs_f64(
                                        left.clamp(0.0, 0.05),
                                    ),
                                );
                            }
                        }
                        if per_version.len() <= d.start_version {
                            per_version.resize(d.start_version + 1, 0);
                        }
                        let t = per_version[d.start_version];
                        per_version[d.start_version] += 1;
                        if !work_q.push(WorkItem { seq: d.seq, t }) {
                            return; // closed — shutdown
                        }
                    }
                })
            };

            // ---- client workers ----------------------------------------
            let worker_handles: Vec<_> = (0..cfg.workers)
                .map(|_| {
                    let plan = std::sync::Arc::clone(&plan);
                    let (stop, work_q, uplink_q) = (&stop, &work_q, &uplink_q);
                    let (publisher, frame_arena, scratch_arena) =
                        (&publisher, &frame_arena, &scratch_arena);
                    let (rejected_frames, rejected_bytes) =
                        (&rejected_frames, &rejected_bytes);
                    scope.spawn(move || -> LatencyHistogram {
                        let mut reader = SnapshotReader::new();
                        let mut hist = LatencyHistogram::new();
                        let mut cs = scratch_arena.acquire();
                        while let Some(item) = work_q.pop() {
                            if stop.load(Ordering::Relaxed) {
                                break;
                            }
                            let d = &plan.dispatches[item.seq];
                            let Some(snap) = reader.wait_for(
                                publisher,
                                d.start_version,
                                || stop.load(Ordering::Relaxed),
                            ) else {
                                break; // cancelled — shutdown
                            };
                            let started = Instant::now();
                            let trains = dispatch_trains(d);
                            let result = if snap.version != d.start_version {
                                Err(anyhow::anyhow!(
                                    "publication invariant broken: wave {} \
                                     saw version {}",
                                    d.start_version,
                                    snap.version
                                ))
                            } else {
                                let mask = ctx.policy.draw_mask(
                                    specs,
                                    ctx.seed,
                                    d.wave,
                                    d.cid as u64,
                                );
                                let nonce = ctx.integrity.then(|| {
                                    downlink_nonce(ctx.seed, d.wave, d.cid as u64)
                                });
                                let downlink = assemble_downlink(
                                    &snap.model,
                                    &snap.vals,
                                    &mask,
                                    frame_arena.acquire(),
                                    nonce,
                                );
                                let down_bytes = downlink.len();
                                let r = if trains {
                                    // serving rejects the sparse stage in
                                    // config validation: no residual state
                                    run_planned_client(
                                        ctx, d, &downlink, &mask, delta_on,
                                        ring_depth, &mut cs, None,
                                    )
                                    .map(Some)
                                } else {
                                    Ok(None)
                                };
                                frame_arena.release(downlink);
                                r.map(|r| (down_bytes, r))
                            };
                            let (down_bytes, result) = match result {
                                Ok((b, r)) => (b, Ok(r)),
                                Err(e) => (0, Err(e)),
                            };
                            if trains && result.is_ok() {
                                hist.record(started.elapsed().as_secs_f64());
                            }
                            let failed = result.is_err();
                            let wr = WorkerResult {
                                t: item.t,
                                down_bytes,
                                result,
                            };
                            // admission control: account the overflow, then
                            // block until admitted (a client retrying)
                            if let Err(wr) = uplink_q.try_push(wr) {
                                let bytes = wr
                                    .result
                                    .as_ref()
                                    .ok()
                                    .and_then(|o| o.as_ref())
                                    .map_or(0, |r| r.upload.len());
                                rejected_frames.fetch_add(1, Ordering::Relaxed);
                                rejected_bytes
                                    .fetch_add(bytes as u64, Ordering::Relaxed);
                                if !uplink_q.push(wr) {
                                    break; // closed — shutdown
                                }
                            }
                            if failed {
                                break; // the server initiates shutdown
                            }
                        }
                        scratch_arena.release(cs);
                        hist
                    })
                })
                .collect();

            // ---- server loop (this thread) -----------------------------
            let mut drive = || -> Result<()> {
                for v in 0..commits {
                    let (wave, snap) = self.engine.begin_wave(ctx, server)?;
                    debug_assert_eq!(wave, v);
                    publisher.publish(PublishedSnapshot {
                        version: v,
                        model: snap,
                        vals: self.engine.wave_vals().to_vec(),
                    });
                    let ntasks = self.engine.wave_tasks(v).len();
                    let mut slots: Vec<Option<WorkerResult>> =
                        (0..ntasks).map(|_| None).collect();
                    let mut filled = 0usize;
                    while filled < ntasks {
                        let wr = uplink_q.pop().context(
                            "uplink queue closed mid-wave (worker died?)",
                        )?;
                        anyhow::ensure!(
                            wr.t < ntasks && slots[wr.t].is_none(),
                            "duplicate or out-of-wave uplink (task {})",
                            wr.t
                        );
                        slots[wr.t] = Some(wr);
                        filled += 1;
                    }
                    // drain-imposed fold order: task order, exactly what
                    // run_commit produces inline
                    let mut results: Vec<(usize, ClientResult)> =
                        Vec::with_capacity(ntasks);
                    let mut down_bytes = 0usize;
                    for slot in slots {
                        let wr = slot.expect("filled == ntasks");
                        down_bytes += wr.down_bytes;
                        if let Some(r) = wr.result? {
                            results.push((wr.t, r));
                        }
                    }
                    let delivered = results.len();
                    let outcome = self.engine.fold_commit(
                        ctx,
                        server,
                        WaveExecution {
                            results,
                            down_bytes,
                        },
                    )?;
                    // recycle the fold-consumed uplink wires as future
                    // downlink frame buffers
                    for buf in self.engine.take_spent() {
                        frame_arena.release(buf);
                    }
                    totals.0 += outcome.down_bytes;
                    totals.1 += outcome.up_bytes;
                    totals.2 += delivered;
                    on_commit(v, &outcome)?;
                }
                Ok(())
            };
            let served = drive();

            // ---- shutdown: wake everything, then join -------------------
            stop.store(true, Ordering::Relaxed);
            work_q.close();
            uplink_q.close();
            publisher.wake_all();
            let mut panicked = false;
            for h in worker_handles {
                match h.join() {
                    Ok(h2) => hist.merge(&h2),
                    Err(_) => panicked = true,
                }
            }
            panicked |= dispatcher.join().is_err();
            anyhow::ensure!(!panicked, "a serving thread panicked");
            served
        });
        served?;
        let wall_s = t0.elapsed().as_secs_f64();

        // ---- admission probe: deterministic rejection accounting --------
        let mut probe_rejected = 0u64;
        if cfg.probe {
            let q: BoundedQueue<usize> = BoundedQueue::new(cfg.queue_depth);
            for i in 0..cfg.queue_depth {
                q.try_push(i).ok().expect("probe fill fits the capacity");
            }
            for i in 0..8usize {
                if q.try_push(i).is_err() {
                    probe_rejected += 1;
                }
            }
            anyhow::ensure!(
                probe_rejected == 8,
                "admission probe admitted past capacity ({probe_rejected}/8 \
                 rejected)"
            );
        }

        Ok(ServeReport {
            commits,
            workers: cfg.workers,
            queue_depth: cfg.queue_depth,
            wall_s,
            down_bytes: totals.0,
            up_bytes: totals.1,
            uplinks: totals.2,
            uplink_p50_s: hist.quantile(0.50),
            uplink_p99_s: hist.quantile(0.99),
            queue_peak_depth: uplink_q.peak_depth(),
            queue_rejected_frames: rejected_frames.load(Ordering::Relaxed),
            queue_rejected_bytes: rejected_bytes.load(Ordering::Relaxed),
            probe_rejected_frames: probe_rejected,
            frame_arena: frame_arena.stats(),
            scratch_arena: scratch_arena.stats(),
        })
    }

    /// PJRT executables are pinned to their creation thread (`!Send`), so
    /// the serving engine cannot run under the `pjrt` feature.
    #[cfg(feature = "pjrt")]
    pub fn run(
        &mut self,
        _ctx: &AsyncContext<'_>,
        _server: &mut Server,
        _on_commit: impl FnMut(usize, &CommitOutcome) -> Result<()>,
    ) -> Result<ServeReport> {
        anyhow::bail!(
            "the serving engine drives real worker threads and needs a \
             Send-safe backend — build without the `pjrt` feature"
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_resolves_zero_knobs() {
        let cfg = ServeConfig::default();
        assert!(!cfg.enabled);
        assert!(cfg.arena);
        assert!(cfg.probe);
        let r = cfg.resolved(6);
        assert!(r.workers >= 1);
        assert_eq!(r.queue_depth, 12);
        // explicit knobs pass through
        let cfg = ServeConfig {
            workers: 3,
            queue_depth: 5,
            ..ServeConfig::default()
        };
        let r = cfg.resolved(6);
        assert_eq!((r.workers, r.queue_depth), (3, 5));
        r.validate().unwrap();
        let bad = ServeConfig {
            rate: f64::NAN,
            ..ServeConfig::default()
        };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn queue_is_fifo_and_bounded() {
        let q: BoundedQueue<usize> = BoundedQueue::new(2);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        // full: admission control rejects, nothing is lost by the caller
        assert_eq!(q.try_push(3), Err(3));
        assert_eq!(q.peak_depth(), 2);
        assert_eq!(q.pop(), Some(1));
        q.try_push(3).unwrap();
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
    }

    #[test]
    fn closed_queue_drains_then_ends() {
        let q: BoundedQueue<usize> = BoundedQueue::new(4);
        q.try_push(7).unwrap();
        q.close();
        assert_eq!(q.try_push(8), Err(8));
        assert!(!q.push(9));
        assert_eq!(q.pop(), Some(7));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn blocking_push_waits_for_a_slot() {
        use std::sync::Arc;
        let q: Arc<BoundedQueue<usize>> = Arc::new(BoundedQueue::new(1));
        q.try_push(0).unwrap();
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || q2.push(1));
        // the producer is blocked until this pop frees the slot
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert_eq!(q.pop(), Some(0));
        assert!(h.join().unwrap());
        assert_eq!(q.pop(), Some(1));
    }

    #[test]
    fn close_unblocks_producers_and_consumers() {
        use std::sync::Arc;
        let q: Arc<BoundedQueue<usize>> = Arc::new(BoundedQueue::new(1));
        q.try_push(0).unwrap();
        let qp = Arc::clone(&q);
        let producer = std::thread::spawn(move || qp.push(1));
        let qc = Arc::clone(&q);
        let closer = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(20));
            qc.close();
        });
        assert!(!producer.join().unwrap()); // woken by close, not admitted
        closer.join().unwrap();
        assert_eq!(q.pop(), Some(0)); // pending item still drains
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn report_rates() {
        let r = ServeReport {
            commits: 10,
            workers: 4,
            queue_depth: 8,
            wall_s: 2.0,
            down_bytes: 1000,
            up_bytes: 3000,
            uplinks: 40,
            uplink_p50_s: 0.001,
            uplink_p99_s: 0.002,
            queue_peak_depth: 5,
            queue_rejected_frames: 3,
            queue_rejected_bytes: 99,
            probe_rejected_frames: 8,
            frame_arena: ArenaStats::default(),
            scratch_arena: ArenaStats::default(),
        };
        assert!((r.commits_per_sec() - 5.0).abs() < 1e-12);
        assert!((r.bytes_per_sec() - 2000.0).abs() < 1e-12);
        assert_eq!(r.rejected_total(), 11);
    }
}
