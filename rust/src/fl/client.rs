//! One simulated client's round of work (paper Sec. 2.1, Fig. 1).
//!
//! The client *only ever holds the compressed model* plus transient
//! decompressed copies: it receives the downlink wire bytes, decodes them to
//! the quantized values Ṽ and PVT scalars, feeds those straight into the
//! lowered OMC training graph (which decompresses on the fly, updates, and
//! re-compresses), and re-packs the returned Ṽ' for the uplink. The FP32
//! baseline path stores and ships raw f32.
//!
//! # Zero-alloc round contract (§Perf)
//!
//! In the steady state, `run_client_round` performs **no per-variable heap
//! allocation for codec buffers**:
//!
//! * the downlink is decoded *streaming* (`codec::for_each_var`) straight
//!   into [`ClientScratch`] buffers whose capacity persists across rounds —
//!   no `CompressedModel`, no per-variable `Vec` churn;
//! * the uplink is emitted *streaming* (`WireWriter::packed_values` /
//!   `raw`) — quantized variables are bit-packed directly into the frame
//!   buffer, never through an intermediate payload `Vec`;
//! * the only steady-state allocation is the single upload frame handed to
//!   the caller in [`ClientResult`] (it is consumed by the server).
//!
//! The `fl_integration` tests exercise this path end-to-end; the buffer
//! reuse itself is unit-tested in `rust/tests/omc_kernels.rs`.

use anyhow::{Context, Result};

use crate::data::synth::Domain;
use crate::omc::codec::{self, VarView, WireWriter};
use crate::omc::format::FloatFormat;
use crate::omc::sparse::{self, ClientResidual, SparseMode, SparseTrainParams};
use crate::omc::store::StoredVar;
use crate::omc::transform::{self, Pvt};
use crate::runtime::engine::LoadedModel;
use crate::util::rng::Xoshiro256pp;
use crate::util::threadpool;

/// Static client-side hyper-parameters for a round.
#[derive(Clone, Copy, Debug)]
pub struct ClientTrainConfig {
    pub lr: f32,
    pub local_steps: usize,
    pub format: FloatFormat,
    pub use_pvt: bool,
    /// FP32 baseline path (no OMC artifacts involved)
    pub fp32_baseline: bool,
    /// `Some(nonce)` ⇒ frame the uplink in the checksummed v2 wire layout
    /// carrying this nonce (wire integrity on); `None` keeps the
    /// byte-identical v1 frames.
    pub uplink_nonce: Option<u64>,
    /// `Some(version)` ⇒ frame the uplink as a v3 delta frame: packed
    /// variables are XOR-coded against the downlink payload this client
    /// just received (the committed bytes both sides hold), tagged with
    /// the shared `version` for the server's ack handshake. Requires
    /// `uplink_nonce` (delta frames are always checksummed); ignored
    /// without it.
    pub delta_base: Option<u64>,
    /// `Some(params)` ⇒ sparsify masked variables on the uplink: the
    /// error-corrected update (new values − downlink values + carried
    /// residual) is reduced to top-k / random-k coordinates shipped as
    /// tag-3 sparse records, the rest banked in the returned
    /// [`ClientResidual`]. Requires `uplink_nonce` (sparse records are
    /// only legal on checksummed frames); ignored without it. Takes
    /// precedence over the delta stage on masked variables (tag-3
    /// records are never delta-coded).
    pub sparse: Option<SparseTrainParams>,
}

/// What the client sends back.
pub struct ClientResult {
    /// uplink wire payload (compressed model)
    pub upload: Vec<u8>,
    /// mean training loss over local steps
    pub loss: f64,
    /// peak parameter-store bytes observed on the client (Sec. 3.4)
    pub peak_param_bytes: usize,
    /// uplink bytes the delta stage saved vs verbatim records (0 on
    /// verbatim frames)
    pub delta_saved: usize,
    /// uplink bytes the sparse stage saved vs dense packed records (0
    /// when sparsification is off)
    pub sparse_saved: usize,
    /// coordinates selected for the uplink across sparsified variables
    pub sparse_selected: u64,
    /// total coordinates across sparsified variables (denominator for
    /// the sparsity metric)
    pub sparse_total: u64,
    /// squared L2 mass of the new residual (f64 accumulation)
    pub sparse_residual_sq: f64,
    /// the error-feedback residual to carry into this client's next
    /// round (`Some` iff sparsification ran)
    pub residual: Option<ClientResidual>,
}

/// Reusable per-client working set: the decoded-variable buffers and PVT
/// scalar vectors whose capacity survives across clients and rounds. One
/// instance per execution thread: the PJRT backend pins client training to
/// the engine thread (one scratch), while a Send-safe engine runs shards
/// of the cohort in parallel, one scratch per worker (`RoundScratch` owns
/// the persistent set — see `fl::round`).
#[derive(Default)]
pub struct ClientScratch {
    /// decoded variable values, one buffer per manifest variable
    vals: Vec<Vec<f32>>,
    s: Vec<f32>,
    b: Vec<f32>,
    /// byte span `(offset, len)` of each packed variable's payload inside
    /// the downlink frame — the delta stage's per-variable base slices
    /// (`None` for raw variables)
    spans: Vec<Option<(usize, usize)>>,
    /// bitpacker working buffers for the v3 uplink
    delta: codec::DeltaScratch,
    /// decompressed downlink values per masked variable (filled only
    /// when sparsification is on — the reference point for the
    /// error-corrected update)
    down_vals: Vec<Vec<f32>>,
    /// dense post-training values for the variable being sparsified
    dense: Vec<f32>,
    /// error-corrected update buffer (update + carried residual)
    err: Vec<f32>,
    /// selected coordinate indices (ascending)
    idx: Vec<u32>,
    /// partial Fisher–Yates working set for random-k
    randk: Vec<u32>,
    /// gathered selected values, writer input
    gathered: Vec<f32>,
}

impl ClientScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// Pooling a scratch across serving workers (`util::arena`) needs no reset:
/// `run_client_round` length-manages every buffer itself (`resize_with` +
/// `clear` on entry) — retained capacity is exactly the point of reuse.
impl crate::util::arena::Reclaim for ClientScratch {
    fn reclaim(&mut self) {}
}

/// Run one client round.
///
/// `download` is the server's wire payload for this client; `mask` is the
/// PPQ selection the server drew for it (needed by the graph to know which
/// variables to re-quantize). `scratch` holds the reused codec buffers —
/// pass the same instance every round for the zero-alloc steady state.
/// `residual` is the error-feedback residual this client banked on its
/// previous participation (`None` when sparsification is off or the
/// client is fresh); the updated residual comes back in the result.
#[allow(clippy::too_many_arguments)]
pub fn run_client_round(
    model: &LoadedModel,
    domain: &Domain,
    speakers: &[usize],
    download: &[u8],
    mask: &[f32],
    cfg: ClientTrainConfig,
    rng: &mut Xoshiro256pp,
    scratch: &mut ClientScratch,
    residual: Option<&ClientResidual>,
) -> Result<ClientResult> {
    let mc = &model.manifest.config;
    let nvars = model.num_vars();
    scratch.vals.resize_with(nvars, Vec::new);
    scratch.s.clear();
    scratch.b.clear();
    scratch.spans.clear();

    // Streaming downlink decode into the scratch buffers. The baseline
    // consumes decompressed values V̄; the OMC graph consumes (Ṽ, s, b).
    // Packed payload spans are recorded so the uplink's delta stage can
    // XOR against the exact downlink bytes (which outlive the round).
    let mut down_param_bytes = 0usize;
    let down_base = download.as_ptr() as usize;
    let vals = &mut scratch.vals;
    let (s, b) = (&mut scratch.s, &mut scratch.b);
    let spans = &mut scratch.spans;
    let decoded = codec::for_each_var(download, |i, view| {
        anyhow::ensure!(i < nvars, "downlink has more vars than the model");
        down_param_bytes += view.memory_bytes();
        if cfg.fp32_baseline {
            view.decompress_into(&mut vals[i]);
        } else {
            view.tilde_into(&mut vals[i]);
        }
        let pvt = match view {
            VarView::Packed { pvt, .. } => pvt,
            VarView::Raw { .. } => Pvt::IDENTITY,
        };
        spans.push(match view {
            VarView::Packed { payload, .. } => {
                // payload borrows from `download` on v1/v2 frames, so the
                // span is plain pointer arithmetic within the same buffer
                let off = payload.as_ptr() as usize - down_base;
                debug_assert!(off + payload.len() <= download.len());
                Some((off, payload.len()))
            }
            VarView::Raw { .. } => None,
        });
        s.push(pvt.s);
        b.push(pvt.b);
        Ok(())
    })
    .context("decoding downlink payload")?;
    anyhow::ensure!(
        decoded == nvars,
        "downlink has {decoded} vars, model expects {nvars}"
    );
    // the client's resident state: compressed payload only
    let mut peak_param_bytes = down_param_bytes;

    if cfg.fp32_baseline {
        // baseline: raw parameters, plain SGD steps
        let mut loss_sum = 0.0f64;
        for _ in 0..cfg.local_steps {
            let batch = domain.batch(speakers, mc.batch, rng);
            let out =
                model.run_train_fp32(&scratch.vals, &batch.x, &batch.y, cfg.lr)?;
            scratch.vals = out.params;
            loss_sum += out.loss as f64;
        }
        let up_bytes: usize = scratch.vals.iter().map(|v| v.len() * 4).sum();
        let mut w = uplink_writer(cfg, up_bytes + 5 * nvars, nvars);
        for v in &scratch.vals {
            w.raw(v);
        }
        peak_param_bytes = peak_param_bytes.max(up_bytes);
        return Ok(ClientResult {
            upload: w.finish(),
            loss: loss_sum / cfg.local_steps.max(1) as f64,
            peak_param_bytes,
            delta_saved: 0,
            sparse_saved: 0,
            sparse_selected: 0,
            sparse_total: 0,
            sparse_residual_sq: 0.0,
            residual: None,
        });
    }

    // Sparse uplink needs the dense values the client *received* — the
    // reference point for the error-corrected update. Reconstruct them
    // from the already-decoded tildes before training overwrites them
    // (bit-identical to `decompress_into`: the fused unpack+affine and
    // unpack-then-`transform::apply` paths are bit-exact by contract).
    let sp = cfg.sparse.filter(|_| cfg.uplink_nonce.is_some());
    if sp.is_some() {
        scratch.down_vals.resize_with(nvars, Vec::new);
        for (i, t) in scratch.vals.iter().enumerate() {
            let dv = &mut scratch.down_vals[i];
            if mask[i] > 0.5 {
                dv.resize(t.len(), 0.0);
                let pvt = Pvt {
                    s: scratch.s[i],
                    b: scratch.b[i],
                };
                transform::apply(pvt, t, dv);
            } else {
                dv.clear();
            }
        }
    }

    // OMC path: the graph consumes (Ṽ, s, b, mask) and returns the same
    // triple re-quantized. Transient decoded copies live only inside this
    // loop, mirroring Fig. 1's dashed-border variables.
    let mut loss_sum = 0.0f64;
    for _ in 0..cfg.local_steps {
        let batch = domain.batch(speakers, mc.batch, rng);
        let out = model.run_train_omc(
            cfg.use_pvt,
            &scratch.vals,
            &scratch.s,
            &scratch.b,
            mask,
            &batch.x,
            &batch.y,
            cfg.lr,
            cfg.format.exp_bits,
            cfg.format.mant_bits,
        )?;
        scratch.vals = out.tildes;
        scratch.s = out.s;
        scratch.b = out.b;
        loss_sum += out.loss as f64;
    }

    // Streaming uplink: quantized vars bit-pack straight into the frame,
    // the rest ship raw. No per-variable buffers. With sparsification on,
    // masked variables ship tag-3 sparse records of the error-corrected
    // update instead of dense packed values.
    let mut up_param_bytes = 0usize;
    let mut cap = 0usize;
    for (i, t) in scratch.vals.iter().enumerate() {
        cap += if mask[i] > 0.5 {
            match sp {
                Some(p) => {
                    // tag-3 worst case: 27 header + ~4.1k index bytes
                    let k = sparse::select_count(t.len(), p.fraction);
                    27 + 5 * k + cfg.format.packed_bytes(k)
                }
                None => 19 + cfg.format.packed_bytes(t.len()),
            }
        } else {
            5 + 4 * t.len()
        };
    }
    let mut w = uplink_writer(cfg, cap, nvars);
    let delta_on = cfg.delta_base.is_some() && cfg.uplink_nonce.is_some();
    let mut sparse_selected = 0u64;
    let mut sparse_total = 0u64;
    let mut new_residual: Option<ClientResidual> = None;
    for (i, t) in scratch.vals.iter().enumerate() {
        if mask[i] > 0.5 {
            let pvt = Pvt {
                s: scratch.s[i],
                b: scratch.b[i],
            };
            if let Some(p) = sp {
                let n = t.len();
                // dense post-training values, then the error-corrected
                // update e = (v_new − v_down) + r_prev (f32, like the
                // training arithmetic itself)
                scratch.dense.resize(n, 0.0);
                transform::apply(pvt, t, &mut scratch.dense);
                let err = &mut scratch.err;
                err.clear();
                err.extend(
                    scratch
                        .dense
                        .iter()
                        .zip(&scratch.down_vals[i])
                        .map(|(nw, dw)| nw - dw),
                );
                if let Some(r) = residual.and_then(|r| r.var(i)) {
                    if r.len() == n {
                        for (e, &rv) in err.iter_mut().zip(r) {
                            *e += rv;
                        }
                    }
                }
                let k = sparse::select_count(n, p.fraction);
                match p.mode {
                    SparseMode::TopK => {
                        sparse::select_topk(err, k, &mut scratch.idx)
                    }
                    SparseMode::RandK => sparse::select_randk(
                        n,
                        k,
                        sparse::var_seed(p.key, i),
                        &mut scratch.idx,
                        &mut scratch.randk,
                    ),
                }
                sparse::gather_into(err, &scratch.idx, &mut scratch.gathered);
                let saved0 = w.sparse_saved();
                w.sparse_values(
                    &scratch.gathered,
                    &scratch.idx,
                    n,
                    cfg.format,
                    cfg.use_pvt,
                );
                up_param_bytes += (19 + cfg.format.packed_bytes(n))
                    .saturating_sub(w.sparse_saved() - saved0);
                // bank the unselected mass: e with the shipped
                // coordinates zeroed — a bitwise partition of e
                for &j in &scratch.idx {
                    err[j as usize] = 0.0;
                }
                sparse_selected += scratch.idx.len() as u64;
                sparse_total += n as u64;
                new_residual
                    .get_or_insert_with(|| ClientResidual::new(nvars))
                    .set(i, err.clone());
            } else {
                // the base is this variable's own downlink payload — valid
                // only when the downlink packed it to the same byte length
                let base = if delta_on {
                    scratch.spans[i].and_then(|(off, len)| {
                        (len == cfg.format.packed_bytes(t.len()))
                            .then(|| &download[off..off + len])
                    })
                } else {
                    None
                };
                if delta_on {
                    w.packed_values_delta(
                        t,
                        cfg.format,
                        pvt,
                        base,
                        &mut scratch.delta,
                    )
                } else {
                    w.packed_values(t, cfg.format, pvt)
                }
                .map_err(|e| anyhow::anyhow!("uplink pack var {i}: {e}"))?;
                up_param_bytes += cfg.format.packed_bytes(t.len()) + 8;
            }
        } else {
            w.raw(t);
            up_param_bytes += 4 * t.len();
        }
    }
    peak_param_bytes = peak_param_bytes.max(up_param_bytes);
    let delta_saved = w.delta_saved();
    let sparse_saved = w.sparse_saved();
    let sparse_residual_sq =
        new_residual.as_ref().map_or(0.0, |r| r.norm_sq());
    Ok(ClientResult {
        upload: w.finish(),
        loss: loss_sum / cfg.local_steps.max(1) as f64,
        peak_param_bytes,
        delta_saved,
        sparse_saved,
        sparse_selected,
        sparse_total,
        sparse_residual_sq,
        residual: new_residual,
    })
}

/// Start the uplink frame in the layout `cfg` asks for, sizing the
/// reserve for the extra v2/v3 overhead (up to 20 header + 4 CRC bytes
/// per var) so the zero-alloc steady state holds on every path.
fn uplink_writer(cfg: ClientTrainConfig, cap: usize, nvars: usize) -> WireWriter {
    match (cfg.uplink_nonce, cfg.delta_base) {
        (Some(nonce), Some(bv)) => {
            WireWriter::with_delta(cap + 20 + 4 * nvars, nonce, bv)
        }
        (Some(nonce), None) => {
            WireWriter::with_integrity(cap + 12 + 4 * nvars, nonce)
        }
        (None, _) => WireWriter::with_capacity(cap),
    }
}

/// Build the downlink payload for one client: compress the server's global
/// model according to the client's PPQ mask (streaming fused pipeline —
/// no intermediate `CompressedModel`).
pub fn make_downlink(
    global: &[Vec<f32>],
    mask: &[f32],
    format: FloatFormat,
    use_pvt: bool,
) -> Vec<u8> {
    let cap: usize = global
        .iter()
        .zip(mask)
        .map(|(v, &m)| {
            if m > 0.5 && !format.is_fp32() {
                19 + format.packed_bytes(v.len())
            } else {
                5 + 4 * v.len()
            }
        })
        .sum();
    let mut w = WireWriter::with_capacity(cap);
    for (v, &m) in global.iter().zip(mask) {
        if m > 0.5 && !format.is_fp32() {
            w.compress_values(v, format, use_pvt);
        } else {
            w.raw(v);
        }
    }
    w.finish()
}

/// Per-round downlink compression cache (§Perf).
///
/// The quantize + PVT-fit + bit-pack of a given variable is identical for
/// every client whose mask selects it, so the server compresses each
/// variable ONCE per round (in parallel over the thread pool) and
/// per-client payloads are assembled from borrowed parts (framing + memcpy
/// only). With 8 clients/round this cuts the downlink build cost ~8x.
pub struct DownlinkCache {
    /// compressed version of each variable (None when format is FP32)
    packed: Vec<Option<StoredVar>>,
}

impl DownlinkCache {
    pub fn build(
        global: &[Vec<f32>],
        format: FloatFormat,
        use_pvt: bool,
        workers: usize,
        any_selected: impl Fn(usize) -> bool,
    ) -> Self {
        let selected: Vec<bool> =
            (0..global.len()).map(any_selected).collect();
        let packed = threadpool::scope_map(global, workers, |i, v| {
            if format.is_fp32() || !selected[i] {
                None
            } else {
                Some(StoredVar::compress(v, format, use_pvt))
            }
        })
        .expect("downlink compress worker panicked");
        Self { packed }
    }

    /// The cached per-variable packed payloads (`None` for FP32 /
    /// unselected variables) — the server-side half of the delta stage's
    /// shared base: `DeltaBase::from_packed_vars(round, cache.packed_vars())`
    /// views exactly the bytes every selected client received.
    pub fn packed_vars(&self) -> &[Option<StoredVar>] {
        &self.packed
    }

    /// Assemble one client's payload from the cache.
    pub fn assemble(&self, global: &[Vec<f32>], mask: &[f32]) -> Vec<u8> {
        self.assemble_into(global, mask, Vec::new())
    }

    /// [`assemble`](Self::assemble) into a recycled buffer (cleared first,
    /// capacity retained — the round loop reuses one buffer per client
    /// slot across rounds).
    pub fn assemble_into(
        &self,
        global: &[Vec<f32>],
        mask: &[f32],
        buf: Vec<u8>,
    ) -> Vec<u8> {
        self.assemble_frame(global, mask, buf, None)
    }

    /// [`assemble_into`](Self::assemble_into), choosing the wire layout:
    /// `Some(nonce)` emits a checksummed v2 frame (the integrity-on
    /// downlink path — the client decoder is version-agnostic, so this is
    /// transparent to `run_client_round`), `None` the classic v1 bytes.
    pub fn assemble_frame(
        &self,
        global: &[Vec<f32>],
        mask: &[f32],
        buf: Vec<u8>,
        nonce: Option<u64>,
    ) -> Vec<u8> {
        let cap: usize = global
            .iter()
            .zip(mask.iter())
            .enumerate()
            .map(|(i, (v, &m))| {
                if m > 0.5 {
                    self.packed[i]
                        .as_ref()
                        .map(|p| p.memory_bytes())
                        .unwrap_or(v.len() * 4)
                } else {
                    v.len() * 4
                }
            })
            .sum();
        let reserve = cap + 16 * global.len();
        let mut w = match nonce {
            Some(n) => WireWriter::with_buf_and_integrity(buf, reserve + 12, n),
            None => WireWriter::with_buf_and_capacity(buf, reserve),
        };
        for (i, v) in global.iter().enumerate() {
            match (&self.packed[i], mask[i] > 0.5) {
                (Some(p), true) => w.var(p),
                _ => w.raw(v),
            }
        }
        w.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::omc::codec;
    use crate::testkit::Gen;

    #[test]
    fn downlink_respects_mask_and_format() {
        let mut g = Gen::new(1);
        let global = vec![g.vec_normal(100, 0.1), g.vec_normal(50, 0.1)];
        let fmt: FloatFormat = "S1E3M7".parse().unwrap();
        let wire = make_downlink(&global, &[1.0, 0.0], fmt, true);
        let m = codec::decode(&wire).unwrap();
        assert!(m.vars[0].is_packed());
        assert!(!m.vars[1].is_packed());
        // fp32 format always ships raw
        let wire = make_downlink(&global, &[1.0, 1.0], FloatFormat::FP32, true);
        let m = codec::decode(&wire).unwrap();
        assert!(m.vars.iter().all(|v| !v.is_packed()));
    }

    #[test]
    fn downlink_size_scales_with_fraction() {
        let mut g = Gen::new(2);
        let global: Vec<Vec<f32>> =
            (0..10).map(|_| g.vec_normal(10_000, 0.1)).collect();
        let fmt: FloatFormat = "S1E3M7".parse().unwrap();
        let all = make_downlink(&global, &[1.0; 10], fmt, true).len();
        let none = make_downlink(&global, &[0.0; 10], fmt, true).len();
        let ratio = all as f64 / none as f64;
        assert!((ratio - 11.0 / 32.0).abs() < 0.02, "ratio {ratio}");
    }

    #[test]
    fn streaming_downlink_matches_storedvar_encoding() {
        // make_downlink now streams through the fused pipeline; the frame
        // must stay byte-identical to the old CompressedModel + encode path
        let mut g = Gen::new(3);
        let global = vec![
            g.vec_normal(700, 0.05),
            g.vec_normal(64, 1.0),
            g.vec_normal(333, 0.2),
        ];
        let mask = [1.0f32, 0.0, 1.0];
        let fmt: FloatFormat = "S1E3M7".parse().unwrap();
        let streamed = make_downlink(&global, &mask, fmt, true);
        let model = crate::omc::store::CompressedModel::new(
            global
                .iter()
                .zip(&mask)
                .map(|(v, &m)| {
                    if m > 0.5 && !fmt.is_fp32() {
                        StoredVar::compress(v, fmt, true)
                    } else {
                        StoredVar::raw(v.clone())
                    }
                })
                .collect(),
        );
        assert_eq!(streamed, codec::encode(&model));
    }

    #[test]
    fn cache_assemble_matches_make_downlink() {
        let mut g = Gen::new(4);
        let global: Vec<Vec<f32>> =
            (0..6).map(|_| g.vec_normal(1500, 0.05)).collect();
        let mask = [1.0f32, 0.0, 1.0, 1.0, 0.0, 1.0];
        let fmt: FloatFormat = "S1E4M14".parse().unwrap();
        for workers in [1, 4] {
            let cache =
                DownlinkCache::build(&global, fmt, true, workers, |i| mask[i] > 0.5);
            let assembled = cache.assemble(&global, &mask);
            assert_eq!(assembled, make_downlink(&global, &mask, fmt, true));
            // recycled-buffer variant is identical and reuses the allocation
            let buf = Vec::with_capacity(2 * assembled.len() + 1024);
            let ptr = buf.as_ptr();
            let again = cache.assemble_into(&global, &mask, buf);
            assert_eq!(again, assembled);
            assert_eq!(again.as_ptr(), ptr, "assemble_into must recycle");
        }
    }

    #[test]
    fn integrity_downlink_decodes_identically() {
        // the v2 assembly carries the same payload as v1 — clients decode
        // either transparently — and verifies end to end with its nonce
        let mut g = Gen::new(5);
        let global: Vec<Vec<f32>> =
            (0..4).map(|_| g.vec_normal(800, 0.05)).collect();
        let mask = [1.0f32, 0.0, 1.0, 0.0];
        let fmt: FloatFormat = "S1E3M7".parse().unwrap();
        let cache = DownlinkCache::build(&global, fmt, true, 1, |i| mask[i] > 0.5);
        let v1 = cache.assemble(&global, &mask);
        let v2 = cache.assemble_frame(&global, &mask, Vec::new(), Some(99));
        let info = codec::verify_frame(&v2).unwrap();
        assert_eq!(info.nonce, Some(99));
        assert_eq!(v2.len(), v1.len() + 12 + 4 * global.len());
        let a = codec::decode_decompressed(&v1).unwrap();
        let b = codec::decode_decompressed(&v2).unwrap();
        assert_eq!(a, b);
    }
}
