//! One simulated client's round of work (paper Sec. 2.1, Fig. 1).
//!
//! The client *only ever holds the compressed model* plus transient
//! decompressed copies: it receives the downlink wire bytes, decodes them to
//! the quantized values Ṽ and PVT scalars, feeds those straight into the
//! lowered OMC training graph (which decompresses on the fly, updates, and
//! re-compresses), and re-packs the returned Ṽ' for the uplink. The FP32
//! baseline path stores and ships raw f32.

use anyhow::{Context, Result};

use crate::data::synth::Domain;
use crate::omc::codec;
use crate::omc::format::FloatFormat;
use crate::omc::store::{CompressedModel, StoredVar};
use crate::omc::transform::Pvt;
use crate::runtime::engine::LoadedModel;
use crate::util::rng::Xoshiro256pp;

/// Static client-side hyper-parameters for a round.
#[derive(Clone, Copy, Debug)]
pub struct ClientTrainConfig {
    pub lr: f32,
    pub local_steps: usize,
    pub format: FloatFormat,
    pub use_pvt: bool,
    /// FP32 baseline path (no OMC artifacts involved)
    pub fp32_baseline: bool,
}

/// What the client sends back.
pub struct ClientResult {
    /// uplink wire payload (compressed model)
    pub upload: Vec<u8>,
    /// mean training loss over local steps
    pub loss: f64,
    /// peak parameter-store bytes observed on the client (Sec. 3.4)
    pub peak_param_bytes: usize,
}

/// Run one client round.
///
/// `download` is the server's wire payload for this client; `mask` is the
/// PPQ selection the server drew for it (needed by the graph to know which
/// variables to re-quantize).
#[allow(clippy::too_many_arguments)]
pub fn run_client_round(
    model: &LoadedModel,
    domain: &Domain,
    speakers: &[usize],
    download: &[u8],
    mask: &[f32],
    cfg: ClientTrainConfig,
    rng: &mut Xoshiro256pp,
) -> Result<ClientResult> {
    let mc = &model.manifest.config;
    let received = codec::decode(download).context("decoding downlink payload")?;
    anyhow::ensure!(
        received.num_vars() == model.num_vars(),
        "downlink has {} vars, model expects {}",
        received.num_vars(),
        model.num_vars()
    );
    // the client's resident state: compressed payload only
    let mut peak_param_bytes = received.memory_bytes();

    if cfg.fp32_baseline {
        // baseline: raw parameters, plain SGD steps
        let mut params = received.decompress_all();
        drop(received);
        let mut loss_sum = 0.0f64;
        for _ in 0..cfg.local_steps {
            let batch = domain.batch(speakers, mc.batch, rng);
            let out = model.run_train_fp32(&params, &batch.x, &batch.y, cfg.lr)?;
            params = out.params;
            loss_sum += out.loss as f64;
        }
        let up = CompressedModel::new(
            params.into_iter().map(StoredVar::raw).collect(),
        );
        peak_param_bytes = peak_param_bytes.max(up.memory_bytes());
        return Ok(ClientResult {
            upload: codec::encode(&up),
            loss: loss_sum / cfg.local_steps.max(1) as f64,
            peak_param_bytes,
        });
    }

    // OMC path: the graph consumes (Ṽ, s, b, mask) and returns the same
    // triple re-quantized. Transient decoded copies live only inside this
    // loop, mirroring Fig. 1's dashed-border variables.
    let mut tildes: Vec<Vec<f32>> =
        received.vars.iter().map(|v| v.decode_tilde()).collect();
    let mut s: Vec<f32> = received.vars.iter().map(|v| v.pvt().s).collect();
    let mut b: Vec<f32> = received.vars.iter().map(|v| v.pvt().b).collect();
    drop(received);

    let mut loss_sum = 0.0f64;
    for _ in 0..cfg.local_steps {
        let batch = domain.batch(speakers, mc.batch, rng);
        let out = model.run_train_omc(
            cfg.use_pvt,
            &tildes,
            &s,
            &b,
            mask,
            &batch.x,
            &batch.y,
            cfg.lr,
            cfg.format.exp_bits,
            cfg.format.mant_bits,
        )?;
        tildes = out.tildes;
        s = out.s;
        b = out.b;
        loss_sum += out.loss as f64;
    }

    // re-pack for the uplink: quantized vars bit-packed, the rest raw
    let mut vars = Vec::with_capacity(tildes.len());
    for (i, t) in tildes.into_iter().enumerate() {
        if mask[i] > 0.5 {
            let pvt = Pvt { s: s[i], b: b[i] };
            let sv = StoredVar::from_quantized(&t, cfg.format, pvt)
                .map_err(|e| anyhow::anyhow!("uplink pack var {i}: {e}"))?;
            vars.push(sv);
        } else {
            vars.push(StoredVar::raw(t));
        }
    }
    let up = CompressedModel::new(vars);
    peak_param_bytes = peak_param_bytes.max(up.memory_bytes());
    Ok(ClientResult {
        upload: codec::encode(&up),
        loss: loss_sum / cfg.local_steps.max(1) as f64,
        peak_param_bytes,
    })
}

/// Build the downlink payload for one client: compress the server's global
/// model according to the client's PPQ mask.
pub fn make_downlink(
    global: &[Vec<f32>],
    mask: &[f32],
    format: FloatFormat,
    use_pvt: bool,
) -> Vec<u8> {
    let vars: Vec<StoredVar> = global
        .iter()
        .zip(mask)
        .map(|(v, &m)| {
            if m > 0.5 && !format.is_fp32() {
                StoredVar::compress(v, format, use_pvt)
            } else {
                StoredVar::raw(v.clone())
            }
        })
        .collect();
    codec::encode(&CompressedModel::new(vars))
}

/// Per-round downlink compression cache (§Perf).
///
/// The quantize + PVT-fit + bit-pack of a given variable is identical for
/// every client whose mask selects it, so the server compresses each
/// variable ONCE per round and per-client payloads are assembled from
/// borrowed parts (framing + memcpy only). With 8 clients/round this cuts
/// the downlink build cost ~8x.
pub struct DownlinkCache {
    /// compressed version of each variable (None when format is FP32)
    packed: Vec<Option<StoredVar>>,
}

impl DownlinkCache {
    pub fn build(
        global: &[Vec<f32>],
        format: FloatFormat,
        use_pvt: bool,
        any_selected: impl Fn(usize) -> bool,
    ) -> Self {
        let packed = global
            .iter()
            .enumerate()
            .map(|(i, v)| {
                if format.is_fp32() || !any_selected(i) {
                    None
                } else {
                    Some(StoredVar::compress(v, format, use_pvt))
                }
            })
            .collect();
        Self { packed }
    }

    /// Assemble one client's payload from the cache.
    pub fn assemble(&self, global: &[Vec<f32>], mask: &[f32]) -> Vec<u8> {
        let cap: usize = global
            .iter()
            .zip(mask.iter())
            .enumerate()
            .map(|(i, (v, &m))| {
                if m > 0.5 {
                    self.packed[i]
                        .as_ref()
                        .map(|p| p.memory_bytes())
                        .unwrap_or(v.len() * 4)
                } else {
                    v.len() * 4
                }
            })
            .sum();
        let mut w = codec::WireWriter::with_capacity(cap + 16 * global.len());
        for (i, v) in global.iter().enumerate() {
            match (&self.packed[i], mask[i] > 0.5) {
                (Some(p), true) => w.var(p),
                _ => w.raw(v),
            }
        }
        w.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::Gen;

    #[test]
    fn downlink_respects_mask_and_format() {
        let mut g = Gen::new(1);
        let global = vec![g.vec_normal(100, 0.1), g.vec_normal(50, 0.1)];
        let fmt: FloatFormat = "S1E3M7".parse().unwrap();
        let wire = make_downlink(&global, &[1.0, 0.0], fmt, true);
        let m = codec::decode(&wire).unwrap();
        assert!(m.vars[0].is_packed());
        assert!(!m.vars[1].is_packed());
        // fp32 format always ships raw
        let wire = make_downlink(&global, &[1.0, 1.0], FloatFormat::FP32, true);
        let m = codec::decode(&wire).unwrap();
        assert!(m.vars.iter().all(|v| !v.is_packed()));
    }

    #[test]
    fn downlink_size_scales_with_fraction() {
        let mut g = Gen::new(2);
        let global: Vec<Vec<f32>> =
            (0..10).map(|_| g.vec_normal(10_000, 0.1)).collect();
        let fmt: FloatFormat = "S1E3M7".parse().unwrap();
        let all = make_downlink(&global, &[1.0; 10], fmt, true).len();
        let none = make_downlink(&global, &[0.0; 10], fmt, true).len();
        let ratio = all as f64 / none as f64;
        assert!((ratio - 11.0 / 32.0).abs() < 0.02, "ratio {ratio}");
    }
}
