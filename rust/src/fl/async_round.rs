//! Buffered asynchronous aggregation with staleness-aware FedAvg weights
//! (§Scale — the async round engine).
//!
//! Synchronous rounds (`fl::round`) pay the straggler tax: every round
//! waits for its slowest reporting client. This module implements the
//! standard production answer — *buffered asynchronous* aggregation: a
//! fixed number of clients is always in flight, each training against the
//! server version that was current when it was dispatched, and the server
//! folds uplinks into a buffer as they arrive, committing a new model
//! version every `K` buffered updates with a staleness discount applied to
//! the FedAvg weights ([`StalenessPolicy`]).
//!
//! # Virtual-time determinism contract
//!
//! The engine is a *simulator*: arrivals are ordered by the deterministic
//! virtual-time latency model of `fl::cohort` (exponential per-dispatch
//! draws keyed by `(seed, wave, cid)`), ties broken FIFO on the dispatch
//! sequence — `(arrival, cid)` order within a wave, since waves dispatch
//! in sorted-cid order (see the `Event` ordering note below).
//! Because latencies do not depend on training, the whole event timeline —
//! who trains against which version, which commit each uplink folds into,
//! every staleness value and normalized weight — is planned up front
//! ([`plan_async`]) as a pure function of the config and seed. Execution
//! then proceeds one *wave* per version: the clients that start from
//! version `v` run (sequentially, or sharded over the thread pool), their
//! uploads are stashed, and commit `v` folds its planned updates **in plan
//! order through a single [`StreamingAggregator`] on the coordinator
//! thread**. Parallelism only ever touches client training, and uploads
//! are bit-identical across schedules (RNG keyed by `(seed, wave, cid)`),
//! so the committed model bytes and every recorded metric are
//! *byte-identical* for any worker count — a stronger guarantee than the
//! sync sharded path (which reassociates f64 sums when merging shard
//! accumulators). Asserted by `rust/tests/async_round.rs` and the CI
//! `async-determinism` leg.
//!
//! # Snapshot ring
//!
//! Committed versions live in an [`SnapshotRing`] under the paper's own
//! storage discipline: each version is kept as a [`CompressedModel`]
//! (policy-eligible variables bit-packed at the experiment format, the
//! rest raw). Downlinks for a wave assemble from the ring entry — packed
//! variables ship their packed bytes verbatim when the client's PPQ mask
//! selects them, everything else ships the snapshot's decompressed values.
//! With full selection (`fraction = 1.0`) or the FP32 baseline this is
//! bit-identical to the synchronous downlink; with partial selection the
//! deselected-but-eligible variables arrive as the server's compressed
//! copy decompressed (the ring never retains a raw duplicate) — a
//! deliberate, documented fidelity trade the sync path does not make. See
//! `docs/ASYNC.md`.
//!
//! # Sync equivalence
//!
//! With the discount pinned to `constant` (any `c`: it cancels in the
//! per-commit normalization), `buffer_k == concurrency == cohort size`,
//! and an ideal-latency cohort, the first commit performs exactly the f64
//! operations of one synchronous `fl::round` round: same participants
//! (`sampler.sample(0)`), same masks and client RNG streams (wave 0 ≡
//! round 0), same downlink bytes, same fold order (zero-latency arrivals
//! process FIFO, i.e. in the sampled cohort order the sync path folds
//! in) and the same normalized weights. `rust/tests/async_round.rs` pins
//! this bit-exactly.

use std::collections::BinaryHeap;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::data::partition::ClientAssignment;
use crate::data::synth::Domain;
use crate::fl::chaos::{self, ChaosClientReport, ChaosConfig, ClientChaos};
use crate::fl::client::{self, ClientResult, ClientScratch, ClientTrainConfig};
use crate::fl::cohort::{self, ClientFate, CohortConfig};
use crate::fl::population::{self, PopulationConfig};
use crate::fl::round::{downlink_nonce, uplink_nonce, RoundScratch};
use crate::fl::sampler::Sampler;
use crate::fl::server::{Server, StreamingAggregator};
use crate::metrics::recorder::CommitRecord;
use crate::model::manifest::VarSpec;
use crate::omc::codec::{self, NonceLedger, WireWriter};
use crate::omc::delta::{AckLedger, DeltaBase};
use crate::omc::format::FloatFormat;
use crate::omc::selection::SelectionPolicy;
use crate::omc::sparse::{ClientResidual, SparseParams, SparseStore};
use crate::omc::store::{CompressedModel, SnapshotRing, StoredVar};
use crate::runtime::engine::LoadedModel;
use crate::util::rng::{hash_seed, Xoshiro256pp};
use crate::util::threadpool;

/// Client-RNG stream tag — MUST equal the constant `fl::round::run_round`
/// uses, so wave-0 uploads are bit-identical to sync round-0 uploads (the
/// first-commit equivalence test enforces this).
pub(crate) const CLIENT_STREAM: u64 = 0xC11E27;

// ---- configuration -------------------------------------------------------

/// Staleness discount applied to a buffered update's FedAvg weight before
/// per-commit normalization. `staleness` is the number of commits the
/// server performed between the client's dispatch and its arrival.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum StalenessPolicy {
    /// A constant multiplier. Note it cancels in the per-commit weight
    /// normalization, so every constant behaves like `1.0` — the variant
    /// exists as the explicit "no discount" reference.
    Constant(f64),
    /// `1 / (1 + staleness)^alpha` — the FedAsync/FedBuff-style polynomial
    /// decay; `alpha = 0` degenerates to constant.
    Polynomial {
        /// decay exponent (`>= 0`)
        alpha: f64,
    },
}

impl StalenessPolicy {
    /// The weight multiplier for an update that is `staleness` commits old.
    pub fn discount(&self, staleness: usize) -> f64 {
        match self {
            StalenessPolicy::Constant(c) => *c,
            StalenessPolicy::Polynomial { alpha } => {
                (1.0 + staleness as f64).powf(-alpha)
            }
        }
    }

    /// Parse the TOML spelling: `constant` (with optional `discount`) or
    /// `polynomial`/`poly` (with optional `alpha`, default `0.5`). A knob
    /// belonging to the *other* policy is rejected, never silently
    /// dropped — `constant` + `alpha` almost certainly meant `polynomial`.
    pub fn parse(
        name: &str,
        discount: Option<f64>,
        alpha: Option<f64>,
    ) -> Result<Self> {
        match name {
            "constant" => {
                anyhow::ensure!(
                    alpha.is_none(),
                    "async.alpha belongs to the polynomial policy, not constant"
                );
                Ok(StalenessPolicy::Constant(discount.unwrap_or(1.0)))
            }
            "polynomial" | "poly" => {
                anyhow::ensure!(
                    discount.is_none(),
                    "async.discount belongs to the constant policy, not polynomial"
                );
                Ok(StalenessPolicy::Polynomial {
                    alpha: alpha.unwrap_or(0.5),
                })
            }
            other => anyhow::bail!(
                "unknown staleness policy {other:?} (constant | polynomial)"
            ),
        }
    }

    /// Bounds-check the policy parameters.
    pub fn validate(&self) -> Result<()> {
        match self {
            StalenessPolicy::Constant(c) => anyhow::ensure!(
                c.is_finite() && *c > 0.0,
                "async constant discount must be finite and > 0, got {c}"
            ),
            StalenessPolicy::Polynomial { alpha } => anyhow::ensure!(
                alpha.is_finite() && *alpha >= 0.0,
                "async polynomial alpha must be finite and >= 0, got {alpha}"
            ),
        }
        Ok(())
    }

    /// Stable canonical encoding (float bit patterns) for the sweep config
    /// fingerprint.
    pub fn canonical(&self) -> String {
        match self {
            StalenessPolicy::Constant(c) => format!("c{:016x}", c.to_bits()),
            StalenessPolicy::Polynomial { alpha } => {
                format!("p{:016x}", alpha.to_bits())
            }
        }
    }
}

impl std::fmt::Display for StalenessPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StalenessPolicy::Constant(c) => write!(f, "constant({c})"),
            StalenessPolicy::Polynomial { alpha } => {
                write!(f, "polynomial({alpha})")
            }
        }
    }
}

/// Knobs of the buffered asynchronous engine (`[async]` TOML table).
#[derive(Clone, Copy, Debug)]
pub struct AsyncConfig {
    /// run the experiment's rounds as async commits instead of sync rounds
    pub enabled: bool,
    /// clients kept in flight at all times; `0` means "the experiment's
    /// `clients_per_round`"
    pub concurrency: usize,
    /// commit a new model version every K buffered updates; `0` means
    /// "equal to the resolved concurrency" (fully-buffered FedAvg)
    pub buffer_k: usize,
    /// staleness discount applied to buffered updates' weights
    pub policy: StalenessPolicy,
    /// discard updates staler than this many commits (bytes still count);
    /// `usize::MAX` = never discard
    pub max_staleness: usize,
    /// committed versions retained compressed in the [`SnapshotRing`]
    pub snapshot_ring: usize,
}

impl Default for AsyncConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            concurrency: 0,
            buffer_k: 0,
            policy: StalenessPolicy::Constant(1.0),
            max_staleness: usize::MAX,
            snapshot_ring: 4,
        }
    }
}

impl AsyncConfig {
    /// Resolve the `0`-means-default knobs against the experiment's
    /// `clients_per_round`.
    pub fn resolved(&self, clients_per_round: usize) -> AsyncConfig {
        let mut r = *self;
        if r.concurrency == 0 {
            r.concurrency = clients_per_round;
        }
        if r.buffer_k == 0 {
            r.buffer_k = r.concurrency;
        }
        r
    }

    /// Bounds-check the knobs (called by `ExperimentConfig::validate`).
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            self.snapshot_ring >= 1,
            "async.snapshot_ring must be >= 1"
        );
        self.policy.validate()
    }
}

// ---- planning ------------------------------------------------------------

/// What ultimately happened to one dispatched client.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DispatchOutcome {
    /// Arrived and was folded into `commit` with the given staleness.
    Folded {
        /// commit index the update folded into
        commit: usize,
        /// commits performed between dispatch and arrival
        staleness: usize,
    },
    /// Arrived too stale (`staleness > max_staleness`): bytes spent,
    /// update dropped in commit window `window`.
    Discarded {
        /// commit window the discard happened in
        window: usize,
        /// the offending staleness
        staleness: usize,
    },
    /// Went offline after the downlink; the server learns at the would-be
    /// report time and refills the slot. Downlink bytes only.
    Dropped,
    /// Killed by the chaos engine: crashed before training (no uplink), or
    /// exhausted its retries sending only corrupt frames (every attempt's
    /// bytes rejected). Either way the update never folds and the slot
    /// refills when the server gives up.
    Crashed,
    /// Still training when the final commit landed; downlink bytes were
    /// spent, training is never executed.
    InFlight,
}

/// One planned client dispatch (slot fill) of the async timeline.
#[derive(Clone, Debug, PartialEq)]
pub struct PlannedDispatch {
    /// dispatch sequence number (index into [`AsyncPlan::dispatches`])
    pub seq: usize,
    /// sampler wave the client was drawn from — the RNG/mask key
    pub wave: u64,
    /// client id
    pub cid: usize,
    /// unnormalized FedAvg weight (example count or 1.0)
    pub weight: f64,
    /// virtual dispatch time (seconds)
    pub start_time: f64,
    /// virtual report time: `start_time` + the cohort latency draw, plus
    /// chaos retry backoff when the dispatch has a fault plan
    pub arrival_time: f64,
    /// server version the client trains against
    pub start_version: usize,
    /// planned fate of the uplink
    pub outcome: DispatchOutcome,
    /// fault-injection plan for this dispatch (`None` when chaos is off or
    /// the plan is entirely clean)
    pub chaos: Option<ClientChaos>,
}

/// One planned commit: which updates fold, in which order, at what weight.
#[derive(Clone, Debug, PartialEq)]
pub struct PlannedCommit {
    /// dispatch seqs in fold order (virtual arrival order, FIFO-tied)
    pub updates: Vec<usize>,
    /// normalized fold weights (`discount(staleness) × weight`, divided by
    /// the buffer sum — sums to 1)
    pub weights: Vec<f64>,
    /// virtual time the commit fired (the K-th buffered arrival)
    pub virtual_time: f64,
    /// staleness histogram of the folded updates (index = staleness)
    pub staleness_hist: Vec<usize>,
    /// mean buffer fill observed at each event of this commit window
    pub mean_occupancy: f64,
    /// arrival/drop events processed during the window
    pub window_events: usize,
    /// updates discarded as too stale during the window
    pub discarded: usize,
    /// transient commit failures injected by chaos before this commit
    /// landed (each retry added backoff to `virtual_time`)
    pub failures: u32,
}

/// The fully planned async timeline (a pure function of config + seed).
#[derive(Clone, Debug, PartialEq)]
pub struct AsyncPlan {
    /// every slot fill, in dispatch order
    pub dispatches: Vec<PlannedDispatch>,
    /// the commits, in version order
    pub commits: Vec<PlannedCommit>,
}

/// Virtual-time event: a dispatched client reporting (or being detected as
/// dropped). Ordered by `(time, seq)`: virtual arrival time first, then
/// FIFO on the dispatch sequence — an update dispatched at instant `t`
/// can never overtake one already in flight at `t`. Within one sampler
/// wave, dispatch order is the wave's order (sorted cids for the uniform
/// sampler), so same-instant arrivals fold in `(arrival, cid)` order —
/// and a zero-latency cohort's first commit folds exactly the wave-0
/// cohort in sync cohort order, which is what makes the first commit
/// bit-exact vs one synchronous round.
#[derive(Clone, Copy, Debug)]
struct Event {
    time: f64,
    seq: usize,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // BinaryHeap pops the max, so reverse: the smallest key wins
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Round-robin view over the sampler's waves: wave `w` is
/// `sampler.sample(w)`, consumed one client at a time. Each drawn client
/// remembers its wave — the key of its RNG, mask, and latency streams.
struct DispatchStream<'a> {
    sampler: &'a Sampler,
    wave: u64,
    queue: std::collections::VecDeque<usize>,
    queue_wave: u64,
}

impl<'a> DispatchStream<'a> {
    fn new(sampler: &'a Sampler) -> Self {
        Self {
            sampler,
            wave: 0,
            queue: std::collections::VecDeque::new(),
            queue_wave: 0,
        }
    }

    fn next(&mut self) -> (u64, usize) {
        if self.queue.is_empty() {
            self.queue.extend(self.sampler.sample(self.wave));
            self.queue_wave = self.wave;
            self.wave += 1;
        }
        (self.queue_wave, self.queue.pop_front().expect("non-empty wave"))
    }
}

/// Plan the whole async timeline: `commits` commits with `acfg` (must be
/// [`resolved`](AsyncConfig::resolved)) over the cohort latency/dropout
/// model, with `chaos` faults superimposed (crashes and give-ups refill
/// the slot; retry backoff shifts arrivals; transient commit failures
/// delay the commit and everything dispatched after it). Deterministic in
/// `(acfg, cohort, chaos, sampler, seed)`; independent of scheduling and
/// worker count.
pub fn plan_async(
    acfg: &AsyncConfig,
    cohort: &CohortConfig,
    chaos_cfg: &ChaosConfig,
    sampler: &Sampler,
    assignment: &ClientAssignment,
    pop_cfg: &PopulationConfig,
    seed: u64,
    commits: usize,
) -> Result<AsyncPlan> {
    anyhow::ensure!(commits > 0, "async plan needs at least one commit");
    anyhow::ensure!(acfg.concurrency >= 1, "async concurrency must be >= 1");
    anyhow::ensure!(acfg.buffer_k >= 1, "async buffer_k must be >= 1");
    // async has no reporting deadline — staleness replaces it. Dropout and
    // the latency draws are untouched (plan_cohort consumes its RNG draws
    // unconditionally, so latencies match the sync draws at the same
    // (seed, wave, cid)).
    let async_cohort = CohortConfig {
        deadline_s: f64::INFINITY,
        ..*cohort
    };

    let mut stream = DispatchStream::new(sampler);
    let mut dispatches: Vec<PlannedDispatch> = Vec::new();
    let mut heap: BinaryHeap<Event> = BinaryHeap::new();
    let mut dispatch_one =
        |start_time: f64,
         start_version: usize,
         dispatches: &mut Vec<PlannedDispatch>,
         heap: &mut BinaryHeap<Event>| {
            let (wave, cid) = stream.next();
            let p = cohort::plan_cohort_with(
                &async_cohort,
                &[cid],
                assignment,
                seed,
                wave,
                Some(pop_cfg),
            )
            .pop()
            .expect("one plan per client");
            let seq = dispatches.len();
            let mut arrival_time = start_time + p.latency_s;
            let mut outcome = if p.fate == ClientFate::Dropped {
                DispatchOutcome::Dropped
            } else {
                DispatchOutcome::InFlight
            };
            let mut ch_plan = None;
            if !chaos_cfg.is_off() && outcome != DispatchOutcome::Dropped {
                // device classes scale fault rates exactly like the sync
                // engine (thresholds move, variate streams don't)
                let ccfg = if pop_cfg.enabled {
                    chaos_cfg.scaled(
                        population::DEVICE_CLASSES
                            [population::class_of(seed, cid)]
                        .fault_mult,
                    )
                } else {
                    *chaos_cfg
                };
                let ch = chaos::plan_client(&ccfg, seed, wave, cid);
                if ch.crashed {
                    // died after the downlink: the server learns at the
                    // would-be report time
                    outcome = DispatchOutcome::Crashed;
                } else {
                    // retries (corrupt attempts) delay the delivery — or
                    // the give-up — by the planned backoff
                    arrival_time += ch.extra_latency_s;
                    if ch.gave_up {
                        outcome = DispatchOutcome::Crashed;
                    }
                }
                if !ch.is_clean() || ch.gave_up {
                    ch_plan = Some(ch);
                }
            }
            dispatches.push(PlannedDispatch {
                seq,
                wave,
                cid,
                weight: p.weight,
                start_time,
                arrival_time,
                start_version,
                outcome,
                chaos: ch_plan,
            });
            heap.push(Event {
                time: arrival_time,
                seq,
            });
        };

    for _ in 0..acfg.concurrency {
        dispatch_one(0.0, 0, &mut dispatches, &mut heap);
    }

    // pure safety net: the loop converges whenever dropout < 1 (enforced
    // by CohortConfig::validate), but a bound keeps a logic bug loud
    let dispatch_cap = acfg.concurrency + (commits * acfg.buffer_k + 1) * 1024;

    let mut version = 0usize;
    let mut buffer: Vec<(usize, usize)> = Vec::new(); // (seq, staleness)
    let mut out_commits: Vec<PlannedCommit> = Vec::with_capacity(commits);
    let (mut win_events, mut win_occupancy, mut win_discarded) = (0usize, 0usize, 0usize);
    while out_commits.len() < commits {
        anyhow::ensure!(
            dispatches.len() <= dispatch_cap,
            "async plan did not converge after {} dispatches \
             (commits={commits}, K={}, concurrency={})",
            dispatches.len(),
            acfg.buffer_k,
            acfg.concurrency
        );
        let e = heap.pop().expect("in-flight slots keep the heap non-empty");
        win_events += 1;
        let dropped = matches!(
            dispatches[e.seq].outcome,
            DispatchOutcome::Dropped | DispatchOutcome::Crashed
        );
        if !dropped {
            let staleness = version - dispatches[e.seq].start_version;
            if staleness > acfg.max_staleness {
                dispatches[e.seq].outcome = DispatchOutcome::Discarded {
                    window: version,
                    staleness,
                };
                win_discarded += 1;
            } else {
                buffer.push((e.seq, staleness));
            }
        }
        win_occupancy += buffer.len();

        // the slot refills at the event time — unless this event triggers
        // a commit that chaos delays, in which case the server is busy
        // retrying the commit and the refill waits for it
        let mut refill_time = e.time;
        if buffer.len() == acfg.buffer_k {
            let folded = std::mem::take(&mut buffer);
            let max_stale =
                folded.iter().map(|&(_, s)| s).max().unwrap_or(0);
            let mut hist = vec![0usize; max_stale + 1];
            let mut raw_w = Vec::with_capacity(folded.len());
            for &(seq, s) in &folded {
                hist[s] += 1;
                raw_w.push(acfg.policy.discount(s) * dispatches[seq].weight);
            }
            let total: f64 = raw_w.iter().sum();
            anyhow::ensure!(
                total > 0.0,
                "commit {} has non-positive total weight",
                out_commits.len()
            );
            let commit_idx = out_commits.len();
            let mut updates = Vec::with_capacity(folded.len());
            let mut weights = Vec::with_capacity(folded.len());
            for (&(seq, s), &w) in folded.iter().zip(&raw_w) {
                dispatches[seq].outcome = DispatchOutcome::Folded {
                    commit: commit_idx,
                    staleness: s,
                };
                updates.push(seq);
                weights.push(w / total);
            }
            // transient server-side commit failures: each planned failure
            // is one failed attempt, retried after exponential backoff in
            // virtual time — the commit lands late and the triggering
            // slot's refill waits out the retries
            let cc = if chaos_cfg.is_off() {
                chaos::CommitChaos::default()
            } else {
                chaos::plan_commit(chaos_cfg, seed, commit_idx as u64)
            };
            let commit_time = e.time + cc.delay_s;
            refill_time = commit_time;
            out_commits.push(PlannedCommit {
                updates,
                weights,
                virtual_time: commit_time,
                staleness_hist: hist,
                mean_occupancy: win_occupancy as f64 / win_events as f64,
                window_events: win_events,
                discarded: win_discarded,
                failures: cc.failures,
            });
            version += 1;
            (win_events, win_occupancy, win_discarded) = (0, 0, 0);
            if out_commits.len() == commits {
                break; // no refill after the final commit
            }
        }
        dispatch_one(refill_time, version, &mut dispatches, &mut heap);
    }

    Ok(AsyncPlan {
        dispatches,
        commits: out_commits,
    })
}

impl AsyncPlan {
    /// Total clients dispatched over the phase (downlink bytes were spent
    /// for every one of them).
    pub fn total_dispatched(&self) -> usize {
        self.dispatches.len()
    }
}

// ---- execution -----------------------------------------------------------

/// Everything an async phase needs, borrowed from the experiment.
pub struct AsyncContext<'a> {
    /// the bound artifact set (training/eval graphs + manifest)
    pub model: &'a LoadedModel,
    /// synthetic-data domain the clients draw batches from
    pub domain: &'a Domain,
    /// speaker shards per client
    pub assignment: &'a ClientAssignment,
    /// the dispatch stream's client source
    pub sampler: &'a Sampler,
    /// PPQ variable-selection policy
    pub policy: SelectionPolicy,
    /// client-side hyper-parameters
    pub train: ClientTrainConfig,
    /// cohort failure model (dropout + latency; the deadline is ignored —
    /// `max_staleness` replaces it)
    pub cohort: CohortConfig,
    /// fault-injection model (`fl::chaos`); `is_off()` skips all planning
    pub chaos: ChaosConfig,
    /// frame all transport in the checksummed v2 wire layout
    pub integrity: bool,
    /// frame uplinks as v3 cross-round deltas against the snapshot the
    /// client trained from (requires `integrity`). A dispatch only deltas
    /// when its planned fold keeps the base inside the snapshot ring
    /// (`staleness < snapshot_ring`); anything staler — or any update
    /// planned to be discarded or killed — falls back to verbatim v2
    /// framing, so a lagging ack can never produce an undecodable frame.
    pub delta: bool,
    /// frame masked uplink variables as tag-3 sparse records of the
    /// error-corrected update (requires `integrity`). Gated per dispatch
    /// exactly like the delta stage: only updates whose planned fold keeps
    /// the start-version snapshot inside the ring sparse-frame (the fold
    /// needs that snapshot decompressed as its dense base); everything
    /// else ships dense and leaves the client's residual untouched.
    pub sparse: Option<SparseParams>,
    /// resolved async knobs
    pub acfg: AsyncConfig,
    /// population-scale scenario (`fl::population`). The async engine
    /// consumes the lazy assignment, availability-aware sampler, and
    /// device-class latency/fault scaling; the two-tier edge topology is
    /// sync-only (`docs/SCALE.md`)
    pub population: PopulationConfig,
    /// experiment seed
    pub seed: u64,
    /// thread-pool width for codec work and sharded client execution
    pub workers: usize,
}

/// Aggregate numbers for one executed commit (the async analog of
/// `fl::round::RoundOutcome`).
#[derive(Clone, Debug)]
pub struct CommitOutcome {
    /// mean training loss over clients that trained this wave (NaN when
    /// the wave trained nobody)
    pub mean_loss: f64,
    /// server→client bytes for every client dispatched this wave
    pub down_bytes: usize,
    /// client→server bytes for every client trained this wave
    pub up_bytes: usize,
    /// subset of `up_bytes` from updates planned to be discarded as stale
    pub up_bytes_discarded: usize,
    /// max client parameter-store bytes observed this wave
    pub peak_client_param_bytes: usize,
    /// clients dispatched from the committed version (the wave size)
    pub dispatched: usize,
    /// updates folded into this commit (= buffer K)
    pub folded: usize,
    /// wave clients that dropped after the downlink
    pub dropped: usize,
    /// wave clients killed by chaos (crash, or retries exhausted)
    pub crashed: usize,
    /// uplink frames the server rejected this wave (corrupt attempts +
    /// duplicate replays)
    pub frames_rejected: u64,
    /// subset of `up_bytes` from rejected frames
    pub up_bytes_rejected: usize,
    /// uplink bytes the v3 delta stage saved vs verbatim framing, summed
    /// over the wave's built uploads (zero when delta is off)
    pub up_bytes_delta_saved: usize,
    /// uplink bytes the sparse stage saved vs dense packed records,
    /// summed over the wave's built uploads (zero when sparse is off)
    pub up_bytes_sparse_saved: usize,
    /// coordinates shipped by the wave's sparse records
    pub sparse_selected: u64,
    /// coordinates eligible for sparsification across the wave's uploads
    pub sparse_total: u64,
    /// Σ‖residual‖² banked by the wave's clients after selection
    pub sparse_residual_sq: f64,
    /// wave clients still in flight when the phase ends (downlink spent,
    /// training skipped)
    pub in_flight: usize,
    /// per-client chaos facts for the quarantine ladder (empty when chaos
    /// is off)
    pub chaos_reports: Vec<ChaosClientReport>,
    /// the commit's deterministic metrics record
    pub commit: CommitRecord,
}

/// One executed wave, ready to fold: the trained results *in task order*
/// plus the wave's downlink byte total. Produced inline by
/// [`AsyncRoundEngine::run_commit`] and by the serving engine's uplink
/// queue drain (`fl::serve`), which re-imposes task order on whatever
/// order the worker threads finished in.
pub(crate) struct WaveExecution {
    /// `(task index, result)` for every trainable dispatch of the wave,
    /// ordered by task index
    pub(crate) results: Vec<(usize, ClientResult)>,
    /// server→client bytes for every dispatch of the wave
    pub(crate) down_bytes: usize,
}

/// Whether a planned dispatch actually trains: it arrives (folded or
/// stale-discarded), or it trained but gave up after all-corrupt retries.
/// Dropped, hard-crashed, and end-of-phase in-flight dispatches spend
/// downlink bytes only.
pub(crate) fn dispatch_trains(d: &PlannedDispatch) -> bool {
    matches!(
        d.outcome,
        DispatchOutcome::Folded { .. } | DispatchOutcome::Discarded { .. }
    ) || (d.outcome == DispatchOutcome::Crashed
        && d.chaos.as_ref().map_or(false, |c| c.gave_up && !c.crashed))
}

/// Whether a dispatch's uplink ships as a v3 delta frame. Decided straight
/// off the plan (so it is identical for any worker count or schedule): an
/// uplink deltas against its start version's snapshot only when the
/// planned fold still finds that snapshot in the ring — at the fold of
/// commit `c` the ring holds versions `c - (depth-1) ..= c`, so the
/// condition is `staleness < depth`. Everything else (stale folds,
/// discards, give-ups, in-flight) ships verbatim v2.
pub(crate) fn delta_frames(
    d: &PlannedDispatch,
    delta_on: bool,
    ring_depth: usize,
) -> bool {
    delta_on
        && matches!(
            d.outcome,
            DispatchOutcome::Folded { staleness, .. }
                if staleness < ring_depth
        )
}

/// Whether a dispatch's uplink carries tag-3 sparse records. Same
/// plan-derived gate as [`delta_frames`]: the fold resolves the sparse
/// record against the dense view of the client's start-version snapshot,
/// so only updates whose planned fold still finds that snapshot in the
/// ring sparsify. Discards, give-ups, and in-flight dispatches ship dense
/// — and bank no residual, keeping the error-feedback state a pure
/// function of the plan.
pub(crate) fn sparse_frames(
    d: &PlannedDispatch,
    sparse_on: bool,
    ring_depth: usize,
) -> bool {
    sparse_on
        && matches!(
            d.outcome,
            DispatchOutcome::Folded { staleness, .. }
                if staleness < ring_depth
        )
}

/// Train one planned dispatch: the client RNG, nonce, delta base, and
/// speaker shard are all pure functions of `(ctx, d)`, so the upload bytes
/// are bit-identical no matter which thread or engine runs this. Shared by
/// [`AsyncRoundEngine::run_commit`] and the serving engine's workers.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_planned_client(
    ctx: &AsyncContext<'_>,
    d: &PlannedDispatch,
    downlink: &[u8],
    mask: &[f32],
    delta_on: bool,
    ring_depth: usize,
    cs: &mut ClientScratch,
    residual: Option<&ClientResidual>,
) -> Result<ClientResult> {
    let mut rng = Xoshiro256pp::new(hash_seed(&[
        ctx.seed,
        CLIENT_STREAM,
        d.wave,
        d.cid as u64,
    ]));
    let mut tc = ctx.train;
    if ctx.integrity {
        tc.uplink_nonce = Some(uplink_nonce(ctx.seed, d.wave, d.cid as u64));
    }
    if delta_frames(d, delta_on, ring_depth) {
        tc.delta_base = Some(d.start_version as u64);
    }
    if let Some(sp) = ctx.sparse {
        if sparse_frames(d, ctx.integrity, ring_depth) {
            tc.sparse = Some(sp.bind(ctx.seed, d.wave, d.cid as u64));
        }
    }
    // speakers_of works in dense AND lazy (population) modes
    let shard = ctx.assignment.speakers_of(d.cid);
    client::run_client_round(
        ctx.model,
        ctx.domain,
        shard.as_ref(),
        downlink,
        mask,
        tc,
        &mut rng,
        cs,
        residual,
    )
    .with_context(|| format!("client {} wave {}", d.cid, d.wave))
}

/// The buffered async executor: owns the plan, the snapshot ring, and the
/// stash of uploads waiting for their commit. One instance per async
/// phase; per-call scratch comes from the caller's [`RoundScratch`] so
/// warmed codec buffers are shared with the sync engine across sweep
/// cells.
pub struct AsyncRoundEngine {
    /// the planned timeline, shared (`Arc`) so the wall-clock serving
    /// engine's worker threads can hold it without borrowing the engine
    plan: Arc<AsyncPlan>,
    ring: SnapshotRing,
    /// dispatch seqs grouped by start version (the execution waves)
    by_version: Vec<Vec<usize>>,
    /// uploads stashed until their commit folds them (≈ concurrency live)
    uploads: Vec<Option<Vec<u8>>>,
    /// bytes of stale-discarded updates, by commit window
    discard_bytes: Vec<usize>,
    /// decompressed values of one snapshot version (reused across waves)
    wave_vals: Vec<Vec<f32>>,
    /// which version `wave_vals` currently holds (`usize::MAX` = none);
    /// the drift pass leaves the freshly committed version decoded here,
    /// so the next wave skips its full-model decompress
    wave_vals_version: usize,
    /// spare per-variable buffer for the drift pass (capacity reused)
    spare_vals: Vec<f32>,
    /// streaming-fold decode scratch (reused across commits)
    decode_scratch: Vec<f32>,
    /// duplicate-uplink detector, shared across the whole phase (nonces
    /// are keyed by `(seed, wave, cid)`, unique per dispatch)
    ledger: NonceLedger,
    /// per-client delta ack state: the last snapshot version each client
    /// demonstrably trained from *and had accepted* (advanced only when
    /// an update folds into a commit — never on rejected, corrupt,
    /// duplicate, or stale-discarded frames)
    acks: AckLedger,
    /// per-client sparse error-feedback residuals, committed in task
    /// order by `fold_commit` (fresh per phase — one engine per phase)
    sparse_store: SparseStore,
    /// stash consumed uplink wires in `spent` instead of dropping them
    /// (the serving engine recycles them through its byte arena)
    recycle_uplinks: bool,
    /// uplink buffers consumed by folds since the last `take_spent`
    spent: Vec<Vec<u8>>,
    next_commit: usize,
}

impl AsyncRoundEngine {
    /// Plan the phase (`commits` commits) and build a cold engine.
    pub fn plan(ctx: &AsyncContext<'_>, commits: usize) -> Result<Self> {
        if !ctx.chaos.is_off() {
            anyhow::ensure!(
                ctx.integrity,
                "chaos injection requires wire integrity (omc.integrity) — \
                 corrupt frames must be detectable"
            );
        }
        let plan = plan_async(
            &ctx.acfg,
            &ctx.cohort,
            &ctx.chaos,
            ctx.sampler,
            ctx.assignment,
            &ctx.population,
            ctx.seed,
            commits,
        )?;
        let mut by_version = vec![Vec::new(); commits];
        for d in &plan.dispatches {
            by_version[d.start_version].push(d.seq);
        }
        let uploads = vec![None; plan.dispatches.len()];
        Ok(Self {
            ring: SnapshotRing::new(ctx.acfg.snapshot_ring),
            discard_bytes: vec![0; commits],
            uploads,
            by_version,
            plan: Arc::new(plan),
            wave_vals: Vec::new(),
            wave_vals_version: usize::MAX,
            spare_vals: Vec::new(),
            decode_scratch: Vec::new(),
            ledger: NonceLedger::new((ctx.acfg.concurrency * 2).max(16)),
            acks: AckLedger::new(),
            sparse_store: SparseStore::new(),
            recycle_uplinks: false,
            spent: Vec::new(),
            next_commit: 0,
        })
    }

    /// Keep consumed uplink wires in a stash instead of dropping them
    /// (see [`take_spent`](Self::take_spent)). Off by default.
    pub(crate) fn set_recycle_uplinks(&mut self, on: bool) {
        self.recycle_uplinks = on;
    }

    /// Drain the stash of uplink buffers consumed by folds since the last
    /// call (empty unless [`set_recycle_uplinks`](Self::set_recycle_uplinks)
    /// turned stashing on).
    pub(crate) fn take_spent(&mut self) -> Vec<Vec<u8>> {
        std::mem::take(&mut self.spent)
    }

    /// The delta ack ledger (read-only — regression tests assert it only
    /// advances on accepted commits).
    pub fn acks(&self) -> &AckLedger {
        &self.acks
    }

    /// The planned timeline (read-only — for tests and reporting).
    pub fn timeline(&self) -> &AsyncPlan {
        self.plan.as_ref()
    }

    /// A shared handle to the planned timeline (the serving engine's
    /// dispatcher and workers iterate it without borrowing the engine).
    pub(crate) fn timeline_arc(&self) -> Arc<AsyncPlan> {
        Arc::clone(&self.plan)
    }

    /// Dispatch seqs that train against version `v` (the wave).
    pub(crate) fn wave_tasks(&self, v: usize) -> &[usize] {
        &self.by_version[v]
    }

    /// Decompressed values of the wave's snapshot — valid after
    /// [`begin_wave`](Self::begin_wave) until the next `fold_commit`.
    pub(crate) fn wave_vals(&self) -> &[Vec<f32>] {
        &self.wave_vals
    }

    /// Commits planned for this phase.
    pub fn commits_planned(&self) -> usize {
        self.plan.commits.len()
    }

    /// The snapshot ring (read-only — for memory accounting and analysis).
    pub fn ring(&self) -> &SnapshotRing {
        &self.ring
    }

    /// Start wave `v = next_commit`: seed the ring at version 0, fetch the
    /// wave's snapshot, and ensure `wave_vals` holds its decompressed
    /// values. Returns `(v, snapshot)` — a shared handle, so the serving
    /// engine (`fl::serve`) can publish it to worker threads while the
    /// ring moves on. Shared by [`run_commit`](Self::run_commit) and the
    /// serving engine; always paired with a later `fold_commit`.
    pub(crate) fn begin_wave(
        &mut self,
        ctx: &AsyncContext<'_>,
        server: &Server,
    ) -> Result<(usize, Arc<CompressedModel>)> {
        let v = self.next_commit;
        anyhow::ensure!(
            v < self.plan.commits.len(),
            "async phase already finished ({v} commits)"
        );
        let specs = &ctx.model.manifest.variables;
        if v == 0 {
            // seed the ring with the initial global model (version 0)
            self.ring.push(
                0,
                snapshot_model(
                    &server.params,
                    specs,
                    &ctx.policy,
                    ctx.train.format,
                    ctx.train.use_pvt,
                    ctx.workers,
                ),
            );
        }
        let snap = self.ring.get_shared(v).with_context(|| {
            format!(
                "snapshot for version {v} evicted (ring depth {})",
                self.ring.capacity()
            )
        })?;

        // decompressed snapshot values — the raw-shipping side of downlink
        // assembly (and the drift baseline after the commit). The drift
        // pass of the previous commit already left this version decoded,
        // so in the steady state nothing decompresses here.
        self.wave_vals.resize_with(specs.len(), Vec::new);
        if self.wave_vals_version != v {
            for (i, sv) in snap.vars.iter().enumerate() {
                sv.decompress_into(&mut self.wave_vals[i]);
            }
            self.wave_vals_version = v;
        }
        Ok((v, snap))
    }

    /// Execute the next wave and commit one model version, updating
    /// `server` in place. Call exactly [`commits_planned`] times.
    ///
    /// [`commits_planned`]: Self::commits_planned
    pub fn run_commit(
        &mut self,
        ctx: &AsyncContext<'_>,
        server: &mut Server,
        scratch: &mut RoundScratch,
    ) -> Result<CommitOutcome> {
        let (v, snap) = self.begin_wave(ctx, server)?;
        let snap: &CompressedModel = &snap;
        let specs = &ctx.model.manifest.variables;
        let plan = self.timeline_arc();
        let plan = plan.as_ref();
        let tasks: &[usize] = &self.by_version[v];
        let wave_vals: &[Vec<f32>] = &self.wave_vals;

        // per-task PPQ masks + downlinks, assembled in parallel from the
        // ring entry into pooled buffers (same discipline as fl::round)
        let masks: Vec<Vec<f32>> = tasks
            .iter()
            .map(|&s| {
                let d = &plan.dispatches[s];
                ctx.policy.draw_mask(specs, ctx.seed, d.wave, d.cid as u64)
            })
            .collect();
        let bufs = scratch.take_downlink_bufs(tasks.len());
        let (seed, integrity) = (ctx.seed, ctx.integrity);
        let items: Vec<((u64, u64), (&Vec<f32>, Vec<u8>))> = tasks
            .iter()
            .map(|&s| {
                let d = &plan.dispatches[s];
                (d.wave, d.cid as u64)
            })
            .zip(masks.iter().zip(bufs))
            .collect();
        let downlinks: Vec<Vec<u8>> = threadpool::scope_map_send(
            items,
            ctx.workers,
            move |_, ((wave, cid), (mask, buf))| {
                let nonce = if integrity {
                    Some(downlink_nonce(seed, wave, cid))
                } else {
                    None
                };
                assemble_downlink(snap, wave_vals, mask, buf, nonce)
            },
        )?;
        let down_bytes: usize = downlinks.iter().map(|d| d.len()).sum();

        // trainable = planned to arrive (folded or stale-discarded) plus
        // give-ups (they trained; every attempt is rejected on arrival);
        // dropped, hard-crashed, and end-of-phase in-flight dispatches
        // spend downlink only
        let trainable: Vec<usize> = (0..tasks.len())
            .filter(|&t| dispatch_trains(&plan.dispatches[tasks[t]]))
            .collect();

        let delta_on = ctx.delta && ctx.integrity;
        let ring_depth = ctx.acfg.snapshot_ring;
        let sparse_store = &self.sparse_store;
        let job = |t: usize, cs: &mut ClientScratch| -> Result<ClientResult> {
            let d = &plan.dispatches[tasks[t]];
            run_planned_client(
                ctx,
                d,
                &downlinks[t],
                &masks[t],
                delta_on,
                ring_depth,
                cs,
                sparse_store.get(d.cid as u64),
            )
        };

        // dispatch mirrors fl::round: sharded client execution needs a
        // Send-safe engine; PJRT executables are !Send and stay pinned
        #[cfg(not(feature = "pjrt"))]
        let results: Vec<(usize, ClientResult)> = {
            let shards = ctx.workers.max(1).min(trainable.len().max(1));
            if ctx.model.is_send_safe() && shards > 1 && trainable.len() > 1 {
                let scratches = scratch.client_scratches(shards);
                let chunk = (trainable.len() + shards - 1) / shards;
                let items: Vec<(&[usize], &mut ClientScratch)> = trainable
                    .chunks(chunk)
                    .zip(scratches.iter_mut())
                    .collect();
                let job = &job;
                let parts = threadpool::scope_map_send(
                    items,
                    shards,
                    move |_, (c, cs): (&[usize], &mut ClientScratch)| {
                        let mut out = Vec::with_capacity(c.len());
                        for &t in c {
                            let r = job(t, cs)?;
                            out.push((t, r));
                        }
                        Ok::<Vec<(usize, ClientResult)>, anyhow::Error>(out)
                    },
                )?;
                let mut flat = Vec::with_capacity(trainable.len());
                for p in parts {
                    flat.extend(p?);
                }
                flat
            } else {
                let cs = &mut scratch.client_scratches(1)[0];
                let mut out = Vec::with_capacity(trainable.len());
                for &t in &trainable {
                    out.push((t, job(t, cs)?));
                }
                out
            }
        };
        #[cfg(feature = "pjrt")]
        let results: Vec<(usize, ClientResult)> = {
            let cs = &mut scratch.client_scratches(1)[0];
            let mut out = Vec::with_capacity(trainable.len());
            for &t in &trainable {
                out.push((t, job(t, cs)?));
            }
            out
        };

        scratch.return_downlink_bufs(downlinks);
        self.fold_commit(ctx, server, WaveExecution { results, down_bytes })
    }

    /// Fold one executed wave into the server: verify and account every
    /// trained result *sequentially in task order*, fold the commit's
    /// planned updates in plan order through ONE aggregator on this
    /// thread, snapshot the committed version, and advance to the next
    /// commit. `exec.results` must be ordered by task index — both
    /// [`run_commit`](Self::run_commit) and the serving engine's queue
    /// drain (`fl::serve`) impose exactly this order, which is what makes
    /// their committed bytes bit-identical.
    pub(crate) fn fold_commit(
        &mut self,
        ctx: &AsyncContext<'_>,
        server: &mut Server,
        exec: WaveExecution,
    ) -> Result<CommitOutcome> {
        let v = self.next_commit;
        let specs = &ctx.model.manifest.variables;
        let plan = self.timeline_arc();
        let plan = plan.as_ref();
        let tasks: &[usize] = &self.by_version[v];
        let delta_on = ctx.delta && ctx.integrity;
        let ring_depth = ctx.acfg.snapshot_ring;
        let WaveExecution { results, down_bytes } = exec;
        let (mut dropped, mut crashed, mut in_flight) = (0usize, 0usize, 0usize);
        for &s in tasks {
            match plan.dispatches[s].outcome {
                DispatchOutcome::Dropped => dropped += 1,
                DispatchOutcome::Crashed => crashed += 1,
                DispatchOutcome::InFlight => in_flight += 1,
                _ => {}
            }
        }

        // stats folded sequentially in task order — NOT per shard — so
        // every reported f64 (and the nonce-ledger evolution) is identical
        // for any worker count
        let (mut loss_sum, mut trained) = (0.0f64, 0usize);
        let (mut up_bytes, mut up_disc, mut peak) = (0usize, 0usize, 0usize);
        let (mut frames_rejected, mut up_rejected) = (0u64, 0usize);
        let mut up_delta_saved = 0usize;
        let mut up_sparse_saved = 0usize;
        let (mut sparse_selected, mut sparse_total) = (0u64, 0u64);
        let mut sparse_residual_sq = 0.0f64;
        let mut chaos_reports: Vec<ChaosClientReport> = Vec::new();
        for (t, mut r) in results {
            let d = &plan.dispatches[tasks[t]];
            loss_sum += r.loss;
            trained += 1;
            peak = peak.max(r.peak_param_bytes);
            up_delta_saved += r.delta_saved;
            up_sparse_saved += r.sparse_saved;
            sparse_selected += r.sparse_selected;
            sparse_total += r.sparse_total;
            sparse_residual_sq += r.sparse_residual_sq;
            // error-feedback state advances here, in task order — the
            // committed residuals are identical for any worker count
            if let Some(res) = r.residual.take() {
                self.sparse_store.commit(d.cid as u64, res);
            }
            match d.outcome {
                DispatchOutcome::Folded { .. } => {
                    // corrupt retries arrive (and are rejected) before the
                    // clean delivery
                    if let Some(ch) = d.chaos.as_ref() {
                        let (f, b) =
                            replay_corrupt(ch, &r.upload, &mut self.ledger, d.cid)?;
                        frames_rejected += f;
                        up_bytes += b;
                        up_rejected += b;
                    }
                    up_bytes += r.upload.len();
                    codec::verify_frame(&r.upload)
                        .and_then(|info| self.ledger.observe(info.nonce))
                        .map_err(|e| {
                            anyhow::anyhow!(
                                "uplink from client {} failed verification \
                                 outside the chaos plan: {e}",
                                d.cid
                            )
                        })?;
                    if d.chaos.as_ref().map_or(false, |c| c.duplicate) {
                        // the accepted frame replayed once: same nonce,
                        // flagged by the ledger
                        let verdict = codec::verify_frame(&r.upload)
                            .and_then(|info| self.ledger.observe(info.nonce));
                        anyhow::ensure!(
                            verdict.is_err(),
                            "duplicated uplink from client {} was accepted twice",
                            d.cid
                        );
                        frames_rejected += 1;
                        up_bytes += r.upload.len();
                        up_rejected += r.upload.len();
                    }
                    if !ctx.chaos.is_off() {
                        chaos_reports.push(ChaosClientReport {
                            cid: d.cid,
                            corrupt_frames: d
                                .chaos
                                .as_ref()
                                .map_or(0, |c| c.faults.len() as u32),
                            delivered_clean: true,
                        });
                    }
                    self.uploads[d.seq] = Some(r.upload);
                }
                DispatchOutcome::Discarded { window, .. } => {
                    // stale updates are discarded unverified — their bytes
                    // (and any retry bytes) never reach the checksum path
                    up_bytes += r.upload.len();
                    self.discard_bytes[window] += r.upload.len();
                    up_disc += r.upload.len();
                }
                DispatchOutcome::Crashed => {
                    // gave up: every attempt was corrupt, all rejected
                    let ch = d.chaos.as_ref().expect("gave-up dispatch has a plan");
                    let (f, b) =
                        replay_corrupt(ch, &r.upload, &mut self.ledger, d.cid)?;
                    frames_rejected += f;
                    up_bytes += b;
                    up_rejected += b;
                    chaos_reports.push(ChaosClientReport {
                        cid: d.cid,
                        corrupt_frames: ch.faults.len() as u32,
                        delivered_clean: false,
                    });
                }
                _ => unreachable!("only arriving dispatches train"),
            }
        }

        // fold this commit's planned updates in plan order through ONE
        // aggregator on this thread — commit bytes are schedule-independent
        let sparse_on = ctx.sparse.is_some() && ctx.integrity;
        let pc = &plan.commits[v];
        let mut agg = StreamingAggregator::new(&server.var_lens());
        for (&s, &w) in pc.updates.iter().zip(&pc.weights) {
            let wire = self.uploads[s].take().with_context(|| {
                format!("upload for dispatch {s} missing at commit {v}")
            })?;
            let d = &plan.dispatches[s];
            let use_delta = delta_frames(d, delta_on, ring_depth);
            let use_sparse = sparse_frames(d, sparse_on, ring_depth);
            if use_delta || use_sparse {
                // folded updates may carry different start versions, so
                // the delta/sparse base is resolved per update from the
                // ring
                let bsnap = self.ring.get(d.start_version).with_context(|| {
                    format!(
                        "update base {} evicted before commit {v} \
                         (ring depth {ring_depth})",
                        d.start_version
                    )
                })?;
                let base = use_delta
                    .then(|| DeltaBase::from_model(d.start_version as u64, bsnap));
                // the sparse fold needs the base's DENSE view; staleness 0
                // (the common case) reuses the wave's one-time decode,
                // stale folds decompress their snapshot on the spot
                let sb_owned: Option<Vec<Vec<f32>>> = (use_sparse
                    && d.start_version != v)
                    .then(|| bsnap.vars.iter().map(|sv| sv.decompress()).collect());
                let sbase: Option<&[Vec<f32>]> = if use_sparse {
                    match &sb_owned {
                        Some(vv) => Some(vv),
                        None => {
                            anyhow::ensure!(
                                self.wave_vals_version == v,
                                "wave_vals holds version {} at commit {v}",
                                self.wave_vals_version
                            );
                            Some(&self.wave_vals)
                        }
                    }
                } else {
                    None
                };
                agg.accumulate_wire_with(
                    &wire,
                    w,
                    &mut self.decode_scratch,
                    base.as_ref(),
                    sbase,
                )?;
            } else {
                agg.accumulate_wire(&wire, w, &mut self.decode_scratch)?;
            }
            // the fold is the accepted commit — only here does the
            // client's delta ack state move forward
            self.acks.advance(d.cid as u64, d.start_version as u64);
            if self.recycle_uplinks {
                self.spent.push(wire);
            }
        }
        agg.apply(server)?;

        // snapshot the committed version; drift vs the served version is
        // RMS over the decompressed views (wave_vals still holds v's)
        let new_snap = snapshot_model(
            &server.params,
            specs,
            &ctx.policy,
            ctx.train.format,
            ctx.train.use_pvt,
            ctx.workers,
        );
        let mut drift_sq = 0.0f64;
        let mut drift_n = 0usize;
        for (i, sv) in new_snap.vars.iter().enumerate() {
            let buf = &mut self.spare_vals;
            sv.decompress_into(buf);
            for (a, b) in buf.iter().zip(&self.wave_vals[i]) {
                let d = (*a - *b) as f64;
                drift_sq += d * d;
            }
            drift_n += buf.len();
            // leave version v+1 decoded in wave_vals for the next wave
            // (buf takes the old values, recycling its capacity)
            std::mem::swap(buf, &mut self.wave_vals[i]);
        }
        self.wave_vals_version = v + 1;
        let param_drift = if drift_n > 0 {
            (drift_sq / drift_n as f64).sqrt()
        } else {
            f64::NAN
        };
        self.ring.push(v + 1, new_snap);

        let folded = pc.updates.len();
        let mean_staleness = {
            let total: usize = pc.staleness_hist.iter().sum();
            let weighted: usize = pc
                .staleness_hist
                .iter()
                .enumerate()
                .map(|(s, &c)| s * c)
                .sum();
            weighted as f64 / total.max(1) as f64
        };
        let commit = CommitRecord {
            commit: v,
            folded,
            mean_staleness,
            staleness_hist: pc.staleness_hist.clone(),
            mean_occupancy: pc.mean_occupancy,
            window_events: pc.window_events,
            discarded_updates: pc.discarded,
            discarded_bytes: self.discard_bytes[v],
            ring_bytes: self.ring.memory_bytes(),
            virtual_time: pc.virtual_time,
            param_drift,
            commit_failures: pc.failures,
        };
        self.next_commit += 1;
        Ok(CommitOutcome {
            mean_loss: if trained > 0 {
                loss_sum / trained as f64
            } else {
                f64::NAN
            },
            down_bytes,
            up_bytes,
            up_bytes_discarded: up_disc,
            peak_client_param_bytes: peak,
            dispatched: tasks.len(),
            folded,
            dropped,
            crashed,
            frames_rejected,
            up_bytes_rejected: up_rejected,
            up_bytes_delta_saved: up_delta_saved,
            up_bytes_sparse_saved: up_sparse_saved,
            sparse_selected,
            sparse_total,
            sparse_residual_sq,
            in_flight,
            chaos_reports,
            commit,
        })
    }
}

/// Compress a committed global model into a ring snapshot: policy-eligible
/// variables bit-packed at the experiment format (in parallel over the
/// thread pool), everything else raw. FP32 experiments store everything
/// raw — byte-identical to the sync downlink source in that case.
pub fn snapshot_model(
    params: &[Vec<f32>],
    specs: &[VarSpec],
    policy: &SelectionPolicy,
    format: FloatFormat,
    use_pvt: bool,
    workers: usize,
) -> CompressedModel {
    let eligible: Vec<bool> = specs
        .iter()
        .map(|s| !format.is_fp32() && policy.eligible(s))
        .collect();
    let vars = threadpool::scope_map(params, workers, |i, v| {
        if eligible[i] {
            StoredVar::compress(v, format, use_pvt)
        } else {
            StoredVar::raw(v.clone())
        }
    })
    .expect("snapshot compress worker panicked");
    CompressedModel::new(vars)
}

/// Replay one dispatch's planned corrupt uplink attempts against the wire
/// verifier. Every replayed frame MUST fail verification — an accepted
/// corrupt frame is an integrity-layer bug and errors out loudly. Returns
/// `(frames rejected, bytes rejected)`.
fn replay_corrupt(
    ch: &ClientChaos,
    upload: &[u8],
    ledger: &mut NonceLedger,
    cid: usize,
) -> Result<(u64, usize)> {
    let (mut frames, mut bytes) = (0u64, 0usize);
    for f in &ch.faults {
        let mut bad = upload.to_vec();
        chaos::apply_fault(f, &mut bad);
        let verdict =
            codec::verify_frame(&bad).and_then(|info| ledger.observe(info.nonce));
        anyhow::ensure!(
            verdict.is_err(),
            "chaos-corrupted frame from client {cid} passed verification \
             (is wire integrity enabled?)"
        );
        frames += 1;
        bytes += bad.len();
    }
    Ok((frames, bytes))
}

/// Assemble one client's downlink from a ring snapshot: packed variables
/// ship verbatim when the mask selects them; everything else ships the
/// snapshot's decompressed values (`vals[i]`, decoded once per wave).
/// With a nonce the frame is written in the checksummed v2 layout.
pub(crate) fn assemble_downlink(
    snap: &CompressedModel,
    vals: &[Vec<f32>],
    mask: &[f32],
    buf: Vec<u8>,
    nonce: Option<u64>,
) -> Vec<u8> {
    let cap: usize = snap
        .vars
        .iter()
        .enumerate()
        .map(|(i, sv)| {
            if mask[i] > 0.5 && sv.is_packed() {
                sv.memory_bytes()
            } else {
                4 * sv.len()
            }
        })
        .sum();
    let nvars = snap.vars.len();
    let mut w = match nonce {
        Some(n) => {
            WireWriter::with_buf_and_integrity(buf, cap + 19 * nvars + 12 + 4 * nvars, n)
        }
        None => WireWriter::with_buf_and_capacity(buf, cap + 19 * nvars),
    };
    for (i, sv) in snap.vars.iter().enumerate() {
        if mask[i] > 0.5 && sv.is_packed() {
            w.var(sv);
        } else {
            w.raw(&vals[i]);
        }
    }
    w.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::partition::Partition;
    use crate::fl::sampler::SamplerKind;

    fn assignment(clients: usize) -> ClientAssignment {
        ClientAssignment::build(Partition::BySpeaker, clients, 64, 7)
    }

    fn resolved(acfg: AsyncConfig) -> AsyncConfig {
        acfg.resolved(4)
    }

    fn plan_with(
        acfg: AsyncConfig,
        cohort: CohortConfig,
        seed: u64,
        commits: usize,
    ) -> AsyncPlan {
        plan_chaos(acfg, cohort, ChaosConfig::default(), seed, commits)
    }

    fn plan_chaos(
        acfg: AsyncConfig,
        cohort: CohortConfig,
        chaos: ChaosConfig,
        seed: u64,
        commits: usize,
    ) -> AsyncPlan {
        let a = assignment(16);
        let sampler = Sampler::new(SamplerKind::Uniform, 16, 4, 9);
        plan_async(
            &resolved(acfg),
            &cohort,
            &chaos,
            &sampler,
            &a,
            &PopulationConfig::off(),
            seed,
            commits,
        )
        .unwrap()
    }

    fn enabled() -> AsyncConfig {
        AsyncConfig {
            enabled: true,
            ..AsyncConfig::default()
        }
    }

    #[test]
    fn discount_policies() {
        let c = StalenessPolicy::Constant(0.7);
        assert_eq!(c.discount(0), 0.7);
        assert_eq!(c.discount(9), 0.7);
        let p = StalenessPolicy::Polynomial { alpha: 0.5 };
        assert_eq!(p.discount(0), 1.0);
        assert!((p.discount(3) - 0.5).abs() < 1e-12); // (1+3)^-0.5
        // monotone non-increasing
        for s in 0..20 {
            assert!(p.discount(s + 1) <= p.discount(s));
        }
        // alpha = 0 degenerates to constant 1
        let z = StalenessPolicy::Polynomial { alpha: 0.0 };
        assert_eq!(z.discount(7), 1.0);
    }

    #[test]
    fn policy_parse_validate_and_canonical() {
        assert_eq!(
            StalenessPolicy::parse("constant", None, None).unwrap(),
            StalenessPolicy::Constant(1.0)
        );
        assert_eq!(
            StalenessPolicy::parse("poly", None, Some(0.25)).unwrap(),
            StalenessPolicy::Polynomial { alpha: 0.25 }
        );
        assert!(StalenessPolicy::parse("chaos", None, None).is_err());
        // a knob from the other policy is rejected, not silently dropped
        assert!(StalenessPolicy::parse("constant", None, Some(0.5)).is_err());
        assert!(StalenessPolicy::parse("poly", Some(0.9), None).is_err());
        assert!(StalenessPolicy::Constant(0.0).validate().is_err());
        assert!(StalenessPolicy::Constant(f64::NAN).validate().is_err());
        assert!(StalenessPolicy::Polynomial { alpha: -1.0 }
            .validate()
            .is_err());
        StalenessPolicy::Polynomial { alpha: 0.5 }.validate().unwrap();
        // canonical encodings are distinct per parameter bits
        assert_ne!(
            StalenessPolicy::Constant(1.0).canonical(),
            StalenessPolicy::Constant(0.5).canonical()
        );
        assert_ne!(
            StalenessPolicy::Constant(0.5).canonical(),
            StalenessPolicy::Polynomial { alpha: 0.5 }.canonical()
        );
    }

    #[test]
    fn config_resolution_and_validation() {
        let a = AsyncConfig::default();
        let r = a.resolved(8);
        assert_eq!(r.concurrency, 8);
        assert_eq!(r.buffer_k, 8);
        let b = AsyncConfig {
            concurrency: 6,
            buffer_k: 0,
            ..AsyncConfig::default()
        }
        .resolved(8);
        assert_eq!(b.concurrency, 6);
        assert_eq!(b.buffer_k, 6);
        assert!(AsyncConfig {
            snapshot_ring: 0,
            ..AsyncConfig::default()
        }
        .validate()
        .is_err());
        AsyncConfig::default().validate().unwrap();
    }

    #[test]
    fn plan_is_deterministic_and_commit_weights_normalize() {
        let cohort = CohortConfig {
            straggler_mean_s: 2.0,
            weight_by_examples: true,
            ..CohortConfig::ideal()
        };
        let p1 = plan_with(enabled(), cohort, 42, 8);
        let p2 = plan_with(enabled(), cohort, 42, 8);
        assert_eq!(p1, p2);
        assert_eq!(p1.commits.len(), 8);
        for (j, c) in p1.commits.iter().enumerate() {
            assert_eq!(c.updates.len(), 4, "commit {j} must fold K updates");
            let sum: f64 = c.weights.iter().sum();
            assert!((sum - 1.0).abs() < 1e-12, "commit {j} weights sum {sum}");
            assert!(c.weights.iter().all(|&w| w > 0.0));
        }
        // virtual time is nondecreasing across commits, and fold order
        // within a commit is nondecreasing in arrival time
        for w in p1.commits.windows(2) {
            assert!(w[1].virtual_time >= w[0].virtual_time);
        }
        for c in &p1.commits {
            for pair in c.updates.windows(2) {
                let (a, b) = (&p1.dispatches[pair[0]], &p1.dispatches[pair[1]]);
                assert!(b.arrival_time >= a.arrival_time);
            }
        }
        // a different seed moves the timeline
        let p3 = plan_with(enabled(), cohort, 43, 8);
        assert_ne!(p1, p3);
    }

    #[test]
    fn zero_latency_first_commit_folds_wave0_in_cohort_order() {
        // ideal cohort: all latencies 0, so same-instant refills must NOT
        // overtake the initial wave (FIFO tie-break) and the first
        // commit's fold order must equal the cohort the uniform sampler
        // draws — the property the first-commit sync equivalence rests on
        let plan = plan_with(enabled(), CohortConfig::ideal(), 11, 2);
        let sampler = Sampler::new(SamplerKind::Uniform, 16, 4, 9);
        let wave0 = sampler.sample(0);
        let first: Vec<usize> = plan.commits[0]
            .updates
            .iter()
            .map(|&s| plan.dispatches[s].cid)
            .collect();
        assert_eq!(first, wave0, "fold order must be the sorted wave-0 cohort");
        for &s in &plan.commits[0].updates {
            assert_eq!(plan.dispatches[s].start_version, 0);
            assert_eq!(
                plan.dispatches[s].outcome,
                DispatchOutcome::Folded {
                    commit: 0,
                    staleness: 0
                }
            );
        }
    }

    #[test]
    fn constant_discount_cancels_in_normalization() {
        let cohort = CohortConfig {
            straggler_mean_s: 1.0,
            weight_by_examples: true,
            ..CohortConfig::ideal()
        };
        let one = plan_with(
            AsyncConfig {
                policy: StalenessPolicy::Constant(1.0),
                ..enabled()
            },
            cohort,
            5,
            6,
        );
        let half = plan_with(
            AsyncConfig {
                policy: StalenessPolicy::Constant(0.5),
                ..enabled()
            },
            cohort,
            5,
            6,
        );
        for (a, b) in one.commits.iter().zip(&half.commits) {
            assert_eq!(a.updates, b.updates);
            assert_eq!(a.weights, b.weights, "constant discount must cancel");
        }
    }

    #[test]
    fn polynomial_discount_downweights_stale_updates() {
        // K=1 commits on every arrival, so the remaining in-flight clients
        // accumulate staleness; a poly commit mixing stalenesses must give
        // the fresher update the larger normalized weight per unit weight
        let cohort = CohortConfig {
            straggler_mean_s: 2.0,
            ..CohortConfig::ideal()
        };
        let mut checked = 0;
        for seed in 0..10u64 {
            let plan = plan_with(
                AsyncConfig {
                    buffer_k: 2,
                    policy: StalenessPolicy::Polynomial { alpha: 1.0 },
                    ..enabled()
                },
                cohort,
                seed,
                12,
            );
            for c in &plan.commits {
                let stals: Vec<usize> = c
                    .updates
                    .iter()
                    .map(|&s| match plan.dispatches[s].outcome {
                        DispatchOutcome::Folded { staleness, .. } => staleness,
                        _ => unreachable!(),
                    })
                    .collect();
                if stals[0] != stals[1] {
                    // per-unit-weight normalized weight follows the discount
                    let per_w: Vec<f64> = c
                        .updates
                        .iter()
                        .zip(&c.weights)
                        .map(|(&s, &w)| w / plan.dispatches[s].weight)
                        .collect();
                    let (fresh, stale) =
                        if stals[0] < stals[1] { (0, 1) } else { (1, 0) };
                    assert!(per_w[fresh] > per_w[stale]);
                    checked += 1;
                }
            }
        }
        assert!(checked > 0, "no mixed-staleness commit over 10 seeds");
    }

    #[test]
    fn max_staleness_zero_discards_overlapping_updates() {
        // K=1: the first arrival commits immediately, making every other
        // in-flight client stale — with max_staleness=0 they must all be
        // discarded on arrival, and the window accounting must see them
        let cohort = CohortConfig {
            straggler_mean_s: 2.0,
            ..CohortConfig::ideal()
        };
        let plan = plan_with(
            AsyncConfig {
                buffer_k: 1,
                max_staleness: 0,
                ..enabled()
            },
            cohort,
            17,
            6,
        );
        let discarded: usize = plan
            .dispatches
            .iter()
            .filter(|d| matches!(d.outcome, DispatchOutcome::Discarded { .. }))
            .count();
        assert!(discarded > 0, "expected stale discards");
        let window_total: usize = plan.commits.iter().map(|c| c.discarded).sum();
        // every discard recorded in a window that was actually committed
        // (discards after the final commit are impossible: the plan stops)
        assert_eq!(discarded, window_total);
        for d in &plan.dispatches {
            if let DispatchOutcome::Discarded { staleness, window } = d.outcome {
                assert!(staleness > 0);
                assert!(window < plan.commits.len());
            }
        }
    }

    #[test]
    fn dropped_dispatches_never_fold_and_slots_refill() {
        let cohort = CohortConfig {
            dropout_prob: 0.4,
            straggler_mean_s: 1.0,
            ..CohortConfig::ideal()
        };
        let plan = plan_with(enabled(), cohort, 3, 6);
        let dropped: usize = plan
            .dispatches
            .iter()
            .filter(|d| d.outcome == DispatchOutcome::Dropped)
            .count();
        assert!(dropped > 0, "40% dropout over 6 commits must drop someone");
        // every commit still folded exactly K updates
        for c in &plan.commits {
            assert_eq!(c.updates.len(), 4);
        }
        // dispatch order is chronological: refills are created as events
        // are processed in virtual-time order
        for d in plan.dispatches.windows(2) {
            assert!(d[1].start_time >= d[0].start_time);
            assert!(d[1].start_version >= d[0].start_version);
        }
    }

    fn noisy_chaos() -> ChaosConfig {
        ChaosConfig {
            enabled: true,
            bitflip_prob: 0.2,
            truncate_prob: 0.1,
            duplicate_prob: 0.15,
            crash_prob: 0.1,
            commit_failure_prob: 0.3,
            max_retries: 1,
            backoff_base_s: 0.25,
            ..ChaosConfig::default()
        }
    }

    #[test]
    fn chaos_plan_is_deterministic_and_crashes_never_fold() {
        let cohort = CohortConfig {
            straggler_mean_s: 1.0,
            ..CohortConfig::ideal()
        };
        let p1 = plan_chaos(enabled(), cohort, noisy_chaos(), 21, 8);
        let p2 = plan_chaos(enabled(), cohort, noisy_chaos(), 21, 8);
        assert_eq!(p1, p2, "chaos plan must be a pure function of the seed");

        let crashed: Vec<&PlannedDispatch> = p1
            .dispatches
            .iter()
            .filter(|d| d.outcome == DispatchOutcome::Crashed)
            .collect();
        assert!(!crashed.is_empty(), "chaos at these rates must kill someone");
        // crashed dispatches never appear in any commit's fold list
        for c in &p1.commits {
            for &s in &c.updates {
                assert!(matches!(
                    p1.dispatches[s].outcome,
                    DispatchOutcome::Folded { .. }
                ));
            }
            assert_eq!(c.updates.len(), 4, "every commit still folds K");
        }
        // both crash shapes occur and their plans are coherent
        let hard = crashed
            .iter()
            .filter(|d| d.chaos.as_ref().map_or(false, |c| c.crashed))
            .count();
        let gave_up = crashed
            .iter()
            .filter(|d| {
                d.chaos.as_ref().map_or(false, |c| c.gave_up && !c.crashed)
            })
            .count();
        assert_eq!(hard + gave_up, crashed.len());
        assert!(gave_up > 0, "some client must exhaust its retries");
        // retry backoff delays arrivals: a gave-up dispatch arrives after
        // its latency draw alone would have it
        for d in &p1.dispatches {
            if let Some(ch) = &d.chaos {
                if !ch.crashed && !ch.faults.is_empty() {
                    assert!(d.arrival_time >= d.start_time + ch.extra_latency_s);
                }
            }
        }
    }

    #[test]
    fn commit_failures_delay_virtual_time_but_keep_fold_order() {
        let cohort = CohortConfig {
            straggler_mean_s: 1.0,
            ..CohortConfig::ideal()
        };
        let calm = plan_chaos(enabled(), cohort, ChaosConfig::default(), 33, 6);
        let only_commit_chaos = ChaosConfig {
            enabled: true,
            commit_failure_prob: 0.5,
            max_retries: 2,
            backoff_base_s: 1.0,
            ..ChaosConfig::default()
        };
        let stormy = plan_chaos(enabled(), cohort, only_commit_chaos, 33, 6);
        let failures: u32 = stormy.commits.iter().map(|c| c.failures).sum();
        assert!(failures > 0, "p=0.5 over 6 commits must fail sometimes");
        assert!(calm.commits.iter().all(|c| c.failures == 0));
        // the timelines are identical until the first failed commit (the
        // delay only shifts refills dispatched after it); that commit
        // folds the same updates, just later
        let j0 = stormy
            .commits
            .iter()
            .position(|c| c.failures > 0)
            .expect("some commit failed");
        for j in 0..j0 {
            assert_eq!(calm.commits[j], stormy.commits[j]);
        }
        assert_eq!(calm.commits[j0].updates, stormy.commits[j0].updates);
        assert!(stormy.commits[j0].virtual_time > calm.commits[j0].virtual_time);
        for w in stormy.commits.windows(2) {
            assert!(w[1].virtual_time >= w[0].virtual_time);
        }
        // commit-only chaos never touches client fates
        assert!(stormy
            .dispatches
            .iter()
            .all(|d| d.outcome != DispatchOutcome::Crashed));
    }

    #[test]
    fn plan_conserves_every_dispatch_fate_under_chaos() {
        // conservation ledger with everything on at once — dropout,
        // stragglers, stale discards, AND the chaos engine: every
        // dispatched client lands in exactly one bucket, and the
        // per-commit accounting sums back to the dispatch totals
        let cohort = CohortConfig {
            dropout_prob: 0.2,
            straggler_mean_s: 2.0,
            deadline_s: f64::INFINITY,
            weight_by_examples: true,
        };
        let acfg = AsyncConfig {
            buffer_k: 1,
            max_staleness: 0,
            ..enabled()
        };
        let plan = plan_chaos(acfg, cohort, noisy_chaos(), 29, 16);
        let (mut folded, mut discarded, mut dropped, mut crashed, mut in_flight) =
            (0usize, 0usize, 0usize, 0usize, 0usize);
        for d in &plan.dispatches {
            match d.outcome {
                DispatchOutcome::Folded { .. } => folded += 1,
                DispatchOutcome::Discarded { .. } => discarded += 1,
                DispatchOutcome::Dropped => dropped += 1,
                DispatchOutcome::Crashed => crashed += 1,
                DispatchOutcome::InFlight => in_flight += 1,
            }
        }
        assert_eq!(
            folded + discarded + dropped + crashed + in_flight,
            plan.total_dispatched()
        );
        // the fold and discard ledgers agree with the commit windows
        assert_eq!(
            folded,
            plan.commits.iter().map(|c| c.updates.len()).sum::<usize>()
        );
        assert_eq!(
            discarded,
            plan.commits.iter().map(|c| c.discarded).sum::<usize>()
        );
        // the scenario genuinely exercises every bucket — otherwise the
        // identity above proves nothing
        assert!(folded > 0, "no folds");
        assert!(discarded > 0, "no stale discards");
        assert!(dropped > 0, "no dropouts");
        assert!(crashed > 0, "no chaos kills");
    }
}
