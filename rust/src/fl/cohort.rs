//! Cohort failure scenarios: dropout, stragglers, and weighted FedAvg.
//!
//! Real cross-device cohorts are not the clean `clients_per_round` the
//! tables assume: devices go offline mid-round (dropout), report after the
//! server's deadline (stragglers), and hold different amounts of data
//! (example-weighted FedAvg). This module decides each sampled client's
//! *fate* for a round — deterministically from `(seed, round, client)`, so
//! a run replays exactly and the planned fates are known before any client
//! trains (which is what lets the round engine normalize FedAvg weights up
//! front and aggregate uplinks *streaming*, see `fl::round`).
//!
//! Semantics, mirroring a production FL server:
//!
//! * **Dropped** clients received their downlink (those bytes were spent)
//!   but never report back: no training cost, no uplink, no aggregation.
//! * **Late** clients train and upload — both directions count toward the
//!   round's transport — but the server's reporting deadline has passed, so
//!   their update is discarded, never aggregated.
//! * **Completing** clients are aggregated with weight proportional to
//!   their example count (or uniformly when `weight_by_examples` is off).

use crate::data::partition::ClientAssignment;
use crate::util::rng::{hash_seed, Xoshiro256pp};

/// Knobs of the simulated cohort failure model (all off by default, which
/// reproduces the paper's ideal full-participation rounds).
#[derive(Clone, Copy, Debug)]
pub struct CohortConfig {
    /// Probability a sampled client drops after receiving its downlink and
    /// never reports back. In `[0, 1)`.
    pub dropout_prob: f64,
    /// Mean of the exponential per-client latency model, in simulated
    /// seconds; `0.0` disables the straggler model (latency 0 for all).
    pub straggler_mean_s: f64,
    /// Per-round reporting deadline in simulated seconds. Clients whose
    /// drawn latency exceeds it are excluded from aggregation (their
    /// uplink bytes still count). `f64::INFINITY` means no deadline.
    pub deadline_s: f64,
    /// Weight each completing client's update by its example count
    /// (speakers it holds) instead of uniformly — weighted FedAvg.
    pub weight_by_examples: bool,
}

impl Default for CohortConfig {
    fn default() -> Self {
        Self {
            dropout_prob: 0.0,
            straggler_mean_s: 0.0,
            deadline_s: f64::INFINITY,
            weight_by_examples: false,
        }
    }
}

impl CohortConfig {
    /// The ideal cohort: nobody drops, nobody is late, uniform weights.
    pub fn ideal() -> Self {
        Self::default()
    }

    /// True when the failure model is fully disabled (the tables' setting).
    pub fn is_ideal(&self) -> bool {
        self.dropout_prob == 0.0
            && self.straggler_mean_s == 0.0
            && self.deadline_s.is_infinite()
            && !self.weight_by_examples
    }

    /// Bounds-check the knobs (called by `ExperimentConfig::validate`).
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            (0.0..1.0).contains(&self.dropout_prob),
            "cohort.dropout must be in [0, 1), got {}",
            self.dropout_prob
        );
        anyhow::ensure!(
            self.straggler_mean_s >= 0.0 && self.straggler_mean_s.is_finite(),
            "cohort.straggler_mean_s must be finite and >= 0"
        );
        anyhow::ensure!(
            self.deadline_s > 0.0,
            "cohort.deadline_s must be > 0 (use infinity for no deadline)"
        );
        Ok(())
    }
}

/// What happens to one sampled client this round.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ClientFate {
    /// Trains, uploads before the deadline, is aggregated.
    #[default]
    Completes,
    /// Goes offline after the downlink; never trains or uploads.
    Dropped,
    /// Trains and uploads after the deadline; excluded from aggregation.
    Late,
    /// Killed by the chaos engine (`fl::chaos`): either crashed before
    /// training, or exhausted its uplink retries with every frame corrupt.
    /// Never aggregated; bytes it did send are accounted as rejected.
    Crashed,
}

/// One sampled client's planned round, decided before any training runs.
#[derive(Clone, Debug, Default)]
pub struct ClientPlan {
    /// Client id (index into the population).
    pub cid: usize,
    /// The client's fate under the failure model.
    pub fate: ClientFate,
    /// Simulated downlink-to-upload latency in seconds (0 when the
    /// straggler model is off).
    pub latency_s: f64,
    /// Unnormalized FedAvg weight (example count, or 1.0 when uniform).
    pub weight: f64,
    /// Planned wire faults for this client, when the chaos engine is on
    /// (`None` leaves the plan byte-identical to the chaos-free path).
    pub chaos: Option<super::chaos::ClientChaos>,
}

/// Draw the deterministic per-client fates for one round's participants.
///
/// Each client gets an independent RNG stream keyed by
/// `(seed, round, cid)`; the same triple always yields the same fate, so
/// replaying a run — or re-executing it with a different worker count —
/// produces the identical cohort.
pub fn plan_cohort(
    cohort: &CohortConfig,
    participants: &[usize],
    assignment: &ClientAssignment,
    seed: u64,
    round: u64,
) -> Vec<ClientPlan> {
    plan_cohort_with(cohort, participants, assignment, seed, round, None)
}

/// [`plan_cohort`] with an optional population scenario: each client's
/// device class (lazily derived from `(seed, cid)`) scales its dropout
/// probability and straggler latency. The class multipliers apply *after*
/// the uniform draws are taken, so the per-client RNG stream is identical
/// with and without a population — A/B comparisons at the same seed see
/// the same variates, classes only move the thresholds.
pub fn plan_cohort_with(
    cohort: &CohortConfig,
    participants: &[usize],
    assignment: &ClientAssignment,
    seed: u64,
    round: u64,
    population: Option<&super::population::PopulationConfig>,
) -> Vec<ClientPlan> {
    let classed = population.map(|p| p.enabled).unwrap_or(false);
    participants
        .iter()
        .map(|&cid| {
            let mut rng = Xoshiro256pp::new(hash_seed(&[
                seed, 0xFA7E5, round, cid as u64,
            ]));
            // every knob consumes its RNG draw unconditionally, so the
            // latency stream stays aligned when dropout is toggled (and
            // vice versa) — A/B scenario comparisons at the same seed see
            // the same per-client draws
            let u_drop = rng.next_f64();
            let u_lat = rng.next_f64();
            let (drop_mult, lat_mult) = if classed {
                let class = &super::population::DEVICE_CLASSES
                    [super::population::class_of(seed, cid)];
                (class.dropout_mult, class.latency_mult)
            } else {
                (1.0, 1.0)
            };
            // scaled probability stays a probability; the draw is already
            // taken so the clamp cannot desynchronize the stream
            let drop_p = (cohort.dropout_prob * drop_mult).min(0.999_999);
            let dropped = u_drop < drop_p;
            let latency_s = if cohort.straggler_mean_s > 0.0 {
                // inverse-CDF exponential draw; u in [0,1) keeps ln finite
                -(1.0 - u_lat).ln() * cohort.straggler_mean_s * lat_mult
            } else {
                0.0
            };
            let fate = if dropped {
                ClientFate::Dropped
            } else if latency_s > cohort.deadline_s {
                ClientFate::Late
            } else {
                ClientFate::Completes
            };
            let weight = if cohort.weight_by_examples {
                assignment.num_examples(cid) as f64
            } else {
                1.0
            };
            ClientPlan {
                cid,
                fate,
                latency_s,
                weight,
                chaos: None,
            }
        })
        .collect()
}

/// FedAvg weights normalized over the clients planned to complete: the
/// `i`-th entry is `plans[i].weight / Σ completing weights` for completing
/// clients and `0.0` for dropped/late ones (also `0.0` everywhere when no
/// client completes). The single source of truth the round engine and its
/// tests share.
pub fn normalized_weights(plans: &[ClientPlan]) -> Vec<f64> {
    let total: f64 = plans
        .iter()
        .filter(|p| p.fate == ClientFate::Completes)
        .map(|p| p.weight)
        .sum();
    plans
        .iter()
        .map(|p| {
            if p.fate == ClientFate::Completes && total > 0.0 {
                p.weight / total
            } else {
                0.0
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::partition::Partition;

    fn assignment(clients: usize) -> ClientAssignment {
        ClientAssignment::build(Partition::BySpeaker, clients, 64, 7)
    }

    #[test]
    fn ideal_cohort_all_complete_with_uniform_weights() {
        let a = assignment(8);
        let ids: Vec<usize> = (0..8).collect();
        let plans = plan_cohort(&CohortConfig::ideal(), &ids, &a, 42, 3);
        assert_eq!(plans.len(), 8);
        for p in &plans {
            assert_eq!(p.fate, ClientFate::Completes);
            assert_eq!(p.latency_s, 0.0);
            assert_eq!(p.weight, 1.0);
        }
        assert!(CohortConfig::ideal().is_ideal());
    }

    #[test]
    fn plans_are_deterministic_and_round_sensitive() {
        let a = assignment(16);
        let ids: Vec<usize> = (0..16).collect();
        let cfg = CohortConfig {
            dropout_prob: 0.3,
            straggler_mean_s: 2.0,
            deadline_s: 3.0,
            weight_by_examples: true,
        };
        let p1 = plan_cohort(&cfg, &ids, &a, 42, 5);
        let p2 = plan_cohort(&cfg, &ids, &a, 42, 5);
        let p3 = plan_cohort(&cfg, &ids, &a, 42, 6);
        for (x, y) in p1.iter().zip(&p2) {
            assert_eq!(x.fate, y.fate);
            assert_eq!(x.latency_s, y.latency_s);
            assert_eq!(x.weight, y.weight);
        }
        // some fate must differ across rounds (16 clients, 30% dropout —
        // identical fates would mean the round isn't in the seed)
        assert!(p1
            .iter()
            .zip(&p3)
            .any(|(x, y)| x.fate != y.fate || x.latency_s != y.latency_s));
    }

    #[test]
    fn dropout_rate_is_statistically_right() {
        let a = assignment(4);
        let ids = [0usize, 1, 2, 3];
        let cfg = CohortConfig {
            dropout_prob: 0.25,
            ..CohortConfig::default()
        };
        let mut dropped = 0usize;
        let trials = 4_000;
        for round in 0..trials / 4 {
            for p in plan_cohort(&cfg, &ids, &a, 1, round as u64) {
                if p.fate == ClientFate::Dropped {
                    dropped += 1;
                }
            }
        }
        let rate = dropped as f64 / trials as f64;
        assert!((rate - 0.25).abs() < 0.03, "dropout rate {rate}");
    }

    #[test]
    fn straggler_latency_has_exponential_mean_and_deadline_splits() {
        let a = assignment(4);
        let ids = [0usize, 1, 2, 3];
        let cfg = CohortConfig {
            straggler_mean_s: 2.0,
            deadline_s: 2.0 * std::f64::consts::LN_2, // median → ~50% late
            ..CohortConfig::default()
        };
        let (mut sum, mut late, mut n) = (0.0f64, 0usize, 0usize);
        for round in 0..2_000u64 {
            for p in plan_cohort(&cfg, &ids, &a, 9, round) {
                sum += p.latency_s;
                n += 1;
                if p.fate == ClientFate::Late {
                    late += 1;
                    assert!(p.latency_s > cfg.deadline_s);
                } else {
                    assert!(p.latency_s <= cfg.deadline_s);
                }
            }
        }
        let mean = sum / n as f64;
        assert!((mean - 2.0).abs() < 0.15, "latency mean {mean}");
        let late_rate = late as f64 / n as f64;
        assert!((late_rate - 0.5).abs() < 0.05, "late rate {late_rate}");
    }

    #[test]
    fn example_weights_follow_assignment_sizes() {
        let a = assignment(6);
        let ids: Vec<usize> = (0..6).collect();
        let cfg = CohortConfig {
            weight_by_examples: true,
            ..CohortConfig::default()
        };
        for p in plan_cohort(&cfg, &ids, &a, 3, 0) {
            assert_eq!(p.weight, a.speakers(p.cid).len() as f64);
            assert!(p.weight >= 1.0);
        }
    }

    #[test]
    fn latency_stream_survives_dropout_toggle() {
        // toggling dropout must not reshuffle the straggler draws — the
        // scenario ladder A/Bs these knobs at the same seed
        let a = assignment(8);
        let ids: Vec<usize> = (0..8).collect();
        let base = CohortConfig {
            straggler_mean_s: 2.0,
            deadline_s: 3.0,
            ..CohortConfig::default()
        };
        let with_drop = CohortConfig {
            dropout_prob: 0.5,
            ..base
        };
        for round in 0..50u64 {
            let p0 = plan_cohort(&base, &ids, &a, 5, round);
            let p1 = plan_cohort(&with_drop, &ids, &a, 5, round);
            for (x, y) in p0.iter().zip(&p1) {
                assert_eq!(x.latency_s, y.latency_s, "round {round}");
            }
        }
    }

    #[test]
    fn class_multipliers_scale_thresholds_without_moving_the_stream() {
        use crate::fl::population::{class_of, DEVICE_CLASSES};
        let a = assignment(16);
        let ids: Vec<usize> = (0..16).collect();
        let cfg = CohortConfig {
            dropout_prob: 0.2,
            straggler_mean_s: 2.0,
            deadline_s: 4.0,
            ..CohortConfig::default()
        };
        let pop = crate::fl::population::PopulationConfig {
            enabled: true,
            registered: 16,
            ..crate::fl::population::PopulationConfig::default()
        };
        for round in 0..50u64 {
            let flat = plan_cohort(&cfg, &ids, &a, 5, round);
            let classed =
                plan_cohort_with(&cfg, &ids, &a, 5, round, Some(&pop));
            for (x, y) in flat.iter().zip(&classed) {
                // the underlying exponential draw is shared: the classed
                // latency is exactly the flat one scaled by the class mult
                let m = DEVICE_CLASSES[class_of(5, x.cid)].latency_mult;
                assert!(
                    (y.latency_s - x.latency_s * m).abs() < 1e-12,
                    "round {round} cid {}",
                    x.cid
                );
            }
        }
        // a disabled population must be byte-identical to the flat path
        for round in 0..10u64 {
            let flat = plan_cohort(&cfg, &ids, &a, 5, round);
            let off = plan_cohort_with(
                &cfg,
                &ids,
                &a,
                5,
                round,
                Some(&crate::fl::population::PopulationConfig::off()),
            );
            for (x, y) in flat.iter().zip(&off) {
                assert_eq!(x.fate, y.fate);
                assert_eq!(x.latency_s, y.latency_s);
                assert_eq!(x.weight, y.weight);
            }
        }
    }

    #[test]
    fn normalized_weights_cover_completers_only() {
        let plans: Vec<ClientPlan> = (0..6)
            .map(|i| ClientPlan {
                cid: i,
                fate: match i % 3 {
                    0 => ClientFate::Completes,
                    1 => ClientFate::Dropped,
                    _ => ClientFate::Late,
                },
                latency_s: 0.0,
                weight: 1.0 + i as f64,
                chaos: None,
            })
            .collect();
        let w = normalized_weights(&plans);
        let sum: f64 = w.iter().sum();
        assert!((sum - 1.0).abs() < 1e-12);
        for (p, &wi) in plans.iter().zip(&w) {
            if p.fate == ClientFate::Completes {
                assert!(wi > 0.0);
            } else {
                assert_eq!(wi, 0.0);
            }
        }
        // an entirely failed cohort yields all-zero weights, not NaN
        let failed: Vec<ClientPlan> = plans
            .into_iter()
            .map(|mut p| {
                p.fate = ClientFate::Dropped;
                p
            })
            .collect();
        assert!(normalized_weights(&failed).iter().all(|&x| x == 0.0));
    }

    #[test]
    fn validate_rejects_bad_knobs() {
        let ok = CohortConfig {
            dropout_prob: 0.5,
            straggler_mean_s: 1.0,
            deadline_s: 2.0,
            weight_by_examples: true,
        };
        ok.validate().unwrap();
        assert!(!ok.is_ideal());
        for bad in [
            CohortConfig { dropout_prob: 1.0, ..ok },
            CohortConfig { dropout_prob: -0.1, ..ok },
            CohortConfig { straggler_mean_s: -1.0, ..ok },
            CohortConfig { straggler_mean_s: f64::INFINITY, ..ok },
            CohortConfig { deadline_s: 0.0, ..ok },
        ] {
            assert!(bad.validate().is_err(), "{bad:?}");
        }
    }
}
