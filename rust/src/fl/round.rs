//! Round orchestration: sample clients, build per-client downlinks, run the
//! client work on the thread pool, aggregate the uplinks.
//!
//! Steady-state allocation discipline: [`RoundScratch`] carries the
//! per-client downlink frame buffers and the client codec scratch across
//! rounds, so the codec layer performs no per-variable heap allocation once
//! capacities have warmed up (see `fl::client` module docs).

use anyhow::{Context, Result};

use crate::data::partition::ClientAssignment;
use crate::data::synth::Domain;
use crate::fl::client::{self, ClientScratch, ClientTrainConfig};
use crate::fl::sampler::Sampler;
use crate::fl::server::Server;
use crate::omc::codec;
use crate::omc::selection::SelectionPolicy;
use crate::runtime::engine::LoadedModel;
use crate::util::rng::{hash_seed, Xoshiro256pp};
use crate::util::threadpool;

/// Everything a round needs, borrowed from the experiment.
pub struct RoundContext<'a> {
    pub model: &'a LoadedModel,
    pub domain: &'a Domain,
    pub assignment: &'a ClientAssignment,
    pub sampler: &'a Sampler,
    pub policy: SelectionPolicy,
    pub train: ClientTrainConfig,
    pub seed: u64,
    pub workers: usize,
}

/// Buffers reused across rounds (owned by the experiment driver).
#[derive(Default)]
pub struct RoundScratch {
    /// per-client downlink frame buffers, recycled round-to-round
    downlink_bufs: Vec<Vec<u8>>,
    /// the (single-threaded) client training loop's codec scratch
    client: ClientScratch,
}

impl RoundScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// Aggregate numbers for one completed round.
#[derive(Clone, Debug)]
pub struct RoundOutcome {
    pub mean_loss: f64,
    pub down_bytes: usize,
    pub up_bytes: usize,
    pub peak_client_param_bytes: usize,
    pub participants: Vec<usize>,
}

/// Run one federated round, updating `server` in place.
pub fn run_round(
    ctx: &RoundContext<'_>,
    server: &mut Server,
    scratch: &mut RoundScratch,
) -> Result<RoundOutcome> {
    let round = server.round as u64;
    let participants = ctx.sampler.sample(round);
    let specs = &ctx.model.manifest.variables;

    // per-client PPQ masks + downlink payloads. Each variable is
    // compressed ONCE per round (DownlinkCache, §Perf, built in parallel
    // over the thread pool) and the per-client payloads are assembled on
    // the thread pool into recycled buffers; PJRT execution below is
    // pinned to this thread (`PjRtLoadedExecutable` is !Send).
    let masks: Vec<Vec<f32>> = participants
        .iter()
        .map(|&c| ctx.policy.draw_mask(specs, ctx.seed, round, c as u64))
        .collect();
    // copy plain values out of ctx: the closures must not capture the
    // !Sync LoadedModel reference
    let (fmt, use_pvt, workers) = (ctx.train.format, ctx.train.use_pvt, ctx.workers);
    let global = &server.params;
    let cache = client::DownlinkCache::build(global, fmt, use_pvt, workers, |i| {
        masks.iter().any(|m| m[i] > 0.5)
    });
    let cache_ref = &cache;
    let mut bufs = std::mem::take(&mut scratch.downlink_bufs);
    bufs.resize_with(masks.len(), Vec::new);
    let items: Vec<(&Vec<f32>, Vec<u8>)> = masks.iter().zip(bufs).collect();
    let downlinks: Vec<Vec<u8>> =
        threadpool::scope_map_send(items, workers, move |_, (mask, buf)| {
            cache_ref.assemble_into(global, mask, buf)
        })?;
    let down_bytes: usize = downlinks.iter().map(|d| d.len()).sum();

    // client training (sequential over the shared PJRT device queue)
    let mut uploads = Vec::with_capacity(participants.len());
    let mut loss_sum = 0.0;
    let mut peak = 0usize;
    for (i, &cid) in participants.iter().enumerate() {
        let mut rng = Xoshiro256pp::new(hash_seed(&[
            ctx.seed, 0xC11E27, round, cid as u64,
        ]));
        let r = client::run_client_round(
            ctx.model,
            ctx.domain,
            ctx.assignment.speakers(cid),
            &downlinks[i],
            &masks[i],
            ctx.train,
            &mut rng,
            &mut scratch.client,
        )
        .with_context(|| format!("client {cid} round {round}"))?;
        loss_sum += r.loss;
        peak = peak.max(r.peak_param_bytes);
        uploads.push(r.upload);
    }
    let up_bytes: usize = uploads.iter().map(|u| u.len()).sum();
    // recycle the downlink frame buffers for the next round
    scratch.downlink_bufs = downlinks;

    // server: decode + fused-decompress uplinks (thread pool), then FedAvg
    let client_models: Vec<Vec<Vec<f32>>> =
        threadpool::scope_map(&uploads, workers, |_, u: &Vec<u8>| {
            codec::decode_decompressed(u)
        })?
        .into_iter()
        .collect::<Result<_>>()?;
    server.aggregate(&client_models, None)?;

    Ok(RoundOutcome {
        mean_loss: loss_sum / participants.len().max(1) as f64,
        down_bytes,
        up_bytes,
        peak_client_param_bytes: peak,
        participants,
    })
}

#[cfg(test)]
mod tests {
    // run_round requires compiled artifacts; its integration tests live in
    // rust/tests/fl_integration.rs. Pure-logic pieces (masks, downlinks,
    // aggregation) are tested in their own modules.
}
