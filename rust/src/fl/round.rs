//! Round orchestration: sample clients, plan the cohort's fates, build
//! per-client downlinks, execute the clients (sequentially or sharded over
//! the thread pool), and fold every uplink into a streaming FedAvg
//! accumulator.
//!
//! # Streaming, sharded round engine (§Scale)
//!
//! The round loop never materializes the decoded cohort. Each client's
//! uplink frame is folded into a [`StreamingAggregator`] the moment it is
//! produced and then dropped, so server working memory is
//! O(params + workers × accumulator) — independent of cohort size — where
//! the old path held O(cohort × params) decoded f32s before FedAvg.
//!
//! Client execution dispatches on the engine:
//!
//! * **Sharded** ([`run_cohort_sharded`]) — when the engine advertises
//!   [`is_send_safe`], the cohort is split into contiguous shards, one per
//!   worker, each with its own [`ClientScratch`] and its own per-shard
//!   aggregator; the shard aggregators are merged in shard order
//!   (deterministic for a fixed worker count; merging only reassociates
//!   f64 sums). No in-tree engine is Send-safe *and executable* yet — the
//!   stub advertises `true` but cannot run training graphs — so today
//!   this path is exercised end-to-end by the mock-job tests below and is
//!   the dispatch a pure-CPU backend will land on.
//! * **Pinned** ([`run_cohort_pinned`]) — the PJRT backend's
//!   `PjRtLoadedExecutable` is `!Send`, so client training stays on the
//!   engine thread; the collected uplink frames are still decoded and
//!   folded over the thread pool (decode is pure Send work).
//! * **Strict sequential** ([`run_cohort_sequential`]) — one thread, one
//!   aggregator, cohort order: the reference the others are compared to,
//!   bit-identical to [`Server::aggregate`] on the same inputs.
//!
//! Per-client RNG streams are keyed by `(seed, round, cid)` — never by
//! worker or execution order — so every path produces identical uploads
//! (asserted by tests below).
//!
//! Cohort failures (`fl::cohort`) are planned before execution: dropped
//! clients consume their downlink and nothing else; late clients train and
//! upload (bytes counted) but are excluded from aggregation; weights are
//! normalized over the completing subset up front, which is what lets the
//! accumulation be one pass.
//!
//! Steady-state allocation discipline: [`RoundScratch`] pools the
//! per-client downlink frame buffers (the pool never shrinks when the
//! cohort does) and the per-worker client codec scratches across rounds.
//! The aggregator f64 sums and decode scratches are allocated fresh each
//! round — O(params × workers), same order as the downlink compression
//! cache the round already builds, and independent of cohort size.
//!
//! [`is_send_safe`]: crate::runtime::engine::LoadedModel::is_send_safe

use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::data::partition::ClientAssignment;
use crate::data::synth::Domain;
use crate::fl::chaos::{self, ChaosClientReport, ChaosConfig};
use crate::fl::client::{self, ClientResult, ClientScratch, ClientTrainConfig};
use crate::fl::cohort::{self, ClientFate, ClientPlan, CohortConfig};
use crate::fl::population::{
    self, EdgeStats, PopulationConfig, PopulationRoundStats,
};
use crate::fl::sampler::Sampler;
use crate::fl::server::{Server, StreamingAggregator};
use crate::omc::codec::{self, NonceLedger};
use crate::omc::delta::DeltaBase;
use crate::omc::selection::SelectionPolicy;
use crate::omc::sparse::{ClientResidual, SparseParams, SparseStore};
use crate::runtime::engine::LoadedModel;
use crate::util::rng::{hash_seed, Xoshiro256pp};
use crate::util::threadpool;

/// Nonce for client `cid`'s uplink frame in `round`. Retries of the same
/// logical update share the nonce (a re-send after a rejected corrupt
/// attempt still passes the server's ledger), while a *duplicated*
/// accepted frame is flagged. Shared with `fl::async_round`.
pub fn uplink_nonce(seed: u64, round: u64, cid: u64) -> u64 {
    hash_seed(&[seed, 0x4E_0C_E1, round, cid])
}

/// Nonce for the downlink frame served to client `cid` in `round`.
pub fn downlink_nonce(seed: u64, round: u64, cid: u64) -> u64 {
    hash_seed(&[seed, 0x4E_0C_E2, round, cid])
}

/// Everything a round needs, borrowed from the experiment.
pub struct RoundContext<'a> {
    /// the bound artifact set (training/eval graphs + manifest)
    pub model: &'a LoadedModel,
    /// synthetic-data domain the clients draw batches from
    pub domain: &'a Domain,
    /// speaker shards per client
    pub assignment: &'a ClientAssignment,
    /// which clients participate each round
    pub sampler: &'a Sampler,
    /// PPQ variable-selection policy
    pub policy: SelectionPolicy,
    /// client-side hyper-parameters
    pub train: ClientTrainConfig,
    /// cohort failure model (dropout / stragglers / weighting)
    pub cohort: CohortConfig,
    /// fault-injection model (`fl::chaos`); `is_off()` skips all planning
    pub chaos: ChaosConfig,
    /// frame all transport in the checksummed v2 wire layout (required
    /// when chaos is enabled — corrupt frames must be detectable)
    pub integrity: bool,
    /// frame uplinks as v3 cross-round deltas against this round's
    /// downlink (requires `integrity`; silently ignored without it —
    /// config validation enforces the pairing upstream). The server-side
    /// base is the round's own [`client::DownlinkCache`], so the sync
    /// engine never has ack lag: every uplink deltas against the packed
    /// payloads the server just committed to the wire.
    pub delta: bool,
    /// uplink sparsification (`omc::sparse`): masked variables ship
    /// top-k / random-k tag-3 records of the error-corrected update, the
    /// unselected mass is banked per client in the engine's
    /// [`SparseStore`] and added back next round. Requires `integrity`
    /// (sparse records only exist on checksummed frames); the server
    /// folds sparse frames against the decompressed downlink values it
    /// just served — no dense client update is ever materialized.
    pub sparse: Option<SparseParams>,
    /// population-scale scenario (`fl::population`); when enabled the
    /// cohort is folded through per-edge aggregators whose merged frames
    /// uplink to the root, device classes scale chaos fault rates, and
    /// shards are read lazily (`ClientAssignment::speakers_of`)
    pub population: PopulationConfig,
    /// clients currently serving a quarantine sentence, excluded from the
    /// sampled cohort this round (ascending; owned by the experiment's
    /// `fl::chaos::Quarantine` ladder)
    pub quarantined: &'a [usize],
    /// experiment seed (all per-round randomness derives from it)
    pub seed: u64,
    /// thread-pool width for codec work and sharded client execution
    pub workers: usize,
}

/// Buffers reused across rounds (owned by the experiment driver).
#[derive(Default)]
pub struct RoundScratch {
    /// pool of downlink frame buffers, recycled round-to-round; excess
    /// buffers stay pooled when the cohort shrinks
    downlink_bufs: Vec<Vec<u8>>,
    /// per-worker client codec scratches (index 0 serves the sequential
    /// path); capacity persists across rounds
    clients: Vec<ClientScratch>,
    /// per-edge verbatim payload from the previous round — the XOR-delta
    /// base for the edge→root hop in population mode (cleared at round 0:
    /// engines are reused across sweep cells)
    edge_prev: Vec<Vec<u8>>,
    /// per-client error-feedback residuals (`omc::sparse`), committed in
    /// plan order after each round; cleared at round 0 because engines
    /// are reused across sweep cells
    sparse: SparseStore,
}

impl RoundScratch {
    /// Fresh, empty scratch (buffers warm up over the first rounds).
    pub fn new() -> Self {
        Self::default()
    }

    /// Take `n` downlink buffers from the pool (empty ones are created if
    /// the pool is short). The pool keeps whatever the caller does not
    /// take, so a shrinking cohort never drops warmed capacity.
    /// Crate-visible: `fl::async_round` shares the pool, so sync and async
    /// cells of one sweep worker recycle the same warmed buffers.
    pub(crate) fn take_downlink_bufs(&mut self, n: usize) -> Vec<Vec<u8>> {
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.downlink_bufs.pop().unwrap_or_default());
        }
        out
    }

    /// Return buffers to the pool for the next round.
    pub(crate) fn return_downlink_bufs(&mut self, bufs: Vec<Vec<u8>>) {
        self.downlink_bufs.extend(bufs);
    }

    /// At least `n` per-worker client scratches, growing (never shrinking)
    /// the persistent set.
    pub(crate) fn client_scratches(&mut self, n: usize) -> &mut [ClientScratch] {
        if self.clients.len() < n {
            self.clients.resize_with(n, ClientScratch::default);
        }
        &mut self.clients[..n]
    }
}

/// Reusable round-execution handle: owns the [`RoundScratch`] so one
/// warmed buffer set can serve *many experiments*, not just many rounds.
/// The sweep engine keeps one `RoundEngine` per worker and threads it
/// through every cell that worker runs — cell-to-cell the downlink pool
/// and per-worker client scratches keep their capacity, which is the same
/// zero-alloc steady state `Experiment` has within a single run.
#[derive(Default)]
pub struct RoundEngine {
    scratch: RoundScratch,
}

impl RoundEngine {
    /// Fresh handle with cold buffers.
    pub fn new() -> Self {
        Self::default()
    }

    /// Run one round against this handle's persistent scratch.
    pub fn run(
        &mut self,
        ctx: &RoundContext<'_>,
        server: &mut Server,
    ) -> Result<RoundOutcome> {
        run_round(ctx, server, &mut self.scratch)
    }

    /// Direct access to the pooled buffers (for accounting/tests).
    pub fn scratch_mut(&mut self) -> &mut RoundScratch {
        &mut self.scratch
    }
}

/// Aggregate numbers for one completed round.
#[derive(Clone, Debug)]
pub struct RoundOutcome {
    /// mean training loss over clients that ran (completing + late);
    /// NaN when the whole cohort dropped before training
    pub mean_loss: f64,
    /// server→client bytes, all sampled clients (dropped ones included —
    /// the server spent those bytes before learning of the drop)
    pub down_bytes: usize,
    /// client→server bytes, every client that uploaded (late included)
    pub up_bytes: usize,
    /// the subset of `up_bytes` from past-deadline clients, spent but
    /// excluded from aggregation
    pub up_bytes_discarded: usize,
    /// max client parameter-store bytes observed (Sec. 3.4)
    pub peak_client_param_bytes: usize,
    /// accounted server-side aggregation working set: accumulators + decode
    /// scratch. O(params × workers); must not scale with cohort size.
    pub server_accum_bytes: usize,
    /// sampled client ids, in cohort order
    pub participants: Vec<usize>,
    /// cohort size sampled this round
    pub sampled: usize,
    /// clients aggregated (reported before the deadline)
    pub completed: usize,
    /// clients that dropped after the downlink
    pub dropped: usize,
    /// clients that reported after the deadline
    pub late: usize,
    /// clients killed by chaos: crashed before training, or gave up after
    /// exhausting uplink retries
    pub crashed: usize,
    /// uplink frames the server rejected (corrupt attempts + duplicates)
    pub frames_rejected: u64,
    /// the subset of `up_bytes` from rejected frames
    pub up_bytes_rejected: usize,
    /// uplink bytes the v3 delta stage saved vs verbatim framing, summed
    /// over every client that built an upload (zero when delta is off)
    pub up_bytes_delta_saved: usize,
    /// uplink bytes the sparse stage saved vs dense packed records,
    /// summed over every client that built an upload (zero when off)
    pub up_bytes_sparse_saved: usize,
    /// coordinates shipped by the sparse stage across trained clients
    pub sparse_selected: u64,
    /// total sparsifiable coordinates across trained clients (the
    /// denominator of the sweep's `sparsity` metric)
    pub sparse_total: u64,
    /// squared L2 mass of the error-feedback residuals banked this round
    pub sparse_residual_sq: f64,
    /// per-client chaos facts for the quarantine ladder (empty when chaos
    /// is off): corrupt-frame counts and whether a clean frame landed
    pub chaos_reports: Vec<ChaosClientReport>,
    /// population-mode round facts (sampling tallies, per-class
    /// completions, edge transport); `None` outside population mode
    pub population: Option<PopulationRoundStats>,
}

/// Byte/loss tallies from executing (part of) a cohort.
#[derive(Clone, Copy, Debug, Default)]
pub struct CohortStats {
    /// uplink bytes from every client that uploaded
    pub up_bytes: usize,
    /// uplink bytes from late clients (subset of `up_bytes`)
    pub up_bytes_discarded: usize,
    /// sum of per-client mean losses (over clients that trained)
    pub loss_sum: f64,
    /// clients that ran training (completing + late)
    pub trained: usize,
    /// clients folded into the aggregator
    pub completed: usize,
    /// clients skipped entirely
    pub dropped: usize,
    /// clients that uploaded past the deadline
    pub late: usize,
    /// clients killed by chaos (crash, or retries exhausted)
    pub crashed: usize,
    /// uplink frames rejected by verification (corrupt + duplicates)
    pub frames_rejected: u64,
    /// uplink bytes from rejected frames (subset of `up_bytes`)
    pub up_bytes_rejected: usize,
    /// bytes the delta stage saved vs verbatim framing (uploads built)
    pub up_bytes_delta_saved: usize,
    /// bytes the sparse stage saved vs dense packed records
    pub up_bytes_sparse_saved: usize,
    /// coordinates shipped by the sparse stage
    pub sparse_selected: u64,
    /// total sparsifiable coordinates seen by the sparse stage
    pub sparse_total: u64,
    /// squared residual mass banked by trained clients
    pub sparse_residual_sq: f64,
    /// max per-client parameter-store bytes
    pub peak_client_param_bytes: usize,
    /// decode-scratch capacity, bytes (summed across workers)
    pub scratch_bytes: usize,
    /// bytes of every live aggregator (chunk accumulators, plus the merge
    /// target on the sharded path)
    pub accum_bytes: usize,
}

impl CohortStats {
    /// Account one trained client's result (loss, peaks, per-stage
    /// savings) — shared by every cohort execution path.
    fn absorb_client(&mut self, r: &ClientResult) {
        self.loss_sum += r.loss;
        self.trained += 1;
        self.peak_client_param_bytes =
            self.peak_client_param_bytes.max(r.peak_param_bytes);
        self.up_bytes_delta_saved += r.delta_saved;
        self.up_bytes_sparse_saved += r.sparse_saved;
        self.sparse_selected += r.sparse_selected;
        self.sparse_total += r.sparse_total;
        self.sparse_residual_sq += r.sparse_residual_sq;
    }

    fn absorb(&mut self, o: &CohortStats) {
        self.up_bytes += o.up_bytes;
        self.up_bytes_discarded += o.up_bytes_discarded;
        self.loss_sum += o.loss_sum;
        self.trained += o.trained;
        self.completed += o.completed;
        self.dropped += o.dropped;
        self.late += o.late;
        self.crashed += o.crashed;
        self.frames_rejected += o.frames_rejected;
        self.up_bytes_rejected += o.up_bytes_rejected;
        self.up_bytes_delta_saved += o.up_bytes_delta_saved;
        self.up_bytes_sparse_saved += o.up_bytes_sparse_saved;
        self.sparse_selected += o.sparse_selected;
        self.sparse_total += o.sparse_total;
        self.sparse_residual_sq += o.sparse_residual_sq;
        self.peak_client_param_bytes =
            self.peak_client_param_bytes.max(o.peak_client_param_bytes);
        self.scratch_bytes += o.scratch_bytes;
        self.accum_bytes += o.accum_bytes;
    }

    /// Accounted server-side aggregation working set: every live
    /// accumulator plus the decode scratches. O(params × workers) — the
    /// cohort-independence tests read this.
    pub fn server_accum_bytes(&self) -> usize {
        self.accum_bytes + self.scratch_bytes
    }
}

/// Replay a client's planned corrupt uplink attempts against the wire
/// verifier and account each rejection. Every replayed frame MUST fail
/// verification — an accepted corrupt frame is an integrity-layer bug and
/// errors out loudly (the acceptance contract: zero silently-accepted
/// corrupt frames).
fn reject_corrupt_attempts(
    plan: &ClientPlan,
    upload: &[u8],
    stats: &mut CohortStats,
    ledger: &mut NonceLedger,
) -> Result<()> {
    let Some(ch) = plan.chaos.as_ref() else {
        return Ok(());
    };
    for f in &ch.faults {
        let mut bad = upload.to_vec();
        chaos::apply_fault(f, &mut bad);
        let verdict = codec::verify_frame(&bad)
            .and_then(|info| ledger.observe(info.nonce));
        anyhow::ensure!(
            verdict.is_err(),
            "chaos-corrupted frame from client {} passed verification \
             (is wire integrity enabled?)",
            plan.cid
        );
        stats.frames_rejected += 1;
        stats.up_bytes += bad.len();
        stats.up_bytes_rejected += bad.len();
    }
    Ok(())
}

/// Account a planned duplicate replay of an already-accepted frame: the
/// ledger must flag it (same nonce), and its bytes count as rejected.
fn reject_duplicate(
    plan: &ClientPlan,
    upload: &[u8],
    stats: &mut CohortStats,
    ledger: &mut NonceLedger,
) -> Result<()> {
    if !plan.chaos.as_ref().map_or(false, |c| c.duplicate) {
        return Ok(());
    }
    let verdict = codec::verify_frame(upload)
        .and_then(|info| ledger.observe(info.nonce));
    anyhow::ensure!(
        verdict.is_err(),
        "duplicated uplink from client {} was accepted twice",
        plan.cid
    );
    stats.frames_rejected += 1;
    stats.up_bytes += upload.len();
    stats.up_bytes_rejected += upload.len();
    Ok(())
}

/// Execute one contiguous chunk of the cohort: run each non-dropped
/// client's job, account its bytes, and fold completing uploads straight
/// into a chunk-local [`StreamingAggregator`] (the upload is dropped
/// immediately after — decoded client models never accumulate).
///
/// Every frame headed for the accumulator is verified first
/// ([`codec::verify_frame`]: structural walk for v1 frames, full CRC +
/// nonce check for v2) — [`StreamingAggregator::accumulate_wire`] folds
/// progressively, so rejection must happen before the sums are touched.
/// Chaos-planned corrupt attempts and duplicates are replayed against the
/// verifier and accounted as rejected.
///
/// `dbase` is the server-held delta base for v3 uplinks (the round's
/// downlink payloads); `None` decodes verbatim frames only — a v3 frame
/// arriving without a base is a typed decode error, never a wrong fold.
/// `sbase` is the server-held decompressed downlink values for sparse
/// (tag-3) records; `None` rejects sparse frames as harness bugs.
#[allow(clippy::too_many_arguments)]
fn run_chunk<F>(
    base: usize,
    chunk: &[ClientPlan],
    norm_w: &[f64],
    var_lens: &[usize],
    dbase: Option<&DeltaBase<'_>>,
    sbase: Option<&[Vec<f32>]>,
    scratch: &mut ClientScratch,
    mut job: F,
) -> Result<(CohortStats, StreamingAggregator)>
where
    F: FnMut(usize, &ClientPlan, &mut ClientScratch) -> Result<ClientResult>,
{
    let mut agg = StreamingAggregator::new(var_lens);
    let mut stats = CohortStats::default();
    let mut decode_scratch: Vec<f32> = Vec::new();
    let mut ledger = NonceLedger::new(chunk.len().max(8) * 2);
    for (k, plan) in chunk.iter().enumerate() {
        let i = base + k;
        match plan.fate {
            ClientFate::Dropped => {
                stats.dropped += 1;
                continue;
            }
            ClientFate::Crashed => {
                // gave-up clients trained and sent only corrupt frames;
                // plain crashes died before training and sent nothing
                let gave_up = plan
                    .chaos
                    .as_ref()
                    .map_or(false, |c| c.gave_up && !c.crashed);
                if gave_up {
                    let r = job(i, plan, scratch)?;
                    stats.absorb_client(&r);
                    reject_corrupt_attempts(plan, &r.upload, &mut stats, &mut ledger)?;
                }
                stats.crashed += 1;
                continue;
            }
            _ => {}
        }
        let r = job(i, plan, scratch)?;
        stats.absorb_client(&r);
        if plan.fate == ClientFate::Late {
            stats.up_bytes += r.upload.len();
            stats.late += 1;
            stats.up_bytes_discarded += r.upload.len();
            continue;
        }
        // chaos retries precede the clean delivery
        reject_corrupt_attempts(plan, &r.upload, &mut stats, &mut ledger)?;
        stats.up_bytes += r.upload.len();
        codec::verify_frame(&r.upload)
            .and_then(|info| ledger.observe(info.nonce))
            .map_err(|e| {
                anyhow::anyhow!(
                    "uplink from client {} failed verification outside the \
                     chaos plan: {e}",
                    plan.cid
                )
            })?;
        agg.accumulate_wire_with(
            &r.upload,
            norm_w[i],
            &mut decode_scratch,
            dbase,
            sbase,
        )?;
        stats.completed += 1;
        reject_duplicate(plan, &r.upload, &mut stats, &mut ledger)?;
    }
    stats.scratch_bytes = decode_scratch.capacity() * 4;
    stats.accum_bytes = agg.memory_bytes();
    Ok((stats, agg))
}

/// Run a planned cohort strictly in order on the calling thread with one
/// shared [`ClientScratch`] — the pinned path the PJRT backend requires
/// (`PjRtLoadedExecutable` is `!Send`). Folding happens in cohort order,
/// so the result is bit-identical to the reference [`Server::aggregate`]
/// fed the same decoded models and normalized weights.
pub fn run_cohort_sequential<F>(
    plans: &[ClientPlan],
    norm_w: &[f64],
    var_lens: &[usize],
    dbase: Option<&DeltaBase<'_>>,
    sbase: Option<&[Vec<f32>]>,
    scratch: &mut ClientScratch,
    job: F,
) -> Result<(CohortStats, StreamingAggregator)>
where
    F: FnMut(usize, &ClientPlan, &mut ClientScratch) -> Result<ClientResult>,
{
    run_chunk(0, plans, norm_w, var_lens, dbase, sbase, scratch, job)
}

/// Run a planned cohort with training pinned to the calling thread but
/// uplink *decode* parallelized: clients execute strictly in order (the
/// PJRT backend's `!Send` executable requirement), completing uploads are
/// collected as wire frames, and the frames are then folded into
/// per-chunk streaming accumulators over the thread pool, merged in chunk
/// order.
///
/// Memory: the collected wire frames are the compressed in-flight
/// transport (the pre-streaming engine held these too) plus
/// O(params × workers) accumulators — the decoded cohort still never
/// materializes. With `workers == 1` the result is bit-identical to
/// [`run_cohort_sequential`]; larger worker counts only reassociate the
/// f64 sums.
#[allow(clippy::too_many_arguments)]
pub fn run_cohort_pinned<F>(
    plans: &[ClientPlan],
    norm_w: &[f64],
    var_lens: &[usize],
    dbase: Option<&DeltaBase<'_>>,
    sbase: Option<&[Vec<f32>]>,
    workers: usize,
    scratch: &mut ClientScratch,
    mut job: F,
) -> Result<(CohortStats, StreamingAggregator)>
where
    F: FnMut(usize, &ClientPlan, &mut ClientScratch) -> Result<ClientResult>,
{
    let mut stats = CohortStats::default();
    let mut uploads: Vec<(usize, Vec<u8>)> = Vec::new();
    // verification runs here on the pinned thread (cohort order, one
    // ledger for the whole cohort); only verified-clean frames reach the
    // pooled fold below
    let mut ledger = NonceLedger::new(plans.len().max(8) * 2);
    for (i, plan) in plans.iter().enumerate() {
        match plan.fate {
            ClientFate::Dropped => {
                stats.dropped += 1;
                continue;
            }
            ClientFate::Crashed => {
                let gave_up = plan
                    .chaos
                    .as_ref()
                    .map_or(false, |c| c.gave_up && !c.crashed);
                if gave_up {
                    let r = job(i, plan, scratch)?;
                    stats.absorb_client(&r);
                    reject_corrupt_attempts(plan, &r.upload, &mut stats, &mut ledger)?;
                }
                stats.crashed += 1;
                continue;
            }
            _ => {}
        }
        let r = job(i, plan, scratch)?;
        stats.absorb_client(&r);
        if plan.fate == ClientFate::Late {
            stats.up_bytes += r.upload.len();
            stats.late += 1;
            stats.up_bytes_discarded += r.upload.len();
            continue;
        }
        reject_corrupt_attempts(plan, &r.upload, &mut stats, &mut ledger)?;
        stats.up_bytes += r.upload.len();
        codec::verify_frame(&r.upload)
            .and_then(|info| ledger.observe(info.nonce))
            .map_err(|e| {
                anyhow::anyhow!(
                    "uplink from client {} failed verification outside the \
                     chaos plan: {e}",
                    plan.cid
                )
            })?;
        stats.completed += 1;
        reject_duplicate(plan, &r.upload, &mut stats, &mut ledger)?;
        uploads.push((i, r.upload));
    }
    let agg = aggregate_uploads(
        &uploads, norm_w, var_lens, dbase, sbase, workers, &mut stats,
    )?;
    Ok((stats, agg))
}

/// Fold collected `(cohort index, wire frame)` uploads into one merged
/// streaming aggregator, chunked over the thread pool; accounting lands in
/// `stats` (`scratch_bytes`, `accum_bytes`). `dbase` resolves v3 delta
/// payloads and `sbase` resolves sparse records (both shared read-only
/// across the pooled workers).
#[allow(clippy::too_many_arguments)]
fn aggregate_uploads(
    uploads: &[(usize, Vec<u8>)],
    norm_w: &[f64],
    var_lens: &[usize],
    dbase: Option<&DeltaBase<'_>>,
    sbase: Option<&[Vec<f32>]>,
    workers: usize,
    stats: &mut CohortStats,
) -> Result<StreamingAggregator> {
    let mut merged = StreamingAggregator::new(var_lens);
    if uploads.is_empty() {
        stats.accum_bytes += merged.memory_bytes();
        return Ok(merged);
    }
    let shards = workers.max(1).min(uploads.len());
    let chunk = (uploads.len() + shards - 1) / shards;
    let chunks: Vec<&[(usize, Vec<u8>)]> = uploads.chunks(chunk).collect();
    let parts = threadpool::scope_map_send(chunks, shards, |_, c| {
        let mut agg = StreamingAggregator::new(var_lens);
        let mut decode_scratch: Vec<f32> = Vec::new();
        for (i, wire) in c {
            agg.accumulate_wire_with(
                wire,
                norm_w[*i],
                &mut decode_scratch,
                dbase,
                sbase,
            )?;
        }
        Ok::<_, anyhow::Error>((decode_scratch.capacity() * 4, agg))
    })?;
    for p in parts {
        let (scratch_bytes, agg) = p?;
        stats.scratch_bytes += scratch_bytes;
        stats.accum_bytes += agg.memory_bytes();
        merged.merge(agg)?;
    }
    stats.accum_bytes += merged.memory_bytes();
    Ok(merged)
}

/// Run a planned cohort sharded over the thread pool: contiguous chunks,
/// one per worker, each with its own [`ClientScratch`] and per-shard
/// aggregator; shard aggregators merge in shard order. Requires a
/// `Send`-safe engine (the job closure must be `Sync`). Uploads are
/// bit-identical to the sequential path — per-client RNG streams depend
/// only on `(seed, round, cid)` — and the merged aggregate differs from it
/// only by f64 re-association (≤ 1e-6 per element).
#[allow(clippy::too_many_arguments)]
pub fn run_cohort_sharded<F>(
    plans: &[ClientPlan],
    norm_w: &[f64],
    var_lens: &[usize],
    dbase: Option<&DeltaBase<'_>>,
    sbase: Option<&[Vec<f32>]>,
    workers: usize,
    scratches: &mut [ClientScratch],
    job: F,
) -> Result<(CohortStats, StreamingAggregator)>
where
    F: Fn(usize, &ClientPlan, &mut ClientScratch) -> Result<ClientResult> + Sync,
{
    let n = plans.len();
    if n == 0 {
        return Ok((CohortStats::default(), StreamingAggregator::new(var_lens)));
    }
    let shards = workers.max(1).min(n);
    anyhow::ensure!(
        scratches.len() >= shards,
        "need one ClientScratch per shard ({} < {shards})",
        scratches.len()
    );
    let chunk = (n + shards - 1) / shards;
    let items: Vec<(usize, &[ClientPlan], &mut ClientScratch)> = plans
        .chunks(chunk)
        .zip(scratches.iter_mut())
        .enumerate()
        .map(|(si, (c, s))| (si * chunk, c, s))
        .collect();
    let job = &job;
    let results = threadpool::scope_map_send(items, shards, move |_, (base, c, s)| {
        run_chunk(base, c, norm_w, var_lens, dbase, sbase, s, job)
    })?;
    let mut stats = CohortStats::default();
    let mut agg = StreamingAggregator::new(var_lens);
    for r in results {
        let (s, a) = r?;
        stats.absorb(&s);
        agg.merge(a)?;
    }
    // the merge target coexisted with the chunk accumulators
    stats.accum_bytes += agg.memory_bytes();
    Ok((stats, agg))
}

/// Number of shards the engine would use for this cohort/worker pair.
#[cfg_attr(feature = "pjrt", allow(dead_code))]
fn shard_count(workers: usize, cohort: usize) -> usize {
    workers.max(1).min(cohort.max(1))
}

/// Two-tier population-mode execution: the cohort is split into
/// contiguous per-edge chunks, each folded through its own
/// [`StreamingAggregator`] by [`run_chunk`] (the same accept/reject logic
/// as every other path), and each edge then uplinks ONE merged frame —
/// weighted f64 sums cast to f32, re-widened losslessly at the root — over
/// the integrity/delta edge→root hop (`fl::population`).
///
/// Clients run strictly in cohort order on the calling thread, so the
/// result is worker-count independent by construction. With `edges == 1`
/// the root model is bit-identical to [`run_cohort_sequential`] (one cast
/// round-trip of each final sum, which f32→f64→f32 preserves); with more
/// edges the root differs from flat aggregation only by f64
/// re-association plus one f32 cast per edge (≤ 1e-6 per element — the
/// documented shard-merge tolerance, pinned by tests below).
///
/// `edge_prev` holds each edge's previous-round verbatim payload (the
/// XOR-delta base); it is cleared at round 0 because engines are reused
/// across sweep cells.
#[allow(clippy::too_many_arguments)]
pub fn run_cohort_edged<F>(
    plans: &[ClientPlan],
    norm_w: &[f64],
    var_lens: &[usize],
    dbase: Option<&DeltaBase<'_>>,
    sbase: Option<&[Vec<f32>]>,
    edges: usize,
    integrity: bool,
    delta: bool,
    seed: u64,
    round: u64,
    edge_prev: &mut Vec<Vec<u8>>,
    scratch: &mut ClientScratch,
    mut job: F,
) -> Result<(CohortStats, StreamingAggregator, EdgeStats)>
where
    F: FnMut(usize, &ClientPlan, &mut ClientScratch) -> Result<ClientResult>,
{
    let edges = edges.max(1);
    if round == 0 {
        edge_prev.clear();
    }
    if edge_prev.len() < edges {
        edge_prev.resize_with(edges, Vec::new);
    }
    let mut stats = CohortStats::default();
    let mut root = StreamingAggregator::new(var_lens);
    let mut ledger = NonceLedger::new(edges.max(8) * 2);
    let mut edge_stats = EdgeStats::default();
    let n = plans.len();
    let chunk = if n == 0 { 0 } else { (n + edges - 1) / edges };
    for e in 0..edges {
        let lo = (e * chunk).min(n);
        let hi = ((e + 1) * chunk).min(n);
        if lo >= hi {
            continue;
        }
        let (s, edge_agg) = run_chunk(
            lo,
            &plans[lo..hi],
            norm_w,
            var_lens,
            dbase,
            sbase,
            scratch,
            &mut job,
        )?;
        stats.absorb(&s);
        if edge_agg.clients() == 0 {
            // every client on this edge dropped/crashed/missed: nothing
            // goes on the wire and the delta base stands for next round
            continue;
        }
        let nonce = population::edge_nonce(seed, round, e);
        let frame = population::encode_edge_frame(
            &edge_agg,
            integrity,
            nonce,
            delta,
            &edge_prev[e],
        );
        edge_stats.frames += 1;
        edge_stats.up_bytes += frame.shipped.len() as u64;
        edge_stats.delta_saved += frame.delta_saved;
        let verbatim = population::decode_edge_frame(
            &frame.shipped,
            &edge_prev[e],
            &mut root,
            &mut ledger,
            integrity.then_some(nonce),
        )
        .with_context(|| format!("edge {e} round {round}"))?;
        edge_prev[e] = verbatim;
    }
    // the root coexists with the (transient, one-at-a-time) edge
    // accumulators already absorbed above
    stats.accum_bytes += root.memory_bytes();
    Ok((stats, root, edge_stats))
}

/// Run one federated round, updating `server` in place.
pub fn run_round(
    ctx: &RoundContext<'_>,
    server: &mut Server,
    scratch: &mut RoundScratch,
) -> Result<RoundOutcome> {
    let round = server.round as u64;
    let pop_on = ctx.population.enabled;
    // population mode samples lazily (rejection sampling over churn/wave
    // availability) and reports its tallies; classic samplers return None
    let (mut participants, sample_stats) =
        ctx.sampler.try_sample_with_stats(round)?;
    // quarantined clients sit the round out entirely: no downlink, no
    // training, no accounting (the ladder owns their exclusion window)
    if !ctx.quarantined.is_empty() {
        participants.retain(|c| !ctx.quarantined.contains(c));
    }
    let specs = &ctx.model.manifest.variables;

    // every sampled client's fate is decided before anything executes —
    // deterministic in (seed, round, cid), so the completing subset and
    // its normalized FedAvg weights are known up front
    let mut plans = cohort::plan_cohort_with(
        &ctx.cohort,
        &participants,
        ctx.assignment,
        ctx.seed,
        round,
        Some(&ctx.population),
    );

    // chaos fate upgrades, planned before any execution (deterministic in
    // (seed, round, cid) exactly like the cohort plan). Only clients the
    // cohort model had completing are touched: crash/give-up become
    // Crashed, retry backoff can push a client past the deadline (Late).
    // Reports feed the experiment's quarantine ladder — one per client
    // that delivered (clean or gave up), so clean rounds reset strikes.
    let mut chaos_reports: Vec<ChaosClientReport> = Vec::new();
    if !ctx.chaos.is_off() {
        anyhow::ensure!(
            ctx.integrity,
            "chaos injection requires wire integrity (omc.integrity) — \
             corrupt frames must be detectable"
        );
        for plan in &mut plans {
            // device classes scale fault rates: budget/IoT hardware
            // corrupts and crashes more often (stream alignment is
            // untouched — plan_client draws the same variates and only
            // the thresholds move)
            let ccfg = if pop_on {
                ctx.chaos.scaled(
                    population::DEVICE_CLASSES
                        [population::class_of(ctx.seed, plan.cid)]
                    .fault_mult,
                )
            } else {
                ctx.chaos
            };
            let ch = chaos::plan_client(&ccfg, ctx.seed, round, plan.cid);
            if plan.fate != ClientFate::Completes {
                // dropped/late clients never reach the verifier; keep the
                // plan for determinism but inject nothing
                continue;
            }
            if ch.crashed || ch.gave_up {
                plan.fate = ClientFate::Crashed;
                if ch.gave_up && !ch.crashed {
                    chaos_reports.push(ChaosClientReport {
                        cid: plan.cid,
                        corrupt_frames: ch.faults.len() as u32,
                        delivered_clean: false,
                    });
                }
            } else if plan.latency_s + ch.extra_latency_s > ctx.cohort.deadline_s {
                // retry backoff pushed the clean delivery past the
                // deadline; the corrupt attempts are discarded unverified
                // along with it, so no report is filed
                plan.fate = ClientFate::Late;
            } else {
                chaos_reports.push(ChaosClientReport {
                    cid: plan.cid,
                    corrupt_frames: ch.faults.len() as u32,
                    delivered_clean: true,
                });
            }
            plan.chaos = Some(ch);
        }
    }

    // per-client PPQ masks + downlink payloads, for ALL sampled clients —
    // the server commits the downlink before it can know a client will
    // drop or miss the deadline. Each variable is compressed ONCE per
    // round (DownlinkCache, §Perf, built in parallel over the thread
    // pool) and the per-client payloads are assembled on the thread pool
    // into pooled buffers.
    let masks: Vec<Vec<f32>> = participants
        .iter()
        .map(|&c| ctx.policy.draw_mask(specs, ctx.seed, round, c as u64))
        .collect();
    // copy plain values out of ctx: the closures must not capture the
    // !Sync LoadedModel reference
    let (fmt, use_pvt, workers) = (ctx.train.format, ctx.train.use_pvt, ctx.workers);
    let global = &server.params;
    let cache = client::DownlinkCache::build(global, fmt, use_pvt, workers, |i| {
        masks.iter().any(|m| m[i] > 0.5)
    });
    let cache_ref = &cache;
    let bufs = scratch.take_downlink_bufs(masks.len());
    let (seed, integrity) = (ctx.seed, ctx.integrity);
    let items: Vec<(usize, &Vec<f32>, Vec<u8>)> = participants
        .iter()
        .copied()
        .zip(masks.iter().zip(bufs))
        .map(|(cid, (mask, buf))| (cid, mask, buf))
        .collect();
    let downlinks: Vec<Vec<u8>> =
        threadpool::scope_map_send(items, workers, move |_, (cid, mask, buf)| {
            let nonce = if integrity {
                Some(downlink_nonce(seed, round, cid as u64))
            } else {
                None
            };
            cache_ref.assemble_frame(global, mask, buf, nonce)
        })?;
    let down_bytes: usize = downlinks.iter().map(|d| d.len()).sum();

    // FedAvg weights, normalized over the clients planned to complete
    let norm_w = cohort::normalized_weights(&plans);

    // v3 delta stage: clients XOR their packed uplink against the packed
    // downlink payloads they just received; the server's base is the same
    // per-round compression cache those payloads were assembled from, so
    // the exchanged base version is always this round number (no ack lag
    // in the sync engine — the async engine handles lagging acks)
    let delta_on = ctx.delta && ctx.integrity;
    let dbase = delta_on
        .then(|| DeltaBase::from_packed_vars(round, cache_ref.packed_vars()));

    // sparse uplink stage: per-client error-feedback residuals are keyed
    // by cid and persist across rounds in the round scratch (cleared at
    // round 0 because engines are reused across sweep cells). The store is
    // taken out for the dispatch — jobs read their own client's residual
    // through a shared reference and deposit the successor into a
    // per-cohort-index slot; slots are committed back in plan order below,
    // so the store's contents never depend on worker scheduling. The
    // server's fold base is the dense view of the SAME downlink the
    // clients decoded: packed vars decompressed, fp32 vars verbatim.
    let sparse_on = ctx.sparse.is_some() && ctx.integrity;
    if round == 0 {
        scratch.sparse.clear();
    }
    let sparse_store = std::mem::take(&mut scratch.sparse);
    let sparse_base: Option<Vec<Vec<f32>>> = sparse_on.then(|| {
        cache_ref
            .packed_vars()
            .iter()
            .enumerate()
            .map(|(i, p)| match p {
                Some(sv) => sv.decompress(),
                None => global[i].clone(),
            })
            .collect()
    });
    let residual_slots: Vec<Mutex<Option<ClientResidual>>> =
        (0..plans.len()).map(|_| Mutex::new(None)).collect();
    let residual_slots_ref = &residual_slots;
    let sparse_store_ref = &sparse_store;

    let var_lens = server.var_lens();
    let job = |i: usize, plan: &ClientPlan, cs: &mut ClientScratch| {
        let mut rng = Xoshiro256pp::new(hash_seed(&[
            ctx.seed,
            0xC11E27,
            round,
            plan.cid as u64,
        ]));
        let mut tc = ctx.train;
        if ctx.integrity {
            tc.uplink_nonce = Some(uplink_nonce(ctx.seed, round, plan.cid as u64));
        }
        if delta_on {
            tc.delta_base = Some(round);
        }
        if sparse_on {
            if let Some(sp) = ctx.sparse {
                tc.sparse = Some(sp.bind(ctx.seed, round, plan.cid as u64));
            }
        }
        // speakers_of works in dense AND lazy modes (population-scale
        // assignments never materialize per-client shard vectors)
        let shard = ctx.assignment.speakers_of(plan.cid);
        let mut r = client::run_client_round(
            ctx.model,
            ctx.domain,
            shard.as_ref(),
            &downlinks[i],
            &masks[i],
            tc,
            &mut rng,
            cs,
            sparse_store_ref.get(plan.cid as u64),
        )
        .with_context(|| format!("client {} round {round}", plan.cid))?;
        if let Some(res) = r.residual.take() {
            *residual_slots_ref[i].lock().unwrap() = Some(res);
        }
        Ok(r)
    };

    // dispatch: sharded client execution needs a Send-safe engine; the
    // PJRT executable is !Send, so that build pins training to this
    // thread (the sharded generic is only instantiated where the job
    // closure is Sync)
    #[cfg(not(feature = "pjrt"))]
    let (stats, agg, edge_stats) = {
        if pop_on {
            // two-tier topology: per-edge fold + merged uplink to the
            // root, strictly in cohort order on this thread (the path is
            // worker-count independent by construction). Split-borrow the
            // scratch so the edge delta bases and a client scratch can be
            // held simultaneously.
            let RoundScratch {
                edge_prev, clients, ..
            } = &mut *scratch;
            if clients.is_empty() {
                clients.resize_with(1, ClientScratch::default);
            }
            let (s, a, es) = run_cohort_edged(
                &plans,
                &norm_w,
                &var_lens,
                dbase.as_ref(),
                sparse_base.as_deref(),
                ctx.population.edges,
                ctx.integrity,
                delta_on,
                ctx.seed,
                round,
                edge_prev,
                &mut clients[0],
                job,
            )?;
            (s, a, Some(es))
        } else {
            let shards = shard_count(ctx.workers, plans.len());
            if ctx.model.is_send_safe() && shards > 1 {
                let scratches = scratch.client_scratches(shards);
                let (s, a) = run_cohort_sharded(
                    &plans,
                    &norm_w,
                    &var_lens,
                    dbase.as_ref(),
                    sparse_base.as_deref(),
                    shards,
                    scratches,
                    job,
                )?;
                (s, a, None)
            } else {
                let cs = &mut scratch.client_scratches(1)[0];
                let (s, a) = run_cohort_pinned(
                    &plans,
                    &norm_w,
                    &var_lens,
                    dbase.as_ref(),
                    sparse_base.as_deref(),
                    ctx.workers,
                    cs,
                    job,
                )?;
                (s, a, None)
            }
        }
    };
    #[cfg(feature = "pjrt")]
    let (stats, agg, edge_stats) = {
        if pop_on {
            let RoundScratch {
                edge_prev, clients, ..
            } = &mut *scratch;
            if clients.is_empty() {
                clients.resize_with(1, ClientScratch::default);
            }
            let (s, a, es) = run_cohort_edged(
                &plans,
                &norm_w,
                &var_lens,
                dbase.as_ref(),
                sparse_base.as_deref(),
                ctx.population.edges,
                ctx.integrity,
                delta_on,
                ctx.seed,
                round,
                edge_prev,
                &mut clients[0],
                job,
            )?;
            (s, a, Some(es))
        } else {
            // training is pinned (!Send executable) but uplink decode is
            // pure Send work — keep it on the thread pool
            let cs = &mut scratch.client_scratches(1)[0];
            let (s, a) = run_cohort_pinned(
                &plans,
                &norm_w,
                &var_lens,
                dbase.as_ref(),
                sparse_base.as_deref(),
                ctx.workers,
                cs,
                job,
            )?;
            (s, a, None)
        }
    };

    // bank the error-feedback residuals in plan order — deterministic
    // regardless of which worker deposited them. Gave-up and late clients
    // commit too: their training (and selection) ran, the fates were
    // planned before execution, so the stream stays aligned with a
    // chaos-free twin's accounting even though their frames never folded.
    let mut sparse_store = sparse_store;
    for (i, plan) in plans.iter().enumerate() {
        if let Some(res) = residual_slots[i].lock().unwrap().take() {
            sparse_store.commit(plan.cid as u64, res);
        }
    }
    scratch.sparse = sparse_store;

    // recycle the downlink frame buffers for the next round
    scratch.return_downlink_bufs(downlinks);

    // accounted server working set for aggregation — O(params × workers),
    // never O(cohort × params)
    let server_accum_bytes = stats.server_accum_bytes();

    if agg.clients() > 0 {
        agg.apply(server)?;
    } else {
        // the whole cohort dropped or missed the deadline: the global
        // model stands, but the round still happened and is accounted
        crate::log_debug!("round {round}: no completing clients, skipping FedAvg");
        server.skip_round();
    }

    // population-mode round facts: sampling tallies straight from the
    // sampler, per-class completions from the final (chaos-upgraded)
    // plans, edge transport from the two-tier fold
    let population = pop_on.then(|| {
        let mut class_completed = [0u64; population::NUM_CLASSES];
        for plan in &plans {
            if plan.fate == ClientFate::Completes {
                class_completed
                    [population::class_of(ctx.seed, plan.cid)] += 1;
            }
        }
        PopulationRoundStats {
            registered: ctx.population.registered,
            edges: ctx.population.edges,
            sample: sample_stats.unwrap_or_default(),
            class_completed,
            edge: edge_stats.unwrap_or_default(),
        }
    });

    Ok(RoundOutcome {
        // NaN, not a perfect-looking 0.0, when no client trained at all
        mean_loss: if stats.trained > 0 {
            stats.loss_sum / stats.trained as f64
        } else {
            f64::NAN
        },
        down_bytes,
        up_bytes: stats.up_bytes,
        up_bytes_discarded: stats.up_bytes_discarded,
        peak_client_param_bytes: stats.peak_client_param_bytes,
        server_accum_bytes,
        sampled: plans.len(),
        completed: stats.completed,
        dropped: stats.dropped,
        late: stats.late,
        crashed: stats.crashed,
        frames_rejected: stats.frames_rejected,
        up_bytes_rejected: stats.up_bytes_rejected,
        up_bytes_delta_saved: stats.up_bytes_delta_saved,
        up_bytes_sparse_saved: stats.up_bytes_sparse_saved,
        sparse_selected: stats.sparse_selected,
        sparse_total: stats.sparse_total,
        sparse_residual_sq: stats.sparse_residual_sq,
        chaos_reports,
        population,
        participants,
    })
}

#[cfg(test)]
mod tests {
    // run_round itself requires compiled artifacts (integration tests in
    // rust/tests/fl_integration.rs). The cohort execution machinery —
    // sequential/sharded dispatch, streaming aggregation, buffer pooling —
    // is pure Rust and tested here with a mock client job.
    use std::sync::Mutex;

    use super::*;
    use crate::omc::codec::{self, WireWriter};

    const VAR_LENS: [usize; 2] = [300, 17];

    fn mk_plans(n: usize, fate: impl Fn(usize) -> ClientFate) -> Vec<ClientPlan> {
        (0..n)
            .map(|i| ClientPlan {
                cid: 100 + i,
                fate: fate(i),
                latency_s: 0.0,
                weight: 1.0 + (i % 3) as f64,
                chaos: None,
            })
            .collect()
    }

    // the production weight rule itself — tests must exercise the same
    // code run_round uses, not a copy
    use crate::fl::cohort::normalized_weights as norm_weights;

    /// Deterministic mock client: the "upload" depends only on the client
    /// id (like the real path, whose RNG is keyed by (seed, round, cid)),
    /// never on worker or execution order. Loss values are dyadic so f64
    /// sums are exact under any association.
    fn mock_result(cid: usize) -> ClientResult {
        let mut rng = Xoshiro256pp::new(hash_seed(&[0xBEEF, cid as u64]));
        let mut w = WireWriter::with_capacity(0);
        for &n in &VAR_LENS {
            let mut v = vec![0.0f32; n];
            rng.fill_normal(&mut v, 0.5);
            w.raw(&v);
        }
        ClientResult {
            upload: w.finish(),
            loss: 1.0 + cid as f64 * 0.25,
            peak_param_bytes: 1000 + cid,
            delta_saved: 0,
            sparse_saved: 0,
            sparse_selected: 0,
            sparse_total: 0,
            sparse_residual_sq: 0.0,
            residual: None,
        }
    }

    /// A mock job that records each produced upload by cohort index.
    fn recording_job(
        uploads: &Mutex<Vec<Option<Vec<u8>>>>,
    ) -> impl Fn(usize, &ClientPlan, &mut ClientScratch) -> Result<ClientResult> + Sync + '_
    {
        move |i: usize, plan: &ClientPlan, _cs: &mut ClientScratch| {
            let r = mock_result(plan.cid);
            uploads.lock().unwrap()[i] = Some(r.upload.clone());
            Ok(r)
        }
    }

    fn mixed_fates(i: usize) -> ClientFate {
        match i % 5 {
            3 => ClientFate::Dropped,
            4 => ClientFate::Late,
            _ => ClientFate::Completes,
        }
    }

    #[test]
    fn sharded_execution_matches_sequential() {
        let plans = mk_plans(13, mixed_fates);
        let norm_w = norm_weights(&plans);

        let seq_uploads = Mutex::new(vec![None; plans.len()]);
        let mut seq_scratch = ClientScratch::default();
        let (seq_stats, seq_agg) = run_cohort_sequential(
            &plans,
            &norm_w,
            &VAR_LENS,
            None,
            None,
            &mut seq_scratch,
            recording_job(&seq_uploads),
        )
        .unwrap();
        let mut seq_server = Server::new(
            VAR_LENS.iter().map(|&n| vec![0.0f32; n]).collect(),
        );
        seq_agg.apply(&mut seq_server).unwrap();

        for workers in [2usize, 4, 32] {
            let par_uploads = Mutex::new(vec![None; plans.len()]);
            let mut scratches: Vec<ClientScratch> =
                (0..workers).map(|_| ClientScratch::default()).collect();
            let (par_stats, par_agg) = run_cohort_sharded(
                &plans,
                &norm_w,
                &VAR_LENS,
                None,
                None,
                workers,
                &mut scratches,
                recording_job(&par_uploads),
            )
            .unwrap();

            // identical uploads, bit for bit, regardless of sharding
            assert_eq!(
                *seq_uploads.lock().unwrap(),
                *par_uploads.lock().unwrap(),
                "uploads differ at workers={workers}"
            );
            // identical accounting (dyadic losses ⇒ exact f64 sums)
            assert_eq!(seq_stats.up_bytes, par_stats.up_bytes);
            assert_eq!(
                seq_stats.up_bytes_discarded,
                par_stats.up_bytes_discarded
            );
            assert_eq!(seq_stats.trained, par_stats.trained);
            assert_eq!(seq_stats.completed, par_stats.completed);
            assert_eq!(seq_stats.dropped, par_stats.dropped);
            assert_eq!(seq_stats.late, par_stats.late);
            assert_eq!(
                seq_stats.peak_client_param_bytes,
                par_stats.peak_client_param_bytes
            );
            assert_eq!(seq_stats.loss_sum, par_stats.loss_sum);
            // the merged aggregate only reassociates f64 sums
            assert_eq!(par_agg.clients(), seq_stats.completed);
            let mut par_server = Server::new(
                VAR_LENS.iter().map(|&n| vec![0.0f32; n]).collect(),
            );
            par_agg.apply(&mut par_server).unwrap();
            for (a, b) in par_server.params.iter().zip(&seq_server.params) {
                for (x, y) in a.iter().zip(b) {
                    assert!(
                        (x - y).abs() <= 1e-6,
                        "sharded {x} vs sequential {y} (workers={workers})"
                    );
                }
            }
        }
    }

    #[test]
    fn pinned_execution_matches_sequential() {
        let plans = mk_plans(11, mixed_fates);
        let norm_w = norm_weights(&plans);

        let seq_uploads = Mutex::new(vec![None; plans.len()]);
        let mut seq_scratch = ClientScratch::default();
        let (seq_stats, seq_agg) = run_cohort_sequential(
            &plans,
            &norm_w,
            &VAR_LENS,
            None,
            None,
            &mut seq_scratch,
            recording_job(&seq_uploads),
        )
        .unwrap();
        let mut seq_server = Server::new(
            VAR_LENS.iter().map(|&n| vec![0.0f32; n]).collect(),
        );
        seq_agg.apply(&mut seq_server).unwrap();

        for workers in [1usize, 4] {
            let pin_uploads = Mutex::new(vec![None; plans.len()]);
            let mut cs = ClientScratch::default();
            let (pin_stats, pin_agg) = run_cohort_pinned(
                &plans,
                &norm_w,
                &VAR_LENS,
                None,
                None,
                workers,
                &mut cs,
                recording_job(&pin_uploads),
            )
            .unwrap();
            assert_eq!(
                *seq_uploads.lock().unwrap(),
                *pin_uploads.lock().unwrap()
            );
            assert_eq!(seq_stats.up_bytes, pin_stats.up_bytes);
            assert_eq!(seq_stats.completed, pin_stats.completed);
            assert_eq!(seq_stats.loss_sum, pin_stats.loss_sum);
            let mut pin_server = Server::new(
                VAR_LENS.iter().map(|&n| vec![0.0f32; n]).collect(),
            );
            pin_agg.apply(&mut pin_server).unwrap();
            for (a, b) in pin_server.params.iter().zip(&seq_server.params) {
                for (x, y) in a.iter().zip(b) {
                    if workers == 1 {
                        // one chunk merged into a zero target: exact
                        assert_eq!(x.to_bits(), y.to_bits());
                    } else {
                        assert!((x - y).abs() <= 1e-6);
                    }
                }
            }
        }
    }

    fn zero_server() -> Server {
        Server::new(VAR_LENS.iter().map(|&n| vec![0.0f32; n]).collect())
    }

    /// Property (docs/SCALE.md): with a single edge, the two-tier fold is
    /// bit-identical to flat sequential aggregation — the edge ships its
    /// weighted f64 sums cast to f32, and `apply` would have performed the
    /// exact same cast on the flat path.
    #[test]
    fn edged_single_edge_matches_sequential_bit_for_bit() {
        let plans = mk_plans(11, mixed_fates);
        let norm_w = norm_weights(&plans);

        let seq_uploads = Mutex::new(vec![None; plans.len()]);
        let mut seq_scratch = ClientScratch::default();
        let (seq_stats, seq_agg) = run_cohort_sequential(
            &plans,
            &norm_w,
            &VAR_LENS,
            None,
            None,
            &mut seq_scratch,
            recording_job(&seq_uploads),
        )
        .unwrap();
        let mut seq_server = zero_server();
        seq_agg.apply(&mut seq_server).unwrap();

        for integrity in [false, true] {
            let edge_uploads = Mutex::new(vec![None; plans.len()]);
            let mut cs = ClientScratch::default();
            let mut edge_prev = Vec::new();
            let (stats, root, es) = run_cohort_edged(
                &plans,
                &norm_w,
                &VAR_LENS,
                None,
                None,
                1,
                integrity,
                false,
                7,
                0,
                &mut edge_prev,
                &mut cs,
                recording_job(&edge_uploads),
            )
            .unwrap();
            // identical client execution, one merged frame on the hop
            assert_eq!(
                *seq_uploads.lock().unwrap(),
                *edge_uploads.lock().unwrap()
            );
            assert_eq!(stats.completed, seq_stats.completed);
            assert_eq!(stats.up_bytes, seq_stats.up_bytes);
            assert_eq!(stats.loss_sum, seq_stats.loss_sum);
            assert_eq!(es.frames, 1);
            assert!(es.up_bytes > 0);
            assert_eq!(root.clients(), seq_stats.completed);
            let mut edge_server = zero_server();
            root.apply(&mut edge_server).unwrap();
            for (a, b) in edge_server.params.iter().zip(&seq_server.params) {
                for (x, y) in a.iter().zip(b) {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "single-edge root must be bit-exact vs flat \
                         (integrity={integrity})"
                    );
                }
            }
        }
    }

    /// With several edges the root differs from flat aggregation only by
    /// f64 re-association plus one f32 cast per edge — the documented
    /// shard-merge tolerance (≤ 1e-6 per element).
    #[test]
    fn edged_multi_edge_matches_flat_within_merge_tolerance() {
        let plans = mk_plans(13, mixed_fates);
        let norm_w = norm_weights(&plans);

        let mut seq_scratch = ClientScratch::default();
        let (seq_stats, seq_agg) = run_cohort_sequential(
            &plans,
            &norm_w,
            &VAR_LENS,
            None,
            None,
            &mut seq_scratch,
            |_i, plan: &ClientPlan, _cs: &mut ClientScratch| {
                Ok(mock_result(plan.cid))
            },
        )
        .unwrap();
        let mut seq_server = zero_server();
        seq_agg.apply(&mut seq_server).unwrap();

        for edges in [2usize, 4, 32] {
            let mut cs = ClientScratch::default();
            let mut edge_prev = Vec::new();
            let (stats, root, es) = run_cohort_edged(
                &plans,
                &norm_w,
                &VAR_LENS,
                None,
                None,
                edges,
                true,
                false,
                7,
                0,
                &mut edge_prev,
                &mut cs,
                |_i, plan: &ClientPlan, _cs: &mut ClientScratch| {
                    Ok(mock_result(plan.cid))
                },
            )
            .unwrap();
            assert_eq!(stats.completed, seq_stats.completed);
            assert_eq!(stats.dropped, seq_stats.dropped);
            assert_eq!(stats.late, seq_stats.late);
            // only edges whose chunk had an accepted client ship a frame
            assert!(es.frames >= 1 && es.frames <= edges as u64);
            assert_eq!(root.clients(), seq_stats.completed);
            let mut edge_server = zero_server();
            root.apply(&mut edge_server).unwrap();
            for (a, b) in edge_server.params.iter().zip(&seq_server.params) {
                for (x, y) in a.iter().zip(b) {
                    assert!(
                        (x - y).abs() <= 1e-6,
                        "edged {x} vs flat {y} (edges={edges})"
                    );
                }
            }
        }
    }

    /// The edge→root hop reuses the cross-round XOR-delta stage: a round
    /// whose merged payload repeats the previous round's deltas away to
    /// almost nothing, losslessly, and round 0 always resets the bases
    /// (engines are reused across sweep cells).
    #[test]
    fn edged_delta_hop_saves_bytes_and_stays_lossless() {
        let plans = mk_plans(8, |_| ClientFate::Completes);
        let norm_w = norm_weights(&plans);
        let job = |_i: usize, plan: &ClientPlan, _cs: &mut ClientScratch| {
            Ok(mock_result(plan.cid))
        };
        let mut edge_prev = Vec::new();
        let mut cs = ClientScratch::default();
        // round 0: no base yet → verbatim frames
        let (_, root0, es0) = run_cohort_edged(
            &plans, &norm_w, &VAR_LENS, None, None, 2, true, true, 7, 0,
            &mut edge_prev, &mut cs, job,
        )
        .unwrap();
        assert_eq!(es0.delta_saved, 0);
        // round 1: the mock uploads depend only on cid, so the merged
        // payload repeats → the delta hop must save bytes
        let (_, root1, es1) = run_cohort_edged(
            &plans, &norm_w, &VAR_LENS, None, None, 2, true, true, 7, 1,
            &mut edge_prev, &mut cs, job,
        )
        .unwrap();
        assert!(
            es1.delta_saved > 0,
            "identical edge payloads must delta away"
        );
        assert!(es1.up_bytes < es0.up_bytes);
        // ...and losslessly: both roots finish to bit-identical servers
        let mut s0 = zero_server();
        root0.apply(&mut s0).unwrap();
        let mut s1 = zero_server();
        root1.apply(&mut s1).unwrap();
        for (a, b) in s0.params.iter().zip(&s1.params) {
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        // a fresh sweep cell re-enters at round 0: bases reset, frames
        // ship verbatim again
        let (_, _, es0b) = run_cohort_edged(
            &plans, &norm_w, &VAR_LENS, None, None, 2, true, true, 7, 0,
            &mut edge_prev, &mut cs, job,
        )
        .unwrap();
        assert_eq!(es0b.delta_saved, 0);
        assert_eq!(es0b.up_bytes, es0.up_bytes);
    }

    #[test]
    fn sequential_streaming_matches_reference_aggregate_bit_for_bit() {
        let plans = mk_plans(9, mixed_fates);
        let norm_w = norm_weights(&plans);
        let uploads = Mutex::new(vec![None; plans.len()]);
        let mut scratch = ClientScratch::default();
        let (_, agg) = run_cohort_sequential(
            &plans,
            &norm_w,
            &VAR_LENS,
            None,
            None,
            &mut scratch,
            recording_job(&uploads),
        )
        .unwrap();
        let mut streaming = Server::new(
            VAR_LENS.iter().map(|&n| vec![0.0f32; n]).collect(),
        );
        agg.apply(&mut streaming).unwrap();

        // reference: materialize exactly the completing clients' decoded
        // models and hand them to the slow-path Server::aggregate
        let uploads = uploads.into_inner().unwrap();
        let mut models = Vec::new();
        let mut weights = Vec::new();
        for (i, p) in plans.iter().enumerate() {
            if p.fate == ClientFate::Completes {
                models.push(
                    codec::decode_decompressed(uploads[i].as_ref().unwrap())
                        .unwrap(),
                );
                weights.push(p.weight);
            }
        }
        let mut reference = Server::new(
            VAR_LENS.iter().map(|&n| vec![0.0f32; n]).collect(),
        );
        reference.aggregate(&models, Some(&weights)).unwrap();

        for (a, b) in streaming.params.iter().zip(&reference.params) {
            assert_eq!(
                a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                b.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
            );
        }
    }

    #[test]
    fn fates_account_bytes_and_exclusions() {
        let plans = mk_plans(10, mixed_fates);
        let norm_w = norm_weights(&plans);
        let uploads = Mutex::new(vec![None; plans.len()]);
        let mut scratch = ClientScratch::default();
        let (stats, agg) = run_cohort_sequential(
            &plans,
            &norm_w,
            &VAR_LENS,
            None,
            None,
            &mut scratch,
            recording_job(&uploads),
        )
        .unwrap();
        // i % 5: 0,1,2 complete; 3 dropped; 4 late → of 10: 6/2/2
        assert_eq!(stats.completed, 6);
        assert_eq!(stats.dropped, 2);
        assert_eq!(stats.late, 2);
        assert_eq!(stats.trained, 8);
        assert_eq!(agg.clients(), 6);
        assert!((agg.total_weight() - 1.0).abs() < 1e-9);
        // dropped clients never uploaded
        let uploads = uploads.into_inner().unwrap();
        let late_bytes: usize = plans
            .iter()
            .enumerate()
            .filter(|(_, p)| p.fate == ClientFate::Late)
            .map(|(i, _)| uploads[i].as_ref().unwrap().len())
            .sum();
        let all_bytes: usize = uploads
            .iter()
            .flatten()
            .map(|u| u.len())
            .sum();
        assert_eq!(stats.up_bytes, all_bytes);
        assert_eq!(stats.up_bytes_discarded, late_bytes);
        assert!(plans
            .iter()
            .enumerate()
            .filter(|(_, p)| p.fate == ClientFate::Dropped)
            .all(|(i, _)| uploads[i].is_none()));
    }

    #[test]
    fn server_working_memory_independent_of_cohort_size() {
        let workers = 4usize;
        let mut accounted = Vec::new();
        for cohort in [4usize, 64] {
            let plans = mk_plans(cohort, |_| ClientFate::Completes);
            let norm_w = norm_weights(&plans);
            let uploads = Mutex::new(vec![None; plans.len()]);
            let mut scratches: Vec<ClientScratch> =
                (0..workers).map(|_| ClientScratch::default()).collect();
            let (stats, agg) = run_cohort_sharded(
                &plans,
                &norm_w,
                &VAR_LENS,
                None,
                None,
                workers,
                &mut scratches,
                recording_job(&uploads),
            )
            .unwrap();
            assert_eq!(agg.clients(), cohort);
            // read the same accounting run_round reports
            accounted.push(stats.server_accum_bytes());
        }
        assert_eq!(
            accounted[0], accounted[1],
            "server aggregation working set must not scale with cohort"
        );
    }

    #[test]
    fn all_failed_cohort_aggregates_nothing() {
        let plans = mk_plans(4, |i| {
            if i % 2 == 0 {
                ClientFate::Dropped
            } else {
                ClientFate::Late
            }
        });
        let norm_w = norm_weights(&plans);
        assert!(norm_w.iter().all(|&w| w == 0.0));
        let uploads = Mutex::new(vec![None; plans.len()]);
        let mut scratch = ClientScratch::default();
        let (stats, agg) = run_cohort_sequential(
            &plans,
            &norm_w,
            &VAR_LENS,
            None,
            None,
            &mut scratch,
            recording_job(&uploads),
        )
        .unwrap();
        assert_eq!(agg.clients(), 0);
        assert_eq!(stats.completed, 0);
        assert_eq!(stats.trained, 2); // late clients still trained
        assert!(stats.up_bytes > 0);
        assert_eq!(stats.up_bytes, stats.up_bytes_discarded);
    }

    /// v2 (checksummed) mock upload, nonce keyed by client id like the
    /// real uplink path.
    fn mock_result_v2(cid: usize) -> ClientResult {
        let mut rng = Xoshiro256pp::new(hash_seed(&[0xBEEF, cid as u64]));
        let mut w =
            WireWriter::with_integrity(0, uplink_nonce(0xBEEF, 7, cid as u64));
        for &n in &VAR_LENS {
            let mut v = vec![0.0f32; n];
            rng.fill_normal(&mut v, 0.5);
            w.raw(&v);
        }
        ClientResult {
            upload: w.finish(),
            loss: 1.0 + cid as f64 * 0.25,
            peak_param_bytes: 1000 + cid,
            delta_saved: 0,
            sparse_saved: 0,
            sparse_selected: 0,
            sparse_total: 0,
            sparse_residual_sq: 0.0,
            residual: None,
        }
    }

    fn v2_job(
        _i: usize,
        plan: &ClientPlan,
        _cs: &mut ClientScratch,
    ) -> Result<ClientResult> {
        Ok(mock_result_v2(plan.cid))
    }

    /// A cohort with every chaos shape represented: clean completers,
    /// retried-then-clean, duplicates, give-ups, crashes, plus the plain
    /// cohort fates.
    fn chaos_plans(n: usize) -> Vec<ClientPlan> {
        use crate::fl::chaos::{ClientChaos, FaultKind, PlannedFault};
        let flip = |p: u64| PlannedFault { kind: FaultKind::BitFlip, param: p };
        let cut = |p: u64| PlannedFault { kind: FaultKind::Truncate, param: p };
        (0..n)
            .map(|i| {
                let (fate, chaos) = match i % 7 {
                    1 => (
                        // all attempts corrupt: trained, nothing landed
                        ClientFate::Crashed,
                        Some(ClientChaos {
                            faults: vec![flip(13 + i as u64), cut(40 + i as u64)],
                            gave_up: true,
                            ..ClientChaos::default()
                        }),
                    ),
                    2 => (
                        // died before training, sent nothing
                        ClientFate::Crashed,
                        Some(ClientChaos {
                            crashed: true,
                            ..ClientChaos::default()
                        }),
                    ),
                    3 => (
                        // one corrupt attempt, then the clean delivery
                        ClientFate::Completes,
                        Some(ClientChaos {
                            faults: vec![flip(9999 + i as u64)],
                            ..ClientChaos::default()
                        }),
                    ),
                    4 => (
                        // clean delivery replayed once
                        ClientFate::Completes,
                        Some(ClientChaos {
                            duplicate: true,
                            ..ClientChaos::default()
                        }),
                    ),
                    5 => (ClientFate::Dropped, None),
                    6 => (ClientFate::Late, None),
                    _ => (ClientFate::Completes, None),
                };
                ClientPlan {
                    cid: 100 + i,
                    fate,
                    latency_s: 0.0,
                    weight: 1.0 + (i % 3) as f64,
                    chaos,
                }
            })
            .collect()
    }

    #[test]
    fn chaos_rejections_accounted_identically_on_every_path() {
        let plans = chaos_plans(21);
        let norm_w = norm_weights(&plans);
        let expected_rejected: u64 = plans
            .iter()
            .filter(|p| p.fate != ClientFate::Late)
            .filter_map(|p| p.chaos.as_ref())
            .map(|c| c.rejected_frames())
            .sum();
        assert!(expected_rejected >= 6, "cohort exercises every fault class");

        let mut seq_scratch = ClientScratch::default();
        let (seq, seq_agg) = run_cohort_sequential(
            &plans,
            &norm_w,
            &VAR_LENS,
            None,
            None,
            &mut seq_scratch,
            v2_job,
        )
        .unwrap();
        assert_eq!(seq.frames_rejected, expected_rejected);
        assert!(seq.up_bytes_rejected > 0);
        // conservation: every sampled client has exactly one fate
        assert_eq!(
            seq.completed + seq.dropped + seq.late + seq.crashed,
            plans.len()
        );
        // byte conservation: accepted + discarded + rejected == up_bytes
        let accepted_bytes: usize = plans
            .iter()
            .filter(|p| p.fate == ClientFate::Completes)
            .map(|p| mock_result_v2(p.cid).upload.len())
            .sum();
        assert_eq!(
            seq.up_bytes,
            accepted_bytes + seq.up_bytes_discarded + seq.up_bytes_rejected
        );
        // gave-up clients trained (and are in the loss mean); crashed did not
        let gave_up = plans
            .iter()
            .filter(|p| {
                p.chaos.as_ref().map_or(false, |c| c.gave_up && !c.crashed)
            })
            .count();
        let hard_crashed = plans
            .iter()
            .filter(|p| p.chaos.as_ref().map_or(false, |c| c.crashed))
            .count();
        assert_eq!(seq.crashed, gave_up + hard_crashed);
        assert_eq!(seq.trained, seq.completed + seq.late + gave_up);

        // identical accounting and aggregate on the parallel paths
        for workers in [2usize, 4] {
            let mut scratches: Vec<ClientScratch> =
                (0..workers).map(|_| ClientScratch::default()).collect();
            let (sh, sh_agg) = run_cohort_sharded(
                &plans,
                &norm_w,
                &VAR_LENS,
                None,
                None,
                workers,
                &mut scratches,
                v2_job,
            )
            .unwrap();
            let mut cs = ClientScratch::default();
            let (pin, pin_agg) = run_cohort_pinned(
                &plans,
                &norm_w,
                &VAR_LENS,
                None,
                None,
                workers,
                &mut cs,
                v2_job,
            )
            .unwrap();
            for s in [&sh, &pin] {
                assert_eq!(s.frames_rejected, seq.frames_rejected);
                assert_eq!(s.up_bytes_rejected, seq.up_bytes_rejected);
                assert_eq!(s.up_bytes, seq.up_bytes);
                assert_eq!(s.crashed, seq.crashed);
                assert_eq!(s.completed, seq.completed);
                assert_eq!(s.trained, seq.trained);
                assert_eq!(s.loss_sum, seq.loss_sum);
            }
            assert_eq!(sh_agg.clients(), seq_agg.clients());
            assert_eq!(pin_agg.clients(), seq_agg.clients());
        }
    }

    #[test]
    fn corrupt_frame_accepted_by_verifier_is_a_hard_error() {
        use crate::fl::chaos::{ClientChaos, FaultKind, PlannedFault};
        // a bit flip deep in a *v1* raw payload passes the structural walk
        // (no CRC to catch it) — the engine must refuse to run chaos over
        // an unverifiable wire rather than count a rejection that never
        // happened
        let mut plans = mk_plans(1, |_| ClientFate::Completes);
        plans[0].chaos = Some(ClientChaos {
            faults: vec![PlannedFault {
                kind: FaultKind::BitFlip,
                // bit 800 : byte 100, well inside var 0's f32 payload
                param: 800,
            }],
            ..ClientChaos::default()
        });
        let norm_w = norm_weights(&plans);
        let mut scratch = ClientScratch::default();
        let err = run_cohort_sequential(
            &plans,
            &norm_w,
            &VAR_LENS,
            None,
            None,
            &mut scratch,
            |_i, plan, _cs| Ok(mock_result(plan.cid)), // v1 frames
        )
        .unwrap_err();
        assert!(
            err.to_string().contains("passed verification"),
            "unexpected error: {err}"
        );
    }

    #[test]
    fn late_upgraded_clients_skip_fault_replay() {
        use crate::fl::chaos::{ClientChaos, FaultKind, PlannedFault};
        // a Late client with a chaos plan (backoff pushed it past the
        // deadline): its corrupt attempts are discarded unverified, so
        // nothing lands in the rejected counters
        let mut plans = mk_plans(2, |_| ClientFate::Completes);
        plans[1].fate = ClientFate::Late;
        plans[1].chaos = Some(ClientChaos {
            faults: vec![PlannedFault { kind: FaultKind::BitFlip, param: 3 }],
            ..ClientChaos::default()
        });
        let norm_w = norm_weights(&plans);
        let mut scratch = ClientScratch::default();
        let (stats, _) = run_cohort_sequential(
            &plans,
            &norm_w,
            &VAR_LENS,
            None,
            None,
            &mut scratch,
            v2_job,
        )
        .unwrap();
        assert_eq!(stats.frames_rejected, 0);
        assert_eq!(stats.up_bytes_rejected, 0);
        assert_eq!(stats.late, 1);
        assert_eq!(stats.completed, 1);
    }

    #[test]
    fn downlink_buffer_pool_survives_cohort_shrink() {
        let mut s = RoundScratch::new();
        // warm the pool with 4 buffers of real capacity
        s.return_downlink_bufs(
            (0..4).map(|_| Vec::with_capacity(4096)).collect(),
        );
        // a smaller round takes 2; the other 2 must stay pooled
        let bufs = s.take_downlink_bufs(2);
        assert_eq!(bufs.len(), 2);
        assert!(bufs.iter().all(|b| b.capacity() >= 4096));
        assert_eq!(s.downlink_bufs.len(), 2, "excess buffers were dropped");
        s.return_downlink_bufs(bufs);
        assert_eq!(s.downlink_bufs.len(), 4);
        // a larger round later reuses all four warmed buffers
        let bufs = s.take_downlink_bufs(5);
        assert_eq!(bufs.len(), 5);
        assert_eq!(
            bufs.iter().filter(|b| b.capacity() >= 4096).count(),
            4,
            "warmed capacity was lost across a cohort shrink"
        );
    }

    #[test]
    fn per_worker_scratches_grow_and_persist() {
        let mut s = RoundScratch::new();
        assert_eq!(s.client_scratches(3).len(), 3);
        // asking for fewer does not shrink the persistent set
        assert_eq!(s.client_scratches(1).len(), 1);
        assert_eq!(s.clients.len(), 3);
        assert_eq!(s.client_scratches(5).len(), 5);
        assert_eq!(s.clients.len(), 5);
    }

    /// v2 mock upload carrying one sparse record (var 0) and one raw var,
    /// with the matching `ClientResult` sparse accounting — exercises the
    /// stats plumbing and the sparse-base fold through every cohort path.
    fn mock_result_sparse(cid: usize) -> ClientResult {
        use crate::omc::format::FloatFormat;
        use crate::omc::sparse::{gather_into, select_topk};
        let fmt: FloatFormat = "S1E4M14".parse().unwrap();
        let mut rng = Xoshiro256pp::new(hash_seed(&[0x5EED, cid as u64]));
        let n = VAR_LENS[0];
        let mut e = vec![0.0f32; n];
        rng.fill_normal(&mut e, 0.5);
        let k = 8usize;
        let mut idx = Vec::new();
        select_topk(&e, k, &mut idx);
        let mut gathered = Vec::new();
        gather_into(&e, &idx, &mut gathered);
        let mut w =
            WireWriter::with_integrity(0, uplink_nonce(0xBEEF, 7, cid as u64));
        w.sparse_values(&gathered, &idx, n, fmt, true);
        let sparse_saved = w.sparse_saved();
        let mut v1 = vec![0.0f32; VAR_LENS[1]];
        rng.fill_normal(&mut v1, 0.5);
        w.raw(&v1);
        for &j in &idx {
            e[j as usize] = 0.0;
        }
        let sparse_residual_sq: f64 =
            e.iter().map(|&x| (x as f64) * (x as f64)).sum();
        ClientResult {
            upload: w.finish(),
            loss: 1.0 + cid as f64 * 0.25,
            peak_param_bytes: 1000 + cid,
            delta_saved: 0,
            sparse_saved,
            sparse_selected: k as u64,
            sparse_total: n as u64,
            sparse_residual_sq,
            residual: None,
        }
    }

    fn sparse_job(
        _i: usize,
        plan: &ClientPlan,
        _cs: &mut ClientScratch,
    ) -> Result<ClientResult> {
        Ok(mock_result_sparse(plan.cid))
    }

    #[test]
    fn sparse_stats_and_fold_accounted_identically_on_every_path() {
        let plans = mk_plans(9, mixed_fates);
        let norm_w = norm_weights(&plans);
        // the fold base stands in for the decoded downlink
        let sbase: Vec<Vec<f32>> = VAR_LENS
            .iter()
            .enumerate()
            .map(|(vi, &n)| {
                let mut v = vec![0.0f32; n];
                Xoshiro256pp::new(hash_seed(&[0xBA5E, vi as u64]))
                    .fill_normal(&mut v, 0.5);
                v
            })
            .collect();
        let mut seq_scratch = ClientScratch::default();
        let (seq, seq_agg) = run_cohort_sequential(
            &plans,
            &norm_w,
            &VAR_LENS,
            None,
            Some(&sbase),
            &mut seq_scratch,
            sparse_job,
        )
        .unwrap();
        // every trained client (completing AND late) banks its sparse
        // accounting — late frames are discarded but the training ran
        let trained: Vec<_> =
            plans.iter().filter(|p| p.fate != ClientFate::Dropped).collect();
        let expected_saved: usize = trained
            .iter()
            .map(|p| mock_result_sparse(p.cid).sparse_saved)
            .sum();
        assert!(expected_saved > 0, "top-8 of 300 must save wire bytes");
        assert_eq!(seq.up_bytes_sparse_saved, expected_saved);
        assert_eq!(seq.sparse_selected, 8 * trained.len() as u64);
        assert_eq!(seq.sparse_total, (VAR_LENS[0] * trained.len()) as u64);
        assert!(seq.sparse_residual_sq > 0.0);
        let mut seq_server = zero_server();
        seq_agg.apply(&mut seq_server).unwrap();

        for workers in [1usize, 4] {
            let mut cs = ClientScratch::default();
            let (pin, pin_agg) = run_cohort_pinned(
                &plans,
                &norm_w,
                &VAR_LENS,
                None,
                Some(&sbase),
                workers,
                &mut cs,
                sparse_job,
            )
            .unwrap();
            assert_eq!(pin.up_bytes_sparse_saved, seq.up_bytes_sparse_saved);
            assert_eq!(pin.sparse_selected, seq.sparse_selected);
            assert_eq!(pin.sparse_total, seq.sparse_total);
            // pinned absorbs client results in cohort order: exact
            assert_eq!(pin.sparse_residual_sq, seq.sparse_residual_sq);
            let mut s = zero_server();
            pin_agg.apply(&mut s).unwrap();
            for (a, b) in s.params.iter().zip(&seq_server.params) {
                for (x, y) in a.iter().zip(b) {
                    if workers == 1 {
                        assert_eq!(x.to_bits(), y.to_bits());
                    } else {
                        assert!((x - y).abs() <= 1e-6);
                    }
                }
            }
        }
        let mut scratches: Vec<ClientScratch> =
            (0..4).map(|_| ClientScratch::default()).collect();
        let (sh, _) = run_cohort_sharded(
            &plans,
            &norm_w,
            &VAR_LENS,
            None,
            Some(&sbase),
            4,
            &mut scratches,
            sparse_job,
        )
        .unwrap();
        assert_eq!(sh.up_bytes_sparse_saved, seq.up_bytes_sparse_saved);
        assert_eq!(sh.sparse_selected, seq.sparse_selected);
        // shard absorption only reassociates the f64 residual sum
        assert!(
            (sh.sparse_residual_sq - seq.sparse_residual_sq).abs()
                <= 1e-9 * seq.sparse_residual_sq.max(1.0)
        );
    }

    /// A sparse frame reaching a fold that holds no base is a harness
    /// bug, not a skip — the cohort run must fail loudly.
    #[test]
    fn sparse_frame_without_base_is_refused_by_the_fold() {
        let plans = mk_plans(3, |_| ClientFate::Completes);
        let norm_w = norm_weights(&plans);
        let mut scratch = ClientScratch::default();
        let err = run_cohort_sequential(
            &plans,
            &norm_w,
            &VAR_LENS,
            None,
            None,
            &mut scratch,
            sparse_job,
        )
        .unwrap_err();
        assert!(err.to_string().contains("sparse"), "unexpected error: {err}");
    }
}
